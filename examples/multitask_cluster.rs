//! Multi-task cluster walkthrough (paper §5 + §4.2): six Table 3 tasks on a
//! 128-GPU cluster managed by the coordinator state machine with the real
//! WAF planner. Injects the full Fig. 7 trigger set — SEV3 link flap (with
//! escalation), SEV2 CUDA error, SEV1 ECC, node join, task finish — and
//! prints the plan after every reconfiguration.
//!
//!     cargo run --release --example multitask_cluster

use unicron::config::{table3_case, ClusterSpec, UnicronConfig};
use unicron::coordinator::Coordinator;
use unicron::failure::ErrorKind;
use unicron::planner::PlanTask;
use unicron::proto::{Action, CoordEvent, DecisionLog, NodeId, TaskId};
use unicron::util::fmt_si;

fn show(coord: &Coordinator, label: &str) {
    println!("\n-- {label} --");
    println!("available workers: {}", coord.available_workers());
    for t in coord.tasks() {
        println!(
            "  task {} ({:<10} w={:.1}): {:>3} workers, F = {}FLOP/s",
            t.spec.id,
            t.spec.model,
            t.spec.weight,
            t.current,
            fmt_si(t.current_waf())
        );
    }
    println!("  cluster WAF: {}FLOP/s", fmt_si(coord.current_waf()));
}

fn act(coord: &mut Coordinator, ev: CoordEvent) {
    println!("\n>> event: {ev:?}");
    for a in coord.handle(ev) {
        match a {
            Action::ApplyPlan { plan, reason } => println!(
                "   action: ApplyPlan ({reason}) -> {:?} (WAF {}FLOP/s)",
                plan.assignment,
                fmt_si(plan.total_waf)
            ),
            other => println!("   action: {other:?}"),
        }
    }
}

fn main() {
    let cluster = ClusterSpec::default();
    let cfg = UnicronConfig::default();
    let n = cluster.total_gpus();

    let mut coord = Coordinator::builder()
        .config(cfg)
        .workers(n)
        .gpus_per_node(cluster.gpus_per_node)
        .tasks(table3_case(5).iter().map(|spec| PlanTask::from_spec(spec, &cluster, n)))
        .build();
    act(&mut coord, CoordEvent::TaskLaunched { task: TaskId(0) });
    show(&coord, "initial plan (Table 3 case 5, 128 GPUs)");

    // SEV3: transient link flap -> reattempt in place, then success
    act(
        &mut coord,
        CoordEvent::ErrorReport {
            node: NodeId(5),
            task: TaskId(3),
            kind: ErrorKind::LinkFlapping,
        },
    );
    act(&mut coord, CoordEvent::ReattemptResult { node: NodeId(5), task: TaskId(3), ok: true });

    // SEV2: CUDA error -> restart the process (config unchanged)
    act(
        &mut coord,
        CoordEvent::ErrorReport { node: NodeId(2), task: TaskId(1), kind: ErrorKind::CudaError },
    );
    act(&mut coord, CoordEvent::RestartResult { node: NodeId(2), task: TaskId(1), ok: true });
    show(&coord, "after SEV3 + SEV2 (no reconfiguration needed)");

    // SEV1: ECC error -> isolate node + cost-aware replan
    act(
        &mut coord,
        CoordEvent::ErrorReport { node: NodeId(9), task: TaskId(4), kind: ErrorKind::EccError },
    );
    show(&coord, "after SEV1 (120 workers)");

    // another node dies outright (lease expiry)
    act(&mut coord, CoordEvent::NodeLost { node: NodeId(3) });
    show(&coord, "after node loss (112 workers)");

    // repaired node rejoins (trigger ④)
    act(&mut coord, CoordEvent::NodeJoined { node: NodeId(9) });
    show(&coord, "after node 9 rejoined (120 workers)");

    // task finishes (trigger ⑤): its workers are redistributed
    act(&mut coord, CoordEvent::TaskFinished { task: TaskId(0) });
    show(&coord, "after task 0 finished");

    // The audit log is a serializable protocol artifact: any session can be
    // captured to bytes and replayed as a regression test (proto layer).
    let bytes = coord.log.to_bytes();
    let revived = DecisionLog::from_bytes(&bytes).expect("decision log must round-trip");
    println!(
        "\nhandled {} events ({} bytes as a DecisionLog artifact); see DESIGN.md §4-§7.",
        revived.len(),
        bytes.len()
    );
}
