//! Multi-task cluster walkthrough (paper §5 + §4.2): six Table 3 tasks on a
//! 128-GPU cluster managed by the coordinator state machine with the real
//! WAF planner. Injects the full Fig. 7 trigger set — SEV3 link flap (with
//! escalation), SEV2 CUDA error, SEV1 ECC, node join, task finish — and
//! prints the plan after every reconfiguration.
//!
//!     cargo run --release --example multitask_cluster

use unicron::config::{table3_case, ClusterSpec, ModelSpec, UnicronConfig};
use unicron::coordinator::{Action, CoordEvent, Coordinator};
use unicron::failure::ErrorKind;
use unicron::perfmodel::throughput_table;
use unicron::planner::PlanTask;
use unicron::util::fmt_si;

fn show(coord: &Coordinator, label: &str) {
    println!("\n-- {label} --");
    println!("available workers: {}", coord.available_workers);
    for t in coord.tasks() {
        println!(
            "  task {} ({:<10} w={:.1}): {:>3} workers, F = {}FLOP/s",
            t.spec.id,
            t.spec.model,
            t.spec.weight,
            t.current,
            fmt_si(t.waf(t.current))
        );
    }
    println!("  cluster WAF: {}FLOP/s", fmt_si(coord.current_waf()));
}

fn act(coord: &mut Coordinator, ev: CoordEvent) {
    println!("\n>> event: {ev:?}");
    for a in coord.handle(ev) {
        match a {
            Action::ApplyPlan { plan, reason } => println!(
                "   action: ApplyPlan ({reason}) -> {:?} (WAF {}FLOP/s)",
                plan.assignment,
                fmt_si(plan.total_waf)
            ),
            other => println!("   action: {other:?}"),
        }
    }
}

fn main() {
    let cluster = ClusterSpec::default();
    let cfg = UnicronConfig::default();
    let n = cluster.total_gpus();

    let mut coord = Coordinator::new(cfg, n, cluster.gpus_per_node);
    for spec in table3_case(5) {
        let model = ModelSpec::gpt3(&spec.model).unwrap();
        coord.add_task(PlanTask {
            throughput: throughput_table(&model, &cluster, n),
            spec,
            current: 0,
            fault: false,
        });
    }
    act(&mut coord, CoordEvent::TaskLaunched { task: 0 });
    show(&coord, "initial plan (Table 3 case 5, 128 GPUs)");

    // SEV3: transient link flap -> reattempt in place, then success
    act(&mut coord, CoordEvent::ErrorReport { node: 5, task: 3, kind: ErrorKind::LinkFlapping });
    act(&mut coord, CoordEvent::ReattemptResult { node: 5, task: 3, ok: true });

    // SEV2: CUDA error -> restart the process (config unchanged)
    act(&mut coord, CoordEvent::ErrorReport { node: 2, task: 1, kind: ErrorKind::CudaError });
    act(&mut coord, CoordEvent::RestartResult { node: 2, task: 1, ok: true });
    show(&coord, "after SEV3 + SEV2 (no reconfiguration needed)");

    // SEV1: ECC error -> isolate node + cost-aware replan
    act(&mut coord, CoordEvent::ErrorReport { node: 9, task: 4, kind: ErrorKind::EccError });
    show(&coord, "after SEV1 (120 workers)");

    // another node dies outright (lease expiry)
    act(&mut coord, CoordEvent::NodeLost { node: 3 });
    show(&coord, "after node loss (112 workers)");

    // repaired node rejoins (trigger ④)
    act(&mut coord, CoordEvent::NodeJoined { node: 9 });
    show(&coord, "after node 9 rejoined (120 workers)");

    // task finishes (trigger ⑤): its workers are redistributed
    act(&mut coord, CoordEvent::TaskFinished { task: 0 });
    show(&coord, "after task 0 finished");

    println!("\nhandled {} events; see DESIGN.md §4 for the module map.", coord.log.len());
}
