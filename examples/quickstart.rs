//! Quickstart: load an AOT-compiled GPT artifact and take a few real
//! training steps through PJRT — the smallest end-to-end path through the
//! stack (Python authored the model once at build time; this binary never
//! touches Python).
//!
//!     make artifacts && cargo run --release --example quickstart

use unicron::trainer::{DpTrainer, LrSchedule, TrainerConfig};

fn main() -> anyhow::Result<()> {
    let model = std::env::args().nth(1).unwrap_or_else(|| "tiny".into());
    let dir = std::path::Path::new("artifacts").join(&model);

    let mut trainer = DpTrainer::new(TrainerConfig {
        artifact_dir: dir,
        dp: 2,
        micro_batches: 4,
        schedule: LrSchedule { base: 5e-3, warmup_steps: 2, total_steps: 20 },
        init_seed: 0,
        data_seed: 7,
    })?;

    println!(
        "loaded {model}: {} params across {} tensors; dp=2, 4 micro-batches/step",
        trainer.manifest.n_params,
        trainer.manifest.params.len()
    );
    println!("{:>5} {:>9} {:>11} {:>9}", "step", "loss", "grad-norm", "time");
    for _ in 0..10 {
        let r = trainer.train_step()?;
        println!(
            "{:>5} {:>9.4} {:>11.3e} {:>8.0}ms",
            r.step,
            r.loss,
            r.grad_norm,
            r.duration_s * 1e3
        );
    }
    println!("done — the loss above should be visibly decreasing.");
    Ok(())
}
