//! End-to-end validation (DESIGN.md §6): train a GPT model for hundreds of
//! steps through the real PJRT path with data-parallel workers, inject
//! worker failures mid-iteration, and let the self-healing machinery do its
//! job — micro-batch redistribution finishes the interrupted global batch
//! (paper §6.2), then the dead rank is revived from a healthy DP replica
//! (nearest principle, §6.3). The loss curve is written to
//! `selfheal_loss.csv` and summarized at the end.
//!
//!     cargo run --release --example selfheal_train -- [model] [steps] [dp]
//!
//! Defaults: mini, 300 steps, dp=2. The ~110M-parameter run recorded in
//! EXPERIMENTS.md used: gpt100m 300 2 (CPU: several seconds per step).

use std::io::Write as _;

use unicron::trainer::{DpTrainer, LrSchedule, TrainerConfig};
use unicron::util::fmt_duration;

fn main() -> anyhow::Result<()> {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let model = args.first().cloned().unwrap_or_else(|| "mini".into());
    let steps: u64 = args.get(1).and_then(|s| s.parse().ok()).unwrap_or(300);
    let dp: usize = args.get(2).and_then(|s| s.parse().ok()).unwrap_or(2);
    let micro_batches = dp * 2;

    let mut trainer = DpTrainer::new(TrainerConfig {
        artifact_dir: std::path::Path::new("artifacts").join(&model),
        dp,
        micro_batches,
        schedule: LrSchedule { base: 3e-3, warmup_steps: steps / 20, total_steps: steps },
        init_seed: 0,
        data_seed: 1,
    })?;
    println!(
        "self-healing training: {model} ({} params), dp={dp}, {micro_batches} micro-batches/step, {steps} steps",
        trainer.manifest.n_params
    );

    // Failure schedule: a worker dies mid-iteration at 20%, 50% and 80% of
    // the run (round-robin over ranks, after 1 completed micro-batch).
    let fail_steps: Vec<u64> = vec![steps / 5, steps / 2, 4 * steps / 5];

    let mut csv = std::fs::File::create("selfheal_loss.csv")?;
    writeln!(csv, "step,loss,grad_norm,lr,duration_s,failures,redistributed")?;

    let t0 = std::time::Instant::now();
    let mut first_loss = None;
    let mut last_loss = 0.0;
    let mut total_failures = 0;
    let mut window: Vec<f64> = Vec::new();

    for step in 0..steps {
        if let Some(i) = fail_steps.iter().position(|&s| s == step) {
            let victim = i % dp;
            println!(">>> injecting SEV2 failure: rank {victim} will die mid-iteration");
            trainer.inject_failure(victim, 1);
        }
        let r = trainer.train_step()?;
        first_loss.get_or_insert(r.loss);
        last_loss = r.loss;
        window.push(r.loss);
        writeln!(
            csv,
            "{},{:.6},{:.6e},{:.6e},{:.4},{},{}",
            r.step,
            r.loss,
            r.grad_norm,
            r.lr,
            r.duration_s,
            r.failures.len(),
            r.redistributed
        )?;
        if !r.failures.is_empty() {
            total_failures += r.failures.len();
            println!(
                "    step {}: rank(s) {:?} died; {} micro-batches redistributed; iteration completed with loss {:.4}",
                r.step, r.failures, r.redistributed, r.loss
            );
            for rank in r.failures {
                trainer.revive(rank)?;
            }
            println!("    revived from healthy DP replica; alive = {:?}", trainer.alive_ranks());
        }
        if r.step % (steps / 10).max(1) == 0 {
            let recent = window.iter().rev().take(20).sum::<f64>()
                / window.iter().rev().take(20).count() as f64;
            println!(
                "step {:>5}/{steps}  loss {:.4} (avg20 {recent:.4})  lr {:.2e}  {}",
                r.step,
                r.loss,
                r.lr,
                fmt_duration(r.duration_s)
            );
        }
    }

    let first = first_loss.unwrap();
    let tail = window.iter().rev().take(20).sum::<f64>() / 20.0_f64.min(window.len() as f64);
    println!("\n==== summary ====");
    println!("wall time: {}", fmt_duration(t0.elapsed().as_secs_f64()));
    println!("loss: {first:.4} -> {last_loss:.4} (tail-20 avg {tail:.4})");
    println!("failures injected+healed: {total_failures}");
    println!("loss curve: selfheal_loss.csv");
    anyhow::ensure!(tail < first - 0.3, "training did not learn (loss {first:.3} -> {tail:.3})");
    println!("VALIDATED: loss decreased through {total_failures} mid-iteration failures.");
    Ok(())
}
