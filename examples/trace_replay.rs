//! Fig. 11 in your terminal: replay failure trace-a or trace-b against all
//! five recovery policies and chart the cluster WAF over time.
//!
//!     cargo run --release --example trace_replay -- [a|b] [seed]

use unicron::config::{table3_case, ClusterSpec, UnicronConfig};
use unicron::failure::{Severity, Trace, TraceConfig};
use unicron::metrics::Figure;
use unicron::simulator::{compare_policies, PolicyKind};
use unicron::util::{fmt_duration, fmt_si};

fn main() {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let which = args.first().map(String::as_str).unwrap_or("a");
    let seed: u64 = args.get(1).and_then(|s| s.parse().ok()).unwrap_or(42);
    let tc = match which {
        "a" => TraceConfig::trace_a(),
        "b" => TraceConfig::trace_b(),
        other => {
            eprintln!("unknown trace {other:?} (want a|b)");
            std::process::exit(1);
        }
    };

    let trace = Trace::generate(tc.clone(), seed);
    println!(
        "{}: {} over {} — {} SEV1 (node drain), {} SEV2/SEV3",
        tc.name,
        trace.events.len(),
        fmt_duration(tc.duration_s),
        trace.count_by_severity(Severity::Sev1),
        trace.events.len() - trace.count_by_severity(Severity::Sev1),
    );

    let cluster = ClusterSpec::default();
    let cfg = UnicronConfig::default();
    let specs = table3_case(5);
    let results = compare_policies(&cluster, &cfg, &specs, &trace);
    let uni = results.iter().find(|r| r.policy == PolicyKind::Unicron).unwrap().accumulated_waf;

    println!("\n{:<10} {:>14} {:>18} {:>11} {:>10}", "system", "mean WAF", "accumulated", "reduction", "Unicron ×");
    for r in &results {
        println!(
            "{:<10} {:>11}FL/s {:>15}FL·s {:>10.1}% {:>9.1}×",
            r.policy.name(),
            fmt_si(r.mean_waf()),
            fmt_si(r.accumulated_waf),
            r.reduction() * 100.0,
            uni / r.accumulated_waf.max(1.0),
        );
    }

    let mut fig = Figure::new(&format!("WAF over time — {}", tc.name), "days", "PFLOP/s");
    for r in &results {
        let s = fig.series_mut(r.policy.name());
        let step = (r.waf_series.len() / 200).max(1);
        for (i, &(t, w)) in r.waf_series.iter().enumerate() {
            if i % step == 0 {
                s.push(t / 86400.0, w / 1e15);
            }
        }
    }
    println!("\n{}", fig.ascii_chart(110, 20));
    fig.save_csv(format!("trace_{which}_waf.csv")).ok();
    println!("series written to trace_{which}_waf.csv");
}
