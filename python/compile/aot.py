"""AOT compile path: lower the L2 training graphs to HLO **text** artifacts.

Run once by ``make artifacts``; Python never runs again after this. For every
named :data:`compile.model.CONFIGS` entry it writes

    artifacts/<name>/micro_step.hlo.txt     (params…, tokens) -> (loss, grads…)
    artifacts/<name>/apply_update.hlo.txt   (params…, m…, v…, grads…, step, lr)
                                            -> (params…, m…, v…)
    artifacts/<name>/manifest.json          tensor table + io orders + config

HLO *text* (not ``HloModuleProto.serialize()``) is the interchange format:
jax >= 0.5 emits protos with 64-bit instruction ids which xla_extension 0.5.1
(the version the published ``xla`` 0.1.6 crate binds) rejects
(``proto.id() <= INT_MAX``); the text parser reassigns ids and round-trips
cleanly. See /opt/xla-example/README.md.
"""

from __future__ import annotations

import argparse
import json
import math
import os
import sys
import time

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from compile import model as M


def to_hlo_text(lowered) -> str:
    """StableHLO -> XlaComputation -> HLO text (ids reassigned by the parser)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def build_manifest(cfg: M.GptConfig) -> dict:
    table = cfg.param_table()
    names = [name for name, _, _, _ in table]
    return {
        "format_version": 1,
        "config": {
            "name": cfg.name,
            "vocab": cfg.vocab,
            "d_model": cfg.d_model,
            "n_layers": cfg.n_layers,
            "n_heads": cfg.n_heads,
            "seq_len": cfg.seq_len,
            "micro_batch": cfg.micro_batch,
            "n_params": cfg.n_params(),
            "flops_per_token": cfg.flops_per_token(),
            "beta1": cfg.beta1,
            "beta2": cfg.beta2,
            "eps": cfg.eps,
            "weight_decay": cfg.weight_decay,
        },
        "params": [
            {"name": name, "shape": list(shape), "init": init, "decay": decay,
             "elems": math.prod(shape)}
            for name, shape, init, decay in table
        ],
        "micro_step": {
            "inputs": [f"param:{n}" for n in names] + ["tokens"],
            "outputs": ["loss"] + [f"grad:{n}" for n in names],
            "tokens_shape": [cfg.micro_batch, cfg.seq_len + 1],
            "tokens_dtype": "s32",
        },
        "apply_update": {
            "inputs": ([f"param:{n}" for n in names] + [f"m:{n}" for n in names]
                        + [f"v:{n}" for n in names] + [f"grad:{n}" for n in names]
                        + ["step", "lr"]),
            "outputs": ([f"param:{n}" for n in names] + [f"m:{n}" for n in names]
                         + [f"v:{n}" for n in names]),
        },
    }


def lower_config(cfg: M.GptConfig, outdir: str) -> None:
    os.makedirs(outdir, exist_ok=True)
    t0 = time.time()

    spec = {name: jax.ShapeDtypeStruct(shape, jnp.float32)
            for name, shape, _, _ in cfg.param_table()}
    tokens_spec = jax.ShapeDtypeStruct((cfg.micro_batch, cfg.seq_len + 1), jnp.int32)
    scalar = jax.ShapeDtypeStruct((), jnp.float32)

    micro = jax.jit(lambda p, t: M.micro_step(cfg, p, t)).lower(spec, tokens_spec)
    with open(os.path.join(outdir, "micro_step.hlo.txt"), "w") as f:
        f.write(to_hlo_text(micro))

    upd = jax.jit(lambda p, m, v, g, s, lr: M.apply_update(cfg, p, m, v, g, s, lr)).lower(
        spec, spec, spec, spec, scalar, scalar)
    with open(os.path.join(outdir, "apply_update.hlo.txt"), "w") as f:
        f.write(to_hlo_text(upd))

    with open(os.path.join(outdir, "manifest.json"), "w") as f:
        json.dump(build_manifest(cfg), f, indent=1)

    print(f"[aot] {cfg.name}: {cfg.n_params():,} params lowered in {time.time()-t0:.1f}s "
          f"-> {outdir}", flush=True)


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out", default="../artifacts", help="artifacts directory")
    ap.add_argument("--configs", default="tiny,mini,gpt100m",
                    help="comma-separated config names (see compile.model.CONFIGS)")
    args = ap.parse_args()

    for name in args.configs.split(","):
        name = name.strip()
        if not name:
            continue
        if name not in M.CONFIGS:
            sys.exit(f"unknown config {name!r}; known: {sorted(M.CONFIGS)}")
        lower_config(M.CONFIGS[name], os.path.join(args.out, name))


if __name__ == "__main__":
    main()
