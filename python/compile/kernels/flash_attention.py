"""Flash attention as a Pallas kernel (forward + backward, custom_vjp).

Hardware adaptation (paper targets A800/CUDA; see DESIGN.md §5): the tiled
online-softmax schedule CUDA implementations express with threadblocks and
shared memory is expressed here with a Pallas ``grid`` + ``BlockSpec`` over
VMEM tiles, shaped for the TPU MXU:

  * grid ``(batch*heads, seq/block_q)``; each program owns one ``(block_q, d)``
    query tile resident in VMEM and streams ``(block_k, d)`` key/value tiles
    with ``pl.dslice`` loads — the HBM→VMEM pipeline that threadblocks +
    cp.async do on GPUs.
  * block sizes default to 128 (MXU systolic array edge) clipped to the
    sequence length; accumulators are f32 as they would be on the MXU.
  * the causal variant skips entirely-masked key blocks (``hi`` loop bound),
    the same work-skipping as FlashAttention's causal kernel.

All kernels run with ``interpret=True``: real TPU lowering emits a Mosaic
custom-call the CPU PJRT plugin cannot execute, while interpret-mode lowers to
plain HLO that runs anywhere (and is what ``aot.py`` bakes into artifacts).

VMEM footprint estimate per program (f32, d=64, block=128):
  q tile 32 KiB + k/v tiles 64 KiB + acc 32 KiB + m/l 1 KiB ≈ 130 KiB
— far under the ~16 MiB VMEM budget, leaving room for double buffering.
"""

from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

NEG_INF = -1e30

DEFAULT_BLOCK = 128


def _block_sizes(seq: int, block_q: int | None, block_k: int | None) -> Tuple[int, int]:
    bq = min(block_q or DEFAULT_BLOCK, seq)
    bk = min(block_k or DEFAULT_BLOCK, seq)
    if seq % bq or seq % bk:
        raise ValueError(f"seq={seq} must be a multiple of block_q={bq} and block_k={bk}")
    return bq, bk


# ---------------------------------------------------------------------------
# Forward
# ---------------------------------------------------------------------------


def _fwd_kernel(q_ref, k_ref, v_ref, o_ref, lse_ref, *, scale, block_q, block_k, seq, causal):
    qi = pl.program_id(1)
    q = q_ref[0].astype(jnp.float32) * scale  # (block_q, d)
    d = q.shape[-1]

    num_kv = seq // block_k
    if causal:
        # Highest kv block that intersects the visible (lower-triangular)
        # region of this q tile; later blocks are fully masked -> skipped.
        hi = (qi * block_q + block_q + block_k - 1) // block_k
    else:
        hi = num_kv

    q_idx = qi * block_q + jax.lax.iota(jnp.int32, block_q)

    def body(j, carry):
        acc, m, l = carry
        k = pl.load(k_ref, (0, pl.dslice(j * block_k, block_k), slice(None))).astype(jnp.float32)
        v = pl.load(v_ref, (0, pl.dslice(j * block_k, block_k), slice(None))).astype(jnp.float32)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())))  # (block_q, block_k)
        if causal:
            k_idx = j * block_k + jax.lax.iota(jnp.int32, block_k)
            mask = q_idx[:, None] >= k_idx[None, :]
            s = jnp.where(mask, s, NEG_INF)
        m_new = jnp.maximum(m, jnp.max(s, axis=-1))
        p = jnp.exp(s - m_new[:, None])
        alpha = jnp.exp(m - m_new)
        l_new = l * alpha + jnp.sum(p, axis=-1)
        acc_new = acc * alpha[:, None] + jax.lax.dot_general(p, v, (((1,), (0,)), ((), ())))
        return acc_new, m_new, l_new

    init = (
        jnp.zeros((block_q, d), jnp.float32),
        jnp.full((block_q,), NEG_INF, jnp.float32),
        jnp.zeros((block_q,), jnp.float32),
    )
    acc, m, l = jax.lax.fori_loop(0, hi, body, init)
    o_ref[0] = (acc / l[:, None]).astype(o_ref.dtype)
    lse_ref[0] = (m + jnp.log(l)).astype(lse_ref.dtype)


def _fwd(q, k, v, *, causal, block_q, block_k):
    b, h, s, d = q.shape
    bq, bk = _block_sizes(s, block_q, block_k)
    scale = 1.0 / (d ** 0.5)
    qf = q.reshape(b * h, s, d)
    kf = k.reshape(b * h, s, d)
    vf = v.reshape(b * h, s, d)

    kernel = functools.partial(
        _fwd_kernel, scale=scale, block_q=bq, block_k=bk, seq=s, causal=causal
    )
    o, lse = pl.pallas_call(
        kernel,
        grid=(b * h, s // bq),
        in_specs=[
            pl.BlockSpec((1, bq, d), lambda i, j: (i, j, 0)),
            pl.BlockSpec((1, s, d), lambda i, j: (i, 0, 0)),
            pl.BlockSpec((1, s, d), lambda i, j: (i, 0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, bq, d), lambda i, j: (i, j, 0)),
            pl.BlockSpec((1, bq), lambda i, j: (i, j)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((b * h, s, d), q.dtype),
            jax.ShapeDtypeStruct((b * h, s), jnp.float32),
        ],
        interpret=True,
    )(qf, kf, vf)
    return o.reshape(b, h, s, d), lse.reshape(b, h, s)


# ---------------------------------------------------------------------------
# Backward
# ---------------------------------------------------------------------------


def _dq_kernel(q_ref, k_ref, v_ref, do_ref, lse_ref, delta_ref, dq_ref, *, scale, block_q, block_k, seq, causal):
    qi = pl.program_id(1)
    q = q_ref[0].astype(jnp.float32)
    do = do_ref[0].astype(jnp.float32)
    lse = lse_ref[0]
    delta = delta_ref[0]
    d = q.shape[-1]

    num_kv = seq // block_k
    hi = (qi * block_q + block_q + block_k - 1) // block_k if causal else num_kv
    q_idx = qi * block_q + jax.lax.iota(jnp.int32, block_q)

    def body(j, dq):
        k = pl.load(k_ref, (0, pl.dslice(j * block_k, block_k), slice(None))).astype(jnp.float32)
        v = pl.load(v_ref, (0, pl.dslice(j * block_k, block_k), slice(None))).astype(jnp.float32)
        z = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ()))) * scale
        if causal:
            k_idx = j * block_k + jax.lax.iota(jnp.int32, block_k)
            z = jnp.where(q_idx[:, None] >= k_idx[None, :], z, NEG_INF)
        p = jnp.exp(z - lse[:, None])
        dp = jax.lax.dot_general(do, v, (((1,), (1,)), ((), ())))
        dz = p * (dp - delta[:, None]) * scale
        return dq + jax.lax.dot_general(dz, k, (((1,), (0,)), ((), ())))

    dq = jax.lax.fori_loop(0, hi, body, jnp.zeros((block_q, d), jnp.float32))
    dq_ref[0] = dq.astype(dq_ref.dtype)


def _dkv_kernel(q_ref, k_ref, v_ref, do_ref, lse_ref, delta_ref, dk_ref, dv_ref, *, scale, block_q, block_k, seq, causal):
    ki = pl.program_id(1)
    k = k_ref[0].astype(jnp.float32)  # (block_k, d)
    v = v_ref[0].astype(jnp.float32)
    d = k.shape[-1]

    num_q = seq // block_q
    # Causal: q tiles strictly before this kv tile see none of it.
    lo = (ki * block_k) // block_q if causal else 0
    k_idx = ki * block_k + jax.lax.iota(jnp.int32, block_k)

    def body(i, carry):
        dk, dv = carry
        q = pl.load(q_ref, (0, pl.dslice(i * block_q, block_q), slice(None))).astype(jnp.float32)
        do = pl.load(do_ref, (0, pl.dslice(i * block_q, block_q), slice(None))).astype(jnp.float32)
        lse = pl.load(lse_ref, (0, pl.dslice(i * block_q, block_q)))
        delta = pl.load(delta_ref, (0, pl.dslice(i * block_q, block_q)))
        z = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ()))) * scale  # (block_q, block_k)
        if causal:
            q_idx = i * block_q + jax.lax.iota(jnp.int32, block_q)
            z = jnp.where(q_idx[:, None] >= k_idx[None, :], z, NEG_INF)
        p = jnp.exp(z - lse[:, None])
        dv_new = dv + jax.lax.dot_general(p, do, (((0,), (0,)), ((), ())))  # (block_k, d)
        dp = jax.lax.dot_general(do, v, (((1,), (1,)), ((), ())))
        dz = p * (dp - delta[:, None]) * scale
        dk_new = dk + jax.lax.dot_general(dz, q, (((0,), (0,)), ((), ())))
        return dk_new, dv_new

    init = (jnp.zeros((block_k, d), jnp.float32), jnp.zeros((block_k, d), jnp.float32))
    dk, dv = jax.lax.fori_loop(lo, num_q, body, init)
    dk_ref[0] = dk.astype(dk_ref.dtype)
    dv_ref[0] = dv.astype(dv_ref.dtype)


def _bwd(causal, block_q, block_k, res, do):
    q, k, v, o, lse = res
    b, h, s, d = q.shape
    bq, bk = _block_sizes(s, block_q, block_k)
    scale = 1.0 / (d ** 0.5)

    # delta_i = rowsum(dO_i * O_i) — tiny elementwise reduction; computed in
    # plain jnp (fuses into the surrounding HLO) rather than its own kernel.
    delta = jnp.sum(do.astype(jnp.float32) * o.astype(jnp.float32), axis=-1)  # (b,h,s)

    qf = q.reshape(b * h, s, d)
    kf = k.reshape(b * h, s, d)
    vf = v.reshape(b * h, s, d)
    dof = do.reshape(b * h, s, d)
    lsef = lse.reshape(b * h, s)
    deltaf = delta.reshape(b * h, s)

    dq = pl.pallas_call(
        functools.partial(_dq_kernel, scale=scale, block_q=bq, block_k=bk, seq=s, causal=causal),
        grid=(b * h, s // bq),
        in_specs=[
            pl.BlockSpec((1, bq, d), lambda i, j: (i, j, 0)),
            pl.BlockSpec((1, s, d), lambda i, j: (i, 0, 0)),
            pl.BlockSpec((1, s, d), lambda i, j: (i, 0, 0)),
            pl.BlockSpec((1, bq, d), lambda i, j: (i, j, 0)),
            pl.BlockSpec((1, bq), lambda i, j: (i, j)),
            pl.BlockSpec((1, bq), lambda i, j: (i, j)),
        ],
        out_specs=pl.BlockSpec((1, bq, d), lambda i, j: (i, j, 0)),
        out_shape=jax.ShapeDtypeStruct((b * h, s, d), q.dtype),
        interpret=True,
    )(qf, kf, vf, dof, lsef, deltaf)

    dk, dv = pl.pallas_call(
        functools.partial(_dkv_kernel, scale=scale, block_q=bq, block_k=bk, seq=s, causal=causal),
        grid=(b * h, s // bk),
        in_specs=[
            pl.BlockSpec((1, s, d), lambda i, j: (i, 0, 0)),
            pl.BlockSpec((1, bk, d), lambda i, j: (i, j, 0)),
            pl.BlockSpec((1, bk, d), lambda i, j: (i, j, 0)),
            pl.BlockSpec((1, s, d), lambda i, j: (i, 0, 0)),
            pl.BlockSpec((1, s), lambda i, j: (i, 0)),
            pl.BlockSpec((1, s), lambda i, j: (i, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, bk, d), lambda i, j: (i, j, 0)),
            pl.BlockSpec((1, bk, d), lambda i, j: (i, j, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((b * h, s, d), q.dtype),
            jax.ShapeDtypeStruct((b * h, s, d), q.dtype),
        ],
        interpret=True,
    )(qf, kf, vf, dof, lsef, deltaf)

    return (
        dq.reshape(b, h, s, d),
        dk.reshape(b, h, s, d),
        dv.reshape(b, h, s, d),
    )


# ---------------------------------------------------------------------------
# Public API
# ---------------------------------------------------------------------------


@functools.partial(jax.custom_vjp, nondiff_argnums=(3, 4, 5))
def flash_attention(q, k, v, causal: bool = True, block_q: int | None = None, block_k: int | None = None):
    """Tiled online-softmax attention; differentiable via custom flash bwd.

    Args:
      q, k, v: ``(batch, heads, seq, head_dim)``; seq must be a multiple of
        the block sizes (defaults: min(128, seq)).
      causal: lower-triangular masking with masked-block skipping.

    Returns:
      ``(batch, heads, seq, head_dim)``, same dtype as ``q``.
    """
    o, _ = _fwd(q, k, v, causal=causal, block_q=block_q, block_k=block_k)
    return o


def _vjp_fwd(q, k, v, causal, block_q, block_k):
    o, lse = _fwd(q, k, v, causal=causal, block_q=block_q, block_k=block_k)
    return o, (q, k, v, o, lse)


def _vjp_bwd(causal, block_q, block_k, res, do):
    return _bwd(causal, block_q, block_k, res, do)


flash_attention.defvjp(_vjp_fwd, _vjp_bwd)
