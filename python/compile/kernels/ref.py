"""Pure-jnp oracles for the Pallas kernels.

Every kernel in this package has a reference implementation here, written in
the most obvious way possible. pytest/hypothesis compare kernel outputs (and
gradients, via ``jax.grad``) against these oracles with ``assert_allclose`` —
this is the core correctness signal for Layer 1.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

NEG_INF = -1e30  # large-negative instead of -inf: keeps exp() exactly zero
# without generating NaNs via (-inf) - (-inf) in fully-masked rows.


def attention(q: jax.Array, k: jax.Array, v: jax.Array, *, causal: bool = True) -> jax.Array:
    """Plain softmax attention.

    Args:
      q, k, v: ``(batch, heads, seq, head_dim)``.
      causal: apply a lower-triangular mask.

    Returns:
      ``(batch, heads, seq, head_dim)`` attention output.
    """
    *_, s, d = q.shape
    scale = 1.0 / jnp.sqrt(jnp.asarray(d, dtype=jnp.float32))
    logits = jnp.einsum("bhqd,bhkd->bhqk", q.astype(jnp.float32), k.astype(jnp.float32)) * scale
    if causal:
        mask = jnp.tril(jnp.ones((s, s), dtype=bool))
        logits = jnp.where(mask[None, None], logits, NEG_INF)
    p = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bhqk,bhkd->bhqd", p, v.astype(jnp.float32))
    return out.astype(q.dtype)


def softmax_xent(logits: jax.Array, targets: jax.Array) -> jax.Array:
    """Per-token softmax cross-entropy.

    Args:
      logits: ``(tokens, vocab)`` float.
      targets: ``(tokens,)`` int class ids.

    Returns:
      ``(tokens,)`` float32 loss per token.
    """
    logits = logits.astype(jnp.float32)
    lse = jax.nn.logsumexp(logits, axis=-1)
    picked = jnp.take_along_axis(logits, targets[:, None], axis=-1)[:, 0]
    return lse - picked


def layer_norm(x: jax.Array, gamma: jax.Array, beta: jax.Array, eps: float = 1e-5) -> jax.Array:
    """Row-wise layer normalization over the last axis."""
    xf = x.astype(jnp.float32)
    mean = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.mean(jnp.square(xf - mean), axis=-1, keepdims=True)
    y = (xf - mean) * jax.lax.rsqrt(var + eps)
    return (y * gamma + beta).astype(x.dtype)
