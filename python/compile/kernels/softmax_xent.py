"""Fused softmax cross-entropy as a Pallas kernel (forward + backward).

The LM-head loss is the second memory-bound hot spot of GPT training: the
``(tokens, vocab)`` logits tensor is huge and a naive softmax+gather makes
three passes over it. This kernel fuses max/exp/sum/gather into one pass per
token block, and the backward pass recomputes the softmax from the saved
logsumexp instead of materializing probabilities.

Grid: ``(tokens / block_t,)``; each program owns a ``(block_t, vocab)`` logits
tile in VMEM. With block_t=8 and vocab=32k (f32) that is 1 MiB — comfortably
inside VMEM. interpret=True for CPU-PJRT execution (see flash_attention.py).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

DEFAULT_BLOCK_T = 8


def _block_t(tokens: int, block_t: int | None) -> int:
    bt = min(block_t or DEFAULT_BLOCK_T, tokens)
    if tokens % bt:
        raise ValueError(f"tokens={tokens} must be a multiple of block_t={bt}")
    return bt


def _fwd_kernel(logits_ref, targets_ref, loss_ref, lse_ref):
    logits = logits_ref[...].astype(jnp.float32)  # (block_t, vocab)
    targets = targets_ref[...]  # (block_t,)
    vocab = logits.shape[-1]
    m = jnp.max(logits, axis=-1)
    lse = m + jnp.log(jnp.sum(jnp.exp(logits - m[:, None]), axis=-1))
    onehot = (jax.lax.iota(jnp.int32, vocab)[None, :] == targets[:, None]).astype(jnp.float32)
    picked = jnp.sum(logits * onehot, axis=-1)
    loss_ref[...] = lse - picked
    lse_ref[...] = lse


def _bwd_kernel(logits_ref, targets_ref, lse_ref, g_ref, dlogits_ref):
    logits = logits_ref[...].astype(jnp.float32)
    targets = targets_ref[...]
    lse = lse_ref[...]
    g = g_ref[...]
    vocab = logits.shape[-1]
    p = jnp.exp(logits - lse[:, None])
    onehot = (jax.lax.iota(jnp.int32, vocab)[None, :] == targets[:, None]).astype(jnp.float32)
    dlogits_ref[...] = ((p - onehot) * g[:, None]).astype(dlogits_ref.dtype)


def _fwd(logits, targets, block_t):
    t, v = logits.shape
    bt = _block_t(t, block_t)
    loss, lse = pl.pallas_call(
        _fwd_kernel,
        grid=(t // bt,),
        in_specs=[
            pl.BlockSpec((bt, v), lambda i: (i, 0)),
            pl.BlockSpec((bt,), lambda i: (i,)),
        ],
        out_specs=[
            pl.BlockSpec((bt,), lambda i: (i,)),
            pl.BlockSpec((bt,), lambda i: (i,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((t,), jnp.float32),
            jax.ShapeDtypeStruct((t,), jnp.float32),
        ],
        interpret=True,
    )(logits, targets)
    return loss, lse


@functools.partial(jax.custom_vjp, nondiff_argnums=(2,))
def softmax_xent(logits, targets, block_t: int | None = None):
    """Per-token cross-entropy ``(tokens, vocab) x (tokens,) -> (tokens,)``."""
    loss, _ = _fwd(logits, targets, block_t)
    return loss


def _vjp_fwd(logits, targets, block_t):
    loss, lse = _fwd(logits, targets, block_t)
    return loss, (logits, targets, lse)


def _vjp_bwd(block_t, res, g):
    logits, targets, lse = res
    t, v = logits.shape
    bt = _block_t(t, block_t)
    dlogits = pl.pallas_call(
        _bwd_kernel,
        grid=(t // bt,),
        in_specs=[
            pl.BlockSpec((bt, v), lambda i: (i, 0)),
            pl.BlockSpec((bt,), lambda i: (i,)),
            pl.BlockSpec((bt,), lambda i: (i,)),
            pl.BlockSpec((bt,), lambda i: (i,)),
        ],
        out_specs=pl.BlockSpec((bt, v), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((t, v), logits.dtype),
        interpret=True,
    )(logits, targets, lse, g)
    return dlogits, None


softmax_xent.defvjp(_vjp_fwd, _vjp_bwd)
