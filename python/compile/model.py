"""Layer 2: GPT (decoder-only transformer) forward/backward + AdamW in JAX.

This is the Megatron-equivalent compute graph the Unicron coordinator manages.
It is authored here, lowered once by ``aot.py`` to HLO text, and executed at
run time by the Rust trainer through PJRT — Python never touches the request
path.

The training step is split in two artifacts on purpose (see DESIGN.md §2):

  * ``micro_step(params, tokens) -> (loss, grads)`` — one micro-batch forward
    + backward. Gradient *accumulation* across micro-batches and the DP
    all-reduce happen in Rust, which is exactly what lets the coordinator
    redistribute a failed DP rank's micro-batches mid-iteration (paper §6.2,
    Eq. 7) with bit-exact optimizer semantics.
  * ``apply_update(params, m, v, grads, step, lr) -> (params, m, v)`` — AdamW,
    applied once per global batch after the all-reduce.

Parameters live in a *flat name->array dict*; JAX flattens dicts in sorted
key order, and names are zero-padded so that order is stable. ``aot.py``
writes the same order into the artifact manifest for the Rust side.

The attention and LM-head loss hot spots call the Pallas kernels from
``kernels/`` so they lower into the same HLO module.
"""

from __future__ import annotations

import dataclasses
import math
from typing import Dict, List, Tuple

import jax
import jax.numpy as jnp

from compile.kernels import ref
from compile.kernels.flash_attention import flash_attention
from compile.kernels.softmax_xent import softmax_xent

Params = Dict[str, jax.Array]


@dataclasses.dataclass(frozen=True)
class GptConfig:
    """Model + micro-batch shape; fully determines the lowered artifacts."""

    name: str
    vocab: int
    d_model: int
    n_layers: int
    n_heads: int
    seq_len: int
    micro_batch: int
    block_q: int = 128
    block_k: int = 128
    block_t: int = 8
    # AdamW hyper-parameters are baked into apply_update; lr and step are
    # runtime scalars so Rust owns the schedule.
    beta1: float = 0.9
    beta2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    use_pallas: bool = True

    @property
    def head_dim(self) -> int:
        assert self.d_model % self.n_heads == 0
        return self.d_model // self.n_heads

    def param_table(self) -> List[Tuple[str, Tuple[int, ...], str, bool]]:
        """(name, shape, init, weight_decay?) for every parameter.

        init is one of ``normal:<std>``, ``zeros``, ``ones`` — the Rust side
        materializes initial values from this table (no multi-hundred-MB
        params.bin artifact needed).
        """
        d, v, s = self.d_model, self.vocab, self.seq_len
        std = 0.02
        # residual-projection init scaled GPT-2 style
        pstd = 0.02 / math.sqrt(2.0 * self.n_layers)
        table: List[Tuple[str, Tuple[int, ...], str, bool]] = [
            ("tok_emb", (v, d), f"normal:{std}", False),
            ("pos_emb", (s, d), f"normal:{std}", False),
            ("lnf_g", (d,), "ones", False),
            ("lnf_b", (d,), "zeros", False),
        ]
        for i in range(self.n_layers):
            p = f"h{i:02d}_"
            table += [
                (p + "ln1_g", (d,), "ones", False),
                (p + "ln1_b", (d,), "zeros", False),
                (p + "qkv_w", (d, 3 * d), f"normal:{std}", True),
                (p + "qkv_b", (3 * d,), "zeros", False),
                (p + "proj_w", (d, d), f"normal:{pstd}", True),
                (p + "proj_b", (d,), "zeros", False),
                (p + "ln2_g", (d,), "ones", False),
                (p + "ln2_b", (d,), "zeros", False),
                (p + "fc_w", (d, 4 * d), f"normal:{std}", True),
                (p + "fc_b", (4 * d,), "zeros", False),
                (p + "out_w", (4 * d, d), f"normal:{pstd}", True),
                (p + "out_b", (d,), "zeros", False),
            ]
        return sorted(table)  # dict-flatten order

    def n_params(self) -> int:
        return sum(math.prod(shape) for _, shape, _, _ in self.param_table())

    def flops_per_token(self) -> float:
        """Approximate training FLOPs per token (fwd+bwd ≈ 6N + attention)."""
        n = self.n_params()
        attn = 12 * self.n_layers * self.d_model * self.seq_len  # qk^T + pv, fwd+bwd
        return 6.0 * n + attn


def init_params(cfg: GptConfig, key: jax.Array) -> Params:
    """Reference initializer (tests only; Rust has its own from the manifest)."""
    params: Params = {}
    for name, shape, init, _ in cfg.param_table():
        if init == "zeros":
            params[name] = jnp.zeros(shape, jnp.float32)
        elif init == "ones":
            params[name] = jnp.ones(shape, jnp.float32)
        else:
            std = float(init.split(":")[1])
            key, sub = jax.random.split(key)
            params[name] = jax.random.normal(sub, shape, jnp.float32) * std
    return params


def _layer_norm(x, g, b):
    return ref.layer_norm(x, g, b)


def _attention(cfg: GptConfig, x: jax.Array, p: Params, prefix: str) -> jax.Array:
    bsz, s, d = x.shape
    h, hd = cfg.n_heads, cfg.head_dim
    qkv = x @ p[prefix + "qkv_w"] + p[prefix + "qkv_b"]  # (b, s, 3d)
    q, k, v = jnp.split(qkv, 3, axis=-1)

    def heads(t):  # (b, s, d) -> (b, h, s, hd)
        return t.reshape(bsz, s, h, hd).transpose(0, 2, 1, 3)

    if cfg.use_pallas:
        o = flash_attention(heads(q), heads(k), heads(v), True, cfg.block_q, cfg.block_k)
    else:
        o = ref.attention(heads(q), heads(k), heads(v), causal=True)
    o = o.transpose(0, 2, 1, 3).reshape(bsz, s, d)
    return o @ p[prefix + "proj_w"] + p[prefix + "proj_b"]


def _mlp(x: jax.Array, p: Params, prefix: str) -> jax.Array:
    hmid = jax.nn.gelu(x @ p[prefix + "fc_w"] + p[prefix + "fc_b"])
    return hmid @ p[prefix + "out_w"] + p[prefix + "out_b"]


def forward_loss(cfg: GptConfig, params: Params, tokens: jax.Array) -> jax.Array:
    """Mean LM loss for a ``(micro_batch, seq_len+1)`` int32 token block."""
    inputs = tokens[:, :-1]
    targets = tokens[:, 1:]
    bsz, s = inputs.shape
    x = params["tok_emb"][inputs] + params["pos_emb"][None, :s]
    for i in range(cfg.n_layers):
        pfx = f"h{i:02d}_"
        x = x + _attention(cfg, _layer_norm(x, params[pfx + "ln1_g"], params[pfx + "ln1_b"]), params, pfx)
        x = x + _mlp(_layer_norm(x, params[pfx + "ln2_g"], params[pfx + "ln2_b"]), params, pfx)
    x = _layer_norm(x, params["lnf_g"], params["lnf_b"])
    logits = (x @ params["tok_emb"].T).reshape(bsz * s, cfg.vocab)
    if cfg.use_pallas:
        losses = softmax_xent(logits, targets.reshape(-1), cfg.block_t)
    else:
        losses = ref.softmax_xent(logits, targets.reshape(-1))
    return jnp.mean(losses)


def micro_step(cfg: GptConfig, params: Params, tokens: jax.Array):
    """One micro-batch: ``(loss, grads)``. Lowered to ``micro_step.hlo.txt``."""
    loss, grads = jax.value_and_grad(lambda p: forward_loss(cfg, p, tokens))(params)
    return loss, grads


def apply_update(cfg: GptConfig, params: Params, m: Params, v: Params, grads: Params,
                 step: jax.Array, lr: jax.Array):
    """AdamW with bias correction; decay mask from the param table.

    ``step`` is the 1-based update index as f32; ``lr`` the learning rate.
    Lowered to ``apply_update.hlo.txt``.
    """
    decay = {name: wd for name, _, _, wd in cfg.param_table()}
    b1, b2 = cfg.beta1, cfg.beta2
    bc1 = 1.0 - jnp.power(b1, step)
    bc2 = 1.0 - jnp.power(b2, step)
    new_p, new_m, new_v = {}, {}, {}
    for name in params:
        g = grads[name]
        m2 = b1 * m[name] + (1.0 - b1) * g
        v2 = b2 * v[name] + (1.0 - b2) * jnp.square(g)
        mhat = m2 / bc1
        vhat = v2 / bc2
        upd = mhat / (jnp.sqrt(vhat) + cfg.eps)
        if decay[name]:
            upd = upd + cfg.weight_decay * params[name]
        new_p[name] = params[name] - lr * upd
        new_m[name] = m2
        new_v[name] = v2
    return new_p, new_m, new_v


# ---------------------------------------------------------------------------
# Named configurations (the artifact set). ``tiny`` is the cargo-test model,
# ``mini`` the quickstart, ``gpt100m`` the end-to-end validation model
# (~110M params — GPT-2-small-shaped with a 32k vocab and short context).
# ---------------------------------------------------------------------------

CONFIGS: Dict[str, GptConfig] = {
    c.name: c
    for c in [
        GptConfig(name="tiny", vocab=256, d_model=64, n_layers=2, n_heads=2,
                  seq_len=32, micro_batch=4, block_q=32, block_k=32, block_t=8),
        GptConfig(name="mini", vocab=512, d_model=128, n_layers=4, n_heads=4,
                  seq_len=64, micro_batch=4, block_q=64, block_k=64, block_t=8),
        GptConfig(name="gpt100m", vocab=32768, d_model=768, n_layers=12, n_heads=12,
                  seq_len=128, micro_batch=1, block_q=128, block_k=128, block_t=8),
    ]
}
