"""Artifact/manifest consistency: what aot.py writes is what Rust will read."""

import json
import os
import re

import pytest

from compile import aot
from compile import model as M

ART = os.path.join(os.path.dirname(__file__), "..", "..", "artifacts")


def manifest_for(name):
    path = os.path.join(ART, name, "manifest.json")
    if not os.path.exists(path):
        pytest.skip(f"artifacts for {name!r} not built (run `make artifacts`)")
    with open(path) as f:
        return json.load(f)


@pytest.mark.parametrize("name", ["tiny", "mini", "gpt100m"])
def test_manifest_matches_config(name):
    cfg = M.CONFIGS[name]
    man = manifest_for(name)
    assert man["config"]["n_params"] == cfg.n_params()
    assert [p["name"] for p in man["params"]] == [n for n, *_ in cfg.param_table()]
    n = len(man["params"])
    ms = man["micro_step"]
    assert len(ms["inputs"]) == n + 1 and ms["inputs"][-1] == "tokens"
    assert len(ms["outputs"]) == n + 1 and ms["outputs"][0] == "loss"
    au = man["apply_update"]
    assert len(au["inputs"]) == 4 * n + 2
    assert len(au["outputs"]) == 3 * n
    assert ms["tokens_shape"] == [cfg.micro_batch, cfg.seq_len + 1]


@pytest.mark.parametrize("name", ["tiny", "mini"])
def test_hlo_entry_layout_matches_manifest(name):
    """The HLO entry computation must have exactly the parameter count and
    shapes the manifest promises, in manifest order."""
    cfg = M.CONFIGS[name]
    man = manifest_for(name)
    path = os.path.join(ART, name, "micro_step.hlo.txt")
    with open(path) as f:
        head = f.read(200_000)
    m = re.search(r"entry_computation_layout=\{\((.*?)\)->", head, re.S)
    assert m, "no entry_computation_layout in HLO text"
    args = re.findall(r"(f32|s32)\[([\d,]*)\]", m.group(1))
    assert len(args) == len(man["params"]) + 1
    for (dt, dims), spec in zip(args[:-1], man["params"]):
        assert dt == "f32"
        shape = [int(x) for x in dims.split(",")] if dims else []
        assert shape == spec["shape"], spec["name"]
    assert args[-1][0] == "s32"
    assert [int(x) for x in args[-1][1].split(",")] == [cfg.micro_batch, cfg.seq_len + 1]


def test_build_manifest_roundtrips_json():
    man = aot.build_manifest(M.CONFIGS["tiny"])
    assert json.loads(json.dumps(man)) == man


def test_flops_per_token_dominated_by_6n():
    cfg = M.CONFIGS["gpt100m"]
    assert cfg.flops_per_token() >= 6 * cfg.n_params()
    assert cfg.flops_per_token() < 8 * cfg.n_params()
