"""Layer-1 correctness: Pallas kernels vs pure-jnp oracles (``ref.py``).

Hypothesis sweeps shapes/dtypes; every comparison is an ``assert_allclose``
against the oracle, including gradients through the custom VJPs.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import ref
from compile.kernels.flash_attention import flash_attention
from compile.kernels.softmax_xent import softmax_xent

jax.config.update("jax_enable_x64", False)


def rand(key, shape, dtype=jnp.float32, scale=1.0):
    return (jax.random.normal(key, shape, jnp.float32) * scale).astype(dtype)


# ---------------------------------------------------------------------------
# flash attention — forward
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("causal", [True, False])
@pytest.mark.parametrize("b,h,s,d,bq,bk", [
    (1, 1, 32, 16, 32, 32),
    (2, 2, 64, 32, 32, 32),
    (1, 3, 128, 64, 64, 64),
    (2, 1, 128, 32, 128, 64),   # single q block, multiple k blocks
    (1, 2, 128, 64, 32, 128),   # multiple q blocks, single k block
])
def test_flash_fwd_matches_ref(causal, b, h, s, d, bq, bk):
    keys = jax.random.split(jax.random.PRNGKey(7), 3)
    q, k, v = (rand(kk, (b, h, s, d)) for kk in keys)
    out = flash_attention(q, k, v, causal, bq, bk)
    want = ref.attention(q, k, v, causal=causal)
    np.testing.assert_allclose(out, want, atol=2e-5, rtol=2e-5)


@settings(max_examples=20, deadline=None)
@given(
    b=st.integers(1, 2),
    h=st.integers(1, 3),
    s_blocks=st.integers(1, 4),
    block=st.sampled_from([16, 32, 64]),
    d=st.sampled_from([8, 16, 32, 64]),
    causal=st.booleans(),
    seed=st.integers(0, 2**31 - 1),
)
def test_flash_fwd_hypothesis(b, h, s_blocks, block, d, causal, seed):
    s = s_blocks * block
    keys = jax.random.split(jax.random.PRNGKey(seed), 3)
    q, k, v = (rand(kk, (b, h, s, d)) for kk in keys)
    out = flash_attention(q, k, v, causal, block, block)
    want = ref.attention(q, k, v, causal=causal)
    np.testing.assert_allclose(out, want, atol=3e-5, rtol=3e-5)


def test_flash_fwd_bf16():
    keys = jax.random.split(jax.random.PRNGKey(3), 3)
    q, k, v = (rand(kk, (2, 2, 64, 32), jnp.bfloat16) for kk in keys)
    out = flash_attention(q, k, v, True, 32, 32)
    want = ref.attention(q, k, v, causal=True)
    assert out.dtype == jnp.bfloat16
    np.testing.assert_allclose(out.astype(np.float32), want.astype(np.float32),
                               atol=2e-2, rtol=2e-2)


def test_flash_rejects_ragged_seq():
    q = jnp.zeros((1, 1, 48, 16))
    with pytest.raises(ValueError, match="multiple"):
        flash_attention(q, q, q, True, 32, 32)


# ---------------------------------------------------------------------------
# flash attention — backward (custom_vjp vs autodiff through the oracle)
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("causal", [True, False])
@pytest.mark.parametrize("b,h,s,d,bq,bk", [
    (1, 1, 32, 16, 32, 32),
    (2, 2, 64, 32, 32, 32),
    (1, 2, 128, 64, 64, 64),
    (1, 1, 128, 32, 32, 64),   # asymmetric blocks
])
def test_flash_bwd_matches_ref(causal, b, h, s, d, bq, bk):
    keys = jax.random.split(jax.random.PRNGKey(11), 4)
    q, k, v = (rand(kk, (b, h, s, d)) for kk in keys[:3])
    w = rand(keys[3], (b, h, s, d))  # random cotangent via weighted sum

    def scalar(fn):
        return lambda q, k, v: jnp.sum(fn(q, k, v) * w)

    got = jax.grad(scalar(lambda q, k, v: flash_attention(q, k, v, causal, bq, bk)),
                   argnums=(0, 1, 2))(q, k, v)
    want = jax.grad(scalar(lambda q, k, v: ref.attention(q, k, v, causal=causal)),
                    argnums=(0, 1, 2))(q, k, v)
    for g, wnt, name in zip(got, want, "qkv"):
        np.testing.assert_allclose(g, wnt, atol=5e-4, rtol=5e-4,
                                   err_msg=f"d{name} mismatch")


@settings(max_examples=10, deadline=None)
@given(
    s_blocks=st.integers(1, 3),
    block=st.sampled_from([16, 32]),
    d=st.sampled_from([8, 32]),
    causal=st.booleans(),
    seed=st.integers(0, 2**31 - 1),
)
def test_flash_bwd_hypothesis(s_blocks, block, d, causal, seed):
    s = s_blocks * block
    keys = jax.random.split(jax.random.PRNGKey(seed), 4)
    q, k, v = (rand(kk, (1, 2, s, d)) for kk in keys[:3])
    w = rand(keys[3], (1, 2, s, d))
    got = jax.grad(lambda q: jnp.sum(flash_attention(q, k, v, causal, block, block) * w))(q)
    want = jax.grad(lambda q: jnp.sum(ref.attention(q, k, v, causal=causal) * w))(q)
    np.testing.assert_allclose(got, want, atol=1e-3, rtol=1e-3)


# ---------------------------------------------------------------------------
# fused softmax cross-entropy
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("t,v,bt", [(8, 64, 8), (32, 512, 8), (16, 1000, 4), (64, 256, 16)])
def test_xent_fwd_matches_ref(t, v, bt):
    key = jax.random.PRNGKey(5)
    logits = rand(key, (t, v), scale=3.0)
    targets = jax.random.randint(key, (t,), 0, v)
    got = softmax_xent(logits, targets, bt)
    want = ref.softmax_xent(logits, targets)
    np.testing.assert_allclose(got, want, atol=2e-5, rtol=2e-5)


@settings(max_examples=20, deadline=None)
@given(
    t_blocks=st.integers(1, 4),
    bt=st.sampled_from([2, 4, 8]),
    v=st.sampled_from([17, 64, 257, 1024]),
    scale=st.sampled_from([0.1, 1.0, 10.0]),
    seed=st.integers(0, 2**31 - 1),
)
def test_xent_hypothesis(t_blocks, bt, v, scale, seed):
    t = t_blocks * bt
    key = jax.random.PRNGKey(seed)
    logits = rand(key, (t, v), scale=scale)
    targets = jax.random.randint(key, (t,), 0, v)
    got = softmax_xent(logits, targets, bt)
    want = ref.softmax_xent(logits, targets)
    np.testing.assert_allclose(got, want, atol=3e-5, rtol=3e-5)
    dg = jax.grad(lambda x: jnp.mean(softmax_xent(x, targets, bt)))(logits)
    dw = jax.grad(lambda x: jnp.mean(ref.softmax_xent(x, targets)))(logits)
    np.testing.assert_allclose(dg, dw, atol=3e-5, rtol=3e-5)


def test_xent_extreme_logits_stable():
    # Large-magnitude logits must not overflow (max-subtraction inside kernel).
    logits = jnp.array([[1e4, -1e4, 0.0, 5e3]] * 4, jnp.float32)
    targets = jnp.array([0, 1, 2, 3], jnp.int32)
    got = softmax_xent(logits, targets, 4)
    want = ref.softmax_xent(logits, targets)
    np.testing.assert_allclose(got, want, atol=1e-4, rtol=1e-4)
    assert np.isfinite(np.asarray(got)).all()


def test_xent_rejects_ragged_tokens():
    with pytest.raises(ValueError, match="multiple"):
        softmax_xent(jnp.zeros((10, 8)), jnp.zeros((10,), jnp.int32), 4)
