"""Layer-2 correctness: the GPT graph with Pallas kernels vs pure-jnp oracle.

``use_pallas=False`` swaps every kernel call for its ``ref.py`` oracle, so a
pallas-vs-ref comparison of the *whole model* (loss and all gradients)
exercises the kernels exactly as the lowered artifact uses them.
"""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import model as M

TINY = M.CONFIGS["tiny"]


@pytest.fixture(scope="module")
def tiny_setup():
    params = M.init_params(TINY, jax.random.PRNGKey(0))
    tokens = jax.random.randint(jax.random.PRNGKey(1), (TINY.micro_batch, TINY.seq_len + 1),
                                0, TINY.vocab)
    return params, tokens


def test_param_table_sorted_and_complete():
    table = TINY.param_table()
    names = [n for n, *_ in table]
    assert names == sorted(names)
    assert len(names) == len(set(names))
    # 4 globals + 12 tensors per layer
    assert len(names) == 4 + 12 * TINY.n_layers
    assert TINY.n_params() == sum(int(np.prod(s)) for _, s, _, _ in table)


def test_init_params_match_table(tiny_setup):
    params, _ = tiny_setup
    for name, shape, init, _ in TINY.param_table():
        assert params[name].shape == shape
        if init == "zeros":
            assert np.all(np.asarray(params[name]) == 0.0)
        elif init == "ones":
            assert np.all(np.asarray(params[name]) == 1.0)


def test_loss_is_near_uniform_at_init(tiny_setup):
    params, tokens = tiny_setup
    loss = M.forward_loss(TINY, params, tokens)
    # Random init => loss ~ log(vocab)
    assert abs(float(loss) - np.log(TINY.vocab)) < 0.5


def test_pallas_model_matches_ref_model(tiny_setup):
    params, tokens = tiny_setup
    ref_cfg = dataclasses.replace(TINY, use_pallas=False)
    loss_pallas, grads_pallas = M.micro_step(TINY, params, tokens)
    loss_ref, grads_ref = M.micro_step(ref_cfg, params, tokens)
    np.testing.assert_allclose(loss_pallas, loss_ref, atol=1e-5, rtol=1e-5)
    for name in grads_ref:
        np.testing.assert_allclose(grads_pallas[name], grads_ref[name],
                                   atol=2e-4, rtol=2e-4, err_msg=name)


def test_grad_accumulation_linearity(tiny_setup):
    """sum of per-micro-batch grads == grad of summed loss — the invariant the
    Rust-side accumulation (paper Eq. 6) relies on."""
    params, _ = tiny_setup
    key = jax.random.PRNGKey(2)
    t1 = jax.random.randint(key, (TINY.micro_batch, TINY.seq_len + 1), 0, TINY.vocab)
    t2 = jax.random.randint(jax.random.fold_in(key, 1),
                            (TINY.micro_batch, TINY.seq_len + 1), 0, TINY.vocab)
    _, g1 = M.micro_step(TINY, params, t1)
    _, g2 = M.micro_step(TINY, params, t2)
    combined = jax.grad(
        lambda p: 0.5 * (M.forward_loss(TINY, p, t1) + M.forward_loss(TINY, p, t2)))(params)
    for name in combined:
        np.testing.assert_allclose(0.5 * (g1[name] + g2[name]), combined[name],
                                   atol=2e-4, rtol=2e-4, err_msg=name)


def test_apply_update_moves_params(tiny_setup):
    params, tokens = tiny_setup
    _, grads = M.micro_step(TINY, params, tokens)
    zeros = {k: jnp.zeros_like(v) for k, v in params.items()}
    step = jnp.asarray(1.0)
    lr = jnp.asarray(1e-3)
    p2, m2, v2 = M.apply_update(TINY, params, zeros, zeros, grads, step, lr)
    # Adam step-1 with zero state: |delta| ≈ lr for every nonzero-grad param.
    delta = np.abs(np.asarray(p2["tok_emb"]) - np.asarray(params["tok_emb"]))
    assert delta.max() <= 1.5e-3
    assert delta.max() > 0.0
    # first-moment update m = (1-b1) * g
    np.testing.assert_allclose(m2["lnf_g"], (1 - TINY.beta1) * grads["lnf_g"],
                               atol=1e-7, rtol=1e-6)
    np.testing.assert_allclose(v2["lnf_g"], (1 - TINY.beta2) * np.square(grads["lnf_g"]),
                               atol=1e-9, rtol=1e-6)


def test_apply_update_weight_decay_mask(tiny_setup):
    params, _ = tiny_setup
    zeros = {k: jnp.zeros_like(v) for k, v in params.items()}
    # zero grads: only decayed tensors move.
    p2, _, _ = M.apply_update(TINY, params, zeros, zeros, zeros,
                              jnp.asarray(1.0), jnp.asarray(1e-3))
    decay = {name: wd for name, _, _, wd in TINY.param_table()}
    for name, moved in ((n, not np.allclose(p2[n], params[n])) for n in params):
        assert moved == (decay[name] and bool(np.any(np.asarray(params[name]) != 0))), name


def test_training_reduces_loss_on_fixed_batch(tiny_setup):
    """A few full AdamW steps on one batch must overfit it measurably."""
    params, tokens = tiny_setup
    m = {k: jnp.zeros_like(v) for k, v in params.items()}
    v = {k: jnp.zeros_like(v_) for k, v_ in params.items()}
    loss0 = None
    p = params
    for i in range(5):
        loss, grads = M.micro_step(TINY, p, tokens)
        loss0 = loss0 if loss0 is not None else float(loss)
        p, m, v = M.apply_update(TINY, p, m, v, grads, jnp.asarray(float(i + 1)),
                                 jnp.asarray(5e-3))
    loss_end, _ = M.micro_step(TINY, p, tokens)
    assert float(loss_end) < loss0 - 0.2
