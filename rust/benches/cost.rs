//! Cost-ledger performance (DESIGN.md §9): the breakdown-carrying
//! [`unicron::planner::solve`] must stay within 1.1× of a pre-ledger
//! scalar-reward reference DP (the typed ledger is bookkeeping, not a tax),
//! and raw [`unicron::planner::reward`] term evaluation must sustain
//! ≥ 1M terms/s (the DP inner loop runs it O(m·n²) times per solve).

use unicron::bench::Bencher;
use unicron::config::{table3_case, ClusterSpec, ModelSpec, UnicronConfig};
use unicron::cost::{CostModel, TransitionProfile};
use unicron::perfmodel::throughput_table;
use unicron::planner::{reward, solve, PlanTask};
use unicron::proto::WorkerCount;

/// The pre-ledger solver shape: bare-scalar `d_running`/`d_transition`, no
/// per-task profiles, no breakdown — the reference the ledger solve is held
/// to. Kept verbatim from the PR-3-era DP so the comparison is honest.
fn scalar_solve(tasks: &[PlanTask], n_workers: u32, d_running: f64, d_transition: f64) -> f64 {
    let n = n_workers as usize;
    let m = tasks.len();
    let mut s = vec![vec![0.0f64; n + 1]; m + 1];
    let mut choice = vec![vec![0u32; n + 1]; m + 1];
    for i in 1..=m {
        let t = &tasks[i - 1];
        for j in 0..=n {
            let mut best = f64::NEG_INFINITY;
            let mut best_k = 0;
            for k in 0..=j {
                let x = k as u32;
                let gain = t.waf(x) * d_running;
                let pen =
                    if t.transitions_to(x) { t.current_waf() * d_transition } else { 0.0 };
                let v = s[i - 1][j - k] + gain - pen;
                if v > best {
                    best = v;
                    best_k = x;
                }
            }
            s[i][j] = best;
            choice[i][j] = best_k;
        }
    }
    let mut j = n;
    for i in (1..=m).rev() {
        j -= choice[i][j] as usize;
    }
    s[m][n]
}

fn main() {
    let cluster = ClusterSpec::default();
    let cost = CostModel::from_config(&UnicronConfig::default());
    let n = cluster.total_gpus();
    let tasks: Vec<PlanTask> = table3_case(5)
        .into_iter()
        .map(|spec| {
            let model = ModelSpec::gpt3(&spec.model).unwrap();
            PlanTask {
                throughput: throughput_table(&model, &cluster, n),
                profile: TransitionProfile::from_model(&model, &cluster),
                spec,
                current: WorkerCount(16),
                fault: false,
                fault_source: unicron::transition::StateSource::InMemoryCheckpoint,
                fault_restore_s: None,
            }
        })
        .collect();

    let mut b = Bencher::new("cost").with_samples(3, 30);
    let ledger = b
        .bench("solve_with_breakdown_6tasks_128", || {
            std::hint::black_box(solve(&tasks, n, &cost).objective);
        })
        .expect("benchmark filtered out");
    let d_running = cost.horizon_s(n);
    let scalar = b
        .bench("solve_scalar_reference_6tasks_128", || {
            std::hint::black_box(scalar_solve(&tasks, n, d_running, 60.0));
        })
        .expect("benchmark filtered out");
    let ratio = ledger.median / scalar.median.max(1e-12);
    println!(
        "\nbreakdown-carrying solve: {:.3} ms vs scalar reference {:.3} ms ({ratio:.3}×)",
        ledger.median * 1e3,
        scalar.median * 1e3,
    );
    assert!(
        ratio <= 1.1,
        "the typed ledger must not tax the solver: {ratio:.3}× > 1.1× the scalar reference"
    );

    // raw term-evaluation throughput: the full reward path (horizon lookup,
    // per-task profile, fault-strategy selection) per call
    const TERMS: u32 = 1_000_000;
    let t0 = &tasks[0];
    let terms = b
        .bench("reward_1m_term_evaluations", || {
            let mut acc = 0.0f64;
            for i in 0..TERMS {
                acc += reward(t0, i % (n + 1), n, &cost);
            }
            std::hint::black_box(acc);
        })
        .expect("benchmark filtered out");
    let rate = TERMS as f64 / terms.median;
    println!("reward terms: {:.2}M evaluations/s", rate / 1e6);
    assert!(rate >= 1e6, "CostModel term evaluation must sustain ≥1M/s, got {rate:.0}/s");
}
