//! Table 2 — failure detection time, measured live over TCP.
//!
//! Starts a real coordinator (kvstore wire protocol + event loop) and a real
//! agent, injects each failure class, and measures injection→detection
//! latency at the coordinator. The heartbeat/lease interval is scaled down
//! (0.05 s/0.4 s vs the paper's seconds) so the bench finishes quickly; the
//! *w/o Unicron* column is the Megatron NCCL timeout (30 min), reported for
//! contrast as in the paper.

use std::sync::Arc;
use std::time::Duration;

use unicron::agent::{Agent, ProcessHandle};
use unicron::bench::Bencher;
use unicron::config::UnicronConfig;
use unicron::coordinator::live::CoordinatorLive;
use unicron::coordinator::Coordinator;
use unicron::failure::ErrorKind;
use unicron::proto::{CoordEvent, NodeId};
use unicron::metrics::Table;
use unicron::util::{Clock, RealClock};

fn cfg() -> UnicronConfig {
    UnicronConfig { heartbeat_period_s: 0.05, lease_ttl_s: 0.4, ..Default::default() }
}

/// One live detection round; returns injection→detection latency (seconds).
/// `inject` receives ownership of the agent and may consume it (crash) or
/// hand it back to keep it alive until detection completes.
fn measure<Inject, Match>(node: u32, inject: Inject, matches: Match) -> f64
where
    Inject: FnOnce(&ProcessHandle, Agent, &Arc<dyn Clock>) -> Option<Agent>,
    Match: Fn(&CoordEvent) -> bool + Copy,
{
    let cfg = cfg();
    let clock: Arc<dyn Clock> = Arc::new(RealClock::new());
    let coord = Coordinator::builder()
        .config(cfg.clone())
        .workers(16u32)
        .gpus_per_node(8u32)
        .build();
    let live = CoordinatorLive::start(coord, clock.clone(), "127.0.0.1:0").unwrap();
    let proc0 = ProcessHandle::new(0u32);
    let agent = Agent::start(node, 8, live.addr, &cfg, vec![proc0.clone()], clock.clone()).unwrap();
    // let registration settle
    live.wait_for(
        |d| matches!(d.event, CoordEvent::NodeJoined { node: n } if n == NodeId(node)),
        Duration::from_secs(5),
    )
    .expect("agent must join");

    let t0 = clock.now();
    let keep = inject(&proc0, agent, &clock);
    let det = live
        .wait_for(|d| matches(&d.event), Duration::from_secs(20))
        .expect("failure must be detected");
    let latency = det.at_s - t0;
    drop(keep);
    latency.max(0.0)
}

fn main() {
    let mut b = Bencher::new("table2_detection").with_samples(0, 5);

    // case 1: node killed (agent crash, lease expiry)
    let case1 = (0..b.sample_iters)
        .map(|i| {
            measure(
                10 + i as u32,
                |_p, agent, _c| {
                    agent.crash(); // abandon the lease: SEV1 path
                    None
                },
                |e| matches!(e, CoordEvent::NodeLost { .. }),
            )
        })
        .collect::<Vec<_>>();

    // case 2: process killed
    let case2 = (0..b.sample_iters)
        .map(|i| {
            measure(
                40 + i as u32,
                |p, agent, _c| {
                    p.kill();
                    Some(agent)
                },
                |e| {
                    matches!(e, CoordEvent::ErrorReport { kind: ErrorKind::ExitedAbnormally, .. })
                },
            )
        })
        .collect::<Vec<_>>();

    // case 3: exception thrown
    let case3 = (0..b.sample_iters)
        .map(|i| {
            measure(
                70 + i as u32,
                |p, agent, _c| {
                    p.throw("CUDA error: device-side assert triggered");
                    Some(agent)
                },
                |e| matches!(e, CoordEvent::ErrorReport { kind: ErrorKind::CudaError, .. }),
            )
        })
        .collect::<Vec<_>>();

    // case 4: performance degradation (stall; 3×D_iter with D_iter ≈ 40 ms)
    let d_iter = 0.04;
    let case4 = (0..b.sample_iters)
        .map(|i| {
            measure(
                100 + i as u32,
                |p, agent, c| {
                    for _ in 0..6 {
                        p.begin_iteration(c.now());
                        std::thread::sleep(Duration::from_secs_f64(d_iter));
                        p.end_iteration(c.now());
                    }
                    p.begin_iteration(c.now()); // hang
                    Some(agent)
                },
                |e| matches!(e, CoordEvent::ErrorReport { kind: ErrorKind::TaskHang, .. }),
            )
        })
        .collect::<Vec<_>>();

    let s1 = b.record("case1_node_health", case1).unwrap();
    let s2 = b.record("case2_process_supervision", case2).unwrap();
    let s3 = b.record("case3_exception_propagation", case3).unwrap();
    let s4 = b.record("case4_statistical_monitoring", case4).unwrap();

    let mut t = Table::new(&["case", "method", "Unicron (median, scaled)", "expected", "w/o Unicron"]);
    t.row(&["1".into(), "Node health monitoring".into(), format!("{:.0} ms", s1.median * 1e3),
            "~lease TTL (0.4s here; 5.6s at paper scale)".into(), "5.7 s".into()]);
    t.row(&["2".into(), "Process supervision".into(), format!("{:.0} ms", s2.median * 1e3),
            "poll interval (5ms here; 1.8s at paper scale)".into(), "D_timeout = 30 m".into()]);
    t.row(&["3".into(), "Exception propagation".into(), format!("{:.0} ms", s3.median * 1e3),
            "immediate (0.3s at paper scale)".into(), "D_timeout = 30 m".into()]);
    t.row(&["4".into(), "Online statistical monitoring".into(), format!("{:.0} ms", s4.median * 1e3),
            format!("3×D_iter = {:.0} ms", 3.0 * d_iter * 1e3), "D_timeout = 30 m".into()]);
    println!("\nTable 2 — live detection latency over TCP (scaled intervals)\n{}", t.render());

    // sanity: the statistical monitor should fire at about 3×D_iter
    assert!(s4.median >= 2.0 * d_iter && s4.median < 20.0 * d_iter,
            "stall detection {:.3}s vs 3×D_iter {:.3}s", s4.median, 3.0 * d_iter);
}
