//! Table 2 — failure detection time, measured live over TCP — plus the
//! in-band health-observation floors (PR 10), recorded as the
//! `BENCH_PR10.json` perf-trajectory artifact (override with `BENCH_JSON`):
//!
//! * streaming-stat updates ≥ 1M/s — `HealthMonitor::observe_step` is an
//!   O(1) EWMA/abs-dev blend per sample, no window, no allocation;
//! * the detection-on decide path ≤ 1.05× detection-off over the same
//!   step-timing + SEV1/rejoin event sequence — in-band observation rides
//!   the decide path, so it must be near-free there.
//!
//! The live half starts a real coordinator (kvstore wire protocol + event
//! loop) and a real agent, injects each failure class, and measures
//! injection→detection latency at the coordinator. The heartbeat/lease
//! interval is scaled down (0.05 s/0.4 s vs the paper's seconds) so the
//! bench finishes quickly; the *w/o Unicron* column is the Megatron NCCL
//! timeout (30 min), reported for contrast as in the paper. CI runs with
//! `BENCH_FILTER=health`, which skips the live-TCP section entirely.

use std::sync::Arc;
use std::time::Duration;

use unicron::agent::{Agent, ProcessHandle};
use unicron::bench::{Bencher, Trajectory};
use unicron::config::{TaskSpec, UnicronConfig};
use unicron::coordinator::live::CoordinatorLive;
use unicron::coordinator::Coordinator;
use unicron::cost::TransitionProfile;
use unicron::failure::ErrorKind;
use unicron::health::HealthMonitor;
use unicron::metrics::Table;
use unicron::planner::PlanTask;
use unicron::proto::{CoordEvent, NodeId, TaskId, WorkerCount};
use unicron::util::{Clock, RealClock};

fn cfg() -> UnicronConfig {
    UnicronConfig { heartbeat_period_s: 0.05, lease_ttl_s: 0.4, ..Default::default() }
}

fn capped_task(id: u32, min: u32, cap: u32) -> PlanTask {
    let throughput = (0..=2 * cap)
        .map(|x| if x >= min { 1e12 * (x as f64).powf(0.9) } else { 0.0 })
        .collect();
    PlanTask {
        spec: TaskSpec::new(id, "synthetic", 1.0, min).with_max_workers(cap),
        throughput,
        profile: TransitionProfile::flat(5.0),
        current: WorkerCount(0),
        fault: false,
        fault_source: unicron::transition::StateSource::InMemoryCheckpoint,
        fault_restore_s: None,
    }
}

/// Floor 1: ≥ 1M streaming-stat updates/s through the public
/// `HealthMonitor::observe_step` path — the rate every in-band step report
/// pays on the decide path.
fn bench_streaming_stats(traj: &mut Trajectory) {
    const UPDATES: u64 = 100_000;
    const FLOOR_NS: f64 = 1_000.0; // 1 µs/update = 1M updates/s

    let mut monitor = HealthMonitor::from_config(&UnicronConfig::default());
    let mut b = Bencher::new("health").with_samples(3, 20);
    let stats = b.bench("streaming_stat_updates_100k", || {
        for i in 0..UPDATES {
            // sub-warn jitter (≤0.6%): pure baseline maintenance across a
            // 64-node stream, no verdicts ever fire
            let d = 45.0 * (1.0 + 0.001 * (i % 7) as f64);
            let verdict = monitor.observe_step(NodeId((i % 64) as u32), d);
            assert!(verdict.is_none(), "jitter inside the warn band must stay silent");
        }
    });
    if let Some(st) = stats {
        traj.gate("streaming_stat_update", st.median * 1e9 / UPDATES as f64, FLOOR_NS);
    }
}

fn decide_coordinator(detection: bool) -> Coordinator {
    let cfg = UnicronConfig {
        domain_batch_window_s: 0.0, // measure every event's full cycle
        // the same nodes are lost and rejoined for thousands of iterations;
        // quarantining them would degrade later events into no-op decides
        lemon_quarantine: false,
        degradation_detection: detection,
        ..Default::default()
    };
    let mut c = Coordinator::builder()
        .config(cfg)
        .workers(256)
        .gpus_per_node(8u32)
        .task(capped_task(0, 8, 64))
        .task(capped_task(1, 8, 64))
        .telemetry(false)
        .build();
    c.handle_at(CoordEvent::TaskLaunched { task: TaskId(0) }, 0.0);
    c
}

/// Floor 2: the detection-on decide path stays within 5% of detection-off.
/// Both coordinators replay the same step-timing + lose/rejoin cycle; the
/// step durations carry only sub-warn jitter, so detection never fires and
/// both arms make identical decisions — the ratio of medians measures pure
/// observation overhead (scaled ×1000: 1050 = 1.05×).
fn bench_detection_overhead(traj: &mut Trajectory) {
    const EVENTS_PER_SAMPLE: usize = 32;
    const FLOOR_RATIO_X1000: f64 = 1_050.0; // 1.05× the detection-off path

    let run_cycle = |detection: bool| {
        let mut c = decide_coordinator(detection);
        let mut b = Bencher::new("health").with_samples(3, 20);
        let name =
            if detection { "decide_cycle_detection_on" } else { "decide_cycle_detection_off" };
        let mut t = 100.0;
        let stats = b.bench(name, || {
            for i in 0..EVENTS_PER_SAMPLE as u32 {
                let node = NodeId(i % 8);
                t += 10.0;
                let d = 45.0 * (1.0 + 0.001 * (i % 7) as f64);
                c.handle_at(
                    CoordEvent::StepTiming { node, task: TaskId(0), duration_s: d },
                    t,
                );
                t += 10.0;
                let lost = c.handle_at(CoordEvent::NodeLost { node }, t);
                assert!(!lost.is_empty(), "a SEV1 must produce actions");
                t += 10.0;
                c.handle_at(CoordEvent::NodeJoined { node }, t);
            }
        });
        stats.map(|st| st.median)
    };

    let on = run_cycle(true);
    let off = run_cycle(false);
    if let (Some(on), Some(off)) = (on, off) {
        traj.gate("detection_overhead_ratio_x1000", on / off * 1_000.0, FLOOR_RATIO_X1000);
    }
}

/// One live detection round; returns injection→detection latency (seconds).
/// `inject` receives ownership of the agent and may consume it (crash) or
/// hand it back to keep it alive until detection completes.
fn measure<Inject, Match>(node: u32, inject: Inject, matches: Match) -> f64
where
    Inject: FnOnce(&ProcessHandle, Agent, &Arc<dyn Clock>) -> Option<Agent>,
    Match: Fn(&CoordEvent) -> bool + Copy,
{
    let cfg = cfg();
    let clock: Arc<dyn Clock> = Arc::new(RealClock::new());
    let coord = Coordinator::builder()
        .config(cfg.clone())
        .workers(16u32)
        .gpus_per_node(8u32)
        .build();
    let live = CoordinatorLive::start(coord, clock.clone(), "127.0.0.1:0").unwrap();
    let proc0 = ProcessHandle::new(0u32);
    let agent = Agent::start(node, 8, live.addr, &cfg, vec![proc0.clone()], clock.clone()).unwrap();
    // let registration settle
    live.wait_for(
        |d| matches!(d.event, CoordEvent::NodeJoined { node: n } if n == NodeId(node)),
        Duration::from_secs(5),
    )
    .expect("agent must join");

    let t0 = clock.now();
    let keep = inject(&proc0, agent, &clock);
    let det = live
        .wait_for(|d| matches(&d.event), Duration::from_secs(20))
        .expect("failure must be detected");
    let latency = det.at_s - t0;
    drop(keep);
    latency.max(0.0)
}

fn main() {
    // in-band health floors — cheap, pure in-process, gate the trajectory
    let mut traj = Trajectory::new();
    bench_streaming_stats(&mut traj);
    bench_detection_overhead(&mut traj);
    traj.finish("BENCH_PR10.json");

    // The live-TCP Table-2 section spins up real coordinators and agents per
    // sample; Bencher's filter only skips record(), so gate the expensive
    // sample collection explicitly (CI sets BENCH_FILTER=health).
    let filter = std::env::var("BENCH_FILTER").ok();
    if !filter.as_deref().map_or(true, |f| "table2_detection".contains(f)) {
        return;
    }

    let mut b = Bencher::new("table2_detection").with_samples(0, 5);

    // case 1: node killed (agent crash, lease expiry)
    let case1 = (0..b.sample_iters)
        .map(|i| {
            measure(
                10 + i as u32,
                |_p, agent, _c| {
                    agent.crash(); // abandon the lease: SEV1 path
                    None
                },
                |e| matches!(e, CoordEvent::NodeLost { .. }),
            )
        })
        .collect::<Vec<_>>();

    // case 2: process killed
    let case2 = (0..b.sample_iters)
        .map(|i| {
            measure(
                40 + i as u32,
                |p, agent, _c| {
                    p.kill();
                    Some(agent)
                },
                |e| {
                    matches!(e, CoordEvent::ErrorReport { kind: ErrorKind::ExitedAbnormally, .. })
                },
            )
        })
        .collect::<Vec<_>>();

    // case 3: exception thrown
    let case3 = (0..b.sample_iters)
        .map(|i| {
            measure(
                70 + i as u32,
                |p, agent, _c| {
                    p.throw("CUDA error: device-side assert triggered");
                    Some(agent)
                },
                |e| matches!(e, CoordEvent::ErrorReport { kind: ErrorKind::CudaError, .. }),
            )
        })
        .collect::<Vec<_>>();

    // case 4: performance degradation (stall; 3×D_iter with D_iter ≈ 40 ms)
    let d_iter = 0.04;
    let case4 = (0..b.sample_iters)
        .map(|i| {
            measure(
                100 + i as u32,
                |p, agent, c| {
                    for _ in 0..6 {
                        p.begin_iteration(c.now());
                        std::thread::sleep(Duration::from_secs_f64(d_iter));
                        p.end_iteration(c.now());
                    }
                    p.begin_iteration(c.now()); // hang
                    Some(agent)
                },
                |e| matches!(e, CoordEvent::ErrorReport { kind: ErrorKind::TaskHang, .. }),
            )
        })
        .collect::<Vec<_>>();

    let s1 = b.record("case1_node_health", case1).unwrap();
    let s2 = b.record("case2_process_supervision", case2).unwrap();
    let s3 = b.record("case3_exception_propagation", case3).unwrap();
    let s4 = b.record("case4_statistical_monitoring", case4).unwrap();

    let mut t = Table::new(&["case", "method", "Unicron (median, scaled)", "expected", "w/o Unicron"]);
    t.row(&["1".into(), "Node health monitoring".into(), format!("{:.0} ms", s1.median * 1e3),
            "~lease TTL (0.4s here; 5.6s at paper scale)".into(), "5.7 s".into()]);
    t.row(&["2".into(), "Process supervision".into(), format!("{:.0} ms", s2.median * 1e3),
            "poll interval (5ms here; 1.8s at paper scale)".into(), "D_timeout = 30 m".into()]);
    t.row(&["3".into(), "Exception propagation".into(), format!("{:.0} ms", s3.median * 1e3),
            "immediate (0.3s at paper scale)".into(), "D_timeout = 30 m".into()]);
    t.row(&["4".into(), "Online statistical monitoring".into(), format!("{:.0} ms", s4.median * 1e3),
            format!("3×D_iter = {:.0} ms", 3.0 * d_iter * 1e3), "D_timeout = 30 m".into()]);
    println!("\nTable 2 — live detection latency over TCP (scaled intervals)\n{}", t.render());

    // sanity: the statistical monitor should fire at about 3×D_iter
    assert!(s4.median >= 2.0 * d_iter && s4.median < 20.0 * d_iter,
            "stall detection {:.3}s vs 3×D_iter {:.3}s", s4.median, 3.0 * d_iter);
}
