//! Fleet hot-path cost: the coordinator ticks the fleet model and may score
//! a failure on *every* event, so lemon-score and spare-decision updates
//! must stay O(1) per event — pinned here at ≥1M updates/s each.

use unicron::bench::Bencher;
use unicron::config::UnicronConfig;
use unicron::failure::Severity;
use unicron::fleet::{FleetModel, SparePool};
use unicron::proto::NodeId;

const N: u32 = 100_000;

fn main() {
    let cfg = UnicronConfig::default();
    let mut b = Bencher::new("fleet").with_samples(3, 20);

    // lemon-score updates: tick + note_failure across a 128-node fleet
    let mut fleet = FleetModel::from_config(&cfg);
    let lemon = b.bench("lemon_score_100k_updates", || {
        for i in 0..N {
            fleet.tick();
            fleet.note_failure(NodeId(i % 128), Severity::Sev2);
        }
        std::hint::black_box(fleet.lemon_score(NodeId(3)));
    });

    // spare decisions: the full value-vs-cost arithmetic per call
    let pool = SparePool::from_config(&cfg);
    let spares = b.bench("spare_decision_100k", || {
        let mut retained = 0u32;
        for i in 0..N {
            let lambda = pool.expected_failures(128, cfg.mtbf_per_gpu_s);
            let node_waf = 1e15 + (i % 7) as f64;
            if pool.decide(i % 3, lambda, node_waf) == unicron::fleet::SpareDecision::Retain {
                retained += 1;
            }
        }
        std::hint::black_box(retained);
    });

    for (name, st) in [("lemon-score", lemon), ("spare-decision", spares)] {
        let st = st.expect("benchmark filtered out");
        let rate = N as f64 / st.median;
        println!("{name}: {:.2}M updates/s", rate / 1e6);
        assert!(
            rate >= 1e6,
            "{name} updates must stay O(1) per event (≥1M/s), got {rate:.0}/s"
        );
    }
}
