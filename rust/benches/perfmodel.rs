//! Fig. 4 — the configuration search itself: cost of `best_config` across
//! the model zoo and the full `throughput_table` calibration pass the
//! coordinator runs per task, plus the Fig. 4 table output.

use unicron::bench::Bencher;
use unicron::config::{ClusterSpec, ModelSpec};
use unicron::perfmodel::{best_config, throughput_table};

fn main() {
    let cluster = ClusterSpec::default();
    let mut b = Bencher::new("perfmodel").with_samples(3, 20);

    for name in ModelSpec::zoo() {
        let model = ModelSpec::gpt3(name).unwrap();
        b.bench(&format!("best_config_{name}_128"), || {
            std::hint::black_box(best_config(&model, &cluster, 128));
        });
    }

    let m7 = ModelSpec::gpt3("gpt3-7b").unwrap();
    b.bench("throughput_table_7b_0..128", || {
        let t = throughput_table(&m7, &cluster, 128);
        assert_eq!(t.len(), 129);
    });

    // print the Fig. 4 rows (the repro harness shares this path)
    println!();
    println!("{}", unicron::repro::run("fig4", 42).unwrap());
}
