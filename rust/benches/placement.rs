//! Placement performance (DESIGN.md §10): a SEV1 replan commits a
//! precomputed plan in O(1) table time, so the layout step riding the same
//! commit must stay off the critical path too — a 512-node / 8-task
//! assignment in under 1 ms (both the min-churn replan and the fill-heavy
//! cold start), and the keep-or-move domain scoring the fill phase runs on
//! must sustain ≥ 1M evaluations/s.

use std::collections::BTreeSet;

use unicron::bench::Bencher;
use unicron::placement::{assign, keep_or_move_score, ClusterView, Layout};
use unicron::proto::{NodeId, TaskId};

const N_NODES: u32 = 512;
const GPN: u32 = 8;
const NPD: u32 = 8; // 64 racks
const N_TASKS: u32 = 8;

fn main() {
    let all: Vec<NodeId> = (0..N_NODES).map(NodeId).collect();
    let view = ClusterView { nodes: &all, gpus_per_node: GPN, nodes_per_domain: NPD };
    // every task wants 1/8th of the cluster
    let demands: Vec<(TaskId, u32)> =
        (0..N_TASKS).map(|t| (TaskId(t), N_NODES / N_TASKS * GPN)).collect();
    let prev = assign(&Layout::default(), &demands, &view);
    assert_eq!(prev.placed_nodes().count(), N_NODES as usize, "fresh assign fills the cluster");

    let mut b = Bencher::new("placement").with_samples(5, 50);

    // the fill-heavy worst case: an empty previous layout, every node
    // placed through the domain-scored fill phase. Cold starts happen at
    // bootstrap, not on the SEV1 path, so the bound is looser than the
    // replan's — but still bounded, so a regression to per-node rescans
    // (O(free²)) fails the build.
    let stats = b
        .bench("assign_512nodes_8tasks_cold_start", || {
            let layout = assign(&Layout::default(), &demands, &view);
            std::hint::black_box(layout.len());
        })
        .expect("benchmark filtered out");
    println!("\n512-node / 8-task cold-start assignment: {:.3} ms", stats.median * 1e3);
    assert!(
        stats.median < 5e-3,
        "a full fill must stay cheap (O(#domains) per pick): {:.3} ms > 5 ms",
        stats.median * 1e3
    );

    // the replan scenario: one node per rack in the first 8 racks died —
    // keeps absorb most demand and the fill tops up the shortfall
    let healthy: Vec<NodeId> =
        all.iter().copied().filter(|n| !(n.0 < 8 * NPD && n.0 % NPD == 0)).collect();
    let view_after = ClusterView { nodes: &healthy, gpus_per_node: GPN, nodes_per_domain: NPD };
    let shrunk: Vec<(TaskId, u32)> = demands.iter().map(|&(t, w)| (t, w - GPN)).collect();
    let stats = b
        .bench("assign_512nodes_8tasks_minchurn_replan", || {
            let layout = assign(&prev, &shrunk, &view_after);
            std::hint::black_box(layout.len());
        })
        .expect("benchmark filtered out");
    println!("512-node / 8-task min-churn replan: {:.3} ms", stats.median * 1e3);
    assert!(
        stats.median < 1e-3,
        "placement must stay off the SEV1 hot path: {:.3} ms > 1 ms",
        stats.median * 1e3
    );
    // sanity: the solver actually kept the survivors in place
    let layout = assign(&prev, &shrunk, &view_after);
    let kept: usize = layout.diff(&prev).iter().map(|m| m.kept.len()).sum();
    assert!(kept >= (N_NODES - 8 * NPD) as usize / 2, "min-churn must keep survivors: {kept}");

    // keep-or-move scoring throughput: the fill phase's per-domain
    // evaluation (two small-map lookups + a set min)
    let domains: Vec<(u32, BTreeSet<NodeId>)> = (0..(N_NODES / NPD))
        .map(|d| {
            let nodes: BTreeSet<NodeId> =
                (0..(1 + d % NPD)).map(|k| NodeId(d * NPD + k)).collect();
            (d % 3, nodes)
        })
        .collect();
    const EVALS: u32 = 1_000_000;
    let n_domains = domains.len() as u32;
    let stats = b
        .bench("keep_or_move_score_1m_evals", || {
            let mut acc = 0u64;
            for i in 0..EVALS {
                let (mine, free_set) = &domains[(i % n_domains) as usize];
                let (m, f, tie) = keep_or_move_score(*mine, free_set);
                acc = acc.wrapping_add(m as u64 + f as u64 + tie.0 .0 as u64);
            }
            std::hint::black_box(acc);
        })
        .expect("benchmark filtered out");
    let rate = EVALS as f64 / stats.median;
    println!("keep-or-move scoring: {:.2}M evaluations/s", rate / 1e6);
    assert!(rate >= 1e6, "scoring must sustain ≥1M evals/s, got {rate:.0}/s");
}
