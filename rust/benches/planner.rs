//! Planner performance (§5.2): the O(m·n²) DP solve at paper scale
//! (6 tasks × 128 workers), the full lookup-table precompute, and the O(1)
//! dispatch the paper claims once the table exists.

use unicron::bench::Bencher;
use unicron::config::{table3_case, ClusterSpec, ModelSpec, UnicronConfig};
use unicron::cost::{CostModel, TransitionProfile};
use unicron::perfmodel::throughput_table;
use unicron::planner::{solve, PlanLookup, PlanTask, ScenarioLookup};
use unicron::proto::WorkerCount;

fn tasks(case: u32, n: u32) -> Vec<PlanTask> {
    let cluster = ClusterSpec::default();
    table3_case(case)
        .into_iter()
        .map(|spec| {
            let model = ModelSpec::gpt3(&spec.model).unwrap();
            PlanTask {
                throughput: throughput_table(&model, &cluster, n),
                profile: TransitionProfile::from_model(&model, &cluster),
                spec,
                current: WorkerCount(8),
                fault: false,
                fault_source: unicron::transition::StateSource::InMemoryCheckpoint,
                fault_restore_s: None,
            }
        })
        .collect()
}

fn main() {
    let cost = CostModel::from_config(&UnicronConfig::default());
    let mut b = Bencher::new("planner").with_samples(3, 30);

    let ts = tasks(5, 128);
    b.bench("solve_6tasks_128workers", || {
        let plan = solve(&ts, 128, &cost);
        assert!(plan.workers_used <= 128);
    });

    // larger synthetic instances: m=16 tasks, n=512 workers
    let big: Vec<PlanTask> = (0..16u32)
        .map(|i| {
            let throughput = (0..=512u32).map(|x| 1e12 * (x as f64).powf(0.85)).collect();
            PlanTask {
                spec: unicron::config::TaskSpec::new(i, "synthetic", 1.0, 1),
                throughput,
                profile: TransitionProfile::flat(60.0),
                current: WorkerCount(32),
                fault: false,
                fault_source: unicron::transition::StateSource::InMemoryCheckpoint,
                fault_restore_s: None,
            }
        })
        .collect();
    b.bench("solve_16tasks_512workers", || {
        let plan = solve(&big, 512, &cost);
        assert!(plan.workers_used <= 512);
    });

    let mut lut = None;
    b.bench("lookup_precompute_128", || {
        lut = Some(PlanLookup::precompute(&ts, 128, &cost));
    });
    let lut = lut.unwrap();
    let mut b2 = Bencher::new("planner").with_samples(3, 50);
    b2.bench("lookup_dispatch_o1", || {
        // the O(1) failure-time path: 1000 retrievals
        let mut total = 0u32;
        for n in 0..1000u32 {
            total = total.wrapping_add(lut.plan_for(n % 129).workers_used);
        }
        std::hint::black_box(total);
    });

    // paper claim check: dispatch is orders of magnitude below a solve
    let solve_t = b.results.iter().find(|(n, _)| n == "solve_6tasks_128workers").unwrap().1.median;
    let disp_t = b2.results[0].1.median / 1000.0;
    println!(
        "\nO(1) dispatch: {:.2} µs/plan vs {:.2} ms/solve ({}× faster)",
        disp_t * 1e6,
        solve_t * 1e3,
        (solve_t / disp_t) as u64
    );
    assert!(disp_t * 50.0 < solve_t, "lookup should be far cheaper than solving");

    // SEV1 replan hot path (§5.2, coordinator-shaped): 4 tasks / 64 workers.
    // "solve" is what a cold coordinator does per SEV1 (fault-flag + DP);
    // "lookup" is the warm path — fault-aware table retrieval + plan commit
    // clone. Acceptance: lookup ≥ 5× faster.
    let cluster = ClusterSpec::default();
    let tasks4: Vec<PlanTask> = table3_case(4)
        .into_iter()
        .take(4)
        .map(|spec| {
            let model = ModelSpec::gpt3(&spec.model).unwrap();
            PlanTask {
                throughput: throughput_table(&model, &cluster, 64),
                profile: TransitionProfile::from_model(&model, &cluster),
                spec,
                current: WorkerCount(16),
                fault: false,
                fault_source: unicron::transition::StateSource::InMemoryCheckpoint,
                fault_restore_s: None,
            }
        })
        .collect();
    let mut faulted = tasks4.clone();
    faulted[1].fault = true;

    let mut b3 = Bencher::new("planner").with_samples(3, 30);
    b3.bench("sev1_replan_via_solve_4tasks_64workers", || {
        // node lost: 64 -> 56 workers, task 1 faulted
        let plan = solve(&faulted, 56, &cost);
        std::hint::black_box(plan.workers_used);
    });
    let replan_table = ScenarioLookup::precompute(&tasks4, 64, &cost);
    b3.bench("sev1_replan_via_lookup_4tasks_64workers", || {
        let plan = replan_table.plan_for(Some(1), 56).clone();
        std::hint::black_box(plan.workers_used);
    });
    let replan_solve =
        b3.results.iter().find(|(n, _)| n.contains("via_solve")).unwrap().1.median;
    let replan_lookup =
        b3.results.iter().find(|(n, _)| n.contains("via_lookup")).unwrap().1.median;
    let speedup = replan_solve / replan_lookup.max(1e-12);
    println!(
        "SEV1 replan (4 tasks, 64 workers): {:.2} µs via lookup vs {:.2} µs via solve \
         ({speedup:.0}× faster)",
        replan_lookup * 1e6,
        replan_solve * 1e6,
    );
    assert!(
        speedup >= 5.0,
        "precomputed SEV1 replan must be ≥5× faster than per-event solve, got {speedup:.1}×"
    );
}
