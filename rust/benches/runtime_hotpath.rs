//! L3 hot-path microbenchmarks (the §Perf targets of DESIGN.md §7):
//! PJRT micro-step / optimizer dispatch, host-side gradient all-reduce
//! bandwidth, checkpoint encode/decode throughput, kvstore op rate, and
//! simulator event rate.

use std::path::PathBuf;
use std::sync::Arc;

use unicron::bench::Bencher;
use unicron::checkpoint::{decode, encode};
use unicron::kvstore::Store;
use unicron::runtime::{allreduce_sum, ModelRuntime, TrainState};
use unicron::util::{fmt_bytes, RealClock, SimClock};

fn artifact(name: &str) -> Option<PathBuf> {
    let dir = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts").join(name);
    dir.join("manifest.json").exists().then_some(dir)
}

fn main() {
    let mut b = Bencher::new("runtime_hotpath").with_samples(2, 15);

    // -- PJRT dispatch -------------------------------------------------------
    if let Some(dir) = artifact("tiny") {
        let rt = ModelRuntime::load(&dir).unwrap();
        let state = rt.init_state(0);
        let tokens: Vec<i32> = (0..rt.manifest.tokens_shape.iter().product::<usize>())
            .map(|i| (i % rt.manifest.vocab) as i32)
            .collect();
        let mut grads = None;
        b.bench("pjrt_micro_step_tiny", || {
            grads = Some(rt.micro_step(&state.params, &tokens).unwrap().grads);
        });
        let grads = grads.unwrap();
        let mut st = state.clone();
        b.bench("pjrt_apply_update_tiny", || {
            rt.apply_update(&mut st, &grads, 1e-3).unwrap();
        });
    } else {
        eprintln!("artifacts/tiny missing — PJRT section skipped");
    }

    // -- host all-reduce (Eq. 6) ---------------------------------------------
    // 110M-parameter-class gradient set: 4 ranks × 110 MB of f32.
    let tensor: Vec<f32> = vec![1.0; 27_580_032];
    let rank: Vec<Vec<f32>> = vec![tensor; 4];
    // pure accumulate bandwidth (the actual hot-loop op; no clone traffic)
    {
        let mut dst = rank.clone();
        let st = b
            .bench("add_assign_110MB", || {
                unicron::runtime::add_assign(&mut dst, &rank);
            })
            .unwrap();
        let bytes = 27_580_032u64 * 4 * 4 * 3; // 4 tensors × (2 reads + 1 write)
        println!("  -> add_assign bandwidth: {}/s", fmt_bytes((bytes as f64 / st.median) as u64));
    }
    let bytes_moved = 4u64 * 27_580_032 * 4 * 4; // read 4 rank copies + write
    let st = b
        .bench("allreduce_4x110MB", || {
            let ranks: Vec<Vec<Vec<f32>>> =
                (0..4).map(|_| rank.clone()).collect::<Vec<_>>();
            std::hint::black_box(allreduce_sum(ranks, 8));
        })
        .unwrap();
    println!(
        "  -> all-reduce effective bandwidth: {}/s (incl. clone traffic)",
        fmt_bytes((bytes_moved as f64 / st.median) as u64)
    );

    // -- checkpoint codec ------------------------------------------------------
    let state = TrainState {
        params: vec![vec![0.5; 1 << 20]; 8], // 32 MiB params
        m: vec![vec![0.1; 1 << 20]; 8],
        v: vec![vec![0.2; 1 << 20]; 8],
        step: 7,
    };
    let total = state.size_bytes();
    let st = b.bench("checkpoint_encode_96MiB", || {
        std::hint::black_box(encode(&state));
    });
    if let Some(st) = st {
        println!("  -> encode throughput: {}/s", fmt_bytes((total as f64 / st.median) as u64));
    }
    let blob = encode(&state);
    let st = b.bench("checkpoint_decode_96MiB", || {
        std::hint::black_box(decode(&blob).unwrap());
    });
    if let Some(st) = st {
        println!("  -> decode throughput: {}/s", fmt_bytes((total as f64 / st.median) as u64));
    }

    // -- kvstore op rate -------------------------------------------------------
    let store = Store::new(Arc::new(RealClock::new()));
    let mut i = 0u64;
    let st = b
        .bench("kvstore_put_get_x1000", || {
            for _ in 0..1000 {
                i += 1;
                let key = format!("/status/{}/{}", i % 16, i);
                store.put(&key, "ok", None).unwrap();
                std::hint::black_box(store.get(&key));
            }
        })
        .unwrap();
    println!("  -> kvstore: {:.0} op-pairs/s", 1000.0 / st.median);

    // -- simulator event rate ---------------------------------------------------
    let trace = unicron::failure::Trace::generate(
        unicron::failure::TraceConfig::trace_b(),
        3,
    );
    let cluster = unicron::config::ClusterSpec::default();
    let cfg = unicron::config::UnicronConfig::default();
    let specs = unicron::config::table3_case(5);
    let st = b
        .bench("simulate_trace_b_unicron", || {
            let s = unicron::simulator::Simulator::builder()
                .cluster(cluster.clone())
                .config(cfg.clone())
                .policy(unicron::simulator::PolicyKind::Unicron)
                .tasks(&specs)
                .build();
            std::hint::black_box(s.run(&trace).accumulated_waf);
        })
        .unwrap();
    println!(
        "  -> simulator: {} events in {:.1} ms",
        trace.events.len(),
        st.median * 1e3
    );
    let _ = SimClock::new(); // referenced: sim clock used by tests
}
