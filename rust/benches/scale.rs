//! Scale floors (incremental replanning): the full replan-to-layout cycle
//! on a 64k-node fleet, and raw engine event dispatch throughput. Each
//! floor-gated bench records a perf-trajectory row; the run writes
//! `BENCH_PR6.json` (override the path with `BENCH_JSON`) and exits
//! non-zero on any floor violation.
//!
//! Why these stay fast at 64k nodes:
//! * capped DP — per-task `max_workers` bounds the solve width, so a replan
//!   solve is O(m·ΣK·K), independent of fleet size;
//! * delta `ScenarioLookup` — a refresh re-solves only rows the event
//!   actually changed, reusing overlapping no-fault keys bit-identically;
//! * warm-start placement — the min-churn assignment reuses the previous
//!   matching and free map, touching only nodes whose state changed.

use unicron::bench::{Bencher, Trajectory};
use unicron::config::{TaskSpec, UnicronConfig};
use unicron::coordinator::Coordinator;
use unicron::cost::TransitionProfile;
use unicron::engine::EventQueue;
use unicron::planner::PlanTask;
use unicron::proto::{CoordEvent, NodeId, TaskId, WorkerCount};

/// A planner task capped at `cap` workers — the scale-out shape: fleets
/// grow, individual training tasks don't.
fn capped_task(id: u32, min: u32, cap: u32) -> PlanTask {
    let throughput = (0..=2 * cap)
        .map(|x| if x >= min { 1e12 * (x as f64).powf(0.9) } else { 0.0 })
        .collect();
    PlanTask {
        spec: TaskSpec::new(id, "synthetic", 1.0, min).with_max_workers(cap),
        throughput,
        profile: TransitionProfile::flat(5.0),
        current: WorkerCount(0),
        fault: false,
        fault_source: unicron::transition::StateSource::InMemoryCheckpoint,
        fault_restore_s: None,
    }
}

/// Floor 1: SEV1 replan-to-layout on 65 536 nodes in < 10 ms — one
/// dispatched node loss through classify → table/solve → min-churn
/// placement → commit, plus the delta horizon refresh that re-warms the
/// table for the next event.
fn bench_replan_64k(traj: &mut Trajectory) {
    const N_NODES: u32 = 65_536;
    const FLOOR_NS: f64 = 10e6; // 10 ms

    let cfg = UnicronConfig {
        domain_batch_window_s: 0.0, // measure every event's full cycle
        ..Default::default()
    };
    let mut c = Coordinator::builder()
        .config(cfg)
        .workers(N_NODES)
        .gpus_per_node(1u32)
        .task(capped_task(0, 8, 128))
        .task(capped_task(1, 8, 128))
        .build();
    c.handle_at(CoordEvent::TaskLaunched { task: TaskId(0) }, 0.0);
    c.precompute_event_plans();
    assert_eq!(c.task_assignment(TaskId(0)), Some(WorkerCount(128)));
    assert_eq!(c.task_assignment(TaskId(1)), Some(WorkerCount(128)));

    // every iteration loses a distinct, currently-placed node: the worst
    // case for placement (the layout must backfill), the common case for
    // the table (capped assignments don't move, the replan is a hit)
    let mut b = Bencher::new("scale").with_samples(3, 20);
    let mut next = 0u32;
    let mut t = 100.0;
    let stats = b.bench("replan_to_layout_64k_nodes", || {
        let node = NodeId(next);
        next += 1;
        t += 10.0;
        let actions = c.handle_at(CoordEvent::NodeLost { node }, t);
        assert!(!actions.is_empty(), "a SEV1 must produce actions");
        if !c.lookup_is_fresh() {
            c.precompute_event_plans(); // delta refresh, part of the cycle
        }
    });
    if let Some(st) = stats {
        // the table path carried the load: replans were mostly hits, and
        // the refreshes reused prior rows instead of re-solving the world
        assert!(c.lookup_hits() > 0, "64k replans should hit the precomputed table");
        assert!(c.lookup_rows_reused() > 0, "refreshes should reuse unchanged rows");
        traj.gate("replan_to_layout_64k_nodes", st.median * 1e9, FLOOR_NS);
    }
}

/// Floor 2: ≥ 1M engine events/s through schedule + batched pop — the
/// dispatch substrate under every simulated and live timer path.
fn bench_engine_events(traj: &mut Trajectory) {
    const EVENTS: usize = 10_000;
    const FLOOR_NS: f64 = 1_000.0; // 1 µs/event = 1M events/s

    let mut b = Bencher::new("scale").with_samples(3, 20);
    let stats = b.bench("engine_schedule_pop_10k_events", || {
        let mut q = EventQueue::new();
        // 1 000 instants × 10 bitwise-simultaneous events: the burst shape
        // pop_simultaneous exists for
        for i in 0..(EVENTS / 10) as u64 {
            let at = ((i * 7919) % 1000) as f64;
            q.schedule_batch(at, (0..10).map(|k| i * 10 + k));
        }
        let mut popped = 0usize;
        loop {
            let burst = q.pop_simultaneous();
            if burst.is_empty() {
                break;
            }
            popped += burst.len();
        }
        assert_eq!(popped, EVENTS);
    });
    if let Some(st) = stats {
        traj.gate("engine_events_per_dispatch", st.median * 1e9 / EVENTS as f64, FLOOR_NS);
    }
}

fn main() {
    let mut traj = Trajectory::new();
    bench_replan_64k(&mut traj);
    bench_engine_events(&mut traj);
    traj.finish("BENCH_PR6.json");
}
