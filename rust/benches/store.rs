//! Snapshot-store hot paths (DESIGN.md §13). Two floors:
//!
//! 1. chunk + content-address throughput ≥ 1 GiB/s — the store must keep up
//!    with checkpoint streams, not become the checkpoint bottleneck;
//! 2. a delta snapshot of a 1%-changed state costs ≤ 5% of a full re-chunk —
//!    the property that makes frequent checkpoints of a slowly-changing
//!    optimizer state near-free.

use unicron::bench::{Bencher, Trajectory};
use unicron::proto::TaskId;
use unicron::store::Manifest;

const STATE_BYTES: usize = 64 << 20; // 64 MiB synthetic optimizer state
const CHUNK_BYTES: usize = 64 << 10; // 1024 chunks
const N_CHUNKS: usize = STATE_BYTES / CHUNK_BYTES;

/// Deterministic xorshift fill — incompressible enough that addressing does
/// real work, with no RNG dependency in the bench.
fn state() -> Vec<u8> {
    let mut x = 0x9e37_79b9_7f4a_7c15u64;
    let mut out = vec![0u8; STATE_BYTES];
    for w in out.chunks_mut(8) {
        x ^= x << 13;
        x ^= x >> 7;
        x ^= x << 17;
        w.copy_from_slice(&x.to_le_bytes()[..w.len()]);
    }
    out
}

fn main() {
    let mut traj = Trajectory::new();
    let data = state();
    let mut b = Bencher::new("store").with_samples(2, 10);

    // Floor 1: full chunk + address pass over the 64 MiB state
    const GIB_PER_S: f64 = (1u64 << 30) as f64;
    let full_stats = b.bench("chunk_address_64mib", || {
        let m = Manifest::build(TaskId(0), 1, &data, CHUNK_BYTES);
        assert_eq!(m.chunks.len(), N_CHUNKS);
        std::hint::black_box(m.total_bytes);
    });
    if let Some(st) = &full_stats {
        traj.gate(
            "store_chunk_address_ns_per_byte",
            st.median * 1e9 / STATE_BYTES as f64,
            1e9 / GIB_PER_S, // ≥ 1 GiB/s
        );
    }

    // Floor 2: delta snapshot with ~1% of chunks dirty vs the full pass.
    // Scattered dirty chunks (not one contiguous run) — the optimizer-state
    // shape where a few hot tensors move every step.
    let prev = Manifest::build(TaskId(0), 1, &data, CHUNK_BYTES);
    let mut next = data.clone();
    let dirty: Vec<std::ops::Range<usize>> = (0..N_CHUNKS / 100)
        .map(|k| {
            let start = (k * 97 % N_CHUNKS) * CHUNK_BYTES;
            for byte in &mut next[start..start + 16] {
                *byte ^= 0xa5;
            }
            start..start + CHUNK_BYTES
        })
        .collect();
    // delta is an acceleration, never a different answer
    assert_eq!(
        Manifest::delta_from(&prev, 2, &next, &dirty),
        Manifest::build(TaskId(0), 2, &next, CHUNK_BYTES),
    );
    let delta_stats = b.bench("delta_manifest_1pct_dirty", || {
        let m = Manifest::delta_from(&prev, 2, &next, &dirty);
        std::hint::black_box(m.chunks.len());
    });
    if let (Some(full), Some(delta)) = (&full_stats, &delta_stats) {
        traj.gate(
            "store_delta_1pct_vs_full_snapshot",
            delta.median * 1e9,
            full.median * 1e9 * 0.05, // ≤ 5% of the full-snapshot cost
        );
    }

    traj.finish("BENCH_PR7.json");
}
