//! Telemetry floors (observability PR): instrumentation must be near-free.
//! Two gates, recorded as the `BENCH_PR8.json` perf-trajectory artifact
//! (override the path with `BENCH_JSON`):
//!
//! * counter updates ≥ 1M/s — the hot-path `Registry::inc` is a `Cell` add
//!   behind a pre-registered id, no hashing, no locking, no formatting;
//! * the fully instrumented decide path (spans + phases + counters +
//!   timeline) ≤ 1.05× the tracing-off path over the same SEV1/rejoin event
//!   sequence — tracing reads a handful of monotonic timestamps per
//!   decision, everything else is the decision itself.

use unicron::bench::{Bencher, Trajectory};
use unicron::config::{TaskSpec, UnicronConfig};
use unicron::coordinator::Coordinator;
use unicron::cost::TransitionProfile;
use unicron::planner::PlanTask;
use unicron::proto::{CoordEvent, NodeId, TaskId, WorkerCount};
use unicron::telemetry::Telemetry;

fn capped_task(id: u32, min: u32, cap: u32) -> PlanTask {
    let throughput = (0..=2 * cap)
        .map(|x| if x >= min { 1e12 * (x as f64).powf(0.9) } else { 0.0 })
        .collect();
    PlanTask {
        spec: TaskSpec::new(id, "synthetic", 1.0, min).with_max_workers(cap),
        throughput,
        profile: TransitionProfile::flat(5.0),
        current: WorkerCount(0),
        fault: false,
        fault_source: unicron::transition::StateSource::InMemoryCheckpoint,
        fault_restore_s: None,
    }
}

fn decide_coordinator(tracing: bool) -> Coordinator {
    let cfg = UnicronConfig {
        domain_batch_window_s: 0.0, // measure every event's full cycle
        // the same nodes are lost and rejoined for thousands of iterations;
        // quarantining them would degrade later events into no-op decides
        // and skew the overhead ratio toward pure span cost
        lemon_quarantine: false,
        ..Default::default()
    };
    let mut c = Coordinator::builder()
        .config(cfg)
        .workers(256)
        .gpus_per_node(8u32)
        .task(capped_task(0, 8, 64))
        .task(capped_task(1, 8, 64))
        .telemetry(tracing)
        .build();
    c.handle_at(CoordEvent::TaskLaunched { task: TaskId(0) }, 0.0);
    c
}

/// Floor 1: ≥ 1M counter updates/s through the public `Telemetry::inc`
/// path — the rate every decide-path counter bump pays.
fn bench_counter_updates(traj: &mut Trajectory) {
    const UPDATES: u64 = 100_000;
    const FLOOR_NS: f64 = 1_000.0; // 1 µs/update = 1M updates/s

    let mut telemetry = Telemetry::new();
    let id = telemetry.registry_mut().counter("bench.updates");
    let mut b = Bencher::new("telemetry").with_samples(3, 20);
    let mut expected = 0u64;
    let stats = b.bench("counter_updates_100k", || {
        for _ in 0..UPDATES {
            telemetry.inc(id, 1);
        }
        expected += UPDATES;
        assert_eq!(telemetry.registry().counter_value(id), expected);
    });
    if let Some(st) = stats {
        traj.gate("counter_update", st.median * 1e9 / UPDATES as f64, FLOOR_NS);
    }
}

/// Floor 2: the instrumented decide path stays within 5% of the
/// uninstrumented one. Both coordinators replay the same lose/rejoin cycle
/// — each event a full classify → solve/lookup → place → commit decision —
/// and the gate is the ratio of medians (scaled ×1000 so the trajectory row
/// stays in integral ns-style units: 1050 = 1.05×).
fn bench_decide_overhead(traj: &mut Trajectory) {
    const EVENTS_PER_SAMPLE: usize = 32;
    const FLOOR_RATIO_X1000: f64 = 1_050.0; // 1.05× the uninstrumented path

    let run_cycle = |tracing: bool| {
        let mut c = decide_coordinator(tracing);
        let mut b = Bencher::new("telemetry").with_samples(3, 20);
        let name = if tracing {
            "decide_cycle_instrumented"
        } else {
            "decide_cycle_uninstrumented"
        };
        let mut t = 100.0;
        let stats = b.bench(name, || {
            for i in 0..EVENTS_PER_SAMPLE as u32 {
                let node = NodeId(i % 8);
                t += 10.0;
                let lost = c.handle_at(CoordEvent::NodeLost { node }, t);
                assert!(!lost.is_empty(), "a SEV1 must produce actions");
                t += 10.0;
                c.handle_at(CoordEvent::NodeJoined { node }, t);
            }
        });
        stats.map(|st| st.median)
    };

    let instrumented = run_cycle(true);
    let uninstrumented = run_cycle(false);
    if let (Some(on), Some(off)) = (instrumented, uninstrumented) {
        traj.gate("decide_overhead_ratio_x1000", on / off * 1_000.0, FLOOR_RATIO_X1000);
    }
}

fn main() {
    let mut traj = Trajectory::new();
    bench_counter_updates(&mut traj);
    bench_decide_overhead(&mut traj);
    traj.finish("BENCH_PR8.json");
}
