//! Figs. 3a / 10a / 10b — healthy-state throughput.
//!
//! * **real**: samples/s of the actual PJRT trainer on the tiny/mini GPT
//!   artifacts (Unicron-on-Megatron introduces no overhead on the training
//!   path — the trainer *is* the execution engine here);
//! * **modeled**: paper-scale samples/s and FLOP/s ratios from the
//!   calibrated cost model (the repro-harness rows for Figs. 3a/10a/10b).

use std::path::PathBuf;

use unicron::bench::Bencher;
use unicron::trainer::{DpTrainer, LrSchedule, TrainerConfig};

fn artifact(name: &str) -> Option<PathBuf> {
    let dir = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts").join(name);
    dir.join("manifest.json").exists().then_some(dir)
}

fn main() {
    let mut b = Bencher::new("throughput").with_samples(2, 10);

    for name in ["tiny", "mini"] {
        let Some(dir) = artifact(name) else {
            eprintln!("artifacts/{name} missing — skipped");
            continue;
        };
        let mut t = DpTrainer::new(TrainerConfig {
            artifact_dir: dir,
            dp: 2,
            micro_batches: 4,
            schedule: LrSchedule { base: 1e-3, warmup_steps: 0, total_steps: 0 },
            init_seed: 0,
            data_seed: 0,
        })
        .unwrap();
        let seqs_per_step = (4 * t.manifest.micro_batch) as f64;
        let flops_per_step = t.manifest.flops_per_micro_step() * 4.0;
        let st = b.bench(&format!("train_step_{name}_dp2"), || {
            t.train_step().unwrap();
        });
        if let Some(st) = st {
            println!(
                "  -> {name}: {:.1} samples/s, ~{} useful FLOP/s through PJRT-CPU",
                seqs_per_step / st.median,
                unicron::util::fmt_si(flops_per_step / st.median)
            );
        }
    }

    println!("\n{}", unicron::repro::run("fig3a", 42).unwrap());
    println!("{}", unicron::repro::run("fig10a", 42).unwrap());
    println!("{}", unicron::repro::run("fig10b", 42).unwrap());
}
