//! Fig. 11 — overall training efficiency under trace-a / trace-b, all five
//! policies, plus the simulator's own replay throughput (an 8-week trace
//! must replay in milliseconds for the lookup-table planner to stay O(1)
//! in practice).

use unicron::bench::Bencher;
use unicron::config::{table3_case, ClusterSpec, UnicronConfig};
use unicron::failure::{Trace, TraceConfig};
use unicron::metrics::Table;
use unicron::simulator::{PolicyKind, Simulator};

fn main() {
    let cluster = ClusterSpec::default();
    let cfg = UnicronConfig::default();
    let specs = table3_case(5);
    let mut b = Bencher::new("fig11_traces").with_samples(1, 5);

    // replay cost per policy (trace-a, one seed)
    let trace_a = Trace::generate(TraceConfig::trace_a(), 42);
    for kind in PolicyKind::all() {
        b.bench(&format!("replay_trace_a_{}", kind.name()), || {
            let r = Simulator::builder()
                .cluster(cluster.clone())
                .config(cfg.clone())
                .policy(kind)
                .tasks(&specs)
                .build()
                .run(&trace_a);
            std::hint::black_box(r.accumulated_waf);
        });
    }

    // headline table: mean accumulated-WAF advantage over 6 seeds
    let seeds = [1u64, 7, 42, 99, 123, 2024];
    let mut table = Table::new(&["trace", "vs Megatron", "vs Oobleck", "vs Varuna", "vs Bamboo", "paper"]);
    for (name, tc, paper) in [
        ("trace-a", TraceConfig::trace_a(), "1.2 / 3.7 / 4.8 / 4.6"),
        ("trace-b", TraceConfig::trace_b(), "1.9 / 3.8 / 5.8 / 4.8"),
    ] {
        let mut sums = [0.0f64; 4];
        for &seed in &seeds {
            let trace = Trace::generate(tc.clone(), seed);
            let acc = |k: PolicyKind| {
                Simulator::builder()
                    .cluster(cluster.clone())
                    .config(cfg.clone())
                    .policy(k)
                    .tasks(&specs)
                    .build()
                    .run(&trace)
                    .accumulated_waf
            };
            let u = acc(PolicyKind::Unicron);
            sums[0] += u / acc(PolicyKind::Megatron);
            sums[1] += u / acc(PolicyKind::Oobleck);
            sums[2] += u / acc(PolicyKind::Varuna);
            sums[3] += u / acc(PolicyKind::Bamboo);
        }
        let n = seeds.len() as f64;
        table.row(&[
            name.into(),
            format!("{:.2}×", sums[0] / n),
            format!("{:.2}×", sums[1] / n),
            format!("{:.2}×", sums[2] / n),
            format!("{:.2}×", sums[3] / n),
            paper.into(),
        ]);
    }
    println!("\nFig. 11 — accumulated-WAF advantage of Unicron (mean over {} seeds)\n{}",
             seeds.len(), table.render());
}
