//! Fig. 9 — transition time after a SEV1 failure.
//!
//! Two views, matching DESIGN.md §6:
//!  * **measured**: the real DP trainer (tiny GPT through PJRT) with an
//!    injected worker death — time for the interrupted global batch to
//!    complete via micro-batch redistribution, and time to revive the rank
//!    from a healthy replica (nearest-principle migration);
//!  * **modeled**: paper-scale transition times per policy and cluster size
//!    from the simulator's calibrated policy parameters.

use std::path::PathBuf;

use unicron::bench::Bencher;
use unicron::config::UnicronConfig;
use unicron::metrics::Table;
use unicron::simulator::{PolicyKind, PolicyParams};
use unicron::trainer::{DpTrainer, LrSchedule, TrainerConfig};
use unicron::util::fmt_duration;

fn artifact() -> Option<PathBuf> {
    let dir = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts/tiny");
    dir.join("manifest.json").exists().then_some(dir)
}

fn main() {
    let mut b = Bencher::new("fig9_transition").with_samples(0, 5);

    if let Some(dir) = artifact() {
        // measured: interrupted-iteration completion (redistribution) vs clean
        let mk = |seed| {
            DpTrainer::new(TrainerConfig {
                artifact_dir: dir.clone(),
                dp: 4,
                micro_batches: 8,
                schedule: LrSchedule { base: 1e-3, warmup_steps: 0, total_steps: 0 },
                init_seed: seed,
                data_seed: seed,
            })
            .unwrap()
        };
        let mut clean = Vec::new();
        let mut interrupted = Vec::new();
        let mut revive = Vec::new();
        for seed in 0..b.sample_iters as u64 {
            let mut t = mk(seed);
            t.train_step().unwrap(); // warmup: workers finish XLA compilation
            let r = t.train_step().unwrap();
            clean.push(r.duration_s);
            t.inject_failure(1, 1);
            let r = t.train_step().unwrap();
            assert_eq!(r.failures, vec![1]);
            interrupted.push(r.duration_s);
            let t0 = std::time::Instant::now();
            t.revive(1).unwrap();
            revive.push(t0.elapsed().as_secs_f64());
        }
        let sc = b.record("iteration_clean", clean).unwrap();
        let si = b.record("iteration_with_sev1_redistribution", interrupted).unwrap();
        let sr = b.record("revive_state_migration", revive).unwrap();
        println!(
            "\nmeasured (tiny GPT, dp=4, PJRT): clean iteration {} vs interrupted {} ({:.2}×, §6.2 \
             partial reuse; 2× would be a from-scratch recompute); revive incl. process restart + \
             XLA re-setup: {}",
            fmt_duration(sc.median),
            fmt_duration(si.median),
            si.median / sc.median,
            fmt_duration(sr.median),
        );
        // the §6.2 claim: finishing an interrupted iteration costs far less
        // than recomputing it from scratch (2× would be full recompute)
        assert!(si.median < 2.0 * sc.median, "redistribution overhead too high");
    } else {
        eprintln!("artifacts/tiny missing — measured section skipped (run `make artifacts`)");
    }

    // modeled paper scale (Fig. 9 shape): per-policy SEV1 transition time
    let cfg = UnicronConfig::default();
    let mut t = Table::new(&["GPUs", "Unicron", "Bamboo", "Oobleck", "Varuna", "Megatron"]);
    for gpus in [16u32, 32, 64] {
        let mut row = vec![gpus.to_string()];
        for k in [PolicyKind::Unicron, PolicyKind::Bamboo, PolicyKind::Oobleck, PolicyKind::Varuna, PolicyKind::Megatron] {
            let p = PolicyParams::for_kind(k, &cfg);
            row.push(fmt_duration(p.sev1_transition_s(gpus / 2)));
        }
        t.row(&row);
    }
    println!("\nFig. 9 (modeled, paper scale) — SEV1 transition time\n{}", t.render());

    // shape assertions from the paper: Unicron lowest and roughly flat
    let p = PolicyParams::for_kind(PolicyKind::Unicron, &cfg);
    let flat = p.sev1_transition_s(32) / p.sev1_transition_s(8);
    assert!(flat < 2.0, "Unicron transition should be roughly scale-stable");
}
