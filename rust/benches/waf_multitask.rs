//! Fig. 10c — multi-task WAF across the Table 3 cases: the real planner vs
//! the equally/weighted/sized baselines, plus the solve-time cost of each.

use unicron::bench::Bencher;
use unicron::config::{table3_case, ClusterSpec, ModelSpec, UnicronConfig};
use unicron::cost::{CostModel, TransitionProfile};
use unicron::perfmodel::throughput_table;
use unicron::planner::{baselines, solve, PlanTask};
use unicron::proto::WorkerCount;

fn main() {
    let cluster = ClusterSpec::default();
    let cost = CostModel::from_config(&UnicronConfig::default());
    let n = cluster.total_gpus();
    let mut b = Bencher::new("fig10c_waf").with_samples(2, 10);

    for case in 1..=5u32 {
        let tasks: Vec<PlanTask> = table3_case(case)
            .into_iter()
            .map(|spec| {
                let model = ModelSpec::gpt3(&spec.model).unwrap();
                PlanTask {
                    throughput: throughput_table(&model, &cluster, n),
                    profile: TransitionProfile::from_model(&model, &cluster),
                    spec,
                    current: WorkerCount(0),
                    fault: false,
                    fault_source: unicron::transition::StateSource::InMemoryCheckpoint,
                    fault_restore_s: None,
                }
            })
            .collect();
        b.bench(&format!("solve_case{case}"), || {
            std::hint::black_box(solve(&tasks, n, &cost));
        });
        // correctness along the way: Unicron ≥ every baseline
        let uni = solve(&tasks, n, &cost).total_waf;
        let waf_of = |alloc: &[u32]| tasks.iter().zip(alloc).map(|(t, &x)| t.waf(x)).sum::<f64>();
        let sizes: Vec<f64> = table3_case(case)
            .iter()
            .map(|s| ModelSpec::gpt3(&s.model).unwrap().n_params)
            .collect();
        for (name, alloc) in [
            ("equally", baselines::equally(&tasks, n)),
            ("weighted", baselines::weighted(&tasks, n)),
            ("sized", baselines::sized(&tasks, n, &sizes)),
        ] {
            assert!(
                uni >= waf_of(&alloc) - 1e-6,
                "case {case}: {name} beat the planner"
            );
        }
    }

    println!("\n{}", unicron::repro::run("fig10c", 42).unwrap());
}
