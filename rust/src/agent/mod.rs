//! The Unicron agent (§3.1): the per-machine daemon. It keeps a persistent
//! (lease-backed) connection to the coordinator, runs one monitoring thread
//! per GPU process, propagates exceptions the instant they are raised, and
//! executes recovery actions the coordinator sends back.
//!
//! Monitored "training processes" are [`ProcessHandle`]s — the seam through
//! which tests and benches inject every Table 1 failure class: `kill()`
//! (process supervision), `throw()` (exception propagation), iteration
//! stalls (online statistical monitoring), and agent death itself (node
//! health, by dropping the whole agent).

use anyhow::Result;
use std::sync::atomic::{AtomicBool, AtomicU32, Ordering};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;
use std::time::Duration;

use crate::config::UnicronConfig;
use crate::detect::StatMonitor;
use crate::kvstore::net::KvClient;
use crate::membership::{NodeInfo, NODES_PREFIX};
use crate::proto::{NodeId, TaskId, WorkerCount};
use crate::ser::Value;
use crate::util::Clock;

/// Handle to one supervised training process (one GPU's worth).
#[derive(Clone)]
pub struct ProcessHandle {
    pub task: TaskId,
    alive: Arc<AtomicBool>,
    exception: Arc<Mutex<Option<String>>>,
    /// Completed-iteration durations feed the stat monitor.
    iter_durations: Arc<Mutex<Vec<f64>>>,
    /// Clock time the current iteration started (None = idle).
    iter_started: Arc<Mutex<Option<f64>>>,
    restarts: Arc<AtomicU32>,
}

impl ProcessHandle {
    pub fn new(task: impl Into<TaskId>) -> ProcessHandle {
        ProcessHandle {
            task: task.into(),
            alive: Arc::new(AtomicBool::new(true)),
            exception: Arc::new(Mutex::new(None)),
            iter_durations: Arc::new(Mutex::new(Vec::new())),
            iter_started: Arc::new(Mutex::new(None)),
            restarts: Arc::new(AtomicU32::new(0)),
        }
    }

    /// Simulate abnormal process termination (SEV2 via process supervision).
    pub fn kill(&self) {
        self.alive.store(false, Ordering::SeqCst);
    }

    /// Simulate a raised exception (exception propagation path).
    pub fn throw(&self, msg: &str) {
        *self.exception.lock().unwrap() = Some(msg.to_string());
    }

    /// Training-loop hooks (normally called by the worker).
    pub fn begin_iteration(&self, now: f64) {
        *self.iter_started.lock().unwrap() = Some(now);
    }

    pub fn end_iteration(&self, now: f64) {
        let mut started = self.iter_started.lock().unwrap();
        if let Some(t0) = started.take() {
            self.iter_durations.lock().unwrap().push(now - t0);
        }
    }

    pub fn is_alive(&self) -> bool {
        self.alive.load(Ordering::SeqCst)
    }

    /// Recovery: the agent restarts the process in place.
    pub fn restart(&self) {
        self.alive.store(true, Ordering::SeqCst);
        *self.exception.lock().unwrap() = None;
        *self.iter_started.lock().unwrap() = None;
        self.restarts.fetch_add(1, Ordering::SeqCst);
    }

    pub fn restart_count(&self) -> u32 {
        self.restarts.load(Ordering::SeqCst)
    }
}

/// A running agent (threads stop when the handle is dropped or `stop()`ed).
pub struct Agent {
    pub node_id: NodeId,
    stop: Arc<AtomicBool>,
    crashed: Arc<AtomicBool>,
    threads: Vec<JoinHandle<()>>,
}

impl Agent {
    /// Start an agent for `node_id`, monitoring `processes`, against the
    /// coordinator's kvstore at `coord_addr`.
    pub fn start(
        node_id: impl Into<NodeId>,
        gpus: impl Into<WorkerCount>,
        coord_addr: std::net::SocketAddr,
        cfg: &UnicronConfig,
        processes: Vec<ProcessHandle>,
        clock: Arc<dyn Clock>,
    ) -> Result<Agent> {
        let node_id = node_id.into();
        let gpus = gpus.into();
        let stop = Arc::new(AtomicBool::new(false));
        let crashed = Arc::new(AtomicBool::new(false));
        let mut threads = Vec::new();

        // -- node health: register + heartbeat (persistent connection) ------
        let mut kv = KvClient::connect(coord_addr)?;
        let lease = kv.lease_grant(cfg.lease_ttl_s)?;
        let info = NodeInfo { id: node_id.to_string(), gpus: gpus.0, addr: String::new() };
        kv.put(&format!("{NODES_PREFIX}{node_id}"), &info.to_json().encode(), Some(lease))?;
        {
            let stop = stop.clone();
            let crashed = crashed.clone();
            let period = Duration::from_secs_f64(cfg.heartbeat_period_s.min(0.2));
            threads.push(std::thread::Builder::new().name(format!("agent{node_id}-hb")).spawn(
                move || {
                    while !stop.load(Ordering::Relaxed) {
                        if kv.keepalive(lease).is_err() {
                            return; // declared dead; stop heartbeating
                        }
                        std::thread::sleep(period);
                    }
                    // crash(): abandon the lease so it expires (SEV1 path);
                    // stop(): revoke it (clean leave).
                    if !crashed.load(Ordering::Relaxed) {
                        let _ = kv.lease_revoke(lease);
                    }
                },
            )?);
        }

        // -- one monitoring thread per GPU process --------------------------
        let seq = Arc::new(AtomicU32::new(0));
        for (gpu_idx, proc_) in processes.into_iter().enumerate() {
            let stop = stop.clone();
            let clock = clock.clone();
            let seq = seq.clone();
            let mut kv = KvClient::connect(coord_addr)?;
            let warn = cfg.stat_warn_factor;
            let fail = cfg.stat_fail_factor;
            let step_period = cfg.step_report_period_s;
            threads.push(
                std::thread::Builder::new().name(format!("agent{node_id}-mon{gpu_idx}")).spawn(
                    move || {
                        let mut stat = StatMonitor::new(warn, fail);
                        let mut reported_dead = false;
                        let mut reported_stall = false;
                        let mut last_step_report = f64::NEG_INFINITY;
                        let mut fed = 0usize;
                        while !stop.load(Ordering::Relaxed) {
                            // exception propagation: immediate
                            if let Some(msg) = proc_.exception.lock().unwrap().take() {
                                report(&mut kv, node_id, &seq, proc_.task, "exception", &msg);
                            }
                            // process supervision
                            if !proc_.is_alive() && !reported_dead {
                                reported_dead = true;
                                report(&mut kv, node_id, &seq, proc_.task, "exit", "");
                            } else if proc_.is_alive() {
                                reported_dead = false;
                            }
                            // online statistical monitoring
                            {
                                let durations = {
                                    let mut g = proc_.iter_durations.lock().unwrap();
                                    std::mem::take(&mut *g)
                                };
                                for d in durations {
                                    stat.record(d);
                                    fed += 1;
                                    reported_stall = false;
                                    // in-band health observation (wire v8):
                                    // ship the raw step wall time on the
                                    // report cadence — the coordinator's
                                    // streaming baseline, not the agent,
                                    // decides whether it is out of band
                                    let now = clock.now();
                                    if now - last_step_report >= step_period {
                                        last_step_report = now;
                                        report_step(&mut kv, node_id, &seq, proc_.task, d);
                                    }
                                }
                                let _ = fed;
                                let started = *proc_.iter_started.lock().unwrap();
                                if let (Some(t0), Some(_avg)) = (started, stat.average()) {
                                    let elapsed = clock.now() - t0;
                                    if stat.check(elapsed) == crate::detect::StatStatus::Failed
                                        && !reported_stall
                                    {
                                        reported_stall = true;
                                        report(&mut kv, node_id, &seq, proc_.task, "stall", "");
                                    }
                                }
                            }
                            std::thread::sleep(Duration::from_millis(5));
                        }
                    },
                )?,
            );
        }

        Ok(Agent { node_id, stop, crashed, threads })
    }

    /// Maintenance hook: announce to the coordinator that this machine's
    /// repair finished and it is ready for a fleet decision — rejoin
    /// (`SpareRetained`), hold/return (`SpareReleased`), or refuse as a
    /// lemon (`NodeQuarantined`). Called by repair tooling, not the agent
    /// threads: the node may not be running an agent yet.
    pub fn announce_repaired(
        coord_addr: std::net::SocketAddr,
        node_id: impl Into<NodeId>,
    ) -> Result<()> {
        let node_id = node_id.into();
        let mut kv = KvClient::connect(coord_addr)?;
        let body = Value::obj().with("task", 0u64).with("class", "repaired").with("msg", "");
        kv.put(&format!("/status/{node_id}/repaired"), &body.encode(), None)?;
        Ok(())
    }

    /// Graceful stop: heartbeat revokes the lease (clean leave, not SEV1).
    pub fn stop(mut self) {
        self.stop.store(true, Ordering::Relaxed);
        for t in self.threads.drain(..) {
            let _ = t.join();
        }
    }

    /// Simulate the whole node dying: threads are *abandoned* (no lease
    /// revoke) so the coordinator only finds out via lease expiry — exactly
    /// the paper's case-1 detection path.
    pub fn crash(mut self) {
        self.crashed.store(true, Ordering::Relaxed);
        self.stop.store(true, Ordering::Relaxed);
        for t in self.threads.drain(..) {
            let _ = t.join();
        }
        // note: no lease_revoke — the lease is left to expire.
    }
}

impl Drop for Agent {
    fn drop(&mut self) {
        self.stop.store(true, Ordering::Relaxed);
        for t in self.threads.drain(..) {
            let _ = t.join();
        }
    }
}

fn report(kv: &mut KvClient, node: NodeId, seq: &AtomicU32, task: TaskId, class: &str, msg: &str) {
    let n = seq.fetch_add(1, Ordering::Relaxed);
    let body = Value::obj().with("task", task.0 as u64).with("class", class).with("msg", msg);
    let _ = kv.put(&format!("/status/{node}/{n}"), &body.encode(), None);
}

/// In-band step-timing report (`{"class":"step"}` →
/// [`crate::proto::CoordEvent::StepTiming`]).
fn report_step(kv: &mut KvClient, node: NodeId, seq: &AtomicU32, task: TaskId, duration_s: f64) {
    let n = seq.fetch_add(1, Ordering::Relaxed);
    let body = Value::obj()
        .with("task", task.0 as u64)
        .with("class", "step")
        .with("duration_s", duration_s);
    let _ = kv.put(&format!("/status/{node}/{n}"), &body.encode(), None);
}

// Live end-to-end tests (agent + coordinator over TCP) are in
// rust/tests/coordinator_e2e.rs; unit tests cover the handle mechanics.
#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn process_handle_lifecycle() {
        let p = ProcessHandle::new(3u32);
        assert!(p.is_alive());
        p.kill();
        assert!(!p.is_alive());
        p.restart();
        assert!(p.is_alive());
        assert_eq!(p.restart_count(), 1);
    }

    #[test]
    fn exception_is_taken_once() {
        let p = ProcessHandle::new(0u32);
        p.throw("CUDA error");
        assert_eq!(p.exception.lock().unwrap().take(), Some("CUDA error".into()));
        assert_eq!(p.exception.lock().unwrap().take(), None);
    }

    #[test]
    fn iteration_hooks_record_durations() {
        let p = ProcessHandle::new(0u32);
        p.begin_iteration(10.0);
        p.end_iteration(12.5);
        p.begin_iteration(13.0);
        // second iteration still running
        let d = p.iter_durations.lock().unwrap().clone();
        assert_eq!(d, vec![2.5]);
        assert!(p.iter_started.lock().unwrap().is_some());
    }
}
