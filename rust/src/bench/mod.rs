//! Mini-criterion: the timing harness behind `cargo bench`.
//!
//! No `criterion` in the vendored registry, so benches use this: warmup,
//! fixed sample count, robust summary statistics (mean/median/p95/min), and
//! an optional `BENCH_FILTER` env var to select benchmarks by substring
//! (set `BENCH_FILTER=replan` to run only matching benches — CI's
//! bench-smoke step uses it to bound runtime). Results print in a
//! criterion-like one-line format and can be dumped as JSON for
//! EXPERIMENTS.md; floor-gated benches additionally record a
//! [`Trajectory`] row and write the perf-trajectory artifact
//! (`BENCH_PR6.json`) that CI archives per run.

use std::time::Instant;

use crate::ser::Value;

/// Summary statistics over per-iteration runtimes (seconds).
#[derive(Debug, Clone)]
pub struct Stats {
    pub samples: usize,
    pub mean: f64,
    pub median: f64,
    pub p95: f64,
    pub min: f64,
    pub max: f64,
    pub stddev: f64,
}

impl Stats {
    pub fn from_samples(mut xs: Vec<f64>) -> Stats {
        assert!(!xs.is_empty());
        xs.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let n = xs.len();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        let pct = |p: f64| xs[((p * (n - 1) as f64).round() as usize).min(n - 1)];
        Stats {
            samples: n,
            mean,
            median: pct(0.5),
            p95: pct(0.95),
            min: xs[0],
            max: xs[n - 1],
            stddev: var.sqrt(),
        }
    }

    pub fn to_json(&self, name: &str) -> Value {
        Value::obj()
            .with("name", name)
            .with("samples", self.samples)
            .with("mean_s", self.mean)
            .with("median_s", self.median)
            .with("p95_s", self.p95)
            .with("min_s", self.min)
            .with("max_s", self.max)
            .with("stddev_s", self.stddev)
    }
}

fn fmt_time(s: f64) -> String {
    if s >= 1.0 {
        format!("{s:.3} s")
    } else if s >= 1e-3 {
        format!("{:.3} ms", s * 1e3)
    } else if s >= 1e-6 {
        format!("{:.3} µs", s * 1e6)
    } else {
        format!("{:.1} ns", s * 1e9)
    }
}

/// A bench group; mirrors criterion's `Criterion` entry point.
pub struct Bencher {
    pub group: String,
    pub warmup_iters: usize,
    pub sample_iters: usize,
    filter: Option<String>,
    pub results: Vec<(String, Stats)>,
}

impl Bencher {
    pub fn new(group: &str) -> Bencher {
        Bencher {
            group: group.to_string(),
            warmup_iters: 3,
            sample_iters: 20,
            filter: std::env::var("BENCH_FILTER").ok(),
            results: Vec::new(),
        }
    }

    pub fn with_samples(mut self, warmup: usize, samples: usize) -> Self {
        self.warmup_iters = warmup;
        self.sample_iters = samples;
        self
    }

    fn selected(&self, name: &str) -> bool {
        match &self.filter {
            Some(f) => name.contains(f.as_str()) || self.group.contains(f.as_str()),
            None => true,
        }
    }

    /// Time `f` (one call = one sample). Returns stats (also stored/printed).
    pub fn bench<F: FnMut()>(&mut self, name: &str, mut f: F) -> Option<Stats> {
        if !self.selected(name) {
            return None;
        }
        for _ in 0..self.warmup_iters {
            f();
        }
        let mut samples = Vec::with_capacity(self.sample_iters);
        for _ in 0..self.sample_iters {
            let t0 = Instant::now();
            f();
            samples.push(t0.elapsed().as_secs_f64());
        }
        let st = Stats::from_samples(samples);
        println!(
            "{:<40} time: [{} {} {}]  p95: {}",
            format!("{}/{}", self.group, name),
            fmt_time(st.min),
            fmt_time(st.median),
            fmt_time(st.max),
            fmt_time(st.p95),
        );
        self.results.push((name.to_string(), st.clone()));
        Some(st)
    }

    /// Record an externally-measured set of samples (e.g. latencies harvested
    /// from a running system rather than a closure loop).
    pub fn record(&mut self, name: &str, samples: Vec<f64>) -> Option<Stats> {
        if !self.selected(name) || samples.is_empty() {
            return None;
        }
        let st = Stats::from_samples(samples);
        println!(
            "{:<40} time: [{} {} {}]  p95: {} ({} samples)",
            format!("{}/{}", self.group, name),
            fmt_time(st.min),
            fmt_time(st.median),
            fmt_time(st.max),
            fmt_time(st.p95),
            st.samples,
        );
        self.results.push((name.to_string(), st.clone()));
        Some(st)
    }

    /// JSON report of all results in this group.
    pub fn report(&self) -> Value {
        Value::obj().with("group", self.group.as_str()).with(
            "results",
            Value::Arr(self.results.iter().map(|(n, s)| s.to_json(n)).collect()),
        )
    }
}

/// Recorded perf trajectory: one row per floor-gated bench — name, measured
/// ns/op, the pinned floor, pass/fail — serialized as the `BENCH_PR6.json`
/// artifact CI uploads per run. Floors are *ceilings on ns/op*; a
/// throughput floor (≥ X ops/s) gates as `1e9 / X` ns/op.
#[derive(Debug, Default)]
pub struct Trajectory {
    rows: Vec<Value>,
    violations: Vec<String>,
}

impl Trajectory {
    pub fn new() -> Trajectory {
        Trajectory::default()
    }

    /// Gate one measurement against its floor (max ns per operation).
    /// Records the row either way and returns whether the floor holds.
    pub fn gate(&mut self, name: &str, ns_per_op: f64, floor_ns_per_op: f64) -> bool {
        let pass = ns_per_op <= floor_ns_per_op;
        self.rows.push(
            Value::obj()
                .with("name", name)
                .with("ns_per_op", ns_per_op)
                .with("floor_ns_per_op", floor_ns_per_op)
                .with("pass", pass),
        );
        let verdict = if pass { "ok" } else { "FLOOR VIOLATED" };
        println!(
            "{name:<40} {:>12.1} ns/op  (floor {:.1} ns/op)  {verdict}",
            ns_per_op, floor_ns_per_op
        );
        if !pass {
            self.violations
                .push(format!("{name}: {ns_per_op:.1} ns/op over floor {floor_ns_per_op:.1}"));
        }
        pass
    }

    pub fn to_json(&self) -> Value {
        Value::obj()
            .with("benches", Value::Arr(self.rows.clone()))
            .with("pass", self.violations.is_empty())
    }

    /// Write the artifact (path from `BENCH_JSON`, defaulting to `path`)
    /// and panic on any recorded floor violation — `cargo bench` exits
    /// non-zero and CI goes red. Call last, after every gate.
    pub fn finish(self, path: &str) {
        let out = std::env::var("BENCH_JSON").unwrap_or_else(|_| path.to_string());
        std::fs::write(&out, self.to_json().encode())
            .unwrap_or_else(|e| panic!("writing {out}: {e}"));
        println!("perf trajectory -> {out}");
        assert!(self.violations.is_empty(), "perf floors violated: {:?}", self.violations);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stats_are_ordered() {
        let s = Stats::from_samples(vec![3.0, 1.0, 2.0, 10.0]);
        assert_eq!(s.min, 1.0);
        assert_eq!(s.max, 10.0);
        assert!(s.min <= s.median && s.median <= s.p95 && s.p95 <= s.max);
        assert_eq!(s.samples, 4);
        assert!((s.mean - 4.0).abs() < 1e-12);
    }

    #[test]
    fn bench_runs_and_records() {
        let mut b = Bencher::new("test").with_samples(1, 3);
        let mut count = 0;
        let st = b.bench("noop", || count += 1).unwrap();
        assert_eq!(count, 4); // 1 warmup + 3 samples
        assert!(st.mean >= 0.0);
        assert_eq!(b.results.len(), 1);
        let report = b.report();
        assert_eq!(report.get("group").unwrap().as_str(), Some("test"));
    }

    #[test]
    fn trajectory_gates_and_serializes() {
        let mut t = Trajectory::new();
        assert!(t.gate("fast_bench", 500.0, 1_000.0));
        assert!(!t.gate("slow_bench", 2_000.0, 1_000.0));
        let j = t.to_json();
        assert_eq!(j.get("pass").unwrap().as_bool(), Some(false));
        let rows = j.get("benches").unwrap().as_arr().unwrap();
        assert_eq!(rows.len(), 2);
        assert_eq!(rows[0].get("name").unwrap().as_str(), Some("fast_bench"));
        assert_eq!(rows[0].get("pass").unwrap().as_bool(), Some(true));
        assert_eq!(rows[1].get("floor_ns_per_op").unwrap().as_f64(), Some(1000.0));
        assert_eq!(rows[1].get("pass").unwrap().as_bool(), Some(false));
        // the artifact round-trips through the strict parser
        let parsed = Value::parse(&j.encode()).unwrap();
        assert_eq!(parsed, j);
    }

    #[test]
    fn fmt_time_scales() {
        assert!(fmt_time(2.0).ends_with(" s"));
        assert!(fmt_time(2e-3).ends_with(" ms"));
        assert!(fmt_time(2e-6).contains("µs"));
        assert!(fmt_time(2e-9).ends_with(" ns"));
    }
}
