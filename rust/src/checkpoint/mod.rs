//! GEMINI-style hierarchical checkpointing (§3.1, following [49]):
//! an **in-memory checkpoint** replicated to a peer node (fast tier) plus an
//! asynchronous copy to **remote persistent storage** (slow tier, the
//! paper's 20 GB/s shared cloud filesystem). Recovery prefers the nearest
//! tier (§6.3) and falls back down the hierarchy.
//!
//! Serialization is a self-contained binary format (magic, step, tensor
//! table, raw f32 data) with an integrity digest — a corrupt or truncated
//! checkpoint is detected, never silently loaded. The digest is CRC32C-style
//! (crc32fast, SIMD) covering the whole body plus a sha256 of the *header*
//! only: full-body sha256 capped encode/decode at ~310 MiB/s (§Perf in
//! EXPERIMENTS.md), while crc32fast runs at multi-GiB/s and catches the same
//! accidental-corruption class (bit flips, truncation, torn writes) — these
//! checkpoints defend against faults, not adversaries.

use anyhow::{bail, Context, Result};
use sha2::{Digest, Sha256};

/// Body checksum: crc32fast over the payload + length, little-endian packed
/// into 32 bytes alongside a sha256 of the fixed-size header for defense in
/// depth on the metadata.
fn digest32(body: &[u8]) -> [u8; 32] {
    let mut out = [0u8; 32];
    let mut h = crc32fast::Hasher::new();
    h.update(body);
    out[..4].copy_from_slice(&h.finalize().to_le_bytes());
    out[4..12].copy_from_slice(&(body.len() as u64).to_le_bytes());
    // sha256 over the fixed-size header — magic (8) + step (8) + tensor
    // count (4) = 20 bytes — clamped for bodies shorter than that. (The
    // old bound summed two independently-clamped terms, which was hard to
    // show in-range for short bodies; min(len, header) is the intent.)
    let hdr = &body[..body.len().min(MAGIC.len() + 12)];
    let sh = Sha256::digest(hdr);
    out[12..32].copy_from_slice(&sh[..20]);
    out
}
use std::collections::BTreeMap;
use std::fs;
use std::path::{Path, PathBuf};
use std::sync::{Arc, Mutex};

use crate::runtime::TrainState;

const MAGIC: &[u8; 8] = b"UNICKPT1";

/// Serialize a [`TrainState`] (params, m, v, step) with integrity digest.
pub fn encode(state: &TrainState) -> Vec<u8> {
    let mut out = Vec::with_capacity(64 + state.size_bytes() as usize);
    out.extend_from_slice(MAGIC);
    out.extend_from_slice(&state.step.to_le_bytes());
    out.extend_from_slice(&(state.params.len() as u32).to_le_bytes());
    for group in [&state.params, &state.m, &state.v] {
        for tensor in group.iter() {
            out.extend_from_slice(&(tensor.len() as u64).to_le_bytes());
            let bytes = unsafe {
                std::slice::from_raw_parts(tensor.as_ptr() as *const u8, tensor.len() * 4)
            };
            out.extend_from_slice(bytes);
        }
    }
    let digest = digest32(&out);
    out.extend_from_slice(&digest);
    out
}

/// Decode + verify. Fails on bad magic, truncation, or digest mismatch.
pub fn decode(bytes: &[u8]) -> Result<TrainState> {
    if bytes.len() < MAGIC.len() + 8 + 4 + 32 {
        bail!("checkpoint too short ({} bytes)", bytes.len());
    }
    let (body, digest) = bytes.split_at(bytes.len() - 32);
    if digest32(body) != digest {
        bail!("checkpoint digest mismatch (corrupt or truncated)");
    }
    let mut pos = 0usize;
    let take = |pos: &mut usize, n: usize| -> Result<&[u8]> {
        if *pos + n > body.len() {
            bail!("checkpoint truncated at byte {}", *pos);
        }
        let s = &body[*pos..*pos + n];
        *pos += n;
        Ok(s)
    };
    if take(&mut pos, 8)? != MAGIC {
        bail!("bad checkpoint magic");
    }
    let step = u64::from_le_bytes(take(&mut pos, 8)?.try_into().unwrap());
    let n = u32::from_le_bytes(take(&mut pos, 4)?.try_into().unwrap()) as usize;
    let mut groups: Vec<Vec<Vec<f32>>> = Vec::with_capacity(3);
    for _ in 0..3 {
        let mut group = Vec::with_capacity(n);
        for _ in 0..n {
            let len = u64::from_le_bytes(take(&mut pos, 8)?.try_into().unwrap()) as usize;
            let raw = take(&mut pos, len * 4)?;
            let mut tensor = vec![0f32; len];
            unsafe {
                std::ptr::copy_nonoverlapping(raw.as_ptr(), tensor.as_mut_ptr() as *mut u8, len * 4);
            }
            group.push(tensor);
        }
        groups.push(group);
    }
    if pos != body.len() {
        bail!("trailing bytes in checkpoint");
    }
    let v = groups.pop().unwrap();
    let m = groups.pop().unwrap();
    let params = groups.pop().unwrap();
    Ok(TrainState { params, m, v, step })
}

/// Fast tier: in-memory checkpoints held by peer "nodes" (here: a shared map
/// keyed by node id — in the live system each agent hosts its shard).
#[derive(Clone, Default)]
pub struct InMemoryTier {
    slots: Arc<Mutex<BTreeMap<String, Arc<Vec<u8>>>>>,
}

impl InMemoryTier {
    pub fn new() -> InMemoryTier {
        Self::default()
    }

    /// Store a checkpoint for `task` on `peer` (replacing older ones).
    pub fn store(&self, task: &str, peer: &str, data: Arc<Vec<u8>>) {
        self.slots.lock().unwrap().insert(format!("{task}@{peer}"), data);
    }

    /// Drop every checkpoint hosted on `peer` (the node died).
    pub fn drop_peer(&self, peer: &str) {
        self.slots.lock().unwrap().retain(|k, _| !k.ends_with(&format!("@{peer}")));
    }

    /// Fetch any replica of `task`'s checkpoint.
    pub fn fetch(&self, task: &str) -> Option<Arc<Vec<u8>>> {
        let g = self.slots.lock().unwrap();
        g.iter().find(|(k, _)| k.starts_with(&format!("{task}@"))).map(|(_, v)| v.clone())
    }

    pub fn replica_count(&self, task: &str) -> usize {
        let g = self.slots.lock().unwrap();
        g.keys().filter(|k| k.starts_with(&format!("{task}@"))).count()
    }
}

/// Checkpoint manager for one task: writes the fast tier synchronously and
/// the slow tier (filesystem directory standing in for the cloud store)
/// on demand; restores via the nearest available tier.
pub struct CheckpointManager {
    pub task: String,
    pub inmem: InMemoryTier,
    remote_dir: PathBuf,
}

/// Which tier a restore came from (mirrors [`crate::transition::StateSource`]).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum RestoredFrom {
    InMemory,
    Remote,
}

impl CheckpointManager {
    pub fn new(task: &str, inmem: InMemoryTier, remote_dir: impl AsRef<Path>) -> Result<Self> {
        fs::create_dir_all(remote_dir.as_ref())
            .with_context(|| format!("creating {}", remote_dir.as_ref().display()))?;
        Ok(CheckpointManager { task: task.into(), inmem, remote_dir: remote_dir.as_ref().into() })
    }

    fn remote_path(&self) -> PathBuf {
        self.remote_dir.join(format!("{}.ckpt", self.task))
    }

    /// Save to the in-memory tier on `peers` (GEMINI replication).
    pub fn save_inmem(&self, state: &TrainState, peers: &[&str]) {
        let data = Arc::new(encode(state));
        for p in peers {
            self.inmem.store(&self.task, p, data.clone());
        }
    }

    /// Persist to the remote tier (atomic rename so readers never see a
    /// partial file).
    pub fn save_remote(&self, state: &TrainState) -> Result<()> {
        let data = encode(state);
        let tmp = self.remote_path().with_extension("tmp");
        fs::write(&tmp, &data)?;
        fs::rename(&tmp, self.remote_path())?;
        Ok(())
    }

    /// Restore from the nearest tier: in-memory replica first, remote second.
    pub fn restore(&self) -> Result<(TrainState, RestoredFrom)> {
        if let Some(data) = self.inmem.fetch(&self.task) {
            match decode(&data) {
                Ok(s) => return Ok((s, RestoredFrom::InMemory)),
                Err(_) => { /* corrupt fast-tier copy: fall through to remote */ }
            }
        }
        let path = self.remote_path();
        let data = fs::read(&path)
            .with_context(|| format!("no checkpoint available for {}", self.task))?;
        Ok((decode(&data)?, RestoredFrom::Remote))
    }

    pub fn remote_exists(&self) -> bool {
        self.remote_path().exists()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn state(step: u64) -> TrainState {
        TrainState {
            params: vec![vec![1.0, -2.0, 3.5], vec![0.25; 5]],
            m: vec![vec![0.1, 0.2, 0.3], vec![0.0; 5]],
            v: vec![vec![0.01, 0.02, 0.03], vec![1.0; 5]],
            step,
        }
    }

    fn tmpdir(tag: &str) -> PathBuf {
        let d = std::env::temp_dir().join(format!("unicron-ckpt-{tag}-{}", std::process::id()));
        let _ = fs::remove_dir_all(&d);
        fs::create_dir_all(&d).unwrap();
        d
    }

    #[test]
    fn digest32_handles_empty_short_and_normal_bodies() {
        // empty and shorter-than-header bodies must not panic, and the
        // length field must reflect the body
        for len in [0usize, 1, 7, 8, 12, 19, 20, 21, 64] {
            let body = vec![0xA5u8; len];
            let d = digest32(&body);
            assert_eq!(d[4..12], (len as u64).to_le_bytes(), "len {len}");
        }
        // the header hash covers exactly the first 20 bytes: flipping a
        // header byte changes out[12..], flipping a later byte must not
        let body: Vec<u8> = (0..64u8).collect();
        let base = digest32(&body);
        let mut hdr_flip = body.clone();
        hdr_flip[10] ^= 0xFF;
        assert_ne!(digest32(&hdr_flip)[12..32], base[12..32]);
        let mut tail_flip = body.clone();
        tail_flip[40] ^= 0xFF;
        let tail_digest = digest32(&tail_flip);
        assert_eq!(tail_digest[12..32], base[12..32], "tail bytes are not header");
        assert_ne!(tail_digest[..4], base[..4], "but the body CRC still catches them");
        // a body exactly one byte short of the header hashes only what exists
        let short = &body[..19];
        assert_eq!(digest32(short)[12..32], {
            let sh = Sha256::digest(short);
            let mut want = [0u8; 20];
            want.copy_from_slice(&sh[..20]);
            want
        });
    }

    #[test]
    fn encode_decode_roundtrip() {
        let s = state(42);
        let data = encode(&s);
        let back = decode(&data).unwrap();
        assert_eq!(back, s);
        assert_eq!(back.step, 42);
    }

    #[test]
    fn corruption_detected() {
        let mut data = encode(&state(1));
        // flip a bit in the middle of tensor data
        let mid = data.len() / 2;
        data[mid] ^= 0x01;
        assert!(decode(&data).is_err());
        // truncation
        let data2 = encode(&state(1));
        assert!(decode(&data2[..data2.len() - 10]).is_err());
        // bad magic
        let mut data3 = encode(&state(1));
        data3[0] = b'X';
        assert!(decode(&data3).is_err()); // digest catches it
    }

    #[test]
    fn inmem_tier_replication_and_peer_loss() {
        let tier = InMemoryTier::new();
        let mgr = CheckpointManager::new("t1", tier.clone(), tmpdir("peer")).unwrap();
        mgr.save_inmem(&state(7), &["nodeA", "nodeB"]);
        assert_eq!(tier.replica_count("t1"), 2);
        tier.drop_peer("nodeA");
        assert_eq!(tier.replica_count("t1"), 1);
        let (s, from) = mgr.restore().unwrap();
        assert_eq!(from, RestoredFrom::InMemory);
        assert_eq!(s.step, 7);
        tier.drop_peer("nodeB");
        assert!(mgr.restore().is_err(), "no tier left");
    }

    #[test]
    fn remote_fallback_when_memory_lost() {
        let tier = InMemoryTier::new();
        let mgr = CheckpointManager::new("t2", tier.clone(), tmpdir("remote")).unwrap();
        mgr.save_inmem(&state(3), &["nodeA"]);
        mgr.save_remote(&state(3)).unwrap();
        assert!(mgr.remote_exists());
        tier.drop_peer("nodeA"); // lose the fast tier
        let (s, from) = mgr.restore().unwrap();
        assert_eq!(from, RestoredFrom::Remote);
        assert_eq!(s.step, 3);
    }

    #[test]
    fn newest_inmem_wins_over_stale_remote() {
        let tier = InMemoryTier::new();
        let mgr = CheckpointManager::new("t3", tier.clone(), tmpdir("newest")).unwrap();
        mgr.save_remote(&state(10)).unwrap();
        mgr.save_inmem(&state(20), &["nodeA"]);
        let (s, from) = mgr.restore().unwrap();
        assert_eq!((s.step, from), (20, RestoredFrom::InMemory));
    }

    #[test]
    fn tasks_are_isolated() {
        let tier = InMemoryTier::new();
        let dir = tmpdir("iso");
        let m1 = CheckpointManager::new("a", tier.clone(), &dir).unwrap();
        let m2 = CheckpointManager::new("b", tier.clone(), &dir).unwrap();
        m1.save_inmem(&state(1), &["n"]);
        m2.save_inmem(&state(2), &["n"]);
        assert_eq!(m1.restore().unwrap().0.step, 1);
        assert_eq!(m2.restore().unwrap().0.step, 2);
    }
}
