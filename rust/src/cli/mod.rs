//! Minimal command-line parser (no `clap` in the vendored registry).
//!
//! Supports subcommands, `--flag`, `--key value` / `--key=value`, and
//! positional arguments, with typed accessors and generated usage text.

use std::collections::BTreeMap;
use std::fmt::Write as _;

/// Declarative description of one option.
#[derive(Debug, Clone)]
pub struct OptSpec {
    pub name: &'static str,
    pub help: &'static str,
    pub takes_value: bool,
    pub default: Option<&'static str>,
}

/// Parsed arguments for one (sub)command.
#[derive(Debug, Default, Clone)]
pub struct Args {
    pub command: String,
    values: BTreeMap<String, String>,
    flags: Vec<String>,
    pub positional: Vec<String>,
}

#[derive(Debug)]
pub struct CliError(pub String);

impl std::fmt::Display for CliError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "{}", self.0)
    }
}
impl std::error::Error for CliError {}

impl Args {
    /// Parse `argv` (without the program name) against the option specs.
    pub fn parse(argv: &[String], specs: &[OptSpec]) -> Result<Args, CliError> {
        let mut args = Args::default();
        for spec in specs {
            if let (true, Some(d)) = (spec.takes_value, spec.default) {
                args.values.insert(spec.name.to_string(), d.to_string());
            }
        }
        let mut i = 0;
        while i < argv.len() {
            let a = &argv[i];
            if let Some(stripped) = a.strip_prefix("--") {
                let (name, inline) = match stripped.split_once('=') {
                    Some((n, v)) => (n.to_string(), Some(v.to_string())),
                    None => (stripped.to_string(), None),
                };
                let spec = specs
                    .iter()
                    .find(|s| s.name == name)
                    .ok_or_else(|| CliError(format!("unknown option --{name}")))?;
                if spec.takes_value {
                    let v = match inline {
                        Some(v) => v,
                        None => {
                            i += 1;
                            argv.get(i)
                                .cloned()
                                .ok_or_else(|| CliError(format!("--{name} needs a value")))?
                        }
                    };
                    args.values.insert(name, v);
                } else {
                    if inline.is_some() {
                        return Err(CliError(format!("--{name} takes no value")));
                    }
                    args.flags.push(name);
                }
            } else {
                args.positional.push(a.clone());
            }
            i += 1;
        }
        Ok(args)
    }

    pub fn flag(&self, name: &str) -> bool {
        self.flags.iter().any(|f| f == name)
    }

    pub fn get(&self, name: &str) -> Option<&str> {
        self.values.get(name).map(|s| s.as_str())
    }

    pub fn str(&self, name: &str) -> Result<&str, CliError> {
        self.get(name).ok_or_else(|| CliError(format!("missing --{name}")))
    }

    pub fn u64(&self, name: &str) -> Result<u64, CliError> {
        self.str(name)?.parse().map_err(|_| CliError(format!("--{name}: expected integer")))
    }

    pub fn usize(&self, name: &str) -> Result<usize, CliError> {
        Ok(self.u64(name)? as usize)
    }

    pub fn f64(&self, name: &str) -> Result<f64, CliError> {
        self.str(name)?.parse().map_err(|_| CliError(format!("--{name}: expected number")))
    }
}

/// Render a usage block for `specs`.
pub fn usage(program: &str, about: &str, specs: &[OptSpec]) -> String {
    let mut out = String::new();
    let _ = writeln!(out, "{about}\n\nUSAGE: {program} [OPTIONS]\n\nOPTIONS:");
    for s in specs {
        let arg = if s.takes_value { format!("--{} <v>", s.name) } else { format!("--{}", s.name) };
        let dflt = s.default.map(|d| format!(" [default: {d}]")).unwrap_or_default();
        let _ = writeln!(out, "  {arg:<24} {}{dflt}", s.help);
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    fn specs() -> Vec<OptSpec> {
        vec![
            OptSpec { name: "model", help: "model name", takes_value: true, default: Some("tiny") },
            OptSpec { name: "steps", help: "step count", takes_value: true, default: None },
            OptSpec { name: "verbose", help: "chatty", takes_value: false, default: None },
        ]
    }

    fn sv(xs: &[&str]) -> Vec<String> {
        xs.iter().map(|s| s.to_string()).collect()
    }

    #[test]
    fn parses_values_flags_positional() {
        let a = Args::parse(&sv(&["--model", "mini", "--verbose", "pos1", "--steps=7"]), &specs())
            .unwrap();
        assert_eq!(a.str("model").unwrap(), "mini");
        assert_eq!(a.u64("steps").unwrap(), 7);
        assert!(a.flag("verbose"));
        assert_eq!(a.positional, vec!["pos1"]);
    }

    #[test]
    fn defaults_apply() {
        let a = Args::parse(&[], &specs()).unwrap();
        assert_eq!(a.str("model").unwrap(), "tiny");
        assert!(a.get("steps").is_none());
        assert!(!a.flag("verbose"));
    }

    #[test]
    fn errors() {
        assert!(Args::parse(&sv(&["--nope"]), &specs()).is_err());
        assert!(Args::parse(&sv(&["--steps"]), &specs()).is_err());
        assert!(Args::parse(&sv(&["--verbose=1"]), &specs()).is_err());
        let a = Args::parse(&sv(&["--steps", "abc"]), &specs()).unwrap();
        assert!(a.u64("steps").is_err());
    }

    #[test]
    fn usage_mentions_every_option() {
        let u = usage("unicron train", "Train.", &specs());
        for s in specs() {
            assert!(u.contains(s.name));
        }
    }
}
