//! Typed configuration: model zoo, cluster hardware, tasks, Unicron knobs.
//!
//! Mirrors the paper's §7.1 experimental setup: GPT-3-family models
//! (1.3B…175B), A800 nodes (8 GPUs, NVSwitch intra-node, 4×200 Gbps
//! inter-node), 20 GB/s remote checkpoint storage — plus the Table 3
//! multi-task cases used by Figs. 10c and 11. Everything round-trips
//! through [`crate::ser::Value`] so configs can be given as JSON files.

use crate::proto::TaskId;
use crate::ser::{JsonError, Value};

/// Transformer shape for the analytical performance model (perfmodel).
#[derive(Debug, Clone, PartialEq)]
pub struct ModelSpec {
    pub name: String,
    /// Total parameter count.
    pub n_params: f64,
    pub n_layers: u32,
    pub hidden: u32,
    pub heads: u32,
    pub seq_len: u32,
    /// Global batch size in sequences (Megatron-style).
    pub global_batch: u32,
    pub vocab: u32,
}

impl ModelSpec {
    /// GPT-3 family, shapes from the GPT-3 paper table 2.1 (vocab 51200 as
    /// in Megatron's GPT-3 configs; 2048 sequence length).
    pub fn gpt3(name: &str) -> Option<ModelSpec> {
        let (n_layers, hidden, heads, global_batch) = match name {
            "gpt3-1.3b" => (24, 2048, 16, 512),
            "gpt3-7b" => (32, 4096, 32, 1024),
            "gpt3-13b" => (40, 5120, 40, 1024),
            "gpt3-70b" => (80, 8192, 64, 1536),
            "gpt3-175b" => (96, 12288, 96, 1536),
            _ => return None,
        };
        let mut spec = ModelSpec {
            name: name.to_string(),
            n_params: 0.0,
            n_layers,
            hidden,
            heads,
            seq_len: 2048,
            global_batch,
            vocab: 51200,
        };
        spec.n_params = spec.count_params();
        Some(spec)
    }

    /// All zoo names in ascending size.
    pub fn zoo() -> Vec<&'static str> {
        vec!["gpt3-1.3b", "gpt3-7b", "gpt3-13b", "gpt3-70b", "gpt3-175b"]
    }

    /// Parameter count from shape: 12·l·h²·(1 + 13/(12h)) + (v+s)·h.
    pub fn count_params(&self) -> f64 {
        let (l, h) = (self.n_layers as f64, self.hidden as f64);
        let (v, s) = (self.vocab as f64, self.seq_len as f64);
        12.0 * l * h * h * (1.0 + 13.0 / (12.0 * h)) + (v + s) * h
    }

    /// Training FLOPs per token (Megatron paper formula, fwd+bwd with
    /// activation recomputation disabled):
    /// `96·l·h²·(1 + s/(6h) + V/(16·l·h)) · B·s` per iteration → per token.
    pub fn flops_per_token(&self) -> f64 {
        let (l, h) = (self.n_layers as f64, self.hidden as f64);
        let (v, s) = (self.vocab as f64, self.seq_len as f64);
        72.0 * l * h * h * (1.0 + s / (6.0 * h) + v / (12.0 * l * h))
    }

    pub fn tokens_per_iteration(&self) -> f64 {
        self.global_batch as f64 * self.seq_len as f64
    }

    pub fn to_json(&self) -> Value {
        Value::obj()
            .with("name", self.name.as_str())
            .with("n_params", self.n_params)
            .with("n_layers", self.n_layers as u64)
            .with("hidden", self.hidden as u64)
            .with("heads", self.heads as u64)
            .with("seq_len", self.seq_len as u64)
            .with("global_batch", self.global_batch as u64)
            .with("vocab", self.vocab as u64)
    }

    pub fn from_json(v: &Value) -> Result<ModelSpec, JsonError> {
        Ok(ModelSpec {
            name: v.req("name")?.as_str().unwrap_or_default().to_string(),
            n_params: v.req("n_params")?.as_f64().unwrap_or(0.0),
            n_layers: v.req("n_layers")?.as_u64().unwrap_or(0) as u32,
            hidden: v.req("hidden")?.as_u64().unwrap_or(0) as u32,
            heads: v.req("heads")?.as_u64().unwrap_or(0) as u32,
            seq_len: v.req("seq_len")?.as_u64().unwrap_or(0) as u32,
            global_batch: v.req("global_batch")?.as_u64().unwrap_or(0) as u32,
            vocab: v.req("vocab")?.as_u64().unwrap_or(0) as u32,
        })
    }
}

/// Hardware description of the training cluster (defaults = paper §7.1).
#[derive(Debug, Clone, PartialEq)]
pub struct ClusterSpec {
    pub n_nodes: u32,
    pub gpus_per_node: u32,
    /// Peak dense bf16 TFLOP/s per GPU (A800 ≈ A100: 312).
    pub gpu_peak_tflops: f64,
    /// HBM per GPU in GiB.
    pub hbm_gib: f64,
    /// Intra-node (NVSwitch) bandwidth per GPU, GB/s (A800: 400).
    pub intra_bw_gbs: f64,
    /// Inter-node NIC bandwidth per node, GB/s (4×200 Gbps = 100 GB/s).
    pub inter_bw_gbs: f64,
    /// Node-local disk (NVMe) bandwidth, GB/s — the snapshot store's
    /// middle tier between peer memory and remote storage.
    pub local_disk_bw_gbs: f64,
    /// Remote persistent checkpoint storage bandwidth, GB/s (paper: 20).
    pub remote_ckpt_bw_gbs: f64,
}

impl Default for ClusterSpec {
    fn default() -> Self {
        ClusterSpec {
            n_nodes: 16,
            gpus_per_node: 8,
            gpu_peak_tflops: 312.0,
            hbm_gib: 80.0,
            intra_bw_gbs: 400.0,
            inter_bw_gbs: 100.0,
            local_disk_bw_gbs: 8.0,
            remote_ckpt_bw_gbs: 20.0,
        }
    }
}

impl ClusterSpec {
    pub fn with_nodes(n_nodes: u32) -> ClusterSpec {
        ClusterSpec { n_nodes, ..Default::default() }
    }

    pub fn total_gpus(&self) -> u32 {
        self.n_nodes * self.gpus_per_node
    }

    /// Aggregate peak FLOP/s of `x` healthy GPUs.
    pub fn peak_flops(&self, x: u32) -> f64 {
        x as f64 * self.gpu_peak_tflops * 1e12
    }

    pub fn to_json(&self) -> Value {
        Value::obj()
            .with("n_nodes", self.n_nodes as u64)
            .with("gpus_per_node", self.gpus_per_node as u64)
            .with("gpu_peak_tflops", self.gpu_peak_tflops)
            .with("hbm_gib", self.hbm_gib)
            .with("intra_bw_gbs", self.intra_bw_gbs)
            .with("inter_bw_gbs", self.inter_bw_gbs)
            .with("local_disk_bw_gbs", self.local_disk_bw_gbs)
            .with("remote_ckpt_bw_gbs", self.remote_ckpt_bw_gbs)
    }

    pub fn from_json(v: &Value) -> Result<ClusterSpec, JsonError> {
        let d = ClusterSpec::default();
        let f = |k: &str, dflt: f64| v.get(k).and_then(Value::as_f64).unwrap_or(dflt);
        Ok(ClusterSpec {
            n_nodes: f("n_nodes", d.n_nodes as f64) as u32,
            gpus_per_node: f("gpus_per_node", d.gpus_per_node as f64) as u32,
            gpu_peak_tflops: f("gpu_peak_tflops", d.gpu_peak_tflops),
            hbm_gib: f("hbm_gib", d.hbm_gib),
            intra_bw_gbs: f("intra_bw_gbs", d.intra_bw_gbs),
            inter_bw_gbs: f("inter_bw_gbs", d.inter_bw_gbs),
            local_disk_bw_gbs: f("local_disk_bw_gbs", d.local_disk_bw_gbs),
            remote_ckpt_bw_gbs: f("remote_ckpt_bw_gbs", d.remote_ckpt_bw_gbs),
        })
    }
}

/// One training task in the multi-task cluster (§5.1).
#[derive(Debug, Clone, PartialEq)]
pub struct TaskSpec {
    pub id: TaskId,
    pub model: String,
    /// Priority weight w(t) ∈ [0.5, 2.0] by recommendation.
    pub weight: f64,
    /// Minimum workers (T_necessary): below this, F(t,x) = 0.
    pub min_workers: u32,
    /// Worker ceiling: the planner never assigns more than this many
    /// workers to the task (scaling saturates — batch-size and
    /// parallelism limits cap useful world size long before fleet size
    /// does). `u32::MAX` (the default) means uncapped; ceilings also
    /// bound the planner DP's row widths at `Σ max_workers`, which is
    /// what keeps replanning affordable on 16k–64k-node fleets.
    pub max_workers: u32,
}

impl TaskSpec {
    pub fn new(id: impl Into<TaskId>, model: &str, weight: f64, min_workers: u32) -> TaskSpec {
        TaskSpec {
            id: id.into(),
            model: model.to_string(),
            weight,
            min_workers,
            max_workers: u32::MAX,
        }
    }

    /// Builder: set the worker ceiling.
    pub fn with_max_workers(mut self, max_workers: u32) -> TaskSpec {
        self.max_workers = max_workers;
        self
    }

    pub fn to_json(&self) -> Value {
        let v = Value::obj()
            .with("id", self.id.0 as u64)
            .with("model", self.model.as_str())
            .with("weight", self.weight)
            .with("min_workers", self.min_workers as u64);
        // omit the vacuous default so pre-ceiling encodings stay stable
        if self.max_workers == u32::MAX {
            v
        } else {
            v.with("max_workers", self.max_workers as u64)
        }
    }

    pub fn from_json(v: &Value) -> Result<TaskSpec, JsonError> {
        Ok(TaskSpec {
            id: TaskId(v.req("id")?.as_u64().unwrap_or(0) as u32),
            model: v.req("model")?.as_str().unwrap_or_default().to_string(),
            weight: v.req("weight")?.as_f64().unwrap_or(1.0),
            min_workers: v.req("min_workers")?.as_u64().unwrap_or(1) as u32,
            max_workers: v
                .get("max_workers")
                .and_then(Value::as_u64)
                .map_or(u32::MAX, |x| x as u32),
        })
    }
}

/// The five multi-task cases of Table 3 (model sizes S. and weights W.).
/// Minimum workers are set to the smallest GPU count the perfmodel can fit
/// the model on (8 for 1.3B/7B, 16 for 13B) — the paper leaves these implicit.
pub fn table3_case(case: u32) -> Vec<TaskSpec> {
    let mk = |specs: &[(&str, f64)]| -> Vec<TaskSpec> {
        specs
            .iter()
            .enumerate()
            .map(|(i, (m, w))| {
                let min = match *m {
                    "gpt3-13b" => 16,
                    _ => 8,
                };
                TaskSpec::new(i as u32, m, *w, min)
            })
            .collect()
    };
    match case {
        1 => mk(&[("gpt3-7b", 1.0); 6]),
        2 => mk(&[
            ("gpt3-1.3b", 1.0),
            ("gpt3-1.3b", 1.0),
            ("gpt3-1.3b", 1.0),
            ("gpt3-7b", 1.0),
            ("gpt3-7b", 1.0),
            ("gpt3-13b", 1.0),
        ]),
        3 => mk(&[
            ("gpt3-7b", 0.5),
            ("gpt3-7b", 0.8),
            ("gpt3-7b", 1.1),
            ("gpt3-7b", 1.4),
            ("gpt3-7b", 1.7),
            ("gpt3-7b", 2.0),
        ]),
        4 => mk(&[
            ("gpt3-1.3b", 0.5),
            ("gpt3-1.3b", 0.8),
            ("gpt3-1.3b", 1.1),
            ("gpt3-7b", 1.4),
            ("gpt3-7b", 1.7),
            ("gpt3-13b", 2.0),
        ]),
        5 => mk(&[
            ("gpt3-1.3b", 2.0),
            ("gpt3-1.3b", 1.7),
            ("gpt3-1.3b", 1.4),
            ("gpt3-7b", 1.1),
            ("gpt3-7b", 0.8),
            ("gpt3-13b", 0.5),
        ]),
        _ => panic!("table 3 defines cases 1..=5, got {case}"),
    }
}

/// Unicron runtime knobs (detection thresholds from §4.1, GEMINI-style
/// checkpointing cadence, planner horizon).
#[derive(Debug, Clone, PartialEq)]
pub struct UnicronConfig {
    /// Agent→coordinator heartbeat period (seconds).
    pub heartbeat_period_s: f64,
    /// Lease TTL after which a silent node is SEV1 (seconds).
    pub lease_ttl_s: f64,
    /// Online statistical monitor: warn threshold × average iter time.
    pub stat_warn_factor: f64,
    /// Online statistical monitor: failure threshold × average iter time.
    pub stat_fail_factor: f64,
    /// Persistent checkpoint interval (seconds). Paper: 30 min.
    pub ckpt_interval_s: f64,
    /// Fixed orchestration overhead of one transition (detach, rendezvous,
    /// process warm-up), seconds. The state-movement part of a transition is
    /// priced per task and per §6.3 strategy by the cost ledger
    /// ([`crate::cost::TransitionProfile`]); this is only the flat part.
    pub transition_base_s: f64,
    /// Prior mean time between failures per GPU (seconds) — the cost
    /// ledger's starting point for the opportunity horizon `D_running(n)`;
    /// tightened by the fleet's EWMA estimate as failures are observed.
    pub mtbf_per_gpu_s: f64,
    /// In-place reattempt budget before escalating SEV3→SEV2.
    pub max_reattempts: u32,
    /// Process-restart budget before escalating SEV2→SEV1.
    pub max_restarts: u32,
    /// Background cadence (seconds) at which the live driver refreshes the
    /// §5.2 precomputed plan table when it has gone stale.
    pub plan_refresh_period_s: f64,
    /// Nodes per failure domain (rack/leaf switch) for correlated-failure
    /// bookkeeping: `domain = node / nodes_per_domain` (fleet layer).
    pub nodes_per_domain: u32,
    /// Per-event decay γ of the lemon recurrence score
    /// (`score ← score·γ^Δevents + w` on each failure; see `fleet`).
    pub lemon_decay: f64,
    /// Quarantine a node once its decayed recurrence score reaches this.
    /// Calibrated so one full §4.2 escalation chain stays well below it —
    /// only *recurrence* (many failures in a short event window) crosses.
    pub lemon_threshold: f64,
    /// Fence lemon nodes before they fail again and refuse to re-admit them
    /// after repair (the `fleet-lemon` experiment compares on/off).
    pub lemon_quarantine: bool,
    /// Holding cost of one hot spare as a fraction of the WAF a node earns —
    /// the spare pool's retain/release break-even probability.
    pub spare_hold_frac: f64,
    /// Provisioning/repair window (seconds) the spare pool insures against.
    pub spare_window_s: f64,
    /// Never hold more hot spares than this.
    pub max_spares: u32,
    /// Batch window for correlated same-domain SEV1s: a burst member's
    /// replan is deferred up to this many seconds so one consolidated plan
    /// replaces N sequential commits. `0.0` disables batching.
    pub domain_batch_window_s: f64,
    /// Domain failure pressure (see [`crate::fleet::FleetModel`]) above
    /// which same-domain SEV1s are treated as one correlated burst. Two
    /// SEV1s in quick succession (~3.0 raw weight) cross the default; a
    /// single failure (1.5) never does.
    pub domain_batch_pressure: f64,
    /// Layout strategy: `true` commits layouts from the min-churn,
    /// domain-compact [`crate::placement::assign`] solver; `false` selects
    /// the topology-blind contiguous reference
    /// ([`crate::placement::assign_blind`]) — the `placement-frag`
    /// experiment's baseline arm.
    pub placement_min_churn: bool,
    /// Execute checkpoint writes/evictions/restores against the snapshot
    /// store ([`crate::store::SnapshotStore`]) so SEV1 failover timing
    /// reflects *actual* tier residency (warm peer replica → sub-second)
    /// instead of the closed-form §6.3 transition formula. Off by default:
    /// the formula path is the long-standing calibrated baseline and the
    /// `warm-peer` experiment compares the two arms.
    pub store_aware_recovery: bool,
    /// Fraction of a task's state assumed dirty between two consecutive
    /// checkpoint ticks (simulated delta snapshots; FFTrainer-style
    /// slowly-changing optimizer state ≈ 1 %).
    pub store_delta_fraction: f64,
    /// In-band degradation detection (DESIGN.md §16): feed per-step timing
    /// reports through the [`crate::health::HealthMonitor`] and let the
    /// coordinator evict sustained stragglers when the ledger says eviction
    /// beats tolerating the drag. Off = observations are ignored (the
    /// degradation-oblivious arm of the `straggler-evict` experiment).
    pub degradation_detection: bool,
    /// Cadence (seconds) at which agents report per-step timings — the
    /// simulator emits `StepTiming` events on this period while a
    /// degradation scenario is active.
    pub step_report_period_s: f64,
    /// Slow fraction (1 − baseline/duration) above which a sustained
    /// excursion is gray degradation (partial bandwidth).
    pub degradation_warn_frac: f64,
    /// Slow fraction above which a sustained excursion is a straggler.
    pub degradation_fail_frac: f64,
    /// Consecutive out-of-band samples before a verdict (also the per-node
    /// warm-up length of the health baseline).
    pub degradation_min_samples: u32,
}

impl Default for UnicronConfig {
    fn default() -> Self {
        UnicronConfig {
            heartbeat_period_s: 1.0,
            lease_ttl_s: 5.0,
            stat_warn_factor: 1.1,
            stat_fail_factor: 3.0,
            ckpt_interval_s: 30.0 * 60.0,
            transition_base_s: 55.0,
            // 128 GPUs fail 1–7×/week => per-GPU MTBF ≈ 128 weeks / 4 ≈ 1.9e7 s
            mtbf_per_gpu_s: 1.9e7,
            max_reattempts: 3,
            max_restarts: 1,
            plan_refresh_period_s: 0.5,
            nodes_per_domain: 4,
            lemon_decay: 0.95,
            lemon_threshold: 8.0,
            lemon_quarantine: true,
            spare_hold_frac: 0.25,
            spare_window_s: 2.0 * 86400.0,
            max_spares: 2,
            domain_batch_window_s: 900.0,
            domain_batch_pressure: 2.5,
            placement_min_churn: true,
            store_aware_recovery: false,
            store_delta_fraction: 0.01,
            degradation_detection: true,
            step_report_period_s: 60.0,
            degradation_warn_frac: 0.05,
            degradation_fail_frac: 0.20,
            degradation_min_samples: 6,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn gpt3_param_counts_are_close_to_nominal() {
        // name encodes the nominal size; computed count within 20%.
        for (name, nominal) in [
            ("gpt3-1.3b", 1.3e9),
            ("gpt3-7b", 7e9),
            ("gpt3-13b", 13e9),
            ("gpt3-70b", 70e9),
            ("gpt3-175b", 175e9),
        ] {
            let m = ModelSpec::gpt3(name).unwrap();
            let ratio = m.n_params / nominal;
            assert!((0.8..1.25).contains(&ratio), "{name}: {:.2e} vs {nominal:.2e}", m.n_params);
        }
        assert!(ModelSpec::gpt3("gpt3-9000b").is_none());
    }

    #[test]
    fn flops_per_token_roughly_6n() {
        for name in ModelSpec::zoo() {
            let m = ModelSpec::gpt3(name).unwrap();
            let r = m.flops_per_token() / (6.0 * m.n_params);
            assert!((0.8..1.6).contains(&r), "{name} ratio {r}");
        }
    }

    #[test]
    fn cluster_defaults_match_paper() {
        let c = ClusterSpec::default();
        assert_eq!(c.total_gpus(), 128);
        assert_eq!(c.gpus_per_node, 8);
        assert_eq!(c.remote_ckpt_bw_gbs, 20.0);
        assert!((c.peak_flops(64) - 64.0 * 312e12).abs() < 1.0);
    }

    #[test]
    fn model_spec_json_roundtrip() {
        let m = ModelSpec::gpt3("gpt3-7b").unwrap();
        let j = m.to_json().encode();
        let back = ModelSpec::from_json(&Value::parse(&j).unwrap()).unwrap();
        assert_eq!(m, back);
    }

    #[test]
    fn cluster_spec_json_roundtrip() {
        let c = ClusterSpec::with_nodes(4);
        let back = ClusterSpec::from_json(&Value::parse(&c.to_json().encode()).unwrap()).unwrap();
        assert_eq!(c, back);
    }

    #[test]
    fn task_spec_json_roundtrip() {
        let t = TaskSpec::new(3u32, "gpt3-7b", 1.4, 8);
        let back = TaskSpec::from_json(&Value::parse(&t.to_json().encode()).unwrap()).unwrap();
        assert_eq!(t, back);
        // the vacuous ceiling is omitted on the wire and restored on decode
        assert!(t.to_json().get("max_workers").is_none());
        assert_eq!(back.max_workers, u32::MAX);
        // a real ceiling round-trips
        let capped = TaskSpec::new(4u32, "gpt3-1.3b", 1.0, 8).with_max_workers(256);
        let back =
            TaskSpec::from_json(&Value::parse(&capped.to_json().encode()).unwrap()).unwrap();
        assert_eq!(capped, back);
        assert_eq!(back.max_workers, 256);
    }

    #[test]
    fn table3_matches_paper() {
        for case in 1..=5 {
            let tasks = table3_case(case);
            assert_eq!(tasks.len(), 6, "case {case}");
        }
        // case 1: six 7B tasks, all weight 1.0
        assert!(table3_case(1).iter().all(|t| t.model == "gpt3-7b" && t.weight == 1.0));
        // case 5: descending weights on mixed sizes
        let c5 = table3_case(5);
        assert_eq!(c5[0].weight, 2.0);
        assert_eq!(c5[5].weight, 0.5);
        assert_eq!(c5[5].model, "gpt3-13b");
        // weights in recommended range
        for case in 1..=5 {
            assert!(table3_case(case).iter().all(|t| (0.5..=2.0).contains(&t.weight)));
        }
    }

    #[test]
    #[should_panic(expected = "cases 1..=5")]
    fn table3_rejects_bad_case() {
        table3_case(6);
    }

    #[test]
    fn transition_and_batching_knobs_have_sane_defaults() {
        let u = UnicronConfig::default();
        // the flat overhead is in the same ballpark as the paper's sub-minute
        // transition claim (Fig. 9); the per-task migration term rides on top
        assert!((10.0..120.0).contains(&u.transition_base_s));
        assert!(u.domain_batch_window_s > 0.0, "batching on by default");
        // a single SEV1 (weight 1.5) must never read as a burst; two in
        // quick succession (~2.9 decayed) must
        assert!((1.5..3.0).contains(&u.domain_batch_pressure));
    }

    #[test]
    fn degradation_knobs_have_sane_defaults() {
        let u = UnicronConfig::default();
        assert!(u.degradation_detection, "in-band health observation on by default");
        // warn strictly below fail, both proper fractions — the health
        // monitor's constructor refuses anything else
        assert!(0.0 < u.degradation_warn_frac && u.degradation_warn_frac < u.degradation_fail_frac);
        assert!(u.degradation_fail_frac < 1.0);
        // a verdict needs several sustained samples, but detection latency
        // (min_samples × report period) stays within minutes
        assert!(u.degradation_min_samples >= 3);
        assert!(u.step_report_period_s > 0.0);
        assert!(u.degradation_min_samples as f64 * u.step_report_period_s <= 900.0);
    }
}
