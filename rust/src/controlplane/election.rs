//! Lease-based leader election over the shared [`Store`] (DESIGN.md §15).
//!
//! Protocol (etcd-style lock with a fencing token):
//!
//! 1. [`TERM_KEY`] is a monotonic counter. Before a candidate may claim
//!    leadership it CAS-bumps the counter; the new value is its *term*.
//!    Terms only move forward — even a candidate that loses the key race
//!    below has already fenced every older leader.
//! 2. [`LEADER_KEY`] holds `{term, addr}` and is attached to a TTL lease.
//!    Claiming is a put-if-absent CAS: exactly one candidate per vacancy
//!    wins. The winner heartbeats the lease; when the process dies or
//!    stalls past the TTL, the key expires and the next sweep frees it.
//! 3. Every participant tracks the highest term it has observed. Writes
//!    (replication frames, ingests) stamped with an older term are stale —
//!    they come from a deposed leader — and are refused.
//!
//! The substrate is the repo's own `kvstore`, reached either in-process
//! ([`Store`]) or over the wire ([`KvClient`]) via the [`ElectionKv`] trait,
//! so a single-host test and a multi-host deployment run the same protocol.

use anyhow::{anyhow, Result};

use crate::kvstore::net::KvClient;
use crate::kvstore::Store;
use crate::ser::Value;

/// Holds `{term, addr}` under the winner's lease.
pub const LEADER_KEY: &str = "/election/leader";
/// Monotonic fencing counter; CAS-bumped by every acquisition attempt.
pub const TERM_KEY: &str = "/election/term";

/// What the leader key holds: the fencing term and the service address
/// standbys replicate from.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct LeaderInfo {
    pub term: u64,
    pub addr: String,
}

impl LeaderInfo {
    pub fn to_value(&self) -> Value {
        Value::obj().with("term", self.term).with("addr", self.addr.as_str())
    }

    pub fn from_value(v: &Value) -> Option<LeaderInfo> {
        Some(LeaderInfo {
            term: v.get("term")?.as_u64()?,
            addr: v.get("addr")?.as_str()?.to_string(),
        })
    }
}

/// The five store operations the election needs, over either an in-process
/// [`Store`] handle or a remote [`KvClient`].
pub trait ElectionKv: Send {
    fn get(&mut self, key: &str) -> Result<Option<(String, u64)>>;
    fn cas(
        &mut self,
        key: &str,
        expected: Option<u64>,
        value: &str,
        lease: Option<u64>,
    ) -> Result<Option<u64>>;
    fn grant_lease(&mut self, ttl_s: f64) -> Result<u64>;
    fn keepalive(&mut self, lease: u64) -> Result<()>;
    fn revoke_lease(&mut self, lease: u64) -> Result<()>;
    /// Drive lease expiry. An in-process store is swept by whoever holds
    /// it, so the local impl ticks; a remote store is swept by its serving
    /// process, so the client impl is a no-op.
    fn tick(&mut self) {}
}

impl ElectionKv for Store {
    fn get(&mut self, key: &str) -> Result<Option<(String, u64)>> {
        Ok(Store::get(self, key))
    }

    fn cas(
        &mut self,
        key: &str,
        expected: Option<u64>,
        value: &str,
        lease: Option<u64>,
    ) -> Result<Option<u64>> {
        Store::cas(self, key, expected, value, lease).map_err(|e| anyhow!(e))
    }

    fn grant_lease(&mut self, ttl_s: f64) -> Result<u64> {
        Ok(Store::grant_lease(self, ttl_s))
    }

    fn keepalive(&mut self, lease: u64) -> Result<()> {
        Store::keepalive(self, lease).map_err(|e| anyhow!(e))
    }

    fn revoke_lease(&mut self, lease: u64) -> Result<()> {
        Store::revoke_lease(self, lease);
        Ok(())
    }

    fn tick(&mut self) {
        let _ = Store::tick(self);
    }
}

impl ElectionKv for KvClient {
    fn get(&mut self, key: &str) -> Result<Option<(String, u64)>> {
        KvClient::get_rev(self, key)
    }

    fn cas(
        &mut self,
        key: &str,
        expected: Option<u64>,
        value: &str,
        lease: Option<u64>,
    ) -> Result<Option<u64>> {
        KvClient::cas(self, key, expected, value, lease)
    }

    fn grant_lease(&mut self, ttl_s: f64) -> Result<u64> {
        KvClient::lease_grant(self, ttl_s)
    }

    fn keepalive(&mut self, lease: u64) -> Result<()> {
        KvClient::keepalive(self, lease)
    }

    fn revoke_lease(&mut self, lease: u64) -> Result<()> {
        KvClient::lease_revoke(self, lease)
    }
}

/// One participant's view of the election.
pub struct Election {
    kv: Box<dyn ElectionKv>,
    ttl_s: f64,
    lease: Option<u64>,
    observed_term: u64,
}

impl Election {
    pub fn new(kv: Box<dyn ElectionKv>, ttl_s: f64) -> Election {
        Election { kv, ttl_s, lease: None, observed_term: 0 }
    }

    /// Who currently holds the lease, if anyone. Also advances lease
    /// expiry on in-process stores and folds the key's term into this
    /// participant's observed maximum.
    pub fn current_leader(&mut self) -> Result<Option<LeaderInfo>> {
        self.kv.tick();
        let Some((raw, _)) = self.kv.get(LEADER_KEY)? else {
            return Ok(None);
        };
        let v = Value::parse(&raw).map_err(|e| anyhow!("bad leader key: {e}"))?;
        let info = LeaderInfo::from_value(&v).ok_or_else(|| anyhow!("bad leader key: {raw}"))?;
        self.observed_term = self.observed_term.max(info.term);
        Ok(Some(info))
    }

    /// Highest term seen so far (from the key, or from a won election).
    pub fn observed_term(&self) -> u64 {
        self.observed_term
    }

    /// Try to become leader: fence (CAS-bump [`TERM_KEY`]), then claim
    /// [`LEADER_KEY`] under a fresh lease. Returns the won term, or `None`
    /// when another participant holds — or just won — the key.
    pub fn try_acquire(&mut self, addr: &str) -> Result<Option<u64>> {
        if self.current_leader()?.is_some() {
            return Ok(None);
        }
        let (cur, rev) = match self.kv.get(TERM_KEY)? {
            Some((raw, rev)) => {
                (raw.parse::<u64>().map_err(|_| anyhow!("bad term key: {raw}"))?, Some(rev))
            }
            None => (0, None),
        };
        let term = cur.max(self.observed_term) + 1;
        if self.kv.cas(TERM_KEY, rev, &term.to_string(), None)?.is_none() {
            return Ok(None); // a racing candidate fenced first; retry later
        }
        let lease = self.kv.grant_lease(self.ttl_s)?;
        let info = LeaderInfo { term, addr: addr.to_string() };
        match self.kv.cas(LEADER_KEY, None, &info.to_value().encode(), Some(lease))? {
            Some(_) => {
                self.lease = Some(lease);
                self.observed_term = term;
                Ok(Some(term))
            }
            None => {
                // lost the key race; don't leave an orphan lease behind
                self.kv.revoke_lease(lease)?;
                Ok(None)
            }
        }
    }

    /// Leader heartbeat: refresh the lease. An error means leadership is
    /// lost — the lease expired, e.g. the process stalled past the TTL —
    /// and the caller must demote itself immediately.
    pub fn heartbeat(&mut self) -> Result<()> {
        match self.lease {
            Some(l) => self.kv.keepalive(l),
            None => Err(anyhow!("not leader: no lease held")),
        }
    }

    /// Voluntarily give up leadership (clean shutdown): revoke the lease
    /// so the key frees immediately instead of after a TTL.
    pub fn resign(&mut self) -> Result<()> {
        if let Some(l) = self.lease.take() {
            self.kv.revoke_lease(l)?;
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::SimClock;
    use std::sync::Arc;

    fn shared_store() -> (Store, Arc<SimClock>) {
        let clock = SimClock::new();
        (Store::new(clock.clone()), clock)
    }

    #[test]
    fn first_candidate_wins_term_one() {
        let (store, _clock) = shared_store();
        let mut e = Election::new(Box::new(store.clone()), 5.0);
        assert_eq!(e.try_acquire("10.0.0.1:7000").unwrap(), Some(1));
        let leader = e.current_leader().unwrap().unwrap();
        assert_eq!(leader, LeaderInfo { term: 1, addr: "10.0.0.1:7000".into() });
    }

    #[test]
    fn second_candidate_defers_then_succeeds_with_higher_term() {
        let (store, clock) = shared_store();
        let mut a = Election::new(Box::new(store.clone()), 5.0);
        let mut b = Election::new(Box::new(store.clone()), 5.0);
        assert_eq!(a.try_acquire("a:1").unwrap(), Some(1));
        assert_eq!(b.try_acquire("b:1").unwrap(), None);
        // leader dies: no more heartbeats, lease expires, key frees
        clock.advance(6.0);
        assert_eq!(b.try_acquire("b:1").unwrap(), Some(2));
        assert_eq!(b.current_leader().unwrap().unwrap().addr, "b:1");
        // the deposed leader's heartbeat now fails: its lease is gone
        assert!(a.heartbeat().is_err());
    }

    #[test]
    fn resign_frees_the_key_immediately() {
        let (store, _clock) = shared_store();
        let mut a = Election::new(Box::new(store.clone()), 60.0);
        let mut b = Election::new(Box::new(store.clone()), 60.0);
        assert_eq!(a.try_acquire("a:1").unwrap(), Some(1));
        a.resign().unwrap();
        // no TTL wait needed: the revoke deleted the lease-attached key
        assert_eq!(b.try_acquire("b:1").unwrap(), Some(2));
    }

    #[test]
    fn terms_are_monotonic_across_reigns() {
        let (store, _clock) = shared_store();
        let mut e = Election::new(Box::new(store.clone()), 60.0);
        for expect in 1..=3u64 {
            assert_eq!(e.try_acquire("x:1").unwrap(), Some(expect));
            e.resign().unwrap();
        }
    }

    #[test]
    fn leader_info_roundtrip_and_strict_parse() {
        let info = LeaderInfo { term: 7, addr: "h:9".into() };
        assert_eq!(LeaderInfo::from_value(&info.to_value()), Some(info));
        assert_eq!(LeaderInfo::from_value(&Value::obj().with("term", 7u64)), None);
        assert_eq!(LeaderInfo::from_value(&Value::Null), None);
    }
}
