//! High-availability control plane (DESIGN.md §15): the networked
//! coordinator service, decision-log replication, and leader election.
//!
//! The [`crate::coordinator::Coordinator`] is the single brain that
//! minimizes failure cost across the cluster (§4 of the paper) — which
//! also makes it the one unreplicated single point of failure in the live
//! driver. This subsystem closes that gap with classic state-machine
//! replication, exploiting an invariant the repo has maintained since the
//! decision log landed: the coordinator is *deterministic*, so a follower
//! that replays the same committed [`crate::proto::DecisionLog`] prefix
//! holds bit-identical state. No snapshot shipping, no state diffing —
//! the log IS the replication payload.
//!
//! Three layers, one per module:
//!
//! * [`service`] — the RPC surface (`ingest_event`, `get_report`,
//!   `query_plan`, `subscribe_log`) with bounded-queue backpressure and
//!   registry-backed telemetry (`cp.*` instruments).
//! * [`replication`] — sequence-numbered, strictly-decoded commit frames
//!   (wire v7) and the follower's replay-and-verify apply path.
//! * [`election`] — lease-based leader election over the shared
//!   [`crate::kvstore::Store`]: a monotonic fencing term plus a TTL lease
//!   kept alive by heartbeats. A standby that wins the lease finishes
//!   applying its stream and takes over mid-incident; writes stamped with
//!   a deposed leader's term are refused.

pub mod election;
pub mod replication;
pub mod service;

pub use election::{Election, ElectionKv, LeaderInfo, LEADER_KEY, TERM_KEY};
pub use replication::{ack_seq, ack_value, apply_frame, LogFrame, ReplicaError};
pub use service::{
    ControlPlane, ControlPlaneConfig, CpClient, Role, CODE_BACKPRESSURE, CODE_BAD_REQUEST,
    CODE_NOT_LEADER, CODE_STALE_TERM,
};
