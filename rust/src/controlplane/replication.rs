//! Decision-log replication frames (wire v7) and the follower apply path.
//!
//! Every entry the leader commits streams to standbys as a [`LogFrame`]:
//! the leader's fencing term plus the committed [`LogEntry`] (which carries
//! its own dense `seq` since wire v7). Decoding is strict — an unknown
//! frame kind, a missing field, or a malformed entry is an error, never a
//! skip — because a replica that guesses at a commit silently diverges.
//!
//! Applying is *replay*, not state transfer: [`apply_frame`] feeds the
//! entry's event through the follower's own [`Coordinator`] at the recorded
//! clock and insists the actions match what the leader recorded. Because
//! the coordinator is deterministic (the invariant PRs 2–8 maintain), a
//! follower that applies the same prefix holds bit-identical state — so
//! takeover needs no snapshot shipping, only the log.

use std::fmt;

use crate::coordinator::Coordinator;
use crate::proto::{LogEntry, ProtoError};
use crate::ser::Value;

/// One replicated commit: the leader's fencing term plus the entry.
#[derive(Debug, Clone, PartialEq)]
pub struct LogFrame {
    pub term: u64,
    pub entry: LogEntry,
}

impl LogFrame {
    pub fn to_value(&self) -> Value {
        Value::obj()
            .with("frame", "entry")
            .with("term", self.term)
            .with("entry", self.entry.to_value())
    }

    /// Strict decode: unknown kinds and malformed entries are errors.
    pub fn from_value(v: &Value) -> Result<LogFrame, ProtoError> {
        match v.get("frame").and_then(Value::as_str) {
            Some("entry") => {}
            Some(other) => return Err(ProtoError::new(format!("unknown frame kind {other:?}"))),
            None => return Err(ProtoError::new("missing field \"frame\"")),
        }
        let term = v
            .req("term")?
            .as_u64()
            .ok_or_else(|| ProtoError::new("field \"term\" is not an unsigned integer"))?;
        Ok(LogFrame { term, entry: LogEntry::from_value(v.req("entry")?)? })
    }
}

/// The standby's ack for a fully applied commit: `{"ack": seq}`.
pub fn ack_value(seq: u64) -> Value {
    Value::obj().with("ack", seq)
}

/// Parse an ack frame back into the applied sequence number.
pub fn ack_seq(v: &Value) -> Option<u64> {
    v.get("ack").and_then(Value::as_u64)
}

/// Why a replica refused (or failed) to apply a frame.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum ReplicaError {
    /// Frame from a deposed leader: its term is older than the replica's
    /// observed term. Refused outright — the fencing guarantee.
    StaleTerm { frame_term: u64, current_term: u64 },
    /// Sequence gap or reorder: commits must apply densely, in order.
    SeqGap { expected: u64, got: u64 },
    /// The follower's replay decided differently than the leader recorded —
    /// a determinism bug or divergent initial state. Never apply past it.
    Diverged(String),
}

impl fmt::Display for ReplicaError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            ReplicaError::StaleTerm { frame_term, current_term } => {
                write!(f, "stale term {frame_term} (current {current_term}): frame refused")
            }
            ReplicaError::SeqGap { expected, got } => {
                write!(f, "sequence gap: expected seq {expected}, got {got}")
            }
            ReplicaError::Diverged(msg) => write!(f, "replay diverged: {msg}"),
        }
    }
}

impl std::error::Error for ReplicaError {}

/// Apply one replicated commit to a follower coordinator by replaying the
/// event at its recorded clock. The follower's own `handle_at` records the
/// entry into its log (with the same `seq`, by density), so after `Ok` the
/// follower's log prefix — and therefore its state — matches the leader's.
pub fn apply_frame(
    coord: &mut Coordinator,
    current_term: u64,
    frame: &LogFrame,
) -> Result<(), ReplicaError> {
    if frame.term < current_term {
        return Err(ReplicaError::StaleTerm { frame_term: frame.term, current_term });
    }
    let expected = coord.log.next_seq();
    if frame.entry.seq != expected {
        return Err(ReplicaError::SeqGap { expected, got: frame.entry.seq });
    }
    let got = coord.handle_at(frame.entry.event.clone(), frame.entry.at_s);
    if got != frame.entry.actions {
        return Err(ReplicaError::Diverged(format!(
            "seq {}: leader recorded {:?}, replay produced {:?}",
            frame.entry.seq, frame.entry.actions, got
        )));
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::UnicronConfig;
    use crate::cost::TransitionProfile;
    use crate::perfmodel::TaskSpec;
    use crate::planner::PlanTask;
    use crate::proto::{CoordEvent, NodeId, WorkerCount};
    use crate::transition::StateSource;

    fn coord() -> Coordinator {
        let mut c = Coordinator::builder()
            .config(UnicronConfig::default())
            .workers(8)
            .gpus_per_node(8)
            .build();
        c.add_task(PlanTask {
            spec: TaskSpec::new(0u32, "m", 1.0, 1),
            throughput: (0..=8u32).map(|x| 1e12 * x as f64).collect(),
            profile: TransitionProfile::flat(5.0),
            current: WorkerCount(8),
            fault: false,
            fault_source: StateSource::InMemoryCheckpoint,
            fault_restore_s: None,
        });
        c
    }

    #[test]
    fn frame_roundtrip_is_exact() {
        let mut leader = coord();
        leader.handle_at(CoordEvent::NodeLost { node: NodeId(1) }, 10.0);
        let frame = LogFrame { term: 3, entry: leader.log.entries[0].clone() };
        let decoded = LogFrame::from_value(&frame.to_value()).unwrap();
        assert_eq!(decoded, frame);
    }

    #[test]
    fn strict_decode_rejects_bad_frames() {
        let mut leader = coord();
        leader.handle_at(CoordEvent::NodeLost { node: NodeId(1) }, 10.0);
        let good = LogFrame { term: 1, entry: leader.log.entries[0].clone() }.to_value();
        assert!(LogFrame::from_value(&good).is_ok());
        // unknown kind
        let bad = good.clone().with("frame", "snapshot");
        assert!(LogFrame::from_value(&bad).is_err());
        // missing term
        let enc = good.encode().replace("\"term\":1,", "");
        assert!(LogFrame::from_value(&Value::parse(&enc).unwrap()).is_err());
        // tampered entry (seq became a string)
        let enc = good.encode().replace("\"seq\":0", "\"seq\":\"0\"");
        assert!(LogFrame::from_value(&Value::parse(&enc).unwrap()).is_err());
        assert!(LogFrame::from_value(&Value::Null).is_err());
    }

    #[test]
    fn follower_replay_matches_leader_log() {
        let mut leader = coord();
        let mut follower = coord();
        let events = [
            (CoordEvent::NodeLost { node: NodeId(1) }, 10.0),
            (CoordEvent::NodeJoined { node: NodeId(1) }, 40.0),
            (CoordEvent::NodeLost { node: NodeId(2) }, 55.0),
        ];
        for (ev, at) in events {
            leader.handle_at(ev, at);
            let e = leader.log.entries.last().unwrap().clone();
            apply_frame(&mut follower, 1, &LogFrame { term: 1, entry: e }).unwrap();
        }
        assert_eq!(follower.log, leader.log);
        assert_eq!(follower.log.next_seq(), 3);
    }

    #[test]
    fn gap_and_stale_term_are_refused() {
        let mut leader = coord();
        let mut follower = coord();
        leader.handle_at(CoordEvent::NodeLost { node: NodeId(1) }, 10.0);
        leader.handle_at(CoordEvent::NodeLost { node: NodeId(2) }, 20.0);
        let e0 = leader.log.entries[0].clone();
        let e1 = leader.log.entries[1].clone();
        // seq 1 before seq 0: gap
        assert_eq!(
            apply_frame(&mut follower, 1, &LogFrame { term: 1, entry: e1.clone() }),
            Err(ReplicaError::SeqGap { expected: 0, got: 1 })
        );
        // stale term: a term-1 frame against a term-2 replica
        assert_eq!(
            apply_frame(&mut follower, 2, &LogFrame { term: 1, entry: e0.clone() }),
            Err(ReplicaError::StaleTerm { frame_term: 1, current_term: 2 })
        );
        // in order and current-term: applies
        apply_frame(&mut follower, 1, &LogFrame { term: 1, entry: e0 }).unwrap();
        apply_frame(&mut follower, 1, &LogFrame { term: 1, entry: e1 }).unwrap();
        assert_eq!(follower.log, leader.log);
    }
}
