//! The networked coordinator service (DESIGN.md §15): what grows
//! [`crate::coordinator::live::CoordinatorLive`]'s single-process loop into
//! a survivable control plane.
//!
//! One [`ControlPlane`] node serves four RPC methods over the repo's
//! framed-JSON transport:
//!
//! * `ingest_event` — submit a [`CoordEvent`] for the leader to commit.
//!   Decoded strictly, then queued on a *bounded* inbound queue; a full
//!   queue answers a typed `backpressure` reject instead of growing
//!   without limit. Standbys answer `not_leader`; requests stamped with an
//!   older term than the node's answer `stale_term` (fencing).
//! * `get_report` — the four `/fleet/*` report bodies (`health`, `layout`,
//!   `store`, `metrics`), stamped with the same versioned envelope the
//!   live loop publishes to the kvstore.
//! * `query_plan` — role, term, committed sequence, current layout and
//!   placeable pool, available workers.
//! * `subscribe_log` — the connection becomes a push stream of
//!   [`LogFrame`]s from a requested sequence onward; the subscriber acks
//!   applied entries so the leader can measure replication lag.
//!
//! A worker thread drains the inbound queue through the node's own
//! [`Coordinator`]; an election thread runs the lease protocol
//! ([`super::election`]) and, on a standby, follows the current leader's
//! log stream, applying each frame by deterministic replay
//! ([`super::replication`]). A standby that wins the lease has — by
//! construction — finished applying every frame it received before the
//! election ran, so it takes over mid-incident with bit-identical state
//! and continues the log without a seq gap.

use anyhow::{anyhow, Result};
use std::collections::VecDeque;
use std::net::TcpStream;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, Condvar, Mutex};
use std::thread::JoinHandle;
use std::time::Duration;

use super::election::Election;
use super::replication::{self, LogFrame, ReplicaError};
use crate::config::ClusterSpec;
use crate::coordinator::live::{envelope, fleet_health_report, layout_report};
use crate::coordinator::Coordinator;
use crate::proto::{CoordEvent, DecisionLog};
use crate::rpc::{self, err_response, ok_response, Client};
use crate::ser::Value;
use crate::store::SnapshotStore;
use crate::telemetry::{CounterId, GaugeId};
use crate::util::{Clock, Level};

/// Typed reject code: the inbound queue is full; retry with backoff.
pub const CODE_BACKPRESSURE: &str = "backpressure";
/// Typed reject code: the request's term is older than the node's.
pub const CODE_STALE_TERM: &str = "stale_term";
/// Typed reject code: this node is a standby; ingest at the leader.
pub const CODE_NOT_LEADER: &str = "not_leader";
/// Typed reject code: the event (or report name) failed strict decoding.
pub const CODE_BAD_REQUEST: &str = "bad_request";

#[derive(Debug, Clone)]
pub struct ControlPlaneConfig {
    /// Bound on the inbound event queue; a full queue rejects with
    /// [`CODE_BACKPRESSURE`] instead of growing without limit.
    pub queue_capacity: usize,
    /// Leader lease TTL: how long a crashed leader fences the cluster.
    pub lease_ttl_s: f64,
    /// Leader heartbeat / standby election-poll period.
    pub heartbeat_period_s: f64,
}

impl Default for ControlPlaneConfig {
    fn default() -> ControlPlaneConfig {
        ControlPlaneConfig { queue_capacity: 256, lease_ttl_s: 2.0, heartbeat_period_s: 0.5 }
    }
}

/// Which side of the replication stream this node is on.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Role {
    Leader,
    Standby,
}

impl Role {
    pub fn name(self) -> &'static str {
        match self {
            Role::Leader => "leader",
            Role::Standby => "standby",
        }
    }
}

/// Instrument ids, registered once in the coordinator's own registry so
/// they surface in `/fleet/metrics` beside every other counter (standing
/// invariant: no ad-hoc counters).
#[derive(Clone, Copy)]
struct CpMetrics {
    sessions: CounterId,
    events_ingested: CounterId,
    rejects_backpressure: CounterId,
    queue_depth: GaugeId,
    replication_lag: GaugeId,
}

/// Everything guarded by the node mutex: the coordinator (and its log —
/// the replicated state machine), plus the HA identity.
struct Node {
    coord: Coordinator,
    term: u64,
    role: Role,
    metrics: CpMetrics,
    /// State-tier view for the `store` report (agent checkpoint traffic
    /// rides the kvstore plane; a service-only node reports empty tiers).
    state_tier: SnapshotStore,
}

/// Bounded inbound event queue (the per-connection backpressure point).
/// Hand-rolled over `Mutex<VecDeque>` + `Condvar` because the drain side
/// needs a timeout and the push side must *fail fast* when full.
struct Inbound {
    q: Mutex<VecDeque<CoordEvent>>,
    cv: Condvar,
    cap: usize,
}

impl Inbound {
    fn new(cap: usize) -> Inbound {
        Inbound { q: Mutex::new(VecDeque::new()), cv: Condvar::new(), cap: cap.max(1) }
    }

    /// Queue an event; `Err` when full (the caller answers backpressure).
    /// Returns the depth after the push.
    fn try_push(&self, ev: CoordEvent) -> Result<usize, ()> {
        let mut g = self.q.lock().unwrap();
        if g.len() >= self.cap {
            return Err(());
        }
        g.push_back(ev);
        let depth = g.len();
        self.cv.notify_one();
        Ok(depth)
    }

    /// Pop with a bounded wait — unless `paused` at pop time. The pause
    /// check happens under the queue lock, *after* the wait, so a pause
    /// flipped while the worker was parked still holds back the event a
    /// concurrent push just notified about.
    fn pop_timeout(&self, d: Duration, paused: &AtomicBool) -> Option<CoordEvent> {
        let mut g = self.q.lock().unwrap();
        if g.is_empty() {
            let (g2, _) = self.cv.wait_timeout(g, d).unwrap();
            g = g2;
        }
        if paused.load(Ordering::Relaxed) {
            return None;
        }
        g.pop_front()
    }

    fn depth(&self) -> usize {
        self.q.lock().unwrap().len()
    }
}

struct Shared {
    node: Mutex<Node>,
    /// Signaled on every commit and role change so log subscribers wake
    /// without polling the mutex.
    commit_cv: Condvar,
}

/// A running control-plane node (leader or standby).
pub struct ControlPlane {
    /// Bound service address (advertised in the leader key when this node
    /// wins an election).
    pub addr: std::net::SocketAddr,
    shared: Arc<Shared>,
    inbound: Arc<Inbound>,
    paused: Arc<AtomicBool>,
    stop: Arc<AtomicBool>,
    crash: Arc<AtomicBool>,
    server: Option<rpc::Server>,
    threads: Vec<JoinHandle<()>>,
}

impl ControlPlane {
    /// Start a node around a built [`Coordinator`]: RPC service on `addr`,
    /// the queue-drain worker, and the election/replication thread.
    ///
    /// `election` supplies the shared election substrate (an in-process
    /// [`crate::kvstore::Store`] clone, or a [`crate::kvstore::net::KvClient`]
    /// to a remote one). `join` is a bootstrap hint: a leader address to
    /// follow before the leader key has ever been observed.
    pub fn start(
        mut coord: Coordinator,
        clock: Arc<dyn Clock>,
        addr: &str,
        cfg: ControlPlaneConfig,
        election: Election,
        join: Option<String>,
    ) -> Result<ControlPlane> {
        let reg = coord.telemetry_mut().registry_mut();
        let metrics = CpMetrics {
            sessions: reg.counter("cp.sessions"),
            events_ingested: reg.counter("cp.events_ingested"),
            rejects_backpressure: reg.counter("cp.rejects_backpressure"),
            queue_depth: reg.gauge("cp.queue_depth", 1.0),
            replication_lag: reg.gauge("cp.replication_lag_entries", 1.0),
        };
        let shared = Arc::new(Shared {
            node: Mutex::new(Node {
                coord,
                term: 0,
                role: Role::Standby,
                metrics,
                state_tier: SnapshotStore::new(&ClusterSpec::default()),
            }),
            commit_cv: Condvar::new(),
        });
        let inbound = Arc::new(Inbound::new(cfg.queue_capacity));
        let paused = Arc::new(AtomicBool::new(false));
        let stop = Arc::new(AtomicBool::new(false));
        let crash = Arc::new(AtomicBool::new(false));

        let server = {
            let shared = shared.clone();
            let inbound = inbound.clone();
            let clock = clock.clone();
            let stop = stop.clone();
            rpc::Server::serve(addr, move |req, stream| {
                let method = req.get("method").and_then(Value::as_str).unwrap_or("");
                match method {
                    "ingest_event" => Some(handle_ingest(&shared, &inbound, &req)),
                    "get_report" => Some(handle_report(&shared, clock.now(), &req)),
                    "query_plan" => Some(handle_query_plan(&shared)),
                    "subscribe_log" => {
                        run_log_subscription(&shared, &stop, &req, stream);
                        None
                    }
                    other => Some(err_response(&format!("unknown method {other:?}"))),
                }
            })?
        };
        let bound = server.addr;

        let mut threads = Vec::new();
        {
            let shared = shared.clone();
            let inbound = inbound.clone();
            let paused = paused.clone();
            let stop = stop.clone();
            let clock = clock.clone();
            threads.push(
                std::thread::Builder::new()
                    .name("cp-apply".into())
                    .spawn(move || drain_loop(&shared, &inbound, &paused, &stop, &clock))
                    .expect("spawn cp-apply"),
            );
        }
        {
            let shared = shared.clone();
            let stop = stop.clone();
            let crash = crash.clone();
            let my_addr = bound.to_string();
            let heartbeat = Duration::from_secs_f64(cfg.heartbeat_period_s.max(0.01));
            threads.push(
                std::thread::Builder::new()
                    .name("cp-election".into())
                    .spawn(move || {
                        election_loop(&shared, &stop, &crash, election, &my_addr, join, heartbeat)
                    })
                    .expect("spawn cp-election"),
            );
        }

        Ok(ControlPlane {
            addr: bound,
            shared,
            inbound,
            paused,
            stop,
            crash,
            server: Some(server),
            threads,
        })
    }

    pub fn role(&self) -> Role {
        self.shared.node.lock().unwrap().role
    }

    pub fn term(&self) -> u64 {
        self.shared.node.lock().unwrap().term
    }

    /// Committed log length (== the next sequence number).
    pub fn committed(&self) -> u64 {
        self.shared.node.lock().unwrap().coord.log.next_seq()
    }

    /// Snapshot of the node's decision log (the replicated state machine).
    pub fn log_snapshot(&self) -> DecisionLog {
        self.shared.node.lock().unwrap().coord.log.clone()
    }

    /// Read a registered counter by name (testing/observability).
    pub fn counter(&self, name: &str) -> u64 {
        let node = self.shared.node.lock().unwrap();
        node.coord.telemetry().registry().counter_named(name).unwrap_or(0)
    }

    /// Poll until this node reports `role` (testing helper for election
    /// convergence); `false` on timeout.
    pub fn wait_for_role(&self, role: Role, timeout: Duration) -> bool {
        let deadline = std::time::Instant::now() + timeout;
        while std::time::Instant::now() < deadline {
            if self.role() == role {
                return true;
            }
            std::thread::sleep(Duration::from_millis(10));
        }
        self.role() == role
    }

    /// Pause/resume the queue-drain worker. Operational drain hook — and
    /// what the backpressure tests use to fill the bounded queue
    /// deterministically.
    pub fn set_drain_paused(&self, paused: bool) {
        self.paused.store(paused, Ordering::Relaxed);
    }

    /// Graceful shutdown: resign leadership (the key frees immediately) and
    /// stop serving.
    pub fn shutdown(&mut self) {
        self.stop_threads();
    }

    /// Crash-style kill: stop serving *without* resigning, so the leader
    /// key lingers until the lease TTL expires — the failover path a real
    /// process death exercises.
    pub fn kill(&mut self) {
        self.crash.store(true, Ordering::Relaxed);
        self.stop_threads();
    }

    fn stop_threads(&mut self) {
        self.stop.store(true, Ordering::Relaxed);
        self.shared.commit_cv.notify_all();
        self.inbound.cv.notify_one();
        if let Some(mut s) = self.server.take() {
            s.shutdown();
        }
        for t in self.threads.drain(..) {
            let _ = t.join();
        }
    }
}

impl Drop for ControlPlane {
    fn drop(&mut self) {
        self.stop_threads();
    }
}

fn reject(code: &str, msg: &str) -> Value {
    err_response(msg).with("code", code)
}

/// True when a transport error is just an idle read timeout (retry), not a
/// disconnect or frame desync (drop the stream).
fn is_idle_timeout(e: &anyhow::Error) -> bool {
    e.downcast_ref::<std::io::Error>().is_some_and(|io| {
        matches!(io.kind(), std::io::ErrorKind::WouldBlock | std::io::ErrorKind::TimedOut)
    })
}

fn handle_ingest(shared: &Shared, inbound: &Inbound, req: &Value) -> Value {
    // decode strictly first — a malformed event must never occupy queue space
    let event = match req.get("event") {
        Some(v) => match CoordEvent::from_value(v) {
            Ok(e) => e,
            Err(e) => return reject(CODE_BAD_REQUEST, &format!("bad event: {}", e.msg)),
        },
        None => return reject(CODE_BAD_REQUEST, "missing field \"event\""),
    };
    let node = shared.node.lock().unwrap();
    if node.role != Role::Leader {
        return reject(CODE_NOT_LEADER, "this node is a standby; ingest at the leader")
            .with("term", node.term);
    }
    if let Some(term) = req.get("term").and_then(Value::as_u64) {
        if term < node.term {
            let msg = format!("stale term {term} (current {})", node.term);
            return reject(CODE_STALE_TERM, &msg).with("term", node.term);
        }
    }
    match inbound.try_push(event) {
        Ok(depth) => {
            let t = node.coord.telemetry();
            t.observe_gauge(node.metrics.queue_depth, depth as f64);
            ok_response().with("queued", true).with("depth", depth).with("term", node.term)
        }
        Err(()) => {
            node.coord.telemetry().inc(node.metrics.rejects_backpressure, 1);
            reject(CODE_BACKPRESSURE, "inbound queue full; retry with backoff")
        }
    }
}

fn handle_report(shared: &Shared, at_s: f64, req: &Value) -> Value {
    let which = req.get("report").and_then(Value::as_str).unwrap_or("");
    let node = shared.node.lock().unwrap();
    let body = match which {
        "health" => fleet_health_report(&node.coord),
        "layout" => layout_report(&node.coord),
        "store" => node.state_tier.report(),
        "metrics" => node.coord.telemetry().metrics_value(),
        other => return reject(CODE_BAD_REQUEST, &format!("unknown report {other:?}")),
    };
    ok_response().with("report", envelope(body, at_s))
}

fn handle_query_plan(shared: &Shared) -> Value {
    let node = shared.node.lock().unwrap();
    ok_response()
        .with("role", node.role.name())
        .with("term", node.term)
        .with("committed", node.coord.log.next_seq())
        .with("available_workers", node.coord.available_workers().0)
        .with("layout", layout_report(&node.coord))
}

/// The `subscribe_log` connection: push committed [`LogFrame`]s from
/// `from_seq` onward, reading acks back to measure replication lag.
fn run_log_subscription(shared: &Shared, stop: &AtomicBool, req: &Value, stream: &mut TcpStream) {
    let mut next = req.get("from_seq").and_then(Value::as_u64).unwrap_or(0);
    {
        let node = shared.node.lock().unwrap();
        node.coord.telemetry().inc(node.metrics.sessions, 1);
        let committed = node.coord.log.next_seq();
        let ack = ok_response().with("term", node.term).with("committed", committed);
        if rpc::send_msg(stream, &ack).is_err() {
            return;
        }
    }
    // short poll for acks so a silent subscriber never blocks the stream
    stream.set_read_timeout(Some(Duration::from_millis(10))).ok();
    let mut acked = next;
    while !stop.load(Ordering::Relaxed) {
        let frames: Vec<Value> = {
            let mut node = shared.node.lock().unwrap();
            if node.coord.log.next_seq() <= next {
                let wait = Duration::from_millis(200);
                let (g, _) = shared.commit_cv.wait_timeout(node, wait).unwrap();
                node = g;
            }
            let term = node.term;
            let start = (next as usize).min(node.coord.log.entries.len());
            node.coord.log.entries[start..]
                .iter()
                .map(|e| LogFrame { term, entry: e.clone() }.to_value())
                .collect()
        };
        for f in &frames {
            if rpc::send_msg(stream, f).is_err() {
                return; // subscriber went away
            }
            next += 1;
        }
        loop {
            match rpc::recv_msg(stream) {
                Ok(v) => {
                    if let Some(seq) = replication::ack_seq(&v) {
                        acked = acked.max(seq + 1);
                    }
                }
                Err(e) => {
                    if is_idle_timeout(&e) {
                        break;
                    }
                    return; // disconnect or frame desync
                }
            }
        }
        let node = shared.node.lock().unwrap();
        let lag = node.coord.log.next_seq().saturating_sub(acked);
        node.coord.telemetry().observe_gauge(node.metrics.replication_lag, lag as f64);
    }
}

/// The queue-drain worker: pops ingested events and commits them through
/// the coordinator (leader only — a demoted node discards queued events;
/// they were never acknowledged as committed).
fn drain_loop(
    shared: &Shared,
    inbound: &Inbound,
    paused: &AtomicBool,
    stop: &AtomicBool,
    clock: &Arc<dyn Clock>,
) {
    while !stop.load(Ordering::Relaxed) {
        if paused.load(Ordering::Relaxed) {
            std::thread::sleep(Duration::from_millis(5));
            continue;
        }
        let Some(ev) = inbound.pop_timeout(Duration::from_millis(50), paused) else {
            continue;
        };
        let mut node = shared.node.lock().unwrap();
        if node.role != Role::Leader {
            continue;
        }
        let now = clock.now();
        let _actions = node.coord.handle_at(ev, now);
        let t = node.coord.telemetry();
        t.inc(node.metrics.events_ingested, 1);
        t.observe_gauge(node.metrics.queue_depth, inbound.depth() as f64);
        drop(node);
        shared.commit_cv.notify_all();
    }
}

/// The election/replication thread: leaders heartbeat their lease;
/// standbys follow the current leader's log stream and, when the lease
/// frees, run for election themselves.
fn election_loop(
    shared: &Shared,
    stop: &AtomicBool,
    crash: &AtomicBool,
    mut election: Election,
    my_addr: &str,
    join: Option<String>,
    heartbeat: Duration,
) {
    let mut last_leader_addr = join;
    while !stop.load(Ordering::Relaxed) {
        let role = shared.node.lock().unwrap().role;
        match role {
            Role::Leader => {
                if let Err(e) = election.heartbeat() {
                    let mut node = shared.node.lock().unwrap();
                    node.role = Role::Standby;
                    let msg = format!("leader lease lost: {e}; demoting to standby");
                    node.coord.telemetry().log(Level::Error, "cp.election", &msg);
                    drop(node);
                    shared.commit_cv.notify_all();
                }
                std::thread::sleep(heartbeat);
            }
            Role::Standby => match election.current_leader() {
                Ok(Some(info)) if info.addr != my_addr => {
                    {
                        let mut node = shared.node.lock().unwrap();
                        node.term = node.term.max(info.term);
                    }
                    last_leader_addr = Some(info.addr.clone());
                    follow_leader(shared, stop, &info.addr);
                    // session over: leader died or stream desynced; the
                    // loop re-reads the election state
                }
                Ok(Some(_)) => {
                    // the key still names *us* from a previous reign —
                    // wait for the lease sweep to free it
                    std::thread::sleep(heartbeat);
                }
                Ok(None) => match election.try_acquire(my_addr) {
                    Ok(Some(term)) => {
                        let mut node = shared.node.lock().unwrap();
                        node.role = Role::Leader;
                        node.term = term;
                        let committed = node.coord.log.next_seq();
                        let msg = format!("won term {term} with {committed} entries replayed");
                        node.coord.telemetry().log(Level::Info, "cp.election", &msg);
                        drop(node);
                        shared.commit_cv.notify_all();
                    }
                    Ok(None) => std::thread::sleep(heartbeat),
                    Err(_) => {
                        // election store unreachable: keep following the
                        // last known leader rather than flapping
                        if let Some(a) = last_leader_addr.clone() {
                            follow_leader(shared, stop, &a);
                        }
                        std::thread::sleep(heartbeat);
                    }
                },
                Err(_) => std::thread::sleep(heartbeat),
            },
        }
    }
    if !crash.load(Ordering::Relaxed) {
        let _ = election.resign();
    }
}

/// One standby replication session: subscribe from our own committed
/// sequence and apply every received frame by deterministic replay,
/// acking as we go. Returns when the stream ends (leader death), a frame
/// fails strict decoding, or the node stops being a standby.
fn follow_leader(shared: &Shared, stop: &AtomicBool, addr: &str) {
    let mut client = match Client::connect(addr) {
        Ok(c) => c,
        Err(_) => {
            // leader key present but service gone: lease not yet expired
            std::thread::sleep(Duration::from_millis(50));
            return;
        }
    };
    let from = shared.node.lock().unwrap().coord.log.next_seq();
    let sub = rpc::request("subscribe_log").with("from_seq", from);
    let ack = match client.call(&sub) {
        Ok(v) if rpc::is_ok(&v) => v,
        _ => return,
    };
    if let Some(t) = ack.get("term").and_then(Value::as_u64) {
        let mut node = shared.node.lock().unwrap();
        node.term = node.term.max(t);
    }
    client.set_read_timeout(Some(Duration::from_millis(100))).ok();
    while !stop.load(Ordering::Relaxed) {
        let v = match client.next_push() {
            Ok(v) => v,
            Err(e) => {
                if is_idle_timeout(&e) {
                    continue;
                }
                return; // leader gone
            }
        };
        let Ok(frame) = LogFrame::from_value(&v) else {
            return; // desync: strict decode failed; resubscribe fresh
        };
        let mut node = shared.node.lock().unwrap();
        if node.role != Role::Standby {
            return;
        }
        let current = node.term;
        match replication::apply_frame(&mut node.coord, current, &frame) {
            Ok(()) => {
                node.term = node.term.max(frame.term);
                let seq = frame.entry.seq;
                drop(node);
                if client.send(&replication::ack_value(seq)).is_err() {
                    return;
                }
            }
            Err(ReplicaError::StaleTerm { .. }) => return, // deposed leader: refuse + drop
            Err(e) => {
                let msg = format!("replication apply failed: {e}");
                node.coord.telemetry().log(Level::Error, "cp.replication", &msg);
                return; // resubscribe resyncs from our committed seq
            }
        }
    }
}

/// Typed client for the control-plane RPC methods.
pub struct CpClient {
    client: Client,
}

impl CpClient {
    pub fn connect(addr: impl std::net::ToSocketAddrs) -> Result<CpClient> {
        Ok(CpClient { client: Client::connect(addr)? })
    }

    /// Submit an event; `term` (if given) is checked against the leader's
    /// fencing term. Returns the full response frame — check
    /// [`crate::rpc::is_ok`] and the `code` field on rejects.
    pub fn ingest_event(&mut self, event: &CoordEvent, term: Option<u64>) -> Result<Value> {
        let mut req = rpc::request("ingest_event").with("event", event.to_value());
        if let Some(t) = term {
            req.set("term", t);
        }
        self.client.call(&req)
    }

    /// Fetch one of the four `/fleet/*` report bodies (`health`, `layout`,
    /// `store`, `metrics`), wrapped in the standard versioned envelope.
    pub fn get_report(&mut self, which: &str) -> Result<Value> {
        let resp = self.client.call(&rpc::request("get_report").with("report", which))?;
        if !rpc::is_ok(&resp) {
            return Err(anyhow!(
                "get_report: {}",
                resp.get("error").and_then(Value::as_str).unwrap_or("unknown")
            ));
        }
        resp.get("report").cloned().ok_or_else(|| anyhow!("get_report: no report in response"))
    }

    /// Role, term, committed sequence, layout, and capacity of the node.
    pub fn query_plan(&mut self) -> Result<Value> {
        let resp = self.client.call(&rpc::request("query_plan"))?;
        if !rpc::is_ok(&resp) {
            return Err(anyhow!("query_plan failed"));
        }
        Ok(resp)
    }
}
