//! Live driver: runs the [`Coordinator`] state machine against real agents
//! over TCP (kvstore wire protocol). This is the deployment shape of Fig. 5:
//! the coordinator embeds the status monitor (kvstore), agents connect over
//! the network, and every detection path of Table 2 flows through here.
//!
//! Timed work (lease-expiry sweeps, §5.2 background plan refresh) runs on
//! the same [`crate::engine::EventQueue`] the simulator advances — here it
//! is drained against wall-clock `now`, there against simulated time, with
//! identical `(time, seq)` ordering. One scheduling substrate, two drivers.
//!
//! The plan refresh is the paper's "proactive plan generation": whenever the
//! precomputed [`crate::planner::ScenarioLookup`] is stale (assignments
//! moved, task set changed, MTBF estimate re-priced) the loop snapshots a
//! [`super::PlanRefreshJob`] — carrying the retired table as a delta donor —
//! and refreshes the ≤ m+3 event-horizon rows on a *worker thread*, on the
//! `UnicronConfig::plan_refresh_period_s` cadence; rows whose solve inputs
//! did not change are copied instead of re-solved (DESIGN.md §12). An MTBF
//! estimate update re-prices every row, so it re-solves the m+3 horizon —
//! not, as before, the full (m+1)·(n+1) grid.
//! The event loop never blocks on it — lease sweeps and detection keep
//! their latency during the rebuild — and an epoch check on install drops
//! results that raced a state change. SEV1 replans are O(1) table commits
//! without any caller having to remember to call
//! [`Coordinator::precompute_plans`].
//!
//! Key layout:
//!   /nodes/<id>            lease-attached registration (node health)
//!   /status/<id>/<seq>     agent error reports (process/exception/stall)
//!   /cmd/<id>/<seq>        coordinator -> agent recovery instructions

use anyhow::Result;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;
use std::time::Duration;

use super::{Action, CoordEvent, Coordinator, NodeId, TaskId};
use crate::config::ClusterSpec;
use crate::detect::classify_exception;
use crate::engine::EventQueue;
use crate::failure::ErrorKind;
use crate::kvstore::{net, Event, Store};
use crate::membership::{membership_event, MembershipEvent, NODES_PREFIX};
use crate::planner::{RefreshStats, ScenarioLookup};
use crate::ser::Value;
use crate::store::{ChunkId, Manifest, SnapshotStore, Tier};
use crate::util::{Clock, Level};

pub const STATUS_PREFIX: &str = "/status/";
pub const CMD_PREFIX: &str = "/cmd/";
/// Schema version stamped (as `report_version`, beside `at_s`) on every
/// `/fleet/*` report the loop publishes — one envelope for health, layout,
/// store, and metrics, so tooling can parse any of them uniformly.
pub const REPORT_VERSION: u64 = 1;
/// Fleet-health report published by the loop (ROADMAP fleet follow-up):
/// per-node history, per-domain MTBF estimates, and the cluster-wide EWMA
/// MTBF estimate, as JSON.
pub const FLEET_HEALTH_KEY: &str = "/fleet/health";
/// The coordinator's authoritative cluster map (per-task node sets),
/// published beside the health report so operators and tooling see which
/// concrete nodes serve which task (DESIGN.md §10).
pub const LAYOUT_KEY: &str = "/fleet/layout";
/// The state-tier report (DESIGN.md §13), published beside health and
/// layout: per-tier occupancy and measured transfer stats, the dedup ratio
/// the delta checkpoints achieve, and restore hit/miss counters.
pub const STORE_KEY: &str = "/fleet/store";
/// The telemetry report (DESIGN.md §14): instrument registry snapshot,
/// recent decision spans, the incident timeline, and the structured log
/// ring — what `unicron obs --addr` renders into an incident narrative.
pub const METRICS_KEY: &str = "/fleet/metrics";

/// Timed work the live loop schedules on the shared engine queue.
#[derive(Debug, Clone, Copy)]
enum LoopTask {
    /// Lease-expiry sweep: drives SEV1 `NodeLost` detection (Table 2 case 1).
    LeaseSweep,
    /// §5.2 background precompute: rebuild the scenario table when stale.
    PlanRefresh,
    /// A coordinator-requested burst-batch wake-up: deliver
    /// [`CoordEvent::ReplanDue`] so the deferred consolidated replan commits.
    ReplanFlush,
}

/// Timestamped record of a detected event (Table 2's measurement hook).
#[derive(Debug, Clone)]
pub struct Detection {
    pub at_s: f64,
    pub event: CoordEvent,
    pub actions: Vec<Action>,
}

/// A running live coordinator.
pub struct CoordinatorLive {
    pub store: Store,
    pub addr: std::net::SocketAddr,
    detections: Arc<Mutex<Vec<Detection>>>,
    /// Completed background scenario-table rebuilds (observability).
    plan_refreshes: Arc<AtomicU64>,
    stop: Arc<AtomicBool>,
    server: Option<crate::rpc::Server>,
    loop_thread: Option<JoinHandle<()>>,
}

impl CoordinatorLive {
    /// Start the live driver around a built [`Coordinator`] (see
    /// [`Coordinator::builder`]): kvstore server on `addr` + event loop.
    pub fn start(
        mut coord: Coordinator,
        clock: Arc<dyn Clock>,
        addr: &str,
    ) -> Result<CoordinatorLive> {
        let cfg = coord.cfg.clone();
        let store = Store::new(clock.clone());
        let server = net::serve(store.clone(), addr)?;
        let server_addr = server.addr;

        let detections = Arc::new(Mutex::new(Vec::new()));
        let plan_refreshes = Arc::new(AtomicU64::new(0));
        let stop = Arc::new(AtomicBool::new(false));

        let store2 = store.clone();
        let det2 = detections.clone();
        let refreshes2 = plan_refreshes.clone();
        let stop2 = stop.clone();
        let seq2 = Arc::new(AtomicU64::new(0));
        let clock2 = clock.clone();
        let loop_thread = std::thread::Builder::new().name("coord-loop".into()).spawn(move || {
            // sweep leases at half the heartbeat period (floored at the poll
            // interval) — frequent enough that expiry detection stays well
            // inside the lease TTL
            let sweep_period = (cfg.heartbeat_period_s * 0.5).max(0.005);
            let refresh_period = cfg.plan_refresh_period_s.max(0.005);
            let nodes_rx = store2.watch(NODES_PREFIX);
            let status_rx = store2.watch(STATUS_PREFIX);
            let mut timers: EventQueue<LoopTask> = EventQueue::new();
            timers.schedule(clock2.now(), LoopTask::LeaseSweep);
            timers.schedule(clock2.now(), LoopTask::PlanRefresh);
            // at most one background precompute in flight at a time
            let mut inflight: Option<JoinHandle<(u64, ScenarioLookup, RefreshStats)>> = None;
            let mut refresh_broken = false;
            // the fleet's view of snapshot residency: agents announce
            // finished checkpoint writes (class "checkpoint" status keys)
            // and the loop tracks occupancy/dedup per tier, publishing the
            // report under /fleet/store on the refresh cadence
            let mut state_tier = SnapshotStore::new(&ClusterSpec::default());
            while !stop2.load(Ordering::Relaxed) {
                // land a finished background rebuild (never blocks)
                if inflight.as_ref().is_some_and(JoinHandle::is_finished) {
                    match inflight.take().unwrap().join() {
                        Ok((epoch, lookup, stats)) => {
                            if coord.install_lookup(epoch, lookup) {
                                refreshes2.fetch_add(1, Ordering::Relaxed);
                                // the background path's row accounting lands
                                // in the same registry the synchronous
                                // refresh feeds
                                coord.note_refresh_stats(&stats);
                            }
                        }
                        Err(_) => {
                            // a panicking precompute is a planner bug: surface
                            // it once and stop respawning the identical job
                            // every period (replans fall back to live solves)
                            refresh_broken = true;
                            coord.telemetry().log(
                                Level::Error,
                                "live.plan_refresh",
                                "background plan refresh panicked; disabling \
                                 background precompute (replans fall back to live solves)",
                            );
                        }
                    }
                }
                for (_, task) in timers.pop_due(clock2.now()) {
                    match task {
                        LoopTask::LeaseSweep => {
                            store2.tick(); // lease expiry -> Delete{expired} events
                            timers.schedule(clock2.now() + sweep_period, LoopTask::LeaseSweep);
                        }
                        LoopTask::PlanRefresh => {
                            if inflight.is_none() && !refresh_broken {
                                if let Some(job) = coord.plan_refresh_job() {
                                    inflight = Some(std::thread::spawn(move || job.compute()));
                                }
                            }
                            let now = clock2.now();
                            publish_fleet_health(&store2, &coord, now);
                            publish_layout(&store2, &coord, now);
                            publish_store(&store2, &state_tier, now);
                            publish_metrics(&store2, &coord, now);
                            timers.schedule(clock2.now() + refresh_period, LoopTask::PlanRefresh);
                        }
                        LoopTask::ReplanFlush => {
                            let event = CoordEvent::ReplanDue;
                            let actions = coord.handle_at(event.clone(), clock2.now());
                            dispatch_actions(&store2, &seq2, &actions);
                            det2.lock().unwrap().push(Detection {
                                at_s: clock2.now(),
                                event,
                                actions,
                            });
                        }
                    }
                }
                let mut events: Vec<CoordEvent> = Vec::new();
                for ev in nodes_rx.try_iter() {
                    match membership_event(&ev) {
                        Some(MembershipEvent::Joined(info)) => {
                            events.push(CoordEvent::NodeJoined {
                                node: NodeId(info.id.parse().unwrap_or(0)),
                            });
                        }
                        Some(MembershipEvent::Left { id, expired }) if expired => {
                            events.push(CoordEvent::NodeLost {
                                node: NodeId(id.parse().unwrap_or(0)),
                            });
                        }
                        _ => {}
                    }
                }
                for ev in status_rx.try_iter() {
                    if let Event::Put { key, value, .. } = ev {
                        // checkpoint announcements feed the state tier, not
                        // the detection path
                        if let Some((tier, host, manifest)) = parse_checkpoint(&key, &value) {
                            state_tier.put_manifest(tier, host, &manifest);
                            continue;
                        }
                        if let Some(e) = parse_status(&key, &value) {
                            events.push(e);
                        }
                    }
                }
                if !events.is_empty() {
                    // the wall clock rides into the decision log (wire v3):
                    // it feeds the fleet's MTBF estimator and makes replays
                    // of live sessions reproduce time-fed decisions exactly
                    let now = clock2.now();
                    // N events surfaced by one poll tick are simultaneous at
                    // this clock resolution: deliver them as ONE
                    // CoordEvent::Batch (wire v5) so the whole burst costs a
                    // single dispatch/replan cycle and one recorded
                    // decision. A lone event stays bare. Live detections
                    // never carry TaskLaunched, so batch replays re-admit
                    // nothing.
                    let event = if events.len() == 1 {
                        events.pop().expect("non-empty")
                    } else {
                        CoordEvent::Batch(std::mem::take(&mut events))
                    };
                    let actions = coord.handle_at(event.clone(), now);
                    for a in &actions {
                        if let Action::ScheduleReplan { after_s } = a {
                            timers.schedule(now + after_s, LoopTask::ReplanFlush);
                        }
                    }
                    dispatch_actions(&store2, &seq2, &actions);
                    // observability stays per member: a batch is recorded as
                    // one Detection per member event, each carrying the
                    // batch's full action list
                    let mut dets = det2.lock().unwrap();
                    match event {
                        CoordEvent::Batch(members) => {
                            for member in members {
                                dets.push(Detection {
                                    at_s: now,
                                    event: member,
                                    actions: actions.clone(),
                                });
                            }
                        }
                        event => dets.push(Detection { at_s: now, event, actions }),
                    }
                }
                std::thread::sleep(Duration::from_millis(5));
            }
            // drain any in-flight rebuild so shutdown doesn't leak the worker
            if let Some(h) = inflight.take() {
                let _ = h.join();
            }
        })?;

        Ok(CoordinatorLive {
            store,
            addr: server_addr,
            detections,
            plan_refreshes,
            stop,
            server: Some(server),
            loop_thread: Some(loop_thread),
        })
    }

    /// Snapshot of everything detected so far.
    pub fn detections(&self) -> Vec<Detection> {
        self.detections.lock().unwrap().clone()
    }

    /// How many background scenario-table rebuilds have completed.
    pub fn plan_refreshes(&self) -> u64 {
        self.plan_refreshes.load(Ordering::Relaxed)
    }

    /// Block until a detection matching `pred` appears (or timeout). Returns
    /// the matching record.
    pub fn wait_for<F: Fn(&Detection) -> bool>(
        &self,
        pred: F,
        timeout: Duration,
    ) -> Option<Detection> {
        let deadline = std::time::Instant::now() + timeout;
        loop {
            if let Some(d) = self.detections.lock().unwrap().iter().find(|d| pred(d)) {
                return Some(d.clone());
            }
            if std::time::Instant::now() > deadline {
                return None;
            }
            std::thread::sleep(Duration::from_millis(2));
        }
    }

    pub fn shutdown(&mut self) {
        self.stop.store(true, Ordering::Relaxed);
        if let Some(h) = self.loop_thread.take() {
            let _ = h.join();
        }
        if let Some(mut s) = self.server.take() {
            s.shutdown();
        }
    }
}

impl Drop for CoordinatorLive {
    fn drop(&mut self) {
        self.shutdown();
    }
}

/// `/status/<node>/<seq>` + JSON body -> coordinator event.
fn parse_status(key: &str, value: &str) -> Option<CoordEvent> {
    let rest = key.strip_prefix(STATUS_PREFIX)?;
    let node = NodeId(rest.split('/').next()?.parse().ok()?);
    let v = Value::parse(value).ok()?;
    let task = TaskId(v.get("task").and_then(Value::as_u64).unwrap_or(0) as u32);
    let class = v.get("class").and_then(Value::as_str).unwrap_or("");
    let msg = v.get("msg").and_then(Value::as_str).unwrap_or("");
    let kind = match class {
        "exception" => classify_exception(msg),
        "exit" => ErrorKind::ExitedAbnormally,
        "stall" => ErrorKind::TaskHang,
        // maintenance tooling announces a finished repair; the fleet layer
        // decides whether the node rejoins, is held, or is quarantined
        "repaired" => return Some(CoordEvent::NodeRepaired { node }),
        // in-band step-timing report (wire v8): agents sample their own
        // training-step wall time and the coordinator's health monitor
        // turns the stream into straggler / gray-failure verdicts
        "step" => {
            let duration_s = v.get("duration_s").and_then(Value::as_f64)?;
            return Some(CoordEvent::StepTiming { node, task, duration_s });
        }
        _ => return None,
    };
    Some(CoordEvent::ErrorReport { node, task, kind })
}

/// Stamp the shared `/fleet/*` envelope ([`REPORT_VERSION`] + publication
/// time) onto a report body. Every fleet report — whether published to the
/// kvstore by this loop or served over RPC by the control plane — goes
/// through here, so every one parses with the same two fields —
/// `background_plan_refresh_keeps_lookup_warm` asserts it.
pub fn envelope(report: Value, at_s: f64) -> Value {
    report.with("report_version", REPORT_VERSION).with("at_s", at_s)
}

fn publish_report(store: &Store, key: &str, report: Value, at_s: f64) {
    let _ = store.put(key, &envelope(report, at_s).encode(), None);
}

/// Build the fleet-health report body (the [`FLEET_HEALTH_KEY`] payload):
/// the cluster-wide EWMA MTBF estimate the cost ledger prices horizons
/// with, plus each node's lifetime history (failures, repairs, lemon
/// score, quarantine/release flags, per-node MTBF estimate). Shared by
/// the live loop's kvstore publisher and the control plane's `get_report`.
pub fn fleet_health_report(coord: &Coordinator) -> Value {
    let nodes: Vec<Value> = coord
        .fleet
        .nodes()
        .map(|(&node, h)| {
            let mut v = Value::obj()
                .with("node", node.0)
                .with("domain", coord.fleet.domain_of(node).0)
                .with("failures", h.failures)
                .with("repairs", h.repairs)
                .with("lemon_score", coord.fleet.lemon_score(node))
                .with("degradation_score", coord.fleet.degradation_score(node))
                .with("hazard_mtbf_s", coord.fleet.hazard_adjusted_mtbf_s(node))
                .with("quarantined", h.quarantined)
                .with("released", h.released);
            if let Some(m) = h.mtbf_estimate_s() {
                v.set("mtbf_s", m);
            }
            v
        })
        .collect();
    // per-domain MTBF estimates (EWMA, seeded from the cluster prior) —
    // the ROADMAP PR-4 follow-up's per-domain column
    let domains: Vec<Value> = coord
        .fleet
        .domains()
        .map(|(&domain, stats)| {
            Value::obj()
                .with("domain", domain.0)
                .with("pressure", coord.fleet.domain_pressure(domain))
                .with("mtbf_est_s", stats.mtbf_estimate_s())
                .with("mtbf_observations", stats.observations())
        })
        .collect();
    Value::obj()
        .with("mtbf_per_gpu_est_s", coord.fleet.mtbf_per_gpu_estimate_s())
        .with("mtbf_observations", coord.fleet.mtbf_observations())
        .with("nodes", Value::Arr(nodes))
        .with("domains", Value::Arr(domains))
}

/// Publish the fleet-health report under [`FLEET_HEALTH_KEY`].
fn publish_fleet_health(store: &Store, coord: &Coordinator, at_s: f64) {
    publish_report(store, FLEET_HEALTH_KEY, fleet_health_report(coord), at_s);
}

/// `/status/<node>/<seq>` checkpoint announcement -> a manifest for the
/// state tier. After a snapshot lands, the writing agent reports
/// `{"class":"checkpoint","task":..,"step":..,"bytes":..}` (optional
/// `chunk_bytes`, and `tier` of "peer"/"disk"/"remote"). Chunk ids are
/// synthetic per (task, index, step): content addressing happens
/// agent-side; the coordinator tracks residency, occupancy, and dedup.
fn parse_checkpoint(key: &str, value: &str) -> Option<(Tier, Option<NodeId>, Manifest)> {
    let rest = key.strip_prefix(STATUS_PREFIX)?;
    let node = NodeId(rest.split('/').next()?.parse().ok()?);
    let v = Value::parse(value).ok()?;
    if v.get("class").and_then(Value::as_str) != Some("checkpoint") {
        return None;
    }
    let task = TaskId(v.get("task").and_then(Value::as_u64)? as u32);
    let step = v.get("step").and_then(Value::as_u64)?;
    let bytes = v.get("bytes").and_then(Value::as_u64)?;
    let chunk_bytes = v.get("chunk_bytes").and_then(Value::as_u64).unwrap_or(64 << 20).max(1);
    let tier = match v.get("tier").and_then(Value::as_str).unwrap_or("peer") {
        "disk" => Tier::LocalDisk,
        "remote" => Tier::Remote,
        _ => Tier::PeerMemory,
    };
    let n = bytes.div_ceil(chunk_bytes).max(1);
    let chunks = (0..n).map(|i| ChunkId::synthetic(task, i, step)).collect();
    // remote is cluster-external: no hosting node to fence or lose
    let host = if tier == Tier::Remote { None } else { Some(node) };
    Some((tier, host, Manifest { task, step, total_bytes: bytes, chunk_bytes, chunks }))
}

/// Publish the state-tier report under [`STORE_KEY`].
fn publish_store(store: &Store, state_tier: &SnapshotStore, at_s: f64) {
    publish_report(store, STORE_KEY, state_tier.report(), at_s);
}

/// Publish the telemetry report under [`METRICS_KEY`]: the coordinator's
/// instrument registry, recent decision spans, the incident timeline, and
/// the structured log ring (DESIGN.md §14).
fn publish_metrics(store: &Store, coord: &Coordinator, at_s: f64) {
    publish_report(store, METRICS_KEY, coord.telemetry().metrics_value(), at_s);
}

/// Build the authoritative cluster-map report body (the [`LAYOUT_KEY`]
/// payload): the per-task node sets of the last committed plan, plus the
/// placeable pool the next layout can draw from. Shared by the live loop's
/// kvstore publisher and the control plane's `get_report`.
pub fn layout_report(coord: &Coordinator) -> Value {
    Value::obj()
        .with("tasks", coord.layout().to_value())
        .with("placeable", coord.placeable_nodes().iter().map(|n| n.0).collect::<Vec<u32>>())
}

/// Publish the cluster map under [`LAYOUT_KEY`].
fn publish_layout(store: &Store, coord: &Coordinator, at_s: f64) {
    publish_report(store, LAYOUT_KEY, layout_report(coord), at_s);
}

/// Publish agent-executable actions under `/cmd/<node>/<seq>`.
fn dispatch_actions(store: &Store, seq: &AtomicU64, actions: &[Action]) {
    for a in actions {
        let (node, body) = match a {
            Action::InstructReattempt { node, task } => {
                (*node, Value::obj().with("op", "reattempt").with("task", task.0 as u64))
            }
            Action::InstructRestart { node, task } => {
                (*node, Value::obj().with("op", "restart").with("task", task.0 as u64))
            }
            Action::IsolateNode { node } => (*node, Value::obj().with("op", "isolate")),
            // a quarantined lemon is fenced exactly like an isolation on the
            // agent side; the permanence lives in coordinator state
            Action::NodeQuarantined { node } => (*node, Value::obj().with("op", "isolate")),
            // a released spare's agent deprovisions the machine
            Action::SpareReleased { node } => (*node, Value::obj().with("op", "release")),
            // plans, alerts, retained spares, and replan timers are
            // coordinator-local (the loop schedules ScheduleReplan itself)
            Action::ApplyPlan { .. }
            | Action::AlertOps { .. }
            | Action::SpareRetained { .. }
            | Action::ScheduleReplan { .. } => continue,
        };
        let n = seq.fetch_add(1, Ordering::Relaxed);
        let _ = store.put(&format!("{CMD_PREFIX}{node}/{n}"), &body.encode(), None);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{TaskSpec, UnicronConfig};
    use crate::planner::PlanTask;
    use crate::proto::WorkerCount;
    use crate::util::RealClock;

    #[test]
    fn parse_status_variants() {
        assert_eq!(
            parse_status("/status/3/0", r#"{"task":1,"class":"exception","msg":"ECC error"}"#),
            Some(CoordEvent::ErrorReport {
                node: NodeId(3),
                task: TaskId(1),
                kind: ErrorKind::EccError
            })
        );
        assert_eq!(
            parse_status("/status/2/9", r#"{"task":0,"class":"exit","msg":""}"#),
            Some(CoordEvent::ErrorReport {
                node: NodeId(2),
                task: TaskId(0),
                kind: ErrorKind::ExitedAbnormally
            })
        );
        assert_eq!(
            parse_status("/status/2/9", r#"{"task":0,"class":"stall","msg":""}"#),
            Some(CoordEvent::ErrorReport {
                node: NodeId(2),
                task: TaskId(0),
                kind: ErrorKind::TaskHang
            })
        );
        assert_eq!(
            parse_status("/status/7/repaired", r#"{"task":0,"class":"repaired","msg":""}"#),
            Some(CoordEvent::NodeRepaired { node: NodeId(7) })
        );
        // in-band step timing (wire v8): agents sample step wall time
        assert_eq!(
            parse_status("/status/5/11", r#"{"task":2,"class":"step","duration_s":47.5}"#),
            Some(CoordEvent::StepTiming {
                node: NodeId(5),
                task: TaskId(2),
                duration_s: 47.5
            })
        );
        // a step report without a measured duration carries no signal
        assert_eq!(parse_status("/status/5/11", r#"{"task":2,"class":"step"}"#), None);
        assert_eq!(parse_status("/status/2/9", r#"{"class":"bogus"}"#), None);
        assert_eq!(parse_status("/other/2", "{}"), None);
    }

    #[test]
    fn live_coordinator_starts_and_stops() {
        let clock: Arc<dyn Clock> = Arc::new(RealClock::new());
        let coord =
            Coordinator::builder().workers(16u32).gpus_per_node(8u32).build();
        let mut live = CoordinatorLive::start(coord, clock, "127.0.0.1:0").unwrap();
        assert!(live.detections().is_empty());
        live.shutdown();
    }

    #[test]
    fn background_plan_refresh_keeps_lookup_warm() {
        // A coordinator with one registered task: the loop must rebuild the
        // stale scenario table on its own cadence, with no caller involved.
        let clock: Arc<dyn Clock> = Arc::new(RealClock::new());
        let cfg = UnicronConfig { plan_refresh_period_s: 0.01, ..Default::default() };
        let throughput = (0..=24u32).map(|x| 1e12 * (x as f64).max(0.0)).collect();
        let task = PlanTask {
            spec: TaskSpec::new(0u32, "m", 1.0, 1),
            throughput,
            profile: crate::cost::TransitionProfile::flat(5.0),
            current: WorkerCount(0),
            fault: false,
            fault_source: crate::transition::StateSource::InMemoryCheckpoint,
            fault_restore_s: None,
        };
        let coord = Coordinator::builder()
            .config(cfg)
            .workers(16u32)
            .gpus_per_node(8u32)
            .task(task)
            .build();
        let mut live = CoordinatorLive::start(coord, clock, "127.0.0.1:0").unwrap();
        let deadline = std::time::Instant::now() + Duration::from_secs(5);
        while live.plan_refreshes() == 0 && std::time::Instant::now() < deadline {
            std::thread::sleep(Duration::from_millis(5));
        }
        assert!(live.plan_refreshes() >= 1, "background precompute never ran");
        // a fresh table is not rebuilt again and again: the count settles
        let settled = live.plan_refreshes();
        std::thread::sleep(Duration::from_millis(100));
        assert_eq!(live.plan_refreshes(), settled, "fresh table must not be rebuilt");
        // the loop publishes the fleet-health report on the same cadence
        let health = live.store.get_prefix(FLEET_HEALTH_KEY);
        assert!(!health.is_empty(), "fleet health must be published");
        let v = Value::parse(&health[0].1).expect("health report must be JSON");
        assert!(v.get("mtbf_per_gpu_est_s").and_then(Value::as_f64).unwrap_or(0.0) > 0.0);
        let nodes = v.get("nodes").and_then(Value::as_arr).expect("nodes column");
        // wire v8: every node row carries its degradation score and the
        // hazard-adjusted MTBF column beside the flat EWMA estimate
        for n in nodes {
            assert!(
                n.get("degradation_score").and_then(Value::as_f64).is_some_and(|s| s >= 0.0),
                "node row missing degradation_score"
            );
            assert!(
                n.get("hazard_mtbf_s").and_then(Value::as_f64).is_some_and(|m| m > 0.0),
                "node row missing hazard_mtbf_s"
            );
        }
        assert!(v.get("domains").and_then(Value::as_arr).is_some(), "per-domain MTBF column");
        // ...and the cluster map beside it
        let layout = live.store.get_prefix(LAYOUT_KEY);
        let layout =
            layout.iter().find(|(k, _)| k == LAYOUT_KEY).expect("layout must be published");
        let v = Value::parse(&layout.1).expect("layout report must be JSON");
        assert!(v.get("tasks").and_then(Value::as_arr).is_some());
        assert!(
            !v.get("placeable").and_then(Value::as_arr).unwrap_or(&[]).is_empty(),
            "the placeable pool must list the seeded nodes"
        );
        // an agent announces a finished checkpoint write: the loop ingests
        // it into the state tier and the /fleet/store report shows the
        // occupancy on the next refresh tick
        live.store
            .put("/status/3/7", r#"{"task":0,"class":"checkpoint","step":1,"bytes":1048576}"#, None)
            .unwrap();
        let deadline = std::time::Instant::now() + Duration::from_secs(5);
        let occupied = loop {
            let mut bytes = 0;
            if let Some((_, raw)) =
                live.store.get_prefix(STORE_KEY).iter().find(|(k, _)| k == STORE_KEY)
            {
                let v = Value::parse(raw).expect("store report must be JSON");
                for key in ["tiers", "dedup_ratio", "hits", "misses"] {
                    assert!(v.get(key).is_some(), "store report missing {key}");
                }
                bytes = v
                    .get("tiers")
                    .and_then(|t| t.get("peer_memory"))
                    .and_then(|t| t.get("occupancy_bytes"))
                    .and_then(Value::as_u64)
                    .unwrap_or(0);
            }
            if bytes > 0 {
                break bytes;
            }
            assert!(
                std::time::Instant::now() < deadline,
                "store report never showed the announced checkpoint"
            );
            std::thread::sleep(Duration::from_millis(5));
        };
        assert_eq!(occupied, 1048576, "one announced megabyte resident in peer memory");
        // one schema for every /fleet/* report: each value parses as JSON
        // and carries the shared envelope (report_version + at_s)
        let reports = live.store.get_prefix("/fleet/");
        for key in [FLEET_HEALTH_KEY, LAYOUT_KEY, STORE_KEY, METRICS_KEY] {
            assert!(reports.iter().any(|(k, _)| k == key), "{key} must be published");
        }
        for (key, raw) in &reports {
            let v = Value::parse(raw).unwrap_or_else(|e| panic!("{key} is not JSON: {e}"));
            assert_eq!(
                v.get("report_version").and_then(Value::as_u64),
                Some(REPORT_VERSION),
                "{key} missing the shared report_version"
            );
            assert!(
                v.get("at_s").and_then(Value::as_f64).is_some_and(|t| t >= 0.0),
                "{key} missing the shared at_s stamp"
            );
        }
        // the metrics report carries the telemetry sections obs renders
        let (_, raw) = reports.iter().find(|(k, _)| k == METRICS_KEY).unwrap();
        let v = Value::parse(raw).unwrap();
        for key in ["registry", "spans", "timeline", "logs"] {
            assert!(v.get(key).is_some(), "metrics report missing {key}");
        }
        live.shutdown();
    }
}
