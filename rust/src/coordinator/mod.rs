//! The Unicron coordinator (§3.2): consolidates agent status, classifies
//! errors, drives the §4.2 handling workflow (Fig. 7), and triggers
//! cost-aware reconfiguration through the [`crate::planner`].
//!
//! The core is a synchronous, fully-deterministic state machine —
//! [`Coordinator::handle`] maps one [`CoordEvent`] to a list of [`Action`]s;
//! it never reads a clock, a thread, or a socket. The event/action
//! vocabulary itself lives in the [`crate::proto`] layer (typed ids,
//! serialization, the [`DecisionLog`] record/replay artifact); this module
//! re-exports it. Two drivers feed the state machine:
//!
//! * the live TCP driver ([`live`]) translates kvstore watches into
//!   [`CoordEvent`]s and publishes the returned [`Action`]s to agents over
//!   the wire, with its timed work ordered by the shared
//!   [`crate::engine::EventQueue`];
//! * the discrete-event environment model ([`crate::simulator`]) translates
//!   failure-trace events into the same [`CoordEvent`]s and executes the
//!   same [`Action`]s against simulated time from the same engine.
//!
//! Both run this exact state machine. `rust/tests/sim_unification.rs`
//! asserts the simulator's executed action sequence is identical to the
//! audit [`Coordinator::log`] replayed standalone — the property that makes
//! the Table 2 / Fig. 9 / Fig. 11 experiments exercise the *actual*
//! coordinator rather than a hand-maintained model of it.
//!
//! Construction goes through [`Coordinator::builder`] (see DESIGN.md §7 for
//! the mapping from the old positional constructor).
//!
//! Hot path (§5.2): between events the owner calls
//! [`Coordinator::precompute_plans`] to build a [`ScenarioLookup`] covering
//! every `(faulted task, worker count)` the next event could produce; a
//! SEV1 replan then commits a precomputed plan in O(1) table time instead of
//! running the O(m·n²) DP inside the failure-handling window. The table
//! invalidates itself whenever committed assignments change. The live
//! driver ([`live`]) refreshes it on a background cadence
//! (`UnicronConfig::plan_refresh_period_s`), so table freshness no longer
//! depends on callers remembering to precompute.

pub mod live;

use std::collections::BTreeMap;

use crate::config::UnicronConfig;
use crate::failure::Severity;
use crate::planner::{solve, PlanTask, ScenarioLookup};
pub use crate::proto::{
    Action, CoordEvent, DecisionLog, NodeId, PlanReason, TaskId, WorkerCount,
};

/// Per-(task, node) escalation bookkeeping.
#[derive(Debug, Default, Clone)]
struct EscalationState {
    reattempts: u32,
    restarts: u32,
}

/// A snapshot of everything a background worker needs to rebuild the §5.2
/// scenario table off the coordinator's thread. Produced by
/// [`Coordinator::plan_refresh_job`]; the epoch inside ties the result to
/// the exact coordinator state it was computed for.
#[derive(Debug, Clone)]
pub struct PlanRefreshJob {
    tasks: Vec<PlanTask>,
    ceiling: u32,
    cfg: UnicronConfig,
    epoch: u64,
}

impl PlanRefreshJob {
    /// Run the expensive precompute (O((m+1)·n·m·n²)). CPU-bound — call it
    /// off the event loop; hand the result to
    /// [`Coordinator::install_lookup`].
    pub fn compute(self) -> (u64, ScenarioLookup) {
        (self.epoch, ScenarioLookup::precompute(&self.tasks, self.ceiling, &self.cfg))
    }
}

/// Staged construction of a [`Coordinator`] — replaces the old positional
/// `Coordinator::new(cfg, workers, gpus_per_node)` (DESIGN.md §7).
#[derive(Debug, Default)]
pub struct CoordinatorBuilder {
    cfg: UnicronConfig,
    workers: WorkerCount,
    gpus_per_node: Option<WorkerCount>,
    tasks: Vec<PlanTask>,
}

impl CoordinatorBuilder {
    pub fn config(mut self, cfg: UnicronConfig) -> Self {
        self.cfg = cfg;
        self
    }

    /// Healthy workers (GPUs) available at start.
    pub fn workers(mut self, w: impl Into<WorkerCount>) -> Self {
        self.workers = w.into();
        self
    }

    /// GPUs contributed per node (to size `NodeLost` effects). Default 8.
    pub fn gpus_per_node(mut self, g: impl Into<WorkerCount>) -> Self {
        self.gpus_per_node = Some(g.into());
        self
    }

    /// Register one task (with its calibrated throughput table) up front.
    pub fn task(mut self, task: PlanTask) -> Self {
        self.tasks.push(task);
        self
    }

    /// Register several tasks up front.
    pub fn tasks(mut self, tasks: impl IntoIterator<Item = PlanTask>) -> Self {
        self.tasks.extend(tasks);
        self
    }

    pub fn build(self) -> Coordinator {
        let mut coord = Coordinator {
            cfg: self.cfg,
            tasks: BTreeMap::new(),
            available_workers: self.workers.0,
            gpus_per_node: self.gpus_per_node.unwrap_or(WorkerCount(8)).0,
            isolated: Vec::new(),
            escalations: BTreeMap::new(),
            log: DecisionLog::new(),
            lookup: None,
            plan_epoch: 0,
            lookup_hits: 0,
            solve_calls: 0,
        };
        for t in self.tasks {
            coord.add_task(t);
        }
        coord
    }
}

/// The coordinator state machine.
pub struct Coordinator {
    pub cfg: UnicronConfig,
    /// Planner inputs for every task currently in the cluster.
    tasks: BTreeMap<TaskId, PlanTask>,
    /// Healthy workers (GPUs) currently available.
    available_workers: u32,
    /// GPUs contributed per node (to size NodeLost effects).
    gpus_per_node: u32,
    /// Nodes currently isolated (fenced off).
    pub isolated: Vec<NodeId>,
    escalations: BTreeMap<(TaskId, NodeId), EscalationState>,
    /// Audit log of (event, actions) — the tests' and benches' ground
    /// truth, and a serializable [`crate::proto::DecisionLog`] artifact.
    pub log: DecisionLog,
    /// §5.2 precomputed plan table; `None` when stale (assignments changed
    /// since the last [`Coordinator::precompute_plans`]).
    lookup: Option<ScenarioLookup>,
    /// Bumped whenever the lookup goes stale — guards stale background
    /// [`PlanRefreshJob`] results against racing a state change.
    plan_epoch: u64,
    /// Replans served from the precomputed table (observability/benches).
    pub lookup_hits: u64,
    /// Replans that fell back to a fresh DP solve.
    pub solve_calls: u64,
}

impl Coordinator {
    /// Start building a coordinator (defaults: empty pool, 8 GPUs/node,
    /// default config).
    pub fn builder() -> CoordinatorBuilder {
        CoordinatorBuilder::default()
    }

    /// Register a task (with its calibrated throughput table) for planning.
    pub fn add_task(&mut self, task: PlanTask) {
        self.tasks.insert(task.spec.id, task);
        self.invalidate_lookup(); // task set changed: precomputed plans are stale
    }

    /// The precomputed table is stale: drop it and bump the epoch so any
    /// in-flight background rebuild for the old state cannot land.
    fn invalidate_lookup(&mut self) {
        self.lookup = None;
        self.plan_epoch += 1;
    }

    /// Healthy workers (GPUs) currently available.
    pub fn available_workers(&self) -> WorkerCount {
        WorkerCount(self.available_workers)
    }

    /// GPUs contributed per node.
    pub fn gpus_per_node(&self) -> WorkerCount {
        WorkerCount(self.gpus_per_node)
    }

    /// Full cluster capacity (healthy + isolated nodes' GPUs) — the upper
    /// bound a join can restore the pool to, and the precompute range.
    fn capacity_ceiling(&self) -> u32 {
        self.available_workers + self.gpus_per_node * self.isolated.len() as u32
    }

    /// Build the §5.2 scenario table for the current assignments. Call this
    /// off the failure path (the paper runs it in the background after every
    /// reconfiguration); subsequent replans are O(1) table commits until the
    /// assignments change again.
    pub fn precompute_plans(&mut self) {
        if self.tasks.is_empty() {
            self.lookup = None;
            return;
        }
        let ordered: Vec<PlanTask> = self.tasks.values().cloned().collect();
        self.lookup = Some(ScenarioLookup::precompute(&ordered, self.capacity_ceiling(), &self.cfg));
    }

    /// Snapshot the inputs for a *background* scenario-table rebuild — the
    /// paper's "proactive plan generation" without blocking the event loop.
    /// Returns `None` when there is nothing to do (no tasks, or the table is
    /// already fresh). Compute the job anywhere (typically a worker thread)
    /// and hand the result back through [`Coordinator::install_lookup`].
    pub fn plan_refresh_job(&self) -> Option<PlanRefreshJob> {
        if self.tasks.is_empty() || self.lookup_is_fresh() {
            return None;
        }
        Some(PlanRefreshJob {
            tasks: self.tasks.values().cloned().collect(),
            ceiling: self.capacity_ceiling(),
            cfg: self.cfg.clone(),
            epoch: self.plan_epoch,
        })
    }

    /// Install a background-computed table. Returns `false` (dropping the
    /// table) if the assignments or task set changed since the job was
    /// snapshotted — a stale table must never serve a replan.
    pub fn install_lookup(&mut self, epoch: u64, lookup: ScenarioLookup) -> bool {
        if epoch != self.plan_epoch {
            return false;
        }
        self.lookup = Some(lookup);
        true
    }

    /// True if the next replan will be served from the precomputed table:
    /// the table matches the current task set and covers the current pool
    /// size (a brand-new node joining past the precomputed ceiling falls
    /// back to a live solve rather than silently clamping).
    pub fn lookup_is_fresh(&self) -> bool {
        self.lookup.as_ref().is_some_and(|l| {
            l.n_tasks() == self.tasks.len() && self.available_workers <= l.max_workers()
        })
    }

    /// True once at least one task is registered for planning.
    pub fn has_tasks(&self) -> bool {
        !self.tasks.is_empty()
    }

    pub fn task_assignment(&self, task: TaskId) -> Option<WorkerCount> {
        self.tasks.get(&task).map(|t| t.current)
    }

    pub fn tasks(&self) -> impl Iterator<Item = &PlanTask> {
        self.tasks.values()
    }

    /// Total WAF of the current assignments (cluster health metric).
    pub fn current_waf(&self) -> f64 {
        self.tasks.values().map(|t| t.waf(t.current.0)).sum()
    }

    /// Process one event; returns the actions (also appended to `log`).
    pub fn handle(&mut self, event: CoordEvent) -> Vec<Action> {
        let actions = self.dispatch(&event);
        self.log.record(event, actions.clone());
        actions
    }

    fn dispatch(&mut self, event: &CoordEvent) -> Vec<Action> {
        match *event {
            CoordEvent::ErrorReport { node, task, kind } => match kind.severity() {
                Severity::Sev3 => self.on_sev3(node, task),
                Severity::Sev2 => self.on_sev2(node, task),
                Severity::Sev1 => self.on_sev1(node, Some(task)),
            },
            CoordEvent::NodeLost { node } => self.on_sev1(node, None),
            CoordEvent::NodeJoined { node } => {
                self.isolated.retain(|&n| n != node);
                self.available_workers += self.gpus_per_node;
                self.reconfigure(PlanReason::NodeJoined, None)
            }
            CoordEvent::TaskFinished { task } => {
                self.tasks.remove(&task);
                self.invalidate_lookup(); // task set changed
                self.reconfigure(PlanReason::TaskFinished, None)
            }
            CoordEvent::TaskLaunched { .. } => {
                // caller adds the PlanTask via add_task before this event
                self.reconfigure(PlanReason::TaskLaunched, None)
            }
            CoordEvent::ReattemptResult { node, task, ok } => {
                if ok {
                    self.escalations.remove(&(task, node));
                    vec![]
                } else {
                    // §4.2: failed reattempt upgrades SEV3 -> SEV2
                    self.on_sev2(node, task)
                }
            }
            CoordEvent::RestartResult { node, task, ok } => {
                if ok {
                    self.escalations.remove(&(task, node));
                    vec![]
                } else {
                    // §4.2: failed restart upgrades SEV2 -> SEV1
                    self.on_sev1(node, Some(task))
                }
            }
        }
    }

    fn on_sev3(&mut self, node: NodeId, task: TaskId) -> Vec<Action> {
        let esc = self.escalations.entry((task, node)).or_default();
        if esc.reattempts < self.cfg.max_reattempts {
            esc.reattempts += 1;
            vec![Action::InstructReattempt { node, task }]
        } else {
            self.on_sev2(node, task)
        }
    }

    fn on_sev2(&mut self, node: NodeId, task: TaskId) -> Vec<Action> {
        let esc = self.escalations.entry((task, node)).or_default();
        if esc.restarts < self.cfg.max_restarts {
            esc.restarts += 1;
            vec![Action::InstructRestart { node, task }]
        } else {
            self.on_sev1(node, Some(task))
        }
    }

    fn on_sev1(&mut self, node: NodeId, task: Option<TaskId>) -> Vec<Action> {
        if self.isolated.contains(&node) {
            return vec![]; // already fenced; duplicate report
        }
        self.isolated.push(node);
        self.available_workers = self.available_workers.saturating_sub(self.gpus_per_node);
        let mut actions = vec![
            Action::IsolateNode { node },
            Action::AlertOps { message: format!("SEV1: node {node} isolated; maintenance required") },
        ];
        actions.extend(self.reconfigure(PlanReason::Sev1Failure, task));
        actions
    }

    /// Cost-aware plan generation (§5) + bookkeeping of the new assignments.
    ///
    /// Served from the precomputed [`ScenarioLookup`] when it is fresh (an
    /// O(1) table commit — the §5.2 hot path), falling back to a live DP
    /// [`solve`] otherwise. Both paths produce the identical plan for the
    /// same state; `coordinator::tests::lookup_path_is_equivalent` holds
    /// them to that.
    fn reconfigure(&mut self, reason: PlanReason, faulted_task: Option<TaskId>) -> Vec<Action> {
        if self.tasks.is_empty() {
            return vec![];
        }
        // map the faulted task id to its position in id-ordered iteration
        let fault_idx = faulted_task.and_then(|t| self.tasks.keys().position(|&k| k == t));
        let plan = if self.lookup_is_fresh() {
            self.lookup_hits += 1;
            let lut = self.lookup.as_ref().unwrap();
            lut.plan_for(fault_idx, self.available_workers).clone()
        } else {
            self.solve_calls += 1;
            let mut ordered: Vec<PlanTask> = self.tasks.values().cloned().collect();
            if let Some(i) = fault_idx {
                ordered[i].fault = true;
            }
            solve(&ordered, self.available_workers, &self.cfg)
        };
        // commit the new assignments; clear fault flags (handled). The
        // precomputed table remains valid only if nothing actually moved.
        let mut changed = false;
        for (pt, &x) in self.tasks.values_mut().zip(plan.assignment.iter()) {
            changed |= pt.current.0 != x;
            pt.current = WorkerCount(x);
            pt.fault = false;
        }
        if changed {
            self.invalidate_lookup();
        }
        vec![Action::ApplyPlan { plan, reason }]
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::TaskSpec;
    use crate::failure::ErrorKind;

    fn plan_task(id: u32, min: u32, current: u32, n: u32) -> PlanTask {
        let throughput =
            (0..=n).map(|x| if x >= min { 1e12 * (x as f64).powf(0.9) } else { 0.0 }).collect();
        PlanTask {
            spec: TaskSpec::new(id, "m", 1.0, min),
            throughput,
            current: WorkerCount(current),
            fault: false,
        }
    }

    fn coord(workers: u32) -> Coordinator {
        Coordinator::builder()
            .workers(workers)
            .gpus_per_node(8u32)
            .task(plan_task(0, 2, workers / 2, workers + 16))
            .task(plan_task(1, 2, workers / 2, workers + 16))
            .build()
    }

    #[test]
    fn sev3_reattempts_then_escalates() {
        let mut c = coord(32);
        // three reattempts allowed
        for i in 0..3 {
            let a = c.handle(CoordEvent::ErrorReport {
                node: NodeId(1),
                task: TaskId(0),
                kind: ErrorKind::ConnectionRefused,
            });
            assert_eq!(
                a,
                vec![Action::InstructReattempt { node: NodeId(1), task: TaskId(0) }],
                "attempt {i}"
            );
        }
        // fourth SEV3 -> restart (SEV2 path)
        let a = c.handle(CoordEvent::ErrorReport {
            node: NodeId(1),
            task: TaskId(0),
            kind: ErrorKind::ConnectionRefused,
        });
        assert_eq!(a, vec![Action::InstructRestart { node: NodeId(1), task: TaskId(0) }]);
    }

    #[test]
    fn reattempt_success_resets_budget() {
        let mut c = coord(32);
        for _ in 0..3 {
            c.handle(CoordEvent::ErrorReport {
                node: NodeId(1),
                task: TaskId(0),
                kind: ErrorKind::LinkFlapping,
            });
        }
        c.handle(CoordEvent::ReattemptResult { node: NodeId(1), task: TaskId(0), ok: true });
        let a = c.handle(CoordEvent::ErrorReport {
            node: NodeId(1),
            task: TaskId(0),
            kind: ErrorKind::LinkFlapping,
        });
        assert_eq!(a, vec![Action::InstructReattempt { node: NodeId(1), task: TaskId(0) }]);
    }

    #[test]
    fn sev2_restarts_then_escalates_to_sev1() {
        let mut c = coord(32);
        let a = c.handle(CoordEvent::ErrorReport {
            node: NodeId(2),
            task: TaskId(1),
            kind: ErrorKind::CudaError,
        });
        assert_eq!(a, vec![Action::InstructRestart { node: NodeId(2), task: TaskId(1) }]);
        // restart failed -> SEV1: isolate + alert + replan
        let a = c.handle(CoordEvent::RestartResult { node: NodeId(2), task: TaskId(1), ok: false });
        assert!(matches!(a[0], Action::IsolateNode { node: NodeId(2) }));
        assert!(matches!(a[1], Action::AlertOps { .. }));
        assert!(matches!(a[2], Action::ApplyPlan { .. }));
        assert_eq!(c.available_workers(), WorkerCount(24));
        assert_eq!(c.isolated, vec![NodeId(2)]);
    }

    #[test]
    fn sev1_reconfigures_within_reduced_capacity() {
        let mut c = coord(32);
        let a = c.handle(CoordEvent::ErrorReport {
            node: NodeId(0),
            task: TaskId(0),
            kind: ErrorKind::EccError,
        });
        let plan = a
            .iter()
            .find_map(|x| match x {
                Action::ApplyPlan { plan, .. } => Some(plan.clone()),
                _ => None,
            })
            .expect("SEV1 must replan");
        assert!(plan.workers_used <= 24);
        // assignments were committed
        let total: u32 =
            (0..=1).map(|t| c.task_assignment(TaskId(t)).unwrap().0).sum();
        assert!(total <= 24);
    }

    #[test]
    fn duplicate_sev1_for_same_node_is_idempotent() {
        let mut c = coord(32);
        c.handle(CoordEvent::NodeLost { node: NodeId(3) });
        let before = c.available_workers();
        let a = c.handle(CoordEvent::NodeLost { node: NodeId(3) });
        assert!(a.is_empty());
        assert_eq!(c.available_workers(), before);
    }

    #[test]
    fn node_join_triggers_reconfiguration() {
        let mut c = coord(32);
        c.handle(CoordEvent::NodeLost { node: NodeId(1) });
        assert_eq!(c.available_workers(), WorkerCount(24));
        let a = c.handle(CoordEvent::NodeJoined { node: NodeId(1) });
        assert_eq!(c.available_workers(), WorkerCount(32));
        assert!(c.isolated.is_empty());
        assert!(matches!(a[0], Action::ApplyPlan { reason: PlanReason::NodeJoined, .. }));
    }

    #[test]
    fn task_lifecycle_triggers_reconfiguration() {
        let mut c = coord(32);
        let a = c.handle(CoordEvent::TaskFinished { task: TaskId(0) });
        assert!(matches!(a[0], Action::ApplyPlan { reason: PlanReason::TaskFinished, .. }));
        assert!(c.task_assignment(TaskId(0)).is_none());
        // remaining task can now take everything useful
        c.add_task(plan_task(2, 2, 0, 48));
        let a = c.handle(CoordEvent::TaskLaunched { task: TaskId(2) });
        assert!(matches!(a[0], Action::ApplyPlan { reason: PlanReason::TaskLaunched, .. }));
        assert!(c.task_assignment(TaskId(2)).unwrap().0 > 0);
    }

    #[test]
    fn lookup_path_is_equivalent_to_solve_path() {
        // Same event storm, one coordinator precomputing between events, one
        // always solving live — the audit logs must be identical.
        let events = [
            CoordEvent::TaskLaunched { task: TaskId(0) },
            CoordEvent::ErrorReport { node: NodeId(1), task: TaskId(0), kind: ErrorKind::EccError },
            CoordEvent::NodeLost { node: NodeId(2) },
            CoordEvent::NodeJoined { node: NodeId(1) },
            CoordEvent::ErrorReport {
                node: NodeId(3),
                task: TaskId(1),
                kind: ErrorKind::NvlinkError,
            },
            CoordEvent::TaskFinished { task: TaskId(0) },
            CoordEvent::NodeJoined { node: NodeId(2) },
        ];
        let mut warm = coord(32);
        let mut cold = coord(32);
        for ev in &events {
            warm.precompute_plans(); // the §5.2 background step
            assert!(warm.lookup_is_fresh());
            let a = warm.handle(ev.clone());
            let b = cold.handle(ev.clone());
            assert_eq!(a, b, "divergence at {ev:?}");
        }
        assert_eq!(warm.log, cold.log);
        assert!(warm.lookup_hits >= 6, "replans should hit the table: {}", warm.lookup_hits);
        // the one allowed miss: TaskFinished shrinks the task set between the
        // precompute and the replan, so that replan must re-solve
        assert!(warm.solve_calls <= 1, "unexpected hot-path solves: {}", warm.solve_calls);
        assert!(cold.lookup_hits == 0 && cold.solve_calls > 0);
    }

    #[test]
    fn lookup_invalidates_when_assignments_move() {
        let mut c = coord(32);
        c.precompute_plans();
        assert!(c.lookup_is_fresh());
        // a SEV1 shrinks the pool and moves workers: the table must go stale
        c.handle(CoordEvent::NodeLost { node: NodeId(0) });
        assert!(!c.lookup_is_fresh(), "stale table must not survive a commit");
        // adding a task also invalidates
        c.precompute_plans();
        assert!(c.lookup_is_fresh());
        c.add_task(plan_task(7, 2, 0, 48));
        assert!(!c.lookup_is_fresh());
    }

    #[test]
    fn waf_drops_after_sev1_and_recovers_after_join() {
        let mut c = coord(32);
        c.handle(CoordEvent::TaskLaunched { task: TaskId(99) }); // force initial plan
        let healthy = c.current_waf();
        c.handle(CoordEvent::NodeLost { node: NodeId(0) });
        let degraded = c.current_waf();
        assert!(degraded < healthy);
        c.handle(CoordEvent::NodeJoined { node: NodeId(0) });
        let recovered = c.current_waf();
        assert!(recovered >= degraded);
        assert!((recovered - healthy).abs() < 1e-6 * healthy);
    }

    #[test]
    fn background_refresh_job_rejects_stale_installs() {
        let mut c = coord(32);
        let job = c.plan_refresh_job().expect("stale table must produce a job");
        // assignments move before the job lands: the install must be rejected
        c.handle(CoordEvent::NodeLost { node: NodeId(5) });
        let (epoch, lookup) = job.compute();
        assert!(!c.install_lookup(epoch, lookup), "stale table must not land");
        assert!(!c.lookup_is_fresh());
        // a job snapshotted from the new state installs fine
        let (epoch, lookup) = c.plan_refresh_job().unwrap().compute();
        assert!(c.install_lookup(epoch, lookup));
        assert!(c.lookup_is_fresh());
        // and a fresh table means there is nothing left to rebuild
        assert!(c.plan_refresh_job().is_none());
        // the installed table serves the next replan from the hot path
        c.handle(CoordEvent::NodeJoined { node: NodeId(5) });
        assert!(c.lookup_hits >= 1, "installed table must serve replans");
    }

    #[test]
    fn builder_registers_tasks_and_defaults() {
        let c =
            Coordinator::builder().workers(WorkerCount(16)).task(plan_task(4, 2, 0, 32)).build();
        assert_eq!(c.available_workers(), WorkerCount(16));
        assert_eq!(c.gpus_per_node(), WorkerCount(8), "default GPUs per node");
        assert!(c.has_tasks());
        assert_eq!(c.task_assignment(TaskId(4)), Some(WorkerCount(0)));
    }
}
