//! The Unicron coordinator (§3.2): consolidates agent status, classifies
//! errors, drives the §4.2 handling workflow (Fig. 7), and triggers
//! cost-aware reconfiguration through the [`crate::planner`].
//!
//! The core is a synchronous, fully-deterministic state machine —
//! [`Coordinator::handle`] maps one [`CoordEvent`] to a list of [`Action`]s;
//! it never reads a clock, a thread, or a socket. The event/action
//! vocabulary itself lives in the [`crate::proto`] layer (typed ids,
//! serialization, the [`DecisionLog`] record/replay artifact); this module
//! re-exports it. Two drivers feed the state machine:
//!
//! * the live TCP driver ([`live`]) translates kvstore watches into
//!   [`CoordEvent`]s and publishes the returned [`Action`]s to agents over
//!   the wire, with its timed work ordered by the shared
//!   [`crate::engine::EventQueue`];
//! * the discrete-event environment model ([`crate::simulator`]) translates
//!   failure-trace events into the same [`CoordEvent`]s and executes the
//!   same [`Action`]s against simulated time from the same engine.
//!
//! Both run this exact state machine. `rust/tests/sim_unification.rs`
//! asserts the simulator's executed action sequence is identical to the
//! audit [`Coordinator::log`] replayed standalone — the property that makes
//! the Table 2 / Fig. 9 / Fig. 11 experiments exercise the *actual*
//! coordinator rather than a hand-maintained model of it.
//!
//! Construction goes through [`Coordinator::builder`] (see DESIGN.md §7 for
//! the mapping from the old positional constructor).
//!
//! Since the fleet layer (DESIGN.md §8) the coordinator also carries
//! per-node memory: a [`FleetModel`] scores recurrent failures on every SEV
//! and fences lemon nodes *before* they fail again
//! ([`Action::NodeQuarantined`]); a repaired node
//! ([`CoordEvent::NodeRepaired`]) is re-admitted, held as a hot spare, or
//! returned to the provider by the [`crate::fleet::SparePool`] cost arithmetic
//! ([`Action::SpareRetained`] / [`Action::SpareReleased`]). All of it is a
//! pure function of the event sequence, so [`DecisionLog`] replays stay
//! bit-identical.
//!
//! Hot path (§5.2): between events the owner calls
//! [`Coordinator::precompute_plans`] to build a [`ScenarioLookup`] covering
//! every `(faulted task, worker count)` the next event could produce; a
//! SEV1 replan then commits a precomputed plan in O(1) table time instead of
//! running the O(m·n²) DP inside the failure-handling window. The table
//! invalidates itself whenever committed assignments change. The live
//! driver ([`live`]) refreshes it on a background cadence
//! (`UnicronConfig::plan_refresh_period_s`), so table freshness no longer
//! depends on callers remembering to precompute.

pub mod live;

use std::collections::{BTreeMap, BTreeSet};

use crate::config::UnicronConfig;
use crate::cost::{CostModel, SpareTerms};
use crate::failure::Severity;
use crate::fleet::{DomainId, FleetModel, SpareDecision};
use crate::health::{DegradationKind, HealthMonitor};
use crate::placement::{self, AssignCache, ClusterView, Layout};
use crate::planner::{solve, HorizonInputs, PlanTask, RefreshStats, ScenarioLookup};
pub use crate::proto::{
    Action, CoordEvent, DecisionLog, NodeId, PlanReason, TaskId, WorkerCount,
};
use crate::telemetry::{CounterId, GaugeId, Phase, SpanPlan, Telemetry};

/// Per-(task, node) escalation bookkeeping.
#[derive(Debug, Default, Clone)]
struct EscalationState {
    reattempts: u32,
    restarts: u32,
}

/// A snapshot of everything a background worker needs to rebuild the §5.2
/// scenario table off the coordinator's thread. Produced by
/// [`Coordinator::plan_refresh_job`]; the epoch inside ties the result to
/// the exact coordinator state it was computed for.
#[derive(Debug, Clone)]
pub struct PlanRefreshJob {
    tasks: Vec<PlanTask>,
    available: u32,
    gpus_per_node: u32,
    /// Snapshot of the cost ledger (including the MTBF estimate) the table
    /// is priced with — a later estimate change bumps the epoch, so a job
    /// priced with a stale ledger can never land.
    cost: CostModel,
    epoch: u64,
    /// The last table the coordinator retired, with the inputs it was solved
    /// from: rows whose exact solve inputs are unchanged are copied instead
    /// of re-solved. An MTBF estimate change re-prices every row's horizon,
    /// so nothing is reusable then — but the refresh still solves only the
    /// m+3 event-horizon rows instead of the old full (m+1)·(n+1) grid.
    prev: Option<(HorizonInputs, ScenarioLookup)>,
}

impl PlanRefreshJob {
    /// Run the event-horizon refresh (≤ m+3 solves, minus any rows delta-
    /// reused from the retired table). CPU-bound — call it off the event
    /// loop; hand the result to [`Coordinator::install_lookup`].
    pub fn compute(self) -> (u64, ScenarioLookup, RefreshStats) {
        let (lookup, stats) = ScenarioLookup::refresh_horizon(
            &self.tasks,
            self.available,
            self.gpus_per_node,
            &self.cost,
            self.prev.as_ref().map(|(inputs, table)| (inputs, table)),
        );
        (self.epoch, lookup, stats)
    }
}

/// The coordinator's instrument handles in the telemetry [`Registry`](crate::telemetry::Registry)
/// — registered once at build time, bumped on the hot path (DESIGN.md §14).
#[derive(Debug, Clone, Copy)]
struct CoordMetrics {
    /// Events dispatched through [`Coordinator::handle_at`].
    events: CounterId,
    /// Plans committed ([`Action::ApplyPlan`]).
    replans: CounterId,
    /// Replans served from the precomputed table (the §5.2 hot path).
    lookup_hits: CounterId,
    /// Replans that fell back to a fresh DP solve.
    solve_calls: CounterId,
    /// Table rows copied from a retired table by the delta refresh.
    rows_reused: CounterId,
    /// Table rows the delta refresh actually re-solved.
    rows_solved: CounterId,
    /// Members delivered inside [`CoordEvent::Batch`] envelopes.
    batch_members: CounterId,
    /// The fleet's effective per-GPU MTBF estimate (already an EWMA —
    /// alpha 1.0 makes the gauge a last-value mirror).
    mtbf_gauge: GaugeId,
}

/// Staged construction of a [`Coordinator`] — replaces the old positional
/// `Coordinator::new(cfg, workers, gpus_per_node)` (DESIGN.md §7).
#[derive(Debug, Default)]
pub struct CoordinatorBuilder {
    cfg: UnicronConfig,
    workers: WorkerCount,
    gpus_per_node: Option<WorkerCount>,
    tasks: Vec<PlanTask>,
    tracing: Option<bool>,
}

impl CoordinatorBuilder {
    pub fn config(mut self, cfg: UnicronConfig) -> Self {
        self.cfg = cfg;
        self
    }

    /// Healthy workers (GPUs) available at start.
    pub fn workers(mut self, w: impl Into<WorkerCount>) -> Self {
        self.workers = w.into();
        self
    }

    /// GPUs contributed per node (to size `NodeLost` effects). Default 8.
    pub fn gpus_per_node(mut self, g: impl Into<WorkerCount>) -> Self {
        self.gpus_per_node = Some(g.into());
        self
    }

    /// Register one task (with its calibrated throughput table) up front.
    pub fn task(mut self, task: PlanTask) -> Self {
        self.tasks.push(task);
        self
    }

    /// Register several tasks up front.
    pub fn tasks(mut self, tasks: impl IntoIterator<Item = PlanTask>) -> Self {
        self.tasks.extend(tasks);
        self
    }

    /// Switch per-decision span/timeline tracing (default on). Counters and
    /// gauges stay live either way; tracing is observe-only, so decisions
    /// are bit-identical with it on or off
    /// (`rust/tests/telemetry_replay.rs` pins this).
    pub fn telemetry(mut self, tracing: bool) -> Self {
        self.tracing = Some(tracing);
        self
    }

    pub fn build(self) -> Coordinator {
        let fleet = FleetModel::from_config(&self.cfg);
        let cost = CostModel::from_config(&self.cfg);
        let gpn = self.gpus_per_node.unwrap_or(WorkerCount(8)).0.max(1);
        // The initial anonymous capacity is realized as concrete node ids
        // 0..ceil(workers/gpn) — the convention every trace generator and
        // the simulated cluster use; real deployments grow/replace the set
        // through NodeJoined/NodeLost as agents register.
        let placeable: BTreeSet<NodeId> =
            (0..self.workers.0.div_ceil(gpn)).map(NodeId).collect();
        let mut telemetry = Telemetry::with_tracing(self.tracing.unwrap_or(true));
        let reg = telemetry.registry_mut();
        let metrics = CoordMetrics {
            events: reg.counter("coord.events"),
            replans: reg.counter("coord.replans"),
            lookup_hits: reg.counter("plan.lookup_hits"),
            solve_calls: reg.counter("plan.solve_calls"),
            rows_reused: reg.counter("plan.lookup_rows_reused"),
            rows_solved: reg.counter("plan.lookup_rows_solved"),
            batch_members: reg.counter("coord.batch_members"),
            mtbf_gauge: reg.gauge("fleet.mtbf_per_gpu_s", 1.0),
        };
        let health = HealthMonitor::from_config(&self.cfg);
        let mut coord = Coordinator {
            fleet,
            cost,
            health,
            pending_degradation: None,
            cfg: self.cfg,
            tasks: BTreeMap::new(),
            available_workers: self.workers.0,
            peak_workers: self.workers.0,
            gpus_per_node: gpn,
            isolated: Vec::new(),
            quarantined: Vec::new(),
            released: Vec::new(),
            pooled: Vec::new(),
            placeable,
            layout: Layout::default(),
            escalations: BTreeMap::new(),
            log: DecisionLog::new(),
            lookup: None,
            lookup_inputs: None,
            stale_lookup: None,
            plan_epoch: 0,
            telemetry,
            metrics,
            place_cache: None,
            batch_depth: 0,
            batch_replan: None,
            last_at_s: 0.0,
            deferred_faults: None,
            last_domain_sev1: BTreeMap::new(),
        };
        for t in self.tasks {
            coord.add_task(t);
        }
        coord
    }
}

/// The coordinator state machine.
pub struct Coordinator {
    pub cfg: UnicronConfig,
    /// Planner inputs for every task currently in the cluster.
    tasks: BTreeMap<TaskId, PlanTask>,
    /// Healthy workers (GPUs) currently available.
    available_workers: u32,
    /// Largest pool the cluster has been entitled to (initial capacity,
    /// grown by explicit joins). A repaired node below this is restoring
    /// lost capacity; at or above it, it is a hot-spare candidate priced by
    /// the [`crate::fleet::SparePool`] economics.
    peak_workers: u32,
    /// GPUs contributed per node (to size NodeLost effects).
    gpus_per_node: u32,
    /// Nodes currently isolated (fenced off, expected back after repair).
    pub isolated: Vec<NodeId>,
    /// Lemon nodes fenced for good — no repair returns them, and they are
    /// excluded from the capacity ceiling plans are precomputed against.
    pub quarantined: Vec<NodeId>,
    /// Nodes returned to the provider by a spare-pool decision.
    pub released: Vec<NodeId>,
    /// Nodes known to be serving in the pool (re-admitted via
    /// `NodeRepaired`/`NodeJoined`; removed on isolation). Deduplicates the
    /// two live re-admission paths — a retained repair followed by the
    /// node's agent re-registering must not add its capacity twice, and a
    /// duplicate repair announcement must not either. Initial anonymous
    /// capacity is not tracked here.
    pooled: Vec<NodeId>,
    /// Concrete placeable node set — the universe [`placement::assign`]
    /// maps plans onto. Seeded from the initial capacity, grown by joins /
    /// retained repairs, shrunk by isolations, quarantines, and releases;
    /// `available_workers ≤ gpus_per_node · |placeable|` is maintained by
    /// construction (capacity only grows together with a node).
    placeable: BTreeSet<NodeId>,
    /// The authoritative cluster map: which concrete nodes serve each task
    /// (DESIGN.md §10). Updated on every committed plan; rides the plan
    /// onto the wire ([`crate::planner::Plan::layout`], v4) so recorded
    /// sessions replay layouts bit-identically.
    layout: Layout,
    /// Per-node lifetime health history — the lemon/quarantine and spare
    /// decisions' evidence base (fleet layer, DESIGN.md §8).
    pub fleet: FleetModel,
    /// In-band streaming health estimators (wire v8, DESIGN.md §16):
    /// per-node step-duration baselines fed by [`CoordEvent::StepTiming`].
    /// State evolves only from the recorded event stream, so replays
    /// rebuild identical estimators and identical degradation verdicts.
    health: HealthMonitor,
    /// Degradation detection-latency penalty owed to the next committed
    /// plan (`slow_frac · F(t, x) · d_degradation`, FLOP·s): stamped by a
    /// degradation eviction and drained when its replan commits — after
    /// plan selection, so a table hit prices identically to a live solve.
    pending_degradation: Option<f64>,
    escalations: BTreeMap<(TaskId, NodeId), EscalationState>,
    /// Audit log of (event, actions) — the tests' and benches' ground
    /// truth, and a serializable [`crate::proto::DecisionLog`] artifact.
    pub log: DecisionLog,
    /// §5.2 precomputed plan table; `None` when stale (assignments changed
    /// since the last [`Coordinator::precompute_plans`]).
    lookup: Option<ScenarioLookup>,
    /// The exact solve inputs `lookup` was built from (fault-cleared tasks +
    /// cost ledger) — what the delta refresh compares against to decide
    /// which retired rows are still live solves.
    lookup_inputs: Option<HorizonInputs>,
    /// The last invalidated table, kept (with its inputs) as the delta-
    /// refresh donor: rows whose solve inputs did not change are copied
    /// instead of re-solved. Purely a cache — reuse is gated on input
    /// bit-equality, so dropping it at any point only costs solves.
    stale_lookup: Option<(HorizonInputs, ScenarioLookup)>,
    /// Bumped whenever the lookup goes stale — guards stale background
    /// [`PlanRefreshJob`] results against racing a state change.
    plan_epoch: u64,
    /// The observability subsystem (DESIGN.md §14): instrument registry
    /// (which absorbed the old ad-hoc `lookup_hits`/`solve_calls`/
    /// `lookup_rows_*` counter fields), per-decision spans, the incident
    /// timeline, and the structured log ring. Strictly observe-only:
    /// nothing in it feeds back into a decision.
    telemetry: Telemetry,
    /// Instrument handles registered at build time.
    metrics: CoordMetrics,
    /// Warm-start state for [`placement::assign_cached`]: the free-node map
    /// carried between replans so an incremental solve touches only what
    /// changed. Purely a cache — results are bit-identical to from-scratch
    /// [`placement::assign`], so replays stay bit-identical.
    place_cache: Option<AssignCache>,
    /// Nesting depth of [`CoordEvent::Batch`] dispatch: while > 0, replans
    /// are deferred so the whole batch costs one consolidated plan.
    batch_depth: u32,
    /// The latest replan reason owed by the current batch (last one wins);
    /// committed once when the outermost batch closes.
    batch_replan: Option<PlanReason>,
    /// The cost ledger every plan, transition, and spare decision is priced
    /// with (DESIGN.md §9). The effective MTBF inside tightens as
    /// [`Coordinator::handle_at`] observes real failure timestamps.
    cost: CostModel,
    /// Latest delivery timestamp seen (the clock [`Coordinator::handle`]
    /// reuses for clockless callers).
    last_at_s: f64,
    /// Faulted tasks of a correlated same-domain burst whose replan was
    /// deferred ([`Action::ScheduleReplan`]); drained into the next
    /// committed replan. `Some(vec![])` means a replan is owed even though
    /// no owned task was hit (idle-node burst losses).
    deferred_faults: Option<Vec<TaskId>>,
    /// Last SEV1 per failure domain: (node, delivery time) — the
    /// distinct-node + recency evidence the burst batcher requires on top
    /// of the fleet's domain pressure.
    last_domain_sev1: BTreeMap<DomainId, (NodeId, f64)>,
}

impl Coordinator {
    /// Start building a coordinator (defaults: empty pool, 8 GPUs/node,
    /// default config).
    pub fn builder() -> CoordinatorBuilder {
        CoordinatorBuilder::default()
    }

    /// Register a task (with its calibrated throughput table) for planning.
    pub fn add_task(&mut self, task: PlanTask) {
        self.tasks.insert(task.spec.id, task);
        self.invalidate_lookup(); // task set changed: precomputed plans are stale
    }

    /// The precomputed table is stale: retire it (it becomes the delta-
    /// refresh donor — rows whose solve inputs are unchanged get copied, not
    /// re-solved) and bump the epoch so any in-flight background rebuild for
    /// the old state cannot land.
    fn invalidate_lookup(&mut self) {
        if let (Some(inputs), Some(table)) = (self.lookup_inputs.take(), self.lookup.take()) {
            self.stale_lookup = Some((inputs, table));
        }
        self.lookup = None;
        self.lookup_inputs = None;
        self.plan_epoch += 1;
    }

    /// Healthy workers (GPUs) currently available.
    pub fn available_workers(&self) -> WorkerCount {
        WorkerCount(self.available_workers)
    }

    /// GPUs contributed per node.
    pub fn gpus_per_node(&self) -> WorkerCount {
        WorkerCount(self.gpus_per_node)
    }

    /// Surviving cluster capacity (healthy + isolated nodes' GPUs) — the
    /// upper bound a repair can restore the pool to, and the precompute
    /// range. Quarantined and released nodes are *not* counted: they never
    /// come back, so precomputing plans for their capacity would waste the
    /// background budget on unreachable scenarios.
    fn capacity_ceiling(&self) -> u32 {
        self.available_workers + self.gpus_per_node * self.isolated.len() as u32
    }

    /// Build the §5.2 scenario table for the current assignments. Call this
    /// off the failure path (the paper runs it in the background after every
    /// reconfiguration); subsequent replans are O(1) table commits until the
    /// assignments change again.
    pub fn precompute_plans(&mut self) {
        if self.tasks.is_empty() {
            self.lookup = None;
            self.lookup_inputs = None;
            return;
        }
        let ordered: Vec<PlanTask> = self.tasks.values().cloned().collect();
        self.lookup =
            Some(ScenarioLookup::precompute(&ordered, self.capacity_ceiling(), &self.cost));
        self.lookup_inputs = Some(HorizonInputs::capture(&ordered, &self.cost));
        self.stale_lookup = None;
    }

    /// Precompute only the *event horizon* — the scenarios one event away
    /// from the current state (see
    /// [`ScenarioLookup::precompute_horizon`]): at most m+3 solves instead
    /// of the full grid's (m+1)·(n+1). Cheap enough to run synchronously
    /// after every decision; the simulator's Unicron policy does exactly
    /// that, so simulated SEV1 replans take the same table path production
    /// does.
    ///
    /// Incremental (tentpole, DESIGN.md §12): the refresh delta-reuses rows
    /// from the previous table — the live one if it merely stopped covering
    /// the horizon (a membership shift with unmoved assignments), or the
    /// retired [`Coordinator::stale_lookup`] donor otherwise. Reuse is gated
    /// on bit-equal solve inputs, so the result is exactly what a
    /// from-scratch [`ScenarioLookup::precompute_horizon`] would build.
    pub fn precompute_event_plans(&mut self) {
        if self.tasks.is_empty() {
            self.lookup = None;
            self.lookup_inputs = None;
            self.stale_lookup = None;
            return;
        }
        let ordered: Vec<PlanTask> = self.tasks.values().cloned().collect();
        let prev = match (self.lookup_inputs.take(), self.lookup.take()) {
            (Some(inputs), Some(table)) => Some((inputs, table)),
            _ => self.stale_lookup.take(),
        };
        let (lookup, stats) = ScenarioLookup::refresh_horizon(
            &ordered,
            self.available_workers,
            self.gpus_per_node,
            &self.cost,
            prev.as_ref().map(|(inputs, table)| (inputs, table)),
        );
        self.note_refresh_stats(&stats);
        self.lookup = Some(lookup);
        self.lookup_inputs = Some(HorizonInputs::capture(&ordered, &self.cost));
        self.stale_lookup = None;
    }

    /// Snapshot the inputs for a *background* scenario-table rebuild — the
    /// paper's "proactive plan generation" without blocking the event loop.
    /// Returns `None` when there is nothing to do (no tasks, or the table is
    /// already fresh). Compute the job anywhere (typically a worker thread)
    /// and hand the result back through [`Coordinator::install_lookup`].
    pub fn plan_refresh_job(&self) -> Option<PlanRefreshJob> {
        if self.tasks.is_empty() || self.lookup_is_fresh() {
            return None;
        }
        Some(PlanRefreshJob {
            tasks: self.tasks.values().cloned().collect(),
            available: self.available_workers,
            gpus_per_node: self.gpus_per_node,
            cost: self.cost.clone(),
            epoch: self.plan_epoch,
            prev: self.stale_lookup.clone(),
        })
    }

    /// Install a background-computed table. Returns `false` (dropping the
    /// table) if the assignments or task set changed since the job was
    /// snapshotted — a stale table must never serve a replan. On a matching
    /// epoch the coordinator's state is exactly the job's snapshot (any
    /// change bumps the epoch), so the inputs are recaptured from `self`.
    pub fn install_lookup(&mut self, epoch: u64, lookup: ScenarioLookup) -> bool {
        if epoch != self.plan_epoch {
            return false;
        }
        let ordered: Vec<PlanTask> = self.tasks.values().cloned().collect();
        self.lookup_inputs = Some(HorizonInputs::capture(&ordered, &self.cost));
        self.lookup = Some(lookup);
        self.stale_lookup = None;
        true
    }

    /// True if the next replan will be served from the precomputed table:
    /// the table matches the current task set and covers a no-fault replan
    /// at the current pool size. Coverage is exact per scenario key — an
    /// event-horizon table answers only the states one event away, and a
    /// pool size it never solved for falls back to a live solve rather than
    /// silently clamping. Either way a hit is bit-identical to a live
    /// solve, so the freshness check is purely a fast-path gate.
    pub fn lookup_is_fresh(&self) -> bool {
        self.lookup.as_ref().is_some_and(|l| {
            l.n_tasks() == self.tasks.len() && l.covers(None, self.available_workers)
        })
    }

    /// True once at least one task is registered for planning.
    pub fn has_tasks(&self) -> bool {
        !self.tasks.is_empty()
    }

    pub fn task_assignment(&self, task: TaskId) -> Option<WorkerCount> {
        self.tasks.get(&task).map(|t| t.current)
    }

    pub fn tasks(&self) -> impl Iterator<Item = &PlanTask> {
        self.tasks.values()
    }

    /// Total WAF of the current assignments (cluster health metric).
    pub fn current_waf(&self) -> f64 {
        self.tasks.values().map(|t| t.waf(t.current.0)).sum()
    }

    /// The cost ledger the coordinator currently prices decisions with.
    pub fn cost_model(&self) -> &CostModel {
        &self.cost
    }

    /// The observability subsystem: instrument registry, decision spans,
    /// incident timeline, structured log (DESIGN.md §14).
    pub fn telemetry(&self) -> &Telemetry {
        &self.telemetry
    }

    /// Mutable telemetry access (instrument registration, driver wiring).
    pub fn telemetry_mut(&mut self) -> &mut Telemetry {
        &mut self.telemetry
    }

    /// Replans served from the precomputed table (the §5.2 hot path).
    pub fn lookup_hits(&self) -> u64 {
        self.telemetry.registry().counter_value(self.metrics.lookup_hits)
    }

    /// Replans that fell back to a fresh DP solve.
    pub fn solve_calls(&self) -> u64 {
        self.telemetry.registry().counter_value(self.metrics.solve_calls)
    }

    /// Table rows copied from a retired table by the delta refresh
    /// (observability: the incremental-solving win).
    pub fn lookup_rows_reused(&self) -> u64 {
        self.telemetry.registry().counter_value(self.metrics.rows_reused)
    }

    /// Table rows the delta refresh actually re-solved.
    pub fn lookup_rows_solved(&self) -> u64 {
        self.telemetry.registry().counter_value(self.metrics.rows_solved)
    }

    /// Fold a table refresh's row accounting into the registry — the
    /// synchronous [`Coordinator::precompute_event_plans`] path does this
    /// itself; the live driver calls it when a background
    /// [`PlanRefreshJob`] lands.
    pub fn note_refresh_stats(&self, stats: &RefreshStats) {
        self.telemetry.inc(self.metrics.rows_reused, stats.reused as u64);
        self.telemetry.inc(self.metrics.rows_solved, stats.solved as u64);
    }

    /// The authoritative cluster map: which concrete nodes serve each task
    /// (empty until the first plan commits).
    pub fn layout(&self) -> &Layout {
        &self.layout
    }

    /// The concrete placeable node set (ascending): healthy nodes the next
    /// layout can use — quarantined, isolated, and released nodes excluded.
    pub fn placeable_nodes(&self) -> Vec<NodeId> {
        self.placeable.iter().copied().collect()
    }

    /// Process one event with no new clock information: delivered at the
    /// last seen timestamp, so time-fed estimators see a zero gap and stay
    /// put. Clockless unit tests and tools use this; real drivers call
    /// [`Coordinator::handle_at`].
    pub fn handle(&mut self, event: CoordEvent) -> Vec<Action> {
        let at = self.last_at_s;
        self.handle_at(event, at)
    }

    /// Process one event delivered at `at_s` on the driver's clock;
    /// returns the actions (also appended to `log` with the timestamp).
    ///
    /// The timestamp is observed *after* the decision: the plan committed
    /// for event k is priced with the MTBF estimate as of events < k, which
    /// is exactly what any table precomputed between k−1 and k was priced
    /// with — table hits and live solves stay bit-identical. The estimate
    /// (and therefore the ledger's horizon) tightens for the *next*
    /// decision, and the stale table is invalidated.
    pub fn handle_at(&mut self, event: CoordEvent, at_s: f64) -> Vec<Action> {
        self.telemetry.inc(self.metrics.events, 1);
        self.telemetry.span_begin(event.label(), at_s);
        self.fleet.tick(); // the fleet's event clock (lemon-score decay)
        let actions = self.apply_event(&event, at_s);
        if at_s > self.last_at_s {
            self.last_at_s = at_s;
        }
        // Observe-only: the span and timeline read the decision, never feed
        // it — `tests/telemetry_replay.rs` pins tracing-on ≡ tracing-off.
        let span = self.telemetry.span_end(self.plan_epoch, actions.len());
        self.telemetry.timeline_record(at_s, &event, &actions, span.as_ref());
        self.log.record(at_s, event, actions.clone());
        actions
    }

    /// Classify + dispatch + estimator feed for one event — everything
    /// [`Coordinator::handle_at`] does except the fleet tick, the clock
    /// update, and the audit record. A [`CoordEvent::Batch`] runs this once
    /// per member but ticks, records, and replans exactly once.
    fn apply_event(&mut self, event: &CoordEvent, at_s: f64) -> Vec<Action> {
        // Classify *before* dispatch: dispatch itself isolates the node, so
        // whether this report is fresh or a duplicate about an
        // already-fenced node must be decided up front.
        self.telemetry.phase_begin(Phase::Detect);
        let observation = self.classify_observation(event);
        self.telemetry.phase_end(Phase::Detect);
        let actions = self.dispatch(event, at_s);
        self.telemetry.phase_begin(Phase::Price);
        if let Some((node, plan_ending)) = observation {
            // per-node inter-failure estimate (fleet-health observability)
            self.fleet.observe_failure_time(node, at_s);
            // the cluster-wide estimate prices the D_running horizon: only
            // plan-ending (SEV1-class) failures end a plan's run, so only
            // they are samples of it — a recoverable SEV2/SEV3 handled in
            // place must not drag the horizon down
            if plan_ending
                && self.fleet.observe_cluster_failure(at_s, self.available_workers.max(1))
            {
                let est = self.fleet.mtbf_per_gpu_estimate_s();
                self.telemetry.observe_gauge(self.metrics.mtbf_gauge, est);
                if self.cost.set_mtbf_per_gpu_s(est) {
                    self.invalidate_lookup(); // plans priced with the old horizon
                }
            }
        }
        self.telemetry.phase_end(Phase::Price);
        actions
    }

    /// Is this event a *fresh* failure observation, and does it end a plan
    /// (SEV1-class)? Duplicate reports about nodes already fenced are not
    /// observations — one physical failure must sample the MTBF estimators
    /// exactly once.
    fn classify_observation(&self, event: &CoordEvent) -> Option<(NodeId, bool)> {
        let (node, sev) = match *event {
            CoordEvent::ErrorReport { node, kind, .. } => (node, kind.severity()),
            CoordEvent::NodeLost { node } => (node, Severity::Sev1),
            _ => return None,
        };
        if self.isolated.contains(&node) || self.quarantined.contains(&node) {
            return None;
        }
        Some((node, sev == Severity::Sev1))
    }

    fn dispatch(&mut self, event: &CoordEvent, at_s: f64) -> Vec<Action> {
        match *event {
            CoordEvent::ErrorReport { node, task, kind } => {
                if self.quarantined.contains(&node) {
                    return vec![]; // fenced for good; stale report
                }
                let sev = kind.severity();
                self.fleet.note_failure(node, sev);
                match sev {
                    // the fleet is consulted on every SEV2/SEV3: a lemon is
                    // fenced *now*, before its next failure, instead of
                    // being reattempted/restarted yet again
                    Severity::Sev3 => self
                        .maybe_quarantine(node, Some(task))
                        .unwrap_or_else(|| self.on_sev3(node, task, at_s)),
                    Severity::Sev2 => self
                        .maybe_quarantine(node, Some(task))
                        .unwrap_or_else(|| self.on_sev2(node, task, at_s)),
                    Severity::Sev1 => self.on_sev1(node, Some(task), at_s),
                }
            }
            CoordEvent::NodeLost { node } => {
                if self.quarantined.contains(&node) {
                    return vec![];
                }
                self.fleet.note_failure(node, Severity::Sev1);
                self.on_sev1(node, None, at_s)
            }
            CoordEvent::NodeJoined { node } => {
                // quarantine is permanent: a fenced lemon's agent
                // re-registering (reboot, supervisor restart) must not
                // silently re-admit it
                if self.quarantined.contains(&node) {
                    return vec![];
                }
                // already serving (e.g. retained via NodeRepaired and now
                // its agent registered): don't double-count its capacity
                if self.pooled.contains(&node) {
                    return vec![];
                }
                self.isolated.retain(|&n| n != node);
                self.released.retain(|&n| n != node);
                self.pooled.push(node);
                self.placeable.insert(node);
                self.fleet.note_join(node);
                self.available_workers += self.gpus_per_node;
                self.peak_workers = self.peak_workers.max(self.available_workers);
                self.reconfigure(PlanReason::NodeJoined, None)
            }
            CoordEvent::NodeRepaired { node } => self.on_repaired(node),
            CoordEvent::TaskFinished { task } => {
                self.tasks.remove(&task);
                self.invalidate_lookup(); // task set changed
                self.reconfigure(PlanReason::TaskFinished, None)
            }
            CoordEvent::TaskLaunched { .. } => {
                // caller adds the PlanTask via add_task before this event
                self.reconfigure(PlanReason::TaskLaunched, None)
            }
            CoordEvent::ReattemptResult { node, task, ok } => {
                if ok {
                    self.escalations.remove(&(task, node));
                    vec![]
                } else {
                    // §4.2: failed reattempt upgrades SEV3 -> SEV2
                    self.on_sev2(node, task, at_s)
                }
            }
            CoordEvent::RestartResult { node, task, ok } => {
                if ok {
                    self.escalations.remove(&(task, node));
                    vec![]
                } else {
                    // §4.2: failed restart upgrades SEV2 -> SEV1
                    self.on_sev1(node, Some(task), at_s)
                }
            }
            CoordEvent::ReplanDue => {
                // the burst-batch timer fired: commit the consolidated
                // replan if it is still owed (an intervening replan may
                // have drained it already — then this is a stale no-op)
                if self.deferred_faults.is_some() {
                    self.reconfigure(PlanReason::Sev1Failure, None)
                } else {
                    vec![]
                }
            }
            CoordEvent::StateResidency { task, source, restore_s } => {
                // Snapshot-store bookkeeping (wire v6): remember where this
                // task restores from (and how fast) if it faults. No actions
                // result, but any precomputed fault row was priced with the
                // old tier, so the table must go stale on a change.
                let restore = Some(restore_s);
                let changed = match self.tasks.get_mut(&task) {
                    Some(t) if t.fault_source != source || t.fault_restore_s != restore => {
                        t.fault_source = source;
                        t.fault_restore_s = restore;
                        true
                    }
                    _ => false,
                };
                if changed {
                    self.invalidate_lookup();
                }
                vec![]
            }
            CoordEvent::StepTiming { node, task, duration_s } => {
                // In-band per-step sample (wire v8): feed the node's
                // streaming baseline; a sustained out-of-band run produces
                // a verdict here, everything else is silent bookkeeping.
                // Fenced nodes and disabled detection are no-ops — the
                // sample is still recorded in the log, so replays agree.
                if !self.cfg.degradation_detection
                    || self.isolated.contains(&node)
                    || self.quarantined.contains(&node)
                {
                    return vec![];
                }
                match self.health.observe_step(node, duration_s) {
                    Some((kind, slow_frac)) => self.on_degraded(node, task, kind, slow_frac),
                    None => vec![],
                }
            }
            CoordEvent::NodeDegraded { node, task, kind, slow_frac } => {
                // External degradation verdict (a provider preemption
                // notice, an out-of-band prober): same path as an internal
                // one, same gating.
                if !self.cfg.degradation_detection
                    || self.isolated.contains(&node)
                    || self.quarantined.contains(&node)
                {
                    return vec![];
                }
                self.on_degraded(node, task, kind, slow_frac)
            }
            CoordEvent::Batch(ref events) => {
                // N simultaneous events, ONE dispatch/replan cycle
                // (tentpole, generalizing the PR-4 same-domain batch):
                // every member is applied with replans deferred; when the
                // outermost batch closes, the owed debt commits one
                // consolidated plan for the merged state. Spare terms of a
                // retention inside a batch do not ride a per-event plan
                // (that plan is suppressed) — the consolidated breakdown
                // prices the merged state instead. Drivers must only batch
                // events whose tasks are already registered:
                // [`DecisionLog::replay`] re-admits tasks for *top-level*
                // `TaskLaunched` entries only.
                self.batch_depth += 1;
                self.telemetry.inc(self.metrics.batch_members, events.len() as u64);
                let mut actions = Vec::new();
                for ev in events {
                    actions.extend(self.apply_event(ev, at_s));
                }
                self.batch_depth -= 1;
                if self.batch_depth == 0 {
                    if let Some(reason) = self.batch_replan.take() {
                        actions.extend(self.reconfigure(reason, None));
                    }
                }
                actions
            }
        }
    }

    /// One degradation verdict about `node` (running `task`): fold it into
    /// the fleet's degradation score, then let the ledger decide
    /// evict-vs-tolerate ([`CostModel::degradation_decision`]). An eviction
    /// has the same capacity mechanics as a SEV1 isolation — the node goes
    /// to maintenance and a repair can return it — plus the degradation
    /// detection-latency penalty stamped onto the replan's breakdown.
    fn on_degraded(
        &mut self,
        node: NodeId,
        task: TaskId,
        kind: DegradationKind,
        slow_frac: f64,
    ) -> Vec<Action> {
        self.fleet.note_degradation(node, slow_frac);
        if kind == DegradationKind::ChurnRisk {
            // a churn forecast is not a measured slowdown: it informs the
            // fleet history (degradation score, hazard column) but evicting
            // a healthy node on a prophecy is never a ledger win
            return vec![];
        }
        self.telemetry.phase_begin(Phase::Price);
        let task_waf = self.tasks.get(&task).map_or(0.0, |t| t.waf(t.current.0));
        let node_waf = self.cost.marginal_node_waf(
            self.current_waf(),
            self.available_workers.max(1),
            self.gpus_per_node,
        );
        let transition_s = self
            .tasks
            .get(&task)
            .map_or(self.cost.transition_base_s(), |t| self.cost.transition_s(&t.profile, true));
        let evict = self.cost.degradation_decision(
            slow_frac,
            task_waf,
            node_waf,
            self.available_workers.max(1),
            transition_s,
        );
        self.telemetry.phase_end(Phase::Price);
        if !evict {
            return vec![]; // tolerating the slowdown is the cheaper side
        }
        self.health.forget(node); // a repaired node starts a fresh baseline
        self.isolated.push(node);
        self.pooled.retain(|&n| n != node);
        self.placeable.remove(&node);
        self.available_workers = self.available_workers.saturating_sub(self.gpus_per_node);
        self.pending_degradation = Some(slow_frac * task_waf * self.cost.degradation_s());
        let mut actions = vec![
            Action::IsolateNode { node },
            Action::AlertOps {
                message: format!(
                    "DEGRADED: node {node} {} (running {:.0}% slow); evicting",
                    kind.name(),
                    slow_frac * 100.0
                ),
            },
        ];
        actions.extend(self.reconfigure(PlanReason::Sev1Failure, Some(task)));
        actions
    }

    fn on_sev3(&mut self, node: NodeId, task: TaskId, at_s: f64) -> Vec<Action> {
        let esc = self.escalations.entry((task, node)).or_default();
        if esc.reattempts < self.cfg.max_reattempts {
            esc.reattempts += 1;
            vec![Action::InstructReattempt { node, task }]
        } else {
            self.on_sev2(node, task, at_s)
        }
    }

    fn on_sev2(&mut self, node: NodeId, task: TaskId, at_s: f64) -> Vec<Action> {
        let esc = self.escalations.entry((task, node)).or_default();
        if esc.restarts < self.cfg.max_restarts {
            esc.restarts += 1;
            vec![Action::InstructRestart { node, task }]
        } else {
            self.on_sev1(node, Some(task), at_s)
        }
    }

    /// Fleet gate, consulted on every SEV2/SEV3 report (after the failure is
    /// noted): a node whose decayed recurrence score crossed the lemon
    /// threshold is fenced *before* it fails again. Same capacity effect as
    /// a SEV1 isolation, but permanent — no repair returns the node.
    fn maybe_quarantine(&mut self, node: NodeId, task: Option<TaskId>) -> Option<Vec<Action>> {
        if !self.cfg.lemon_quarantine || !self.fleet.is_lemon(node) {
            return None;
        }
        self.quarantined.push(node);
        self.fleet.note_quarantine(node);
        self.pooled.retain(|&n| n != node);
        self.placeable.remove(&node);
        let was_isolated = self.isolated.contains(&node);
        self.isolated.retain(|&n| n != node);
        if !was_isolated {
            self.available_workers = self.available_workers.saturating_sub(self.gpus_per_node);
        }
        let mut actions = vec![Action::NodeQuarantined { node }];
        actions.extend(self.reconfigure(PlanReason::Sev1Failure, task));
        Some(actions)
    }

    /// Trigger for [`CoordEvent::NodeRepaired`]: maintenance finished — the
    /// fleet layer decides the node's fate. Lemons are quarantined instead
    /// of re-admitted; otherwise the [`crate::fleet::SparePool`] prices retaining the node
    /// against releasing it (restoring lost capacity is always retained).
    fn on_repaired(&mut self, node: NodeId) -> Vec<Action> {
        if self.quarantined.contains(&node) || self.released.contains(&node) {
            return vec![]; // already out of the fleet
        }
        if self.pooled.contains(&node) {
            return vec![]; // already serving: duplicate repair announcement
        }
        self.fleet.note_repair(node);
        if self.cfg.lemon_quarantine && self.fleet.is_lemon(node) {
            // the repair fixed the symptom, not the node: refuse readmission
            self.quarantined.push(node);
            self.fleet.note_quarantine(node);
            self.isolated.retain(|&n| n != node);
            self.placeable.remove(&node);
            return vec![Action::NodeQuarantined { node }];
        }
        self.telemetry.phase_begin(Phase::Price);
        let decision = self.spare_decision();
        self.telemetry.phase_end(Phase::Price);
        match decision {
            (SpareDecision::Retain, terms) => {
                self.isolated.retain(|&n| n != node);
                self.pooled.push(node);
                self.placeable.insert(node);
                self.fleet.note_join(node);
                self.available_workers += self.gpus_per_node;
                let mut actions = vec![Action::SpareRetained { node }];
                let mut replans = self.reconfigure(PlanReason::NodeJoined, None);
                // the retention's spare terms ride the plan's breakdown, so
                // the decision log explains retain-vs-release in the same
                // currency as the plan objective
                if let (Some(t), Some(Action::ApplyPlan { plan, .. })) =
                    (terms, replans.last_mut())
                {
                    plan.breakdown.spare_value = t.value;
                    plan.breakdown.spare_hold_cost = t.hold_cost;
                }
                actions.extend(replans);
                actions
            }
            (SpareDecision::Release, _) => {
                self.isolated.retain(|&n| n != node);
                self.released.push(node);
                self.placeable.remove(&node);
                self.fleet.note_release(node);
                vec![Action::SpareReleased { node }]
            }
        }
    }

    /// The spare-pool verdict for one repaired node, priced by the cost
    /// ledger in the planner's WAF currency: below the entitled peak the
    /// node is restoring lost capacity (always retain, nothing priced); at
    /// or above it, [`CostModel::spare_decision`] weighs the Poisson-tail
    /// shortfall value of the `(held+1)`-th spare against its holding cost,
    /// using the same effective MTBF the planner's horizon uses.
    ///
    /// Every input is a pure function of coordinator state plus the
    /// recorded event/timestamp stream, so recorded decisions replay
    /// bit-identically.
    fn spare_decision(&self) -> (SpareDecision, Option<SpareTerms>) {
        if self.available_workers < self.peak_workers {
            return (SpareDecision::Retain, None);
        }
        let gpn = self.gpus_per_node.max(1);
        let held = (self.available_workers - self.peak_workers) / gpn;
        let (decision, terms) =
            self.cost.spare_decision(held, self.available_workers, self.current_waf(), gpn);
        (decision, Some(terms))
    }

    fn on_sev1(&mut self, node: NodeId, task: Option<TaskId>, at_s: f64) -> Vec<Action> {
        if self.isolated.contains(&node) || self.quarantined.contains(&node) {
            return vec![]; // already fenced; duplicate report
        }
        self.isolated.push(node);
        self.pooled.retain(|&n| n != node);
        self.placeable.remove(&node);
        self.available_workers = self.available_workers.saturating_sub(self.gpus_per_node);
        let mut actions = vec![
            Action::IsolateNode { node },
            Action::AlertOps { message: format!("SEV1: node {node} isolated; maintenance required") },
        ];
        // Correlated-burst batching (ROADMAP fleet follow-up): when this
        // SEV1 looks like a continuation of a same-domain burst — the
        // domain's failure pressure is elevated AND a *different* node in
        // the domain went down within the batch window — defer the replan
        // and ask the driver for a ReplanDue wake-up instead, so the whole
        // burst costs one consolidated transition instead of N.
        let domain = self.fleet.domain_of(node);
        let burst = self.cfg.domain_batch_window_s > 0.0
            && self.fleet.domain_pressure(domain) >= self.cfg.domain_batch_pressure
            && self.last_domain_sev1.get(&domain).is_some_and(|&(prev, prev_at)| {
                prev != node && at_s - prev_at <= self.cfg.domain_batch_window_s
            });
        self.last_domain_sev1.insert(domain, (node, at_s));
        if burst {
            let faults = self.deferred_faults.get_or_insert_with(Vec::new);
            if let Some(t) = task {
                if !faults.contains(&t) {
                    faults.push(t);
                }
            }
            actions.push(Action::ScheduleReplan { after_s: self.cfg.domain_batch_window_s });
        } else {
            actions.extend(self.reconfigure(PlanReason::Sev1Failure, task));
        }
        actions
    }

    /// Cost-aware plan generation (§5) + bookkeeping of the new assignments.
    ///
    /// Served from the precomputed [`ScenarioLookup`] when it is fresh (an
    /// O(1) table commit — the §5.2 hot path), falling back to a live DP
    /// [`solve`] otherwise. Both paths produce the identical plan for the
    /// same state; `coordinator::tests::lookup_path_is_equivalent` holds
    /// them to that.
    ///
    /// Any deferred burst faults are drained into this replan — a committed
    /// plan always settles everything owed, whether it was triggered by the
    /// [`CoordEvent::ReplanDue`] timer or by an unrelated event.
    fn reconfigure(&mut self, reason: PlanReason, faulted_task: Option<TaskId>) -> Vec<Action> {
        if self.batch_depth > 0 {
            // inside a CoordEvent::Batch: note the debt (the fault and the
            // latest reason) and let the closing batch commit one
            // consolidated plan for the merged state
            let faults = self.deferred_faults.get_or_insert_with(Vec::new);
            if let Some(t) = faulted_task {
                if !faults.contains(&t) {
                    faults.push(t);
                }
            }
            self.batch_replan = Some(reason);
            return vec![];
        }
        let mut faults: Vec<TaskId> = self.deferred_faults.take().unwrap_or_default();
        if let Some(t) = faulted_task {
            if !faults.contains(&t) {
                faults.push(t);
            }
        }
        if self.tasks.is_empty() {
            self.layout = Layout::default(); // nothing left to place
            return vec![];
        }
        // map faulted task ids to positions in id-ordered iteration
        let fault_indices: Vec<usize> = faults
            .iter()
            .filter_map(|t| self.tasks.keys().position(|&k| k == *t))
            .collect();
        // the table covers single-fault scenarios; a multi-fault burst
        // replan always re-solves live
        let single_fault = match fault_indices[..] {
            [] => Some(None),
            [i] => Some(Some(i)),
            _ => None,
        };
        // the table serves the replan only on an *exact* scenario hit (full
        // grids cover everything in range; event-horizon tables exactly the
        // one-event-away scenarios) — anything else re-solves live. Both
        // paths produce bit-identical plans for the same state.
        self.telemetry.phase_begin(Phase::Lookup);
        let precomputed = match single_fault {
            Some(fault_idx) if self.lookup_is_fresh() => self
                .lookup
                .as_ref()
                .and_then(|l| l.get(fault_idx, self.available_workers))
                .cloned(),
            _ => None,
        };
        self.telemetry.phase_end(Phase::Lookup);
        let lookup_hit = precomputed.is_some();
        let mut plan = match precomputed {
            Some(plan) => {
                self.telemetry.inc(self.metrics.lookup_hits, 1);
                plan
            }
            None => {
                self.telemetry.inc(self.metrics.solve_calls, 1);
                self.telemetry.phase_begin(Phase::Solve);
                let mut ordered: Vec<PlanTask> = self.tasks.values().cloned().collect();
                for &i in &fault_indices {
                    ordered[i].fault = true;
                }
                let plan = solve(&ordered, self.available_workers, &self.cost);
                self.telemetry.phase_end(Phase::Solve);
                plan
            }
        };
        // A degradation eviction owes its detection-latency penalty to the
        // plan that settles it. Stamped *after* plan selection (identically
        // on the table and solve paths), so lookup hits stay bit-identical
        // to live solves and the breakdown still reconciles.
        if let Some(dp) = self.pending_degradation.take() {
            plan.breakdown.degradation_penalty = dp;
            plan.objective -= dp;
        }
        // Placement: turn the plan's counts into the concrete cluster map.
        // Both the table and the solver leave `plan.layout` empty, and the
        // assignment solver reads only (previous layout, counts, placeable
        // nodes) — so a table commit and a live solve produce bit-identical
        // layouts for the same state.
        self.telemetry.phase_begin(Phase::Place);
        let demands: Vec<(TaskId, u32)> =
            self.tasks.keys().copied().zip(plan.assignment.iter().copied()).collect();
        let nodes = self.placeable_nodes();
        let view = ClusterView {
            nodes: &nodes,
            gpus_per_node: self.gpus_per_node,
            nodes_per_domain: self.cfg.nodes_per_domain.max(1),
        };
        let layout = if self.cfg.placement_min_churn {
            // warm-started: the carried free-node map makes the incremental
            // solve touch only what changed, with a result bit-identical to
            // from-scratch `assign` (see placement::assign_cached)
            placement::assign_cached(&mut self.place_cache, &self.layout, &demands, &view)
        } else {
            placement::assign_blind(&demands, &view)
        };
        self.layout = layout.clone();
        plan.layout = layout;
        self.telemetry.phase_end(Phase::Place);
        // commit the new assignments; clear fault flags (handled). The
        // precomputed table remains valid only if nothing actually moved.
        let mut changed = false;
        for (pt, &x) in self.tasks.values_mut().zip(plan.assignment.iter()) {
            changed |= pt.current.0 != x;
            pt.current = WorkerCount(x);
            pt.fault = false;
        }
        if changed {
            self.invalidate_lookup();
        }
        self.telemetry.inc(self.metrics.replans, 1);
        self.telemetry.note_plan(SpanPlan {
            reason: reason.name(),
            objective: plan.objective,
            running_reward: plan.breakdown.running_reward,
            transition_penalty: plan.breakdown.transition_penalty,
            detection_penalty: plan.breakdown.detection_penalty,
            degradation_penalty: plan.breakdown.degradation_penalty,
            state_source: plan.breakdown.state_source.name(),
            workers_used: plan.workers_used,
            transition_s: plan.transition_seconds(),
            lookup_hit,
        });
        vec![Action::ApplyPlan { plan, reason }]
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::TaskSpec;
    use crate::cost::TransitionProfile;
    use crate::failure::ErrorKind;

    fn plan_task(id: u32, min: u32, current: u32, n: u32) -> PlanTask {
        let throughput =
            (0..=n).map(|x| if x >= min { 1e12 * (x as f64).powf(0.9) } else { 0.0 }).collect();
        PlanTask {
            spec: TaskSpec::new(id, "m", 1.0, min),
            throughput,
            profile: TransitionProfile::flat(5.0),
            current: WorkerCount(current),
            fault: false,
            fault_source: crate::transition::StateSource::InMemoryCheckpoint,
            fault_restore_s: None,
        }
    }

    fn coord(workers: u32) -> Coordinator {
        Coordinator::builder()
            .workers(workers)
            .gpus_per_node(8u32)
            .task(plan_task(0, 2, workers / 2, workers + 16))
            .task(plan_task(1, 2, workers / 2, workers + 16))
            .build()
    }

    #[test]
    fn sev3_reattempts_then_escalates() {
        let mut c = coord(32);
        // three reattempts allowed
        for i in 0..3 {
            let a = c.handle(CoordEvent::ErrorReport {
                node: NodeId(1),
                task: TaskId(0),
                kind: ErrorKind::ConnectionRefused,
            });
            assert_eq!(
                a,
                vec![Action::InstructReattempt { node: NodeId(1), task: TaskId(0) }],
                "attempt {i}"
            );
        }
        // fourth SEV3 -> restart (SEV2 path)
        let a = c.handle(CoordEvent::ErrorReport {
            node: NodeId(1),
            task: TaskId(0),
            kind: ErrorKind::ConnectionRefused,
        });
        assert_eq!(a, vec![Action::InstructRestart { node: NodeId(1), task: TaskId(0) }]);
    }

    #[test]
    fn reattempt_success_resets_budget() {
        let mut c = coord(32);
        for _ in 0..3 {
            c.handle(CoordEvent::ErrorReport {
                node: NodeId(1),
                task: TaskId(0),
                kind: ErrorKind::LinkFlapping,
            });
        }
        c.handle(CoordEvent::ReattemptResult { node: NodeId(1), task: TaskId(0), ok: true });
        let a = c.handle(CoordEvent::ErrorReport {
            node: NodeId(1),
            task: TaskId(0),
            kind: ErrorKind::LinkFlapping,
        });
        assert_eq!(a, vec![Action::InstructReattempt { node: NodeId(1), task: TaskId(0) }]);
    }

    #[test]
    fn sev2_restarts_then_escalates_to_sev1() {
        let mut c = coord(32);
        let a = c.handle(CoordEvent::ErrorReport {
            node: NodeId(2),
            task: TaskId(1),
            kind: ErrorKind::CudaError,
        });
        assert_eq!(a, vec![Action::InstructRestart { node: NodeId(2), task: TaskId(1) }]);
        // restart failed -> SEV1: isolate + alert + replan
        let a = c.handle(CoordEvent::RestartResult { node: NodeId(2), task: TaskId(1), ok: false });
        assert!(matches!(a[0], Action::IsolateNode { node: NodeId(2) }));
        assert!(matches!(a[1], Action::AlertOps { .. }));
        assert!(matches!(a[2], Action::ApplyPlan { .. }));
        assert_eq!(c.available_workers(), WorkerCount(24));
        assert_eq!(c.isolated, vec![NodeId(2)]);
    }

    #[test]
    fn sev1_reconfigures_within_reduced_capacity() {
        let mut c = coord(32);
        let a = c.handle(CoordEvent::ErrorReport {
            node: NodeId(0),
            task: TaskId(0),
            kind: ErrorKind::EccError,
        });
        let plan = a
            .iter()
            .find_map(|x| match x {
                Action::ApplyPlan { plan, .. } => Some(plan.clone()),
                _ => None,
            })
            .expect("SEV1 must replan");
        assert!(plan.workers_used <= 24);
        // assignments were committed
        let total: u32 =
            (0..=1).map(|t| c.task_assignment(TaskId(t)).unwrap().0).sum();
        assert!(total <= 24);
    }

    #[test]
    fn duplicate_sev1_for_same_node_is_idempotent() {
        let mut c = coord(32);
        c.handle(CoordEvent::NodeLost { node: NodeId(3) });
        let before = c.available_workers();
        let a = c.handle(CoordEvent::NodeLost { node: NodeId(3) });
        assert!(a.is_empty());
        assert_eq!(c.available_workers(), before);
    }

    #[test]
    fn node_join_triggers_reconfiguration() {
        let mut c = coord(32);
        c.handle(CoordEvent::NodeLost { node: NodeId(1) });
        assert_eq!(c.available_workers(), WorkerCount(24));
        let a = c.handle(CoordEvent::NodeJoined { node: NodeId(1) });
        assert_eq!(c.available_workers(), WorkerCount(32));
        assert!(c.isolated.is_empty());
        assert!(matches!(a[0], Action::ApplyPlan { reason: PlanReason::NodeJoined, .. }));
    }

    #[test]
    fn task_lifecycle_triggers_reconfiguration() {
        let mut c = coord(32);
        let a = c.handle(CoordEvent::TaskFinished { task: TaskId(0) });
        assert!(matches!(a[0], Action::ApplyPlan { reason: PlanReason::TaskFinished, .. }));
        assert!(c.task_assignment(TaskId(0)).is_none());
        // remaining task can now take everything useful
        c.add_task(plan_task(2, 2, 0, 48));
        let a = c.handle(CoordEvent::TaskLaunched { task: TaskId(2) });
        assert!(matches!(a[0], Action::ApplyPlan { reason: PlanReason::TaskLaunched, .. }));
        assert!(c.task_assignment(TaskId(2)).unwrap().0 > 0);
    }

    #[test]
    fn lookup_path_is_equivalent_to_solve_path() {
        // Same event storm, one coordinator precomputing between events, one
        // always solving live — the audit logs must be identical. Nodes are
        // spread across failure domains so no SEV1 reads as a correlated
        // burst (batching has its own test).
        let events = [
            CoordEvent::TaskLaunched { task: TaskId(0) },
            CoordEvent::ErrorReport { node: NodeId(1), task: TaskId(0), kind: ErrorKind::EccError },
            CoordEvent::NodeLost { node: NodeId(8) },
            CoordEvent::NodeJoined { node: NodeId(1) },
            CoordEvent::ErrorReport {
                node: NodeId(12),
                task: TaskId(1),
                kind: ErrorKind::NvlinkError,
            },
            CoordEvent::TaskFinished { task: TaskId(0) },
            CoordEvent::NodeJoined { node: NodeId(8) },
        ];
        let mut warm = coord(32);
        let mut cold = coord(32);
        for ev in &events {
            warm.precompute_plans(); // the §5.2 background step
            assert!(warm.lookup_is_fresh());
            let a = warm.handle(ev.clone());
            let b = cold.handle(ev.clone());
            assert_eq!(a, b, "divergence at {ev:?}");
        }
        assert_eq!(warm.log, cold.log);
        assert!(warm.lookup_hits() >= 6, "replans should hit the table: {}", warm.lookup_hits());
        // the one allowed miss: TaskFinished shrinks the task set between the
        // precompute and the replan, so that replan must re-solve
        assert!(warm.solve_calls() <= 1, "unexpected hot-path solves: {}", warm.solve_calls());
        assert!(cold.lookup_hits() == 0 && cold.solve_calls() > 0);
    }

    #[test]
    fn lookup_invalidates_when_assignments_move() {
        let mut c = coord(32);
        c.precompute_plans();
        assert!(c.lookup_is_fresh());
        // a SEV1 shrinks the pool and moves workers: the table must go stale
        c.handle(CoordEvent::NodeLost { node: NodeId(0) });
        assert!(!c.lookup_is_fresh(), "stale table must not survive a commit");
        // adding a task also invalidates
        c.precompute_plans();
        assert!(c.lookup_is_fresh());
        c.add_task(plan_task(7, 2, 0, 48));
        assert!(!c.lookup_is_fresh());
    }

    #[test]
    fn waf_drops_after_sev1_and_recovers_after_join() {
        let mut c = coord(32);
        c.handle(CoordEvent::TaskLaunched { task: TaskId(99) }); // force initial plan
        let healthy = c.current_waf();
        c.handle(CoordEvent::NodeLost { node: NodeId(0) });
        let degraded = c.current_waf();
        assert!(degraded < healthy);
        c.handle(CoordEvent::NodeJoined { node: NodeId(0) });
        let recovered = c.current_waf();
        assert!(recovered >= degraded);
        assert!((recovered - healthy).abs() < 1e-6 * healthy);
    }

    #[test]
    fn background_refresh_job_rejects_stale_installs() {
        let mut c = coord(32);
        let job = c.plan_refresh_job().expect("stale table must produce a job");
        // assignments move before the job lands: the install must be rejected
        c.handle(CoordEvent::NodeLost { node: NodeId(5) });
        let (epoch, lookup, _) = job.compute();
        assert!(!c.install_lookup(epoch, lookup), "stale table must not land");
        assert!(!c.lookup_is_fresh());
        // a job snapshotted from the new state installs fine
        let (epoch, lookup, _) = c.plan_refresh_job().unwrap().compute();
        assert!(c.install_lookup(epoch, lookup));
        assert!(c.lookup_is_fresh());
        // and a fresh table means there is nothing left to rebuild
        assert!(c.plan_refresh_job().is_none());
        // the installed table serves the next replan from the hot path
        c.handle(CoordEvent::NodeJoined { node: NodeId(5) });
        assert!(c.lookup_hits() >= 1, "installed table must serve replans");
    }

    #[test]
    fn lemon_node_is_quarantined_before_it_fails_again() {
        // A node caught in a fail/restart/fail loop must eventually be
        // fenced proactively — with a NodeQuarantined + SEV1-class replan —
        // instead of being restarted forever.
        let mut c = coord(32);
        c.handle(CoordEvent::TaskLaunched { task: TaskId(0) });
        let mut quarantined_at = None;
        for cycle in 0..25 {
            let a = c.handle(CoordEvent::ErrorReport {
                node: NodeId(1),
                task: TaskId(0),
                kind: ErrorKind::CudaError,
            });
            if matches!(a.first(), Some(Action::NodeQuarantined { node: NodeId(1) })) {
                assert!(
                    a.iter().any(|x| matches!(
                        x,
                        Action::ApplyPlan { reason: PlanReason::Sev1Failure, .. }
                    )),
                    "quarantine must replan around the lost capacity: {a:?}"
                );
                quarantined_at = Some(cycle);
                break;
            }
            assert_eq!(
                a,
                vec![Action::InstructRestart { node: NodeId(1), task: TaskId(0) }],
                "cycle {cycle}"
            );
            // the restart succeeds — the classic lemon pattern
            c.handle(CoordEvent::RestartResult { node: NodeId(1), task: TaskId(0), ok: true });
        }
        let cycle = quarantined_at.expect("a recurrent failer must be quarantined");
        assert!(cycle >= 4, "one escalation chain must not look like a lemon (cycle {cycle})");
        assert!(c.quarantined.contains(&NodeId(1)));
        assert_eq!(c.available_workers(), WorkerCount(24), "quarantine costs the node's GPUs");
        // fenced for good: further reports are stale no-ops
        let a = c.handle(CoordEvent::ErrorReport {
            node: NodeId(1),
            task: TaskId(0),
            kind: ErrorKind::CudaError,
        });
        assert!(a.is_empty());
    }

    #[test]
    fn repaired_lemon_is_refused_readmission() {
        // A node cycling SEV1 -> repair -> SEV1 is a lemon too: at some
        // repair the fleet refuses to re-admit it.
        let mut c = coord(32);
        c.handle(CoordEvent::TaskLaunched { task: TaskId(0) });
        let mut refused = false;
        for _ in 0..12 {
            c.handle(CoordEvent::NodeLost { node: NodeId(2) });
            let a = c.handle(CoordEvent::NodeRepaired { node: NodeId(2) });
            match a.first() {
                Some(Action::NodeQuarantined { node: NodeId(2) }) => {
                    refused = true;
                    break;
                }
                Some(Action::SpareRetained { node: NodeId(2) }) => {
                    assert!(matches!(
                        a.get(1),
                        Some(Action::ApplyPlan { reason: PlanReason::NodeJoined, .. })
                    ));
                }
                other => panic!("unexpected repair outcome: {other:?} in {a:?}"),
            }
        }
        assert!(refused, "a recurrently SEV1-ing node must be quarantined at repair");
        assert!(c.quarantined.contains(&NodeId(2)));
        assert_eq!(c.available_workers(), WorkerCount(24), "the lemon never rejoined");
        // idempotent: another repair report changes nothing
        assert!(c.handle(CoordEvent::NodeRepaired { node: NodeId(2) }).is_empty());
        // quarantine is permanent: even the lemon's agent re-registering
        // (a membership NodeJoined) must not re-admit it
        assert!(c.handle(CoordEvent::NodeJoined { node: NodeId(2) }).is_empty());
        assert!(c.quarantined.contains(&NodeId(2)));
        assert_eq!(c.available_workers(), WorkerCount(24));
    }

    #[test]
    fn readmission_is_deduplicated_across_repair_and_join() {
        // The live flow has two re-admission paths — a repair announcement
        // and the node's agent registering with membership. One capacity
        // credit per readmission, no matter how the reports arrive or
        // repeat.
        let mut c = coord(32);
        c.handle(CoordEvent::TaskLaunched { task: TaskId(0) });
        c.handle(CoordEvent::NodeLost { node: NodeId(4) });
        assert_eq!(c.available_workers(), WorkerCount(24));
        // repair announced -> retained
        let a = c.handle(CoordEvent::NodeRepaired { node: NodeId(4) });
        assert!(matches!(a[0], Action::SpareRetained { node: NodeId(4) }));
        assert_eq!(c.available_workers(), WorkerCount(32));
        // duplicate repair announcement: no phantom capacity
        assert!(c.handle(CoordEvent::NodeRepaired { node: NodeId(4) }).is_empty());
        assert_eq!(c.available_workers(), WorkerCount(32));
        // the node's agent now registers: already pooled, not counted again
        assert!(c.handle(CoordEvent::NodeJoined { node: NodeId(4) }).is_empty());
        assert_eq!(c.available_workers(), WorkerCount(32));
        // a real new loss/readmission cycle still works
        c.handle(CoordEvent::NodeLost { node: NodeId(4) });
        assert_eq!(c.available_workers(), WorkerCount(24));
        let a = c.handle(CoordEvent::NodeJoined { node: NodeId(4) });
        assert!(matches!(a[0], Action::ApplyPlan { reason: PlanReason::NodeJoined, .. }));
        assert_eq!(c.available_workers(), WorkerCount(32));
    }

    #[test]
    fn repaired_node_below_peak_is_always_retained() {
        let mut c = coord(32);
        c.handle(CoordEvent::TaskLaunched { task: TaskId(0) });
        c.handle(CoordEvent::NodeLost { node: NodeId(3) });
        assert_eq!(c.available_workers(), WorkerCount(24));
        let a = c.handle(CoordEvent::NodeRepaired { node: NodeId(3) });
        assert!(matches!(a[0], Action::SpareRetained { node: NodeId(3) }));
        assert!(matches!(a[1], Action::ApplyPlan { reason: PlanReason::NodeJoined, .. }));
        assert_eq!(c.available_workers(), WorkerCount(32));
        assert!(c.isolated.is_empty());
    }

    #[test]
    fn surplus_spares_are_priced_not_hoarded() {
        // At full entitled capacity, retain/release follows the WAF
        // break-even: free spares are kept (up to the cap), expensive ones
        // released.
        let keepers = UnicronConfig {
            spare_hold_frac: 0.0, // free to hold
            max_spares: 1,
            ..Default::default()
        };
        let mut c = Coordinator::builder()
            .config(keepers)
            .workers(32u32)
            .gpus_per_node(8u32)
            .task(plan_task(0, 2, 16, 64))
            .task(plan_task(1, 2, 16, 64))
            .build();
        c.handle(CoordEvent::TaskLaunched { task: TaskId(0) });
        // surplus node #1: free -> retained as the first hot spare
        let a = c.handle(CoordEvent::NodeRepaired { node: NodeId(9) });
        assert!(matches!(a[0], Action::SpareRetained { node: NodeId(9) }), "{a:?}");
        assert_eq!(c.available_workers(), WorkerCount(40));
        // surplus node #2: past max_spares -> released even though free
        let a = c.handle(CoordEvent::NodeRepaired { node: NodeId(10) });
        assert_eq!(a, vec![Action::SpareReleased { node: NodeId(10) }]);
        assert_eq!(c.available_workers(), WorkerCount(40));
        assert!(c.released.contains(&NodeId(10)));

        // an expensive spare is released immediately
        let pricey = UnicronConfig { spare_hold_frac: 1.0, ..Default::default() };
        let mut c = Coordinator::builder()
            .config(pricey)
            .workers(32u32)
            .gpus_per_node(8u32)
            .task(plan_task(0, 2, 16, 64))
            .task(plan_task(1, 2, 16, 64))
            .build();
        c.handle(CoordEvent::TaskLaunched { task: TaskId(0) });
        let a = c.handle(CoordEvent::NodeRepaired { node: NodeId(9) });
        assert_eq!(a, vec![Action::SpareReleased { node: NodeId(9) }]);
        assert_eq!(c.available_workers(), WorkerCount(32));
    }

    #[test]
    fn event_horizon_table_serves_sev1_and_join_replans() {
        // The cheap per-event precompute must put SEV1/join replans on the
        // table path, with decisions identical to an always-solving twin.
        let mut warm = coord(32);
        let mut cold = coord(32);
        let events = [
            CoordEvent::TaskLaunched { task: TaskId(0) },
            CoordEvent::NodeLost { node: NodeId(1) },
            CoordEvent::ErrorReport { node: NodeId(8), task: TaskId(1), kind: ErrorKind::EccError },
            CoordEvent::NodeRepaired { node: NodeId(1) },
        ];
        for ev in &events {
            if !warm.lookup_is_fresh() {
                warm.precompute_event_plans();
            }
            let a = warm.handle(ev.clone());
            let b = cold.handle(ev.clone());
            assert_eq!(a, b, "table and solver commits diverged at {ev:?}");
        }
        assert_eq!(warm.log, cold.log);
        // the bootstrap launch solves (no table yet); everything after hits
        assert!(warm.lookup_hits() >= 3, "horizon hits: {}", warm.lookup_hits());
        assert!(warm.solve_calls() <= 1, "horizon misses: {}", warm.solve_calls());
        assert!(cold.lookup_hits() == 0 && cold.solve_calls() >= 4);
    }

    #[test]
    fn same_domain_burst_batches_replans_into_one() {
        // Three SEV1s in one failure domain inside the batch window: the
        // first replans immediately, the continuations defer with a
        // ScheduleReplan, and the ReplanDue timer commits ONE consolidated
        // plan — replan count < failure count.
        let mut c = coord(32);
        c.handle_at(CoordEvent::TaskLaunched { task: TaskId(0) }, 0.0);
        let first = c.handle_at(
            CoordEvent::ErrorReport { node: NodeId(0), task: TaskId(0), kind: ErrorKind::EccError },
            100.0,
        );
        assert!(first.iter().any(|a| matches!(a, Action::ApplyPlan { .. })), "{first:?}");
        let second = c.handle_at(
            CoordEvent::ErrorReport { node: NodeId(1), task: TaskId(1), kind: ErrorKind::EccError },
            160.0,
        );
        assert!(matches!(second[0], Action::IsolateNode { node: NodeId(1) }));
        assert!(
            second.iter().any(|a| matches!(a, Action::ScheduleReplan { .. })),
            "burst continuation must defer: {second:?}"
        );
        assert!(!second.iter().any(|a| matches!(a, Action::ApplyPlan { .. })));
        let third = c.handle_at(
            CoordEvent::ErrorReport { node: NodeId(2), task: TaskId(0), kind: ErrorKind::EccError },
            220.0,
        );
        assert!(third.iter().any(|a| matches!(a, Action::ScheduleReplan { .. })), "{third:?}");
        assert_eq!(c.available_workers(), WorkerCount(8), "capacity tracked through deferral");
        // the timer fires: one consolidated plan for the whole burst
        let flush = c.handle_at(CoordEvent::ReplanDue, 220.0 + 900.0);
        match &flush[..] {
            [Action::ApplyPlan { plan, reason: PlanReason::Sev1Failure }] => {
                assert!(plan.workers_used <= 8, "plan must fit the surviving pool");
            }
            other => panic!("expected the consolidated replan, got {other:?}"),
        }
        // a late/duplicate timer is a stale no-op
        assert!(c.handle_at(CoordEvent::ReplanDue, 2000.0).is_empty());
        // the pin: 3 SEV1 failures produced only 2 SEV1-class replans
        let sev1_replans = c
            .log
            .actions()
            .filter(|a| matches!(a, Action::ApplyPlan { reason: PlanReason::Sev1Failure, .. }))
            .count();
        assert_eq!(sev1_replans, 2);
    }

    #[test]
    fn batched_events_cost_one_replan_cycle() {
        // A CoordEvent::Batch of two simultaneous node losses: both nodes
        // are fenced, but the whole burst commits ONE consolidated plan —
        // and the batch is one recorded decision that replays bit-
        // identically.
        let mut c = coord(32);
        c.handle_at(CoordEvent::TaskLaunched { task: TaskId(0) }, 0.0);
        let a = c.handle_at(
            CoordEvent::Batch(vec![
                CoordEvent::NodeLost { node: NodeId(0) },
                CoordEvent::NodeLost { node: NodeId(2) },
            ]),
            50.0,
        );
        assert!(a.iter().any(|x| matches!(x, Action::IsolateNode { node: NodeId(0) })));
        assert!(a.iter().any(|x| matches!(x, Action::IsolateNode { node: NodeId(2) })));
        assert_eq!(c.available_workers(), WorkerCount(16));
        let plans: Vec<_> = a
            .iter()
            .filter_map(|x| match x {
                Action::ApplyPlan { plan, reason } => Some((plan, reason)),
                _ => None,
            })
            .collect();
        let (plan, reason) = match &plans[..] {
            [one] => *one,
            other => panic!("a batch must commit exactly one plan, got {}", other.len()),
        };
        assert_eq!(*reason, PlanReason::Sev1Failure);
        assert!(plan.workers_used <= 16, "the consolidated plan fits the surviving pool");
        assert!(plan.layout.owner_of(NodeId(0)).is_none());
        assert!(plan.layout.owner_of(NodeId(2)).is_none());
        // the batch debt is settled: a stray timer is a stale no-op
        assert!(c.handle_at(CoordEvent::ReplanDue, 1000.0).is_empty());
        // one log entry for the whole burst, and the log replays
        let mut twin = coord(32);
        let steps =
            c.log.replay(&mut twin, |_| None).unwrap_or_else(|d| panic!("replay diverged: {d}"));
        assert_eq!(steps, c.log.len());
        assert_eq!(steps, 3, "launch + batch + stale timer");
    }

    #[test]
    fn horizon_refresh_reuses_rows_when_assignments_hold_still() {
        // Capped tasks on surplus capacity: a node loss does not move the
        // optimum, so the committed table survives, and the next horizon
        // refresh re-solves only the rows the membership shift changed.
        fn capped(id: u32, cap: u32, n: u32) -> PlanTask {
            let mut t = plan_task(id, 2, cap, n);
            t.spec.max_workers = cap;
            t
        }
        // per-node failure domains: back-to-back losses on this 4-node pool
        // must replan immediately, not defer as a correlated same-domain burst
        let cfg = UnicronConfig { nodes_per_domain: 1, ..Default::default() };
        let mut c = Coordinator::builder()
            .config(cfg)
            .workers(32u32)
            .gpus_per_node(8u32)
            .task(capped(0, 4, 48))
            .task(capped(1, 4, 48))
            .build();
        c.handle(CoordEvent::TaskLaunched { task: TaskId(0) });
        c.precompute_event_plans();
        assert_eq!(c.lookup_rows_reused(), 0, "nothing to delta against yet");
        let cold_rows = c.lookup_rows_solved();
        assert_eq!(cold_rows, 2 + 3, "m+3 event-horizon rows");
        // SEV1 shrinks the pool 32 -> 24, but the caps bind: the replan is
        // a table hit and the committed counts do not move
        c.handle(CoordEvent::NodeLost { node: NodeId(3) });
        assert_eq!(c.task_assignment(TaskId(0)), Some(WorkerCount(4)));
        assert_eq!(c.task_assignment(TaskId(1)), Some(WorkerCount(4)));
        c.precompute_event_plans();
        // the shifted horizon shares two no-fault keys (24, 32) with the
        // previous one — copied, not re-solved
        assert_eq!(c.lookup_rows_reused(), 2, "overlapping rows must be reused");
        assert_eq!(c.lookup_rows_solved(), cold_rows + 3);
        // and the refreshed table still serves the next replan exactly
        let before = c.lookup_hits();
        c.handle(CoordEvent::NodeLost { node: NodeId(2) });
        assert_eq!(c.lookup_hits(), before + 1);
    }

    #[test]
    fn deferred_burst_faults_merge_into_the_next_replan() {
        // An unrelated replan arriving before the timer settles the debt:
        // the deferred faults ride it and the timer becomes a no-op.
        let mut c = coord(32);
        c.handle_at(CoordEvent::TaskLaunched { task: TaskId(0) }, 0.0);
        c.handle_at(
            CoordEvent::ErrorReport { node: NodeId(0), task: TaskId(0), kind: ErrorKind::EccError },
            10.0,
        );
        let deferred = c.handle_at(
            CoordEvent::ErrorReport { node: NodeId(1), task: TaskId(1), kind: ErrorKind::EccError },
            20.0,
        );
        assert!(deferred.iter().any(|a| matches!(a, Action::ScheduleReplan { .. })));
        // node 0 comes back: the join replan drains the deferred fault
        let join = c.handle_at(CoordEvent::NodeJoined { node: NodeId(0) }, 30.0);
        assert!(
            join.iter().any(|a| matches!(
                a,
                Action::ApplyPlan { reason: PlanReason::NodeJoined, .. }
            )),
            "{join:?}"
        );
        assert!(c.handle_at(CoordEvent::ReplanDue, 920.0).is_empty(), "debt already settled");
    }

    #[test]
    fn failure_timestamps_tighten_the_ledger_horizon() {
        // ROADMAP fleet follow-up: detection timestamps feed the EWMA MTBF,
        // and the cost ledger's horizon tightens as data accumulates. Nodes
        // span distinct domains so no SEV1 reads as a burst.
        let mut c = coord(32);
        let prior = c.cost_model().mtbf_per_gpu_s();
        c.handle_at(CoordEvent::TaskLaunched { task: TaskId(0) }, 0.0);
        c.handle_at(CoordEvent::NodeLost { node: NodeId(0) }, 3600.0);
        assert_eq!(c.cost_model().mtbf_per_gpu_s(), prior, "first failure only anchors the clock");
        c.handle_at(CoordEvent::NodeLost { node: NodeId(8) }, 7200.0);
        let est = c.cost_model().mtbf_per_gpu_s();
        assert!(est < prior, "observed failure rate must tighten the MTBF: {est} vs {prior}");
        assert_eq!(est, c.fleet.mtbf_per_gpu_estimate_s(), "ledger follows the fleet estimate");
        // a table priced with the tightened estimate serves; the next
        // observation re-prices the ledger and stales it again
        c.precompute_plans();
        assert!(c.lookup_is_fresh());
        c.handle_at(CoordEvent::NodeLost { node: NodeId(12) }, 10800.0);
        assert!(!c.lookup_is_fresh());
        let est3 = c.cost_model().mtbf_per_gpu_s();
        assert!(est3 < est);
        // one physical failure samples the estimator exactly once: a
        // duplicate report about the fenced node is not an observation, and
        // neither is an in-place-recoverable SEV2
        c.handle_at(CoordEvent::NodeLost { node: NodeId(12) }, 14000.0);
        c.handle_at(
            CoordEvent::ErrorReport {
                node: NodeId(4),
                task: TaskId(0),
                kind: ErrorKind::CudaError,
            },
            14400.0,
        );
        assert_eq!(c.cost_model().mtbf_per_gpu_s(), est3);
        // replays are still bit-identical: the timestamps are in the log
        let mut twin = coord(32);
        let steps = c
            .log
            .replay(&mut twin, |_| None)
            .unwrap_or_else(|d| panic!("replay diverged: {d}"));
        assert_eq!(steps, c.log.len());
        assert_eq!(twin.cost_model().mtbf_per_gpu_s(), c.cost_model().mtbf_per_gpu_s());
    }

    #[test]
    fn retained_surplus_spare_terms_ride_the_plan_breakdown() {
        // A surplus spare retained by the pool economics: its value/cost
        // terms are recorded on the replan's CostBreakdown, so the decision
        // log explains the retention in the plan's own currency.
        let keepers = UnicronConfig {
            spare_hold_frac: 0.0, // free to hold -> retain
            max_spares: 1,
            ..Default::default()
        };
        let mut c = Coordinator::builder()
            .config(keepers)
            .workers(32u32)
            .gpus_per_node(8u32)
            .task(plan_task(0, 2, 16, 64))
            .task(plan_task(1, 2, 16, 64))
            .build();
        c.handle(CoordEvent::TaskLaunched { task: TaskId(0) });
        let a = c.handle(CoordEvent::NodeRepaired { node: NodeId(9) });
        assert!(matches!(a[0], Action::SpareRetained { node: NodeId(9) }));
        let plan = a
            .iter()
            .find_map(|x| match x {
                Action::ApplyPlan { plan, .. } => Some(plan),
                _ => None,
            })
            .expect("retention must replan");
        assert!(plan.breakdown.spare_value > 0.0, "priced retention: {:?}", plan.breakdown);
        assert_eq!(plan.breakdown.spare_hold_cost, 0.0, "holding was free");
        // the spare terms are informational: the objective still reconciles
        assert_eq!(plan.breakdown.objective(), plan.objective);

        // a below-peak readmission restores capacity: nothing priced
        let mut c = coord(32);
        c.handle(CoordEvent::TaskLaunched { task: TaskId(0) });
        c.handle(CoordEvent::NodeLost { node: NodeId(3) });
        let a = c.handle(CoordEvent::NodeRepaired { node: NodeId(3) });
        let plan = a
            .iter()
            .find_map(|x| match x {
                Action::ApplyPlan { plan, .. } => Some(plan),
                _ => None,
            })
            .expect("readmission must replan");
        assert_eq!(plan.breakdown.spare_value, 0.0);
        assert_eq!(plan.breakdown.spare_hold_cost, 0.0);
    }

    #[test]
    fn layout_commits_are_concrete_min_churn_and_avoid_fenced_nodes() {
        let mut c = coord(32);
        assert_eq!(
            c.placeable_nodes(),
            vec![NodeId(0), NodeId(1), NodeId(2), NodeId(3)],
            "initial capacity seeds concrete node ids"
        );
        assert!(c.layout().is_empty(), "no plan committed yet");
        let a = c.handle(CoordEvent::TaskLaunched { task: TaskId(0) });
        let plan = match &a[..] {
            [Action::ApplyPlan { plan, .. }] => plan.clone(),
            other => panic!("expected one ApplyPlan, got {other:?}"),
        };
        assert_eq!(&plan.layout, c.layout(), "the committed layout IS the coordinator's map");
        assert!(!plan.layout.is_empty());
        // disjoint, placeable-only
        let placed: Vec<NodeId> = plan.layout.placed_nodes().collect();
        let unique: std::collections::BTreeSet<NodeId> = placed.iter().copied().collect();
        assert_eq!(placed.len(), unique.len());
        assert!(placed.iter().all(|n| n.0 < 4), "only seeded nodes are placeable: {placed:?}");
        let before = c.layout().clone();

        // a SEV1 fences node 1: the new layout avoids it and keeps every
        // surviving node in place (min-churn)
        let a = c.handle(CoordEvent::ErrorReport {
            node: NodeId(1),
            task: TaskId(0),
            kind: ErrorKind::EccError,
        });
        let plan = a
            .iter()
            .find_map(|x| match x {
                Action::ApplyPlan { plan, .. } => Some(plan.clone()),
                _ => None,
            })
            .expect("SEV1 must replan");
        assert!(!c.placeable_nodes().contains(&NodeId(1)));
        assert!(plan.layout.owner_of(NodeId(1)).is_none(), "fenced node must not be placed");
        for moves in plan.layout.diff(&before) {
            for lost in &moves.lost {
                assert_eq!(*lost, NodeId(1), "only the fenced node may be lost: {moves:?}");
            }
        }
    }

    #[test]
    fn topology_blind_knob_selects_the_contiguous_reference() {
        let blind = UnicronConfig { placement_min_churn: false, ..Default::default() };
        let mut c = Coordinator::builder()
            .config(blind)
            .workers(32u32)
            .gpus_per_node(8u32)
            .task(plan_task(0, 2, 16, 48))
            .task(plan_task(1, 2, 16, 48))
            .build();
        c.handle(CoordEvent::TaskLaunched { task: TaskId(0) });
        // contiguous in node-id order: both tasks get placed, and the first
        // task's nodes all precede the second task's
        let l = c.layout().clone();
        let max0 = l.nodes_of(TaskId(0)).iter().map(|n| n.0).max();
        let min1 = l.nodes_of(TaskId(1)).iter().map(|n| n.0).min();
        let (max0, min1) = (
            max0.expect("task 0 must be placed"),
            min1.expect("task 1 must be placed"),
        );
        assert!(max0 < min1, "blind layouts are contiguous: {l}");
    }

    #[test]
    fn state_residency_reprices_the_sev1_replan() {
        use crate::transition::StateSource;
        let mut c = coord(32);
        c.handle(CoordEvent::TaskLaunched { task: TaskId(0) });
        c.precompute_plans();
        assert!(c.lookup_is_fresh());
        // the store reports task 0's nearest snapshot moved to local disk
        let a = c.handle(CoordEvent::StateResidency {
            task: TaskId(0),
            source: StateSource::LocalDiskCheckpoint,
            restore_s: 0.8,
        });
        assert!(a.is_empty(), "residency is bookkeeping, not an action");
        assert!(!c.lookup_is_fresh(), "fault rows were priced with the old tier");
        // a duplicate report changes nothing and keeps the rebuilt table
        c.precompute_plans();
        c.handle(CoordEvent::StateResidency {
            task: TaskId(0),
            source: StateSource::LocalDiskCheckpoint,
            restore_s: 0.8,
        });
        assert!(c.lookup_is_fresh(), "unchanged residency must not invalidate");
        // SEV1 on task 0: the committed plan stamps the resolved tier
        let a = c.handle(CoordEvent::ErrorReport {
            node: NodeId(0),
            task: TaskId(0),
            kind: ErrorKind::EccError,
        });
        let plan = a
            .iter()
            .find_map(|x| match x {
                Action::ApplyPlan { plan, .. } => Some(plan),
                _ => None,
            })
            .expect("SEV1 must replan");
        assert_eq!(plan.breakdown.state_source, StateSource::LocalDiskCheckpoint);
        // recorded residency replays bit-identically through a fresh twin
        let mut twin = coord(32);
        let steps =
            c.log.replay(&mut twin, |_| None).unwrap_or_else(|d| panic!("replay diverged: {d}"));
        assert_eq!(steps, c.log.len());
        assert_eq!(twin.log, c.log);
    }

    #[test]
    fn sustained_straggler_is_evicted_by_the_ledger() {
        let mut c = coord(32);
        c.handle(CoordEvent::TaskLaunched { task: TaskId(0) });
        // warm-up: the first steps build node 1's baseline silently
        for _ in 0..6 {
            let a = c.handle(CoordEvent::StepTiming {
                node: NodeId(1),
                task: TaskId(0),
                duration_s: 45.0,
            });
            assert!(a.is_empty(), "warm-up samples must be silent");
        }
        // the node turns into a 3x straggler (slow_frac = 2/3, well past
        // the ledger's break-even): after min_samples sustained slow steps
        // the verdict fires and the ledger evicts
        let mut evicted = None;
        for i in 0..12 {
            let a = c.handle(CoordEvent::StepTiming {
                node: NodeId(1),
                task: TaskId(0),
                duration_s: 135.0,
            });
            if !a.is_empty() {
                evicted = Some((i, a));
                break;
            }
        }
        let (i, a) = evicted.expect("a sustained straggler must be evicted");
        assert!(i >= 5, "the verdict needs min_samples sustained steps, fired at {i}");
        assert!(matches!(a[0], Action::IsolateNode { node: NodeId(1) }));
        match &a[1] {
            Action::AlertOps { message } => {
                assert!(
                    message.contains("DEGRADED") && message.contains("straggler"),
                    "{message}"
                );
            }
            other => panic!("expected the degradation page, got {other:?}"),
        }
        let plan = a
            .iter()
            .find_map(|x| match x {
                Action::ApplyPlan { plan, reason: PlanReason::Sev1Failure } => Some(plan),
                _ => None,
            })
            .expect("eviction must replan around the lost node");
        assert!(plan.breakdown.degradation_penalty > 0.0, "{:?}", plan.breakdown);
        // the breakdown still reconciles with the penalty subtracted
        assert!(
            (plan.breakdown.objective() - plan.objective).abs()
                <= 1e-9 * plan.objective.abs().max(1.0)
        );
        assert_eq!(c.available_workers(), WorkerCount(24), "eviction costs the node's GPUs");
        assert!(c.isolated.contains(&NodeId(1)), "same mechanics as a SEV1 isolation");
        assert!(plan.layout.owner_of(NodeId(1)).is_none());
        // the fleet history remembers the degradation
        assert!(c.fleet.degradation_score(NodeId(1)) > 0.0);
        // replays rebuild the estimators from the recorded StepTiming
        // stream, so the whole session is bit-identical through a twin
        let mut twin = coord(32);
        let steps =
            c.log.replay(&mut twin, |_| None).unwrap_or_else(|d| panic!("replay diverged: {d}"));
        assert_eq!(steps, c.log.len());
        assert_eq!(twin.log, c.log);
    }

    #[test]
    fn mild_degradation_is_tolerated_and_churn_risk_never_evicts() {
        let mut c = coord(32);
        c.handle(CoordEvent::TaskLaunched { task: TaskId(0) });
        // an externally-delivered verdict below the ledger's break-even:
        // the fleet records it, the node stays
        let a = c.handle(CoordEvent::NodeDegraded {
            node: NodeId(2),
            task: TaskId(0),
            kind: DegradationKind::PartialBandwidth,
            slow_frac: 0.10,
        });
        assert!(a.is_empty(), "tolerating must be silent: {a:?}");
        assert!(c.fleet.degradation_score(NodeId(2)) > 0.0, "scored even when tolerated");
        assert_eq!(c.available_workers(), WorkerCount(32), "the node stays");
        // churn risk is a forecast, not a measured slowdown: recorded,
        // never evicted — even at a severe predicted fraction
        let a = c.handle(CoordEvent::NodeDegraded {
            node: NodeId(3),
            task: TaskId(0),
            kind: DegradationKind::ChurnRisk,
            slow_frac: 0.9,
        });
        assert!(a.is_empty());
        assert_eq!(c.available_workers(), WorkerCount(32));
        assert!(c.fleet.degradation_score(NodeId(3)) > 0.0);
        // a severe external verdict takes the same eviction path the
        // internal estimators do
        let a = c.handle(CoordEvent::NodeDegraded {
            node: NodeId(2),
            task: TaskId(0),
            kind: DegradationKind::Straggler,
            slow_frac: 0.9,
        });
        assert!(matches!(a[0], Action::IsolateNode { node: NodeId(2) }), "{a:?}");
        assert_eq!(c.available_workers(), WorkerCount(24));
        // duplicate verdicts about the fenced node are stale no-ops
        let a = c.handle(CoordEvent::NodeDegraded {
            node: NodeId(2),
            task: TaskId(0),
            kind: DegradationKind::Straggler,
            slow_frac: 0.9,
        });
        assert!(a.is_empty());
    }

    #[test]
    fn degradation_detection_can_be_disabled() {
        let off = UnicronConfig { degradation_detection: false, ..Default::default() };
        let mut c = Coordinator::builder()
            .config(off)
            .workers(32u32)
            .gpus_per_node(8u32)
            .task(plan_task(0, 2, 16, 48))
            .task(plan_task(1, 2, 16, 48))
            .build();
        c.handle(CoordEvent::TaskLaunched { task: TaskId(0) });
        for _ in 0..30 {
            let a = c.handle(CoordEvent::StepTiming {
                node: NodeId(1),
                task: TaskId(0),
                duration_s: 450.0,
            });
            assert!(a.is_empty(), "detection off: timing samples are inert");
        }
        let a = c.handle(CoordEvent::NodeDegraded {
            node: NodeId(1),
            task: TaskId(0),
            kind: DegradationKind::Straggler,
            slow_frac: 0.9,
        });
        assert!(a.is_empty(), "detection off: external verdicts are inert too");
        assert_eq!(c.available_workers(), WorkerCount(32));
        assert_eq!(c.fleet.degradation_score(NodeId(1)), 0.0);
    }

    #[test]
    fn builder_registers_tasks_and_defaults() {
        let c =
            Coordinator::builder().workers(WorkerCount(16)).task(plan_task(4, 2, 0, 32)).build();
        assert_eq!(c.available_workers(), WorkerCount(16));
        assert_eq!(c.gpus_per_node(), WorkerCount(8), "default GPUs per node");
        assert!(c.has_tasks());
        assert_eq!(c.task_assignment(TaskId(4)), Some(WorkerCount(0)));
    }

    #[test]
    fn sev1_decision_records_a_span_and_an_incident() {
        // DESIGN.md §14: every handle_at cycle leaves a DecisionSpan, and a
        // SEV1 failure opens an incident that the replan closes — with the
        // committed plan's terms riding both.
        let mut c = coord(32);
        c.handle_at(CoordEvent::TaskLaunched { task: TaskId(0) }, 0.0);
        c.handle_at(
            CoordEvent::ErrorReport { node: NodeId(1), task: TaskId(0), kind: ErrorKind::EccError },
            100.0,
        );
        let spans = c.telemetry().spans();
        assert_eq!(spans.len(), 2, "one span per decision");
        let sev1 = &spans[1];
        assert_eq!(sev1.event, "error_report");
        assert_eq!(sev1.at_s, 100.0);
        assert!(sev1.actions >= 2, "isolate + alert + replan: {}", sev1.actions);
        let plan = sev1.plan.as_ref().expect("the SEV1 replan rides the span");
        assert_eq!(plan.reason, "sev1_failure");
        assert!(plan.objective > 0.0);
        assert!(!plan.lookup_hit, "no table was precomputed");
        let timeline = c.telemetry().timeline();
        assert!(timeline.open_incidents().is_empty(), "the replan closed the incident");
        let incidents = timeline.incidents();
        assert_eq!(incidents.len(), 1);
        let inc = &incidents[0];
        assert_eq!(inc.node, NodeId(1));
        assert_eq!(inc.kind, "ecc_error");
        assert!(inc.replan.is_some() && inc.recovered_at_s.is_some());
        // the narrative renders without error from live state
        let text = timeline.render().expect("timeline must render");
        assert!(text.contains("ecc_error"), "{text}");

        // tracing off: decisions identical, nothing recorded
        let mut quiet = Coordinator::builder()
            .workers(32u32)
            .gpus_per_node(8u32)
            .task(plan_task(0, 2, 16, 48))
            .task(plan_task(1, 2, 16, 48))
            .telemetry(false)
            .build();
        quiet.handle_at(CoordEvent::TaskLaunched { task: TaskId(0) }, 0.0);
        quiet.handle_at(
            CoordEvent::ErrorReport { node: NodeId(1), task: TaskId(0), kind: ErrorKind::EccError },
            100.0,
        );
        assert!(quiet.telemetry().spans().is_empty());
        assert!(quiet.telemetry().timeline().incidents().is_empty());
        assert_eq!(quiet.log, c.log, "tracing must not change decisions");
        // counters stay live either way
        assert_eq!(quiet.solve_calls(), c.solve_calls());
    }
}
