//! The Unicron coordinator (§3.2): consolidates agent status, classifies
//! errors, drives the §4.2 handling workflow (Fig. 7), and triggers
//! cost-aware reconfiguration through the [`crate::planner`].
//!
//! The core is a synchronous, fully-deterministic state machine —
//! [`Coordinator::handle`] maps one [`CoordEvent`] to a list of [`Action`]s;
//! it never reads a clock, a thread, or a socket. Two drivers feed it:
//!
//! * the live TCP driver ([`live`]) translates kvstore watches into
//!   [`CoordEvent`]s and publishes the returned [`Action`]s to agents over
//!   the wire, with its timed work ordered by the shared
//!   [`crate::engine::EventQueue`];
//! * the discrete-event environment model ([`crate::simulator`]) translates
//!   failure-trace events into the same [`CoordEvent`]s and executes the
//!   same [`Action`]s against simulated time from the same engine.
//!
//! Both run this exact state machine. `rust/tests/sim_unification.rs`
//! asserts the simulator's executed action sequence is identical to the
//! audit [`Coordinator::log`] replayed standalone — the property that makes
//! the Table 2 / Fig. 9 / Fig. 11 experiments exercise the *actual*
//! coordinator rather than a hand-maintained model of it.
//!
//! Hot path (§5.2): between events the owner calls
//! [`Coordinator::precompute_plans`] to build a [`ScenarioLookup`] covering
//! every `(faulted task, worker count)` the next event could produce; a
//! SEV1 replan then commits a precomputed plan in O(1) table time instead of
//! running the O(m·n²) DP inside the failure-handling window. The table
//! invalidates itself whenever committed assignments change.

pub mod live;

use std::collections::BTreeMap;

use crate::config::UnicronConfig;
use crate::failure::{ErrorKind, Severity};
use crate::planner::{solve, Plan, PlanTask, ScenarioLookup};

/// Events the coordinator reacts to. ①–⑥ refer to Fig. 7's triggers.
#[derive(Debug, Clone, PartialEq)]
pub enum CoordEvent {
    /// An agent reported an error observed on `node` for `task` (①②③ by
    /// the kind's severity).
    ErrorReport { node: u32, task: u32, kind: ErrorKind },
    /// A node's lease expired — SEV1 lost connection (①).
    NodeLost { node: u32 },
    /// A repaired or new node joined (④).
    NodeJoined { node: u32 },
    /// A task completed (⑤).
    TaskFinished { task: u32 },
    /// A new task was submitted (⑥).
    TaskLaunched { task: u32 },
    /// Outcome of a previously-instructed reattempt/restart.
    ReattemptResult { node: u32, task: u32, ok: bool },
    RestartResult { node: u32, task: u32, ok: bool },
}

/// Instructions the coordinator emits (executed by agents / the simulator).
#[derive(Debug, Clone, PartialEq)]
pub enum Action {
    /// SEV3 ①: retry the failed operation where it failed.
    InstructReattempt { node: u32, task: u32 },
    /// SEV2 ②: restart the training process on the node, same configuration;
    /// state recovers from a DP replica or checkpoint (§6.3).
    InstructRestart { node: u32, task: u32 },
    /// SEV1 ③: fence the node out of the cluster.
    IsolateNode { node: u32 },
    /// Reconfigure affected tasks to a new plan (assignments per task id).
    ApplyPlan { plan: Plan, reason: &'static str },
    /// Page the humans (§3.2 "other external interactions").
    AlertOps { message: String },
}

/// Per-(task, node) escalation bookkeeping.
#[derive(Debug, Default, Clone)]
struct EscalationState {
    reattempts: u32,
    restarts: u32,
}

/// The coordinator state machine.
pub struct Coordinator {
    pub cfg: UnicronConfig,
    /// Planner inputs for every task currently in the cluster.
    tasks: BTreeMap<u32, PlanTask>,
    /// Healthy workers (GPUs) currently available.
    pub available_workers: u32,
    /// GPUs contributed per node (to size NodeLost effects).
    pub gpus_per_node: u32,
    /// Nodes currently isolated (fenced off).
    pub isolated: Vec<u32>,
    escalations: BTreeMap<(u32, u32), EscalationState>,
    /// Audit log of (event, actions) — the tests' and benches' ground truth.
    pub log: Vec<(CoordEvent, Vec<Action>)>,
    /// §5.2 precomputed plan table; `None` when stale (assignments changed
    /// since the last [`Coordinator::precompute_plans`]).
    lookup: Option<ScenarioLookup>,
    /// Replans served from the precomputed table (observability/benches).
    pub lookup_hits: u64,
    /// Replans that fell back to a fresh DP solve.
    pub solve_calls: u64,
}

impl Coordinator {
    pub fn new(cfg: UnicronConfig, available_workers: u32, gpus_per_node: u32) -> Coordinator {
        Coordinator {
            cfg,
            tasks: BTreeMap::new(),
            available_workers,
            gpus_per_node,
            isolated: Vec::new(),
            escalations: BTreeMap::new(),
            log: Vec::new(),
            lookup: None,
            lookup_hits: 0,
            solve_calls: 0,
        }
    }

    /// Register a task (with its calibrated throughput table) for planning.
    pub fn add_task(&mut self, task: PlanTask) {
        self.tasks.insert(task.spec.id, task);
        self.lookup = None; // task set changed: precomputed plans are stale
    }

    /// Full cluster capacity (healthy + isolated nodes' GPUs) — the upper
    /// bound a join can restore the pool to, and the precompute range.
    fn capacity_ceiling(&self) -> u32 {
        self.available_workers + self.gpus_per_node * self.isolated.len() as u32
    }

    /// Build the §5.2 scenario table for the current assignments. Call this
    /// off the failure path (the paper runs it in the background after every
    /// reconfiguration); subsequent replans are O(1) table commits until the
    /// assignments change again.
    pub fn precompute_plans(&mut self) {
        if self.tasks.is_empty() {
            self.lookup = None;
            return;
        }
        let ordered: Vec<PlanTask> = self.tasks.values().cloned().collect();
        self.lookup = Some(ScenarioLookup::precompute(&ordered, self.capacity_ceiling(), &self.cfg));
    }

    /// True if the next replan will be served from the precomputed table:
    /// the table matches the current task set and covers the current pool
    /// size (a brand-new node joining past the precomputed ceiling falls
    /// back to a live solve rather than silently clamping).
    pub fn lookup_is_fresh(&self) -> bool {
        self.lookup.as_ref().is_some_and(|l| {
            l.n_tasks() == self.tasks.len() && self.available_workers <= l.max_workers()
        })
    }

    pub fn task_assignment(&self, task: u32) -> Option<u32> {
        self.tasks.get(&task).map(|t| t.current)
    }

    pub fn tasks(&self) -> impl Iterator<Item = &PlanTask> {
        self.tasks.values()
    }

    /// Total WAF of the current assignments (cluster health metric).
    pub fn current_waf(&self) -> f64 {
        self.tasks.values().map(|t| t.waf(t.current)).sum()
    }

    /// Process one event; returns the actions (also appended to `log`).
    pub fn handle(&mut self, event: CoordEvent) -> Vec<Action> {
        let actions = self.dispatch(&event);
        self.log.push((event, actions.clone()));
        actions
    }

    fn dispatch(&mut self, event: &CoordEvent) -> Vec<Action> {
        match *event {
            CoordEvent::ErrorReport { node, task, kind } => match kind.severity() {
                Severity::Sev3 => self.on_sev3(node, task),
                Severity::Sev2 => self.on_sev2(node, task),
                Severity::Sev1 => self.on_sev1(node, Some(task)),
            },
            CoordEvent::NodeLost { node } => self.on_sev1(node, None),
            CoordEvent::NodeJoined { node } => {
                self.isolated.retain(|&n| n != node);
                self.available_workers += self.gpus_per_node;
                self.reconfigure("node joined", None)
            }
            CoordEvent::TaskFinished { task } => {
                self.tasks.remove(&task);
                self.lookup = None; // task set changed
                self.reconfigure("task finished", None)
            }
            CoordEvent::TaskLaunched { .. } => {
                // caller adds the PlanTask via add_task before this event
                self.reconfigure("task launched", None)
            }
            CoordEvent::ReattemptResult { node, task, ok } => {
                if ok {
                    self.escalations.remove(&(task, node));
                    vec![]
                } else {
                    // §4.2: failed reattempt upgrades SEV3 -> SEV2
                    self.on_sev2(node, task)
                }
            }
            CoordEvent::RestartResult { node, task, ok } => {
                if ok {
                    self.escalations.remove(&(task, node));
                    vec![]
                } else {
                    // §4.2: failed restart upgrades SEV2 -> SEV1
                    self.on_sev1(node, Some(task))
                }
            }
        }
    }

    fn on_sev3(&mut self, node: u32, task: u32) -> Vec<Action> {
        let esc = self.escalations.entry((task, node)).or_default();
        if esc.reattempts < self.cfg.max_reattempts {
            esc.reattempts += 1;
            vec![Action::InstructReattempt { node, task }]
        } else {
            self.on_sev2(node, task)
        }
    }

    fn on_sev2(&mut self, node: u32, task: u32) -> Vec<Action> {
        let esc = self.escalations.entry((task, node)).or_default();
        if esc.restarts < self.cfg.max_restarts {
            esc.restarts += 1;
            vec![Action::InstructRestart { node, task }]
        } else {
            self.on_sev1(node, Some(task))
        }
    }

    fn on_sev1(&mut self, node: u32, task: Option<u32>) -> Vec<Action> {
        if self.isolated.contains(&node) {
            return vec![]; // already fenced; duplicate report
        }
        self.isolated.push(node);
        self.available_workers = self.available_workers.saturating_sub(self.gpus_per_node);
        let mut actions = vec![
            Action::IsolateNode { node },
            Action::AlertOps { message: format!("SEV1: node {node} isolated; maintenance required") },
        ];
        actions.extend(self.reconfigure("SEV1 failure", task));
        actions
    }

    /// Cost-aware plan generation (§5) + bookkeeping of the new assignments.
    ///
    /// Served from the precomputed [`ScenarioLookup`] when it is fresh (an
    /// O(1) table commit — the §5.2 hot path), falling back to a live DP
    /// [`solve`] otherwise. Both paths produce the identical plan for the
    /// same state; `coordinator::tests::lookup_path_is_equivalent` holds
    /// them to that.
    fn reconfigure(&mut self, reason: &'static str, faulted_task: Option<u32>) -> Vec<Action> {
        if self.tasks.is_empty() {
            return vec![];
        }
        // map the faulted task id to its position in id-ordered iteration
        let fault_idx = faulted_task.and_then(|t| self.tasks.keys().position(|&k| k == t));
        let plan = if self.lookup_is_fresh() {
            self.lookup_hits += 1;
            let lut = self.lookup.as_ref().unwrap();
            lut.plan_for(fault_idx, self.available_workers).clone()
        } else {
            self.solve_calls += 1;
            let mut ordered: Vec<PlanTask> = self.tasks.values().cloned().collect();
            if let Some(i) = fault_idx {
                ordered[i].fault = true;
            }
            solve(&ordered, self.available_workers, &self.cfg)
        };
        // commit the new assignments; clear fault flags (handled). The
        // precomputed table remains valid only if nothing actually moved.
        let mut changed = false;
        for (pt, &x) in self.tasks.values_mut().zip(plan.assignment.iter()) {
            changed |= pt.current != x;
            pt.current = x;
            pt.fault = false;
        }
        if changed {
            self.lookup = None;
        }
        vec![Action::ApplyPlan { plan, reason }]
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::TaskSpec;

    fn plan_task(id: u32, min: u32, current: u32, n: u32) -> PlanTask {
        let throughput =
            (0..=n).map(|x| if x >= min { 1e12 * (x as f64).powf(0.9) } else { 0.0 }).collect();
        PlanTask { spec: TaskSpec::new(id, "m", 1.0, min), throughput, current, fault: false }
    }

    fn coord(workers: u32) -> Coordinator {
        let mut c = Coordinator::new(UnicronConfig::default(), workers, 8);
        c.add_task(plan_task(0, 2, workers / 2, workers + 16));
        c.add_task(plan_task(1, 2, workers / 2, workers + 16));
        c
    }

    #[test]
    fn sev3_reattempts_then_escalates() {
        let mut c = coord(32);
        // three reattempts allowed
        for i in 0..3 {
            let a = c.handle(CoordEvent::ErrorReport {
                node: 1,
                task: 0,
                kind: ErrorKind::ConnectionRefused,
            });
            assert_eq!(a, vec![Action::InstructReattempt { node: 1, task: 0 }], "attempt {i}");
        }
        // fourth SEV3 -> restart (SEV2 path)
        let a = c.handle(CoordEvent::ErrorReport {
            node: 1,
            task: 0,
            kind: ErrorKind::ConnectionRefused,
        });
        assert_eq!(a, vec![Action::InstructRestart { node: 1, task: 0 }]);
    }

    #[test]
    fn reattempt_success_resets_budget() {
        let mut c = coord(32);
        for _ in 0..3 {
            c.handle(CoordEvent::ErrorReport { node: 1, task: 0, kind: ErrorKind::LinkFlapping });
        }
        c.handle(CoordEvent::ReattemptResult { node: 1, task: 0, ok: true });
        let a = c.handle(CoordEvent::ErrorReport { node: 1, task: 0, kind: ErrorKind::LinkFlapping });
        assert_eq!(a, vec![Action::InstructReattempt { node: 1, task: 0 }]);
    }

    #[test]
    fn sev2_restarts_then_escalates_to_sev1() {
        let mut c = coord(32);
        let a = c.handle(CoordEvent::ErrorReport { node: 2, task: 1, kind: ErrorKind::CudaError });
        assert_eq!(a, vec![Action::InstructRestart { node: 2, task: 1 }]);
        // restart failed -> SEV1: isolate + alert + replan
        let a = c.handle(CoordEvent::RestartResult { node: 2, task: 1, ok: false });
        assert!(matches!(a[0], Action::IsolateNode { node: 2 }));
        assert!(matches!(a[1], Action::AlertOps { .. }));
        assert!(matches!(a[2], Action::ApplyPlan { .. }));
        assert_eq!(c.available_workers, 24);
        assert_eq!(c.isolated, vec![2]);
    }

    #[test]
    fn sev1_reconfigures_within_reduced_capacity() {
        let mut c = coord(32);
        let a = c.handle(CoordEvent::ErrorReport { node: 0, task: 0, kind: ErrorKind::EccError });
        let plan = a
            .iter()
            .find_map(|x| match x {
                Action::ApplyPlan { plan, .. } => Some(plan.clone()),
                _ => None,
            })
            .expect("SEV1 must replan");
        assert!(plan.workers_used <= 24);
        // assignments were committed
        let total: u32 =
            (0..=1).map(|t| c.task_assignment(t).unwrap()).sum();
        assert!(total <= 24);
    }

    #[test]
    fn duplicate_sev1_for_same_node_is_idempotent() {
        let mut c = coord(32);
        c.handle(CoordEvent::NodeLost { node: 3 });
        let before = c.available_workers;
        let a = c.handle(CoordEvent::NodeLost { node: 3 });
        assert!(a.is_empty());
        assert_eq!(c.available_workers, before);
    }

    #[test]
    fn node_join_triggers_reconfiguration() {
        let mut c = coord(32);
        c.handle(CoordEvent::NodeLost { node: 1 });
        assert_eq!(c.available_workers, 24);
        let a = c.handle(CoordEvent::NodeJoined { node: 1 });
        assert_eq!(c.available_workers, 32);
        assert!(c.isolated.is_empty());
        assert!(matches!(a[0], Action::ApplyPlan { reason: "node joined", .. }));
    }

    #[test]
    fn task_lifecycle_triggers_reconfiguration() {
        let mut c = coord(32);
        let a = c.handle(CoordEvent::TaskFinished { task: 0 });
        assert!(matches!(a[0], Action::ApplyPlan { reason: "task finished", .. }));
        assert!(c.task_assignment(0).is_none());
        // remaining task can now take everything useful
        c.add_task(plan_task(2, 2, 0, 48));
        let a = c.handle(CoordEvent::TaskLaunched { task: 2 });
        assert!(matches!(a[0], Action::ApplyPlan { reason: "task launched", .. }));
        assert!(c.task_assignment(2).unwrap() > 0);
    }

    #[test]
    fn lookup_path_is_equivalent_to_solve_path() {
        // Same event storm, one coordinator precomputing between events, one
        // always solving live — the audit logs must be identical.
        let events = [
            CoordEvent::TaskLaunched { task: 0 },
            CoordEvent::ErrorReport { node: 1, task: 0, kind: ErrorKind::EccError },
            CoordEvent::NodeLost { node: 2 },
            CoordEvent::NodeJoined { node: 1 },
            CoordEvent::ErrorReport { node: 3, task: 1, kind: ErrorKind::NvlinkError },
            CoordEvent::TaskFinished { task: 0 },
            CoordEvent::NodeJoined { node: 2 },
        ];
        let mut warm = coord(32);
        let mut cold = coord(32);
        for ev in &events {
            warm.precompute_plans(); // the §5.2 background step
            assert!(warm.lookup_is_fresh());
            let a = warm.handle(ev.clone());
            let b = cold.handle(ev.clone());
            assert_eq!(a, b, "divergence at {ev:?}");
        }
        assert_eq!(warm.log, cold.log);
        assert!(warm.lookup_hits >= 6, "replans should hit the table: {}", warm.lookup_hits);
        // the one allowed miss: TaskFinished shrinks the task set between the
        // precompute and the replan, so that replan must re-solve
        assert!(warm.solve_calls <= 1, "unexpected hot-path solves: {}", warm.solve_calls);
        assert!(cold.lookup_hits == 0 && cold.solve_calls > 0);
    }

    #[test]
    fn lookup_invalidates_when_assignments_move() {
        let mut c = coord(32);
        c.precompute_plans();
        assert!(c.lookup_is_fresh());
        // a SEV1 shrinks the pool and moves workers: the table must go stale
        c.handle(CoordEvent::NodeLost { node: 0 });
        assert!(!c.lookup_is_fresh(), "stale table must not survive a commit");
        // adding a task also invalidates
        c.precompute_plans();
        assert!(c.lookup_is_fresh());
        c.add_task(plan_task(7, 2, 0, 48));
        assert!(!c.lookup_is_fresh());
    }

    #[test]
    fn waf_drops_after_sev1_and_recovers_after_join() {
        let mut c = coord(32);
        c.handle(CoordEvent::TaskLaunched { task: 99 }); // force initial plan
        let healthy = c.current_waf();
        c.handle(CoordEvent::NodeLost { node: 0 });
        let degraded = c.current_waf();
        assert!(degraded < healthy);
        c.handle(CoordEvent::NodeJoined { node: 0 });
        let recovered = c.current_waf();
        assert!(recovered >= degraded);
        assert!((recovered - healthy).abs() < 1e-6 * healthy);
    }
}
