//! The cost ledger (paper §5 + DESIGN.md §9): one typed [`CostModel`] that
//! every cost-aware decision in the system prices against.
//!
//! Before this module the cost math was scattered and lossy: the planner's
//! reward took two bare `f64` scalars, every task paid the same flat global
//! transition cost no matter *how* it transitions, the per-strategy
//! migration times of [`crate::transition::migration_time_s`] (§6.3's
//! nearest principle) never reached the planner, and the spare pool priced
//! nodes with an ad-hoc formula inlined in the coordinator. The ledger
//! unifies all of it:
//!
//! * [`TransitionProfile`] — per-task, per-strategy transition pricing
//!   derived from the §6.3 migration-time model: a planned resize pulls
//!   state from a healthy DP replica, a faulted transition reloads the
//!   GEMINI in-memory checkpoint, and the cold fallback reads the remote
//!   persistent checkpoint. Bigger models pay more to move; the planner
//!   finally sees that.
//! * [`CostModel`] — the shared currency: the opportunity horizon
//!   `D_running(n) = MTBF_gpu / n` (Eq. 3), per-task transition penalties
//!   (`F(t, x) · d_transition(t)`), and the spare-pool economics
//!   ([`crate::fleet::SparePool`]) all priced with the *same* effective
//!   per-GPU MTBF. The MTBF starts at the `UnicronConfig` prior and is
//!   tightened by the fleet's EWMA estimate as real detection timestamps
//!   accumulate ([`crate::fleet::FleetModel::observe_cluster_failure`]).
//! * [`CostBreakdown`] — the typed explanation carried by every committed
//!   [`crate::planner::Plan`] (wire v3): running reward, transition
//!   penalty, the horizon and MTBF behind them, and the spare-pool terms
//!   when the plan resolves a retention. The invariant
//!   `objective = running_reward − transition_penalty` is pinned by
//!   `rust/tests/proto_roundtrip.rs`, so a replayed decision log explains
//!   each decision term-by-term in the currency it optimized.
//!
//! # Determinism
//!
//! A `CostModel` is a pure value: the same `(config, MTBF estimate)` prices
//! every quantity identically. The MTBF estimate itself evolves only from
//! the event/timestamp stream recorded in the v3
//! [`crate::proto::DecisionLog`], so replays reprice decisions
//! bit-identically.

use crate::config::{ClusterSpec, ModelSpec, UnicronConfig};
use crate::failure::{DetectionMethod, ErrorKind};
use crate::fleet::{SpareDecision, SparePool};
use crate::store::{SnapshotStore, Tier};
use crate::transition::{migration_time_s, StateSource};

/// Bytes of migratable training state per parameter: fp16 weights (2) +
/// fp32 master weights (4) + fp32 Adam moments (8) + gradient slack (2).
pub const STATE_BYTES_PER_PARAM: f64 = 16.0;

// ---------------------------------------------------------------------------
// Table 2 detection latencies
// ---------------------------------------------------------------------------

/// Table 2 case 1 — node health monitoring (lease TTL): the SEV1 node-drain
/// path, and the latency the planner prices into every faulted task's
/// reward (only SEV1-class faults reach a replan).
pub const DETECT_NODE_HEALTH_S: f64 = 5.6;
/// Table 2 case 2 — process supervision (agent poll).
pub const DETECT_PROCESS_S: f64 = 1.8;
/// Table 2 case 3 — exception propagation (immediate).
pub const DETECT_EXCEPTION_S: f64 = 0.3;
/// Table 2 case 4 — online statistical monitoring: 3 × D_iter at the
/// paper's ~45 s iteration time.
pub const DETECT_STATISTICAL_S: f64 = 3.0 * 45.0;
/// Gray-degradation detection window (wire v8): the streaming estimators
/// need `degradation_min_samples` (default 6) consecutive out-of-band
/// per-step samples at the paper's ~45 s iteration time before a
/// [`crate::proto::CoordEvent::NodeDegraded`] verdict fires — work during
/// that window ran at the degraded rate, so the ledger prices it into the
/// eviction plan ([`CostBreakdown::degradation_penalty`]).
pub const DETECT_DEGRADATION_S: f64 = 6.0 * 45.0;

/// Table 2 detection latency for one error kind — the per-error-kind time
/// between the failure and the coordinator learning about it, by the §4.1
/// method that catches the kind. Work done during this window is lost, so
/// the ledger prices it into the reward ([`CostBreakdown::detection_penalty`]).
pub fn detection_latency_s(kind: ErrorKind) -> f64 {
    match kind.detector() {
        DetectionMethod::NodeHealthMonitoring => DETECT_NODE_HEALTH_S,
        DetectionMethod::ProcessSupervision => DETECT_PROCESS_S,
        DetectionMethod::ExceptionPropagation => DETECT_EXCEPTION_S,
        DetectionMethod::OnlineStatisticalMonitoring => DETECT_STATISTICAL_S,
    }
}

/// Per-task transition pricing, seconds, one entry per §6.3 migration
/// strategy (nearest first). Derived once per task from its model size and
/// the cluster's interconnect/storage bandwidths, so the planner prices a
/// 13B task's reshuffle higher than a 1.3B task's.
#[derive(Debug, Clone, PartialEq)]
pub struct TransitionProfile {
    /// Planned resize: state pulled from a healthy DP replica (fastest).
    pub replica_s: f64,
    /// Faulted transition: the nearest replica died with the node; state
    /// reloads from a GEMINI-style in-memory checkpoint on a peer.
    pub inmem_s: f64,
    /// Middle tier: checkpoint on a surviving node's local disk (the
    /// snapshot store's demotion target when peer memory fills).
    pub local_s: f64,
    /// Cold fallback: remote persistent checkpoint (worst case; priced for
    /// observability, the planner's fault path uses `inmem_s`).
    pub remote_s: f64,
}

impl TransitionProfile {
    /// Price the §6.3 strategies for `state_bytes` of training state on
    /// `cluster` — the closed-form formula (the cold-start prior).
    pub fn from_state_bytes(state_bytes: u64, cluster: &ClusterSpec) -> TransitionProfile {
        TransitionProfile {
            replica_s: migration_time_s(StateSource::DpReplica, state_bytes, cluster, 1),
            inmem_s: migration_time_s(StateSource::InMemoryCheckpoint, state_bytes, cluster, 1),
            local_s: migration_time_s(StateSource::LocalDiskCheckpoint, state_bytes, cluster, 1),
            remote_s: migration_time_s(StateSource::RemoteCheckpoint, state_bytes, cluster, 1),
        }
    }

    /// Profile for a resolved model: state size from its parameter count.
    pub fn from_model(model: &ModelSpec, cluster: &ClusterSpec) -> TransitionProfile {
        TransitionProfile::from_state_bytes(
            (model.n_params * STATE_BYTES_PER_PARAM) as u64,
            cluster,
        )
    }

    /// Price the checkpoint-tier strategies from the snapshot store's
    /// *measured* per-tier latency/bandwidth statistics. Tiers with no
    /// observed transfers keep the closed-form formula as their cold-start
    /// prior — so a fresh store prices identically to
    /// [`TransitionProfile::from_state_bytes`], and measurements only ever
    /// refine, never destabilize, the planner's inputs. The replica path
    /// never touches the store (a healthy DP replica is a live process,
    /// not a snapshot), so `replica_s` is always the formula.
    pub fn from_store(
        state_bytes: u64,
        cluster: &ClusterSpec,
        store: &SnapshotStore,
    ) -> TransitionProfile {
        let formula = TransitionProfile::from_state_bytes(state_bytes, cluster);
        let measured = |tier: Tier, prior: f64| {
            let stats = store.tier_stats(tier);
            if stats.transfers == 0 || state_bytes == 0 {
                prior
            } else {
                stats.time_s(state_bytes)
            }
        };
        TransitionProfile {
            replica_s: formula.replica_s,
            inmem_s: measured(Tier::PeerMemory, formula.inmem_s),
            local_s: measured(Tier::LocalDisk, formula.local_s),
            remote_s: measured(Tier::Remote, formula.remote_s),
        }
    }

    /// Uniform profile: every strategy costs `d_s` seconds (synthetic tasks
    /// and tests that want the pre-ledger flat pricing).
    pub fn flat(d_s: f64) -> TransitionProfile {
        TransitionProfile { replica_s: d_s, inmem_s: d_s, local_s: d_s, remote_s: d_s }
    }

    /// Migration seconds when state pulls from `source` — the store-aware
    /// fault path prices exactly the tier the state will restore from.
    pub fn source_s(&self, source: StateSource) -> f64 {
        match source {
            StateSource::DpReplica => self.replica_s,
            StateSource::InMemoryCheckpoint => self.inmem_s,
            StateSource::LocalDiskCheckpoint => self.local_s,
            StateSource::RemoteCheckpoint => self.remote_s,
        }
    }

    /// Migration seconds for the strategy a transition actually uses:
    /// faulted tasks lost their nearest replica and pay the in-memory
    /// checkpoint path, planned resizes pull from a healthy replica.
    pub fn migration_s(&self, faulted: bool) -> f64 {
        if faulted {
            self.inmem_s
        } else {
            self.replica_s
        }
    }
}

/// The spare-pool terms behind one retain/release verdict, in the planner's
/// WAF currency (FLOP·s over the insured window).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct SpareTerms {
    /// Expected shortfall the next spare covers: `P(X ≥ held+1) · F_node · W`.
    pub value: f64,
    /// What holding the spare costs: `hold_frac · F_node · W`.
    pub hold_cost: f64,
    /// Expected node-failure count in the insured window (Poisson rate).
    pub lambda: f64,
}

/// The one cost ledger. Built from [`UnicronConfig`]; the effective per-GPU
/// MTBF tightens as the fleet observes real failure timestamps.
#[derive(Debug, Clone, PartialEq)]
pub struct CostModel {
    /// Fixed orchestration overhead of any transition (detach, rendezvous,
    /// process warm-up), seconds — the part that does not scale with state.
    transition_base_s: f64,
    /// The configured prior per-GPU MTBF.
    prior_mtbf_per_gpu_s: f64,
    /// Effective per-GPU MTBF — starts at the prior, updated from the
    /// fleet's EWMA estimate.
    mtbf_per_gpu_s: f64,
    /// Hot-spare economics, priced with the same MTBF.
    pool: SparePool,
}

impl CostModel {
    pub fn from_config(cfg: &UnicronConfig) -> CostModel {
        CostModel {
            transition_base_s: cfg.transition_base_s,
            prior_mtbf_per_gpu_s: cfg.mtbf_per_gpu_s,
            mtbf_per_gpu_s: cfg.mtbf_per_gpu_s,
            pool: SparePool::from_config(cfg),
        }
    }

    /// The configured prior per-GPU MTBF (seconds).
    pub fn prior_mtbf_per_gpu_s(&self) -> f64 {
        self.prior_mtbf_per_gpu_s
    }

    /// The effective per-GPU MTBF every term is priced with (seconds).
    pub fn mtbf_per_gpu_s(&self) -> f64 {
        self.mtbf_per_gpu_s
    }

    /// Fixed per-transition overhead (seconds).
    pub fn transition_base_s(&self) -> f64 {
        self.transition_base_s
    }

    /// Install a tightened MTBF estimate (the fleet's EWMA). Non-positive
    /// estimates are ignored. Returns true when the effective MTBF changed —
    /// the caller must treat precomputed plans as stale then.
    pub fn set_mtbf_per_gpu_s(&mut self, est_s: f64) -> bool {
        if est_s.is_nan() || est_s <= 0.0 || est_s == self.mtbf_per_gpu_s {
            return false;
        }
        self.mtbf_per_gpu_s = est_s;
        true
    }

    /// Opportunity horizon `D_running(n)`: the expected time to the next
    /// failure somewhere in an `n`-worker pool (Eq. 3). Larger pools fail
    /// sooner; a tighter MTBF estimate shortens every plan's horizon.
    pub fn horizon_s(&self, n_workers: u32) -> f64 {
        if n_workers == 0 {
            return 0.0;
        }
        self.mtbf_per_gpu_s / n_workers as f64
    }

    /// Seconds one transition of a task with `profile` takes: the fixed
    /// orchestration overhead plus the §6.3 migration time of the strategy
    /// the transition uses (`faulted` selects it).
    pub fn transition_s(&self, profile: &TransitionProfile, faulted: bool) -> f64 {
        self.transition_base_s + profile.migration_s(faulted)
    }

    /// Store-aware variant of [`CostModel::transition_s`] for faulted
    /// tasks: the fixed overhead plus the migration time of the *resolved*
    /// state source — a measured per-restore estimate when the store has
    /// one (`measured_s`), otherwise the profile's price for that source.
    /// With the default resolution (`InMemoryCheckpoint`, no measurement)
    /// this equals `transition_s(profile, true)` exactly.
    pub fn transition_from_s(
        &self,
        profile: &TransitionProfile,
        source: StateSource,
        measured_s: Option<f64>,
    ) -> f64 {
        self.transition_base_s + measured_s.unwrap_or_else(|| profile.source_s(source))
    }

    /// Detection latency the planner prices into a *faulted* task's reward:
    /// the Table 2 window between the failure and the coordinator learning
    /// about it, during which the task's work is already lost.
    ///
    /// Deliberately **kind-independent** (the SEV1 node-health entry, the
    /// severity class that ends a plan): the §5.2 scenario tables are
    /// precomputed *before* the failure whose kind they will serve, so a
    /// kind-dependent term would make a table hit price differently from
    /// the live solve it must be bit-identical to. Replans escalated from
    /// faster-detected kinds (e.g. a SEV2 lemon quarantine) are therefore
    /// charged conservatively; the exact per-error-kind times remain
    /// available as [`detection_latency_s`] for observability and the
    /// environment model's timing ([`crate::simulator::PolicyParams`]).
    pub fn detection_s(&self) -> f64 {
        DETECT_NODE_HEALTH_S
    }

    /// Detection latency charged when a plan is triggered by a gray
    /// degradation verdict rather than a fail-stop SEV1: the streaming
    /// estimators' verdict window (see [`DETECT_DEGRADATION_S`]). Like
    /// [`CostModel::detection_s`] this is deliberately **kind-independent**
    /// so a precomputed table hit prices identically to the live solve.
    pub fn degradation_s(&self) -> f64 {
        DETECT_DEGRADATION_S
    }

    /// The evict-vs-tolerate ledger verdict for a degraded node (wire v8):
    /// evict iff the goodput the degradation forfeits over the opportunity
    /// horizon exceeds what the eviction itself costs.
    ///
    /// Tolerating a node that runs `slow_frac` below baseline loses
    /// `slow_frac · task_waf · H` FLOP·s over the horizon
    /// `H = D_running(n)`. Evicting pays the task's transition
    /// (`task_waf · transition_s`) and gives up the node's marginal share
    /// (`node_waf · H`) until a repair returns it. Both sides are in the
    /// planner's WAF currency, so a degradation eviction and a plan
    /// objective are directly comparable.
    pub fn degradation_decision(
        &self,
        slow_frac: f64,
        task_waf: f64,
        node_waf: f64,
        n_workers: u32,
        transition_s: f64,
    ) -> bool {
        let horizon_s = self.horizon_s(n_workers);
        let tolerate_loss = slow_frac * task_waf * horizon_s;
        let evict_cost = task_waf * transition_s + node_waf * horizon_s;
        tolerate_loss > evict_cost
    }

    /// WAF one node carries: the proportional share of the cluster's
    /// current WAF attributed to `gpus_per_node` of `pool_gpus` workers.
    pub fn marginal_node_waf(&self, total_waf: f64, pool_gpus: u32, gpus_per_node: u32) -> f64 {
        total_waf * gpus_per_node as f64 / pool_gpus.max(1) as f64
    }

    /// The spare-pool terms for holding the `(held+1)`-th spare over a pool
    /// of `pool_gpus` workers whose marginal node earns `node_waf`.
    pub fn spare_terms(&self, held: u32, pool_gpus: u32, node_waf: f64) -> SpareTerms {
        let lambda = self.pool.expected_failures(pool_gpus, self.mtbf_per_gpu_s);
        SpareTerms {
            value: self.pool.spare_value(held, lambda, node_waf),
            hold_cost: self.pool.hold_cost(node_waf),
            lambda,
        }
    }

    /// Retain/release verdict for a surplus node, with the priced terms —
    /// the same currency [`crate::planner::solve`] optimizes, so a spare
    /// decision and a plan objective are directly comparable. The verdict
    /// is derived from the very terms returned (one Poisson rate, one
    /// pricing), so the recorded explanation always matches the decision.
    pub fn spare_decision(
        &self,
        held: u32,
        pool_gpus: u32,
        total_waf: f64,
        gpus_per_node: u32,
    ) -> (SpareDecision, SpareTerms) {
        let node_waf = self.marginal_node_waf(total_waf, pool_gpus, gpus_per_node);
        let terms = self.spare_terms(held, pool_gpus, node_waf);
        let decision = self.pool.decide(held, terms.lambda, node_waf);
        (decision, terms)
    }
}

/// Typed explanation of one committed plan, in the ledger's currency.
/// Carried by every [`crate::planner::Plan`] and serialized with it (wire
/// v3+), so a replayed [`crate::proto::DecisionLog`] explains each decision
/// term-by-term.
///
/// Invariant: `objective() = running_reward − transition_penalty −
/// detection_penalty − degradation_penalty` equals the plan's DP objective
/// to within 1e-9 relative error.
#[derive(Debug, Clone, PartialEq, Default)]
pub struct CostBreakdown {
    /// Σ F(tᵢ, xᵢ') · D_running — weighted useful work the plan earns over
    /// the opportunity horizon (FLOP·s).
    pub running_reward: f64,
    /// Σ 1_transition(tᵢ) · F(tᵢ, xᵢ) · d_transition(tᵢ) — work forfeited
    /// while transitioning tasks move state (FLOP·s).
    pub transition_penalty: f64,
    /// Σ_{faulted i} F(tᵢ, xᵢ) · d_detect — work already lost between the
    /// failure and its detection (Table 2, wire v4); zero for fault-free
    /// replans (joins, launches, finishes).
    pub detection_penalty: f64,
    /// `slow_frac · F(t, x) · d_degradation` — work the degraded node
    /// silently forfeited during the streaming estimators' verdict window
    /// (wire v8); zero unless the plan evicts a gray-degraded node.
    pub degradation_penalty: f64,
    /// The opportunity horizon `D_running(n)` the plan was priced with (s).
    pub horizon_s: f64,
    /// Effective per-GPU MTBF behind that horizon (s) — the prior, or the
    /// fleet's tightened EWMA estimate.
    pub mtbf_per_gpu_s: f64,
    /// Spare-pool value term when this plan resolves a spare retention
    /// (`P(shortfall) · F_node · W`, FLOP·s); zero otherwise.
    pub spare_value: f64,
    /// Matching holding cost (`hold_frac · F_node · W`, FLOP·s); zero
    /// unless the plan resolves a spare retention.
    pub spare_hold_cost: f64,
    /// The §6.3 state source the faulted task's transition was priced
    /// against (wire v6): [`StateSource::DpReplica`] — the default — for
    /// fault-free replans, otherwise the tier the snapshot store resolved
    /// (or the formula's in-memory assumption when store-aware recovery is
    /// off). Fault-free plans and pre-v6 logs both read as `DpReplica`.
    pub state_source: StateSource,
}

impl CostBreakdown {
    /// The objective the terms reconcile to: reward minus the transition,
    /// detection, and degradation penalties.
    pub fn objective(&self) -> f64 {
        self.running_reward
            - self.transition_penalty
            - self.detection_penalty
            - self.degradation_penalty
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cfg() -> UnicronConfig {
        UnicronConfig::default()
    }

    #[test]
    fn horizon_shrinks_with_pool_size_and_tighter_mtbf() {
        let mut cost = CostModel::from_config(&cfg());
        assert!(cost.horizon_s(128) < cost.horizon_s(64));
        assert_eq!(cost.horizon_s(0), 0.0);
        // 128 GPUs at the paper prior: failure gap slightly over a day (§2.2)
        let days = cost.horizon_s(128) / 86400.0;
        assert!((1.0..3.0).contains(&days), "{days} days");
        // a tightened estimate shortens every horizon
        let before = cost.horizon_s(128);
        assert!(cost.set_mtbf_per_gpu_s(cost.mtbf_per_gpu_s() / 4.0));
        assert!((cost.horizon_s(128) - before / 4.0).abs() < 1e-9 * before);
        // no-ops report unchanged
        let now = cost.mtbf_per_gpu_s();
        assert!(!cost.set_mtbf_per_gpu_s(now));
        assert!(!cost.set_mtbf_per_gpu_s(0.0));
        assert!(!cost.set_mtbf_per_gpu_s(-1.0));
        assert_eq!(cost.prior_mtbf_per_gpu_s(), cfg().mtbf_per_gpu_s);
    }

    #[test]
    fn profiles_price_bigger_models_higher_and_strategies_by_distance() {
        let cluster = ClusterSpec::default();
        let small = ModelSpec::gpt3("gpt3-1.3b").unwrap();
        let big = ModelSpec::gpt3("gpt3-13b").unwrap();
        let ps = TransitionProfile::from_model(&small, &cluster);
        let pb = TransitionProfile::from_model(&big, &cluster);
        assert!(pb.replica_s > ps.replica_s, "13B must cost more to move than 1.3B");
        // §6.3 nearest-principle ordering per model
        for p in [&ps, &pb] {
            assert!(p.replica_s < p.inmem_s && p.inmem_s < p.remote_s, "{p:?}");
            assert!(p.inmem_s < p.local_s, "peer memory beats local disk: {p:?}");
        }
        // the faulted strategy is the in-memory checkpoint
        assert_eq!(pb.migration_s(true), pb.inmem_s);
        assert_eq!(pb.migration_s(false), pb.replica_s);
        // flat profiles are uniform across strategies
        let f = TransitionProfile::flat(60.0);
        assert_eq!(f.migration_s(true), 60.0);
        assert_eq!(f.migration_s(false), 60.0);
    }

    #[test]
    fn transition_cost_adds_base_overhead_to_the_strategy_time() {
        let cost = CostModel::from_config(&cfg());
        let p = TransitionProfile::flat(5.0);
        assert_eq!(cost.transition_s(&p, false), cfg().transition_base_s + 5.0);
        let hetero =
            TransitionProfile { replica_s: 1.0, inmem_s: 3.0, local_s: 6.0, remote_s: 9.0 };
        assert_eq!(
            cost.transition_s(&hetero, true) - cost.transition_s(&hetero, false),
            2.0,
            "a faulted transition pays the farther strategy"
        );
        // the store-aware fault path prices exactly the resolved source…
        let base = cfg().transition_base_s;
        assert_eq!(
            cost.transition_from_s(&hetero, StateSource::LocalDiskCheckpoint, None),
            base + 6.0
        );
        // …and a measured restore estimate overrides the profile
        assert_eq!(
            cost.transition_from_s(&hetero, StateSource::LocalDiskCheckpoint, Some(0.4)),
            base + 0.4
        );
        // default resolution reproduces the formula fault path bit-for-bit
        assert_eq!(
            cost.transition_from_s(&hetero, StateSource::InMemoryCheckpoint, None),
            cost.transition_s(&hetero, true)
        );
    }

    #[test]
    fn from_store_keeps_the_formula_until_transfers_are_measured() {
        let cluster = ClusterSpec::default();
        let bytes = 50_000_000_000u64; // 50 GB
        let mut store = SnapshotStore::new(&cluster);
        // cold store: identical to the closed form, bit for bit
        assert_eq!(
            TransitionProfile::from_store(bytes, &cluster, &store),
            TransitionProfile::from_state_bytes(bytes, &cluster)
        );
        // a fast measured peer-memory transfer undercuts the formula's
        // 1 s lookup assumption; unmeasured tiers keep the prior
        store.observe_transfer(Tier::PeerMemory, bytes, 0.3 + bytes as f64 / 1e9 / 200.0);
        let p = TransitionProfile::from_store(bytes, &cluster, &store);
        let f = TransitionProfile::from_state_bytes(bytes, &cluster);
        assert!(p.inmem_s < f.inmem_s, "measured {} vs formula {}", p.inmem_s, f.inmem_s);
        assert_eq!(p.local_s, f.local_s);
        assert_eq!(p.remote_s, f.remote_s);
        assert_eq!(p.replica_s, f.replica_s, "the replica path never touches the store");
        // degenerate size stays degenerate even with measurements
        let z = TransitionProfile::from_store(0, &cluster, &store);
        assert_eq!(z, TransitionProfile::flat(0.0));
    }

    #[test]
    fn spare_decision_speaks_the_planner_currency() {
        let cost = CostModel::from_config(&cfg());
        let total_waf = 1e16;
        let node_waf = cost.marginal_node_waf(total_waf, 128, 8);
        assert!((node_waf - total_waf / 16.0).abs() < 1e-3);
        // the decision's terms are exactly the pool's value/cost arithmetic
        let (decision, terms) = cost.spare_decision(0, 128, total_waf, 8);
        assert!(terms.lambda > 0.0);
        assert_eq!(
            decision == SpareDecision::Retain,
            terms.value > terms.hold_cost,
            "verdict must follow the priced terms: {terms:?}"
        );
        // an empty pool protects nothing
        let (d, t) = cost.spare_decision(0, 0, 0.0, 8);
        assert_eq!(d, SpareDecision::Release);
        assert_eq!(t.value, 0.0);
        // a tighter MTBF raises the expected shortfall, never lowers it
        let mut tight = cost.clone();
        tight.set_mtbf_per_gpu_s(cost.mtbf_per_gpu_s() / 100.0);
        let t2 = tight.spare_terms(0, 128, node_waf);
        assert!(t2.lambda > terms.lambda);
        assert!(t2.value >= terms.value);
    }

    #[test]
    fn breakdown_objective_is_reward_minus_penalties() {
        let b = CostBreakdown {
            running_reward: 10.0,
            transition_penalty: 4.0,
            detection_penalty: 1.0,
            degradation_penalty: 2.0,
            horizon_s: 100.0,
            mtbf_per_gpu_s: 1e6,
            spare_value: 0.0,
            spare_hold_cost: 0.0,
            state_source: StateSource::InMemoryCheckpoint,
        };
        assert_eq!(b.objective(), 3.0);
        assert_eq!(CostBreakdown::default().objective(), 0.0);
        // fault-free default: the replica source
        assert_eq!(CostBreakdown::default().state_source, StateSource::DpReplica);
    }

    #[test]
    fn degradation_eviction_is_a_ledger_verdict() {
        let cost = CostModel::from_config(&cfg());
        // the verdict window is the 6-sample streaming-estimator default
        assert_eq!(cost.degradation_s(), DETECT_DEGRADATION_S);
        assert_eq!(DETECT_DEGRADATION_S, 6.0 * 45.0);
        let total_waf = 1e16;
        let node_waf = cost.marginal_node_waf(total_waf, 32, 8);
        // a severe straggler (50 % slow) forfeits more over the horizon
        // than the eviction costs — evict
        assert!(cost.degradation_decision(0.5, total_waf, node_waf, 32, 100.0));
        // a mild 10 % degradation is cheaper to tolerate than to lose a
        // quarter of the pool's marginal share — tolerate
        assert!(!cost.degradation_decision(0.10, total_waf, node_waf, 32, 100.0));
        // the break-even slope is node_waf/task_waf + transition_s/H:
        // losing the node entirely (slow_frac = 1.0) always beats keeping
        // a fully-stalled node when the transition is cheap
        assert!(cost.degradation_decision(1.0, total_waf, node_waf, 32, 100.0));
        // degenerate pool: horizon 0 means only the transition cost counts
        assert!(!cost.degradation_decision(0.9, total_waf, node_waf, 0, 100.0));
    }

    #[test]
    fn table2_detection_latencies_per_error_kind() {
        use crate::failure::Severity;
        // the four §4.1 methods map to their Table 2 times
        assert_eq!(detection_latency_s(ErrorKind::LostConnection), DETECT_NODE_HEALTH_S);
        assert_eq!(detection_latency_s(ErrorKind::ExitedAbnormally), DETECT_PROCESS_S);
        assert_eq!(detection_latency_s(ErrorKind::EccError), DETECT_EXCEPTION_S);
        assert_eq!(detection_latency_s(ErrorKind::TaskHang), DETECT_STATISTICAL_S);
        // total over the taxonomy: every kind has a finite positive latency,
        // and in-band methods beat the 30-minute NCCL timeout by far
        for &k in ErrorKind::all() {
            let d = detection_latency_s(k);
            assert!(d > 0.0 && d < 30.0 * 60.0, "{k:?}: {d}");
        }
        // the planner's faulted-task term is the SEV1 (node health) entry
        let cost = CostModel::from_config(&cfg());
        assert_eq!(cost.detection_s(), DETECT_NODE_HEALTH_S);
        assert_eq!(ErrorKind::LostConnection.severity(), Severity::Sev1);
    }
}
