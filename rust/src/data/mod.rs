//! Synthetic training data: a seeded first-order Markov "language" whose
//! entropy sits well below `log(vocab)`, so a GPT that is learning shows a
//! clearly decreasing loss curve (the end-to-end validation signal in
//! EXPERIMENTS.md), while being fully deterministic and self-contained.

use crate::rng::{Rand, Xoshiro256};

/// Markov-chain corpus: each token has `branch` likely successors taken with
/// probability `1 - noise`, otherwise a uniform token.
#[derive(Debug, Clone)]
pub struct SyntheticCorpus {
    vocab: usize,
    branch: usize,
    noise: f64,
    successors: Vec<Vec<u32>>,
    seed: u64,
}

impl SyntheticCorpus {
    pub fn new(vocab: usize, seed: u64) -> SyntheticCorpus {
        assert!(vocab >= 4);
        let branch = 4.min(vocab - 1);
        let mut rng = Xoshiro256::seed_from_u64(seed ^ 0xDA7A);
        let successors = (0..vocab)
            .map(|_| (0..branch).map(|_| rng.below(vocab as u64) as u32).collect())
            .collect();
        SyntheticCorpus { vocab, branch, noise: 0.1, successors, seed }
    }

    pub fn vocab(&self) -> usize {
        self.vocab
    }

    /// Theoretical per-token entropy (nats) — the loss floor a perfect model
    /// approaches: `H ≈ (1-noise)·log(branch) + noise·log(vocab)` plus the
    /// mixing cross terms; this upper-bound form is good enough for asserts.
    pub fn entropy_upper_bound(&self) -> f64 {
        (1.0 - self.noise) * (self.branch as f64).ln() + self.noise * (self.vocab as f64).ln()
    }

    /// One sequence of `len` tokens. Deterministic in `(seed, sequence_id)`:
    /// the same id always yields the same tokens, which is what makes
    /// micro-batch *recomputation* after redistribution (paper Eq. 7)
    /// reproduce identical gradients.
    pub fn sequence(&self, sequence_id: u64, len: usize) -> Vec<i32> {
        let mut rng = Xoshiro256::seed_from_u64(self.seed ^ sequence_id.wrapping_mul(0x9E3779B97F4A7C15));
        let mut out = Vec::with_capacity(len);
        let mut cur = rng.below(self.vocab as u64) as u32;
        out.push(cur as i32);
        for _ in 1..len {
            cur = if rng.f64() < self.noise {
                rng.below(self.vocab as u64) as u32
            } else {
                let succ = &self.successors[cur as usize];
                *rng.choose(succ)
            };
            out.push(cur as i32);
        }
        out
    }

    /// Row-major `(micro_batch, len)` token block for micro-batch
    /// `micro_batch_id` of iteration `iter`. Sequence ids are derived from
    /// `(iter, micro_batch_id, row)` so every micro-batch is globally unique
    /// but reproducible.
    pub fn micro_batch(&self, iter: u64, micro_batch_id: u64, rows: usize, len: usize) -> Vec<i32> {
        let mut out = Vec::with_capacity(rows * len);
        for row in 0..rows {
            let sid = iter.wrapping_mul(1_000_003) ^ micro_batch_id.wrapping_mul(10_007) ^ row as u64;
            out.extend(self.sequence(sid, len));
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_per_sequence_id() {
        let c = SyntheticCorpus::new(256, 7);
        assert_eq!(c.sequence(5, 64), c.sequence(5, 64));
        assert_ne!(c.sequence(5, 64), c.sequence(6, 64));
        let c2 = SyntheticCorpus::new(256, 8);
        assert_ne!(c.sequence(5, 64), c2.sequence(5, 64));
    }

    #[test]
    fn tokens_in_vocab_range() {
        let c = SyntheticCorpus::new(100, 1);
        for t in c.micro_batch(3, 2, 4, 33) {
            assert!((0..100).contains(&t));
        }
    }

    #[test]
    fn micro_batch_shape_and_reproducibility() {
        let c = SyntheticCorpus::new(256, 42);
        let a = c.micro_batch(10, 3, 4, 33);
        assert_eq!(a.len(), 4 * 33);
        assert_eq!(a, c.micro_batch(10, 3, 4, 33));
        assert_ne!(a, c.micro_batch(11, 3, 4, 33));
        assert_ne!(a, c.micro_batch(10, 4, 4, 33));
    }

    #[test]
    fn chain_is_predictable() {
        // Empirical successor concentration: the most frequent successor of a
        // token should be far above uniform (1/vocab).
        let c = SyntheticCorpus::new(64, 3);
        let mut counts = vec![vec![0u32; 64]; 64];
        for sid in 0..200 {
            let s = c.sequence(sid, 128);
            for w in s.windows(2) {
                counts[w[0] as usize][w[1] as usize] += 1;
            }
        }
        let mut concentrated = 0;
        for row in &counts {
            let total: u32 = row.iter().sum();
            if total >= 20 {
                let max = *row.iter().max().unwrap();
                if max as f64 / total as f64 > 3.0 / 64.0 {
                    concentrated += 1;
                }
            }
        }
        assert!(concentrated > 32, "chain structure too weak: {concentrated}");
    }

    #[test]
    fn entropy_bound_below_uniform() {
        let c = SyntheticCorpus::new(256, 0);
        assert!(c.entropy_upper_bound() < (256f64).ln() * 0.6);
    }
}
