//! In-band error detection (§4.1): the analysis pieces behind the four
//! detection methods. The *wiring* (heartbeat threads, process polling)
//! lives in [`crate::agent`]; this module holds the testable logic:
//!
//! * [`StatMonitor`] — online statistical monitoring of iteration completion
//!   times (Fig. 6): warn at `1.1×` the running average, declare failure at
//!   `3×` (both configurable; §4.1 found 3× the practical balance).
//! * [`classify_exception`] — exception propagation: map a raised exception
//!   string to the Table 1 [`ErrorKind`].
//!
//! The agent-local window monitor here answers "is *my* step late?" with a
//! hard verdict. The coordinator-side complement is [`crate::health`]: it
//! ingests the whole fleet's step-timing streams (wire v8
//! `CoordEvent::StepTiming`), holds a per-node EWMA/MAD baseline, and
//! classifies *gray* degradation — stragglers and partial-bandwidth nodes
//! that never trip a hard failure — so eviction can be priced through the
//! cost ledger instead of declared here.

use std::collections::VecDeque;

use crate::failure::ErrorKind;

/// Health verdict from the statistical monitor.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum StatStatus {
    /// Within the normal band.
    Normal,
    /// Above `warn_factor ×` average — network variation/congestion; keep
    /// going (the red-dot region of Fig. 6).
    Degraded,
    /// Above `fail_factor ×` average — declare a failure (grey line).
    Failed,
    /// Not enough samples to judge yet.
    Unknown,
}

/// Online statistical monitor over iteration completion times.
#[derive(Debug, Clone)]
pub struct StatMonitor {
    window: VecDeque<f64>,
    capacity: usize,
    min_samples: usize,
    warn_factor: f64,
    fail_factor: f64,
    sum: f64,
}

impl StatMonitor {
    pub fn new(warn_factor: f64, fail_factor: f64) -> StatMonitor {
        assert!(fail_factor > warn_factor && warn_factor >= 1.0);
        StatMonitor {
            window: VecDeque::new(),
            capacity: 100,
            min_samples: 5,
            warn_factor,
            fail_factor,
            sum: 0.0,
        }
    }

    /// Paper defaults: 1.1× warn, 3× fail.
    pub fn paper_defaults() -> StatMonitor {
        Self::new(1.1, 3.0)
    }

    /// Record a *completed* iteration's duration.
    pub fn record(&mut self, duration_s: f64) {
        assert!(duration_s.is_finite() && duration_s >= 0.0);
        self.window.push_back(duration_s);
        self.sum += duration_s;
        if self.window.len() > self.capacity {
            self.sum -= self.window.pop_front().unwrap();
        }
    }

    pub fn average(&self) -> Option<f64> {
        if self.window.len() < self.min_samples {
            None
        } else {
            Some(self.sum / self.window.len() as f64)
        }
    }

    /// Judge the *currently running* iteration given how long it has been
    /// executing so far.
    pub fn check(&self, elapsed_s: f64) -> StatStatus {
        match self.average() {
            None => StatStatus::Unknown,
            Some(avg) => {
                if elapsed_s >= self.fail_factor * avg {
                    StatStatus::Failed
                } else if elapsed_s >= self.warn_factor * avg {
                    StatStatus::Degraded
                } else {
                    StatStatus::Normal
                }
            }
        }
    }

    /// Seconds after which the running iteration becomes `Failed` —
    /// Table 2's case-4 detection time (`3 × D_iter`).
    pub fn failure_deadline(&self) -> Option<f64> {
        self.average().map(|avg| self.fail_factor * avg)
    }

    pub fn samples(&self) -> usize {
        self.window.len()
    }
}

/// Exception propagation (§4.1): classify a raised exception message.
///
/// Matching is deliberately substring-based and case-insensitive — this is
/// what production log classifiers do, and it keeps the table auditable.
pub fn classify_exception(msg: &str) -> ErrorKind {
    let m = msg.to_ascii_lowercase();
    let has = |pat: &str| m.contains(pat);
    if has("ecc") {
        ErrorKind::EccError
    } else if has("nvlink") {
        ErrorKind::NvlinkError
    } else if has("dma") {
        ErrorKind::InvalidDmaMapping
    } else if has("driver") {
        ErrorKind::GpuDriverError
    } else if has("illegal memory") || has("illegal address") {
        ErrorKind::IllegalMemoryAccess
    } else if has("cuda") {
        ErrorKind::CudaError
    } else if has("nccl") && (has("timeout") || has("timed out")) {
        ErrorKind::NcclTimeout
    } else if has("connection refused") || has("connection reset") {
        ErrorKind::ConnectionRefused
    } else if has("link") && has("flap") {
        ErrorKind::LinkFlapping
    } else if has("network") || has("socket") || has("unreachable") {
        ErrorKind::OtherNetworkError
    } else if has("hang") || has("stall") {
        ErrorKind::TaskHang
    } else {
        ErrorKind::OtherSoftwareError
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::failure::Severity;

    #[test]
    fn monitor_needs_minimum_samples() {
        let mut m = StatMonitor::paper_defaults();
        assert_eq!(m.check(100.0), StatStatus::Unknown);
        for _ in 0..4 {
            m.record(10.0);
        }
        assert_eq!(m.check(100.0), StatStatus::Unknown);
        m.record(10.0);
        assert_eq!(m.check(100.0), StatStatus::Failed);
    }

    #[test]
    fn thresholds_match_fig6() {
        let mut m = StatMonitor::paper_defaults();
        for _ in 0..10 {
            m.record(10.0);
        }
        assert_eq!(m.average(), Some(10.0));
        assert_eq!(m.check(10.5), StatStatus::Normal);
        assert_eq!(m.check(11.0), StatStatus::Degraded); // 1.1×: keep going
        assert_eq!(m.check(29.9), StatStatus::Degraded);
        assert_eq!(m.check(30.0), StatStatus::Failed); // 3×: failure
        assert_eq!(m.failure_deadline(), Some(30.0));
    }

    #[test]
    fn window_adapts_to_new_regime() {
        let mut m = StatMonitor::paper_defaults();
        for _ in 0..100 {
            m.record(10.0);
        }
        // workload legitimately slows (reconfiguration to fewer GPUs)
        for _ in 0..200 {
            m.record(20.0);
        }
        let avg = m.average().unwrap();
        assert!((avg - 20.0).abs() < 0.5, "window should track the new regime, avg={avg}");
    }

    #[test]
    fn minor_fluctuation_stays_normal() {
        let mut m = StatMonitor::paper_defaults();
        for i in 0..50 {
            m.record(10.0 + 0.3 * ((i % 5) as f64 - 2.0)); // ±0.6 jitter
        }
        assert_eq!(m.check(10.4), StatStatus::Normal);
    }

    #[test]
    #[should_panic]
    fn rejects_inverted_thresholds() {
        StatMonitor::new(3.0, 1.1);
    }

    #[test]
    fn exception_classification_table1() {
        use ErrorKind::*;
        let cases = [
            ("GPU 3: uncorrectable ECC error encountered", EccError),
            ("NVLink transmission error on link 2", NvlinkError),
            ("invalid DMA mapping for buffer", InvalidDmaMapping),
            ("NVIDIA driver wedged, reinitializing", GpuDriverError),
            ("CUDA error: an illegal memory access was encountered", IllegalMemoryAccess),
            ("CUDA_ERROR_LAUNCH_FAILED", CudaError),
            ("NCCL watchdog: collective timed out after 1800s", NcclTimeout),
            ("connect: Connection refused", ConnectionRefused),
            ("eth2: link flap detected", LinkFlapping),
            ("socket closed by peer", OtherNetworkError),
            ("training loop hang detected", TaskHang),
            ("KeyError: 'optimizer'", OtherSoftwareError),
        ];
        for (msg, want) in cases {
            assert_eq!(classify_exception(msg), want, "{msg}");
        }
    }

    #[test]
    fn classified_severities_sane() {
        // ECC must be SEV1, CUDA SEV2, NCCL timeout SEV3 (Table 1)
        assert_eq!(classify_exception("double-bit ECC").severity(), Severity::Sev1);
        assert_eq!(classify_exception("CUDA error 700").severity(), Severity::Sev2);
        assert_eq!(classify_exception("NCCL timeout").severity(), Severity::Sev3);
    }
}
