//! Deterministic event engine — the one clock every healing decision runs on.
//!
//! FoundationDB-style deterministic simulation only pays off if the *same*
//! scheduling substrate drives production and test code. This module owns
//! the seeded, totally-ordered event queue that used to live inside the
//! discrete-event simulator:
//!
//! * [`EventQueue`] — a min-heap of `(time, seq)`-ordered events. Time is
//!   compared with [`f64::total_cmp`], so NaN/-0.0 can never corrupt heap
//!   order (a NaN comparing `Equal` to everything silently breaks the heap
//!   invariant and with it replay determinism). `seq` breaks ties FIFO, so
//!   two events at the same instant always pop in schedule order.
//! * Scheduled events are cancelable: [`EventQueue::schedule`] returns an
//!   [`EventId`]; [`EventQueue::cancel`] tombstones it and pop skips it.
//!   (The simulator currently supersedes stale `RecoveryDone` events with
//!   its per-task epoch counters; cancelation is the engine-level
//!   alternative for callers that hold on to their `EventId`s. `cancel` is
//!   O(pending) per call — fine at trace scale, not for hot loops.)
//! * [`EngineClock`] — a [`crate::util::Clock`] view of the queue's current
//!   time, so components written against the clock abstraction (detectors,
//!   the live loop's lease logic) read simulated time transparently.
//!
//! The discrete-event simulator ([`crate::simulator`]) advances the queue to
//! exhaustion; the live driver ([`crate::coordinator::live`]) uses the same
//! queue for its timed work (due-date ordering of deferred commands) against
//! wall-clock `now`. Same ordering rules either way — which is what makes a
//! recorded simulation seed a faithful regression test of production logic.

use std::cmp::Ordering as CmpOrdering;
use std::collections::BinaryHeap;
use std::collections::HashSet;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;

use crate::util::Clock;

/// Handle to a scheduled event; pass to [`EventQueue::cancel`].
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct EventId(u64);

/// One queue entry: an event `ev` due at simulated/wall time `at`.
#[derive(Debug, Clone)]
struct Scheduled<E> {
    at: f64,
    seq: u64,
    ev: E,
}

impl<E> PartialEq for Scheduled<E> {
    fn eq(&self, other: &Self) -> bool {
        self.at.total_cmp(&other.at) == CmpOrdering::Equal && self.seq == other.seq
    }
}
impl<E> Eq for Scheduled<E> {}
impl<E> PartialOrd for Scheduled<E> {
    fn partial_cmp(&self, other: &Self) -> Option<CmpOrdering> {
        Some(self.cmp(other))
    }
}
impl<E> Ord for Scheduled<E> {
    fn cmp(&self, other: &Self) -> CmpOrdering {
        // min-heap by (time, seq): reverse both operands. `total_cmp` is a
        // total order over all f64 bit patterns — no NaN escape hatch.
        other.at.total_cmp(&self.at).then(other.seq.cmp(&self.seq))
    }
}

/// Deterministic `(time, seq)`-ordered event queue with cancelation.
///
/// Determinism contract: given the same sequence of `schedule`/`cancel`
/// calls, `pop` returns the same events at the same times, bit-for-bit.
/// There is no wall-clock, thread, or hash-order dependence anywhere in the
/// dispatch path (`HashSet` is only membership-tested, never iterated).
#[derive(Debug)]
pub struct EventQueue<E> {
    heap: BinaryHeap<Scheduled<E>>,
    /// Monotone tie-breaker; doubles as the `EventId` namespace.
    seq: u64,
    canceled: HashSet<u64>,
    /// Time of the most recently popped event (the engine's "now").
    now: f64,
}

impl<E> Default for EventQueue<E> {
    fn default() -> Self {
        EventQueue::new()
    }
}

impl<E> EventQueue<E> {
    pub fn new() -> EventQueue<E> {
        EventQueue { heap: BinaryHeap::new(), seq: 0, canceled: HashSet::new(), now: 0.0 }
    }

    /// Current engine time: the timestamp of the last popped event (0 before
    /// the first pop). The simulator treats this as simulated "now".
    pub fn now(&self) -> f64 {
        self.now
    }

    /// Number of live (not-yet-canceled) pending events.
    pub fn len(&self) -> usize {
        self.heap.len() - self.canceled.len()
    }

    /// Lifetime count of events ever scheduled (including popped and
    /// canceled ones) — with [`EventQueue::len`], the queue's contribution
    /// to a `/fleet/metrics` report: total throughput and current depth.
    pub fn scheduled_total(&self) -> u64 {
        self.seq
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Schedule `ev` at absolute time `at`. Returns a cancelation handle.
    ///
    /// `at` may be in the past (≤ `now`); the event still pops, at its
    /// scheduled position in the total order — deterministic replay must not
    /// silently drop late work.
    pub fn schedule(&mut self, at: f64, ev: E) -> EventId {
        assert!(!at.is_nan(), "event time must not be NaN");
        self.seq += 1;
        self.heap.push(Scheduled { at, seq: self.seq, ev });
        EventId(self.seq)
    }

    /// Cancel a previously scheduled event. Returns true if the event was
    /// still pending (false if it already popped or was already canceled).
    pub fn cancel(&mut self, id: EventId) -> bool {
        if id.0 == 0 || id.0 > self.seq {
            return false;
        }
        // An id can only be tombstoned while its entry is still in the heap;
        // pop() removes tombstones as it encounters them.
        if self.heap.iter().any(|s| s.seq == id.0) {
            self.canceled.insert(id.0)
        } else {
            false
        }
    }

    /// Pop the earliest live event, advancing `now` to its timestamp.
    pub fn pop(&mut self) -> Option<(f64, E)> {
        while let Some(s) = self.heap.pop() {
            if self.canceled.remove(&s.seq) {
                continue; // tombstoned
            }
            self.now = s.at;
            return Some((s.at, s.ev));
        }
        None
    }

    /// Earliest pending event time without popping (skips canceled entries).
    pub fn peek_time(&mut self) -> Option<f64> {
        while let Some(s) = self.heap.peek() {
            if self.canceled.contains(&s.seq) {
                let seq = s.seq;
                self.heap.pop();
                self.canceled.remove(&seq);
                continue;
            }
            return Some(s.at);
        }
        None
    }

    /// Earliest pending event — time and a borrow of its payload — without
    /// popping. Canceled head entries are pruned, exactly like
    /// [`EventQueue::peek_time`]. Lets a consumer decide whether the next
    /// event belongs to the batch it is currently draining.
    pub fn peek(&mut self) -> Option<(f64, &E)> {
        self.peek_time()?; // prune tombstones off the head
        self.heap.peek().map(|s| (s.at, &s.ev))
    }

    /// Schedule a burst of events at the same instant. They pop in iterator
    /// order (FIFO seqs), and [`EventQueue::pop_simultaneous`] returns the
    /// whole burst in one call. Returns the cancelation handles in order.
    pub fn schedule_batch(&mut self, at: f64, evs: impl IntoIterator<Item = E>) -> Vec<EventId> {
        evs.into_iter().map(|ev| self.schedule(at, ev)).collect()
    }

    /// Pop the earliest live event *and* every further live event due at the
    /// bit-identical instant (`total_cmp` equality), in seq order — the
    /// engine half of batched dispatch: a burst of N simultaneous events
    /// costs its consumer one dispatch cycle instead of N. Returns an empty
    /// vec when the queue is drained.
    pub fn pop_simultaneous(&mut self) -> Vec<(f64, E)> {
        let Some((at, ev)) = self.pop() else { return Vec::new() };
        let mut batch = vec![(at, ev)];
        while matches!(self.peek_time(), Some(t) if t.total_cmp(&at) == CmpOrdering::Equal) {
            if let Some(e) = self.pop() {
                batch.push(e);
            }
        }
        batch
    }

    /// Drain every event due at or before `deadline`, in order. Used by the
    /// live loop: each tick collects the work that has come due.
    pub fn pop_due(&mut self, deadline: f64) -> Vec<(f64, E)> {
        let mut due = Vec::new();
        while matches!(self.peek_time(), Some(t) if t.total_cmp(&deadline) != CmpOrdering::Greater)
        {
            if let Some(e) = self.pop() {
                due.push(e);
            }
        }
        due
    }
}

/// Shared, thread-safe view of engine time implementing [`Clock`].
///
/// `sleep` is a no-op: under the engine, time advances only when the queue
/// pops an event, never by blocking.
#[derive(Debug, Default)]
pub struct EngineClock {
    micros: AtomicU64,
}

impl EngineClock {
    pub fn new() -> Arc<EngineClock> {
        Arc::new(EngineClock { micros: AtomicU64::new(0) })
    }

    /// Advance the clock to `t` seconds (monotone; earlier values ignored).
    pub fn advance_to(&self, t: f64) {
        let target = (t * 1e6).max(0.0) as u64;
        self.micros.fetch_max(target, Ordering::Relaxed);
    }
}

impl Clock for EngineClock {
    fn now(&self) -> f64 {
        self.micros.load(Ordering::Relaxed) as f64 / 1e6
    }

    fn sleep(&self, _seconds: f64) {}
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn pops_in_time_then_seq_order() {
        let mut q = EventQueue::new();
        q.schedule(5.0, "b");
        q.schedule(1.0, "a");
        q.schedule(5.0, "c"); // same instant as "b": FIFO by seq
        q.schedule(0.5, "first");
        let order: Vec<&str> = std::iter::from_fn(|| q.pop().map(|(_, e)| e)).collect();
        assert_eq!(order, vec!["first", "a", "b", "c"]);
    }

    #[test]
    fn now_tracks_last_pop() {
        let mut q = EventQueue::new();
        assert_eq!(q.now(), 0.0);
        q.schedule(2.5, ());
        q.schedule(7.0, ());
        q.pop();
        assert_eq!(q.now(), 2.5);
        q.pop();
        assert_eq!(q.now(), 7.0);
    }

    #[test]
    fn cancel_skips_event() {
        let mut q = EventQueue::new();
        let a = q.schedule(1.0, "a");
        q.schedule(2.0, "b");
        assert!(q.cancel(a));
        assert!(!q.cancel(a), "double-cancel is a no-op");
        assert_eq!(q.len(), 1);
        assert_eq!(q.pop(), Some((2.0, "b")));
        assert_eq!(q.pop(), None);
    }

    #[test]
    fn cancel_after_pop_is_noop() {
        let mut q = EventQueue::new();
        let a = q.schedule(1.0, "a");
        assert_eq!(q.pop(), Some((1.0, "a")));
        assert!(!q.cancel(a));
    }

    #[test]
    fn negative_zero_and_subnormals_keep_total_order() {
        // total_cmp: -0.0 < +0.0, and neither compares Equal to the other —
        // the partial_cmp(..).unwrap_or(Equal) bug class this engine fixes.
        let mut q = EventQueue::new();
        q.schedule(0.0, "pos");
        q.schedule(-0.0, "neg");
        assert_eq!(q.pop().unwrap().1, "neg");
        assert_eq!(q.pop().unwrap().1, "pos");
    }

    #[test]
    #[should_panic(expected = "NaN")]
    fn nan_times_are_rejected_at_the_door() {
        let mut q = EventQueue::new();
        q.schedule(f64::NAN, ());
    }

    #[test]
    fn pop_due_drains_only_due_work() {
        let mut q = EventQueue::new();
        q.schedule(1.0, 1u32);
        q.schedule(2.0, 2u32);
        q.schedule(3.0, 3u32);
        let due = q.pop_due(2.0);
        assert_eq!(due, vec![(1.0, 1), (2.0, 2)]);
        assert_eq!(q.len(), 1);
    }

    #[test]
    fn deterministic_under_identical_schedules() {
        let run = || {
            let mut q = EventQueue::new();
            let mut ids = Vec::new();
            for i in 0..200u64 {
                // adversarial times: duplicates and reverse order
                let t = ((i * 7919) % 97) as f64 / 3.0;
                ids.push(q.schedule(t, i));
            }
            for id in ids.iter().step_by(3) {
                q.cancel(*id);
            }
            let mut out = Vec::new();
            while let Some((t, e)) = q.pop() {
                out.push((t.to_bits(), e));
            }
            out
        };
        assert_eq!(run(), run());
    }

    #[test]
    fn peek_shows_head_without_popping() {
        let mut q = EventQueue::new();
        let a = q.schedule(1.0, "a");
        q.schedule(2.0, "b");
        assert_eq!(q.peek(), Some((1.0, &"a")));
        assert_eq!(q.len(), 2, "peek must not consume");
        q.cancel(a);
        assert_eq!(q.peek(), Some((2.0, &"b")), "canceled head is pruned");
        q.pop();
        assert_eq!(q.peek(), None);
    }

    #[test]
    fn schedule_batch_pops_fifo_and_cancels_individually() {
        let mut q = EventQueue::new();
        q.schedule(0.5, "early");
        let ids = q.schedule_batch(3.0, ["x", "y", "z"]);
        assert_eq!(ids.len(), 3);
        assert!(q.cancel(ids[1]));
        let order: Vec<&str> = std::iter::from_fn(|| q.pop().map(|(_, e)| e)).collect();
        assert_eq!(order, vec!["early", "x", "z"]);
    }

    #[test]
    fn pop_simultaneous_returns_bitwise_equal_bursts() {
        let mut q = EventQueue::new();
        q.schedule(1.0, 10u32);
        q.schedule_batch(4.0, [1u32, 2, 3]);
        // -0.0 and +0.0 are distinct under total_cmp: NOT the same burst
        q.schedule(0.0, 20u32);
        q.schedule(-0.0, 21u32);
        assert_eq!(q.pop_simultaneous(), vec![(-0.0, 21)]);
        assert_eq!(q.pop_simultaneous(), vec![(0.0, 20)]);
        assert_eq!(q.pop_simultaneous(), vec![(1.0, 10)]);
        assert_eq!(q.pop_simultaneous(), vec![(4.0, 1), (4.0, 2), (4.0, 3)]);
        assert_eq!(q.now(), 4.0, "now advances to the burst instant");
        assert!(q.pop_simultaneous().is_empty());
    }

    #[test]
    fn scheduled_total_counts_lifetime_events() {
        let mut q = EventQueue::new();
        assert_eq!(q.scheduled_total(), 0);
        let a = q.schedule(1.0, ());
        q.schedule(2.0, ());
        q.cancel(a);
        q.pop();
        // cancels and pops shrink the depth, never the lifetime count
        assert_eq!(q.scheduled_total(), 2);
        assert_eq!(q.len(), 0);
    }

    #[test]
    fn engine_clock_is_monotone() {
        let c = EngineClock::new();
        c.advance_to(4.0);
        c.advance_to(2.0); // ignored: never goes backwards
        assert!((c.now() - 4.0).abs() < 1e-9);
        c.sleep(100.0); // no-op, returns immediately
        assert!((c.now() - 4.0).abs() < 1e-9);
    }
}
