//! Failure taxonomy (paper Table 1) and failure statistics (Fig. 1).
//!
//! Every error the system can observe carries an [`ErrorKind`]; the mapping
//! to a [`Severity`] and a [`DetectionMethod`] is the paper's Table 1,
//! reproduced verbatim by [`ErrorKind::severity`] / [`ErrorKind::detector`].

pub mod trace;

pub use trace::{FailureEvent, LifecycleKind, TaskLifecycle, Trace, TraceConfig};

/// Severity drives the §4.2 handling workflow: SEV3 → reattempt in place,
/// SEV2 → restart process, SEV1 → isolate node + reconfigure cluster.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub enum Severity {
    /// Most severe: node must be drained (hardware / driver level).
    Sev1,
    /// Process-level: restart the training process on the node.
    Sev2,
    /// Transient: reattempt the failed operation in place.
    Sev3,
}

/// The four in-band detection methods of §4.1.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum DetectionMethod {
    NodeHealthMonitoring,
    ProcessSupervision,
    ExceptionPropagation,
    OnlineStatisticalMonitoring,
}

/// Error statuses — the rows of Table 1.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum ErrorKind {
    // node health monitoring
    LostConnection,
    // process supervision
    ExitedAbnormally,
    // exception propagation
    ConnectionRefused,
    IllegalMemoryAccess,
    EccError,
    InvalidDmaMapping,
    CudaError,
    NvlinkError,
    GpuDriverError,
    OtherNetworkError,
    OtherSoftwareError,
    // online statistical monitoring
    NcclTimeout,
    LinkFlapping,
    TaskHang,
    SlowSoftwareError,
}

impl ErrorKind {
    /// Table 1, column "Severity".
    pub fn severity(self) -> Severity {
        use ErrorKind::*;
        match self {
            LostConnection => Severity::Sev1,
            ExitedAbnormally => Severity::Sev2,
            ConnectionRefused => Severity::Sev3,
            IllegalMemoryAccess => Severity::Sev2,
            EccError => Severity::Sev1,
            InvalidDmaMapping => Severity::Sev1,
            CudaError => Severity::Sev2,
            NvlinkError => Severity::Sev1,
            GpuDriverError => Severity::Sev1,
            OtherNetworkError => Severity::Sev3,
            OtherSoftwareError => Severity::Sev2,
            NcclTimeout => Severity::Sev3,
            LinkFlapping => Severity::Sev3,
            TaskHang => Severity::Sev2,
            SlowSoftwareError => Severity::Sev2,
        }
    }

    /// Table 1, column "Detection method".
    pub fn detector(self) -> DetectionMethod {
        use DetectionMethod::*;
        use ErrorKind::*;
        match self {
            LostConnection => NodeHealthMonitoring,
            ExitedAbnormally => ProcessSupervision,
            ConnectionRefused | IllegalMemoryAccess | EccError | InvalidDmaMapping | CudaError
            | NvlinkError | GpuDriverError | OtherNetworkError | OtherSoftwareError => {
                ExceptionPropagation
            }
            NcclTimeout | LinkFlapping | TaskHang | SlowSoftwareError => {
                OnlineStatisticalMonitoring
            }
        }
    }

    pub fn all() -> &'static [ErrorKind] {
        use ErrorKind::*;
        &[
            LostConnection,
            ExitedAbnormally,
            ConnectionRefused,
            IllegalMemoryAccess,
            EccError,
            InvalidDmaMapping,
            CudaError,
            NvlinkError,
            GpuDriverError,
            OtherNetworkError,
            OtherSoftwareError,
            NcclTimeout,
            LinkFlapping,
            TaskHang,
            SlowSoftwareError,
        ]
    }

    /// Stable snake_case wire name — the [`crate::proto`] serialization of
    /// this kind. Unknown names are rejected on decode (versioning rule).
    pub fn name(self) -> &'static str {
        use ErrorKind::*;
        match self {
            LostConnection => "lost_connection",
            ExitedAbnormally => "exited_abnormally",
            ConnectionRefused => "connection_refused",
            IllegalMemoryAccess => "illegal_memory_access",
            EccError => "ecc_error",
            InvalidDmaMapping => "invalid_dma_mapping",
            CudaError => "cuda_error",
            NvlinkError => "nvlink_error",
            GpuDriverError => "gpu_driver_error",
            OtherNetworkError => "other_network_error",
            OtherSoftwareError => "other_software_error",
            NcclTimeout => "nccl_timeout",
            LinkFlapping => "link_flapping",
            TaskHang => "task_hang",
            SlowSoftwareError => "slow_software_error",
        }
    }

    /// Inverse of [`ErrorKind::name`].
    pub fn from_name(s: &str) -> Option<ErrorKind> {
        ErrorKind::all().iter().copied().find(|k| k.name() == s)
    }

    /// Representative split of §1/§2.2: ~73 % of failures are transient
    /// (restart suffices — SEV2/SEV3), 37 % of the *hardware-related* ones
    /// need node drain (SEV1). Used by the trace generator's kind sampler.
    pub fn is_transient(self) -> bool {
        self.severity() != Severity::Sev1
    }
}

/// Fig. 1 — distribution of task termination statistics. The paper's raw
/// logs are proprietary; this reproduces the published shape: failure rate
/// grows steeply with task resource share, hitting 43.4 % for the top-5 %
/// tasks.
#[derive(Debug, Clone)]
pub struct TerminationStats {
    /// (resource percentile bucket label, abnormal-termination rate).
    pub buckets: Vec<(&'static str, f64)>,
}

impl TerminationStats {
    pub fn published() -> TerminationStats {
        TerminationStats {
            buckets: vec![
                ("p0-50", 0.021),
                ("p50-75", 0.054),
                ("p75-90", 0.124),
                ("p90-95", 0.221),
                ("p95-100", 0.434),
            ],
        }
    }

    /// Failure rate for the top-5% bucket — the headline 43.4 % number.
    pub fn top5_rate(&self) -> f64 {
        self.buckets.last().map(|b| b.1).unwrap_or(0.0)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table1_mapping_is_total_and_matches_paper() {
        use DetectionMethod::*;
        use ErrorKind::*;
        // spot checks straight from Table 1
        assert_eq!(LostConnection.severity(), Severity::Sev1);
        assert_eq!(LostConnection.detector(), NodeHealthMonitoring);
        assert_eq!(ExitedAbnormally.severity(), Severity::Sev2);
        assert_eq!(ExitedAbnormally.detector(), ProcessSupervision);
        assert_eq!(EccError.severity(), Severity::Sev1);
        assert_eq!(CudaError.severity(), Severity::Sev2);
        assert_eq!(NvlinkError.severity(), Severity::Sev1);
        assert_eq!(NcclTimeout.severity(), Severity::Sev3);
        assert_eq!(NcclTimeout.detector(), OnlineStatisticalMonitoring);
        assert_eq!(LinkFlapping.severity(), Severity::Sev3);
        assert_eq!(TaskHang.severity(), Severity::Sev2);
        // totality: every kind classifies without panicking
        for &k in ErrorKind::all() {
            let _ = (k.severity(), k.detector());
        }
        assert_eq!(ErrorKind::all().len(), 15);
    }

    #[test]
    fn severity_orders_by_urgency() {
        assert!(Severity::Sev1 < Severity::Sev2);
        assert!(Severity::Sev2 < Severity::Sev3);
    }

    #[test]
    fn transient_majority() {
        // §1: 73% of failures are remediable by restart. In the taxonomy the
        // transient kinds must outnumber SEV1 kinds.
        let transient = ErrorKind::all().iter().filter(|k| k.is_transient()).count();
        assert!(transient as f64 / ErrorKind::all().len() as f64 > 0.6);
    }

    #[test]
    fn fig1_shape() {
        let s = TerminationStats::published();
        assert_eq!(s.top5_rate(), 0.434);
        // monotone increasing failure rate with resource share
        for w in s.buckets.windows(2) {
            assert!(w[1].1 > w[0].1);
        }
    }
}
