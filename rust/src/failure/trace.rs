//! Failure traces (paper §7.5): *trace-a* — 8 weeks, 10 SEV1 + 33 other
//! failures on a 128-GPU/16-node cluster, node repair uniform in 1–7 days;
//! *trace-b* — the same cluster with failure frequency amplified 20×,
//! 7 days, ~26 SEV1 + ~80 other failures, repaired nodes rejoining at a
//! similar rate. Arrivals are Poisson; all draws are seeded.

use crate::failure::{ErrorKind, Severity};
use crate::health::DegradationKind;
use crate::proto::{NodeId, TaskId};
use crate::rng::{Rand, Xoshiro256};

/// One failure occurrence in a trace.
#[derive(Debug, Clone, PartialEq)]
pub struct FailureEvent {
    /// Seconds from trace start.
    pub at_s: f64,
    pub kind: ErrorKind,
    /// Node the failure hits.
    pub node: NodeId,
    /// For SEV1 (node-drain) failures: seconds until the node is repaired
    /// and rejoins. 0 for SEV2/SEV3.
    pub repair_after_s: f64,
}

impl FailureEvent {
    pub fn severity(&self) -> Severity {
        self.kind.severity()
    }
}

/// Parameters of a synthetic trace.
#[derive(Debug, Clone)]
pub struct TraceConfig {
    pub name: String,
    pub duration_s: f64,
    pub n_nodes: u32,
    /// Expected SEV1 count over the whole duration.
    pub expect_sev1: f64,
    /// Expected SEV2+SEV3 count over the whole duration.
    pub expect_other: f64,
    /// Repair time bounds for SEV1 (uniform draw), seconds.
    pub repair_min_s: f64,
    pub repair_max_s: f64,
}

impl TraceConfig {
    /// trace-a: 8 weeks, 10 SEV1 + 33 others, repairs 1–7 days (§7.5).
    pub fn trace_a() -> TraceConfig {
        TraceConfig {
            name: "trace-a".into(),
            duration_s: 8.0 * 7.0 * 86400.0,
            n_nodes: 16,
            expect_sev1: 10.0,
            expect_other: 33.0,
            repair_min_s: 1.0 * 86400.0,
            repair_max_s: 7.0 * 86400.0,
        }
    }

    /// trace-b: trace-a's *rate* ×20, over 7 days (≈26 SEV1 + ≈80 others);
    /// repairs arrive fast enough to keep the pool roughly stable (§7.5).
    pub fn trace_b() -> TraceConfig {
        let a = Self::trace_a();
        let scale = 7.0 / (8.0 * 7.0); // duration ratio
        TraceConfig {
            name: "trace-b".into(),
            duration_s: 7.0 * 86400.0,
            n_nodes: 16,
            expect_sev1: a.expect_sev1 * 20.0 * scale,  // = 25
            expect_other: a.expect_other * 20.0 * scale, // = 82.5
            repair_min_s: 0.1 * 86400.0,
            repair_max_s: 0.5 * 86400.0,
        }
    }

    /// Large-fleet scaling study: trace-a's *per-node* failure rates on an
    /// `n_nodes`-node fleet over 30 minutes, cloud-tier repairs (4–24 h).
    /// At 16k nodes that is ≈3.8 expected SEV1s and ≈12.6 others in the
    /// window — enough churn to exercise the replan pipeline, short enough
    /// to simulate at 64k-node scale.
    pub fn large_fleet(n_nodes: u32) -> TraceConfig {
        let a = Self::trace_a();
        let duration_s = 1800.0;
        let node_seconds = n_nodes as f64 * duration_s;
        let per_node_s = |expect: f64| expect / (a.n_nodes as f64 * a.duration_s);
        TraceConfig {
            name: format!("large-fleet-{n_nodes}"),
            duration_s,
            n_nodes,
            expect_sev1: per_node_s(a.expect_sev1) * node_seconds,
            expect_other: per_node_s(a.expect_other) * node_seconds,
            repair_min_s: 4.0 * 3600.0,
            repair_max_s: 24.0 * 3600.0,
        }
    }
}

/// One degradation episode in a trace: the node keeps running but slower —
/// a straggler, a gray partial-bandwidth link, or an elevated preemption
/// (churn) risk. Unlike [`FailureEvent`]s these are *not* fail-stop: the
/// environment keeps the node in the pool and drags its task's goodput by
/// `slow_frac` until the episode ends or the coordinator evicts the node.
#[derive(Debug, Clone, PartialEq)]
pub struct DegradationEvent {
    /// Seconds from trace start when the degradation begins.
    pub at_s: f64,
    /// Node that degrades.
    pub node: NodeId,
    pub kind: DegradationKind,
    /// Fraction of the node's contribution lost while degraded (0..1).
    pub slow_frac: f64,
    /// How long the episode lasts if nobody intervenes, seconds.
    pub duration_s: f64,
}

/// Whether a task enters or leaves the cluster (Fig. 7 triggers ⑥ and ⑤).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum LifecycleKind {
    /// A new task is submitted (⑥) — the coordinator replans to admit it.
    Arrival,
    /// A task completes (⑤) — its workers are redistributed.
    Departure,
}

/// One task arrival/departure in a trace. `task` refers to the index of the
/// task in the simulated cluster's spec list: a task with an [`Arrival`]
/// event is inactive before `at_s`; a [`Departure`] deactivates it.
///
/// [`Arrival`]: LifecycleKind::Arrival
/// [`Departure`]: LifecycleKind::Departure
#[derive(Debug, Clone, PartialEq)]
pub struct TaskLifecycle {
    /// Seconds from trace start.
    pub at_s: f64,
    /// Task id (index into the simulation's `TaskSpec` list / planner id).
    pub task: TaskId,
    pub kind: LifecycleKind,
}

/// A generated (or replayed) trace: failure events sorted by time, plus the
/// task arrival/departure schedule (empty for single-cohort traces like the
/// stock trace-a/trace-b).
#[derive(Debug, Clone)]
pub struct Trace {
    pub config: TraceConfig,
    pub events: Vec<FailureEvent>,
    pub lifecycle: Vec<TaskLifecycle>,
    /// Non-fail-stop degradation episodes (empty for the stock traces).
    pub degradations: Vec<DegradationEvent>,
}

impl Trace {
    /// Generate a seeded trace: Poisson arrivals for each class, error kinds
    /// drawn uniformly within the class, node uniform, SEV1 repairs uniform
    /// in `[repair_min, repair_max]`.
    pub fn generate(config: TraceConfig, seed: u64) -> Trace {
        let mut rng = Xoshiro256::seed_from_u64(seed);
        let mut events = Vec::new();

        let sev1_kinds: Vec<ErrorKind> = ErrorKind::all()
            .iter()
            .copied()
            .filter(|k| k.severity() == Severity::Sev1)
            .collect();
        let other_kinds: Vec<ErrorKind> = ErrorKind::all()
            .iter()
            .copied()
            .filter(|k| k.severity() != Severity::Sev1)
            .collect();

        // Poisson process: exponential inter-arrivals with the class rate.
        let emit = |kinds: &[ErrorKind], expect: f64, rng: &mut Xoshiro256, out: &mut Vec<FailureEvent>| {
            if expect <= 0.0 {
                return;
            }
            let rate = expect / config.duration_s;
            let mut t = 0.0;
            loop {
                t += rng.exponential(rate);
                if t >= config.duration_s {
                    break;
                }
                let kind = *rng.choose(kinds);
                let repair = if kind.severity() == Severity::Sev1 {
                    rng.uniform(config.repair_min_s, config.repair_max_s)
                } else {
                    0.0
                };
                out.push(FailureEvent {
                    at_s: t,
                    kind,
                    node: NodeId(rng.below(config.n_nodes as u64) as u32),
                    repair_after_s: repair,
                });
            }
        };
        emit(&sev1_kinds, config.expect_sev1, &mut rng, &mut events);
        emit(&other_kinds, config.expect_other, &mut rng, &mut events);

        events.sort_by(|a, b| a.at_s.total_cmp(&b.at_s));
        Trace { config, events, lifecycle: Vec::new(), degradations: Vec::new() }
    }

    /// Large-fleet scaling trace (16k/64k nodes): background failures at
    /// trace-a's per-node rates ([`TraceConfig::large_fleet`]) plus
    /// `n_bursts` *bitwise-simultaneous* SEV1 bursts — each hits
    /// `burst_size` distinct nodes with one shared `at_s` bit pattern, the
    /// shape that drives the batched dispatch path (a burst of N costs one
    /// decide/replan cycle, not N). Ordinary Poisson draws never collide
    /// bitwise; these collisions are deliberate.
    pub fn with_large_fleet(n_nodes: u32, n_bursts: u32, burst_size: u32, seed: u64) -> Trace {
        assert!(burst_size >= 1 && burst_size <= n_nodes);
        let mut trace = Trace::generate(TraceConfig::large_fleet(n_nodes), seed);
        let mut rng = Xoshiro256::seed_from_u64(seed ^ 0xB16_F1EE7);
        let sev1_kinds: Vec<ErrorKind> = ErrorKind::all()
            .iter()
            .copied()
            .filter(|k| k.severity() == Severity::Sev1)
            .collect();
        let d = trace.config.duration_s;
        for _ in 0..n_bursts {
            let at = rng.uniform(0.0, d);
            let first = rng.below((n_nodes - burst_size + 1) as u64) as u32;
            for k in 0..burst_size {
                trace.events.push(FailureEvent {
                    at_s: at, // identical bit pattern across the burst
                    kind: *rng.choose(&sev1_kinds),
                    node: NodeId(first + k),
                    repair_after_s: rng
                        .uniform(trace.config.repair_min_s, trace.config.repair_max_s),
                });
            }
        }
        // stable sort: burst members keep node order at their shared instant
        trace.events.sort_by(|a, b| a.at_s.total_cmp(&b.at_s));
        trace
    }

    /// Attach a task arrival/departure schedule (Fig. 7 ⑤⑥ — the multi-task
    /// scenarios of §7.5). Events are kept time-sorted; out-of-range times
    /// are clamped to the trace duration.
    pub fn with_lifecycle(mut self, mut lifecycle: Vec<TaskLifecycle>) -> Trace {
        for l in &mut lifecycle {
            l.at_s = l.at_s.clamp(0.0, self.config.duration_s);
        }
        lifecycle.sort_by(|a, b| a.at_s.total_cmp(&b.at_s).then(a.task.cmp(&b.task)));
        self.lifecycle = lifecycle;
        self
    }

    /// Seeded helper for the ⑤⑥ experiments: the last `n_late` of `n_tasks`
    /// arrive at uniformly-drawn times in the first half of the trace, and
    /// `n_finish` of the initially-running tasks depart in the second half.
    pub fn with_task_churn(self, n_tasks: u32, n_late: u32, n_finish: u32, seed: u64) -> Trace {
        let mut rng = Xoshiro256::seed_from_u64(seed ^ 0x5F5C_A11E);
        let d = self.config.duration_s;
        let mut lifecycle = Vec::new();
        let n_late = n_late.min(n_tasks);
        for task in n_tasks - n_late..n_tasks {
            lifecycle.push(TaskLifecycle {
                at_s: rng.uniform(0.0, d * 0.5),
                task: TaskId(task),
                kind: LifecycleKind::Arrival,
            });
        }
        for task in 0..n_finish.min(n_tasks - n_late) {
            lifecycle.push(TaskLifecycle {
                at_s: rng.uniform(d * 0.5, d),
                task: TaskId(task),
                kind: LifecycleKind::Departure,
            });
        }
        self.with_lifecycle(lifecycle)
    }

    /// Correlated domain-burst faults: infrastructure failures (switch,
    /// rack PDU) take down several nodes of one failure domain nearly at
    /// once — the scenario class "Characterization of LLM Development in
    /// the Datacenter" reports dominating correlated outages. Each of
    /// `n_bursts` seeded bursts picks a domain (nodes grouped as
    /// `domain = node / nodes_per_domain`) and hits `burst_size` distinct
    /// nodes of it with SEV1 failures inside a `spread_s`-second window;
    /// repairs draw from the trace's usual bounds.
    pub fn with_domain_burst(
        mut self,
        nodes_per_domain: u32,
        n_bursts: u32,
        burst_size: u32,
        spread_s: f64,
        seed: u64,
    ) -> Trace {
        assert!(nodes_per_domain > 0);
        let mut rng = Xoshiro256::seed_from_u64(seed ^ 0xD0_4A1B_0057);
        let n_domains = (self.config.n_nodes + nodes_per_domain - 1) / nodes_per_domain;
        let sev1_kinds: Vec<ErrorKind> = ErrorKind::all()
            .iter()
            .copied()
            .filter(|k| k.severity() == Severity::Sev1)
            .collect();
        for _ in 0..n_bursts {
            let domain = rng.below(n_domains as u64) as u32;
            let first = domain * nodes_per_domain;
            let count = burst_size.min(nodes_per_domain).min(self.config.n_nodes - first);
            let t0 = rng.uniform(0.0, (self.config.duration_s - spread_s).max(0.0));
            for k in 0..count {
                self.events.push(FailureEvent {
                    at_s: t0 + rng.uniform(0.0, spread_s),
                    kind: *rng.choose(&sev1_kinds),
                    node: NodeId(first + k),
                    repair_after_s: rng.uniform(self.config.repair_min_s, self.config.repair_max_s),
                });
            }
        }
        self.events.sort_by(|a, b| a.at_s.total_cmp(&b.at_s));
        self
    }

    /// Fragmentation churn: `n_waves` waves of scattered SEV1s, each wave
    /// failing one node in *every* failure domain at staggered times with
    /// fast repairs. Replacement capacity is always in some *other* domain,
    /// so a topology-blind assignment scatters tasks across racks wave
    /// after wave — the scenario class the `placement` min-churn solver
    /// exists to consolidate (`placement-frag` experiment).
    pub fn with_fragmented_cluster(
        mut self,
        nodes_per_domain: u32,
        n_waves: u32,
        seed: u64,
    ) -> Trace {
        assert!(nodes_per_domain > 0);
        let mut rng = Xoshiro256::seed_from_u64(seed ^ 0xF4A6_3A11);
        let n_domains = self.config.n_nodes.div_ceil(nodes_per_domain);
        let sev1_kinds: Vec<ErrorKind> = ErrorKind::all()
            .iter()
            .copied()
            .filter(|k| k.severity() == Severity::Sev1)
            .collect();
        let wave_span = self.config.duration_s / (n_waves as f64 + 1.0);
        for wave in 0..n_waves {
            let t0 = (wave as f64 + 0.5) * wave_span;
            for domain in 0..n_domains {
                let first = domain * nodes_per_domain;
                let span = nodes_per_domain.min(self.config.n_nodes - first);
                let node = first + rng.below(span as u64) as u32;
                self.events.push(FailureEvent {
                    at_s: t0 + rng.uniform(0.0, 600.0),
                    kind: *rng.choose(&sev1_kinds),
                    node: NodeId(node),
                    // fast repairs: the node is back well before the next
                    // wave, so capacity churns instead of shrinking
                    repair_after_s: rng.uniform(0.05, 0.25) * wave_span,
                });
            }
        }
        self.events.sort_by(|a, b| a.at_s.total_cmp(&b.at_s));
        self
    }

    /// Rack drain: every node of one failure domain SEV1s in sequence from
    /// `start_s`, one every `interval_s` seconds, with repairs past the end
    /// of the trace — the domain slowly empties and never comes back, so
    /// layouts must migrate the hosted tasks off the dying rack. Seedless
    /// and deterministic, like [`Trace::with_recurrent_lemon`].
    pub fn with_rack_drain(
        mut self,
        domain: u32,
        nodes_per_domain: u32,
        start_s: f64,
        interval_s: f64,
    ) -> Trace {
        assert!(nodes_per_domain > 0);
        assert!(interval_s > 0.0, "drain interval must be positive");
        let first = domain * nodes_per_domain;
        assert!(first < self.config.n_nodes, "domain {domain} is outside the cluster");
        let count = nodes_per_domain.min(self.config.n_nodes - first);
        let never = 2.0 * self.config.duration_s; // repaired after the credits roll
        for k in 0..count {
            let at = start_s + k as f64 * interval_s;
            if at >= self.config.duration_s {
                break;
            }
            self.events.push(FailureEvent {
                at_s: at,
                kind: ErrorKind::GpuDriverError, // SEV1 node drain
                node: NodeId(first + k),
                repair_after_s: never,
            });
        }
        self.events.sort_by(|a, b| a.at_s.total_cmp(&b.at_s));
        self
    }

    /// Recurrent-lemon schedule: `node` fails with `kind` every `period_s`
    /// seconds from `start_s` until `until_s` (clamped to the trace
    /// duration) — the recurrent-failure pattern Meta's reliability study
    /// found dominating lost goodput. SEV1 kinds draw a repair time from
    /// the trace's bounds midpoint so the schedule stays seedless.
    pub fn with_recurrent_lemon(
        mut self,
        node: NodeId,
        kind: ErrorKind,
        start_s: f64,
        period_s: f64,
        until_s: f64,
    ) -> Trace {
        assert!(period_s > 0.0, "lemon period must be positive");
        let until = until_s.min(self.config.duration_s);
        let repair = if kind.severity() == Severity::Sev1 {
            0.5 * (self.config.repair_min_s + self.config.repair_max_s)
        } else {
            0.0
        };
        let mut t = start_s;
        while t < until {
            self.events.push(FailureEvent { at_s: t, kind, node, repair_after_s: repair });
            t += period_s;
        }
        self.events.sort_by(|a, b| a.at_s.total_cmp(&b.at_s));
        self
    }

    /// Inject one precisely-timed failure at `at_s` on `node` — the
    /// controlled-scenario builder for failover-latency experiments
    /// (`warm-peer`): a quiet trace plus one injected SEV1 isolates the
    /// restore path under test. Seedless and deterministic like
    /// [`Trace::with_recurrent_lemon`]; SEV1 kinds repair at the midpoint
    /// of the trace's bounds.
    pub fn with_injected_failure(mut self, node: NodeId, at_s: f64, kind: ErrorKind) -> Trace {
        assert!(node.0 < self.config.n_nodes, "node {} outside the cluster", node.0);
        assert!(
            (0.0..self.config.duration_s).contains(&at_s),
            "injection time {at_s} outside the trace"
        );
        let repair = if kind.severity() == Severity::Sev1 {
            0.5 * (self.config.repair_min_s + self.config.repair_max_s)
        } else {
            0.0
        };
        self.events.push(FailureEvent { at_s, kind, node, repair_after_s: repair });
        self.events.sort_by(|a, b| a.at_s.total_cmp(&b.at_s));
        self
    }

    /// Straggler onset: from `at_s`, `node` keeps running but every step on
    /// it takes `1/(1-slow_frac)`× the healthy duration for `duration_s`
    /// seconds — the compute gray failure the in-band health observers
    /// exist to catch (the node never reports an error, it just drags the
    /// whole data-parallel cohort). Seedless and deterministic like
    /// [`Trace::with_injected_failure`].
    pub fn with_straggler_onset(
        mut self,
        node: NodeId,
        at_s: f64,
        slow_frac: f64,
        duration_s: f64,
    ) -> Trace {
        assert!(node.0 < self.config.n_nodes, "node {} outside the cluster", node.0);
        assert!((0.0..1.0).contains(&slow_frac), "slow_frac {slow_frac} outside [0, 1)");
        self.degradations.push(DegradationEvent {
            at_s: at_s.clamp(0.0, self.config.duration_s),
            node,
            kind: DegradationKind::Straggler,
            slow_frac,
            duration_s: duration_s.max(0.0),
        });
        self.degradations.sort_by(|a, b| a.at_s.total_cmp(&b.at_s).then(a.node.cmp(&b.node)));
        self
    }

    /// Gray partial-bandwidth episode: `node`'s NIC or its ToR uplink
    /// degrades (flapping link, ECN storm) so collectives stall and steps
    /// stretch by `1/(1-slow_frac)`× for `duration_s` seconds. Same
    /// seedless mechanics as [`Trace::with_straggler_onset`], different
    /// [`DegradationKind`] so detectors and dashboards can tell the two
    /// root-cause classes apart.
    pub fn with_gray_bandwidth(
        mut self,
        node: NodeId,
        at_s: f64,
        slow_frac: f64,
        duration_s: f64,
    ) -> Trace {
        assert!(node.0 < self.config.n_nodes, "node {} outside the cluster", node.0);
        assert!((0.0..1.0).contains(&slow_frac), "slow_frac {slow_frac} outside [0, 1)");
        self.degradations.push(DegradationEvent {
            at_s: at_s.clamp(0.0, self.config.duration_s),
            node,
            kind: DegradationKind::PartialBandwidth,
            slow_frac,
            duration_s: duration_s.max(0.0),
        });
        self.degradations.sort_by(|a, b| a.at_s.total_cmp(&b.at_s).then(a.node.cmp(&b.node)));
        self
    }

    /// Spot/preemption churn: `n_events` seeded preemptions, each preceded
    /// by a [`DegradationKind::ChurnRisk`] advisory `notice_s` seconds
    /// before the node is yanked with a SEV1 `LostConnection` (the cloud
    /// two-minute-warning shape). The advisory's `slow_frac` carries the
    /// predicted preemption probability, not a measured slowdown; its
    /// `duration_s` is the remaining notice window.
    pub fn with_spot_churn(mut self, n_events: u32, notice_s: f64, seed: u64) -> Trace {
        assert!(notice_s >= 0.0);
        let mut rng = Xoshiro256::seed_from_u64(seed ^ 0x0DE6_AADE);
        let d = self.config.duration_s;
        for _ in 0..n_events {
            let node = NodeId(rng.below(self.config.n_nodes as u64) as u32);
            let at = rng.uniform(notice_s, d.max(notice_s + 1.0));
            self.degradations.push(DegradationEvent {
                at_s: (at - notice_s).max(0.0),
                node,
                kind: DegradationKind::ChurnRisk,
                slow_frac: rng.uniform(0.5, 0.95),
                duration_s: notice_s,
            });
            if at < d {
                self.events.push(FailureEvent {
                    at_s: at,
                    kind: ErrorKind::LostConnection,
                    node,
                    repair_after_s: rng
                        .uniform(self.config.repair_min_s, self.config.repair_max_s),
                });
            }
        }
        self.events.sort_by(|a, b| a.at_s.total_cmp(&b.at_s));
        self.degradations.sort_by(|a, b| a.at_s.total_cmp(&b.at_s).then(a.node.cmp(&b.node)));
        self
    }

    /// Task indices that are active at t = 0 (no pending Arrival event).
    pub fn initially_active(&self, n_tasks: usize) -> Vec<bool> {
        let mut active = vec![true; n_tasks];
        for l in &self.lifecycle {
            if l.kind == LifecycleKind::Arrival {
                if let Some(a) = active.get_mut(l.task.0 as usize) {
                    *a = false;
                }
            }
        }
        active
    }

    pub fn count_by_severity(&self, sev: Severity) -> usize {
        self.events.iter().filter(|e| e.severity() == sev).count()
    }

    /// Available-GPU timeline: (time, available GPU count) steps, starting
    /// from full capacity — the y-axis of Fig. 11a/11d. Only SEV1 failures
    /// remove capacity (§7.5); repairs restore it.
    pub fn availability_timeline(&self, gpus_per_node: u32) -> Vec<(f64, u32)> {
        let total = self.config.n_nodes * gpus_per_node;
        let mut deltas: Vec<(f64, i64)> = Vec::new();
        for e in &self.events {
            if e.severity() == Severity::Sev1 {
                deltas.push((e.at_s, -(gpus_per_node as i64)));
                let back = e.at_s + e.repair_after_s;
                if back < self.config.duration_s {
                    deltas.push((back, gpus_per_node as i64));
                }
            }
        }
        deltas.sort_by(|a, b| a.0.partial_cmp(&b.0).unwrap());
        let mut timeline = vec![(0.0, total)];
        let mut cur = total as i64;
        for (t, d) in deltas {
            cur = (cur + d).clamp(0, total as i64);
            timeline.push((t, cur as u32));
        }
        timeline
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn trace_a_counts_near_paper() {
        // Average over seeds: expectation 10 SEV1 / 33 other.
        let mut sev1 = 0usize;
        let mut other = 0usize;
        let n = 40;
        for seed in 0..n {
            let t = Trace::generate(TraceConfig::trace_a(), seed);
            sev1 += t.count_by_severity(Severity::Sev1);
            other += t.count_by_severity(Severity::Sev2) + t.count_by_severity(Severity::Sev3);
        }
        let mean_sev1 = sev1 as f64 / n as f64;
        let mean_other = other as f64 / n as f64;
        assert!((8.0..12.0).contains(&mean_sev1), "mean SEV1 {mean_sev1}");
        assert!((29.0..37.0).contains(&mean_other), "mean other {mean_other}");
    }

    #[test]
    fn trace_b_is_20x_denser() {
        let a = TraceConfig::trace_a();
        let b = TraceConfig::trace_b();
        let rate_a = (a.expect_sev1 + a.expect_other) / a.duration_s;
        let rate_b = (b.expect_sev1 + b.expect_other) / b.duration_s;
        assert!((rate_b / rate_a - 20.0).abs() < 0.5, "ratio {}", rate_b / rate_a);
    }

    #[test]
    fn deterministic_per_seed() {
        let t1 = Trace::generate(TraceConfig::trace_a(), 99);
        let t2 = Trace::generate(TraceConfig::trace_a(), 99);
        assert_eq!(t1.events, t2.events);
        let t3 = Trace::generate(TraceConfig::trace_a(), 100);
        assert_ne!(t1.events, t3.events);
    }

    #[test]
    fn events_sorted_and_in_bounds() {
        let t = Trace::generate(TraceConfig::trace_b(), 7);
        let cfg = &t.config;
        let mut prev = 0.0;
        for e in &t.events {
            assert!(e.at_s >= prev);
            assert!(e.at_s < cfg.duration_s);
            assert!(e.node.0 < cfg.n_nodes);
            if e.severity() == Severity::Sev1 {
                assert!(e.repair_after_s >= cfg.repair_min_s && e.repair_after_s <= cfg.repair_max_s);
            } else {
                assert_eq!(e.repair_after_s, 0.0);
            }
            prev = e.at_s;
        }
    }

    #[test]
    fn lifecycle_sorted_clamped_and_deterministic() {
        let mk = || {
            Trace::generate(TraceConfig::trace_a(), 4).with_lifecycle(vec![
                TaskLifecycle { at_s: 9e99, task: TaskId(1), kind: LifecycleKind::Departure },
                TaskLifecycle { at_s: 100.0, task: TaskId(2), kind: LifecycleKind::Arrival },
                TaskLifecycle { at_s: -5.0, task: TaskId(3), kind: LifecycleKind::Arrival },
            ])
        };
        let t = mk();
        assert_eq!(t.lifecycle.len(), 3);
        let mut prev = 0.0;
        for l in &t.lifecycle {
            assert!(l.at_s >= prev && l.at_s <= t.config.duration_s);
            prev = l.at_s;
        }
        assert_eq!(t.lifecycle, mk().lifecycle);
    }

    #[test]
    fn task_churn_schedule_shape() {
        let t = Trace::generate(TraceConfig::trace_a(), 7).with_task_churn(6, 2, 1, 7);
        let d = t.config.duration_s;
        let arrivals: Vec<_> =
            t.lifecycle.iter().filter(|l| l.kind == LifecycleKind::Arrival).collect();
        let departures: Vec<_> =
            t.lifecycle.iter().filter(|l| l.kind == LifecycleKind::Departure).collect();
        assert_eq!(arrivals.len(), 2);
        assert_eq!(departures.len(), 1);
        // the late cohort is the highest-indexed tasks, in the first half
        assert!(arrivals.iter().all(|l| l.task.0 >= 4 && l.at_s <= d * 0.5));
        // departures come from the initially-running cohort, second half
        assert!(departures.iter().all(|l| l.task.0 < 4 && l.at_s >= d * 0.5));
        assert_eq!(t.initially_active(6), vec![true, true, true, true, false, false]);
    }

    #[test]
    fn stock_traces_have_empty_lifecycle() {
        let t = Trace::generate(TraceConfig::trace_b(), 1);
        assert!(t.lifecycle.is_empty());
        assert_eq!(t.initially_active(4), vec![true; 4]);
    }

    #[test]
    fn domain_burst_hits_one_domain_within_the_window() {
        let base = Trace::generate(TraceConfig::trace_a(), 3);
        let before = base.events.len();
        let t = base.with_domain_burst(4, 2, 3, 600.0, 7);
        let sev1s = t.events.iter().filter(|e| e.severity() == Severity::Sev1).count();
        assert_eq!(t.events.len(), before + 6, "2 bursts × 3 nodes");
        assert!(sev1s >= 6, "burst events are SEV1 node drains");
        // events stay sorted and in bounds
        let mut prev = 0.0;
        for e in &t.events {
            assert!(e.at_s >= prev && e.at_s < t.config.duration_s);
            prev = e.at_s;
        }
        // deterministic per seed
        let again = Trace::generate(TraceConfig::trace_a(), 3).with_domain_burst(4, 2, 3, 600.0, 7);
        assert_eq!(t.events, again.events);
        let other = Trace::generate(TraceConfig::trace_a(), 3).with_domain_burst(4, 2, 3, 600.0, 8);
        assert_ne!(t.events, other.events);
    }

    #[test]
    fn domain_burst_nodes_share_a_domain_and_are_sev1() {
        // start from an empty trace so every event is burst-generated
        let tc = TraceConfig { expect_sev1: 0.0, expect_other: 0.0, ..TraceConfig::trace_a() };
        let t = Trace::generate(tc, 0).with_domain_burst(4, 1, 3, 900.0, 11);
        assert_eq!(t.events.len(), 3);
        let domains: Vec<u32> = t.events.iter().map(|e| e.node.0 / 4).collect();
        assert!(domains.windows(2).all(|w| w[0] == w[1]), "one burst, one domain: {domains:?}");
        let span = t.events.last().unwrap().at_s - t.events[0].at_s;
        assert!(span <= 900.0, "burst spread {span}");
        for e in &t.events {
            assert_eq!(e.severity(), Severity::Sev1);
            assert!(e.repair_after_s >= t.config.repair_min_s);
        }
        // distinct nodes
        let mut nodes: Vec<u32> = t.events.iter().map(|e| e.node.0).collect();
        nodes.sort_unstable();
        nodes.dedup();
        assert_eq!(nodes.len(), 3);
    }

    #[test]
    fn fragmented_cluster_hits_every_domain_each_wave_with_fast_repairs() {
        let tc = TraceConfig { expect_sev1: 0.0, expect_other: 0.0, ..TraceConfig::trace_a() };
        let t = Trace::generate(tc, 0).with_fragmented_cluster(4, 3, 9);
        // 16 nodes / 4 per domain = 4 domains; 3 waves × 4 domains
        assert_eq!(t.events.len(), 12);
        for e in &t.events {
            assert_eq!(e.severity(), Severity::Sev1);
            assert!(e.at_s < t.config.duration_s);
            // fast repairs: back before the next wave
            assert!(e.repair_after_s < t.config.duration_s / 4.0);
        }
        // each wave covers all four domains
        let domains: std::collections::BTreeSet<u32> =
            t.events[..4].iter().map(|e| e.node.0 / 4).collect();
        assert_eq!(domains.len(), 4, "first wave must scatter across every domain");
        // deterministic per seed, sorted
        let again = Trace::generate(
            TraceConfig { expect_sev1: 0.0, expect_other: 0.0, ..TraceConfig::trace_a() },
            0,
        )
        .with_fragmented_cluster(4, 3, 9);
        assert_eq!(t.events, again.events);
        assert!(t.events.windows(2).all(|w| w[0].at_s <= w[1].at_s));
    }

    #[test]
    fn rack_drain_empties_one_domain_for_good() {
        let tc = TraceConfig { expect_sev1: 0.0, expect_other: 0.0, ..TraceConfig::trace_a() };
        let t = Trace::generate(tc, 0).with_rack_drain(1, 4, 1000.0, 500.0);
        assert_eq!(t.events.len(), 4);
        let times: Vec<f64> = t.events.iter().map(|e| e.at_s).collect();
        assert_eq!(times, vec![1000.0, 1500.0, 2000.0, 2500.0]);
        for (k, e) in t.events.iter().enumerate() {
            assert_eq!(e.node, NodeId(4 + k as u32), "drains domain 1's nodes in order");
            assert_eq!(e.severity(), Severity::Sev1);
            assert!(e.repair_after_s > t.config.duration_s, "the rack never comes back");
        }
    }

    #[test]
    fn recurrent_lemon_schedule_shape() {
        let tc = TraceConfig { expect_sev1: 0.0, expect_other: 0.0, ..TraceConfig::trace_a() };
        let t = Trace::generate(tc, 0).with_recurrent_lemon(
            NodeId(5),
            ErrorKind::CudaError,
            100.0,
            50.0,
            400.0,
        );
        let times: Vec<f64> = t.events.iter().map(|e| e.at_s).collect();
        assert_eq!(times, vec![100.0, 150.0, 200.0, 250.0, 300.0, 350.0]);
        assert!(t.events.iter().all(|e| e.node == NodeId(5)));
        assert!(t.events.iter().all(|e| e.repair_after_s == 0.0), "SEV2 needs no repair slot");
        // a SEV1 lemon draws the midpoint repair
        let tc = TraceConfig { expect_sev1: 0.0, expect_other: 0.0, ..TraceConfig::trace_a() };
        let t = Trace::generate(tc, 0).with_recurrent_lemon(
            NodeId(2),
            ErrorKind::EccError,
            0.0,
            1e6,
            f64::INFINITY,
        );
        let mid = 0.5 * (t.config.repair_min_s + t.config.repair_max_s);
        assert!(t.events.iter().all(|e| e.repair_after_s == mid));
        assert!(!t.events.is_empty());
    }

    #[test]
    fn injected_failure_lands_exactly_where_asked() {
        let tc = TraceConfig { expect_sev1: 0.0, expect_other: 0.0, ..TraceConfig::trace_a() };
        let t = Trace::generate(tc, 0).with_injected_failure(
            NodeId(3),
            7200.0,
            ErrorKind::LostConnection,
        );
        assert_eq!(t.events.len(), 1);
        let e = &t.events[0];
        assert_eq!((e.node, e.at_s), (NodeId(3), 7200.0));
        assert_eq!(e.severity(), Severity::Sev1);
        let mid = 0.5 * (t.config.repair_min_s + t.config.repair_max_s);
        assert_eq!(e.repair_after_s, mid);
        // SEV2 injections carry no repair slot
        let tc = TraceConfig { expect_sev1: 0.0, expect_other: 0.0, ..TraceConfig::trace_a() };
        let t = Trace::generate(tc, 0).with_injected_failure(
            NodeId(0),
            100.0,
            ErrorKind::CudaError,
        );
        assert_eq!(t.events[0].repair_after_s, 0.0);
        // injections merge time-sorted into a busy trace
        let busy = Trace::generate(TraceConfig::trace_a(), 5).with_injected_failure(
            NodeId(1),
            1234.5,
            ErrorKind::EccError,
        );
        assert!(busy.events.windows(2).all(|w| w[0].at_s <= w[1].at_s));
        assert!(busy.events.iter().any(|e| e.at_s == 1234.5 && e.node == NodeId(1)));
    }

    #[test]
    fn large_fleet_scales_trace_a_per_node_rates() {
        let a = TraceConfig::trace_a();
        for n in [16384u32, 65536] {
            let c = TraceConfig::large_fleet(n);
            assert_eq!(c.n_nodes, n);
            // per-node-second rates match trace-a's exactly
            let rate = |e: f64, cfg: &TraceConfig| e / (cfg.n_nodes as f64 * cfg.duration_s);
            assert!((rate(c.expect_sev1, &c) - rate(a.expect_sev1, &a)).abs() < 1e-15);
            assert!((rate(c.expect_other, &c) - rate(a.expect_other, &a)).abs() < 1e-15);
        }
        // 16k nodes for 30 min: a handful of failures, not thousands
        let c = TraceConfig::large_fleet(16384);
        assert!((3.0..5.0).contains(&c.expect_sev1), "{}", c.expect_sev1);
        assert!((10.0..16.0).contains(&c.expect_other), "{}", c.expect_other);
    }

    #[test]
    fn large_fleet_bursts_are_bitwise_simultaneous() {
        let t = Trace::with_large_fleet(16384, 2, 4, 11);
        // each burst shares ONE timestamp bit pattern across distinct nodes
        let mut by_time: std::collections::BTreeMap<u64, Vec<u32>> = Default::default();
        for e in &t.events {
            by_time.entry(e.at_s.to_bits()).or_default().push(e.node.0);
        }
        let bursts: Vec<&Vec<u32>> = by_time.values().filter(|v| v.len() > 1).collect();
        assert_eq!(bursts.len(), 2, "two simultaneous bursts");
        for nodes in bursts {
            assert_eq!(nodes.len(), 4);
            let mut uniq = nodes.clone();
            uniq.sort_unstable();
            uniq.dedup();
            assert_eq!(uniq.len(), 4, "burst nodes are distinct");
        }
        // everything in bounds and sorted
        let mut prev = 0.0;
        for e in &t.events {
            assert!(e.at_s >= prev && e.at_s < t.config.duration_s);
            assert!(e.node.0 < 16384);
            prev = e.at_s;
        }
        // deterministic per seed — the corpus contract
        let again = Trace::with_large_fleet(16384, 2, 4, 11);
        assert_eq!(t.events, again.events);
    }

    #[test]
    fn large_fleet_generates_at_64k_nodes() {
        let t = Trace::with_large_fleet(65536, 1, 8, 3);
        assert!(t.events.iter().all(|e| e.node.0 < 65536));
        assert!(t.events.len() >= 8, "at least the burst itself");
        assert!(t.lifecycle.is_empty());
    }

    #[test]
    fn straggler_and_gray_builders_schedule_degradations_not_failures() {
        let tc = TraceConfig { expect_sev1: 0.0, expect_other: 0.0, ..TraceConfig::trace_a() };
        let t = Trace::generate(tc, 0)
            .with_straggler_onset(NodeId(3), 4000.0, 0.6, 20000.0)
            .with_gray_bandwidth(NodeId(7), 9000.0, 0.3, 5000.0);
        assert!(t.events.is_empty(), "degradations are not fail-stop events");
        assert_eq!(t.degradations.len(), 2);
        let s = &t.degradations[0];
        assert_eq!(
            (s.node, s.at_s, s.kind, s.slow_frac, s.duration_s),
            (NodeId(3), 4000.0, DegradationKind::Straggler, 0.6, 20000.0)
        );
        let g = &t.degradations[1];
        assert_eq!(g.kind, DegradationKind::PartialBandwidth);
        assert_eq!(g.node, NodeId(7));
        // time-sorted regardless of builder order
        let swapped = Trace::generate(
            TraceConfig { expect_sev1: 0.0, expect_other: 0.0, ..TraceConfig::trace_a() },
            0,
        )
        .with_gray_bandwidth(NodeId(7), 9000.0, 0.3, 5000.0)
        .with_straggler_onset(NodeId(3), 4000.0, 0.6, 20000.0);
        assert_eq!(t.degradations, swapped.degradations);
    }

    #[test]
    fn spot_churn_warns_before_every_preemption() {
        let tc = TraceConfig { expect_sev1: 0.0, expect_other: 0.0, ..TraceConfig::trace_a() };
        let t = Trace::generate(tc, 0).with_spot_churn(5, 120.0, 13);
        assert_eq!(t.degradations.len(), 5);
        for w in &t.degradations {
            assert_eq!(w.kind, DegradationKind::ChurnRisk);
            assert!((0.5..0.95).contains(&w.slow_frac), "predicted probability {}", w.slow_frac);
            assert_eq!(w.duration_s, 120.0);
            // the preemption itself lands notice_s after the advisory
            let hit = t.events.iter().find(|e| {
                e.node == w.node && (e.at_s - (w.at_s + 120.0)).abs() < 1e-6
            });
            assert!(hit.is_some(), "advisory for node {} has no preemption", w.node.0);
            assert_eq!(hit.unwrap().kind, ErrorKind::LostConnection);
        }
        // deterministic per seed — the corpus contract
        let again = Trace::generate(
            TraceConfig { expect_sev1: 0.0, expect_other: 0.0, ..TraceConfig::trace_a() },
            0,
        )
        .with_spot_churn(5, 120.0, 13);
        assert_eq!(t.degradations, again.degradations);
        assert_eq!(t.events, again.events);
        assert!(t.events.windows(2).all(|w| w[0].at_s <= w[1].at_s));
        assert!(t.degradations.windows(2).all(|w| w[0].at_s <= w[1].at_s));
    }

    #[test]
    fn stock_traces_have_no_degradations() {
        assert!(Trace::generate(TraceConfig::trace_a(), 1).degradations.is_empty());
        assert!(Trace::with_large_fleet(16384, 1, 4, 2).degradations.is_empty());
    }

    #[test]
    fn availability_timeline_steps_down_and_up() {
        let t = Trace::generate(TraceConfig::trace_a(), 3);
        let tl = t.availability_timeline(8);
        assert_eq!(tl[0], (0.0, 128));
        let min = tl.iter().map(|&(_, g)| g).min().unwrap();
        assert!(min < 128, "SEV1 failures must reduce availability");
        // capacity never exceeds total or goes negative (clamped)
        assert!(tl.iter().all(|&(_, g)| g <= 128));
    }
}
