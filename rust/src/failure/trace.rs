//! Failure traces (paper §7.5): *trace-a* — 8 weeks, 10 SEV1 + 33 other
//! failures on a 128-GPU/16-node cluster, node repair uniform in 1–7 days;
//! *trace-b* — the same cluster with failure frequency amplified 20×,
//! 7 days, ~26 SEV1 + ~80 other failures, repaired nodes rejoining at a
//! similar rate. Arrivals are Poisson; all draws are seeded.

use crate::failure::{ErrorKind, Severity};
use crate::rng::{Rand, Xoshiro256};

/// One failure occurrence in a trace.
#[derive(Debug, Clone, PartialEq)]
pub struct FailureEvent {
    /// Seconds from trace start.
    pub at_s: f64,
    pub kind: ErrorKind,
    /// Node index the failure hits.
    pub node: u32,
    /// For SEV1 (node-drain) failures: seconds until the node is repaired
    /// and rejoins. 0 for SEV2/SEV3.
    pub repair_after_s: f64,
}

impl FailureEvent {
    pub fn severity(&self) -> Severity {
        self.kind.severity()
    }
}

/// Parameters of a synthetic trace.
#[derive(Debug, Clone)]
pub struct TraceConfig {
    pub name: String,
    pub duration_s: f64,
    pub n_nodes: u32,
    /// Expected SEV1 count over the whole duration.
    pub expect_sev1: f64,
    /// Expected SEV2+SEV3 count over the whole duration.
    pub expect_other: f64,
    /// Repair time bounds for SEV1 (uniform draw), seconds.
    pub repair_min_s: f64,
    pub repair_max_s: f64,
}

impl TraceConfig {
    /// trace-a: 8 weeks, 10 SEV1 + 33 others, repairs 1–7 days (§7.5).
    pub fn trace_a() -> TraceConfig {
        TraceConfig {
            name: "trace-a".into(),
            duration_s: 8.0 * 7.0 * 86400.0,
            n_nodes: 16,
            expect_sev1: 10.0,
            expect_other: 33.0,
            repair_min_s: 1.0 * 86400.0,
            repair_max_s: 7.0 * 86400.0,
        }
    }

    /// trace-b: trace-a's *rate* ×20, over 7 days (≈26 SEV1 + ≈80 others);
    /// repairs arrive fast enough to keep the pool roughly stable (§7.5).
    pub fn trace_b() -> TraceConfig {
        let a = Self::trace_a();
        let scale = 7.0 / (8.0 * 7.0); // duration ratio
        TraceConfig {
            name: "trace-b".into(),
            duration_s: 7.0 * 86400.0,
            n_nodes: 16,
            expect_sev1: a.expect_sev1 * 20.0 * scale,  // = 25
            expect_other: a.expect_other * 20.0 * scale, // = 82.5
            repair_min_s: 0.1 * 86400.0,
            repair_max_s: 0.5 * 86400.0,
        }
    }
}

/// A generated (or replayed) trace: failure events sorted by time.
#[derive(Debug, Clone)]
pub struct Trace {
    pub config: TraceConfig,
    pub events: Vec<FailureEvent>,
}

impl Trace {
    /// Generate a seeded trace: Poisson arrivals for each class, error kinds
    /// drawn uniformly within the class, node uniform, SEV1 repairs uniform
    /// in `[repair_min, repair_max]`.
    pub fn generate(config: TraceConfig, seed: u64) -> Trace {
        let mut rng = Xoshiro256::seed_from_u64(seed);
        let mut events = Vec::new();

        let sev1_kinds: Vec<ErrorKind> = ErrorKind::all()
            .iter()
            .copied()
            .filter(|k| k.severity() == Severity::Sev1)
            .collect();
        let other_kinds: Vec<ErrorKind> = ErrorKind::all()
            .iter()
            .copied()
            .filter(|k| k.severity() != Severity::Sev1)
            .collect();

        // Poisson process: exponential inter-arrivals with the class rate.
        let emit = |kinds: &[ErrorKind], expect: f64, rng: &mut Xoshiro256, out: &mut Vec<FailureEvent>| {
            if expect <= 0.0 {
                return;
            }
            let rate = expect / config.duration_s;
            let mut t = 0.0;
            loop {
                t += rng.exponential(rate);
                if t >= config.duration_s {
                    break;
                }
                let kind = *rng.choose(kinds);
                let repair = if kind.severity() == Severity::Sev1 {
                    rng.uniform(config.repair_min_s, config.repair_max_s)
                } else {
                    0.0
                };
                out.push(FailureEvent {
                    at_s: t,
                    kind,
                    node: rng.below(config.n_nodes as u64) as u32,
                    repair_after_s: repair,
                });
            }
        };
        emit(&sev1_kinds, config.expect_sev1, &mut rng, &mut events);
        emit(&other_kinds, config.expect_other, &mut rng, &mut events);

        events.sort_by(|a, b| a.at_s.partial_cmp(&b.at_s).unwrap());
        Trace { config, events }
    }

    pub fn count_by_severity(&self, sev: Severity) -> usize {
        self.events.iter().filter(|e| e.severity() == sev).count()
    }

    /// Available-GPU timeline: (time, available GPU count) steps, starting
    /// from full capacity — the y-axis of Fig. 11a/11d. Only SEV1 failures
    /// remove capacity (§7.5); repairs restore it.
    pub fn availability_timeline(&self, gpus_per_node: u32) -> Vec<(f64, u32)> {
        let total = self.config.n_nodes * gpus_per_node;
        let mut deltas: Vec<(f64, i64)> = Vec::new();
        for e in &self.events {
            if e.severity() == Severity::Sev1 {
                deltas.push((e.at_s, -(gpus_per_node as i64)));
                let back = e.at_s + e.repair_after_s;
                if back < self.config.duration_s {
                    deltas.push((back, gpus_per_node as i64));
                }
            }
        }
        deltas.sort_by(|a, b| a.0.partial_cmp(&b.0).unwrap());
        let mut timeline = vec![(0.0, total)];
        let mut cur = total as i64;
        for (t, d) in deltas {
            cur = (cur + d).clamp(0, total as i64);
            timeline.push((t, cur as u32));
        }
        timeline
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn trace_a_counts_near_paper() {
        // Average over seeds: expectation 10 SEV1 / 33 other.
        let mut sev1 = 0usize;
        let mut other = 0usize;
        let n = 40;
        for seed in 0..n {
            let t = Trace::generate(TraceConfig::trace_a(), seed);
            sev1 += t.count_by_severity(Severity::Sev1);
            other += t.count_by_severity(Severity::Sev2) + t.count_by_severity(Severity::Sev3);
        }
        let mean_sev1 = sev1 as f64 / n as f64;
        let mean_other = other as f64 / n as f64;
        assert!((8.0..12.0).contains(&mean_sev1), "mean SEV1 {mean_sev1}");
        assert!((29.0..37.0).contains(&mean_other), "mean other {mean_other}");
    }

    #[test]
    fn trace_b_is_20x_denser() {
        let a = TraceConfig::trace_a();
        let b = TraceConfig::trace_b();
        let rate_a = (a.expect_sev1 + a.expect_other) / a.duration_s;
        let rate_b = (b.expect_sev1 + b.expect_other) / b.duration_s;
        assert!((rate_b / rate_a - 20.0).abs() < 0.5, "ratio {}", rate_b / rate_a);
    }

    #[test]
    fn deterministic_per_seed() {
        let t1 = Trace::generate(TraceConfig::trace_a(), 99);
        let t2 = Trace::generate(TraceConfig::trace_a(), 99);
        assert_eq!(t1.events, t2.events);
        let t3 = Trace::generate(TraceConfig::trace_a(), 100);
        assert_ne!(t1.events, t3.events);
    }

    #[test]
    fn events_sorted_and_in_bounds() {
        let t = Trace::generate(TraceConfig::trace_b(), 7);
        let cfg = &t.config;
        let mut prev = 0.0;
        for e in &t.events {
            assert!(e.at_s >= prev);
            assert!(e.at_s < cfg.duration_s);
            assert!(e.node < cfg.n_nodes);
            if e.severity() == Severity::Sev1 {
                assert!(e.repair_after_s >= cfg.repair_min_s && e.repair_after_s <= cfg.repair_max_s);
            } else {
                assert_eq!(e.repair_after_s, 0.0);
            }
            prev = e.at_s;
        }
    }

    #[test]
    fn availability_timeline_steps_down_and_up() {
        let t = Trace::generate(TraceConfig::trace_a(), 3);
        let tl = t.availability_timeline(8);
        assert_eq!(tl[0], (0.0, 128));
        let min = tl.iter().map(|&(_, g)| g).min().unwrap();
        assert!(min < 128, "SEV1 failures must reduce availability");
        // capacity never exceeds total or goes negative (clamped)
        assert!(tl.iter().all(|&(_, g)| g <= 128));
    }
}
