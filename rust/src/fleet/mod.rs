//! Fleet management: node health history, lemon detection, and cost-aware
//! hot-spare economics.
//!
//! The coordinator's view of the cluster used to stop at a flat
//! `isolated: Vec<NodeId>` — nodes were anonymous and memoryless, so the
//! system could neither recognize a *lemon* (a node whose failures recur
//! faster than repairs fix it — the dominant goodput sink in Meta's
//! "Revisiting Reliability in Large-Scale ML Research Clusters") nor reason
//! about how many repaired nodes to keep as hot spares versus return to the
//! cloud. This module is that memory:
//!
//! * [`FleetModel`] — per-node lifetime state: join/isolate/repair counts,
//!   a decayed **lemon score** over recurrent failures, an EWMA
//!   inter-failure-time MTBF estimate, and [`DomainId`] (rack/switch)
//!   membership with per-domain failure pressure for correlated-fault
//!   triage ("Characterization of LLM Development in the Datacenter" shows
//!   failures cluster by infrastructure domain).
//! * [`SparePool`] — the retain/release decision for a repaired node, in
//!   the same WAF currency the §5 planner optimizes: the expected FLOP·s a
//!   spare saves (Poisson tail of node failures in the insured window ×
//!   the WAF one node contributes) against the FLOP·s it costs to hold.
//!
//! # Determinism and the event clock
//!
//! Every *decision-relevant* quantity here is a pure function of the
//! coordinator's event sequence, never of wall-clock time: the lemon score
//! decays per **event** ([`FleetModel::tick`] advances the clock once per
//! [`crate::proto::CoordEvent`]), so replaying a recorded
//! [`crate::proto::DecisionLog`] through a fresh coordinator reproduces
//! every quarantine and spare decision bit-identically.
//!
//! Two EWMA MTBF estimates are time-fed by drivers that have a clock:
//!
//! * the **per-node** inter-failure-time estimate
//!   ([`FleetModel::observe_failure_time`]) — observability only, the
//!   fleet-health report's column;
//! * the **cluster-wide per-GPU** estimate
//!   ([`FleetModel::observe_cluster_failure`]) — *decision-relevant*: it
//!   tightens the cost ledger's opportunity horizon
//!   ([`crate::cost::CostModel`]) as real failure data accumulates.
//!   Determinism is preserved because every decision-relevant timestamp
//!   rides the v3 [`crate::proto::DecisionLog`] (`LogEntry::at_s`), so a
//!   replay feeds the estimator the exact recorded clock.
//!
//! # Lemon scoring
//!
//! On each failure attributed to a node:
//!
//! ```text
//! score ← score · γ^Δevents + w(severity)      (γ = lemon_decay)
//! ```
//!
//! Diffuse background failures (large `Δevents` between a node's failures)
//! decay away; a recurrent failer accumulates toward `w/(1−γ^Δ)` and
//! crosses `lemon_threshold`, at which point the coordinator fences the
//! node *before* its next failure and refuses to re-admit it after repair.

use std::collections::BTreeMap;
use std::fmt;

use crate::config::UnicronConfig;
use crate::failure::{Severity, Trace};
use crate::proto::NodeId;

/// Failure-domain identifier (rack / leaf switch). Nodes in one domain
/// share infrastructure and fail together under switch- or rack-level
/// faults. Mapping: `domain = node / nodes_per_domain`.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Hash, Default)]
pub struct DomainId(pub u32);

impl fmt::Display for DomainId {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        fmt::Display::fmt(&self.0, f)
    }
}

/// EWMA smoothing factor for the inter-failure-time estimate.
const EWMA_ALPHA: f64 = 0.3;

/// Exact decay over an event gap. `powi` is O(log dt) — at most ~31
/// multiplications — so the update stays O(1) per event regardless of idle
/// gaps, and slow-decay configurations (γ close to 1) keep their true
/// residual instead of being clipped to zero at an arbitrary horizon.
fn decayed(score: f64, decay: f64, dt_events: u64) -> f64 {
    if score == 0.0 {
        return 0.0;
    }
    score * decay.powi(dt_events.min(i32::MAX as u64) as i32)
}

/// Severity weight in the lemon score: a node-drain failure is stronger
/// evidence of bad hardware than a process-level one.
fn severity_weight(sev: Severity) -> f64 {
    match sev {
        Severity::Sev1 => 1.5,
        Severity::Sev2 | Severity::Sev3 => 1.0,
    }
}

/// Lifetime health record of one node.
#[derive(Debug, Clone, Default)]
pub struct NodeHealth {
    /// Failure domain (rack/switch) this node belongs to.
    pub domain: DomainId,
    /// Failures attributed to the node, lifetime (any severity).
    pub failures: u64,
    /// Times the node (re)joined the worker pool.
    pub joins: u64,
    /// Times the node came back from maintenance.
    pub repairs: u64,
    /// Fenced for good as a lemon.
    pub quarantined: bool,
    /// Returned to the provider (healthy, but out of the fleet).
    pub released: bool,
    /// Decayed recurrence score as of `last_failure_seq` (see module docs).
    score: f64,
    /// Event-clock stamp of the last failure (for decay).
    last_failure_seq: u64,
    /// EWMA of inter-failure times, seconds — the node's MTBF estimate.
    /// Observability only; decisions never read it (determinism note).
    ewma_ift_s: Option<f64>,
    last_failure_at_s: Option<f64>,
    /// EWMA of observed degradation slow fractions (wire v8) — the
    /// `/fleet/health` per-node degradation-score column. 0 for a node
    /// never seen degraded; rises toward the sustained slow fraction.
    degradation: f64,
}

impl NodeHealth {
    /// EWMA inter-failure-time MTBF estimate, seconds (None until the node
    /// has failed twice with observed times).
    pub fn mtbf_estimate_s(&self) -> Option<f64> {
        self.ewma_ift_s
    }
}

/// Per-domain failure statistics: the decayed burst pressure (event clock,
/// decision-relevant) and an EWMA inter-failure-time MTBF estimate
/// (wall-clock-fed, observability only — the `/fleet/health` report's
/// per-domain column).
#[derive(Debug, Clone)]
pub struct DomainStats {
    /// Decayed failure pressure (see [`FleetModel::domain_pressure`]).
    pressure: f64,
    /// Event-clock stamp of the last pressure update.
    last_seq: u64,
    /// EWMA of the domain's inter-failure times, seconds — seeded from the
    /// cluster prior (see [`FleetModel::domain_mtbf_estimate_s`]).
    ewma_ift_s: f64,
    /// Wall-clock stamp of the domain's last observed failure.
    last_failure_at_s: Option<f64>,
    /// Inter-failure gaps the domain estimate has absorbed.
    observations: u64,
}

impl DomainStats {
    /// The domain's EWMA MTBF estimate, seconds (the seeded prior until
    /// two failures with observed times have landed in the domain).
    pub fn mtbf_estimate_s(&self) -> f64 {
        self.ewma_ift_s
    }

    /// Inter-failure gaps absorbed so far.
    pub fn observations(&self) -> u64 {
        self.observations
    }
}

/// Per-node lifetime state + per-domain failure pressure for the whole
/// fleet. See the module docs for the scoring model and determinism rules.
#[derive(Debug, Clone)]
pub struct FleetModel {
    nodes: BTreeMap<NodeId, NodeHealth>,
    /// Per-domain statistics: burst pressure + EWMA MTBF.
    domains: BTreeMap<DomainId, DomainStats>,
    /// Seed for a fresh domain's MTBF estimate: the per-GPU cluster prior
    /// scaled to node granularity (one failing unit per node) — a domain of
    /// `nodes_per_domain` nodes is expected to fail that much more often
    /// than a single GPU-group. Observability only, so the scaling
    /// convention matters less than its consistency across domains.
    domain_prior_s: f64,
    /// Event clock: one tick per coordinator event (not wall time).
    seq: u64,
    nodes_per_domain: u32,
    decay: f64,
    threshold: f64,
    /// Cluster-wide EWMA per-GPU MTBF estimate, seconds. Starts at the
    /// config prior and is updated toward `gap × pool_gpus` on every
    /// observed cluster failure (see [`FleetModel::observe_cluster_failure`]).
    mtbf_per_gpu_est_s: f64,
    /// Timestamp of the last observed cluster failure.
    last_cluster_failure_at_s: Option<f64>,
    /// How many inter-failure gaps the estimate has absorbed.
    mtbf_observations: u64,
}

impl FleetModel {
    pub fn from_config(cfg: &UnicronConfig) -> FleetModel {
        let nodes_per_domain = cfg.nodes_per_domain.max(1);
        FleetModel {
            nodes: BTreeMap::new(),
            domains: BTreeMap::new(),
            domain_prior_s: cfg.mtbf_per_gpu_s / nodes_per_domain as f64,
            seq: 0,
            nodes_per_domain,
            decay: cfg.lemon_decay,
            threshold: cfg.lemon_threshold,
            mtbf_per_gpu_est_s: cfg.mtbf_per_gpu_s,
            last_cluster_failure_at_s: None,
            mtbf_observations: 0,
        }
    }

    /// Advance the event clock. The coordinator calls this once per handled
    /// [`crate::proto::CoordEvent`]; decay is measured in these ticks.
    pub fn tick(&mut self) {
        self.seq += 1;
    }

    /// Current event-clock value (ticks seen so far).
    pub fn now(&self) -> u64 {
        self.seq
    }

    /// Failure domain of `node`.
    pub fn domain_of(&self, node: NodeId) -> DomainId {
        DomainId(node.0 / self.nodes_per_domain)
    }

    fn entry(&mut self, node: NodeId) -> &mut NodeHealth {
        let domain = DomainId(node.0 / self.nodes_per_domain);
        self.nodes.entry(node).or_insert_with(|| NodeHealth { domain, ..Default::default() })
    }

    /// Record a failure attributed to `node`; returns the updated lemon
    /// score. Also bumps the node's domain pressure.
    pub fn note_failure(&mut self, node: NodeId, sev: Severity) -> f64 {
        let seq = self.seq;
        let decay = self.decay;
        let w = severity_weight(sev);
        let h = self.entry(node);
        let dt = seq.saturating_sub(h.last_failure_seq);
        h.score = decayed(h.score, decay, dt) + w;
        h.last_failure_seq = seq;
        h.failures += 1;
        let score = h.score;
        let domain = self.domain_of(node);
        let d = self.domain_entry(domain);
        let ddt = seq.saturating_sub(d.last_seq);
        d.pressure = decayed(d.pressure, decay, ddt) + w;
        d.last_seq = seq;
        score
    }

    fn domain_entry(&mut self, domain: DomainId) -> &mut DomainStats {
        let prior = self.domain_prior_s;
        self.domains.entry(domain).or_insert_with(|| DomainStats {
            pressure: 0.0,
            last_seq: 0,
            ewma_ift_s: prior,
            last_failure_at_s: None,
            observations: 0,
        })
    }

    /// Feed the wall-clock time of a failure on `node` (drivers that have a
    /// clock). Updates the node's *and its domain's* EWMA inter-failure-time
    /// MTBF estimates — observability only, never read by decisions.
    pub fn observe_failure_time(&mut self, node: NodeId, at_s: f64) {
        let h = self.entry(node);
        if let Some(prev) = h.last_failure_at_s {
            let ift = (at_s - prev).max(0.0);
            h.ewma_ift_s = Some(match h.ewma_ift_s {
                None => ift,
                Some(e) => EWMA_ALPHA * ift + (1.0 - EWMA_ALPHA) * e,
            });
        }
        h.last_failure_at_s = Some(at_s);
        // the domain's estimate: EWMA over the domain's own failure gaps,
        // starting at the cluster-prior seed (zero/negative gaps — burst
        // members, out-of-order feeds — are not independent samples)
        let domain = self.domain_of(node);
        let d = self.domain_entry(domain);
        if let Some(prev) = d.last_failure_at_s {
            let gap = at_s - prev;
            if gap > 0.0 {
                d.ewma_ift_s = EWMA_ALPHA * gap + (1.0 - EWMA_ALPHA) * d.ewma_ift_s;
                d.observations += 1;
            }
        }
        let anchor = d.last_failure_at_s.map_or(at_s, |p| p.max(at_s));
        d.last_failure_at_s = Some(anchor);
    }

    /// Feed the wall-clock time of *any* failure in a pool of `pool_gpus`
    /// workers. Updates the cluster-wide EWMA per-GPU MTBF estimate —
    /// `gap × pool_gpus` is one sample of the per-GPU MTBF (a pool of `n`
    /// GPUs failing every `g` seconds implies each GPU fails every `n·g`).
    ///
    /// The first observation only anchors the clock; zero or negative gaps
    /// (same-instant burst members, out-of-order feeds) are skipped — a
    /// correlated burst is one failure event for MTBF purposes, not `k`
    /// independent samples. Returns true when the estimate changed.
    pub fn observe_cluster_failure(&mut self, at_s: f64, pool_gpus: u32) -> bool {
        let prev = self.last_cluster_failure_at_s;
        self.last_cluster_failure_at_s = Some(match prev {
            Some(p) if at_s < p => p,
            _ => at_s,
        });
        let Some(prev) = prev else { return false };
        let gap = at_s - prev;
        if gap <= 0.0 {
            return false;
        }
        let sample = gap * pool_gpus.max(1) as f64;
        let before = self.mtbf_per_gpu_est_s;
        self.mtbf_per_gpu_est_s = (1.0 - EWMA_ALPHA) * before + EWMA_ALPHA * sample;
        self.mtbf_observations += 1;
        self.mtbf_per_gpu_est_s != before
    }

    /// Cluster-wide per-GPU MTBF estimate, seconds: the config prior until
    /// failures are observed, then the EWMA-tightened value. This is the
    /// MTBF the cost ledger prices horizons and spare economics with.
    pub fn mtbf_per_gpu_estimate_s(&self) -> f64 {
        self.mtbf_per_gpu_est_s
    }

    /// Number of inter-failure gaps the cluster estimate has absorbed.
    pub fn mtbf_observations(&self) -> u64 {
        self.mtbf_observations
    }

    pub fn note_join(&mut self, node: NodeId) {
        let h = self.entry(node);
        h.joins += 1;
        h.quarantined = false;
        h.released = false;
    }

    pub fn note_repair(&mut self, node: NodeId) {
        self.entry(node).repairs += 1;
    }

    pub fn note_quarantine(&mut self, node: NodeId) {
        self.entry(node).quarantined = true;
    }

    /// Record a gray-degradation observation on `node` (wire v8): the
    /// measured slow fraction blends into the node's EWMA degradation
    /// score. Clamped to [0, 1] so a wild sample cannot poison the score.
    pub fn note_degradation(&mut self, node: NodeId, slow_frac: f64) {
        let s = slow_frac.clamp(0.0, 1.0);
        let h = self.entry(node);
        h.degradation = EWMA_ALPHA * s + (1.0 - EWMA_ALPHA) * h.degradation;
    }

    /// The node's EWMA degradation score in [0, 1] — 0 for a node with no
    /// history or one never observed degraded.
    pub fn degradation_score(&self, node: NodeId) -> f64 {
        self.nodes.get(&node).map_or(0.0, |h| h.degradation)
    }

    /// Hazard-aware MTBF (seconds): the node's EWMA inter-failure-time
    /// estimate (or the cluster-wide per-GPU estimate when the node has no
    /// history of its own) scaled by a Weibull-shaped age multiplier with
    /// shape k < 1 — the infant-mortality regime both datacenter
    /// characterization studies measure: a barely-exercised node carries a
    /// hazard rate well above the fleet average, and the rate settles
    /// toward baseline as the node survives more lifecycle events.
    ///
    /// The age proxy is the node's lifecycle event count
    /// (joins + repairs + failures) — event-clock data, not wall time.
    /// The multiplier `(age / AGE_SCALE)^(1 − k)` is clamped to
    /// [0.25, 4.0] so the column stays interpretable next to the raw
    /// estimate. **Observability only** — the `/fleet/health` report's
    /// hazard column; decisions keep pricing with the flat EWMA estimate
    /// (determinism: replays would otherwise have to reproduce the age
    /// proxy exactly, and the cost ledger's horizon stays a pure EWMA).
    pub fn hazard_adjusted_mtbf_s(&self, node: NodeId) -> f64 {
        /// Weibull shape: k < 1 means decreasing hazard with age.
        const WEIBULL_K: f64 = 0.7;
        /// Lifecycle events at which a node reaches the fleet baseline.
        const AGE_SCALE: f64 = 8.0;
        let base = self
            .nodes
            .get(&node)
            .and_then(|h| h.ewma_ift_s)
            .unwrap_or(self.mtbf_per_gpu_est_s);
        let age = self
            .nodes
            .get(&node)
            .map_or(0, |h| h.joins + h.repairs + h.failures)
            .max(1) as f64;
        let multiplier = (age / AGE_SCALE).powf(1.0 - WEIBULL_K).clamp(0.25, 4.0);
        base * multiplier
    }

    pub fn note_release(&mut self, node: NodeId) {
        self.entry(node).released = true;
    }

    /// The node's lemon score decayed to the current event clock.
    pub fn lemon_score(&self, node: NodeId) -> f64 {
        match self.nodes.get(&node) {
            Some(h) => decayed(h.score, self.decay, self.seq.saturating_sub(h.last_failure_seq)),
            None => 0.0,
        }
    }

    /// True when the node's decayed recurrence score has crossed the
    /// quarantine threshold — the fence-before-it-fails-again signal.
    pub fn is_lemon(&self, node: NodeId) -> bool {
        self.lemon_score(node) >= self.threshold
    }

    /// Decayed failure pressure of a domain (rack/switch). A burst of
    /// near-simultaneous failures inside one domain pushes this far above
    /// what independent node failures produce.
    pub fn domain_pressure(&self, domain: DomainId) -> f64 {
        match self.domains.get(&domain) {
            Some(d) => decayed(d.pressure, self.decay, self.seq.saturating_sub(d.last_seq)),
            None => 0.0,
        }
    }

    /// The domain's EWMA MTBF estimate, seconds: the cluster-prior seed
    /// (`mtbf_per_gpu_s / nodes_per_domain`) until the domain has observed
    /// failure gaps, then the EWMA-tightened value. Observability only —
    /// the `/fleet/health` report's per-domain column (ROADMAP PR-4
    /// follow-up).
    pub fn domain_mtbf_estimate_s(&self, domain: DomainId) -> f64 {
        self.domains.get(&domain).map_or(self.domain_prior_s, |d| d.ewma_ift_s)
    }

    /// All domains with recorded history, ascending id, with their stats.
    pub fn domains(&self) -> impl Iterator<Item = (&DomainId, &DomainStats)> {
        self.domains.iter()
    }

    /// True when a domain's pressure indicates a correlated (switch/rack)
    /// fault rather than independent node failures.
    pub fn domain_is_bursting(&self, domain: DomainId) -> bool {
        self.domain_pressure(domain) >= self.threshold
    }

    /// Health record of `node`, if it has any history.
    pub fn health(&self, node: NodeId) -> Option<&NodeHealth> {
        self.nodes.get(&node)
    }

    /// All recorded nodes in id order.
    pub fn nodes(&self) -> impl Iterator<Item = (&NodeId, &NodeHealth)> {
        self.nodes.iter()
    }

    /// Rank candidate nodes healthiest-first: ascending decayed lemon
    /// score, then ascending lifetime failures, then id. This is the
    /// "prefer non-lemon nodes" order for placement and for choosing which
    /// spare to give up first.
    pub fn healthiest_first(&self, candidates: &[NodeId]) -> Vec<NodeId> {
        let mut ranked: Vec<NodeId> = candidates.to_vec();
        ranked.sort_by(|&a, &b| {
            self.lemon_score(a)
                .total_cmp(&self.lemon_score(b))
                .then_with(|| {
                    let fa = self.nodes.get(&a).map_or(0, |h| h.failures);
                    let fb = self.nodes.get(&b).map_or(0, |h| h.failures);
                    fa.cmp(&fb)
                })
                .then(a.cmp(&b))
        });
        ranked
    }

    /// Build a fleet view from a failure trace (offline analysis: the
    /// `fleet-lemon` experiment's health report). Feeds both the event-clock
    /// score and the time-based MTBF estimate.
    pub fn ingest_trace(trace: &Trace, cfg: &UnicronConfig) -> FleetModel {
        let mut fleet = FleetModel::from_config(cfg);
        for e in &trace.events {
            fleet.tick();
            fleet.note_failure(e.node, e.severity());
            fleet.observe_failure_time(e.node, e.at_s);
        }
        fleet
    }
}

// ---------------------------------------------------------------------------
// Spare-pool economics
// ---------------------------------------------------------------------------

/// Retain/release verdict for a repaired (or surplus) node.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum SpareDecision {
    /// Keep the node — rejoin the pool (or hold as a hot spare).
    Retain,
    /// Return the node to the provider.
    Release,
}

/// The hot-spare cost model, in the §5 planner's WAF currency (Eq. 2:
/// FLOP/s weighted by priority; integrated over the insured window the
/// comparison is FLOP·s on both sides):
///
/// * **value** of holding the `(k+1)`-th spare = `P(X ≥ k+1) · F_node · W`
///   where `X ~ Poisson(λ)` is the node-failure count inside the window
///   `W`, and `F_node` is the WAF one node contributes — the expected
///   useful work the spare rescues by covering a shortfall;
/// * **cost** of holding it = `hold_frac · F_node · W` — the fraction of a
///   node's worth of WAF the money spent on an idle machine could have
///   bought.
///
/// Retain while value exceeds cost, never beyond `max_spares`. `F_node · W`
/// appears on both sides, so the break-even condition reduces to
/// `P(shortfall) > hold_frac` — the knob is directly a probability.
#[derive(Debug, Clone, PartialEq)]
pub struct SparePool {
    /// Holding cost of one spare as a fraction of the WAF a node earns.
    pub hold_frac: f64,
    /// Provisioning/repair window (seconds) the pool insures against.
    pub window_s: f64,
    /// Hard cap on held spares.
    pub max_spares: u32,
}

/// Upper tail `P(X ≥ k)` for `X ~ Poisson(lambda)`.
pub fn poisson_tail(lambda: f64, k: u32) -> f64 {
    if lambda <= 0.0 {
        return if k == 0 { 1.0 } else { 0.0 };
    }
    let mut term = (-lambda).exp(); // P(X = 0)
    let mut cdf = 0.0;
    for i in 0..k {
        cdf += term;
        term *= lambda / (i + 1) as f64;
    }
    (1.0 - cdf).max(0.0)
}

impl SparePool {
    pub fn from_config(cfg: &UnicronConfig) -> SparePool {
        SparePool {
            hold_frac: cfg.spare_hold_frac,
            window_s: cfg.spare_window_s,
            max_spares: cfg.max_spares,
        }
    }

    /// Expected node-failure count in the insured window for a pool of
    /// `gpus` workers with per-GPU MTBF `mtbf_per_gpu_s` (one GPU failure
    /// drains its node, §5.1's failure model).
    pub fn expected_failures(&self, gpus: u32, mtbf_per_gpu_s: f64) -> f64 {
        if mtbf_per_gpu_s <= 0.0 {
            return 0.0;
        }
        gpus as f64 * self.window_s / mtbf_per_gpu_s
    }

    /// WAF-style value (FLOP·s) of holding the `(held+1)`-th spare.
    pub fn spare_value(&self, held: u32, lambda: f64, node_waf: f64) -> f64 {
        poisson_tail(lambda, held + 1) * node_waf * self.window_s
    }

    /// Cost (FLOP·s) of holding one spare for the window.
    pub fn hold_cost(&self, node_waf: f64) -> f64 {
        self.hold_frac * node_waf * self.window_s
    }

    /// The retain/release decision with `held` spares already in hand.
    pub fn decide(&self, held: u32, lambda: f64, node_waf: f64) -> SpareDecision {
        if held >= self.max_spares {
            return SpareDecision::Release;
        }
        if self.spare_value(held, lambda, node_waf) > self.hold_cost(node_waf) {
            SpareDecision::Retain
        } else {
            SpareDecision::Release
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::failure::{ErrorKind, TraceConfig};

    fn cfg() -> UnicronConfig {
        UnicronConfig::default()
    }

    fn fleet() -> FleetModel {
        FleetModel::from_config(&cfg())
    }

    #[test]
    fn recurrent_failures_cross_the_threshold_diffuse_ones_do_not() {
        // back-to-back failures on one node accumulate...
        let mut f = fleet();
        let mut crossed_at = None;
        for i in 0..30 {
            f.tick();
            f.note_failure(NodeId(3), Severity::Sev2);
            if crossed_at.is_none() && f.is_lemon(NodeId(3)) {
                crossed_at = Some(i + 1);
            }
        }
        let crossed_at = crossed_at.expect("a node failing every event is a lemon");
        assert!(crossed_at >= 4, "threshold must tolerate a short escalation chain: {crossed_at}");

        // ...while the same count spread far apart decays away
        let mut g = fleet();
        for _ in 0..30 {
            for _ in 0..100 {
                g.tick(); // 100 quiet events between failures
            }
            g.note_failure(NodeId(3), Severity::Sev2);
        }
        assert!(!g.is_lemon(NodeId(3)), "diffuse failures are not a lemon signal");
        assert!(g.lemon_score(NodeId(3)) < 1.5);
        assert_eq!(g.health(NodeId(3)).unwrap().failures, 30);
    }

    #[test]
    fn short_escalation_chains_stay_below_threshold() {
        // The §4.2 ladder (3 reattempts + restart + SEV1) on a healthy node
        // must NOT read as a lemon — only *recurrence* does.
        let mut f = fleet();
        for _ in 0..5 {
            f.tick();
            f.note_failure(NodeId(7), Severity::Sev3);
        }
        f.tick();
        f.note_failure(NodeId(7), Severity::Sev1);
        assert!(!f.is_lemon(NodeId(7)), "score {}", f.lemon_score(NodeId(7)));
    }

    #[test]
    fn lemon_score_decays_between_failures() {
        let mut f = fleet();
        f.tick();
        let s1 = f.note_failure(NodeId(0), Severity::Sev2);
        for _ in 0..10 {
            f.tick();
        }
        assert!(f.lemon_score(NodeId(0)) < s1);
        for _ in 0..1000 {
            f.tick();
        }
        assert!(f.lemon_score(NodeId(0)) < 1e-12, "ancient history decays to nothing");
    }

    #[test]
    fn slow_decay_configurations_accumulate_across_long_gaps() {
        // γ close to 1: a node failing every ~600 events must still build
        // toward quarantine — no hidden horizon may zero the residual.
        let cfg = UnicronConfig { lemon_decay: 0.999, ..UnicronConfig::default() };
        let mut f = FleetModel::from_config(&cfg);
        let mut last = 0.0;
        for _ in 0..6 {
            for _ in 0..600 {
                f.tick();
            }
            last = f.note_failure(NodeId(1), Severity::Sev2);
        }
        // true residual 0.999^600 ≈ 0.55 per gap: the score compounds
        assert!(last > 2.0, "slow decay must accumulate, got {last}");
    }

    #[test]
    fn sev1_weighs_more_than_sev3() {
        let mut a = fleet();
        a.tick();
        let s1 = a.note_failure(NodeId(1), Severity::Sev1);
        let mut b = fleet();
        b.tick();
        let s3 = b.note_failure(NodeId(1), Severity::Sev3);
        assert!(s1 > s3);
    }

    #[test]
    fn domain_membership_and_burst_pressure() {
        let mut f = fleet();
        assert_eq!(f.domain_of(NodeId(0)), f.domain_of(NodeId(3)));
        assert_ne!(f.domain_of(NodeId(0)), f.domain_of(NodeId(4)));
        // a tight burst across one domain's nodes raises that domain only
        for node in [0u32, 1, 2, 3, 0, 1, 2, 3] {
            f.tick();
            f.note_failure(NodeId(node), Severity::Sev1);
        }
        let d0 = f.domain_of(NodeId(0));
        assert!(f.domain_is_bursting(d0), "pressure {}", f.domain_pressure(d0));
        assert!(!f.domain_is_bursting(f.domain_of(NodeId(4))));
        // no single node in the burst is a lemon yet
        assert!(!f.is_lemon(NodeId(0)));
    }

    #[test]
    fn ewma_mtbf_tracks_inter_failure_times() {
        let mut f = fleet();
        for k in 0..10u32 {
            f.tick();
            f.note_failure(NodeId(2), Severity::Sev2);
            f.observe_failure_time(NodeId(2), 100.0 * k as f64);
        }
        let est = f.health(NodeId(2)).unwrap().mtbf_estimate_s().unwrap();
        assert!((est - 100.0).abs() < 1e-9, "constant gaps converge exactly: {est}");
        // a node seen once has no estimate
        f.tick();
        f.note_failure(NodeId(9), Severity::Sev2);
        f.observe_failure_time(NodeId(9), 5.0);
        assert!(f.health(NodeId(9)).unwrap().mtbf_estimate_s().is_none());
    }

    #[test]
    fn cluster_mtbf_estimate_starts_at_prior_and_tightens() {
        let mut f = fleet();
        let prior = cfg().mtbf_per_gpu_s;
        assert_eq!(f.mtbf_per_gpu_estimate_s(), prior);
        assert_eq!(f.mtbf_observations(), 0);
        // first observation only anchors the clock
        assert!(!f.observe_cluster_failure(1000.0, 128));
        assert_eq!(f.mtbf_per_gpu_estimate_s(), prior);
        // failures every hour in a 128-GPU pool: samples of 3600·128 ≈ 4.6e5,
        // far below the 1.9e7 prior — the estimate must tighten toward them
        let mut t = 1000.0;
        for _ in 0..40 {
            t += 3600.0;
            assert!(f.observe_cluster_failure(t, 128));
        }
        let est = f.mtbf_per_gpu_estimate_s();
        assert!(est < prior / 10.0, "estimate must tighten: {est} vs prior {prior}");
        assert!(est > 3600.0 * 128.0 * 0.99, "never below the observed rate: {est}");
        assert_eq!(f.mtbf_observations(), 40);
    }

    #[test]
    fn domain_mtbf_seeds_from_the_cluster_prior_and_tightens_per_domain() {
        let mut f = fleet();
        let prior = cfg().mtbf_per_gpu_s / cfg().nodes_per_domain as f64;
        let d0 = f.domain_of(NodeId(0));
        let d1 = f.domain_of(NodeId(4));
        // unseen domains report the seeded prior
        assert_eq!(f.domain_mtbf_estimate_s(d0), prior);
        // hourly failures across domain 0's nodes tighten d0's estimate;
        // d1 never fails and keeps the prior
        for k in 0..20u32 {
            let node = NodeId(k % 4); // all of domain 0
            f.tick();
            f.note_failure(node, Severity::Sev2);
            f.observe_failure_time(node, 3600.0 * k as f64);
        }
        let est = f.domain_mtbf_estimate_s(d0);
        assert!(est < prior / 10.0, "domain estimate must tighten: {est} vs {prior}");
        assert!(est > 3600.0 * 0.99, "never below the observed domain rate: {est}");
        assert_eq!(f.domain_mtbf_estimate_s(d1), prior);
        // zero-gap burst members are not independent samples
        let stats = f.domains().find(|(&d, _)| d == d0).map(|(_, s)| s.clone()).unwrap();
        let obs = stats.observations();
        f.observe_failure_time(NodeId(1), 3600.0 * 19.0); // same instant as last
        let stats = f.domains().find(|(&d, _)| d == d0).map(|(_, s)| s.clone()).unwrap();
        assert_eq!(stats.observations(), obs);
        assert_eq!(stats.mtbf_estimate_s(), est);
    }

    #[test]
    fn cluster_mtbf_skips_zero_gaps_and_out_of_order_feeds() {
        let mut f = fleet();
        f.observe_cluster_failure(100.0, 64);
        // a same-instant burst member is not an independent MTBF sample
        assert!(!f.observe_cluster_failure(100.0, 64));
        // out-of-order (a driver replaying stale events) is skipped too
        assert!(!f.observe_cluster_failure(50.0, 64));
        assert_eq!(f.mtbf_observations(), 0);
        // the clock anchor did not move backwards
        assert!(f.observe_cluster_failure(160.0, 64), "60 s gap must count");
        assert_eq!(f.mtbf_observations(), 1);
    }

    #[test]
    fn degradation_score_blends_toward_the_sustained_slow_fraction() {
        let mut f = fleet();
        assert_eq!(f.degradation_score(NodeId(3)), 0.0, "no history means no score");
        for _ in 0..30 {
            f.note_degradation(NodeId(3), 0.4);
        }
        let s = f.degradation_score(NodeId(3));
        assert!((s - 0.4).abs() < 1e-3, "sustained 40 % slow converges: {s}");
        // other nodes are untouched
        assert_eq!(f.degradation_score(NodeId(4)), 0.0);
        // wild samples are clamped, never poisoning the score
        f.note_degradation(NodeId(3), 50.0);
        assert!(f.degradation_score(NodeId(3)) <= 1.0);
        f.note_degradation(NodeId(3), -7.0);
        assert!(f.degradation_score(NodeId(3)) >= 0.0);
    }

    #[test]
    fn hazard_mtbf_penalizes_young_nodes_and_settles_with_age() {
        let mut f = fleet();
        let base = f.mtbf_per_gpu_estimate_s();
        // a brand-new node (no lifecycle history) is in the infant-mortality
        // regime: its hazard-adjusted MTBF sits below the flat estimate
        let young = f.hazard_adjusted_mtbf_s(NodeId(0));
        assert!(young < base, "young {young} vs base {base}");
        assert!(young >= base * 0.25, "clamp floor holds");
        // the multiplier rises monotonically with lifecycle age
        let mut prev = young;
        for _ in 0..20 {
            f.note_join(NodeId(0));
            let h = f.hazard_adjusted_mtbf_s(NodeId(0));
            assert!(h >= prev, "hazard MTBF never falls with age: {h} < {prev}");
            prev = h;
        }
        // a long-serving node earns a multiplier above 1 (clamped at 4)
        assert!(prev > base && prev <= base * 4.0);
        // a node with its own inter-failure history scales that estimate
        let mut g = fleet();
        for k in 0..10u32 {
            g.tick();
            g.note_failure(NodeId(2), Severity::Sev2);
            g.observe_failure_time(NodeId(2), 100.0 * k as f64);
        }
        let own = g.health(NodeId(2)).unwrap().mtbf_estimate_s().unwrap();
        let h = g.hazard_adjusted_mtbf_s(NodeId(2));
        assert!(h >= own * 0.25 && h <= own * 4.0, "{h} vs own estimate {own}");
    }

    #[test]
    fn ingest_trace_builds_history_for_every_failing_node() {
        let trace = Trace::generate(TraceConfig::trace_a(), 42);
        let f = FleetModel::ingest_trace(&trace, &cfg());
        let total: u64 = f.nodes().map(|(_, h)| h.failures).sum();
        assert_eq!(total as usize, trace.events.len());
        // a stock trace's diffuse failures never flag a lemon
        for (&n, _) in f.nodes() {
            assert!(!f.is_lemon(n), "node {n} wrongly flagged in a stock trace");
        }
    }

    #[test]
    fn recurrent_lemon_trace_is_flagged_by_ingest() {
        let tc = TraceConfig { expect_sev1: 0.0, expect_other: 0.0, ..TraceConfig::trace_a() };
        let trace = Trace::generate(tc, 1).with_recurrent_lemon(
            NodeId(5),
            ErrorKind::CudaError,
            600.0,
            30.0,
            600.0 + 86400.0,
        );
        let f = FleetModel::ingest_trace(&trace, &cfg());
        assert!(f.is_lemon(NodeId(5)));
        assert!((f.health(NodeId(5)).unwrap().mtbf_estimate_s().unwrap() - 30.0).abs() < 1e-6);
    }

    #[test]
    fn healthiest_first_prefers_non_lemons() {
        let mut f = fleet();
        for _ in 0..8 {
            f.tick();
            f.note_failure(NodeId(4), Severity::Sev2);
        }
        f.tick();
        f.note_failure(NodeId(1), Severity::Sev3);
        let order = f.healthiest_first(&[NodeId(4), NodeId(1), NodeId(0)]);
        assert_eq!(order, vec![NodeId(0), NodeId(1), NodeId(4)]);
    }

    #[test]
    fn join_clears_quarantine_flags() {
        let mut f = fleet();
        f.note_quarantine(NodeId(6));
        assert!(f.health(NodeId(6)).unwrap().quarantined);
        f.note_join(NodeId(6)); // operator override
        let h = f.health(NodeId(6)).unwrap();
        assert!(!h.quarantined && !h.released);
        assert_eq!(h.joins, 1);
    }

    #[test]
    fn poisson_tail_sane() {
        assert_eq!(poisson_tail(0.0, 0), 1.0);
        assert_eq!(poisson_tail(0.0, 1), 0.0);
        let lambda = 1.2;
        assert!((poisson_tail(lambda, 0) - 1.0).abs() < 1e-12);
        let p1 = poisson_tail(lambda, 1);
        assert!((p1 - (1.0 - (-lambda as f64).exp())).abs() < 1e-12);
        // monotone decreasing in k, bounded in [0, 1]
        let mut prev = 1.0;
        for k in 0..8 {
            let p = poisson_tail(lambda, k);
            assert!((0.0..=1.0).contains(&p));
            assert!(p <= prev + 1e-15);
            prev = p;
        }
    }

    #[test]
    fn spare_decisions_follow_the_waf_break_even() {
        let pool = SparePool { hold_frac: 0.25, window_s: 86400.0, max_spares: 2 };
        let node_waf = 1e15;
        // high failure pressure: P(X >= 1) well above hold_frac -> retain
        assert_eq!(pool.decide(0, 2.0, node_waf), SpareDecision::Retain);
        // negligible failure pressure -> release
        assert_eq!(pool.decide(0, 0.01, node_waf), SpareDecision::Release);
        // cap: never hold more than max_spares
        assert_eq!(pool.decide(2, 50.0, node_waf), SpareDecision::Release);
        // free spares (no holding cost) are always worth keeping under load
        let free = SparePool { hold_frac: 0.0, ..pool.clone() };
        assert_eq!(free.decide(1, 0.5, node_waf), SpareDecision::Retain);
        // a cluster doing no work protects nothing
        assert_eq!(free.decide(0, 0.5, 0.0), SpareDecision::Release);
    }

    #[test]
    fn spare_value_decreases_with_spares_already_held() {
        let pool = SparePool::from_config(&cfg());
        let lambda = pool.expected_failures(128, cfg().mtbf_per_gpu_s);
        assert!(lambda > 0.0);
        let v0 = pool.spare_value(0, lambda, 1e15);
        let v1 = pool.spare_value(1, lambda, 1e15);
        assert!(v0 > v1, "the second spare insures a rarer event");
        assert!(pool.hold_cost(1e15) > 0.0);
    }
}
