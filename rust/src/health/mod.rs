//! In-band health observation (DESIGN.md §16): bounded-memory streaming
//! statistics over per-node per-step durations, turning the paper's
//! fail-stop detection ladder (§4.1) into one that also sees the *quiet*
//! failures the datacenter characterization studies blame for most lost
//! goodput — stragglers and gray degradation (a sick NVLink/NIC silently
//! slowing a whole DP group).
//!
//! Three pieces:
//!
//! * [`DegradationKind`] — the typed vocabulary of the wire-v8
//!   `NodeDegraded` event (straggler / partial-bandwidth / churn-risk).
//! * [`StreamStats`] — an O(1)-per-sample online estimator: EWMA mean plus
//!   an EWMA of absolute deviation (a robust MAD-style scale), no
//!   allocation after construction. `score` is the robust z-score the
//!   outlier gate uses.
//! * [`HealthMonitor`] — per-node streams behind one observe call. Each
//!   node's *baseline* folds in only in-band samples (outliers are scored,
//!   never absorbed, so a sustained slowdown cannot drag its own reference
//!   up), and sustained excursions classify: `slow_frac ≥ fail` for
//!   `min_samples` consecutive steps is a [`DegradationKind::Straggler`];
//!   a longer streak in the warn band is gray
//!   [`DegradationKind::PartialBandwidth`].
//!
//! The monitor is deterministic state driven purely by the recorded
//! [`CoordEvent::StepTiming`](crate::proto::CoordEvent) stream, so replays
//! of a [`DecisionLog`](crate::proto::DecisionLog) rebuild identical
//! classifications — detection stays inside the standing
//! `Trace` → `CoordEvent` → `RecoveryPolicy` → `Action` flow.

use std::collections::BTreeMap;

use crate::config::UnicronConfig;
use crate::proto::NodeId;

/// Robust z-score above which a sample is an outlier the baseline refuses
/// to absorb (1.4826·MAD ≈ one σ under normality; 3σ is the usual gate).
const OUTLIER_SCORE: f64 = 3.0;

/// Typed degradation vocabulary of the wire-v8 `NodeDegraded` event.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
pub enum DegradationKind {
    /// Sustained per-step slowdown past the fail fraction: the node drags
    /// its whole task (the classic straggler).
    Straggler,
    /// Sustained warn-band slowdown: gray partial-bandwidth loss — the
    /// node still completes steps, just consistently slower.
    PartialBandwidth,
    /// An external churn signal (spot/preemption notice): no slowdown yet,
    /// but the hazard of imminent loss is elevated.
    ChurnRisk,
}

impl DegradationKind {
    pub fn all() -> &'static [DegradationKind] {
        &[
            DegradationKind::Straggler,
            DegradationKind::PartialBandwidth,
            DegradationKind::ChurnRisk,
        ]
    }

    /// Stable wire name (the tagged-JSON `kind` field).
    pub fn name(self) -> &'static str {
        match self {
            DegradationKind::Straggler => "straggler",
            DegradationKind::PartialBandwidth => "partial_bandwidth",
            DegradationKind::ChurnRisk => "churn_risk",
        }
    }

    /// Strict inverse of [`name`](Self::name): unknown names are `None`
    /// (the proto layer turns that into a decode error, never a default).
    pub fn from_name(name: &str) -> Option<DegradationKind> {
        DegradationKind::all().iter().copied().find(|k| k.name() == name)
    }
}

/// Bounded-memory online estimator: EWMA mean + EWMA absolute deviation.
/// O(1) per sample, no allocation, four words of state.
#[derive(Debug, Clone, Default)]
pub struct StreamStats {
    count: u64,
    mean: f64,
    abs_dev: f64,
    alpha: f64,
}

impl StreamStats {
    /// `alpha` is the EWMA weight of the newest sample (0 < alpha ≤ 1).
    pub fn new(alpha: f64) -> StreamStats {
        assert!(alpha > 0.0 && alpha <= 1.0, "alpha must be in (0, 1]: {alpha}");
        StreamStats { count: 0, mean: 0.0, abs_dev: 0.0, alpha }
    }

    /// Fold one sample into the estimator.
    pub fn observe(&mut self, x: f64) {
        if self.count == 0 {
            self.mean = x;
            self.abs_dev = 0.0;
        } else {
            let dev = (x - self.mean).abs();
            self.abs_dev += self.alpha * (dev - self.abs_dev);
            self.mean += self.alpha * (x - self.mean);
        }
        self.count += 1;
    }

    pub fn count(&self) -> u64 {
        self.count
    }

    pub fn mean(&self) -> f64 {
        self.mean
    }

    /// Robust MAD-style scale (EWMA of absolute deviation).
    pub fn mad(&self) -> f64 {
        self.abs_dev
    }

    /// Robust z-score of `x` against the stream: deviation over
    /// 1.4826·MAD (the normal-consistency factor), floored so a perfectly
    /// constant warm-up stream still scores spikes as outliers.
    pub fn score(&self, x: f64) -> f64 {
        let scale = (1.4826 * self.abs_dev).max(1e-3 * self.mean.abs()).max(1e-12);
        (x - self.mean).abs() / scale
    }
}

/// Per-node stream state: the in-band baseline plus excursion streaks.
#[derive(Debug, Clone, Default)]
struct NodeStream {
    baseline: StreamStats,
    warn_streak: u32,
    fail_streak: u32,
}

/// Per-node per-step duration ingestion with slow-node / gray-degradation
/// classification. One [`observe_step`](Self::observe_step) call per
/// sample; the steady-state hot path is a small-map lookup plus a handful
/// of multiply-adds (allocation happens only on a node's *first* sample).
#[derive(Debug, Clone)]
pub struct HealthMonitor {
    nodes: BTreeMap<NodeId, NodeStream>,
    alpha: f64,
    warn_frac: f64,
    fail_frac: f64,
    min_samples: u32,
}

impl HealthMonitor {
    pub fn from_config(cfg: &UnicronConfig) -> HealthMonitor {
        assert!(
            cfg.degradation_fail_frac > cfg.degradation_warn_frac
                && cfg.degradation_warn_frac > 0.0
                && cfg.degradation_fail_frac < 1.0,
            "degradation fractions must satisfy 0 < warn < fail < 1"
        );
        HealthMonitor {
            nodes: BTreeMap::new(),
            // the baseline adapts slowly on purpose: it is the reference a
            // sustained excursion is judged against
            alpha: 0.05,
            warn_frac: cfg.degradation_warn_frac,
            fail_frac: cfg.degradation_fail_frac,
            min_samples: cfg.degradation_min_samples.max(1),
        }
    }

    /// Ingest one per-step duration for `node`. Returns a classification
    /// once an excursion is *sustained*: `Straggler` after `min_samples`
    /// consecutive steps past the fail fraction, `PartialBandwidth` after
    /// `2×min_samples` consecutive steps past the warn fraction. While
    /// degraded the classification repeats every step (the caller decides
    /// once and isolates, or keeps tolerating), and the baseline never
    /// absorbs out-of-band samples.
    pub fn observe_step(&mut self, node: NodeId, duration_s: f64) -> Option<(DegradationKind, f64)> {
        if !(duration_s.is_finite() && duration_s > 0.0) {
            return None;
        }
        let alpha = self.alpha;
        let min_samples = self.min_samples;
        let s = self.nodes.entry(node).or_insert_with(|| NodeStream {
            baseline: StreamStats::new(alpha),
            ..Default::default()
        });
        if s.baseline.count() < u64::from(min_samples) {
            s.baseline.observe(duration_s); // warm-up: build the reference
            return None;
        }
        let base = s.baseline.mean();
        // how much of the step the node wastes vs its own healthy baseline
        let slow_frac = (1.0 - base / duration_s).max(0.0);
        let outlier = s.baseline.score(duration_s) >= OUTLIER_SCORE;
        if slow_frac >= self.fail_frac {
            s.fail_streak += 1;
            s.warn_streak += 1;
        } else if slow_frac >= self.warn_frac && outlier {
            s.fail_streak = 0;
            s.warn_streak += 1;
        } else {
            s.fail_streak = 0;
            s.warn_streak = 0;
            s.baseline.observe(duration_s); // in-band: refresh the baseline
        }
        if s.fail_streak >= min_samples {
            Some((DegradationKind::Straggler, slow_frac))
        } else if s.warn_streak >= 2 * min_samples {
            Some((DegradationKind::PartialBandwidth, slow_frac))
        } else {
            None
        }
    }

    /// The node's healthy-baseline step duration, once warmed up.
    pub fn baseline_s(&self, node: NodeId) -> Option<f64> {
        let s = self.nodes.get(&node)?;
        (s.baseline.count() > 0).then(|| s.baseline.mean())
    }

    /// Number of nodes with at least one ingested sample.
    pub fn nodes_observed(&self) -> usize {
        self.nodes.len()
    }

    /// Drop a node's stream (evicted/isolated nodes stop being judged; a
    /// repaired node re-warms from scratch).
    pub fn forget(&mut self, node: NodeId) {
        self.nodes.remove(&node);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn monitor() -> HealthMonitor {
        HealthMonitor::from_config(&UnicronConfig::default())
    }

    #[test]
    fn kind_names_round_trip_strictly() {
        for &k in DegradationKind::all() {
            assert_eq!(DegradationKind::from_name(k.name()), Some(k));
        }
        assert_eq!(DegradationKind::from_name("bogus"), None);
        assert_eq!(DegradationKind::from_name("Straggler"), None, "names are exact");
        assert_eq!(DegradationKind::all().len(), 3);
    }

    #[test]
    fn stream_stats_track_mean_and_deviation() {
        let mut s = StreamStats::new(0.3);
        for _ in 0..50 {
            s.observe(10.0);
        }
        assert!((s.mean() - 10.0).abs() < 1e-9);
        assert!(s.mad() < 1e-9);
        assert_eq!(s.count(), 50);
        // a constant stream scores any excursion as a huge outlier
        assert!(s.score(11.0) > OUTLIER_SCORE);
        // jittered stream: mean tracks, score of in-band sample is small
        let mut j = StreamStats::new(0.3);
        for i in 0..200 {
            j.observe(10.0 + 0.2 * ((i % 5) as f64 - 2.0));
        }
        assert!((j.mean() - 10.0).abs() < 0.5);
        assert!(j.score(10.1) < OUTLIER_SCORE);
        assert!(j.score(20.0) > OUTLIER_SCORE);
    }

    #[test]
    fn warm_up_is_silent() {
        let mut m = monitor();
        let n = NodeId(3);
        // even wildly slow samples during warm-up produce no verdict
        for _ in 0..UnicronConfig::default().degradation_min_samples - 1 {
            assert_eq!(m.observe_step(n, 500.0), None);
        }
        assert!(m.baseline_s(n).is_some());
        assert_eq!(m.nodes_observed(), 1);
    }

    #[test]
    fn sustained_straggler_is_classified_with_its_slow_fraction() {
        let mut m = monitor();
        let n = NodeId(1);
        for _ in 0..20 {
            assert_eq!(m.observe_step(n, 45.0), None, "healthy stream stays silent");
        }
        // node slows to 2× (slow_frac = 0.5): silent until sustained,
        // then classified as a straggler every subsequent step
        let min = UnicronConfig::default().degradation_min_samples;
        let mut verdicts = 0;
        for i in 0..min + 3 {
            match m.observe_step(n, 90.0) {
                Some((kind, frac)) => {
                    verdicts += 1;
                    assert_eq!(kind, DegradationKind::Straggler);
                    assert!((frac - 0.5).abs() < 0.05, "slow_frac ≈ 0.5, got {frac}");
                    assert!(i + 1 >= min, "must not fire before {min} sustained samples");
                }
                None => assert!(i + 1 < min, "must fire from sample {min}, silent at {}", i + 1),
            }
        }
        assert_eq!(verdicts, 4);
        // the baseline never absorbed the degraded samples
        assert!((m.baseline_s(n).unwrap() - 45.0).abs() < 1.0);
    }

    #[test]
    fn warn_band_is_gray_partial_bandwidth_and_below_warn_is_silent() {
        let mut m = monitor();
        let cfg = UnicronConfig::default();
        let gray = NodeId(2);
        let fine = NodeId(4);
        for _ in 0..20 {
            assert_eq!(m.observe_step(gray, 45.0), None);
            assert_eq!(m.observe_step(fine, 45.0), None);
        }
        // 12% sustained loss: warn-band (below fail_frac), classified gray
        // only after the longer 2×min_samples streak
        let slow = 45.0 / (1.0 - 0.12);
        let mut first = None;
        for i in 0..3 * cfg.degradation_min_samples {
            if let Some((kind, frac)) = m.observe_step(gray, slow) {
                assert_eq!(kind, DegradationKind::PartialBandwidth);
                assert!((frac - 0.12).abs() < 0.03, "slow_frac ≈ 0.12, got {frac}");
                first.get_or_insert(i + 1);
            }
            // sub-warn jitter on the healthy node never classifies
            assert_eq!(m.observe_step(fine, 45.0 * 1.02), None);
        }
        assert_eq!(first, Some(2 * cfg.degradation_min_samples), "gray needs a longer streak");
    }

    #[test]
    fn recovery_resets_the_streaks() {
        let mut m = monitor();
        let n = NodeId(7);
        for _ in 0..10 {
            m.observe_step(n, 45.0);
        }
        let min = UnicronConfig::default().degradation_min_samples;
        for _ in 0..min - 1 {
            m.observe_step(n, 90.0); // one short of sustained
        }
        assert_eq!(m.observe_step(n, 45.0), None, "back in band: streak resets");
        for i in 0..min {
            let v = m.observe_step(n, 90.0);
            assert_eq!(v.is_some(), i + 1 >= min, "streak must restart from zero");
        }
        m.forget(n);
        assert_eq!(m.baseline_s(n), None);
        assert_eq!(m.nodes_observed(), 0);
    }

    #[test]
    fn estimator_is_constant_memory_over_a_million_samples() {
        let mut m = monitor();
        let n = NodeId(0);
        for i in 0..1_000_000u64 {
            m.observe_step(n, 45.0 + 0.01 * ((i % 11) as f64));
        }
        assert_eq!(m.nodes_observed(), 1, "one node = one bounded stream");
        let base = m.baseline_s(n).unwrap();
        assert!((base - 45.05).abs() < 0.2, "baseline converged: {base}");
    }
}
