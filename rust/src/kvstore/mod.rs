//! etcd-like distributed key-value store — the *status monitor* substrate of
//! §3.2. The paper uses etcd; we build the subset Unicron needs:
//!
//! * revisioned puts/gets/deletes over string keys,
//! * **leases** with TTLs — a key attached to a lease disappears when the
//!   lease expires (node-health detection rides on this),
//! * **watches** on key prefixes — the coordinator consolidates agent status
//!   reports by watching `/status/…`,
//! * a TCP wire protocol ([`net`]) so agents on other "machines" talk to it.
//!
//! Expiry is clock-driven via [`Store::tick`], which both the live
//! coordinator loop and the tests (with [`crate::util::SimClock`]) call.

pub mod net;

use std::collections::BTreeMap;
use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::{Arc, Mutex};

use crate::util::Clock;

/// A watch notification.
#[derive(Debug, Clone, PartialEq)]
pub enum Event {
    Put { key: String, value: String, revision: u64 },
    Delete { key: String, revision: u64, expired: bool },
}

impl Event {
    pub fn key(&self) -> &str {
        match self {
            Event::Put { key, .. } | Event::Delete { key, .. } => key,
        }
    }
}

#[derive(Debug, Clone)]
struct Entry {
    value: String,
    lease: Option<u64>,
    mod_revision: u64,
}

#[derive(Debug, Clone)]
struct Lease {
    ttl_s: f64,
    expires_at: f64,
    keys: Vec<String>,
}

struct Watcher {
    prefix: String,
    tx: Sender<Event>,
}

struct Inner {
    map: BTreeMap<String, Entry>,
    leases: BTreeMap<u64, Lease>,
    watchers: Vec<Watcher>,
    revision: u64,
    next_lease: u64,
}

/// Thread-safe store handle (clone freely).
#[derive(Clone)]
pub struct Store {
    inner: Arc<Mutex<Inner>>,
    clock: Arc<dyn Clock>,
}

impl Store {
    pub fn new(clock: Arc<dyn Clock>) -> Store {
        Store {
            inner: Arc::new(Mutex::new(Inner {
                map: BTreeMap::new(),
                leases: BTreeMap::new(),
                watchers: Vec::new(),
                revision: 0,
                next_lease: 1,
            })),
            clock,
        }
    }

    /// Put a key, optionally attached to a lease. Returns the new revision.
    pub fn put(&self, key: &str, value: &str, lease: Option<u64>) -> Result<u64, String> {
        let mut g = self.inner.lock().unwrap();
        if let Some(id) = lease {
            let l = g.leases.get_mut(&id).ok_or_else(|| format!("no such lease {id}"))?;
            if !l.keys.iter().any(|k| k == key) {
                l.keys.push(key.to_string());
            }
        }
        g.revision += 1;
        let rev = g.revision;
        g.map.insert(key.to_string(), Entry { value: value.to_string(), lease, mod_revision: rev });
        notify(&mut g, Event::Put { key: key.into(), value: value.into(), revision: rev });
        Ok(rev)
    }

    pub fn get(&self, key: &str) -> Option<(String, u64)> {
        let g = self.inner.lock().unwrap();
        g.map.get(key).map(|e| (e.value.clone(), e.mod_revision))
    }

    /// Atomic compare-and-swap on a key's `mod_revision`: the put happens
    /// only if the key's current revision equals `expected` (`None` = the
    /// key must be absent). Returns the new revision on success, `None` on
    /// a lost race. This is the election primitive — two candidates racing
    /// for a leader key serialize on the store lock, and exactly one wins.
    pub fn cas(
        &self,
        key: &str,
        expected: Option<u64>,
        value: &str,
        lease: Option<u64>,
    ) -> Result<Option<u64>, String> {
        let mut g = self.inner.lock().unwrap();
        if g.map.get(key).map(|e| e.mod_revision) != expected {
            return Ok(None);
        }
        if let Some(id) = lease {
            let l = g.leases.get_mut(&id).ok_or_else(|| format!("no such lease {id}"))?;
            if !l.keys.iter().any(|k| k == key) {
                l.keys.push(key.to_string());
            }
        }
        g.revision += 1;
        let rev = g.revision;
        g.map.insert(key.to_string(), Entry { value: value.to_string(), lease, mod_revision: rev });
        notify(&mut g, Event::Put { key: key.into(), value: value.into(), revision: rev });
        Ok(Some(rev))
    }

    /// All key/value pairs under a prefix (sorted by key).
    pub fn get_prefix(&self, prefix: &str) -> Vec<(String, String)> {
        let g = self.inner.lock().unwrap();
        g.map
            .range(prefix.to_string()..)
            .take_while(|(k, _)| k.starts_with(prefix))
            .map(|(k, e)| (k.clone(), e.value.clone()))
            .collect()
    }

    pub fn delete(&self, key: &str) -> bool {
        let mut g = self.inner.lock().unwrap();
        if g.map.remove(key).is_some() {
            g.revision += 1;
            let rev = g.revision;
            notify(&mut g, Event::Delete { key: key.into(), revision: rev, expired: false });
            true
        } else {
            false
        }
    }

    /// Grant a lease with the given TTL; returns the lease id.
    pub fn grant_lease(&self, ttl_s: f64) -> u64 {
        let mut g = self.inner.lock().unwrap();
        let id = g.next_lease;
        g.next_lease += 1;
        let expires_at = self.clock.now() + ttl_s;
        g.leases.insert(id, Lease { ttl_s, expires_at, keys: Vec::new() });
        id
    }

    /// Refresh a lease (heartbeat). Errors if the lease already expired.
    pub fn keepalive(&self, id: u64) -> Result<(), String> {
        let mut g = self.inner.lock().unwrap();
        let now = self.clock.now();
        match g.leases.get_mut(&id) {
            Some(l) if l.expires_at >= now => {
                l.expires_at = now + l.ttl_s;
                Ok(())
            }
            Some(_) => Err(format!("lease {id} expired")),
            None => Err(format!("no such lease {id}")),
        }
    }

    /// Revoke a lease, deleting its keys (clean agent shutdown).
    pub fn revoke_lease(&self, id: u64) {
        let mut g = self.inner.lock().unwrap();
        if let Some(l) = g.leases.remove(&id) {
            for key in l.keys {
                if g.map.get(&key).map_or(false, |e| e.lease == Some(id)) {
                    g.map.remove(&key);
                    g.revision += 1;
                    let rev = g.revision;
                    notify(&mut g, Event::Delete { key, revision: rev, expired: false });
                }
            }
        }
    }

    /// Expire overdue leases; their keys are deleted with `expired: true`
    /// (the node-health SEV1 signal). Returns expired lease ids.
    pub fn tick(&self) -> Vec<u64> {
        let now = self.clock.now();
        let mut g = self.inner.lock().unwrap();
        let overdue: Vec<u64> =
            g.leases.iter().filter(|(_, l)| l.expires_at < now).map(|(&id, _)| id).collect();
        for id in &overdue {
            if let Some(l) = g.leases.remove(id) {
                for key in l.keys {
                    if g.map.get(&key).map_or(false, |e| e.lease == Some(*id)) {
                        g.map.remove(&key);
                        g.revision += 1;
                        let rev = g.revision;
                        notify(&mut g, Event::Delete { key, revision: rev, expired: true });
                    }
                }
            }
        }
        overdue
    }

    /// Subscribe to events whose key starts with `prefix`.
    pub fn watch(&self, prefix: &str) -> Receiver<Event> {
        let (tx, rx) = channel();
        let mut g = self.inner.lock().unwrap();
        g.watchers.push(Watcher { prefix: prefix.to_string(), tx });
        rx
    }

    pub fn revision(&self) -> u64 {
        self.inner.lock().unwrap().revision
    }

    pub fn lease_count(&self) -> usize {
        self.inner.lock().unwrap().leases.len()
    }
}

fn notify(inner: &mut Inner, event: Event) {
    inner.watchers.retain(|w| {
        if event.key().starts_with(&w.prefix) {
            w.tx.send(event.clone()).is_ok() // drop dead watchers
        } else {
            true
        }
    });
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::SimClock;

    fn store() -> (Store, Arc<SimClock>) {
        let clock = SimClock::new();
        (Store::new(clock.clone()), clock)
    }

    #[test]
    fn put_get_delete_with_revisions() {
        let (s, _) = store();
        let r1 = s.put("/a", "1", None).unwrap();
        let r2 = s.put("/a", "2", None).unwrap();
        assert!(r2 > r1);
        assert_eq!(s.get("/a"), Some(("2".into(), r2)));
        assert!(s.delete("/a"));
        assert!(!s.delete("/a"));
        assert_eq!(s.get("/a"), None);
    }

    #[test]
    fn prefix_scan_sorted() {
        let (s, _) = store();
        s.put("/nodes/2", "b", None).unwrap();
        s.put("/nodes/1", "a", None).unwrap();
        s.put("/tasks/1", "t", None).unwrap();
        let nodes = s.get_prefix("/nodes/");
        assert_eq!(nodes, vec![("/nodes/1".into(), "a".into()), ("/nodes/2".into(), "b".into())]);
    }

    #[test]
    fn lease_expiry_deletes_keys() {
        let (s, clock) = store();
        let lease = s.grant_lease(5.0);
        s.put("/nodes/n1", "alive", Some(lease)).unwrap();
        clock.advance(3.0);
        assert_eq!(s.tick(), Vec::<u64>::new());
        assert!(s.get("/nodes/n1").is_some());
        clock.advance(3.0);
        assert_eq!(s.tick(), vec![lease]);
        assert!(s.get("/nodes/n1").is_none());
        assert_eq!(s.lease_count(), 0);
    }

    #[test]
    fn keepalive_extends_lease() {
        let (s, clock) = store();
        let lease = s.grant_lease(5.0);
        s.put("/n", "x", Some(lease)).unwrap();
        for _ in 0..5 {
            clock.advance(3.0);
            s.keepalive(lease).unwrap();
            s.tick();
        }
        assert!(s.get("/n").is_some(), "kept alive for 15s on a 5s TTL");
        clock.advance(6.0);
        s.tick();
        assert!(s.keepalive(lease).is_err());
    }

    #[test]
    fn watch_sees_puts_deletes_and_expiry() {
        let (s, clock) = store();
        let rx = s.watch("/status/");
        s.put("/status/n1", "ok", None).unwrap();
        s.put("/other/x", "ignored", None).unwrap();
        s.delete("/status/n1");
        let lease = s.grant_lease(1.0);
        s.put("/status/n2", "ok", Some(lease)).unwrap();
        clock.advance(2.0);
        s.tick();

        let events: Vec<Event> = rx.try_iter().collect();
        assert_eq!(events.len(), 4);
        assert!(matches!(&events[0], Event::Put { key, .. } if key == "/status/n1"));
        assert!(matches!(&events[1], Event::Delete { key, expired: false, .. } if key == "/status/n1"));
        assert!(matches!(&events[2], Event::Put { key, .. } if key == "/status/n2"));
        assert!(matches!(&events[3], Event::Delete { key, expired: true, .. } if key == "/status/n2"));
    }

    #[test]
    fn cas_put_if_absent_wins_exactly_once() {
        let (s, _) = store();
        let r1 = s.cas("/leader", None, "a", None).unwrap();
        assert!(r1.is_some(), "first candidate must win the absent key");
        assert_eq!(s.cas("/leader", None, "b", None).unwrap(), None, "second must lose");
        assert_eq!(s.get("/leader").unwrap().0, "a");
    }

    #[test]
    fn cas_requires_current_revision() {
        let (s, _) = store();
        let rev = s.put("/term", "1", None).unwrap();
        let newer = s.cas("/term", Some(rev), "2", None).unwrap().expect("matching rev swaps");
        assert_eq!(s.cas("/term", Some(rev), "3", None).unwrap(), None, "stale rev must lose");
        assert_eq!(s.get("/term"), Some(("2".into(), newer)));
    }

    #[test]
    fn cas_key_expires_with_its_lease() {
        let (s, clock) = store();
        let lease = s.grant_lease(1.0);
        assert!(s.cas("/leader", None, "a", Some(lease)).unwrap().is_some());
        clock.advance(2.0);
        s.tick();
        assert_eq!(s.get("/leader"), None, "lease expiry must free the key");
        assert!(s.cas("/leader", None, "b", None).unwrap().is_some(), "successor acquires");
        assert!(s.cas("/x", None, "v", Some(lease)).is_err(), "expired lease is an error");
    }

    #[test]
    fn revoke_lease_cleans_up() {
        let (s, _) = store();
        let lease = s.grant_lease(100.0);
        s.put("/a", "1", Some(lease)).unwrap();
        s.put("/b", "2", None).unwrap();
        s.revoke_lease(lease);
        assert!(s.get("/a").is_none());
        assert!(s.get("/b").is_some());
    }

    #[test]
    fn put_on_missing_lease_fails() {
        let (s, _) = store();
        assert!(s.put("/a", "1", Some(42)).is_err());
    }

    #[test]
    fn concurrent_access() {
        let (s, _) = store();
        let mut handles = Vec::new();
        for t in 0..4 {
            let s2 = s.clone();
            handles.push(std::thread::spawn(move || {
                for i in 0..100 {
                    s2.put(&format!("/t{t}/k{i}"), &i.to_string(), None).unwrap();
                }
            }));
        }
        for h in handles {
            h.join().unwrap();
        }
        assert_eq!(s.revision(), 400);
        assert_eq!(s.get_prefix("/t0/").len(), 100);
    }
}
