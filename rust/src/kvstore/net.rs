//! TCP wire protocol for [`Store`]: what makes the status monitor a
//! *distributed* KV store the agents can reach from other machines.
//!
//! Methods: `put`, `get`, `get_prefix`, `delete`, `cas`, `lease_grant`,
//! `keepalive`, `lease_revoke`, `watch` (the connection switches to a push
//! stream of events after the ack).

use anyhow::{anyhow, Result};
use std::net::ToSocketAddrs;
use std::time::Duration;

use super::{Event, Store};
use crate::rpc::{self, err_response, ok_response, Client};
use crate::ser::Value;

/// Serve `store` on `addr`; returns the RPC server handle (shuts down on drop).
pub fn serve(store: Store, addr: impl ToSocketAddrs) -> Result<rpc::Server> {
    rpc::Server::serve(addr, move |req, stream| {
        let method = req.get("method").and_then(Value::as_str).unwrap_or("");
        match method {
            "put" => {
                let key = req.get("key").and_then(Value::as_str).unwrap_or("");
                let value = req.get("value").and_then(Value::as_str).unwrap_or("");
                let lease = req.get("lease").and_then(Value::as_u64);
                Some(match store.put(key, value, lease) {
                    Ok(rev) => ok_response().with("revision", rev),
                    Err(e) => err_response(&e),
                })
            }
            "get" => {
                let key = req.get("key").and_then(Value::as_str).unwrap_or("");
                Some(match store.get(key) {
                    Some((value, rev)) => {
                        ok_response().with("value", value).with("revision", rev).with("found", true)
                    }
                    None => ok_response().with("found", false),
                })
            }
            "get_prefix" => {
                let prefix = req.get("prefix").and_then(Value::as_str).unwrap_or("");
                let kvs: Vec<Value> = store
                    .get_prefix(prefix)
                    .into_iter()
                    .map(|(k, v)| Value::obj().with("key", k).with("value", v))
                    .collect();
                Some(ok_response().with("kvs", Value::Arr(kvs)))
            }
            "delete" => {
                let key = req.get("key").and_then(Value::as_str).unwrap_or("");
                Some(ok_response().with("deleted", store.delete(key)))
            }
            "cas" => {
                let key = req.get("key").and_then(Value::as_str).unwrap_or("");
                let value = req.get("value").and_then(Value::as_str).unwrap_or("");
                let expected = req.get("expected").and_then(Value::as_u64);
                let lease = req.get("lease").and_then(Value::as_u64);
                Some(match store.cas(key, expected, value, lease) {
                    Ok(Some(rev)) => ok_response().with("swapped", true).with("revision", rev),
                    Ok(None) => ok_response().with("swapped", false),
                    Err(e) => err_response(&e),
                })
            }
            "lease_grant" => {
                let ttl = req.get("ttl_s").and_then(Value::as_f64).unwrap_or(5.0);
                Some(ok_response().with("lease", store.grant_lease(ttl)))
            }
            "keepalive" => {
                let id = req.get("lease").and_then(Value::as_u64).unwrap_or(0);
                Some(match store.keepalive(id) {
                    Ok(()) => ok_response(),
                    Err(e) => err_response(&e),
                })
            }
            "lease_revoke" => {
                let id = req.get("lease").and_then(Value::as_u64).unwrap_or(0);
                store.revoke_lease(id);
                Some(ok_response())
            }
            "watch" => {
                // ack, then stream events on this connection until it drops
                let prefix =
                    req.get("prefix").and_then(Value::as_str).unwrap_or("").to_string();
                let rx = store.watch(&prefix);
                if rpc::send_msg(stream, &ok_response()).is_err() {
                    return None;
                }
                stream.set_read_timeout(Some(Duration::from_millis(50))).ok();
                loop {
                    match rx.recv_timeout(Duration::from_millis(200)) {
                        Ok(ev) => {
                            if rpc::send_msg(stream, &event_to_json(&ev)).is_err() {
                                return None;
                            }
                        }
                        Err(std::sync::mpsc::RecvTimeoutError::Timeout) => {
                            // connection liveness check: peek for EOF
                            let mut probe = [0u8; 1];
                            use std::io::Read;
                            match stream.read(&mut probe) {
                                Ok(0) => return None, // peer closed
                                Ok(_) => {}           // ignore stray bytes
                                Err(e)
                                    if matches!(
                                        e.kind(),
                                        std::io::ErrorKind::WouldBlock
                                            | std::io::ErrorKind::TimedOut
                                    ) => {}
                                Err(_) => return None,
                            }
                        }
                        Err(_) => return None,
                    }
                }
            }
            other => Some(err_response(&format!("unknown method {other:?}"))),
        }
    })
    .map_err(|e| anyhow!("kvstore serve: {e}"))
}

fn event_to_json(ev: &Event) -> Value {
    match ev {
        Event::Put { key, value, revision } => Value::obj()
            .with("type", "put")
            .with("key", key.as_str())
            .with("value", value.as_str())
            .with("revision", *revision),
        Event::Delete { key, revision, expired } => Value::obj()
            .with("type", "delete")
            .with("key", key.as_str())
            .with("revision", *revision)
            .with("expired", *expired),
    }
}

/// Parse a pushed watch frame back into an [`Event`].
pub fn event_from_json(v: &Value) -> Option<Event> {
    let key = v.get("key")?.as_str()?.to_string();
    let revision = v.get("revision")?.as_u64()?;
    match v.get("type")?.as_str()? {
        "put" => Some(Event::Put { key, value: v.get("value")?.as_str()?.to_string(), revision }),
        "delete" => Some(Event::Delete {
            key,
            revision,
            expired: v.get("expired").and_then(Value::as_bool).unwrap_or(false),
        }),
        _ => None,
    }
}

/// Typed client for the wire protocol.
pub struct KvClient {
    client: Client,
}

impl KvClient {
    pub fn connect(addr: impl ToSocketAddrs) -> Result<KvClient> {
        Ok(KvClient { client: Client::connect(addr)? })
    }

    /// Replace the underlying connection (after a server restart or a
    /// transport error). Granted leases and watches do NOT survive a
    /// reconnect — they belong to the server-side session; re-grant and
    /// re-subscribe after this returns. Any configured read timeout is
    /// reset too.
    pub fn reconnect(&mut self, addr: impl ToSocketAddrs) -> Result<()> {
        self.client = Client::connect(addr)?;
        Ok(())
    }

    /// Bound how long calls wait for a response (a slow or hung server
    /// surfaces as a timeout `io::Error` instead of blocking forever).
    /// After a timeout the request/response stream may be desynced —
    /// [`KvClient::reconnect`] before reusing the client.
    pub fn set_read_timeout(&mut self, t: Option<Duration>) -> Result<()> {
        self.client.set_read_timeout(t)
    }

    fn expect_ok(resp: Value) -> Result<Value> {
        if rpc::is_ok(&resp) {
            Ok(resp)
        } else {
            Err(anyhow!(
                "kv error: {}",
                resp.get("error").and_then(Value::as_str).unwrap_or("unknown")
            ))
        }
    }

    pub fn put(&mut self, key: &str, value: &str, lease: Option<u64>) -> Result<u64> {
        let mut req = rpc::request("put").with("key", key).with("value", value);
        if let Some(l) = lease {
            req.set("lease", l);
        }
        let resp = Self::expect_ok(self.client.call(&req)?)?;
        resp.get("revision").and_then(Value::as_u64).ok_or_else(|| anyhow!("no revision"))
    }

    pub fn get(&mut self, key: &str) -> Result<Option<String>> {
        Ok(self.get_rev(key)?.map(|(v, _)| v))
    }

    /// Like [`KvClient::get`] but keeps the `mod_revision`, which is the
    /// expectation token [`KvClient::cas`] swaps against.
    pub fn get_rev(&mut self, key: &str) -> Result<Option<(String, u64)>> {
        let resp = Self::expect_ok(self.client.call(&rpc::request("get").with("key", key))?)?;
        if resp.get("found").and_then(Value::as_bool).unwrap_or(false) {
            let value = resp
                .get("value")
                .and_then(Value::as_str)
                .ok_or_else(|| anyhow!("get: no value"))?
                .to_string();
            let rev = resp.get("revision").and_then(Value::as_u64);
            Ok(Some((value, rev.ok_or_else(|| anyhow!("no revision"))?)))
        } else {
            Ok(None)
        }
    }

    /// Compare-and-swap over the wire (see [`Store::cas`]): returns the new
    /// revision when the swap happened, `None` on a lost race.
    pub fn cas(
        &mut self,
        key: &str,
        expected: Option<u64>,
        value: &str,
        lease: Option<u64>,
    ) -> Result<Option<u64>> {
        let mut req = rpc::request("cas").with("key", key).with("value", value);
        if let Some(rev) = expected {
            req.set("expected", rev);
        }
        if let Some(l) = lease {
            req.set("lease", l);
        }
        let resp = Self::expect_ok(self.client.call(&req)?)?;
        if resp.get("swapped").and_then(Value::as_bool).unwrap_or(false) {
            let rev = resp.get("revision").and_then(Value::as_u64);
            Ok(Some(rev.ok_or_else(|| anyhow!("no revision"))?))
        } else {
            Ok(None)
        }
    }

    pub fn get_prefix(&mut self, prefix: &str) -> Result<Vec<(String, String)>> {
        let resp =
            Self::expect_ok(self.client.call(&rpc::request("get_prefix").with("prefix", prefix))?)?;
        Ok(resp
            .get("kvs")
            .and_then(Value::as_arr)
            .unwrap_or(&[])
            .iter()
            .filter_map(|kv| {
                Some((kv.get("key")?.as_str()?.to_string(), kv.get("value")?.as_str()?.to_string()))
            })
            .collect())
    }

    pub fn delete(&mut self, key: &str) -> Result<bool> {
        let resp = Self::expect_ok(self.client.call(&rpc::request("delete").with("key", key))?)?;
        Ok(resp.get("deleted").and_then(Value::as_bool).unwrap_or(false))
    }

    pub fn lease_grant(&mut self, ttl_s: f64) -> Result<u64> {
        let resp =
            Self::expect_ok(self.client.call(&rpc::request("lease_grant").with("ttl_s", ttl_s))?)?;
        resp.get("lease").and_then(Value::as_u64).ok_or_else(|| anyhow!("no lease id"))
    }

    pub fn keepalive(&mut self, lease: u64) -> Result<()> {
        Self::expect_ok(self.client.call(&rpc::request("keepalive").with("lease", lease))?)?;
        Ok(())
    }

    pub fn lease_revoke(&mut self, lease: u64) -> Result<()> {
        Self::expect_ok(self.client.call(&rpc::request("lease_revoke").with("lease", lease))?)?;
        Ok(())
    }

    /// Subscribe; this client becomes a push stream (use `next_event`).
    pub fn watch(mut self, prefix: &str) -> Result<WatchStream> {
        let resp = self.client.call(&rpc::request("watch").with("prefix", prefix))?;
        Self::expect_ok(resp)?;
        Ok(WatchStream { client: self.client })
    }
}

/// Blocking stream of watch events.
pub struct WatchStream {
    client: Client,
}

impl WatchStream {
    pub fn next_event(&mut self) -> Result<Event> {
        let v = self.client.next_push()?;
        event_from_json(&v).ok_or_else(|| anyhow!("bad watch frame: {}", v.encode()))
    }

    pub fn set_read_timeout(&mut self, t: Option<Duration>) -> Result<()> {
        self.client.set_read_timeout(t)
    }
}

// Integration tests over real TCP live in rust/tests/kvstore_tcp.rs.
#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn event_json_roundtrip() {
        for ev in [
            Event::Put { key: "/k".into(), value: "v".into(), revision: 3 },
            Event::Delete { key: "/k".into(), revision: 4, expired: true },
        ] {
            let j = event_to_json(&ev);
            assert_eq!(event_from_json(&j).unwrap(), ev);
        }
    }

    #[test]
    fn bad_event_json_rejected() {
        assert!(event_from_json(&Value::obj().with("type", "nope")).is_none());
        assert!(event_from_json(&Value::Null).is_none());
    }
}
