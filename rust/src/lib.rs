//! # Unicron — economizing self-healing LLM training at scale
//!
//! Reproduction of *Unicron: Economizing Self-Healing LLM Training at Scale*
//! (He et al., Alibaba, 2023) as a three-layer Rust + JAX + Pallas system.
//!
//! This crate is Layer 3: the workload manager that owns the request path.
//! The JAX/Pallas layers (under `python/`) run only at build time and produce
//! HLO-text artifacts that [`runtime`] loads through PJRT.
//!
//! Module map (see DESIGN.md §4 for the full inventory):
//!
//! * substrates: [`util`], [`rng`], [`ser`], [`config`], [`cli`], [`bench`],
//!   [`proptest`], [`metrics`]
//! * deterministic scheduling: [`engine`] — the seeded `(time, seq)` event
//!   queue both the simulator and the live coordinator loop run on
//! * the recovery protocol: [`proto`] — typed ids, serializable
//!   `CoordEvent`/`Action`, and the record/replay `DecisionLog`
//! * the cost ledger: [`cost`] — the typed `CostModel` every cost-aware
//!   decision (plan reward, transition pricing, spare economics) is priced
//!   against (DESIGN.md §9)
//! * distributed plumbing: [`kvstore`], [`rpc`], [`membership`], [`checkpoint`]
//! * high availability: [`controlplane`] — the networked coordinator
//!   service, leader election, and decision-log replication (DESIGN.md §15)
//! * the state tier: [`store`] — content-addressed, deduplicating, tiered
//!   snapshot store the transition/cost layers price against (DESIGN.md §13)
//! * the paper's contribution: [`failure`] + [`detect`] + [`health`] (§4),
//!   [`perfmodel`] +
//!   [`planner`] (§5), [`transition`] (§6), [`agent`] + [`coordinator`] (§3)
//! * fleet economics: [`fleet`] — node health history, lemon detection,
//!   and the cost-aware hot-spare pool (DESIGN.md §8)
//! * topology: [`placement`] — the min-churn node-to-task assignment
//!   solver and the [`placement::Layout`] cluster map every committed plan
//!   carries (DESIGN.md §10)
//! * observability: [`telemetry`] — typed instruments, per-decision span
//!   tracing, and the incident timeline (DESIGN.md §14)
//! * execution: [`runtime`], [`trainer`], [`data`]
//! * evaluation: [`simulator`] (environment model around the production
//!   coordinator), [`repro`]

pub mod agent;
pub mod bench;
pub mod checkpoint;
pub mod cli;
pub mod config;
pub mod controlplane;
pub mod coordinator;
pub mod cost;
pub mod data;
pub mod detect;
pub mod engine;
pub mod failure;
pub mod fleet;
pub mod health;
pub mod kvstore;
pub mod membership;
pub mod metrics;
pub mod perfmodel;
pub mod placement;
pub mod planner;
pub mod proptest;
pub mod proto;
pub mod repro;
pub mod rng;
pub mod rpc;
pub mod runtime;
pub mod ser;
pub mod simulator;
pub mod store;
pub mod telemetry;
pub mod trainer;
pub mod transition;
pub mod util;
