//! `unicron` — the workload-manager CLI (launcher, Fig. 5's entry point).
//!
//! Subcommands:
//!   repro <exp>      regenerate a paper table/figure (see `repro list`)
//!   train            run the real DP trainer on an AOT'd model artifact
//!   simulate         replay a failure trace under a recovery policy
//!   plan             solve a multi-task reconfiguration plan (Table 3 cases)
//!   perfmodel        query the Megatron cost model T(t, x)
//!   coordinator      start a live coordinator (TCP kvstore + event loop)
//!   obs              render an incident timeline from a recorded
//!                    DecisionLog or a live session's /fleet/metrics

use std::process::ExitCode;
use std::sync::Arc;

use unicron::cli::{usage, Args, OptSpec};
use unicron::config::{table3_case, ClusterSpec, ModelSpec, UnicronConfig};
use unicron::controlplane::{ControlPlane, ControlPlaneConfig, Election, ElectionKv};
use unicron::coordinator::live::{CoordinatorLive, METRICS_KEY, REPORT_VERSION};
use unicron::coordinator::{Coordinator, DecisionLog};
use unicron::failure::{Trace, TraceConfig};
use unicron::kvstore::net::KvClient;
use unicron::kvstore::Store;
use unicron::perfmodel::best_config;
use unicron::ser::Value;
use unicron::simulator::{PolicyKind, Simulator};
use unicron::telemetry::Timeline;
use unicron::trainer::{DpTrainer, LrSchedule, TrainerConfig};
use unicron::util::{fmt_duration, fmt_si, RealClock};

const ABOUT: &str = "Unicron: economizing self-healing LLM training at scale (reproduction)";

fn main() -> ExitCode {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    let (cmd, rest) = match argv.split_first() {
        Some((c, r)) => (c.as_str(), r.to_vec()),
        None => {
            print_help();
            return ExitCode::FAILURE;
        }
    };
    let result = match cmd {
        "repro" => cmd_repro(&rest),
        "train" => cmd_train(&rest),
        "simulate" => cmd_simulate(&rest),
        "plan" => cmd_plan(&rest),
        "perfmodel" => cmd_perfmodel(&rest),
        "coordinator" => cmd_coordinator(&rest),
        "serve" => cmd_serve(&rest),
        "obs" => cmd_obs(&rest),
        "help" | "--help" | "-h" => {
            print_help();
            Ok(())
        }
        other => Err(format!("unknown command {other:?}")),
    };
    match result {
        Ok(()) => ExitCode::SUCCESS,
        Err(e) => {
            eprintln!("error: {e}");
            ExitCode::FAILURE
        }
    }
}

fn print_help() {
    println!("{ABOUT}\n");
    println!("USAGE: unicron <command> [options]\n");
    println!("COMMANDS:");
    println!("  repro <exp|list>   regenerate a paper table/figure");
    println!("  train              train a GPT artifact with the self-healing DP engine");
    println!("  simulate           replay a failure trace under a recovery policy");
    println!("  plan               multi-task WAF plan for a Table 3 case");
    println!("  perfmodel          query T(model, gpus) and the best 3D config");
    println!("  coordinator        start a live coordinator (TCP)");
    println!("  serve              start an HA control-plane node (leader or standby)");
    println!("  obs                render an incident timeline (--log file | --addr host:port)");
}

fn cmd_repro(argv: &[String]) -> Result<(), String> {
    let specs = [OptSpec { name: "seed", help: "trace seed", takes_value: true, default: Some("42") }];
    let args = Args::parse(argv, &specs).map_err(|e| e.to_string())?;
    let exp = args.positional.first().map(String::as_str).unwrap_or("list");
    if exp == "list" {
        println!("experiments:");
        for e in unicron::repro::EXPERIMENTS {
            println!("  {:<14} {}", e.id, e.description);
        }
        return Ok(());
    }
    let seed = args.u64("seed").map_err(|e| e.to_string())?;
    if exp == "all" {
        for e in unicron::repro::EXPERIMENTS {
            println!("{}\n", (e.run)(seed));
        }
        return Ok(());
    }
    println!("{}", unicron::repro::run(exp, seed)?);
    Ok(())
}

fn cmd_train(argv: &[String]) -> Result<(), String> {
    let specs = [
        OptSpec { name: "model", help: "artifact name under artifacts/", takes_value: true, default: Some("tiny") },
        OptSpec { name: "dp", help: "data-parallel workers", takes_value: true, default: Some("2") },
        OptSpec { name: "micro-batches", help: "micro-batches per global batch", takes_value: true, default: Some("4") },
        OptSpec { name: "steps", help: "optimizer steps", takes_value: true, default: Some("20") },
        OptSpec { name: "lr", help: "peak learning rate", takes_value: true, default: Some("1e-3") },
        OptSpec { name: "seed", help: "init seed", takes_value: true, default: Some("0") },
        OptSpec { name: "fail-at", help: "inject: step:rank:after_mbs (e.g. 3:1:2)", takes_value: true, default: None },
        OptSpec { name: "artifacts", help: "artifacts root", takes_value: true, default: Some("artifacts") },
    ];
    let args = Args::parse(argv, &specs).map_err(|e| e.to_string())?;
    let model = args.str("model").map_err(|e| e.to_string())?;
    let steps = args.u64("steps").map_err(|e| e.to_string())?;
    let dp = args.usize("dp").map_err(|e| e.to_string())?;
    let micro = args.usize("micro-batches").map_err(|e| e.to_string())?;
    let lr = args.f64("lr").map_err(|e| e.to_string())? as f32;
    let seed = args.u64("seed").map_err(|e| e.to_string())?;
    let fail: Option<(u64, usize, usize)> = match args.get("fail-at") {
        Some(s) => {
            let parts: Vec<&str> = s.split(':').collect();
            if parts.len() != 3 {
                return Err("--fail-at expects step:rank:after_mbs".into());
            }
            Some((
                parts[0].parse().map_err(|_| "bad step")?,
                parts[1].parse().map_err(|_| "bad rank")?,
                parts[2].parse().map_err(|_| "bad after_mbs")?,
            ))
        }
        None => None,
    };

    let cfg = TrainerConfig {
        artifact_dir: std::path::Path::new(args.str("artifacts").unwrap()).join(model),
        dp,
        micro_batches: micro,
        schedule: LrSchedule { base: lr, warmup_steps: steps / 10, total_steps: steps },
        init_seed: seed,
        data_seed: seed ^ 0xDA7A,
    };
    let mut trainer = DpTrainer::new(cfg).map_err(|e| e.to_string())?;
    println!(
        "training {model}: {} params, dp={dp}, {micro} micro-batches/step",
        trainer.manifest.n_params
    );
    for step in 0..steps {
        if let Some((s, rank, after)) = fail {
            if s == step {
                println!("injecting failure: rank {rank} dies after {after} micro-batches");
                trainer.inject_failure(rank, after);
            }
        }
        let rep = trainer.train_step().map_err(|e| e.to_string())?;
        println!(
            "step {:>4}  loss {:.4}  |g| {:.3e}  lr {:.2e}  {}  alive={:?}{}",
            rep.step,
            rep.loss,
            rep.grad_norm,
            rep.lr,
            fmt_duration(rep.duration_s),
            trainer.alive_ranks(),
            if rep.failures.is_empty() {
                String::new()
            } else {
                format!("  FAILED {:?}, redistributed {}", rep.failures, rep.redistributed)
            }
        );
        // self-heal: revive dead ranks via nearest-principle state migration
        if !rep.failures.is_empty() {
            for rank in rep.failures {
                trainer.revive(rank).map_err(|e| e.to_string())?;
                println!("revived rank {rank} from healthy DP replica");
            }
        }
    }
    Ok(())
}

fn cmd_simulate(argv: &[String]) -> Result<(), String> {
    let specs = [
        OptSpec { name: "trace", help: "a | b", takes_value: true, default: Some("a") },
        OptSpec { name: "policy", help: "unicron|megatron|oobleck|varuna|bamboo|all", takes_value: true, default: Some("all") },
        OptSpec { name: "case", help: "Table 3 case (1-5)", takes_value: true, default: Some("5") },
        OptSpec { name: "seed", help: "trace seed", takes_value: true, default: Some("42") },
        OptSpec { name: "record", help: "write the run's DecisionLog JSON here (single policy)", takes_value: true, default: None },
        OptSpec { name: "straggler", help: "overlay a straggler onset: node:at_s:slow_frac:duration_s", takes_value: true, default: None },
    ];
    let args = Args::parse(argv, &specs).map_err(|e| e.to_string())?;
    let tc = match args.str("trace").unwrap() {
        "a" => TraceConfig::trace_a(),
        "b" => TraceConfig::trace_b(),
        other => return Err(format!("unknown trace {other:?}")),
    };
    let seed = args.u64("seed").map_err(|e| e.to_string())?;
    let case = args.u64("case").map_err(|e| e.to_string())? as u32;
    let mut trace = Trace::generate(tc, seed);
    if let Some(s) = args.get("straggler") {
        let parts: Vec<&str> = s.split(':').collect();
        if parts.len() != 4 {
            return Err("--straggler expects node:at_s:slow_frac:duration_s".into());
        }
        let node: u32 = parts[0].parse().map_err(|_| "bad straggler node")?;
        let at_s: f64 = parts[1].parse().map_err(|_| "bad straggler at_s")?;
        let slow_frac: f64 = parts[2].parse().map_err(|_| "bad straggler slow_frac")?;
        let duration_s: f64 = parts[3].parse().map_err(|_| "bad straggler duration_s")?;
        trace = trace.with_straggler_onset(
            unicron::proto::NodeId(node),
            at_s,
            slow_frac,
            duration_s,
        );
    }
    let cluster = ClusterSpec::default();
    let cfg = UnicronConfig::default();
    let tasks = table3_case(case);
    let kinds: Vec<PolicyKind> = match args.str("policy").unwrap() {
        "all" => PolicyKind::all().to_vec(),
        name => vec![parse_policy(name)?],
    };
    let record = args.get("record");
    if record.is_some() && kinds.len() != 1 {
        return Err("--record needs a single --policy (the log is one policy's run)".into());
    }
    for kind in kinds {
        let r = Simulator::builder()
            .cluster(cluster.clone())
            .config(cfg.clone())
            .policy(kind)
            .tasks(&tasks)
            .build()
            .run(&trace);
        println!(
            "{:<10} mean WAF {}FLOP/s   accumulated {}FLOP·s   reduction {:.1}%   transitions {}",
            kind.name(),
            fmt_si(r.mean_waf()),
            fmt_si(r.accumulated_waf),
            r.reduction() * 100.0,
            r.transitions.len()
        );
        if let Some(path) = record {
            std::fs::write(path, r.decision_log.to_bytes())
                .map_err(|e| format!("write {path}: {e}"))?;
            println!("recorded {} decisions to {path}", r.decision_log.len());
        }
    }
    Ok(())
}

fn parse_policy(name: &str) -> Result<PolicyKind, String> {
    Ok(match name {
        "unicron" => PolicyKind::Unicron,
        "megatron" => PolicyKind::Megatron,
        "oobleck" => PolicyKind::Oobleck,
        "varuna" => PolicyKind::Varuna,
        "bamboo" => PolicyKind::Bamboo,
        other => return Err(format!("unknown policy {other:?}")),
    })
}

fn cmd_plan(argv: &[String]) -> Result<(), String> {
    let specs = [
        OptSpec { name: "case", help: "Table 3 case (1-5)", takes_value: true, default: Some("5") },
        OptSpec { name: "gpus", help: "available workers", takes_value: true, default: Some("128") },
    ];
    let args = Args::parse(argv, &specs).map_err(|e| e.to_string())?;
    let case = args.u64("case").map_err(|e| e.to_string())? as u32;
    let gpus = args.u64("gpus").map_err(|e| e.to_string())? as u32;
    let cluster = ClusterSpec::default();
    let cost = unicron::cost::CostModel::from_config(&UnicronConfig::default());
    let tasks: Vec<unicron::planner::PlanTask> = table3_case(case)
        .iter()
        .map(|spec| unicron::planner::PlanTask::from_spec(spec, &cluster, gpus))
        .collect();
    let plan = unicron::planner::solve(&tasks, gpus, &cost);
    for (t, &x) in tasks.iter().zip(&plan.assignment) {
        println!(
            "task {} ({:<10} w={:.1}): {:>3} workers  F = {}FLOP/s",
            t.spec.id,
            t.spec.model,
            t.spec.weight,
            x,
            fmt_si(t.waf(x))
        );
    }
    println!("total WAF {}FLOP/s, workers used {}/{gpus}", fmt_si(plan.total_waf), plan.workers_used);
    let b = &plan.breakdown;
    println!(
        "ledger: objective {}FLOP·s = running {}FLOP·s - transition {}FLOP·s - detection {}FLOP·s \
         (horizon {}, MTBF/GPU {})",
        fmt_si(plan.objective),
        fmt_si(b.running_reward),
        fmt_si(b.transition_penalty),
        fmt_si(b.detection_penalty),
        fmt_duration(b.horizon_s),
        fmt_duration(b.mtbf_per_gpu_s),
    );
    Ok(())
}

fn cmd_perfmodel(argv: &[String]) -> Result<(), String> {
    let specs = [
        OptSpec { name: "model", help: "gpt3-{1.3b,7b,13b,70b,175b}", takes_value: true, default: Some("gpt3-7b") },
        OptSpec { name: "gpus", help: "GPU count", takes_value: true, default: Some("64") },
    ];
    let args = Args::parse(argv, &specs).map_err(|e| e.to_string())?;
    let model = ModelSpec::gpt3(args.str("model").unwrap())
        .ok_or_else(|| format!("unknown model; zoo: {:?}", ModelSpec::zoo()))?;
    let gpus = args.u64("gpus").map_err(|e| e.to_string())? as u32;
    let cluster = ClusterSpec::default();
    match best_config(&model, &cluster, gpus) {
        Some(e) => {
            println!("model {} ({} params)", model.name, fmt_si(model.n_params));
            println!(
                "best config on {gpus} GPUs: tp={} pp={} dp={} mbs={}",
                e.config.tp, e.config.pp, e.config.dp, e.config.mbs
            );
            println!("iteration time {}", fmt_duration(e.iter_time_s));
            println!("achieved {}FLOP/s ({:.1}% of peak)", fmt_si(e.achieved_flops), e.flops_ratio * 100.0);
            println!("samples/s {:.2}   memory {:.1} GiB/GPU", e.samples_per_s, e.memory_gib);
        }
        None => println!("infeasible: {} does not fit on {gpus} GPUs", model.name),
    }
    Ok(())
}

/// `unicron serve` — start one HA control-plane node (DESIGN.md §15):
/// the coordinator behind the RPC service, with lease-based election over
/// a shared kvstore (`--election`) and log replication from the current
/// leader (`--join` as a bootstrap hint). With neither flag the node runs
/// standalone: it elects itself over a private in-process store.
fn cmd_serve(argv: &[String]) -> Result<(), String> {
    let specs = [
        OptSpec { name: "addr", help: "bind address for the control-plane RPC service", takes_value: true, default: Some("127.0.0.1:7080") },
        OptSpec { name: "join", help: "leader address to replicate from (standby bootstrap hint)", takes_value: true, default: None },
        OptSpec { name: "election", help: "shared election kvstore host:port (omit = standalone)", takes_value: true, default: None },
        OptSpec { name: "workers", help: "initial healthy workers", takes_value: true, default: Some("128") },
        OptSpec { name: "lease-ttl", help: "leader lease TTL seconds", takes_value: true, default: Some("2.0") },
        OptSpec { name: "duration", help: "seconds to run (0 = forever)", takes_value: true, default: Some("0") },
    ];
    let args = Args::parse(argv, &specs).map_err(|e| e.to_string())?;
    let clock: Arc<RealClock> = Arc::new(RealClock::new());
    let coord = Coordinator::builder()
        .config(UnicronConfig::default())
        .workers(args.u64("workers").map_err(|e| e.to_string())? as u32)
        .gpus_per_node(8u32)
        .build();
    let kv: Box<dyn ElectionKv> = match args.get("election") {
        Some(addr) => {
            Box::new(KvClient::connect(addr).map_err(|e| format!("election store: {e}"))?)
        }
        None => Box::new(Store::new(clock.clone())),
    };
    let ttl = args.f64("lease-ttl").map_err(|e| e.to_string())?;
    let cfg = ControlPlaneConfig { lease_ttl_s: ttl, ..ControlPlaneConfig::default() };
    let cp = ControlPlane::start(
        coord,
        clock,
        args.str("addr").unwrap(),
        cfg,
        Election::new(kv, ttl),
        args.get("join").map(String::from),
    )
    .map_err(|e| e.to_string())?;
    println!("control plane on {} (role converges via election)", cp.addr);
    let duration = args.f64("duration").map_err(|e| e.to_string())?;
    if duration > 0.0 {
        std::thread::sleep(std::time::Duration::from_secs_f64(duration));
        println!("served {duration}s as {} (term {})", cp.role().name(), cp.term());
    } else {
        loop {
            std::thread::sleep(std::time::Duration::from_secs(3600));
        }
    }
    Ok(())
}

/// `unicron obs` — reconstruct the incident narrative (failure → detection
/// → replan → transition → recovered) either from a recorded
/// [`DecisionLog`] (`--log`) or from a live session's `/fleet/metrics`
/// report (`--addr`). Render errors (non-reconciling cost terms, bad
/// timestamps) exit non-zero — the CI smoke run relies on that.
fn cmd_obs(argv: &[String]) -> Result<(), String> {
    let specs = [
        OptSpec { name: "log", help: "recorded DecisionLog JSON file", takes_value: true, default: None },
        OptSpec { name: "addr", help: "live coordinator host:port (reads /fleet/metrics)", takes_value: true, default: None },
    ];
    let args = Args::parse(argv, &specs).map_err(|e| e.to_string())?;
    let timeline = match (args.get("log"), args.get("addr")) {
        (Some(path), None) => {
            let bytes = std::fs::read(path).map_err(|e| format!("read {path}: {e}"))?;
            let log = DecisionLog::from_bytes(&bytes).map_err(|e| e.to_string())?;
            println!("replaying {} recorded decisions from {path}\n", log.len());
            Timeline::from_log(&log)
        }
        (None, Some(addr)) => {
            let mut kv = KvClient::connect(addr).map_err(|e| e.to_string())?;
            let pairs = kv.get_prefix(METRICS_KEY).map_err(|e| e.to_string())?;
            let (_, raw) = pairs
                .iter()
                .find(|(k, _)| k == METRICS_KEY)
                .ok_or("no /fleet/metrics report published yet")?;
            let v = Value::parse(raw).map_err(|e| e.to_string())?;
            let version = v
                .get("report_version")
                .and_then(Value::as_u64)
                .ok_or("metrics report missing report_version")?;
            if version != REPORT_VERSION {
                return Err(format!(
                    "metrics report_version {version} (this binary speaks {REPORT_VERSION})"
                ));
            }
            let at = v.get("at_s").and_then(Value::as_f64).unwrap_or(0.0);
            println!("live /fleet/metrics from {addr} (published at t={at:.1}s)\n");
            Timeline::from_value(v.get("timeline").ok_or("metrics report missing timeline")?)?
        }
        _ => return Err("obs needs exactly one of --log <path> or --addr <host:port>".into()),
    };
    print!("{}", timeline.render()?);
    Ok(())
}

fn cmd_coordinator(argv: &[String]) -> Result<(), String> {
    let specs = [
        OptSpec { name: "listen", help: "bind address", takes_value: true, default: Some("127.0.0.1:7077") },
        OptSpec { name: "workers", help: "initial healthy workers", takes_value: true, default: Some("128") },
        OptSpec { name: "duration", help: "seconds to run (0 = forever)", takes_value: true, default: Some("0") },
    ];
    let args = Args::parse(argv, &specs).map_err(|e| e.to_string())?;
    let clock = Arc::new(RealClock::new());
    let coord = Coordinator::builder()
        .config(UnicronConfig::default())
        .workers(args.u64("workers").map_err(|e| e.to_string())? as u32)
        .gpus_per_node(8u32)
        .build();
    let live = CoordinatorLive::start(coord, clock, args.str("listen").unwrap())
        .map_err(|e| e.to_string())?;
    println!("coordinator listening on {} (kvstore wire protocol)", live.addr);
    let duration = args.f64("duration").map_err(|e| e.to_string())?;
    if duration > 0.0 {
        std::thread::sleep(std::time::Duration::from_secs_f64(duration));
    } else {
        loop {
            std::thread::sleep(std::time::Duration::from_secs(3600));
        }
    }
    let _ = usage; // referenced to keep the helper exported
    Ok(())
}
