//! Cluster membership over kvstore leases — the node-health half of §4.1.
//!
//! An agent registers its node under `/nodes/<id>` attached to a TTL lease
//! and keeps the lease alive with heartbeats (its "persistent connection" to
//! the coordinator). If the agent dies or the machine drops off the network,
//! the lease expires, the key is deleted with `expired: true`, and the
//! coordinator's watch turns that into a SEV1 `LostConnection` within one
//! lease TTL — the 5–6 s detection row of Table 2.

use anyhow::{anyhow, Result};

use crate::kvstore::{Event, Store};
use crate::ser::Value;

pub const NODES_PREFIX: &str = "/nodes/";

/// What a node advertises when joining.
#[derive(Debug, Clone, PartialEq)]
pub struct NodeInfo {
    pub id: String,
    pub gpus: u32,
    /// RPC address of the node's agent.
    pub addr: String,
}

impl NodeInfo {
    pub fn to_json(&self) -> Value {
        Value::obj()
            .with("id", self.id.as_str())
            .with("gpus", self.gpus as u64)
            .with("addr", self.addr.as_str())
    }

    pub fn from_json(v: &Value) -> Option<NodeInfo> {
        Some(NodeInfo {
            id: v.get("id")?.as_str()?.to_string(),
            gpus: v.get("gpus")?.as_u64()? as u32,
            addr: v.get("addr")?.as_str()?.to_string(),
        })
    }
}

/// Membership change derived from the store's watch stream.
#[derive(Debug, Clone, PartialEq)]
pub enum MembershipEvent {
    Joined(NodeInfo),
    /// `expired == true` means the lease lapsed (crash/partition — SEV1);
    /// `false` means a clean deregistration.
    Left { id: String, expired: bool },
}

/// Translate a raw kv event under `/nodes/` into a membership event.
pub fn membership_event(ev: &Event) -> Option<MembershipEvent> {
    match ev {
        Event::Put { key, value, .. } if key.starts_with(NODES_PREFIX) => {
            let info = NodeInfo::from_json(&Value::parse(value).ok()?)?;
            Some(MembershipEvent::Joined(info))
        }
        Event::Delete { key, expired, .. } if key.starts_with(NODES_PREFIX) => Some(
            MembershipEvent::Left { id: key[NODES_PREFIX.len()..].to_string(), expired: *expired },
        ),
        _ => None,
    }
}

/// Agent-side registration handle (in-process store variant; the TCP variant
/// goes through [`crate::kvstore::net::KvClient`] with the same keys).
pub struct Registration {
    store: Store,
    pub lease: u64,
    pub key: String,
}

impl Registration {
    /// Register `info` with a lease of `ttl_s`.
    pub fn register(store: &Store, info: &NodeInfo, ttl_s: f64) -> Result<Registration> {
        let lease = store.grant_lease(ttl_s);
        let key = format!("{NODES_PREFIX}{}", info.id);
        store.put(&key, &info.to_json().encode(), Some(lease)).map_err(|e| anyhow!(e))?;
        Ok(Registration { store: store.clone(), lease, key })
    }

    /// Heartbeat. Errors once the lease has already expired (the agent must
    /// then re-register — it was declared dead).
    pub fn heartbeat(&self) -> Result<()> {
        self.store.keepalive(self.lease).map_err(|e| anyhow!(e))
    }

    /// Clean shutdown: revoke the lease (reported as non-expired Left).
    pub fn deregister(self) {
        self.store.revoke_lease(self.lease);
    }
}

/// Coordinator-side view: list the currently-registered nodes.
pub fn list_nodes(store: &Store) -> Vec<NodeInfo> {
    store
        .get_prefix(NODES_PREFIX)
        .into_iter()
        .filter_map(|(_, v)| NodeInfo::from_json(&Value::parse(&v).ok()?))
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::SimClock;
    use std::sync::Arc;

    fn setup() -> (Store, Arc<SimClock>) {
        let clock = SimClock::new();
        (Store::new(clock.clone()), clock)
    }

    fn info(id: &str) -> NodeInfo {
        NodeInfo { id: id.into(), gpus: 8, addr: format!("10.0.0.{id}:9000") }
    }

    #[test]
    fn register_list_deregister() {
        let (store, _) = setup();
        let r1 = Registration::register(&store, &info("1"), 5.0).unwrap();
        let _r2 = Registration::register(&store, &info("2"), 5.0).unwrap();
        let mut nodes = list_nodes(&store);
        nodes.sort_by(|a, b| a.id.cmp(&b.id));
        assert_eq!(nodes.len(), 2);
        assert_eq!(nodes[0], info("1"));
        r1.deregister();
        assert_eq!(list_nodes(&store).len(), 1);
    }

    #[test]
    fn crash_detected_via_lease_expiry() {
        let (store, clock) = setup();
        let rx = store.watch(NODES_PREFIX);
        let reg = Registration::register(&store, &info("7"), 5.0).unwrap();
        // heartbeats keep it alive
        for _ in 0..3 {
            clock.advance(3.0);
            reg.heartbeat().unwrap();
            store.tick();
        }
        // crash: no more heartbeats
        clock.advance(6.0);
        store.tick();
        let events: Vec<MembershipEvent> = rx.try_iter().filter_map(|e| membership_event(&e)).collect();
        assert_eq!(events.first(), Some(&MembershipEvent::Joined(info("7"))));
        assert_eq!(
            events.last(),
            Some(&MembershipEvent::Left { id: "7".into(), expired: true })
        );
        assert!(reg.heartbeat().is_err(), "declared dead; heartbeat must fail");
    }

    #[test]
    fn clean_leave_is_not_expired() {
        let (store, _) = setup();
        let rx = store.watch(NODES_PREFIX);
        let reg = Registration::register(&store, &info("3"), 5.0).unwrap();
        reg.deregister();
        let events: Vec<MembershipEvent> = rx.try_iter().filter_map(|e| membership_event(&e)).collect();
        assert_eq!(
            events.last(),
            Some(&MembershipEvent::Left { id: "3".into(), expired: false })
        );
    }

    #[test]
    fn node_info_roundtrip_and_garbage() {
        let i = info("9");
        assert_eq!(NodeInfo::from_json(&Value::parse(&i.to_json().encode()).unwrap()), Some(i));
        assert_eq!(NodeInfo::from_json(&Value::Null), None);
        // non-node keys ignored
        let ev = Event::Put { key: "/tasks/1".into(), value: "{}".into(), revision: 1 };
        assert_eq!(membership_event(&ev), None);
    }
}
