//! Metrics: time series, summaries, CSV/JSON export, and ASCII charts.
//!
//! The repro harness uses this to print the paper's figures as tables and
//! quick terminal plots (WAF-over-time for Fig. 11, bars for Figs. 3/9/10).

use std::fmt::Write as _;
use std::fs;
use std::io;
use std::path::Path;

use crate::ser::Value;

/// An (x, y) series with a name — one line/bar group of a figure.
#[derive(Debug, Clone, Default)]
pub struct Series {
    pub name: String,
    pub points: Vec<(f64, f64)>,
}

impl Series {
    pub fn new(name: &str) -> Series {
        Series { name: name.to_string(), points: Vec::new() }
    }

    pub fn push(&mut self, x: f64, y: f64) {
        self.points.push((x, y));
    }

    /// Trapezoidal integral — "accumulated WAF" in Fig. 11 terms.
    pub fn integral(&self) -> f64 {
        self.points.windows(2).map(|w| 0.5 * (w[0].1 + w[1].1) * (w[1].0 - w[0].0)).sum()
    }

    /// Mean of y values (unweighted).
    pub fn mean_y(&self) -> f64 {
        if self.points.is_empty() {
            return 0.0;
        }
        self.points.iter().map(|p| p.1).sum::<f64>() / self.points.len() as f64
    }

    pub fn max_y(&self) -> f64 {
        self.points.iter().map(|p| p.1).fold(f64::NEG_INFINITY, f64::max)
    }

    pub fn to_json(&self) -> Value {
        Value::obj().with("name", self.name.as_str()).with(
            "points",
            Value::Arr(
                self.points.iter().map(|(x, y)| Value::Arr(vec![Value::Num(*x), Value::Num(*y)])).collect(),
            ),
        )
    }
}

/// A figure: several series plus axis labels; exportable as CSV/JSON/ASCII.
#[derive(Debug, Clone, Default)]
pub struct Figure {
    pub title: String,
    pub x_label: String,
    pub y_label: String,
    pub series: Vec<Series>,
}

impl Figure {
    pub fn new(title: &str, x_label: &str, y_label: &str) -> Figure {
        Figure { title: title.into(), x_label: x_label.into(), y_label: y_label.into(), series: Vec::new() }
    }

    pub fn series_mut(&mut self, name: &str) -> &mut Series {
        if let Some(i) = self.series.iter().position(|s| s.name == name) {
            return &mut self.series[i];
        }
        self.series.push(Series::new(name));
        self.series.last_mut().unwrap()
    }

    pub fn get(&self, name: &str) -> Option<&Series> {
        self.series.iter().find(|s| s.name == name)
    }

    /// CSV: header `x,<name1>,<name2>…` aligned on shared x (union of xs).
    pub fn to_csv(&self) -> String {
        let mut xs: Vec<f64> = self.series.iter().flat_map(|s| s.points.iter().map(|p| p.0)).collect();
        xs.sort_by(|a, b| a.partial_cmp(b).unwrap());
        xs.dedup_by(|a, b| (*a - *b).abs() < 1e-12);
        let mut out = String::new();
        let _ = write!(out, "{}", self.x_label);
        for s in &self.series {
            let _ = write!(out, ",{}", s.name);
        }
        out.push('\n');
        for &x in &xs {
            let _ = write!(out, "{x}");
            for s in &self.series {
                match s.points.iter().find(|p| (p.0 - x).abs() < 1e-12) {
                    Some((_, y)) => {
                        let _ = write!(out, ",{y}");
                    }
                    None => out.push(','),
                }
            }
            out.push('\n');
        }
        out
    }

    pub fn to_json(&self) -> Value {
        Value::obj()
            .with("title", self.title.as_str())
            .with("x_label", self.x_label.as_str())
            .with("y_label", self.y_label.as_str())
            .with("series", Value::Arr(self.series.iter().map(|s| s.to_json()).collect()))
    }

    pub fn save_csv(&self, path: impl AsRef<Path>) -> io::Result<()> {
        fs::write(path, self.to_csv())
    }

    /// Terminal line chart (one char per column, one glyph per series).
    /// Degenerate dimensions are clamped to a 1×1 plot area rather than
    /// underflowing the grid math.
    pub fn ascii_chart(&self, width: usize, height: usize) -> String {
        let (width, height) = (width.max(1), height.max(1));
        let glyphs = ['*', '+', 'o', 'x', '#', '@', '%', '&'];
        let all: Vec<(f64, f64)> = self.series.iter().flat_map(|s| s.points.iter().copied()).collect();
        if all.is_empty() {
            return format!("{} (no data)\n", self.title);
        }
        let (x0, x1) = all.iter().fold((f64::MAX, f64::MIN), |(lo, hi), p| (lo.min(p.0), hi.max(p.0)));
        let (y0, y1) = all.iter().fold((f64::MAX, f64::MIN), |(lo, hi), p| (lo.min(p.1), hi.max(p.1)));
        let xspan = (x1 - x0).max(1e-12);
        let yspan = (y1 - y0).max(1e-12);
        let mut grid = vec![vec![' '; width]; height];
        for (si, s) in self.series.iter().enumerate() {
            let g = glyphs[si % glyphs.len()];
            for &(x, y) in &s.points {
                let cx = (((x - x0) / xspan) * (width - 1) as f64).round() as usize;
                let cy = (((y - y0) / yspan) * (height - 1) as f64).round() as usize;
                grid[height - 1 - cy][cx.min(width - 1)] = g;
            }
        }
        let mut out = format!("{}  [y: {} .. {} {}]\n", self.title, fmt3(y0), fmt3(y1), self.y_label);
        for row in grid {
            out.push('|');
            out.extend(row);
            out.push('\n');
        }
        let _ = writeln!(out, "+{}", "-".repeat(width));
        let _ = writeln!(out, " x: {} .. {} {}", fmt3(x0), fmt3(x1), self.x_label);
        for (si, s) in self.series.iter().enumerate() {
            let _ = writeln!(out, "   {} {}", glyphs[si % glyphs.len()], s.name);
        }
        out
    }
}

fn fmt3(x: f64) -> String {
    if x == 0.0 {
        "0".into()
    } else if x.abs() >= 1e5 || x.abs() < 1e-2 {
        format!("{x:.2e}")
    } else {
        format!("{x:.2}")
    }
}

/// Fixed-width table printer for the `repro` harness (paper-style rows).
pub struct Table {
    pub headers: Vec<String>,
    pub rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(headers: &[&str]) -> Table {
        Table { headers: headers.iter().map(|s| s.to_string()).collect(), rows: Vec::new() }
    }

    pub fn row(&mut self, cells: &[String]) {
        assert_eq!(cells.len(), self.headers.len(), "row width mismatch");
        self.rows.push(cells.to_vec());
    }

    pub fn render(&self) -> String {
        let mut widths: Vec<usize> = self.headers.iter().map(|h| h.len()).collect();
        for row in &self.rows {
            for (i, c) in row.iter().enumerate() {
                widths[i] = widths[i].max(c.len());
            }
        }
        let mut out = String::new();
        let line = |out: &mut String, cells: &[String]| {
            for (i, c) in cells.iter().enumerate() {
                let _ = write!(out, "| {:<w$} ", c, w = widths[i]);
            }
            out.push_str("|\n");
        };
        line(&mut out, &self.headers);
        let _ = writeln!(out, "|{}|", widths.iter().map(|w| "-".repeat(w + 2)).collect::<Vec<_>>().join("|"));
        for row in &self.rows {
            line(&mut out, row);
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn series_integral_trapezoid() {
        let mut s = Series::new("s");
        s.push(0.0, 0.0);
        s.push(1.0, 2.0);
        s.push(3.0, 2.0);
        assert!((s.integral() - (1.0 + 4.0)).abs() < 1e-12);
        assert!((s.mean_y() - 4.0 / 3.0).abs() < 1e-12);
        assert_eq!(s.max_y(), 2.0);
    }

    #[test]
    fn figure_csv_alignment() {
        let mut f = Figure::new("t", "x", "y");
        f.series_mut("a").push(0.0, 1.0);
        f.series_mut("a").push(1.0, 2.0);
        f.series_mut("b").push(1.0, 5.0);
        let csv = f.to_csv();
        let lines: Vec<&str> = csv.lines().collect();
        assert_eq!(lines[0], "x,a,b");
        assert_eq!(lines[1], "0,1,");
        assert_eq!(lines[2], "1,2,5");
    }

    #[test]
    fn figure_json_roundtrip() {
        let mut f = Figure::new("t", "x", "y");
        f.series_mut("a").push(0.5, 1.5);
        let j = f.to_json().encode();
        let v = Value::parse(&j).unwrap();
        assert_eq!(v.get("title").unwrap().as_str(), Some("t"));
    }

    #[test]
    fn ascii_chart_contains_series_glyphs() {
        let mut f = Figure::new("chart", "t", "v");
        for i in 0..10 {
            f.series_mut("up").push(i as f64, i as f64);
            f.series_mut("down").push(i as f64, 9.0 - i as f64);
        }
        let art = f.ascii_chart(40, 10);
        assert!(art.contains('*') && art.contains('+'));
        assert!(art.contains("up") && art.contains("down"));
    }

    #[test]
    fn integral_and_mean_of_degenerate_series() {
        // no points: both reductions are defined (0), not NaN
        let empty = Series::new("e");
        assert_eq!(empty.integral(), 0.0);
        assert_eq!(empty.mean_y(), 0.0);
        // one point: no interval to integrate over, mean is the point
        let mut one = Series::new("o");
        one.push(2.0, 7.0);
        assert_eq!(one.integral(), 0.0);
        assert_eq!(one.mean_y(), 7.0);
        assert_eq!(one.max_y(), 7.0);
    }

    #[test]
    fn ragged_series_leave_empty_csv_cells() {
        // series with disjoint x supports: each row fills only the columns
        // that have a sample there, and the union of xs stays sorted
        let mut f = Figure::new("t", "x", "y");
        f.series_mut("a").push(0.0, 1.0);
        f.series_mut("a").push(2.0, 3.0);
        f.series_mut("b").push(1.0, 5.0);
        let csv = f.to_csv();
        let lines: Vec<&str> = csv.lines().collect();
        assert_eq!(lines, vec!["x,a,b", "0,1,", "1,,5", "2,3,"]);
        // a figure with no series still emits a (header-only) CSV
        let bare = Figure::new("t", "x", "y");
        assert_eq!(bare.to_csv(), "x\n");
    }

    #[test]
    fn ascii_chart_degenerate_dimensions_do_not_panic() {
        let mut f = Figure::new("tiny", "t", "v");
        f.series_mut("a").push(0.0, 1.0);
        // zero-sized plot areas clamp to 1x1 instead of underflowing
        for (w, h) in [(0, 0), (0, 5), (5, 0), (1, 1)] {
            let art = f.ascii_chart(w, h);
            assert!(art.contains('*'), "the single point must plot at {w}x{h}:\n{art}");
        }
        // a single point spans zero x/y range: still one glyph, no NaN cells
        let art = f.ascii_chart(10, 3);
        assert_eq!(art.matches('*').count(), 2, "one plotted point + one legend glyph");
        // and an empty figure short-circuits whatever the dims are
        let none = Figure::new("void", "t", "v");
        assert_eq!(none.ascii_chart(0, 0), "void (no data)\n");
    }

    #[test]
    fn table_renders_aligned() {
        let mut t = Table::new(&["case", "value"]);
        t.row(&["x".into(), "1".into()]);
        t.row(&["longer".into(), "2".into()]);
        let r = t.render();
        assert!(r.contains("| case   |"));
        assert!(r.lines().count() == 4);
    }

    #[test]
    #[should_panic(expected = "row width mismatch")]
    fn table_rejects_bad_row() {
        let mut t = Table::new(&["a"]);
        t.row(&["1".into(), "2".into()]);
    }
}
