//! Analytical Megatron performance model: `T(t, x)` — the achieved aggregate
//! FLOP/s of task `t` on `x` GPUs under the *best* 3D-parallelism
//! configuration (paper §5.1).
//!
//! The paper calibrates `T(t,x)` by profiling on the real cluster and uses
//! automatic execution-plan search (Alpa [55]) for the parallelism settings.
//! We substitute an analytical cost model in the Megatron tradition
//! (compute + TP/DP collectives + pipeline bubble + memory capacity), with
//! the A800 constants from [`crate::config::ClusterSpec`]. It reproduces the
//! qualitative behaviour the paper builds on:
//!
//! * ≈40–55 % achieved/peak FLOP/s for well-chosen configs (Figs. 3a, 4),
//! * memory infeasibility below a model-size-dependent GPU count
//!   (`T_necessary`),
//! * non-monotonic aggregate FLOP/s in `x` when an awkward GPU count forces
//!   a worse factorization (Fig. 4's 48→56 dip),
//! * per-GPU efficiency that varies across tasks and scales — the signal the
//!   WAF planner exploits.

use crate::config::{ClusterSpec, ModelSpec};

pub mod search;

pub use search::{best_config, sweep, throughput_table};

/// A concrete 3D-parallelism configuration. `tp*pp*dp == gpus`.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct ParallelConfig {
    pub tp: u32,
    pub pp: u32,
    pub dp: u32,
    /// Micro-batch size in sequences.
    pub mbs: u32,
}

impl ParallelConfig {
    pub fn gpus(&self) -> u32 {
        self.tp * self.pp * self.dp
    }
}

/// Cost breakdown for one configuration of one model on one cluster.
#[derive(Debug, Clone, Copy)]
pub struct Estimate {
    pub config: ParallelConfig,
    /// Wall time of one training iteration (one global batch), seconds.
    pub iter_time_s: f64,
    /// Achieved aggregate FLOP/s = useful FLOPs per iteration / iter time.
    pub achieved_flops: f64,
    /// achieved / (gpus × peak).
    pub flops_ratio: f64,
    /// Peak per-GPU memory, GiB.
    pub memory_gib: f64,
    /// Samples (sequences) per second.
    pub samples_per_s: f64,
}

/// Fraction of peak a dense matmul sustains on the GPU (empirical constant;
/// folds kernel efficiency, layernorm/softmax tails, and scheduling gaps).
const MATMUL_EFF: f64 = 0.62;
/// Fraction of the DP gradient all-reduce hidden behind backward compute.
const DP_OVERLAP: f64 = 0.5;
/// Point-to-point pipeline latency per microbatch hop (seconds).
const PP_HOP_LATENCY: f64 = 20e-6;
/// Bytes per parameter resident on each model-parallel shard:
/// bf16 weights (2) + bf16 grads (2) + fp32 master + Adam m,v (12).
const BYTES_PER_PARAM: f64 = 16.0;
/// Per-GPU framework overhead (CUDA context, NCCL buffers, workspace), GiB.
const FRAMEWORK_OVERHEAD_GIB: f64 = 4.0;
/// Fixed per-iteration overhead (launch gaps, host sync, optimizer tails,
/// stragglers), seconds. Negligible for big models (10 s iterations),
/// decisive for small models at large scale — the per-GPU-efficiency decay
/// Fig. 4 shows and the WAF planner exploits.
const FIXED_ITER_OVERHEAD_S: f64 = 0.25;
/// Activation bytes per token per layer, divided by tp (Megatron-style
/// selective recomputation, bf16): ~34·h bytes per token per layer.
const ACT_BYTES_COEF: f64 = 34.0;

/// Evaluate one configuration. Returns `None` if it does not fit in memory
/// or violates basic divisibility (callers enumerate; see [`search`]).
pub fn evaluate(model: &ModelSpec, cluster: &ClusterSpec, cfg: ParallelConfig) -> Option<Estimate> {
    let (l, h, s, v) = (
        model.n_layers as f64,
        model.hidden as f64,
        model.seq_len as f64,
        model.vocab as f64,
    );
    let b = model.global_batch as f64;
    let (tp, pp, dp, mbs) = (cfg.tp as f64, cfg.pp as f64, cfg.dp as f64, cfg.mbs as f64);

    // -- divisibility ------------------------------------------------------
    if cfg.tp == 0 || cfg.pp == 0 || cfg.dp == 0 || cfg.mbs == 0 {
        return None;
    }
    if model.heads % cfg.tp != 0 || model.n_layers % cfg.pp != 0 {
        return None;
    }
    // TP beyond one node would cross the slow interconnect; Megatron forbids.
    if cfg.tp > cluster.gpus_per_node {
        return None;
    }
    // Micro-batches per pipeline: round the global batch *up* to the nearest
    // multiple of dp·mbs (Megatron pads the last ragged micro-batch); the
    // iteration then processes b_eff >= b sequences.
    let m = (b / (dp * mbs)).ceil();
    if m < 1.0 {
        return None;
    }
    let b_eff = m * dp * mbs;

    // -- memory ------------------------------------------------------------
    // Transformer-layer parameters sharded over tp, stages over pp;
    // embeddings live on the first/last stage sharded over tp.
    let layer_params = 12.0 * l * h * h;
    let emb_params = (v + s) * h;
    let shard_params = layer_params / (tp * pp) + emb_params / tp / pp.max(1.0);
    let param_bytes = shard_params * BYTES_PER_PARAM;
    // 1F1B keeps up to `pp` microbatches of this stage's activations live.
    let inflight = pp.min(m);
    let act_bytes = inflight * (l / pp) * ACT_BYTES_COEF * h * s * mbs / tp;
    let mem_gib = (param_bytes + act_bytes) / (1u64 << 30) as f64 + FRAMEWORK_OVERHEAD_GIB;
    if mem_gib > cluster.hbm_gib {
        return None;
    }

    // -- compute time ------------------------------------------------------
    // Useful model FLOPs for one iteration (all tokens, fwd+bwd); the padded
    // b_eff tokens are what the hardware executes.
    let flops_iter = model.flops_per_token() * model.tokens_per_iteration();
    let flops_exec = flops_iter * (b_eff / b);
    // Per-GPU sustained matmul rate.
    let eff_flops = cluster.gpu_peak_tflops * 1e12 * MATMUL_EFF;
    // Compute time for one microbatch through one stage (tp-sharded).
    let stage_flops = flops_exec / (m * dp) / pp / tp;
    let t_stage = stage_flops / eff_flops;

    // -- TP collectives ----------------------------------------------------
    // 4 all-reduces (2 fwd + 2 bwd) of the activation tensor per layer.
    let t_tp = if cfg.tp > 1 {
        let bytes = s * mbs * h * 2.0; // bf16 activations
        let ring = 2.0 * (tp - 1.0) / tp * bytes / (cluster.intra_bw_gbs * 1e9)
            + 2.0 * (tp - 1.0) * 3e-6; // NVSwitch hop latency
        4.0 * (l / pp) * ring
    } else {
        0.0
    };

    // -- pipeline ----------------------------------------------------------
    let t_mb = t_stage + t_tp;
    let hop = if cfg.pp > 1 {
        PP_HOP_LATENCY + s * mbs * h * 2.0 / (cluster.inter_bw_gbs * 1e9)
    } else {
        0.0
    };
    // 1F1B: (m + pp - 1) microbatch slots; each non-warm-up slot costs t_mb.
    let t_pipeline = (m + pp - 1.0) * (t_mb + hop);

    // -- DP gradient all-reduce --------------------------------------------
    let t_dp = if cfg.dp > 1 {
        let grad_bytes = 4.0 * shard_params; // fp32 gradient reduction
        // Replicas co-resident on one node share its NIC; a ring that spans
        // nodes is bottlenecked by the per-replica NIC share.
        let replicas_per_node = (cluster.gpus_per_node as f64 / (tp * pp)).max(1.0).floor();
        let crosses_nodes = dp > replicas_per_node;
        let bw = if crosses_nodes {
            cluster.inter_bw_gbs / replicas_per_node.min(dp)
        } else {
            cluster.intra_bw_gbs
        };
        let ring = 2.0 * (dp - 1.0) / dp * grad_bytes / (bw * 1e9);
        // per-hop ring latency: 2(dp-1) steps
        let lat = 2.0 * (dp - 1.0) * if crosses_nodes { 20e-6 } else { 5e-6 };
        (ring + lat) * (1.0 - DP_OVERLAP)
    } else {
        0.0
    };

    // -- optimizer step ------------------------------------------------------
    // Memory-bound pass over the shard: read+write 16B/param at ~1 TB/s HBM.
    let t_opt = shard_params * 2.0 * BYTES_PER_PARAM / 1.0e12;

    let iter_time = t_pipeline + t_dp + t_opt + FIXED_ITER_OVERHEAD_S;
    let gpus = cfg.gpus();
    let achieved = flops_iter / iter_time;
    Some(Estimate {
        config: cfg,
        iter_time_s: iter_time,
        achieved_flops: achieved,
        flops_ratio: achieved / cluster.peak_flops(gpus),
        memory_gib: mem_gib,
        samples_per_s: b / iter_time,
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    fn spec(name: &str) -> ModelSpec {
        ModelSpec::gpt3(name).unwrap()
    }

    #[test]
    fn evaluate_rejects_bad_divisibility() {
        let m = spec("gpt3-7b");
        let c = ClusterSpec::default();
        // heads=32 not divisible by tp=3
        assert!(evaluate(&m, &c, ParallelConfig { tp: 3, pp: 1, dp: 1, mbs: 1 }).is_none());
        // layers=32 not divisible by pp=5
        assert!(evaluate(&m, &c, ParallelConfig { tp: 1, pp: 5, dp: 1, mbs: 1 }).is_none());
        // global_batch not divisible by dp*mbs
        assert!(evaluate(&m, &c, ParallelConfig { tp: 1, pp: 1, dp: 3, mbs: 7 }).is_none());
        // tp crossing the node boundary
        assert!(evaluate(&m, &c, ParallelConfig { tp: 16, pp: 1, dp: 1, mbs: 1 }).is_none());
    }

    #[test]
    fn seven_b_fits_on_eight_gpus_not_one() {
        let m = spec("gpt3-7b");
        let c = ClusterSpec::default();
        assert!(evaluate(&m, &c, ParallelConfig { tp: 8, pp: 1, dp: 1, mbs: 1 }).is_some());
        assert!(evaluate(&m, &c, ParallelConfig { tp: 1, pp: 1, dp: 1, mbs: 1 }).is_none(),
                "7B with 16 B/param cannot fit one 80 GiB GPU");
    }

    #[test]
    fn ratio_in_plausible_band() {
        let m = spec("gpt3-7b");
        let c = ClusterSpec::default();
        let e = evaluate(&m, &c, ParallelConfig { tp: 8, pp: 1, dp: 8, mbs: 2 }).unwrap();
        assert!((0.25..0.62).contains(&e.flops_ratio), "ratio {}", e.flops_ratio);
        assert!(e.iter_time_s > 0.0 && e.samples_per_s > 0.0);
    }

    #[test]
    fn tp_comm_costs_something() {
        let m = spec("gpt3-1.3b");
        let c = ClusterSpec::default();
        let tp1 = evaluate(&m, &c, ParallelConfig { tp: 1, pp: 1, dp: 8, mbs: 4 }).unwrap();
        let tp8 = evaluate(&m, &c, ParallelConfig { tp: 8, pp: 1, dp: 1, mbs: 4 }).unwrap();
        assert!(tp1.achieved_flops > tp8.achieved_flops, "tp=8 should pay collective cost");
    }

    #[test]
    fn pipeline_bubble_hurts_small_batch() {
        let mut m = spec("gpt3-7b");
        let c = ClusterSpec::default();
        m.global_batch = 64;
        let deep = evaluate(&m, &c, ParallelConfig { tp: 1, pp: 32, dp: 1, mbs: 1 }).unwrap();
        let shallow = evaluate(&m, &c, ParallelConfig { tp: 8, pp: 4, dp: 1, mbs: 1 }).unwrap();
        // same gpu count, deeper pipe = bigger bubble at small m
        assert!(shallow.flops_ratio > deep.flops_ratio);
    }

    #[test]
    fn memory_accounts_for_pipeline_inflight() {
        let m = spec("gpt3-13b");
        let c = ClusterSpec::default();
        let e = evaluate(&m, &c, ParallelConfig { tp: 8, pp: 5, dp: 1, mbs: 1 }).unwrap();
        assert!(e.memory_gib > FRAMEWORK_OVERHEAD_GIB);
        assert!(e.memory_gib <= c.hbm_gib);
    }
}
