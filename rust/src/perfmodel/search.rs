//! Configuration search: the "automatic execution plan generation" the paper
//! leans on (§5.1, citing Alpa) — enumerate all legal (tp, pp, dp, mbs)
//! factorizations of `x` GPUs and keep the fastest feasible one.
//!
//! `throughput_table` materializes `T(t, x)` for x = 0..=n once per task;
//! the planner and simulator index it in O(1) afterwards (the paper's
//! "calibrating tasks on the given GPU cluster").

use super::{evaluate, Estimate, ParallelConfig};
use crate::config::{ClusterSpec, ModelSpec};

/// Best configuration for running `model` on exactly `x` GPUs, or `None` if
/// no legal configuration fits (e.g. not enough aggregate memory).
pub fn best_config(model: &ModelSpec, cluster: &ClusterSpec, x: u32) -> Option<Estimate> {
    if x == 0 {
        return None;
    }
    let mut best: Option<Estimate> = None;
    let mut tp = 1;
    while tp <= cluster.gpus_per_node && tp <= x && tp <= model.heads {
        if model.heads % tp == 0 && x % tp == 0 {
            let per_tp = x / tp;
            for pp in 1..=per_tp.min(model.n_layers) {
                if model.n_layers % pp != 0 || per_tp % pp != 0 {
                    continue;
                }
                let dp = per_tp / pp;
                for mbs_exp in 0..=4 {
                    let mbs = 1u32 << mbs_exp;
                    let cfg = ParallelConfig { tp, pp, dp, mbs };
                    if let Some(e) = evaluate(model, cluster, cfg) {
                        if best.map_or(true, |b| e.achieved_flops > b.achieved_flops) {
                            best = Some(e);
                        }
                    }
                }
            }
        }
        tp *= 2;
    }
    best
}

/// `T(t, x)` in FLOP/s for x = 0..=n (index = GPU count; 0 where infeasible).
///
/// This is the per-task "calibration table" of §5.1: computed once, then the
/// WAF function and the DP solver read it in O(1).
pub fn throughput_table(model: &ModelSpec, cluster: &ClusterSpec, n: u32) -> Vec<f64> {
    (0..=n)
        .map(|x| best_config(model, cluster, x).map_or(0.0, |e| e.achieved_flops))
        .collect()
}

/// Sweep of best estimates over a list of GPU counts (Fig. 4 driver).
pub fn sweep(model: &ModelSpec, cluster: &ClusterSpec, xs: &[u32]) -> Vec<(u32, Option<Estimate>)> {
    xs.iter().map(|&x| (x, best_config(model, cluster, x))).collect()
}

/// Smallest GPU count on which `model` is feasible — `T_necessary` when the
/// task spec does not pin one explicitly.
pub fn min_feasible_gpus(model: &ModelSpec, cluster: &ClusterSpec, limit: u32) -> Option<u32> {
    (1..=limit).find(|&x| best_config(model, cluster, x).is_some())
}

#[cfg(test)]
mod tests {
    use super::*;

    fn spec(name: &str) -> ModelSpec {
        ModelSpec::gpt3(name).unwrap()
    }

    #[test]
    fn best_config_uses_all_gpus() {
        let m = spec("gpt3-7b");
        let c = ClusterSpec::default();
        for x in [8, 16, 32, 64] {
            let e = best_config(&m, &c, x).unwrap();
            assert_eq!(e.config.gpus(), x, "x={x}");
        }
    }

    #[test]
    fn min_feasible_matches_memory_wall() {
        let c = ClusterSpec::default();
        let small = min_feasible_gpus(&spec("gpt3-1.3b"), &c, 128).unwrap();
        let big = min_feasible_gpus(&spec("gpt3-175b"), &c, 128).unwrap();
        assert!(small <= 2, "1.3B should fit on 1-2 GPUs, got {small}");
        assert!(big >= 48, "175B needs a lot of GPUs, got {big}");
        assert!(small < big);
    }

    #[test]
    fn throughput_table_shape() {
        let m = spec("gpt3-7b");
        let c = ClusterSpec::default();
        let t = throughput_table(&m, &c, 64);
        assert_eq!(t.len(), 65);
        assert_eq!(t[0], 0.0);
        // below the memory wall: zero
        assert_eq!(t[1], 0.0);
        // beyond: positive and mostly increasing in aggregate
        assert!(t[8] > 0.0);
        assert!(t[64] > t[8]);
    }

    #[test]
    fn table_can_be_non_monotonic_fig4() {
        // Awkward GPU counts force worse (or no) factorizations: adding GPUs
        // must not always increase aggregate throughput. Two forms, both in
        // the paper's Fig. 4 discussion: (a) hard infeasibility at counts
        // whose factorizations can't satisfy memory (aggregate drops to 0),
        // (b) the achieved/peak *ratio* dips between feasible counts.
        let m = spec("gpt3-7b");
        let c = ClusterSpec::default();
        let t = throughput_table(&m, &c, 64);
        let aggregate_dip = (9..=64).any(|x| t[x] < t[x - 1] && t[x - 1] > 0.0);
        assert!(aggregate_dip, "expected a Fig.4-style aggregate dip in 9..=64");
        // ratio non-monotonicity among feasible counts
        let ratios: Vec<f64> = (8..=64u32)
            .filter_map(|x| best_config(&m, &c, x).map(|e| e.flops_ratio))
            .collect();
        assert!(ratios.windows(2).any(|w| w[1] < w[0] - 1e-6), "ratio should dip somewhere");
    }

    #[test]
    fn per_gpu_efficiency_declines_at_scale() {
        let m = spec("gpt3-7b");
        let c = ClusterSpec::default();
        let e8 = best_config(&m, &c, 8).unwrap();
        let e64 = best_config(&m, &c, 64).unwrap();
        assert!(e8.flops_ratio >= e64.flops_ratio * 0.95,
                "8-GPU ratio {} should not be far below 64-GPU {}",
                e8.flops_ratio, e64.flops_ratio);
    }

    #[test]
    fn sweep_matches_best_config() {
        let m = spec("gpt3-1.3b");
        let c = ClusterSpec::default();
        let sw = sweep(&m, &c, &[4, 6, 8]);
        assert_eq!(sw.len(), 3);
        for (x, e) in sw {
            let direct = best_config(&m, &c, x);
            assert_eq!(e.map(|v| v.achieved_flops), direct.map(|v| v.achieved_flops), "x={x}");
        }
    }

    #[test]
    fn bigger_cluster_spec_serves_bigger_models() {
        let m = spec("gpt3-175b");
        let c = ClusterSpec::default(); // 128 GPUs
        let e = best_config(&m, &c, 128);
        assert!(e.is_some(), "175B must be trainable on the full 128-GPU cluster");
        let e = e.unwrap();
        assert!((0.2..0.65).contains(&e.flops_ratio), "ratio {}", e.flops_ratio);
    }
}
