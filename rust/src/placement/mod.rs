//! Placement: topology-aware node-to-task assignment (DESIGN.md §10).
//!
//! The §5 planner decides *how many* workers each task gets and the §6.3
//! transition strategy prices *how far* state must move — but until this
//! module nothing decided *which* nodes serve which task:
//! `Plan.assignment` was a bare count vector and every driver implicitly
//! assumed free, topology-blind shuffling. Real clusters fail by rack and
//! switch domain, and placement churn dominates restart cost, so the
//! missing link between the cost ledger and the fleet model is a concrete,
//! deterministic cluster map:
//!
//! * [`Layout`] — the coordinator's authoritative map from [`TaskId`] to
//!   the sorted set of [`NodeId`]s serving it. Every committed
//!   [`crate::planner::Plan`] carries one (wire v4), so layouts are
//!   recorded in the decision log and replayed bit-identically.
//! * [`assign`] — the min-churn solver: first **maximize nodes kept in
//!   place** (a worker that stays put pays nothing), then prefer
//!   **domain-compact** fills (new nodes drawn from domains where the task
//!   already lives, else from the emptiest free domain so the task can
//!   consolidate). Quarantined/isolated nodes are simply absent from the
//!   [`ClusterView`] — the fleet's exclusion set is respected by
//!   construction.
//! * [`assign_blind`] — the topology-blind reference (contiguous
//!   assignment in node-id order, ignoring the previous layout): the
//!   pre-placement behaviour, kept as the `placement-frag` experiment's
//!   baseline and selectable via `UnicronConfig::placement_min_churn`.
//! * [`TaskMoves`] / [`Layout::diff`] — per-task move accounting feeding
//!   the cost ledger real migration facts: kept nodes are free, gained
//!   nodes pay the task's §6.3 strategy price
//!   ([`TaskMoves::migration_s`]).
//!
//! # Determinism
//!
//! [`assign`] is a pure function of `(previous layout, demands in task-id
//! order, placeable node list, domain geometry)` — all of which are
//! functions of the recorded event stream — so a replayed
//! [`crate::proto::DecisionLog`] reproduces every layout bit-identically,
//! and a plan served from the §5.2 precomputed table commits the exact
//! layout a live solve would (the counts are identical, and placement only
//! reads the counts).
//!
//! # Optimality
//!
//! Because each node serves at most one task, the previous per-task node
//! sets are disjoint; keeping is therefore contention-free and the greedy
//! keep phase attains the true maximum-keep matching
//! `Σᵢ min(needᵢ, |prevᵢ ∩ healthy|)` — pinned against brute-force
//! matching on small instances by the property test below.

use std::collections::{BTreeMap, BTreeSet};
use std::fmt;

use crate::cost::{CostModel, TransitionProfile};
use crate::fleet::DomainId;
use crate::proto::{NodeId, TaskId};
use crate::ser::Value;

/// The placement solver's view of the cluster: the placeable nodes (healthy,
/// not quarantined/isolated/released — the fleet's exclusion set is applied
/// by the caller), how many GPUs each contributes, and the rack/switch
/// geometry.
#[derive(Debug, Clone, Copy)]
pub struct ClusterView<'a> {
    /// Placeable nodes, ascending id.
    pub nodes: &'a [NodeId],
    pub gpus_per_node: u32,
    /// Failure-domain geometry: `domain = node / nodes_per_domain`, the same
    /// mapping [`crate::fleet::FleetModel::domain_of`] uses.
    pub nodes_per_domain: u32,
}

impl ClusterView<'_> {
    pub fn domain_of(&self, node: NodeId) -> DomainId {
        DomainId(node.0 / self.nodes_per_domain.max(1))
    }

    /// Whole nodes needed to host `workers` GPUs.
    pub fn nodes_needed(&self, workers: u32) -> usize {
        let gpn = self.gpus_per_node.max(1);
        workers.div_ceil(gpn) as usize
    }
}

/// The authoritative cluster map: which concrete nodes serve each task.
/// Node lists are sorted ascending; every task the layout was solved for is
/// present (possibly with an empty list when it was assigned zero workers
/// or the pool ran dry). The default (empty) layout is what topology-blind
/// policies (the §7 baselines) publish.
#[derive(Debug, Clone, PartialEq, Eq, Default)]
pub struct Layout {
    tasks: BTreeMap<TaskId, Vec<NodeId>>,
}

impl Layout {
    /// Build a layout from explicit per-task node sets (tests, tools).
    pub fn new(entries: impl IntoIterator<Item = (TaskId, Vec<NodeId>)>) -> Layout {
        let mut tasks: BTreeMap<TaskId, Vec<NodeId>> = entries.into_iter().collect();
        for nodes in tasks.values_mut() {
            nodes.sort_unstable();
        }
        Layout { tasks }
    }

    /// True when the layout holds no task entries at all (a topology-blind
    /// plan). A layout whose tasks all have empty node lists is *not*
    /// empty — it states that every task is unplaced.
    pub fn is_empty(&self) -> bool {
        self.tasks.is_empty()
    }

    /// Number of task entries.
    pub fn len(&self) -> usize {
        self.tasks.len()
    }

    /// Nodes serving `task` (empty if unknown).
    pub fn nodes_of(&self, task: TaskId) -> &[NodeId] {
        self.tasks.get(&task).map(Vec::as_slice).unwrap_or(&[])
    }

    /// Which task `node` serves, if any.
    pub fn owner_of(&self, node: NodeId) -> Option<TaskId> {
        self.tasks
            .iter()
            .find(|(_, nodes)| nodes.binary_search(&node).is_ok())
            .map(|(&task, _)| task)
    }

    /// `(task, nodes)` entries in ascending task-id order.
    pub fn iter(&self) -> impl Iterator<Item = (TaskId, &[NodeId])> {
        self.tasks.iter().map(|(&t, ns)| (t, ns.as_slice()))
    }

    /// All placed nodes across tasks (each node appears at most once — the
    /// solver never double-books).
    pub fn placed_nodes(&self) -> impl Iterator<Item = NodeId> + '_ {
        self.tasks.values().flatten().copied()
    }

    /// One-line shape summary ("3 tasks on 12 nodes") — what the incident
    /// narrative and the `/fleet/metrics` tooling print for a layout
    /// without dumping the node lists.
    pub fn summary(&self) -> String {
        let nodes = self.placed_nodes().count();
        format!("{} task{} on {} node{}", self.len(), plural(self.len()), nodes, plural(nodes))
    }

    /// Distinct failure domains `task` is spread over — the fragmentation
    /// metric the `placement-frag` experiment reports.
    pub fn domain_spread(&self, task: TaskId, nodes_per_domain: u32) -> usize {
        let npd = nodes_per_domain.max(1);
        let domains: BTreeSet<u32> =
            self.nodes_of(task).iter().map(|n| n.0 / npd).collect();
        domains.len()
    }

    /// Per-task move accounting against `prev`: which nodes were kept in
    /// place, which were gained (state must be pulled in), which were lost.
    pub fn diff(&self, prev: &Layout) -> Vec<TaskMoves> {
        self.tasks
            .iter()
            .map(|(&task, nodes)| {
                let before: BTreeSet<NodeId> = prev.nodes_of(task).iter().copied().collect();
                let after: BTreeSet<NodeId> = nodes.iter().copied().collect();
                TaskMoves {
                    task,
                    kept: after.intersection(&before).copied().collect(),
                    gained: after.difference(&before).copied().collect(),
                    lost: before.difference(&after).copied().collect(),
                }
            })
            .collect()
    }

    /// Tagged-JSON encoding: an array of `{"task": id, "nodes": [ids]}` in
    /// ascending task order (deterministic, replay-stable).
    pub fn to_value(&self) -> Value {
        Value::Arr(
            self.tasks
                .iter()
                .map(|(t, ns)| {
                    Value::obj()
                        .with("task", t.0)
                        .with("nodes", ns.iter().map(|n| n.0).collect::<Vec<u32>>())
                })
                .collect(),
        )
    }

    /// Strict decode (inverse of [`Layout::to_value`]): malformed entries,
    /// repeated tasks, and double-booked nodes (one node listed under two
    /// tasks, or twice in one) are rejected, never repaired — a tampered
    /// cluster map must not replay.
    pub fn from_value(v: &Value) -> Result<Layout, String> {
        let arr = v.as_arr().ok_or("layout is not an array")?;
        let mut tasks = BTreeMap::new();
        let mut booked: BTreeSet<NodeId> = BTreeSet::new();
        for entry in arr {
            let task = entry
                .get("task")
                .and_then(Value::as_u64)
                .and_then(|x| u32::try_from(x).ok())
                .ok_or("layout entry field \"task\" is not a u32")?;
            let nodes = entry
                .get("nodes")
                .and_then(Value::as_arr)
                .ok_or("layout entry field \"nodes\" is not an array")?
                .iter()
                .map(|n| {
                    n.as_u64()
                        .and_then(|x| u32::try_from(x).ok())
                        .map(NodeId)
                        .ok_or("layout node is not a u32")
                })
                .collect::<Result<Vec<NodeId>, &str>>()?;
            if !nodes.windows(2).all(|w| w[0] < w[1]) {
                return Err(format!("layout nodes for task {task} are not strictly ascending"));
            }
            for &n in &nodes {
                if !booked.insert(n) {
                    return Err(format!("layout places node {n} twice"));
                }
            }
            if tasks.insert(TaskId(task), nodes).is_some() {
                return Err(format!("layout repeats task {task}"));
            }
        }
        Ok(Layout::new(tasks))
    }
}

fn plural(n: usize) -> &'static str {
    if n == 1 {
        ""
    } else {
        "s"
    }
}

impl fmt::Display for Layout {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        for (i, (t, ns)) in self.tasks.iter().enumerate() {
            if i > 0 {
                write!(f, "; ")?;
            }
            write!(f, "task {t}: {:?}", ns.iter().map(|n| n.0).collect::<Vec<u32>>())?;
        }
        Ok(())
    }
}

/// One task's placement delta between two layouts.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct TaskMoves {
    pub task: TaskId,
    /// Nodes serving the task in both layouts — their workers stay in place
    /// and pay nothing.
    pub kept: Vec<NodeId>,
    /// Nodes newly serving the task — state must be pulled onto them.
    pub gained: Vec<NodeId>,
    /// Nodes the task no longer uses.
    pub lost: Vec<NodeId>,
}

impl TaskMoves {
    /// GPUs whose state must move onto the gained nodes: workers pack onto
    /// the kept nodes first (they stay in place and pay nothing), so only
    /// the overflow migrates — capped by the gained nodes' capacity.
    pub fn gained_gpus(&self, gpus_per_node: u32, new_workers: u32) -> u32 {
        let gpn = gpus_per_node.max(1);
        let kept_capacity = self.kept.len() as u32 * gpn;
        ((self.gained.len() as u32) * gpn).min(new_workers.saturating_sub(kept_capacity))
    }

    /// The migration fact this move feeds the ledger: a task whose every
    /// worker stayed in place pays nothing; one that pulled state onto new
    /// nodes (or lost its nearest replica — `faulted`) pays its §6.3
    /// strategy price plus the flat orchestration overhead.
    pub fn migration_s(&self, profile: &TransitionProfile, cost: &CostModel, faulted: bool) -> f64 {
        if self.gained.is_empty() && !faulted {
            0.0
        } else {
            cost.transition_s(profile, faulted)
        }
    }
}

/// Keep-or-move score of pulling a task's next node from one domain,
/// higher wins: co-locate with the task's existing nodes first
/// (`mine_in_domain`), else prefer the domain with the most free nodes (so
/// the task can consolidate into it), ties to the domain holding the
/// lowest free node id. Equivalent to scoring every free node individually
/// — the best node is always the lowest-id free node of the best domain —
/// but evaluated once per domain, which keeps a full fill O(#domains) per
/// pick instead of O(#free). `benches/placement.rs` pins this evaluation
/// at ≥ 1M/s.
#[inline]
pub fn keep_or_move_score(
    mine_in_domain: u32,
    free_in_domain: &BTreeSet<NodeId>,
) -> (u32, usize, std::cmp::Reverse<NodeId>) {
    (
        mine_in_domain,
        free_in_domain.len(),
        std::cmp::Reverse(free_in_domain.first().copied().unwrap_or(NodeId(u32::MAX))),
    )
}

/// The min-churn, domain-compact assignment solver. `demands` are
/// `(task, workers)` in ascending task-id order — the same order every
/// `Plan.assignment` uses. See the module docs for the objective.
///
/// Best-effort on infeasible packings: if whole-node demands exceed the
/// placeable pool (worker counts that are not node-multiples can overbook
/// nodes), earlier tasks are served first and the shortfall shows up as a
/// shorter node list — never a shared or phantom node.
pub fn assign(prev: &Layout, demands: &[(TaskId, u32)], view: &ClusterView) -> Layout {
    let node_set: BTreeSet<NodeId> = view.nodes.iter().copied().collect();
    let (mut out, shortfall, _dropped) = keep_phase(prev, demands, &node_set, view);
    if shortfall.iter().all(|&(_, need)| need == 0) {
        // every task was served entirely by keeps — phase 2 never consults
        // the free pool, so skip building it (the common steady-state
        // replan, and the reason a no-shortfall solve is O(placed) not
        // O(fleet))
        return Layout { tasks: out };
    }
    let used: BTreeSet<NodeId> = out.values().flatten().copied().collect();
    let mut free: BTreeMap<DomainId, BTreeSet<NodeId>> = BTreeMap::new();
    for &n in node_set.difference(&used) {
        free.entry(view.domain_of(n)).or_default().insert(n);
    }
    fill_phase(&mut out, &shortfall, &mut free, view);
    Layout { tasks: out }
}

/// Phase 1 — keeps. Previous per-task sets are disjoint, so each task
/// keeping its own healthy nodes (up to demand) is the maximum-keep
/// matching. Within a task, keep the domain-compact subset: nodes from
/// the domains where the task has the most survivors first. Returns the
/// per-task keeps, per-task shortfalls (task-id order), and the surviving
/// previous nodes that were *not* kept because the task's demand shrank.
fn keep_phase(
    prev: &Layout,
    demands: &[(TaskId, u32)],
    node_set: &BTreeSet<NodeId>,
    view: &ClusterView,
) -> (BTreeMap<TaskId, Vec<NodeId>>, Vec<(TaskId, usize)>, Vec<NodeId>) {
    let mut out: BTreeMap<TaskId, Vec<NodeId>> = BTreeMap::new();
    let mut shortfall: Vec<(TaskId, usize)> = Vec::with_capacity(demands.len());
    let mut dropped: Vec<NodeId> = Vec::new();
    for &(task, workers) in demands {
        let need = view.nodes_needed(workers);
        let mut healthy: Vec<NodeId> =
            prev.nodes_of(task).iter().copied().filter(|n| node_set.contains(n)).collect();
        let mut per_domain: BTreeMap<DomainId, u32> = BTreeMap::new();
        for &n in &healthy {
            *per_domain.entry(view.domain_of(n)).or_insert(0) += 1;
        }
        healthy.sort_by_key(|&n| {
            let d = view.domain_of(n);
            (std::cmp::Reverse(per_domain[&d]), d, n)
        });
        dropped.extend(healthy.drain(need.min(healthy.len())..));
        shortfall.push((task, need - healthy.len()));
        healthy.sort_unstable();
        out.insert(task, healthy);
    }
    (out, shortfall, dropped)
}

/// Phase 2 — fills from the free pool, domain-compact, task-id order.
/// Picked nodes are consumed from `free`; emptied domains keep their (now
/// empty) entry, which the pick filter ignores.
fn fill_phase(
    out: &mut BTreeMap<TaskId, Vec<NodeId>>,
    shortfall: &[(TaskId, usize)],
    free: &mut BTreeMap<DomainId, BTreeSet<NodeId>>,
    view: &ClusterView,
) {
    for &(task, need) in shortfall {
        if need == 0 {
            continue;
        }
        let assigned = out.get_mut(&task).expect("phase 1 inserted every task");
        let mut mine: BTreeMap<DomainId, u32> = BTreeMap::new();
        for &n in assigned.iter() {
            *mine.entry(view.domain_of(n)).or_insert(0) += 1;
        }
        for _ in 0..need {
            let best = free
                .iter()
                .filter(|&(_, nodes)| !nodes.is_empty())
                .max_by_key(|&(d, nodes)| {
                    keep_or_move_score(mine.get(d).copied().unwrap_or(0), nodes)
                })
                .map(|(&d, _)| d);
            let Some(d) = best else {
                break; // pool ran dry: honest shortfall
            };
            let nodes = free.get_mut(&d).expect("best domain came from the free map");
            let pick = *nodes.first().expect("best domain is non-empty");
            nodes.remove(&pick);
            *mine.entry(d).or_insert(0) += 1;
            assigned.push(pick);
        }
        assigned.sort_unstable();
    }
}

/// Warm-start state for [`assign_cached`]: the previous solve's inputs,
/// its result, and the maintained free pool
/// (`free == node_set − result.placed_nodes()`), so the next solve in a
/// replan chain touches only the membership/demand delta instead of
/// rebuilding O(fleet) structures.
///
/// The cache is pure acceleration — [`assign_cached`] returns exactly what
/// [`assign`] returns for the same `(prev, demands, view)` — so holding or
/// dropping it never changes a committed layout, only the time to compute
/// it (replay-safe by construction).
#[derive(Debug, Clone)]
pub struct AssignCache {
    nodes: Vec<NodeId>,
    gpus_per_node: u32,
    nodes_per_domain: u32,
    prev: Layout,
    demands: Vec<(TaskId, u32)>,
    node_set: BTreeSet<NodeId>,
    /// Invariant between calls: exactly the placeable nodes the cached
    /// result leaves unplaced, grouped by domain (no empty domain entries).
    free: BTreeMap<DomainId, BTreeSet<NodeId>>,
    result: Layout,
}

impl AssignCache {
    fn geometry_matches(&self, view: &ClusterView) -> bool {
        self.gpus_per_node == view.gpus_per_node
            && self.nodes_per_domain == view.nodes_per_domain
    }
}

/// [`assign`], warm-started from the previous solve.
///
/// Bit-identical to [`assign`] on every input (the
/// `warm_start_assign_equals_from_scratch` property pins this); the cache
/// only changes *how much work* the solve does:
///
/// * same `(prev, demands, nodes)` as the cached call — the cached layout
///   is returned as-is;
/// * `prev` is the cached call's *result* (the normal replan chain: commit,
///   then replan after the next event) — `node_set` and the free pool are
///   updated by the sorted-merge membership delta, phase 1 re-keeps only
///   the O(placed) previous nodes, and a no-shortfall solve never touches
///   an O(fleet) structure at all;
/// * anything else — cold start, identical to [`assign`] plus snapshotting
///   the cache for the next call.
///
/// Like [`assign`], `prev`'s per-task node sets must be disjoint (every
/// committed layout's are — the solver never double-books).
pub fn assign_cached(
    cache: &mut Option<AssignCache>,
    prev: &Layout,
    demands: &[(TaskId, u32)],
    view: &ClusterView,
) -> Layout {
    if let Some(c) = cache.as_ref() {
        if c.geometry_matches(view)
            && c.nodes == view.nodes
            && c.prev == *prev
            && c.demands == demands
        {
            return c.result.clone();
        }
    }
    let warm = cache.take().filter(|c| c.geometry_matches(view) && c.result == *prev);
    // Establish `free == node_set − (surviving nodes `prev` still places)`.
    let (mut node_set, mut free) = match warm {
        Some(c) => {
            // prev == the cached result, so the cached free pool already
            // satisfies the invariant over the *old* membership; apply the
            // sorted-merge delta between the old and new placeable lists.
            let (mut node_set, mut free) = (c.node_set, c.free);
            let (old, new) = (c.nodes.as_slice(), view.nodes);
            let (mut i, mut j) = (0usize, 0usize);
            while i < old.len() || j < new.len() {
                let (o, n) = (old.get(i).copied(), new.get(j).copied());
                if o.is_some() && o == n {
                    i += 1;
                    j += 1;
                } else if o.is_some() && (n.is_none() || o < n) {
                    let o = o.expect("checked is_some");
                    node_set.remove(&o);
                    if let Some(d) = free.get_mut(&view.domain_of(o)) {
                        d.remove(&o); // placed nodes are not in the pool
                    }
                    i += 1;
                } else {
                    // a joined node was never placed by the cached result
                    let n = n.expect("merge walk not done");
                    node_set.insert(n);
                    free.entry(view.domain_of(n)).or_default().insert(n);
                    j += 1;
                }
            }
            (node_set, free)
        }
        None => {
            let node_set: BTreeSet<NodeId> = view.nodes.iter().copied().collect();
            let mut free: BTreeMap<DomainId, BTreeSet<NodeId>> = BTreeMap::new();
            for &n in &node_set {
                free.entry(view.domain_of(n)).or_default().insert(n);
            }
            for (_, placed) in prev.iter() {
                for n in placed {
                    if let Some(d) = free.get_mut(&view.domain_of(*n)) {
                        d.remove(n);
                    }
                }
            }
            (node_set, free)
        }
    };
    let (mut out, shortfall, dropped) = keep_phase(prev, demands, &node_set, view);
    // Nodes `prev` placed but this solve keeps nowhere join the pool:
    // survivors a shrinking task dropped, plus every surviving node of a
    // task that left the demand list. With them, free == node_set − keeps —
    // exactly the pool [`assign`] builds from scratch.
    for n in dropped {
        free.entry(view.domain_of(n)).or_default().insert(n);
    }
    for (task, placed) in prev.iter() {
        if demands.binary_search_by_key(&task, |&(t, _)| t).is_err() {
            for &n in placed {
                if node_set.contains(&n) {
                    free.entry(view.domain_of(n)).or_default().insert(n);
                }
            }
        }
    }
    fill_phase(&mut out, &shortfall, &mut free, view);
    free.retain(|_, nodes| !nodes.is_empty());
    let result = Layout { tasks: out };
    *cache = Some(AssignCache {
        nodes: view.nodes.to_vec(),
        gpus_per_node: view.gpus_per_node,
        nodes_per_domain: view.nodes_per_domain,
        prev: prev.clone(),
        demands: demands.to_vec(),
        node_set,
        free,
        result: result.clone(),
    });
    result
}

/// Topology-blind reference assignment: contiguous whole-node chunks in
/// node-id order, ignoring the previous layout entirely — the convention
/// the pre-placement simulator hard-coded. Every reconfiguration reshuffles
/// everyone, which is exactly the churn [`assign`] exists to avoid; the
/// `placement-frag` experiment pins the gap.
pub fn assign_blind(demands: &[(TaskId, u32)], view: &ClusterView) -> Layout {
    let mut cursor = 0usize;
    let mut out = BTreeMap::new();
    for &(task, workers) in demands {
        let need = view.nodes_needed(workers);
        let end = (cursor + need).min(view.nodes.len());
        out.insert(task, view.nodes[cursor..end].to_vec());
        cursor = end;
    }
    Layout { tasks: out }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::UnicronConfig;
    use crate::proptest::{run, Config, Prop};
    use crate::rng::{Rand, Xoshiro256};

    fn nodes(ids: &[u32]) -> Vec<NodeId> {
        ids.iter().copied().map(NodeId).collect()
    }

    fn view(ns: &[NodeId], gpn: u32, npd: u32) -> ClusterView<'_> {
        ClusterView { nodes: ns, gpus_per_node: gpn, nodes_per_domain: npd }
    }

    #[test]
    fn layout_summary_counts_tasks_and_nodes() {
        assert_eq!(Layout::default().summary(), "0 tasks on 0 nodes");
        let one = Layout::new([(TaskId(0), nodes(&[3]))]);
        assert_eq!(one.summary(), "1 task on 1 node");
        let l = Layout::new([(TaskId(0), nodes(&[0, 1])), (TaskId(1), nodes(&[2]))]);
        assert_eq!(l.summary(), "2 tasks on 3 nodes");
    }

    /// Brute-force maximum-keep matching: every disjoint way of giving each
    /// task *up to* its needed node count from the pool (under-assignment
    /// allowed — the solver's honest-shortfall semantics), maximizing total
    /// keeps over all of them.
    fn brute_max_keeps(prev: &Layout, demands: &[(TaskId, u32)], v: &ClusterView) -> usize {
        fn rec(
            i: usize,
            demands: &[(TaskId, usize)],
            prev: &Layout,
            free: &mut Vec<NodeId>,
            chosen: &mut Vec<(TaskId, Vec<NodeId>)>,
            best: &mut usize,
        ) {
            if i == demands.len() {
                let keeps: usize = chosen
                    .iter()
                    .map(|(t, ns)| {
                        ns.iter().filter(|n| prev.nodes_of(*t).contains(*n)).count()
                    })
                    .sum();
                *best = (*best).max(keeps);
                return;
            }
            let (task, need) = demands[i];
            // enumerate all subsets of `free` with size 0..=need
            fn subsets(
                free: &[NodeId],
                max_k: usize,
                start: usize,
                cur: &mut Vec<NodeId>,
                out: &mut Vec<Vec<NodeId>>,
            ) {
                out.push(cur.clone());
                if cur.len() == max_k {
                    return;
                }
                for j in start..free.len() {
                    cur.push(free[j]);
                    subsets(free, max_k, j + 1, cur, out);
                    cur.pop();
                }
            }
            let mut subs = Vec::new();
            subsets(free, need.min(free.len()), 0, &mut Vec::new(), &mut subs);
            for sub in subs {
                let saved = free.clone();
                free.retain(|n| !sub.contains(n));
                chosen.push((task, sub));
                rec(i + 1, demands, prev, free, chosen, best);
                chosen.pop();
                *free = saved;
            }
        }
        let demands: Vec<(TaskId, usize)> =
            demands.iter().map(|&(t, w)| (t, v.nodes_needed(w))).collect();
        let mut free: Vec<NodeId> = v.nodes.to_vec();
        let mut best = 0;
        rec(0, &demands, prev, &mut free, &mut Vec::new(), &mut best);
        best
    }

    fn keeps_of(layout: &Layout, prev: &Layout) -> usize {
        layout.diff(prev).iter().map(|m| m.kept.len()).sum()
    }

    #[test]
    fn fresh_assignment_is_compact_and_disjoint() {
        let ns = nodes(&[0, 1, 2, 3, 4, 5, 6, 7]);
        let v = view(&ns, 8, 4);
        let layout = assign(
            &Layout::default(),
            &[(TaskId(0), 32), (TaskId(1), 32)],
            &v,
        );
        assert_eq!(layout.nodes_of(TaskId(0)).len(), 4);
        assert_eq!(layout.nodes_of(TaskId(1)).len(), 4);
        // disjoint
        let all: BTreeSet<NodeId> = layout.placed_nodes().collect();
        assert_eq!(all.len(), 8);
        // each task fits exactly one domain (4 nodes per domain)
        assert_eq!(layout.domain_spread(TaskId(0), 4), 1);
        assert_eq!(layout.domain_spread(TaskId(1), 4), 1);
    }

    #[test]
    fn min_churn_keeps_surviving_nodes_in_place() {
        let ns = nodes(&[0, 1, 2, 3, 4, 5, 6, 7]);
        let v = view(&ns, 8, 4);
        let prev = Layout::new([
            (TaskId(0), nodes(&[0, 1, 2, 3])),
            (TaskId(1), nodes(&[4, 5, 6, 7])),
        ]);
        // node 5 dies and spare node 8 is placeable: task 1 must keep 4/6/7
        // and pull exactly one new node
        let healthy = nodes(&[0, 1, 2, 3, 4, 6, 7, 8]);
        let v2 = view(&healthy, 8, 4);
        let layout = assign(&prev, &[(TaskId(0), 32), (TaskId(1), 32)], &v2);
        let moves = layout.diff(&prev);
        assert_eq!(moves[0].kept, nodes(&[0, 1, 2, 3]), "untouched task keeps everything");
        assert!(moves[0].gained.is_empty() && moves[0].lost.is_empty());
        assert_eq!(moves[1].kept, nodes(&[4, 6, 7]));
        assert_eq!(moves[1].lost, nodes(&[5]));
        assert_eq!(moves[1].gained.len(), 1, "exactly the replacement moves");
    }

    #[test]
    fn fills_prefer_the_tasks_existing_domain() {
        // task 0 lives in domain 1 (nodes 4..8); a free node exists in both
        // domain 0 and domain 1 — the fill must co-locate.
        let ns = nodes(&[0, 4, 5, 6, 7]);
        let v = view(&ns, 8, 4);
        let prev = Layout::new([(TaskId(0), nodes(&[4, 5, 6]))]);
        let layout = assign(&prev, &[(TaskId(0), 32)], &v);
        assert_eq!(layout.nodes_of(TaskId(0)), nodes(&[4, 5, 6, 7]).as_slice());
    }

    #[test]
    fn consolidation_prefers_the_emptiest_free_domain() {
        // fresh task, free nodes: 1 in domain 0, 3 in domain 1 — picking the
        // fuller domain lets the whole task fit one rack
        let ns = nodes(&[0, 4, 5, 6]);
        let v = view(&ns, 8, 4);
        let layout = assign(&Layout::default(), &[(TaskId(0), 24)], &v);
        assert_eq!(layout.nodes_of(TaskId(0)), nodes(&[4, 5, 6]).as_slice());
        assert_eq!(layout.domain_spread(TaskId(0), 4), 1);
    }

    #[test]
    fn blind_assignment_ignores_history() {
        let ns = nodes(&[0, 1, 2, 3]);
        let v = view(&ns, 8, 4);
        let prev = Layout::new([(TaskId(0), nodes(&[2, 3])), (TaskId(1), nodes(&[0, 1]))]);
        let layout = assign_blind(&[(TaskId(0), 16), (TaskId(1), 16)], &v);
        // contiguous in id order, prev be damned
        assert_eq!(layout.nodes_of(TaskId(0)), nodes(&[0, 1]).as_slice());
        assert_eq!(layout.nodes_of(TaskId(1)), nodes(&[2, 3]).as_slice());
        assert_eq!(keeps_of(&layout, &prev), 0);
    }

    #[test]
    fn overbooked_pool_serves_earlier_tasks_first() {
        let ns = nodes(&[0]);
        let v = view(&ns, 8, 4);
        let layout =
            assign(&Layout::default(), &[(TaskId(0), 4), (TaskId(1), 4)], &v);
        assert_eq!(layout.nodes_of(TaskId(0)).len(), 1);
        assert_eq!(layout.nodes_of(TaskId(1)).len(), 0, "honest shortfall, no sharing");
    }

    #[test]
    fn zero_worker_tasks_keep_an_empty_entry() {
        let ns = nodes(&[0, 1]);
        let v = view(&ns, 8, 4);
        let layout = assign(&Layout::default(), &[(TaskId(0), 8), (TaskId(1), 0)], &v);
        assert!(!layout.is_empty());
        assert_eq!(layout.len(), 2);
        assert!(layout.nodes_of(TaskId(1)).is_empty());
        assert_eq!(layout.owner_of(NodeId(0)), Some(TaskId(0)));
        assert_eq!(layout.owner_of(NodeId(1)), None);
    }

    #[test]
    fn move_accounting_prices_kept_free_and_gained_at_strategy_price() {
        let cost = CostModel::from_config(&UnicronConfig::default());
        let profile = TransitionProfile { replica_s: 2.0, inmem_s: 40.0, remote_s: 300.0 };
        let stay = TaskMoves { task: TaskId(0), kept: nodes(&[0, 1]), gained: vec![], lost: vec![] };
        assert_eq!(stay.migration_s(&profile, &cost, false), 0.0, "staying is free");
        let pull =
            TaskMoves { task: TaskId(0), kept: nodes(&[0]), gained: nodes(&[5]), lost: nodes(&[1]) };
        assert_eq!(
            pull.migration_s(&profile, &cost, false),
            cost.transition_base_s() + profile.replica_s,
            "a planned pull pays the replica path"
        );
        assert_eq!(
            pull.migration_s(&profile, &cost, true),
            cost.transition_base_s() + profile.inmem_s,
            "a faulted pull pays the in-memory checkpoint path"
        );
        // one kept node (8 slots) + one gained: at 12 workers only the 4
        // that overflow the kept node migrate; at 3 everything fits in place
        assert_eq!(pull.gained_gpus(8, 12), 4);
        assert_eq!(pull.gained_gpus(8, 3), 0, "workers packing onto kept nodes never move");
        let fresh = TaskMoves {
            task: TaskId(1),
            kept: vec![],
            gained: nodes(&[0, 1]),
            lost: vec![],
        };
        assert_eq!(fresh.gained_gpus(8, 12), 12, "a cold start moves every worker");
    }

    #[test]
    fn layout_json_round_trips_and_rejects_tampering() {
        let layout = Layout::new([
            (TaskId(0), nodes(&[0, 3])),
            (TaskId(2), nodes(&[])),
            (TaskId(7), nodes(&[1, 2, 9])),
        ]);
        let text = layout.to_value().encode();
        let back = Layout::from_value(&Value::parse(&text).unwrap()).unwrap();
        assert_eq!(back, layout);
        // non-array, bad node, repeated task, double-booked node: rejected
        assert!(Layout::from_value(&Value::obj()).is_err());
        let bad = text.replace("\"nodes\":[0,3]", "\"nodes\":[0,-3]");
        assert!(Layout::from_value(&Value::parse(&bad).unwrap()).is_err());
        let bad = text.replace("\"task\":2", "\"task\":0");
        assert!(Layout::from_value(&Value::parse(&bad).unwrap()).is_err());
        // node 1 already serves task 7: listing it under task 2 as well is
        // a corrupt map, not a decodable one
        let bad = text.replace("\"nodes\":[],\"task\":2", "\"nodes\":[1],\"task\":2");
        assert!(bad != text);
        assert!(Layout::from_value(&Value::parse(&bad).unwrap()).is_err());
        // ...and so is the same node twice within one task
        let bad = text.replace("\"nodes\":[0,3]", "\"nodes\":[0,0,3]");
        assert!(Layout::from_value(&Value::parse(&bad).unwrap()).is_err());
        // non-canonical ordering is rejected, not silently re-sorted — a
        // decode-then-reencode must reproduce the input bytes
        let bad = text.replace("\"nodes\":[0,3]", "\"nodes\":[3,0]");
        assert!(Layout::from_value(&Value::parse(&bad).unwrap()).is_err());
    }

    #[test]
    fn cached_assign_tracks_a_replan_chain_bit_identically() {
        // Scripted chain: cold start → node loss → join → demand shrink →
        // task departure → repeat call. Every step must equal the
        // from-scratch solver exactly, with `prev` always the previous
        // committed layout (the production replan chain).
        let gpn = 8u32;
        let npd = 4u32;
        let mut cache: Option<AssignCache> = None;
        let mut prev = Layout::default();
        let steps: Vec<(Vec<u32>, Vec<(TaskId, u32)>)> = vec![
            ((0..12).collect(), vec![(TaskId(0), 32), (TaskId(1), 32)]),
            // node 5 lost: task holding it must pull a replacement
            (vec![0, 1, 2, 3, 4, 6, 7, 8, 9, 10, 11], vec![(TaskId(0), 32), (TaskId(1), 32)]),
            // node 5 repaired + spare 12 joins
            ((0..13).collect(), vec![(TaskId(0), 32), (TaskId(1), 32)]),
            // task 0 shrinks (drops survivors), task 1 grows
            ((0..13).collect(), vec![(TaskId(0), 16), (TaskId(1), 48)]),
            // task 0 leaves the cluster entirely
            ((0..13).collect(), vec![(TaskId(1), 48)]),
            // steady state: identical inputs again
            ((0..13).collect(), vec![(TaskId(1), 48)]),
        ];
        for (ids, demands) in steps {
            let ns = nodes(&ids);
            let v = view(&ns, gpn, npd);
            let warm = assign_cached(&mut cache, &prev, &demands, &v);
            assert_eq!(warm, assign(&prev, &demands, &v), "demands {demands:?}");
            // the maintained pool must be exactly the unplaced placeables
            let c = cache.as_ref().unwrap();
            let placed: BTreeSet<NodeId> = warm.placed_nodes().collect();
            let expect: BTreeSet<NodeId> =
                ns.iter().copied().filter(|n| !placed.contains(n)).collect();
            let got: BTreeSet<NodeId> = c.free.values().flatten().copied().collect();
            assert_eq!(got, expect, "free-pool invariant");
            assert!(c.free.values().all(|s| !s.is_empty()), "no empty domain entries");
            prev = warm;
        }
    }

    #[test]
    fn cached_assign_cold_starts_on_geometry_or_history_changes() {
        // A cache built under one geometry must not poison a solve under
        // another, and an unrelated `prev` (not the cached result) must
        // fall back to a cold start — still equal to from-scratch.
        let ns = nodes(&[0, 1, 2, 3, 4, 5, 6, 7]);
        let mut cache: Option<AssignCache> = None;
        let demands = [(TaskId(0), 16), (TaskId(1), 16)];
        let v8 = view(&ns, 8, 4);
        let first = assign_cached(&mut cache, &Layout::default(), &demands, &v8);
        assert_eq!(first, assign(&Layout::default(), &demands, &v8));
        // same nodes, different domain geometry
        let v2 = view(&ns, 8, 2);
        let regrouped = assign_cached(&mut cache, &first, &demands, &v2);
        assert_eq!(regrouped, assign(&first, &demands, &v2));
        // a prev that is not the cached result (e.g. after a replayed log
        // truncated differently)
        let foreign = Layout::new([(TaskId(0), nodes(&[6, 7]))]);
        let cold = assign_cached(&mut cache, &foreign, &demands, &v2);
        assert_eq!(cold, assign(&foreign, &demands, &v2));
    }

    #[test]
    fn min_churn_matches_brute_force_matching_on_small_instances() {
        // The acceptance property: the solver's keep count equals the
        // brute-force maximum-keep matching, and the layout is well-formed.
        run(
            "placement_min_churn_vs_brute",
            Config { cases: 60, ..Default::default() },
            |rng: &mut Xoshiro256, _size| {
                let n_nodes = 2 + rng.below(4) as u32; // ≤ 5 nodes (brute force is 2^n per task)
                let npd = 1 + rng.below(3) as u32;
                let gpn = 1 + rng.below(8) as u32;
                let m = 1 + rng.below(3) as usize; // ≤ 3 tasks
                let all: Vec<u32> = (0..n_nodes).collect();
                // random disjoint previous sets + random survivor subset
                let mut pool: Vec<u32> = all.clone();
                rng.shuffle(&mut pool);
                let mut prev: Vec<(TaskId, Vec<NodeId>)> = Vec::new();
                for t in 0..m {
                    let take = rng.below(pool.len() as u64 + 1) as usize;
                    let picked: Vec<NodeId> =
                        pool.drain(..take).map(NodeId).collect();
                    prev.push((TaskId(t as u32), picked));
                }
                let healthy: Vec<u32> =
                    all.into_iter().filter(|_| rng.f64() < 0.8).collect();
                let demands: Vec<(TaskId, u32)> = (0..m)
                    .map(|t| (TaskId(t as u32), rng.below(gpn as u64 * 4) as u32))
                    .collect();
                (prev, healthy, demands, gpn, npd)
            },
            |(prev, healthy, demands, gpn, npd)| {
                let prev = Layout::new(prev.clone());
                let ns = nodes(healthy);
                let v = view(&ns, *gpn, *npd);
                let layout = assign(&prev, demands, &v);
                // well-formed: disjoint, placeable-only, demand-bounded
                let mut seen = BTreeSet::new();
                for (task, assigned) in layout.iter() {
                    let (_, w) = demands.iter().find(|(t, _)| *t == task).unwrap();
                    if assigned.len() > v.nodes_needed(*w) {
                        return Prop::Fail(format!("task {task} over-assigned"));
                    }
                    for n in assigned {
                        if !ns.contains(n) {
                            return Prop::Fail(format!("unplaceable node {n}"));
                        }
                        if !seen.insert(*n) {
                            return Prop::Fail(format!("node {n} double-booked"));
                        }
                    }
                }
                // deterministic
                if assign(&prev, demands, &v) != layout {
                    return Prop::Fail("nondeterministic assignment".into());
                }
                // min-churn: keep count equals the brute-force matching max
                let got = keeps_of(&layout, &prev);
                let best = brute_max_keeps(&prev, demands, &v);
                Prop::check(got == best, || {
                    format!("solver kept {got}, brute-force matching keeps {best}")
                })
            },
        );
    }
}
