//! Optimal reconfiguration plan generation (paper §5).
//!
//! Implements the WAF metric (Eq. 2), the reward `G(t, x→x')` with its
//! transition penalty (Eq. 3/4), the dynamic-programming solver over
//! `S(i,j) = max_k S(i-1, j-k) + G(t_i, k)` (Eq. 5) with traceback, and the
//! precomputed lookup table that gives O(1) plan retrieval when a failure
//! actually happens (§5.2).
//!
//! Every cost in this module is priced by the one ledger
//! ([`crate::cost::CostModel`], DESIGN.md §9): the opportunity horizon
//! `D_running(n)` comes from the ledger's effective MTBF, and each task pays
//! its *own* transition price — a [`crate::cost::TransitionProfile`] derived
//! from the §6.3 migration-time model, so moving a 13B task costs more than
//! moving a 1.3B task, and a faulted task (whose nearest replica died) pays
//! the in-memory-checkpoint path. Every solved [`Plan`] carries a
//! [`CostBreakdown`] reconciling its objective term-by-term.

use crate::config::{ClusterSpec, ModelSpec, TaskSpec};
use crate::cost::{CostBreakdown, CostModel, TransitionProfile};
use crate::perfmodel::throughput_table;
use crate::placement::Layout;
use crate::proto::WorkerCount;
use crate::transition::StateSource;

/// Everything the solver needs to know about one task.
///
/// `PartialEq` compares the *exact solve inputs* — spec (including the
/// worker ceiling), calibrated throughput table, transition profile,
/// current count, and fault flag — which is what the delta-refresh path
/// ([`ScenarioLookup::refresh_horizon`]) uses to prove a cached row is
/// bit-reusable.
#[derive(Debug, Clone, PartialEq)]
pub struct PlanTask {
    pub spec: TaskSpec,
    /// Calibrated `T(t, x)` table, FLOP/s, indexed by worker count
    /// (from [`crate::perfmodel::throughput_table`]).
    pub throughput: Vec<f64>,
    /// Per-strategy transition pricing for this task (§6.3 via the ledger).
    pub profile: TransitionProfile,
    /// Workers currently assigned (before reconfiguration).
    pub current: WorkerCount,
    /// True if one of this task's workers is the faulting one — forces the
    /// transition penalty even when the worker count stays the same (Eq. 4),
    /// and selects the faulted migration strategy in the profile.
    pub fault: bool,
    /// Which state tier this task restores from *if* faulted — resolved
    /// from snapshot-store residency ([`crate::transition::resolve_source`])
    /// when the store is live, [`StateSource::InMemoryCheckpoint`] as the
    /// cold-start default (the pre-store assumption, so pricing is
    /// unchanged until residency says otherwise).
    pub fault_source: StateSource,
    /// Measured restore time from the store's tier stats, seconds. `None`
    /// prices the fault through the §6.3 formula for `fault_source`.
    pub fault_restore_s: Option<f64>,
}

impl PlanTask {
    /// Build the planner input for `spec` on `cluster`: resolve the model,
    /// calibrate its `T(t, x)` table up to `max_workers`, and price its
    /// transition profile from the model's state size. The task starts
    /// unassigned and fault-free. Panics on an unknown model name
    /// (programmer error — specs come from the typed model zoo).
    pub fn from_spec(spec: &TaskSpec, cluster: &ClusterSpec, max_workers: u32) -> PlanTask {
        let model = ModelSpec::gpt3(&spec.model)
            .unwrap_or_else(|| panic!("unknown model {}", spec.model));
        PlanTask {
            throughput: throughput_table(&model, cluster, max_workers),
            profile: TransitionProfile::from_model(&model, cluster),
            spec: spec.clone(),
            current: WorkerCount(0),
            fault: false,
            fault_source: StateSource::InMemoryCheckpoint,
            fault_restore_s: None,
        }
    }

    /// WAF — Eq. 2: `F(t,x) = w(t)·T(t,x)` if `x` meets `T_necessary`, else 0.
    pub fn waf(&self, x: u32) -> f64 {
        if x < self.spec.min_workers {
            return 0.0;
        }
        let t = self.throughput.get(x as usize).copied().unwrap_or(0.0);
        if t <= 0.0 {
            return 0.0; // infeasible (memory wall) — requirement not met
        }
        self.spec.weight * t
    }

    /// WAF at the currently-committed worker count.
    pub fn current_waf(&self) -> f64 {
        self.waf(self.current.0)
    }

    /// Transition indicator — Eq. 4.
    pub fn transitions_to(&self, x_new: u32) -> bool {
        self.fault || x_new != self.current.0
    }
}

/// The produced plan: a worker count per task plus diagnostic totals and
/// the typed cost explanation.
#[derive(Debug, Clone, PartialEq)]
pub struct Plan {
    pub assignment: Vec<u32>,
    /// Σ G(tᵢ, xᵢ') — the DP objective (FLOP·s units: FLOP/s × seconds).
    /// Always equals `breakdown.objective()` exactly (same summation).
    pub objective: f64,
    /// Σ F(tᵢ, xᵢ') — cluster WAF after the plan is applied (FLOP/s).
    pub total_waf: f64,
    pub workers_used: u32,
    /// Term-by-term explanation of `objective` in the ledger's currency.
    pub breakdown: CostBreakdown,
    /// Concrete node-to-task map realizing `assignment` (wire v4). The
    /// solver leaves it empty — counts alone determine the optimum — and
    /// the coordinator fills it at commit time via the
    /// [`crate::placement`] min-churn solver, so a plan served from the
    /// precomputed table commits the exact layout a live solve would.
    /// Topology-blind policies (the §7 baselines) leave it empty.
    pub layout: Layout,
}

impl Plan {
    /// WAF-weighted transition duration estimate (seconds): the breakdown's
    /// transition penalty (FLOP·s) divided back by the cluster WAF the plan
    /// earns. This is the duration the penalty priced, so the telemetry
    /// timeline can report a recovery estimate without re-deriving §6.3
    /// migration times. Zero when the plan moves nothing or earns nothing.
    pub fn transition_seconds(&self) -> f64 {
        if self.total_waf > 0.0 {
            self.breakdown.transition_penalty / self.total_waf
        } else {
            0.0
        }
    }
}

/// One reward term `G(t, x')` given the task's hoisted penalty — THE
/// pricing expression. Every consumer (the DP inner loop, the brute-force
/// reference, the public [`reward`], and [`CostBreakdown`] via
/// `breakdown_for`'s algebraically-identical split) prices through this one
/// formula, so the optimized value and the reported explanation can never
/// drift apart.
#[inline]
fn term(t: &PlanTask, x: u32, horizon: f64, penalty: f64) -> f64 {
    t.waf(x) * horizon - if t.transitions_to(x) { penalty } else { 0.0 }
}

/// Reward `G(tᵢ, xᵢ → xᵢ')` — Eq. 3, priced by the ledger: the gain runs
/// over `cost.horizon_s(n_workers)` and the penalty is this task's own
/// transition price (`F(t, x) · d_transition(t)`) plus, for faulted tasks,
/// the Table 2 detection latency (work already lost before the coordinator
/// even learned of the failure).
pub fn reward(task: &PlanTask, x_new: u32, n_workers: u32, cost: &CostModel) -> f64 {
    let (trans, detect) = penalty_terms(task, cost);
    term(task, x_new, cost.horizon_s(n_workers), trans + detect)
}

/// A task's `(transition, detection)` penalty pair. Neither depends on the
/// candidate `x'` (the detection window is paid iff the task is faulted,
/// and a faulted task always transitions — Eq. 4), so both hoist out of
/// the DP inner loop and, being constant offsets, never change the argmax.
fn penalty_terms(t: &PlanTask, cost: &CostModel) -> (f64, f64) {
    let waf = t.current_waf();
    // A faulted task pays the restore path the store says it actually has
    // (tier + optional measured time); at the defaults
    // (`InMemoryCheckpoint`, no measurement) this is exactly the old
    // `transition_s(profile, true)` formula price.
    let trans_s = if t.fault {
        cost.transition_from_s(&t.profile, t.fault_source, t.fault_restore_s)
    } else {
        cost.transition_s(&t.profile, false)
    };
    (waf * trans_s, if t.fault { waf * cost.detection_s() } else { 0.0 })
}

/// Per-task penalty pairs hoisted out of the DP inner loop.
fn hoisted_penalties(tasks: &[PlanTask], cost: &CostModel) -> Vec<(f64, f64)> {
    tasks.iter().map(|t| penalty_terms(t, cost)).collect()
}

/// Build the [`CostBreakdown`] (and exact objective) for a final assignment.
fn breakdown_for(
    tasks: &[PlanTask],
    assignment: &[u32],
    penalties: &[(f64, f64)],
    horizon: f64,
    cost: &CostModel,
) -> CostBreakdown {
    let mut running = 0.0;
    let mut transition = 0.0;
    let mut detection = 0.0;
    for ((t, &x), &(trans, detect)) in tasks.iter().zip(assignment).zip(penalties) {
        running += t.waf(x) * horizon;
        if t.transitions_to(x) {
            transition += trans;
            detection += detect;
        }
    }
    // The plan's chosen restore tier: the first faulted task's resolved
    // source (a SEV1 replan faults exactly one task), DpReplica for
    // fault-free plans — the same default pre-v6 logs decode to.
    let state_source = tasks
        .iter()
        .find(|t| t.fault)
        .map(|t| t.fault_source)
        .unwrap_or(StateSource::DpReplica);
    CostBreakdown {
        running_reward: running,
        transition_penalty: transition,
        detection_penalty: detection,
        horizon_s: horizon,
        mtbf_per_gpu_s: cost.mtbf_per_gpu_s(),
        spare_value: 0.0,
        spare_hold_cost: 0.0,
        state_source,
    }
}

/// Solve Eq. 3 for `n_workers` available workers via the Eq. 5 DP.
///
/// Complexity O(m·W·K) where `W = min(n, Σ caps)` and `K = max cap`
/// (`cap_i` = the task's [`crate::config::TaskSpec::max_workers`] ceiling
/// clamped to the budget). Uncapped tasks give `W = K = n` — the classic
/// O(m·n²) of §5.2 — and in that case the row layout, the candidate
/// iteration order, and therefore every tie-break and output bit are
/// identical to the uncapped DP. With ceilings, budget beyond `Σ caps` can
/// never be spent, so DP rows stay `Σ caps` wide no matter how large the
/// fleet is — this is what keeps replanning affordable at 16k/64k nodes.
pub fn solve(tasks: &[PlanTask], n_workers: u32, cost: &CostModel) -> Plan {
    let n = n_workers as usize;
    let m = tasks.len();
    let horizon = cost.horizon_s(n_workers);
    let penalties = hoisted_penalties(tasks, cost);

    // Per-task ceilings and cumulative row widths. Row `i` is constant
    // ("saturated") for budgets ≥ widths[i] = min(n, Σ_{i'≤i} cap_{i'}),
    // so reads past a row's stored width clamp to its last cell — exactly
    // equal to the full-width DP (the saturated cells all hold the same
    // value and the same first-argmax choice).
    let caps: Vec<usize> = tasks.iter().map(|t| (t.spec.max_workers as usize).min(n)).collect();
    let mut widths = Vec::with_capacity(m + 1);
    widths.push(0usize);
    for &cap in &caps {
        let prev = *widths.last().expect("widths starts non-empty");
        widths.push(n.min(prev + cap));
    }

    // S[i][j]: best value of first i tasks with j workers; choice[i][j] = k.
    let mut s: Vec<Vec<f64>> = vec![vec![0.0f64]];
    let mut choice: Vec<Vec<u32>> = vec![vec![0u32]];
    for i in 1..=m {
        let t = &tasks[i - 1];
        let cap = caps[i - 1];
        let (w, w_prev) = (widths[i], widths[i - 1]);
        let pen = penalties[i - 1].0 + penalties[i - 1].1;
        let prev_row = &s[i - 1];
        let mut row = vec![0.0f64; w + 1];
        let mut crow = vec![0u32; w + 1];
        // G(t, 0) may be negative (losing a running task still pays its
        // penalty) but assigning zero is always *allowed*.
        for j in 0..=w {
            let mut best = f64::NEG_INFINITY;
            let mut best_k = 0;
            for k in 0..=j.min(cap) {
                let x = k as u32;
                let v = prev_row[(j - k).min(w_prev)] + term(t, x, horizon, pen);
                if v > best {
                    best = v;
                    best_k = x;
                }
            }
            row[j] = best;
            crow[j] = best_k;
        }
        s.push(row);
        choice.push(crow);
    }

    // Traceback from S(m, n); budgets past a row's width read its
    // saturated last cell.
    let mut assignment = vec![0u32; m];
    let mut j = n;
    for i in (1..=m).rev() {
        let k = choice[i][j.min(widths[i])];
        assignment[i - 1] = k;
        j -= k as usize;
    }

    let total_waf = tasks.iter().zip(&assignment).map(|(t, &x)| t.waf(x)).sum();
    let workers_used = assignment.iter().sum();
    let breakdown = breakdown_for(tasks, &assignment, &penalties, horizon, cost);
    let objective = breakdown.objective();
    Plan { assignment, objective, total_waf, workers_used, breakdown, layout: Layout::default() }
}

/// Brute-force reference solver (exponential; tests only — DESIGN.md §11).
pub fn solve_brute(tasks: &[PlanTask], n_workers: u32, cost: &CostModel) -> Plan {
    let horizon = cost.horizon_s(n_workers);
    let penalties = hoisted_penalties(tasks, cost);
    let m = tasks.len();
    let mut best_assign = vec![0u32; m];
    let mut best_val = f64::NEG_INFINITY;
    let mut assign = vec![0u32; m];

    fn rec(
        i: usize,
        left: u32,
        tasks: &[PlanTask],
        horizon: f64,
        penalties: &[(f64, f64)],
        assign: &mut Vec<u32>,
        best_val: &mut f64,
        best_assign: &mut Vec<u32>,
    ) {
        if i == tasks.len() {
            let v: f64 = tasks
                .iter()
                .zip(assign.iter())
                .zip(penalties.iter())
                .map(|((t, &x), &(trans, detect))| term(t, x, horizon, trans + detect))
                .sum();
            if v > *best_val {
                *best_val = v;
                best_assign.clone_from(assign);
            }
            return;
        }
        for k in 0..=left.min(tasks[i].spec.max_workers) {
            assign[i] = k;
            rec(i + 1, left - k, tasks, horizon, penalties, assign, best_val, best_assign);
        }
        assign[i] = 0;
    }
    rec(0, n_workers, tasks, horizon, &penalties, &mut assign, &mut best_val, &mut best_assign);

    let total_waf = tasks.iter().zip(&best_assign).map(|(t, &x)| t.waf(x)).sum();
    let workers_used = best_assign.iter().sum();
    let breakdown = breakdown_for(tasks, &best_assign, &penalties, horizon, cost);
    let objective = breakdown.objective();
    Plan {
        assignment: best_assign,
        objective,
        total_waf,
        workers_used,
        breakdown,
        layout: Layout::default(),
    }
}

/// Precomputed lookup table (§5.2): plans for every cluster size the next
/// event could leave us with, so dispatch on failure/join is O(1).
#[derive(Debug)]
pub struct PlanLookup {
    /// plans[j] = plan for a cluster of j available workers.
    plans: Vec<Plan>,
}

impl PlanLookup {
    /// Precompute plans for all worker counts 0..=max_workers.
    ///
    /// The paper precomputes "potential failure scenarios of any task or
    /// joining node"; sizes n'−k (failures) and n'+k (joins) cover those —
    /// we simply cover the full range.
    pub fn precompute(tasks: &[PlanTask], max_workers: u32, cost: &CostModel) -> PlanLookup {
        let plans = (0..=max_workers).map(|n| solve(tasks, n, cost)).collect();
        PlanLookup { plans }
    }

    /// O(1) retrieval.
    pub fn plan_for(&self, n_workers: u32) -> &Plan {
        &self.plans[(n_workers as usize).min(self.plans.len() - 1)]
    }

    pub fn max_workers(&self) -> u32 {
        (self.plans.len() - 1) as u32
    }
}

/// Fault-aware precomputed plan table (§5.2): one [`Plan`] per
/// `(faulted task, available workers)` scenario, so the coordinator's SEV1
/// hot path is a table index instead of an O(m·n²) solve.
///
/// [`PlanLookup`] covers the "cluster shrank/grew" axis only; a SEV1 replan
/// additionally flags the affected task as faulted (Eq. 4 forces its
/// transition penalty even at an unchanged worker count), which changes the
/// optimum. This table enumerates both axes, in one of two shapes:
///
/// * [`ScenarioLookup::precompute`] — the **full grid**, every fault × every
///   worker count `0..=max`. O((m+1)·n·m·n²) to build; the live driver runs
///   it on a background worker thread.
/// * [`ScenarioLookup::precompute_horizon`] — the **event horizon**: exactly
///   the scenarios one event away from the current state (a SEV1/quarantine
///   shrinking the pool by one node with any task faulted, a join growing
///   it, a same-size replan). Only m+3 solves, cheap enough that the
///   simulator rebuilds it after *every* decision, so simulated SEV1s
///   exercise the same table path production does.
///
/// Either table is valid for exactly one snapshot of
/// `(current assignments, fault-free task set, cost model)` — any commit of
/// new assignments *or* a tightened MTBF estimate invalidates it, after
/// which the owner recomputes (the paper's "proactive plan generation").
/// Entries are produced by the same [`solve`] a cold replan would run, so a
/// table hit and a live solve are bit-identical —
/// `rust/tests/sim_unification.rs` pins this.
#[derive(Debug, Clone)]
pub struct ScenarioLookup {
    grid: Grid,
}

#[derive(Debug, Clone)]
enum Grid {
    /// plans[f][j]: plan for `j` available workers with task `f-1` faulted
    /// (`f = 0` means no task faulted — joins, launches, finishes).
    Full(Vec<Vec<Plan>>),
    /// Exact next-event scenarios only, keyed `(fault row, capacity)`.
    Sparse {
        n_tasks: usize,
        max_workers: u32,
        plans: std::collections::BTreeMap<(usize, u32), Plan>,
    },
}

/// Snapshot of the solve inputs a [`ScenarioLookup`] was built from, used
/// by [`ScenarioLookup::refresh_horizon`] to prove which rows of a previous
/// table are bit-reusable. Holds the *fault-cleared* task vector (fault
/// flags are part of the row key, not the snapshot — but the restore-source
/// fields stay, so a store-residency change honestly invalidates every
/// row) and the cost model;
/// `available`/`gpn` are deliberately absent — rows are keyed by absolute
/// capacity, so a membership change reuses whatever keys still overlap.
#[derive(Debug, Clone, PartialEq)]
pub struct HorizonInputs {
    tasks: Vec<PlanTask>,
    cost: CostModel,
}

impl HorizonInputs {
    /// Capture the snapshot a table built from `(tasks, cost)` depends on.
    pub fn capture(tasks: &[PlanTask], cost: &CostModel) -> HorizonInputs {
        let mut tasks = tasks.to_vec();
        for t in &mut tasks {
            t.fault = false;
        }
        HorizonInputs { tasks, cost: cost.clone() }
    }
}

/// How a [`ScenarioLookup::refresh_horizon`] call split its m+3 rows.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub struct RefreshStats {
    /// Rows copied bit-for-bit from the previous table.
    pub reused: usize,
    /// Rows recomputed by a live [`solve`].
    pub solved: usize,
}

impl ScenarioLookup {
    /// Precompute plans for every fault scenario × worker count 0..=max.
    ///
    /// O((m+1)·n·m·n²) total — expensive, which is exactly why it runs off
    /// the failure path (between events), not on it.
    pub fn precompute(tasks: &[PlanTask], max_workers: u32, cost: &CostModel) -> ScenarioLookup {
        let mut scenario: Vec<PlanTask> = tasks.to_vec();
        for t in &mut scenario {
            t.fault = false;
        }
        let mut plans = Vec::with_capacity(tasks.len() + 1);
        for f in 0..=tasks.len() {
            if f > 0 {
                scenario[f - 1].fault = true;
            }
            plans.push((0..=max_workers).map(|n| solve(&scenario, n, cost)).collect());
            if f > 0 {
                scenario[f - 1].fault = false;
            }
        }
        ScenarioLookup { grid: Grid::Full(plans) }
    }

    /// Precompute only the scenarios reachable one event from `available`
    /// workers: the no-fault row at `available − gpn` / `available` /
    /// `available + gpn` (node loss of an idle node, same-size replan,
    /// join) plus every faulted task at `available − gpn` (a SEV1 or a
    /// lemon quarantine always costs one node and faults one task).
    ///
    /// m+3 [`solve`] calls instead of the full grid's (m+1)·(n+1).
    pub fn precompute_horizon(
        tasks: &[PlanTask],
        available: u32,
        gpn: u32,
        cost: &CostModel,
    ) -> ScenarioLookup {
        Self::refresh_horizon(tasks, available, gpn, cost, None).0
    }

    /// Delta-maintained event-horizon table: rebuild the m+3 scenario rows,
    /// but copy any row whose exact solve inputs are unchanged from a
    /// previous `(inputs, table)` snapshot instead of re-solving it.
    ///
    /// A row is reusable iff the previous snapshot was captured over a
    /// bit-equal fault-cleared task vector and a bit-equal [`CostModel`] —
    /// which are the *only* inputs to [`solve`] besides the worker count
    /// already encoded in the row key. So reuse is exact: a copied row is
    /// the row a fresh [`precompute_horizon`] would have produced, bit for
    /// bit (`tests/properties.rs` pins this against randomized event
    /// sequences).
    ///
    /// What each kind of change costs:
    /// * **membership change** (node lost/joined/repaired): `available`
    ///   shifts by one node's workers, so the three no-fault keys overlap
    ///   the previous three in ≤ 2 entries and every fault row moves to a
    ///   new `lo` — typically 1–2 of m+3 rows reused. When `available` is
    ///   unchanged (same-size replan after a launch confirm), all m+3 rows
    ///   reuse and the refresh is free.
    /// * **MTBF estimate update**: every row's [`crate::cost::CostBreakdown`]
    ///   stamps `mtbf_per_gpu_s` and the horizon, so under bit-equality *no*
    ///   row survives a cost change — the refresh honestly degrades to the
    ///   full m+3 solves rather than serving stale economics.
    /// * **task set / assignment commit**: the fault-cleared vector differs
    ///   (different `current` counts), zero reuse — correct, because every
    ///   row's transition penalties depend on the currents.
    ///
    /// [`precompute_horizon`]: ScenarioLookup::precompute_horizon
    pub fn refresh_horizon(
        tasks: &[PlanTask],
        available: u32,
        gpn: u32,
        cost: &CostModel,
        prev: Option<(&HorizonInputs, &ScenarioLookup)>,
    ) -> (ScenarioLookup, RefreshStats) {
        let mut scenario: Vec<PlanTask> = tasks.to_vec();
        for t in &mut scenario {
            t.fault = false;
        }
        let reusable = prev.filter(|(inp, _)| inp.cost == *cost && inp.tasks == scenario);
        let mut stats = RefreshStats::default();
        let mut reuse_or_solve = |table_row: Option<&Plan>, scenario: &[PlanTask], w: u32| {
            match table_row {
                Some(p) => {
                    stats.reused += 1;
                    p.clone()
                }
                None => {
                    stats.solved += 1;
                    solve(scenario, w, cost)
                }
            }
        };
        let lo = available.saturating_sub(gpn);
        let hi = available + gpn;
        let mut plans = std::collections::BTreeMap::new();
        for w in [lo, available, hi] {
            if !plans.contains_key(&(0usize, w)) {
                let row = reusable.and_then(|(_, t)| t.get(None, w));
                plans.insert((0usize, w), reuse_or_solve(row, &scenario, w));
            }
        }
        for f in 1..=tasks.len() {
            scenario[f - 1].fault = true;
            let row = reusable.and_then(|(_, t)| t.get(Some(f - 1), lo));
            let plan = reuse_or_solve(row, &scenario, lo);
            plans.insert((f, lo), plan);
            scenario[f - 1].fault = false;
        }
        let lookup =
            ScenarioLookup { grid: Grid::Sparse { n_tasks: tasks.len(), max_workers: hi, plans } };
        (lookup, stats)
    }

    fn fault_row(&self, faulted: Option<usize>) -> Option<usize> {
        match faulted {
            None => Some(0),
            Some(i) if i < self.n_tasks() => Some(i + 1),
            Some(_) => None,
        }
    }

    /// Exact O(1) retrieval — `None` when the scenario was not precomputed
    /// (sparse table miss, capacity beyond the grid, stale fault index).
    /// Callers fall back to a live [`solve`] on `None`; no clamping ever
    /// substitutes a plan for a different scenario.
    pub fn get(&self, faulted: Option<usize>, n_workers: u32) -> Option<&Plan> {
        let f = self.fault_row(faulted)?;
        match &self.grid {
            Grid::Full(plans) => plans[f].get(n_workers as usize),
            Grid::Sparse { plans, .. } => plans.get(&(f, n_workers)),
        }
    }

    /// True when the exact scenario is in the table.
    pub fn covers(&self, faulted: Option<usize>, n_workers: u32) -> bool {
        self.get(faulted, n_workers).is_some()
    }

    /// O(1) retrieval with clamping semantics (full grids): worker counts
    /// above the precomputed range clamp to the largest table entry; a fault
    /// index outside the table (caller holds a stale table for a different
    /// task set) falls back to the no-fault row rather than charging the
    /// penalty to an arbitrary task. Sparse tables have no meaningful clamp
    /// — use [`ScenarioLookup::get`] there (this panics on a sparse miss).
    pub fn plan_for(&self, faulted: Option<usize>, n_workers: u32) -> &Plan {
        let f = match self.fault_row(faulted) {
            Some(f) => f,
            None => {
                debug_assert!(false, "fault index out of range for this table");
                0
            }
        };
        match &self.grid {
            Grid::Full(plans) => {
                let row = &plans[f];
                &row[(n_workers as usize).min(row.len() - 1)]
            }
            Grid::Sparse { plans, max_workers, .. } => plans
                .get(&(f, n_workers))
                .or_else(|| plans.get(&(f, n_workers.min(*max_workers))))
                .unwrap_or_else(|| {
                    panic!("scenario (fault {faulted:?}, {n_workers} workers) not precomputed")
                }),
        }
    }

    pub fn max_workers(&self) -> u32 {
        match &self.grid {
            Grid::Full(plans) => (plans[0].len() - 1) as u32,
            Grid::Sparse { max_workers, .. } => *max_workers,
        }
    }

    /// Number of task slots this table was built for.
    pub fn n_tasks(&self) -> usize {
        match &self.grid {
            Grid::Full(plans) => plans.len() - 1,
            Grid::Sparse { n_tasks, .. } => *n_tasks,
        }
    }
}

/// Baseline allocation strategies from §7.4's Fig. 10c comparison.
pub mod baselines {
    use super::PlanTask;

    /// Largest-remainder apportionment of `n` workers proportional to `score`,
    /// respecting each task's minimum; returns worker counts.
    fn proportional(tasks: &[PlanTask], n: u32, score: impl Fn(&PlanTask) -> f64) -> Vec<u32> {
        let total: f64 = tasks.iter().map(&score).sum();
        if total <= 0.0 {
            return vec![0; tasks.len()];
        }
        let ideal: Vec<f64> = tasks.iter().map(|t| score(t) / total * n as f64).collect();
        let mut alloc: Vec<u32> = ideal.iter().map(|x| x.floor() as u32).collect();
        let mut left = n - alloc.iter().sum::<u32>();
        // distribute remainders by largest fraction
        let mut order: Vec<usize> = (0..tasks.len()).collect();
        order.sort_by(|&a, &b| {
            (ideal[b] - ideal[b].floor()).partial_cmp(&(ideal[a] - ideal[a].floor())).unwrap()
        });
        for &i in order.iter().cycle() {
            if left == 0 {
                break;
            }
            alloc[i] += 1;
            left -= 1;
        }
        alloc
    }

    /// "equally": even split regardless of task shape.
    pub fn equally(tasks: &[PlanTask], n: u32) -> Vec<u32> {
        proportional(tasks, n, |_| 1.0)
    }

    /// "weighted": proportional to w(t).
    pub fn weighted(tasks: &[PlanTask], n: u32) -> Vec<u32> {
        proportional(tasks, n, |t| t.spec.weight)
    }

    /// "sized": proportional to model size (min_workers as its proxy here is
    /// too coarse; use the first feasible throughput point's memory need —
    /// we approximate with min_workers which tracks model size).
    pub fn sized(tasks: &[PlanTask], n: u32, sizes: &[f64]) -> Vec<u32> {
        let sizes = sizes.to_vec();
        proportional(tasks, n, move |t| sizes[t.spec.id.0 as usize])
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{TaskSpec, UnicronConfig};

    /// Synthetic concave-ish throughput: T(x) = s·x^0.9 above min, 0 below.
    /// The flat 5 s profile plus the 55 s base overhead reproduces the
    /// pre-ledger 60 s flat transition cost.
    fn task(id: u32, weight: f64, min: u32, scale: f64, current: u32, fault: bool, n: u32) -> PlanTask {
        let throughput = (0..=n)
            .map(|x| if x >= min { scale * (x as f64).powf(0.9) } else { 0.0 })
            .collect();
        PlanTask {
            spec: TaskSpec::new(id, "synthetic", weight, min),
            throughput,
            profile: TransitionProfile::flat(5.0),
            current: WorkerCount(current),
            fault,
            fault_source: StateSource::InMemoryCheckpoint,
            fault_restore_s: None,
        }
    }

    fn cost() -> CostModel {
        CostModel::from_config(&UnicronConfig {
            transition_base_s: 55.0,
            mtbf_per_gpu_s: 1e6,
            ..Default::default()
        })
    }

    #[test]
    fn waf_zero_below_minimum() {
        let t = task(0, 1.5, 4, 10.0, 0, false, 16);
        assert_eq!(t.waf(3), 0.0);
        assert!(t.waf(4) > 0.0);
        assert_eq!(t.waf(4), 1.5 * 10.0 * 4f64.powf(0.9));
    }

    #[test]
    fn dp_matches_brute_force_small() {
        let tasks = vec![
            task(0, 1.0, 2, 10.0, 4, false, 12),
            task(1, 2.0, 3, 8.0, 4, true, 12),
            task(2, 0.5, 1, 20.0, 4, false, 12),
        ];
        for n in [0u32, 3, 7, 12] {
            let dp = solve(&tasks, n, &cost());
            let bf = solve_brute(&tasks, n, &cost());
            assert!((dp.objective - bf.objective).abs() < 1e-6 * bf.objective.abs().max(1.0),
                    "n={n}: dp {} vs brute {}", dp.objective, bf.objective);
        }
    }

    #[test]
    fn constraint_respected() {
        let tasks = vec![task(0, 1.0, 1, 5.0, 0, false, 32), task(1, 1.0, 1, 5.0, 0, false, 32)];
        let plan = solve(&tasks, 9, &cost());
        assert!(plan.workers_used <= 9);
        assert_eq!(plan.assignment.iter().sum::<u32>(), plan.workers_used);
    }

    #[test]
    fn transition_penalty_discourages_churn() {
        // Healthy task at its optimum; a second task could marginally gain by
        // stealing one worker, but the penalty should block the reshuffle.
        let n = 16u32;
        let healthy = task(0, 1.0, 1, 10.0, 8, false, n);
        let greedy = task(1, 1.0, 1, 10.1, 8, false, n);
        let pricey = CostModel::from_config(&UnicronConfig {
            transition_base_s: 1e5, // huge transition cost
            mtbf_per_gpu_s: 1e6,
            ..Default::default()
        });
        let plan = solve(&[healthy, greedy], n, &pricey);
        assert_eq!(plan.assignment, vec![8, 8], "penalty should keep the status quo");
    }

    #[test]
    fn per_task_profiles_steer_which_task_moves() {
        // Two identical tasks, one cheap to migrate and one expensive; when
        // the pool comes up one worker short, the solver shrinks the cheap
        // one — exactly the per-task pricing the flat global cost lost.
        let n = 16u32;
        let mut cheap = task(0, 1.0, 1, 10.0, 8, false, n);
        cheap.profile = TransitionProfile::flat(0.0);
        let mut dear = task(1, 1.0, 1, 10.0, 8, false, n);
        dear.profile = TransitionProfile::flat(1e5);
        let plan = solve(&[cheap, dear], 15, &cost());
        assert_eq!(plan.assignment, vec![7, 8], "the cheap-to-move task gives up the worker");
    }

    #[test]
    fn faulted_task_pays_penalty_even_when_size_unchanged() {
        let t_ok = task(0, 1.0, 1, 10.0, 8, false, 16);
        let t_bad = task(1, 1.0, 1, 10.0, 8, true, 16);
        let c = cost();
        let g_ok = reward(&t_ok, 8, 16, &c);
        let g_bad = reward(&t_bad, 8, 16, &c);
        assert!(g_bad < g_ok);
    }

    #[test]
    fn faulted_transition_prices_the_farther_strategy_plus_detection() {
        // Same heterogeneous profile; the faulted twin pays inmem_s instead
        // of replica_s, plus the Table 2 detection window, so its reward is
        // strictly lower at every size.
        let profile =
            TransitionProfile { replica_s: 2.0, inmem_s: 40.0, local_s: 80.0, remote_s: 300.0 };
        let mut healthy = task(0, 1.0, 1, 10.0, 8, false, 16);
        healthy.profile = profile.clone();
        let mut faulted = healthy.clone();
        faulted.fault = true;
        let c = cost();
        // both transition when resizing to 6 — only the strategy (and the
        // fault's detection latency) differs
        let diff = reward(&healthy, 6, 16, &c) - reward(&faulted, 6, 16, &c);
        let expected =
            healthy.current_waf() * (profile.inmem_s - profile.replica_s + c.detection_s());
        assert!((diff - expected).abs() < 1e-6 * expected, "diff {diff} vs {expected}");
    }

    #[test]
    fn breakdown_reconciles_to_the_objective() {
        let tasks = vec![
            task(0, 1.0, 2, 10.0, 4, false, 16),
            task(1, 1.3, 2, 9.0, 6, true, 16),
            task(2, 0.7, 4, 12.0, 4, false, 16),
        ];
        let c = cost();
        for n in [0u32, 8, 12, 16] {
            let plan = solve(&tasks, n, &c);
            let b = &plan.breakdown;
            assert_eq!(b.objective(), plan.objective, "exact by construction (n={n})");
            assert_eq!(b.horizon_s, c.horizon_s(n));
            assert_eq!(b.mtbf_per_gpu_s, c.mtbf_per_gpu_s());
            assert_eq!(b.spare_value, 0.0);
            // manual recomputation of all three terms
            let running: f64 =
                tasks.iter().zip(&plan.assignment).map(|(t, &x)| t.waf(x) * b.horizon_s).sum();
            let penalty: f64 = tasks
                .iter()
                .zip(&plan.assignment)
                .filter(|(t, &x)| t.transitions_to(x))
                .map(|(t, _)| t.current_waf() * c.transition_s(&t.profile, t.fault))
                .sum();
            let detection: f64 = tasks
                .iter()
                .filter(|t| t.fault)
                .map(|t| t.current_waf() * c.detection_s())
                .sum();
            assert!((b.running_reward - running).abs() <= 1e-9 * running.abs().max(1.0));
            assert!((b.transition_penalty - penalty).abs() <= 1e-9 * penalty.abs().max(1.0));
            assert!((b.detection_penalty - detection).abs() <= 1e-9 * detection.abs().max(1.0));
            // the faulted task (task 1) resolves to the default in-memory
            // checkpoint tier, and the breakdown records the choice
            assert_eq!(b.state_source, StateSource::InMemoryCheckpoint, "n={n}");
        }
        // fault-free plans stamp the replica source
        let quiet: Vec<PlanTask> = tasks
            .iter()
            .cloned()
            .map(|mut t| {
                t.fault = false;
                t
            })
            .collect();
        assert_eq!(solve(&quiet, 8, &c).breakdown.state_source, StateSource::DpReplica);
    }

    #[test]
    fn measured_restore_reprices_the_faulted_penalty() {
        // Same faulted task, three pricings: formula inmem (default), formula
        // local disk (residency resolved a slower tier), and a measured
        // sub-second peer restore. The reward must move with the price.
        let base = task(0, 1.0, 1, 10.0, 8, true, 16);
        let profile =
            TransitionProfile { replica_s: 2.0, inmem_s: 40.0, local_s: 80.0, remote_s: 300.0 };
        let c = cost();
        let mut inmem = base.clone();
        inmem.profile = profile.clone();
        let mut local = inmem.clone();
        local.fault_source = StateSource::LocalDiskCheckpoint;
        let mut measured = inmem.clone();
        measured.fault_restore_s = Some(0.4);
        let (g_in, g_loc, g_meas) =
            (reward(&inmem, 6, 16, &c), reward(&local, 6, 16, &c), reward(&measured, 6, 16, &c));
        assert!(g_loc < g_in, "farther tier must cost more: {g_loc} vs {g_in}");
        assert!(g_meas > g_in, "a measured fast restore must cost less: {g_meas} vs {g_in}");
        let waf = inmem.current_waf();
        assert!((g_in - g_loc - waf * (profile.local_s - profile.inmem_s)).abs() < 1e-6);
        assert!((g_meas - g_in - waf * (profile.inmem_s - 0.4)).abs() < 1e-6);
    }

    #[test]
    fn weights_steer_allocation() {
        let n = 10u32;
        // identical tasks except weight; the heavier one must get ≥ workers.
        let tasks =
            vec![task(0, 0.5, 1, 10.0, 0, false, n), task(1, 2.0, 1, 10.0, 0, false, n)];
        let plan = solve(&tasks, n, &cost());
        assert!(plan.assignment[1] >= plan.assignment[0]);
    }

    #[test]
    fn lookup_table_consistent_with_solve() {
        let tasks = vec![
            task(0, 1.0, 2, 10.0, 4, false, 16),
            task(1, 1.3, 2, 9.0, 6, false, 16),
        ];
        let c = cost();
        let lut = PlanLookup::precompute(&tasks, 16, &c);
        for n in [0u32, 5, 11, 16] {
            assert_eq!(lut.plan_for(n).assignment, solve(&tasks, n, &c).assignment, "n={n}");
        }
        assert_eq!(lut.max_workers(), 16);
        // out-of-range clamps
        assert_eq!(lut.plan_for(99).assignment, solve(&tasks, 16, &c).assignment);
    }

    #[test]
    fn scenario_lookup_matches_fresh_solves_per_fault() {
        let tasks = vec![
            task(0, 1.0, 2, 10.0, 6, false, 16),
            task(1, 1.3, 2, 9.0, 6, false, 16),
            task(2, 0.7, 4, 12.0, 4, false, 16),
        ];
        let c = cost();
        let lut = ScenarioLookup::precompute(&tasks, 16, &c);
        assert_eq!(lut.max_workers(), 16);
        assert_eq!(lut.n_tasks(), 3);
        for faulted in [None, Some(0), Some(1), Some(2)] {
            let mut scenario = tasks.clone();
            if let Some(i) = faulted {
                scenario[i].fault = true;
            }
            for n in [0u32, 7, 8, 15, 16] {
                let fresh = solve(&scenario, n, &c);
                let looked = lut.plan_for(faulted, n);
                assert_eq!(looked.assignment, fresh.assignment, "fault {faulted:?} n={n}");
                assert!((looked.objective - fresh.objective).abs() <= 1e-9 * fresh.objective.abs().max(1.0));
            }
        }
        // clamping on both axes
        assert_eq!(lut.plan_for(None, 99).assignment, solve(&tasks, 16, &c).assignment);
    }

    #[test]
    fn scenario_lookup_fault_axis_changes_the_plan_when_it_should() {
        // A faulted task pays the transition penalty regardless, so with a
        // huge transition cost the optimum can shift relative to the
        // no-fault scenario at the same worker count.
        let tasks = vec![
            task(0, 1.0, 1, 10.0, 8, false, 16),
            task(1, 1.0, 1, 10.0, 8, false, 16),
        ];
        let pricey = CostModel::from_config(&UnicronConfig {
            transition_base_s: 1e5,
            mtbf_per_gpu_s: 1e6,
            ..Default::default()
        });
        let lut = ScenarioLookup::precompute(&tasks, 16, &pricey);
        let no_fault = lut.plan_for(None, 16);
        assert_eq!(no_fault.assignment, vec![8, 8], "status quo is optimal unfaulted");
        // fault scenarios must at minimum reproduce the dedicated solve
        for i in 0..2 {
            let mut scenario = tasks.clone();
            scenario[i].fault = true;
            assert_eq!(
                lut.plan_for(Some(i), 16).assignment,
                solve(&scenario, 16, &pricey).assignment
            );
        }
    }

    #[test]
    fn horizon_table_matches_fresh_solves_for_next_event_scenarios() {
        let tasks = vec![
            task(0, 1.0, 2, 10.0, 6, false, 32),
            task(1, 1.3, 2, 9.0, 6, false, 32),
            task(2, 0.7, 4, 12.0, 4, false, 32),
        ];
        let c = cost();
        let (avail, gpn) = (24u32, 8u32);
        let lut = ScenarioLookup::precompute_horizon(&tasks, avail, gpn, &c);
        assert_eq!(lut.n_tasks(), 3);
        assert_eq!(lut.max_workers(), avail + gpn);
        // no-fault scenarios: loss / same / join capacities
        for w in [avail - gpn, avail, avail + gpn] {
            let fresh = solve(&tasks, w, &c);
            let got = lut.get(None, w).unwrap_or_else(|| panic!("horizon must cover w={w}"));
            assert_eq!(got, &fresh);
        }
        // every fault at the one-node-short capacity
        for f in 0..tasks.len() {
            let mut scenario = tasks.clone();
            scenario[f].fault = true;
            let fresh = solve(&scenario, avail - gpn, &c);
            assert_eq!(lut.get(Some(f), avail - gpn), Some(&fresh), "fault {f}");
        }
        // anything else is an honest miss (caller re-solves), never a clamp
        assert!(lut.get(None, avail - 2 * gpn).is_none());
        assert!(lut.get(Some(0), avail).is_none());
        assert!(lut.get(Some(9), avail - gpn).is_none(), "stale fault index");
        assert!(lut.covers(None, avail) && !lut.covers(None, 1));
    }

    #[test]
    fn full_grid_get_is_exact_while_plan_for_clamps() {
        let tasks =
            vec![task(0, 1.0, 2, 10.0, 4, false, 16), task(1, 1.3, 2, 9.0, 6, false, 16)];
        let c = cost();
        let lut = ScenarioLookup::precompute(&tasks, 16, &c);
        assert!(lut.get(None, 16).is_some());
        assert!(lut.get(None, 17).is_none(), "get never clamps");
        assert_eq!(lut.plan_for(None, 99).assignment, solve(&tasks, 16, &c).assignment);
    }

    #[test]
    fn baseline_allocations_sum_to_n() {
        let n = 13u32;
        let tasks = vec![
            task(0, 0.5, 1, 10.0, 0, false, n),
            task(1, 1.0, 1, 10.0, 0, false, n),
            task(2, 2.0, 1, 10.0, 0, false, n),
        ];
        for alloc in [
            baselines::equally(&tasks, n),
            baselines::weighted(&tasks, n),
            baselines::sized(&tasks, n, &[1.0, 2.0, 4.0]),
        ] {
            assert_eq!(alloc.iter().sum::<u32>(), n, "{alloc:?}");
        }
        let w = baselines::weighted(&tasks, n);
        assert!(w[2] > w[0]);
    }

    #[test]
    fn capped_dp_matches_brute_force() {
        // Worker ceilings clamp DP row widths; the clamped reads must stay
        // exactly optimal, including when caps bind, don't bind, or are 0.
        let mut tasks = vec![
            task(0, 1.0, 2, 10.0, 4, false, 12),
            task(1, 2.0, 3, 8.0, 4, true, 12),
            task(2, 0.5, 1, 20.0, 4, false, 12),
        ];
        tasks[0].spec = tasks[0].spec.clone().with_max_workers(3);
        tasks[1].spec = tasks[1].spec.clone().with_max_workers(5);
        for n in [0u32, 3, 7, 12] {
            let dp = solve(&tasks, n, &cost());
            let bf = solve_brute(&tasks, n, &cost());
            assert_eq!(dp.assignment, bf.assignment, "n={n}");
            assert!((dp.objective - bf.objective).abs() < 1e-6 * bf.objective.abs().max(1.0));
        }
        tasks[2].spec = tasks[2].spec.clone().with_max_workers(0);
        let dp = solve(&tasks, 12, &cost());
        assert_eq!(dp.assignment[2], 0, "cap 0 forbids any allocation");
        assert_eq!(dp.assignment, solve_brute(&tasks, 12, &cost()).assignment);
    }

    #[test]
    fn caps_above_the_budget_never_change_the_plan() {
        // A ceiling ≥ n is vacuous: row widths all equal n, so the capped
        // DP runs the exact classic recurrence, bit for bit.
        let tasks = vec![
            task(0, 1.0, 2, 10.0, 6, false, 16),
            task(1, 1.3, 2, 9.0, 6, true, 16),
        ];
        let mut capped = tasks.clone();
        for t in &mut capped {
            t.spec = t.spec.clone().with_max_workers(16);
        }
        for n in [0u32, 9, 16] {
            assert_eq!(solve(&tasks, n, &cost()), solve(&capped, n, &cost()), "n={n}");
        }
    }

    #[test]
    fn capped_assignments_respect_the_ceiling() {
        let mut tasks = vec![
            task(0, 2.0, 1, 14.0, 0, false, 32),
            task(1, 1.0, 1, 6.0, 0, false, 32),
        ];
        tasks[0].spec = tasks[0].spec.clone().with_max_workers(4);
        let plan = solve(&tasks, 32, &cost());
        assert!(plan.assignment[0] <= 4);
        // the budget the capped task can't take flows to the other task
        assert!(plan.assignment[1] > plan.assignment[0]);
    }

    /// Row-by-row bit equality of two horizon tables over their m+3 keys.
    fn assert_horizon_eq(a: &ScenarioLookup, b: &ScenarioLookup, avail: u32, gpn: u32) {
        assert_eq!(a.n_tasks(), b.n_tasks());
        assert_eq!(a.max_workers(), b.max_workers());
        for w in [avail.saturating_sub(gpn), avail, avail + gpn] {
            assert_eq!(a.get(None, w), b.get(None, w), "no-fault w={w}");
        }
        for f in 0..a.n_tasks() {
            let lo = avail.saturating_sub(gpn);
            assert_eq!(a.get(Some(f), lo), b.get(Some(f), lo), "fault {f}");
        }
    }

    #[test]
    fn refresh_horizon_with_no_previous_table_solves_everything() {
        let tasks = vec![
            task(0, 1.0, 2, 10.0, 6, false, 32),
            task(1, 1.3, 2, 9.0, 6, false, 32),
        ];
        let c = cost();
        let (lut, stats) = ScenarioLookup::refresh_horizon(&tasks, 24, 8, &c, None);
        assert_eq!(stats, RefreshStats { reused: 0, solved: tasks.len() + 3 });
        assert_horizon_eq(&lut, &ScenarioLookup::precompute_horizon(&tasks, 24, 8, &c), 24, 8);
    }

    #[test]
    fn refresh_horizon_reuses_all_rows_when_nothing_changed() {
        let tasks = vec![
            task(0, 1.0, 2, 10.0, 6, false, 32),
            task(1, 1.3, 2, 9.0, 6, false, 32),
            task(2, 0.7, 4, 12.0, 4, false, 32),
        ];
        let c = cost();
        let prev = ScenarioLookup::precompute_horizon(&tasks, 24, 8, &c);
        let inputs = HorizonInputs::capture(&tasks, &c);
        let (lut, stats) =
            ScenarioLookup::refresh_horizon(&tasks, 24, 8, &c, Some((&inputs, &prev)));
        assert_eq!(stats, RefreshStats { reused: tasks.len() + 3, solved: 0 });
        assert_horizon_eq(&lut, &prev, 24, 8);
    }

    #[test]
    fn refresh_horizon_after_membership_change_reuses_overlapping_rows() {
        let tasks = vec![
            task(0, 1.0, 2, 10.0, 6, false, 32),
            task(1, 1.3, 2, 9.0, 6, false, 32),
        ];
        let c = cost();
        let (avail, gpn) = (24u32, 8u32);
        let prev = ScenarioLookup::precompute_horizon(&tasks, avail, gpn, &c);
        let inputs = HorizonInputs::capture(&tasks, &c);
        // one node lost: available drops by gpn, no-fault keys {8,16,24}
        // overlap the old {16,24,32} in two entries; fault rows move to a
        // fresh lo and must be re-solved
        let (lut, stats) =
            ScenarioLookup::refresh_horizon(&tasks, avail - gpn, gpn, &c, Some((&inputs, &prev)));
        assert_eq!(stats, RefreshStats { reused: 2, solved: tasks.len() + 1 });
        assert_horizon_eq(
            &lut,
            &ScenarioLookup::precompute_horizon(&tasks, avail - gpn, gpn, &c),
            avail - gpn,
            gpn,
        );
    }

    #[test]
    fn refresh_horizon_solves_fresh_after_cost_or_task_changes() {
        let tasks = vec![
            task(0, 1.0, 2, 10.0, 6, false, 32),
            task(1, 1.3, 2, 9.0, 6, false, 32),
        ];
        let c = cost();
        let prev = ScenarioLookup::precompute_horizon(&tasks, 24, 8, &c);
        let inputs = HorizonInputs::capture(&tasks, &c);
        // MTBF estimate moved: every breakdown stamps the horizon, so bit
        // equality forbids any reuse
        let mut tighter = c.clone();
        assert!(tighter.set_mtbf_per_gpu_s(9e5));
        let (lut, stats) =
            ScenarioLookup::refresh_horizon(&tasks, 24, 8, &tighter, Some((&inputs, &prev)));
        assert_eq!(stats, RefreshStats { reused: 0, solved: tasks.len() + 3 });
        assert_horizon_eq(
            &lut,
            &ScenarioLookup::precompute_horizon(&tasks, 24, 8, &tighter),
            24,
            8,
        );
        // committed assignments changed: transition penalties depend on the
        // current counts, zero reuse again
        let mut moved = tasks.clone();
        moved[0].current = WorkerCount(7);
        let (lut, stats) =
            ScenarioLookup::refresh_horizon(&moved, 24, 8, &c, Some((&inputs, &prev)));
        assert_eq!(stats, RefreshStats { reused: 0, solved: tasks.len() + 3 });
        assert_horizon_eq(&lut, &ScenarioLookup::precompute_horizon(&moved, 24, 8, &c), 24, 8);
    }

    #[test]
    fn refresh_horizon_ignores_stale_fault_flags_when_matching() {
        // fault flags are cleared on both sides of the input comparison, so
        // a snapshot captured mid-fault still proves reuse
        let tasks = vec![
            task(0, 1.0, 2, 10.0, 6, false, 32),
            task(1, 1.3, 2, 9.0, 6, false, 32),
        ];
        let c = cost();
        let prev = ScenarioLookup::precompute_horizon(&tasks, 24, 8, &c);
        let mut flagged = tasks.clone();
        flagged[1].fault = true;
        let inputs = HorizonInputs::capture(&flagged, &c);
        let (_, stats) =
            ScenarioLookup::refresh_horizon(&flagged, 24, 8, &c, Some((&inputs, &prev)));
        assert_eq!(stats, RefreshStats { reused: tasks.len() + 3, solved: 0 });
    }

    #[test]
    fn unicron_beats_baselines_on_waf() {
        // Heterogeneous tasks: unicron's plan must dominate naive splits.
        let n = 24u32;
        let tasks = vec![
            task(0, 2.0, 2, 14.0, 0, false, n),
            task(1, 1.0, 4, 6.0, 0, false, n),
            task(2, 0.5, 8, 30.0, 0, false, n),
        ];
        let c = cost();
        let plan = solve(&tasks, n, &c);
        let waf_of = |alloc: &[u32]| -> f64 {
            tasks.iter().zip(alloc).map(|(t, &x)| t.waf(x)).sum()
        };
        for alloc in [baselines::equally(&tasks, n), baselines::weighted(&tasks, n)] {
            assert!(plan.total_waf >= waf_of(&alloc) - 1e-9, "{alloc:?}");
        }
    }
}
