//! Mini property-testing framework (no `proptest` in the vendored registry).
//!
//! Provides seeded generators and a runner with greedy shrinking: on failure,
//! the runner re-generates inputs with progressively smaller size hints and
//! reports the smallest failing case it found. Used for the coordinator
//! invariants DESIGN.md §11 lists (planner optimality, micro-batch
//! conservation, perfmodel feasibility, …).

use crate::rng::{Rand, Xoshiro256};

/// Size-aware generator: `gen(rng, size)` where `size` shrinks toward 0.
pub trait Gen {
    type Output;
    fn generate(&self, rng: &mut Xoshiro256, size: usize) -> Self::Output;
}

impl<T, F: Fn(&mut Xoshiro256, usize) -> T> Gen for F {
    type Output = T;
    fn generate(&self, rng: &mut Xoshiro256, size: usize) -> T {
        self(rng, size)
    }
}

/// Outcome of a property over one input.
pub enum Prop {
    Pass,
    /// Property failed with a message describing what went wrong.
    Fail(String),
    /// Input rejected (precondition not met); does not count as a case.
    Discard,
}

impl Prop {
    pub fn check(cond: bool, msg: impl FnOnce() -> String) -> Prop {
        if cond {
            Prop::Pass
        } else {
            Prop::Fail(msg())
        }
    }
}

/// Configuration for a property run.
pub struct Config {
    pub cases: usize,
    pub seed: u64,
    pub max_size: usize,
    pub max_discards: usize,
}

impl Default for Config {
    fn default() -> Self {
        // Seed overridable for CI reproduction of failures.
        let seed = std::env::var("PROPTEST_SEED").ok().and_then(|s| s.parse().ok()).unwrap_or(0xC0FFEE);
        Config { cases: 100, seed, max_size: 64, max_discards: 1000 }
    }
}

/// Run `prop` over `cases` generated inputs; panic with the smallest failing
/// input's debug string on failure.
pub fn run<G, P>(name: &str, cfg: Config, gen: G, prop: P)
where
    G: Gen,
    G::Output: std::fmt::Debug,
    P: Fn(&G::Output) -> Prop,
{
    let mut rng = Xoshiro256::seed_from_u64(cfg.seed ^ hash_name(name));
    let mut done = 0;
    let mut discards = 0;
    let mut case_idx = 0u64;
    while done < cfg.cases {
        // size ramps up over the run: small cases first (cheap smoke), then big.
        let size = 1 + (cfg.max_size * done) / cfg.cases.max(1);
        let mut case_rng = rng.fork(case_idx);
        case_idx += 1;
        let input = gen.generate(&mut case_rng, size);
        match prop(&input) {
            Prop::Pass => done += 1,
            Prop::Discard => {
                discards += 1;
                if discards > cfg.max_discards {
                    panic!("property {name}: too many discards ({discards})");
                }
            }
            Prop::Fail(msg) => {
                // Greedy shrink: retry with smaller sizes from the same stream,
                // keeping the smallest failure found.
                let mut smallest = (size, input, msg);
                let mut shrink_size = size;
                let mut budget = 200;
                while shrink_size > 1 && budget > 0 {
                    shrink_size /= 2;
                    for sub in 0..8 {
                        budget -= 1;
                        let mut srng = case_rng.fork(1000 + shrink_size as u64 * 16 + sub);
                        let candidate = gen.generate(&mut srng, shrink_size);
                        if let Prop::Fail(m) = prop(&candidate) {
                            smallest = (shrink_size, candidate, m);
                            break;
                        }
                    }
                }
                panic!(
                    "property {name} failed (seed {}, case {}):\n  input (size {}): {:?}\n  reason: {}",
                    cfg.seed, done, smallest.0, smallest.1, smallest.2
                );
            }
        }
    }
}

fn hash_name(name: &str) -> u64 {
    // FNV-1a, just to decorrelate properties sharing a seed.
    let mut h = 0xcbf29ce484222325u64;
    for b in name.bytes() {
        h ^= b as u64;
        h = h.wrapping_mul(0x100000001b3);
    }
    h
}

// ---------------------------------------------------------------------------
// Common generators
// ---------------------------------------------------------------------------

/// Vec of u64 in `[lo, hi]`, length in `[0, size]`.
pub fn vec_u64(lo: u64, hi: u64) -> impl Gen<Output = Vec<u64>> {
    move |rng: &mut Xoshiro256, size: usize| {
        let len = rng.below(size as u64 + 1) as usize;
        (0..len).map(|_| rng.range_inclusive(lo, hi)).collect()
    }
}

/// Vec of f64 in `[lo, hi)`, length in `[1, size]`.
pub fn vec_f64(lo: f64, hi: f64) -> impl Gen<Output = Vec<f64>> {
    move |rng: &mut Xoshiro256, size: usize| {
        let len = 1 + rng.below(size as u64 + 1) as usize;
        (0..len).map(|_| rng.uniform(lo, hi)).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_completes() {
        run("sum_nonneg", Config { cases: 50, ..Default::default() }, vec_u64(0, 100), |xs| {
            Prop::check(xs.iter().sum::<u64>() as i64 >= 0, || "negative sum".into())
        });
    }

    #[test]
    #[should_panic(expected = "property short_vecs failed")]
    fn failing_property_panics_with_input() {
        run("short_vecs", Config { cases: 100, ..Default::default() }, vec_u64(0, 10), |xs| {
            Prop::check(xs.len() < 3, || format!("len {}", xs.len()))
        });
    }

    #[test]
    fn shrinking_reports_smaller_case() {
        // Capture the panic message and assert the shrunk size is small.
        let result = std::panic::catch_unwind(|| {
            run(
                "any_nonempty",
                Config { cases: 100, max_size: 64, ..Default::default() },
                vec_u64(0, 10),
                |xs| Prop::check(xs.is_empty(), || format!("len {}", xs.len())),
            )
        });
        let msg = *result.unwrap_err().downcast::<String>().unwrap();
        // the shrinker should get well below the max size of 64
        let size: usize = msg.split("(size ").nth(1).unwrap().split(')').next().unwrap().parse().unwrap();
        assert!(size <= 8, "shrunk size {size} too large\n{msg}");
    }

    #[test]
    #[should_panic(expected = "too many discards")]
    fn discard_limit_enforced() {
        run("discards", Config { cases: 10, max_discards: 5, ..Default::default() }, vec_u64(0, 1), |_| {
            Prop::Discard
        });
    }

    #[test]
    fn deterministic_given_seed() {
        // Two identical runs must generate identical sequences: we assert by
        // collecting the inputs via a side channel.
        use std::sync::Mutex;
        let seen: Mutex<Vec<Vec<u64>>> = Mutex::new(Vec::new());
        let collect = |xs: &Vec<u64>| {
            seen.lock().unwrap().push(xs.clone());
            Prop::Pass
        };
        run("det_a", Config { cases: 20, seed: 7, ..Default::default() }, vec_u64(0, 9), collect);
        let first: Vec<_> = std::mem::take(&mut *seen.lock().unwrap());
        let collect2 = |xs: &Vec<u64>| {
            seen.lock().unwrap().push(xs.clone());
            Prop::Pass
        };
        run("det_a", Config { cases: 20, seed: 7, ..Default::default() }, vec_u64(0, 9), collect2);
        assert_eq!(first, *seen.lock().unwrap());
    }
}
