//! The recovery protocol — the typed, serializable contract between
//! detection (§4), cost-aware planning (§5), and transition execution (§6).
//!
//! Since PR 1 every recovery decision flows through one vocabulary: a
//! [`CoordEvent`] goes into the [`crate::coordinator::Coordinator`] state
//! machine (directly in production, via the environment model in
//! simulation) and a list of [`Action`]s comes out. This module makes that
//! vocabulary a first-class protocol instead of an in-memory side-channel:
//!
//! * **Typed identifiers** — [`TaskId`], [`NodeId`], and [`WorkerCount`]
//!   replace the raw `u32`s that used to flow through events, actions,
//!   [`crate::planner::PlanTask`], and the
//!   [`crate::simulator::RecoveryPolicy`] trait. A task id can no longer be
//!   passed where a node id is expected; the compiler checks the protocol.
//! * **Serialization** — every event, action, and plan round-trips through
//!   the in-repo [`crate::ser`] JSON layer ([`CoordEvent::to_value`] /
//!   [`CoordEvent::from_value`] and friends). Numeric fields use Rust's
//!   shortest-round-trip `f64` formatting, so a decoded plan compares equal
//!   to the encoded one and replays stay bit-identical.
//! * **[`DecisionLog`]** — a versioned record of an entire coordinator (or
//!   simulator) session: the ordered `(event, actions)` pairs. It
//!   serializes to bytes, deserializes, and [`DecisionLog::replay`]s
//!   through a fresh [`crate::coordinator::Coordinator`], asserting the
//!   identical action sequence at every step. Any captured production
//!   incident thereby becomes a deterministic regression artifact — the
//!   same grow-only corpus discipline `rust/tests/sim_determinism.rs`
//!   applies to trace seeds.
//!
//! # Versioning rule
//!
//! The wire format carries an explicit `version` field (currently
//! [`DECISION_LOG_VERSION`]). Decoding is **strict**:
//!
//! * an artifact whose `version` differs from the reader's is rejected —
//!   there is no best-effort cross-version parsing;
//! * an unknown event type, action type, error kind, or plan reason is
//!   rejected, never skipped. A skipped entry would silently change the
//!   replayed action sequence, which is exactly the corruption a recorded
//!   incident exists to rule out.
//!
//! Consequently **any** change to the set of variants or their fields —
//! adding, removing, or renaming — must bump [`DECISION_LOG_VERSION`].
//! Old artifacts stay readable only by the code revision that wrote them;
//! the determinism corpus pins revisions, not formats.

use std::fmt;

use crate::cost::CostBreakdown;
use crate::failure::ErrorKind;
use crate::health::DegradationKind;
use crate::placement::Layout;
use crate::planner::Plan;
use crate::ser::{JsonError, Value};
use crate::transition::StateSource;

/// Format version stamped into every serialized [`DecisionLog`]. Bump on
/// any variant/field change to the protocol types (see the module docs).
///
/// * v1 — PR 2: the initial protocol (typed ids, Fig. 7 events/actions).
/// * v2 — fleet layer: [`CoordEvent::NodeRepaired`] and the
///   [`Action::NodeQuarantined`] / [`Action::SpareRetained`] /
///   [`Action::SpareReleased`] decision surface.
/// * v3 — cost ledger: every entry carries its delivery timestamp
///   ([`LogEntry::at_s`] — the clock the fleet's MTBF estimator and the
///   burst-batch window run on), every [`Action::ApplyPlan`] carries a
///   typed [`CostBreakdown`] explaining the plan objective term-by-term,
///   and the correlated-burst surface ([`CoordEvent::ReplanDue`] /
///   [`Action::ScheduleReplan`]) joins the vocabulary.
/// * v4 — placement: every plan carries its concrete
///   [`crate::placement::Layout`] (per-task node sets, the coordinator's
///   authoritative cluster map), and the breakdown gains the Table 2
///   detection-latency term ([`CostBreakdown::detection_penalty`]).
/// * v5 — batched dispatch: [`CoordEvent::Batch`] delivers N simultaneous
///   events as one recorded decision, so a burst costs one dispatch/replan
///   cycle and replays as one step.
/// * v6 — the state tier: [`CoordEvent::StateResidency`] reports where a
///   task's snapshot actually lives (and the measured restore time), and
///   every [`CostBreakdown`] stamps the restore tier the plan priced
///   ([`CostBreakdown::state_source`]).
/// * v7 — replication: every entry carries its commit sequence number
///   ([`LogEntry::seq`], assigned densely from 0 at record time). The
///   control plane streams committed entries to standbys as
///   sequence-numbered frames, and a decoded log must be seq-gapless —
///   a gap or reorder is a strict decode error, not a skip.
/// * v8 — in-band health observation: [`CoordEvent::StepTiming`] carries a
///   per-node per-step duration sample into the coordinator's streaming
///   estimators, [`CoordEvent::NodeDegraded`] is the resulting SEV-class
///   verdict (typed [`crate::health::DegradationKind`] + measured slow
///   fraction), and every [`CostBreakdown`] gains the degradation
///   detection-latency term ([`CostBreakdown::degradation_penalty`]).
pub const DECISION_LOG_VERSION: u64 = 8;

// ---------------------------------------------------------------------------
// Typed identifiers
// ---------------------------------------------------------------------------

/// Identifier of one training task in the multi-task cluster (§5.1).
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Hash, Default)]
pub struct TaskId(pub u32);

/// Identifier of one physical node (machine) in the cluster.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Hash, Default)]
pub struct NodeId(pub u32);

/// A count of workers (GPUs) — pool sizes, per-task assignments, GPUs per
/// node. Distinct from the identifier types: a count can be compared and
/// budgeted, but never used to address a task or node.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Hash, Default)]
pub struct WorkerCount(pub u32);

macro_rules! id_impls {
    ($t:ident) => {
        impl fmt::Display for $t {
            fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
                // delegate so width/alignment flags apply to the number
                fmt::Display::fmt(&self.0, f)
            }
        }
        impl From<u32> for $t {
            fn from(x: u32) -> $t {
                $t(x)
            }
        }
    };
}
id_impls!(TaskId);
id_impls!(NodeId);
id_impls!(WorkerCount);

// ---------------------------------------------------------------------------
// Events and actions
// ---------------------------------------------------------------------------

/// Events the coordinator reacts to. ①–⑥ refer to Fig. 7's triggers.
#[derive(Debug, Clone, PartialEq)]
pub enum CoordEvent {
    /// An agent reported an error observed on `node` for `task` (①②③ by
    /// the kind's severity).
    ErrorReport { node: NodeId, task: TaskId, kind: ErrorKind },
    /// A node's lease expired — SEV1 lost connection (①).
    NodeLost { node: NodeId },
    /// A repaired or new node joined (④).
    NodeJoined { node: NodeId },
    /// Maintenance finished on `node`: it is healthy and *available*, but
    /// not yet back in the pool — the fleet layer decides whether it
    /// rejoins ([`Action::SpareRetained`]), is returned to the provider
    /// ([`Action::SpareReleased`]), or is fenced for good as a lemon
    /// ([`Action::NodeQuarantined`]).
    NodeRepaired { node: NodeId },
    /// A task completed (⑤).
    TaskFinished { task: TaskId },
    /// A new task was submitted (⑥).
    TaskLaunched { task: TaskId },
    /// Outcome of a previously-instructed reattempt/restart.
    ReattemptResult { node: NodeId, task: TaskId, ok: bool },
    RestartResult { node: NodeId, task: TaskId, ok: bool },
    /// A previously requested [`Action::ScheduleReplan`] timer fired: if a
    /// correlated-burst replan is still deferred, commit it now (one
    /// consolidated plan instead of N sequential commits).
    ReplanDue,
    /// N simultaneous events delivered as **one** decision: the coordinator
    /// applies the members in order but defers any replan they trigger
    /// until the whole batch is absorbed, so a burst costs one
    /// dispatch/replan cycle instead of N (the generalization of the
    /// correlated same-domain burst path to arbitrary co-arriving events).
    /// Recorded and replayed as a single [`LogEntry`].
    Batch(Vec<CoordEvent>),
    /// The snapshot store's residency for `task` changed (wire v6): if this
    /// task faults now, it restores from `source` in an estimated
    /// `restore_s` seconds (store tier stats — measured when transfers have
    /// been observed, the §6.3 prior otherwise). The coordinator updates
    /// its planner inputs and invalidates the precomputed table; no actions
    /// result, but the event is recorded so replays re-price identically.
    StateResidency { task: TaskId, source: StateSource, restore_s: f64 },
    /// In-band per-step timing sample (wire v8): the agent on `node`
    /// measured one training step of `task` taking `duration_s` seconds.
    /// This is the raw observation the paper's "no extra overhead"
    /// detection pillar runs on — it feeds the coordinator's per-node
    /// streaming estimators ([`crate::health::HealthMonitor`]) and usually
    /// decides nothing; it is recorded so replays rebuild the identical
    /// estimator state and hence the identical degradation verdicts.
    StepTiming { node: NodeId, task: TaskId, duration_s: f64 },
    /// SEV-class degradation verdict (wire v8): `node` (running `task`) is
    /// classified as quietly degraded — a straggler, a partial-bandwidth
    /// gray failure, or a churn-risk spot instance — running at a measured
    /// `slow_frac` goodput deficit (0.25 = 25 % slower than its own
    /// baseline). Emitted internally when the streaming estimators cross
    /// their verdict thresholds, and accepted externally so out-of-band
    /// observers (provider preemption notices) share the same path.
    NodeDegraded { node: NodeId, task: TaskId, kind: DegradationKind, slow_frac: f64 },
}

impl CoordEvent {
    /// Stable event-kind tag — the same strings the wire format uses as
    /// type discriminators. Telemetry spans and counters key on this;
    /// it is NOT part of the serialized log (no version impact).
    pub fn label(&self) -> &'static str {
        match self {
            CoordEvent::ErrorReport { .. } => "error_report",
            CoordEvent::NodeLost { .. } => "node_lost",
            CoordEvent::NodeJoined { .. } => "node_joined",
            CoordEvent::NodeRepaired { .. } => "node_repaired",
            CoordEvent::TaskFinished { .. } => "task_finished",
            CoordEvent::TaskLaunched { .. } => "task_launched",
            CoordEvent::ReattemptResult { .. } => "reattempt_result",
            CoordEvent::RestartResult { .. } => "restart_result",
            CoordEvent::ReplanDue => "replan_due",
            CoordEvent::Batch(_) => "batch",
            CoordEvent::StateResidency { .. } => "state_residency",
            CoordEvent::StepTiming { .. } => "step_timing",
            CoordEvent::NodeDegraded { .. } => "node_degraded",
        }
    }
}

/// Why a reconfiguration plan was generated — the Fig. 7 trigger class.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum PlanReason {
    /// Trigger ⑥: a task was submitted/admitted.
    TaskLaunched,
    /// Trigger ⑤: a task completed; its workers are redistributed.
    TaskFinished,
    /// Trigger ④: a repaired node rejoined the pool.
    NodeJoined,
    /// Trigger ①②③ escalated to SEV1: node isolated, cluster replans.
    Sev1Failure,
}

impl PlanReason {
    pub fn all() -> [PlanReason; 4] {
        [
            PlanReason::TaskLaunched,
            PlanReason::TaskFinished,
            PlanReason::NodeJoined,
            PlanReason::Sev1Failure,
        ]
    }

    /// Stable snake_case wire tag — deliberately distinct from the
    /// human-readable [`fmt::Display`] label, so cosmetic label edits can
    /// never silently change the wire format.
    pub fn name(self) -> &'static str {
        match self {
            PlanReason::TaskLaunched => "task_launched",
            PlanReason::TaskFinished => "task_finished",
            PlanReason::NodeJoined => "node_joined",
            PlanReason::Sev1Failure => "sev1_failure",
        }
    }

    /// Inverse of [`PlanReason::name`].
    pub fn from_name(s: &str) -> Option<PlanReason> {
        PlanReason::all().into_iter().find(|r| r.name() == s)
    }

    /// Human-readable label (the [`fmt::Display`] output).
    pub fn as_str(self) -> &'static str {
        match self {
            PlanReason::TaskLaunched => "task launched",
            PlanReason::TaskFinished => "task finished",
            PlanReason::NodeJoined => "node joined",
            PlanReason::Sev1Failure => "SEV1 failure",
        }
    }
}

impl fmt::Display for PlanReason {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(self.as_str())
    }
}

/// Instructions the coordinator emits (executed by agents / the simulator).
#[derive(Debug, Clone, PartialEq)]
pub enum Action {
    /// SEV3 ①: retry the failed operation where it failed.
    InstructReattempt { node: NodeId, task: TaskId },
    /// SEV2 ②: restart the training process on the node, same configuration;
    /// state recovers from a DP replica or checkpoint (§6.3).
    InstructRestart { node: NodeId, task: TaskId },
    /// SEV1 ③: fence the node out of the cluster.
    IsolateNode { node: NodeId },
    /// Fleet: fence a recurrently-failing (lemon) node *permanently* —
    /// before it fails again, and past any repair. Unlike
    /// [`Action::IsolateNode`], no future repair returns the node.
    NodeQuarantined { node: NodeId },
    /// Fleet: a repaired node rejoins the pool (or is held as a hot spare).
    SpareRetained { node: NodeId },
    /// Fleet: a repaired node is returned to the provider — holding it
    /// costs more than the expected shortfall it would cover.
    SpareReleased { node: NodeId },
    /// Reconfigure affected tasks to a new plan (assignments per task id).
    ApplyPlan { plan: Plan, reason: PlanReason },
    /// Correlated same-domain burst: the SEV1's replan is deferred so one
    /// consolidated plan can cover the whole burst. The driver must deliver
    /// [`CoordEvent::ReplanDue`] after at most `after_s` seconds.
    ScheduleReplan { after_s: f64 },
    /// Page the humans (§3.2 "other external interactions").
    AlertOps { message: String },
}

// ---------------------------------------------------------------------------
// Errors
// ---------------------------------------------------------------------------

/// Decode/replay error for protocol artifacts.
#[derive(Debug, Clone, PartialEq)]
pub struct ProtoError {
    pub msg: String,
}

impl ProtoError {
    pub(crate) fn new(msg: impl Into<String>) -> ProtoError {
        ProtoError { msg: msg.into() }
    }
}

impl fmt::Display for ProtoError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "protocol error: {}", self.msg)
    }
}

impl std::error::Error for ProtoError {}

impl From<JsonError> for ProtoError {
    fn from(e: JsonError) -> ProtoError {
        ProtoError::new(e.to_string())
    }
}

// ---------------------------------------------------------------------------
// Serialization helpers
// ---------------------------------------------------------------------------

fn get_u32(v: &Value, key: &str) -> Result<u32, ProtoError> {
    v.req(key)?
        .as_u64()
        .and_then(|x| u32::try_from(x).ok())
        .ok_or_else(|| ProtoError::new(format!("field {key:?} is not a u32")))
}

fn get_f64(v: &Value, key: &str) -> Result<f64, ProtoError> {
    v.req(key)?
        .as_f64()
        .ok_or_else(|| ProtoError::new(format!("field {key:?} is not a number")))
}

fn get_bool(v: &Value, key: &str) -> Result<bool, ProtoError> {
    v.req(key)?
        .as_bool()
        .ok_or_else(|| ProtoError::new(format!("field {key:?} is not a bool")))
}

fn get_str<'a>(v: &'a Value, key: &str) -> Result<&'a str, ProtoError> {
    v.req(key)?
        .as_str()
        .ok_or_else(|| ProtoError::new(format!("field {key:?} is not a string")))
}

fn get_node(v: &Value) -> Result<NodeId, ProtoError> {
    Ok(NodeId(get_u32(v, "node")?))
}

fn get_task(v: &Value) -> Result<TaskId, ProtoError> {
    Ok(TaskId(get_u32(v, "task")?))
}

fn get_kind(v: &Value) -> Result<ErrorKind, ProtoError> {
    let name = get_str(v, "kind")?;
    ErrorKind::from_name(name)
        .ok_or_else(|| ProtoError::new(format!("unknown error kind {name:?}")))
}

impl CoordEvent {
    /// Encode as a tagged JSON object (`{"event": "...", ...}`).
    pub fn to_value(&self) -> Value {
        match self {
            CoordEvent::ErrorReport { node, task, kind } => Value::obj()
                .with("event", "error_report")
                .with("node", node.0)
                .with("task", task.0)
                .with("kind", kind.name()),
            CoordEvent::NodeLost { node } => {
                Value::obj().with("event", "node_lost").with("node", node.0)
            }
            CoordEvent::NodeJoined { node } => {
                Value::obj().with("event", "node_joined").with("node", node.0)
            }
            CoordEvent::NodeRepaired { node } => {
                Value::obj().with("event", "node_repaired").with("node", node.0)
            }
            CoordEvent::TaskFinished { task } => {
                Value::obj().with("event", "task_finished").with("task", task.0)
            }
            CoordEvent::TaskLaunched { task } => {
                Value::obj().with("event", "task_launched").with("task", task.0)
            }
            CoordEvent::ReattemptResult { node, task, ok } => Value::obj()
                .with("event", "reattempt_result")
                .with("node", node.0)
                .with("task", task.0)
                .with("ok", *ok),
            CoordEvent::RestartResult { node, task, ok } => Value::obj()
                .with("event", "restart_result")
                .with("node", node.0)
                .with("task", task.0)
                .with("ok", *ok),
            CoordEvent::ReplanDue => Value::obj().with("event", "replan_due"),
            CoordEvent::Batch(events) => Value::obj()
                .with("event", "batch")
                .with("events", Value::Arr(events.iter().map(CoordEvent::to_value).collect())),
            CoordEvent::StateResidency { task, source, restore_s } => Value::obj()
                .with("event", "state_residency")
                .with("task", task.0)
                .with("source", source.name())
                .with("restore_s", *restore_s),
            CoordEvent::StepTiming { node, task, duration_s } => Value::obj()
                .with("event", "step_timing")
                .with("node", node.0)
                .with("task", task.0)
                .with("duration_s", *duration_s),
            CoordEvent::NodeDegraded { node, task, kind, slow_frac } => Value::obj()
                .with("event", "node_degraded")
                .with("node", node.0)
                .with("task", task.0)
                .with("kind", kind.name())
                .with("slow_frac", *slow_frac),
        }
    }

    /// Strict decode: unknown event tags and error kinds are rejected.
    pub fn from_value(v: &Value) -> Result<CoordEvent, ProtoError> {
        match get_str(v, "event")? {
            "error_report" => Ok(CoordEvent::ErrorReport {
                node: get_node(v)?,
                task: get_task(v)?,
                kind: get_kind(v)?,
            }),
            "node_lost" => Ok(CoordEvent::NodeLost { node: get_node(v)? }),
            "node_joined" => Ok(CoordEvent::NodeJoined { node: get_node(v)? }),
            "node_repaired" => Ok(CoordEvent::NodeRepaired { node: get_node(v)? }),
            "task_finished" => Ok(CoordEvent::TaskFinished { task: get_task(v)? }),
            "task_launched" => Ok(CoordEvent::TaskLaunched { task: get_task(v)? }),
            "reattempt_result" => Ok(CoordEvent::ReattemptResult {
                node: get_node(v)?,
                task: get_task(v)?,
                ok: get_bool(v, "ok")?,
            }),
            "restart_result" => Ok(CoordEvent::RestartResult {
                node: get_node(v)?,
                task: get_task(v)?,
                ok: get_bool(v, "ok")?,
            }),
            "replan_due" => Ok(CoordEvent::ReplanDue),
            "batch" => {
                let members = v
                    .req("events")?
                    .as_arr()
                    .ok_or_else(|| ProtoError::new("field \"events\" is not an array"))?
                    .iter()
                    .map(CoordEvent::from_value)
                    .collect::<Result<Vec<CoordEvent>, ProtoError>>()?;
                Ok(CoordEvent::Batch(members))
            }
            "state_residency" => {
                let name = get_str(v, "source")?;
                let source = StateSource::from_name(name).ok_or_else(|| {
                    ProtoError::new(format!("unknown state source {name:?}"))
                })?;
                Ok(CoordEvent::StateResidency {
                    task: get_task(v)?,
                    source,
                    restore_s: get_f64(v, "restore_s")?,
                })
            }
            "step_timing" => Ok(CoordEvent::StepTiming {
                node: get_node(v)?,
                task: get_task(v)?,
                duration_s: get_f64(v, "duration_s")?,
            }),
            "node_degraded" => {
                let name = get_str(v, "kind")?;
                let kind = DegradationKind::from_name(name).ok_or_else(|| {
                    ProtoError::new(format!("unknown degradation kind {name:?}"))
                })?;
                Ok(CoordEvent::NodeDegraded {
                    node: get_node(v)?,
                    task: get_task(v)?,
                    kind,
                    slow_frac: get_f64(v, "slow_frac")?,
                })
            }
            other => Err(ProtoError::new(format!("unknown event type {other:?}"))),
        }
    }
}

fn breakdown_to_value(b: &CostBreakdown) -> Value {
    Value::obj()
        .with("running_reward", b.running_reward)
        .with("transition_penalty", b.transition_penalty)
        .with("detection_penalty", b.detection_penalty)
        .with("degradation_penalty", b.degradation_penalty)
        .with("horizon_s", b.horizon_s)
        .with("mtbf_per_gpu_s", b.mtbf_per_gpu_s)
        .with("spare_value", b.spare_value)
        .with("spare_hold_cost", b.spare_hold_cost)
        .with("state_source", b.state_source.name())
}

fn breakdown_from_value(v: &Value) -> Result<CostBreakdown, ProtoError> {
    Ok(CostBreakdown {
        running_reward: get_f64(v, "running_reward")?,
        transition_penalty: get_f64(v, "transition_penalty")?,
        detection_penalty: get_f64(v, "detection_penalty")?,
        degradation_penalty: get_f64(v, "degradation_penalty")?,
        horizon_s: get_f64(v, "horizon_s")?,
        mtbf_per_gpu_s: get_f64(v, "mtbf_per_gpu_s")?,
        spare_value: get_f64(v, "spare_value")?,
        spare_hold_cost: get_f64(v, "spare_hold_cost")?,
        state_source: {
            let name = get_str(v, "state_source")?;
            StateSource::from_name(name)
                .ok_or_else(|| ProtoError::new(format!("unknown state source {name:?}")))?
        },
    })
}

fn plan_to_value(plan: &Plan) -> Value {
    Value::obj()
        .with("assignment", plan.assignment.clone())
        .with("objective", plan.objective)
        .with("total_waf", plan.total_waf)
        .with("workers_used", plan.workers_used)
        .with("breakdown", breakdown_to_value(&plan.breakdown))
        .with("layout", plan.layout.to_value())
}

fn plan_from_value(v: &Value) -> Result<Plan, ProtoError> {
    let arr = v
        .req("assignment")?
        .as_arr()
        .ok_or_else(|| ProtoError::new("field \"assignment\" is not an array"))?;
    let assignment = arr
        .iter()
        .map(|x| {
            x.as_u64()
                .and_then(|n| u32::try_from(n).ok())
                .ok_or_else(|| ProtoError::new("assignment entry is not a u32"))
        })
        .collect::<Result<Vec<u32>, ProtoError>>()?;
    Ok(Plan {
        assignment,
        objective: get_f64(v, "objective")?,
        total_waf: get_f64(v, "total_waf")?,
        workers_used: get_u32(v, "workers_used")?,
        breakdown: breakdown_from_value(v.req("breakdown")?)?,
        layout: Layout::from_value(v.req("layout")?).map_err(ProtoError::new)?,
    })
}

impl Action {
    /// Encode as a tagged JSON object (`{"action": "...", ...}`).
    pub fn to_value(&self) -> Value {
        match self {
            Action::InstructReattempt { node, task } => Value::obj()
                .with("action", "instruct_reattempt")
                .with("node", node.0)
                .with("task", task.0),
            Action::InstructRestart { node, task } => Value::obj()
                .with("action", "instruct_restart")
                .with("node", node.0)
                .with("task", task.0),
            Action::IsolateNode { node } => {
                Value::obj().with("action", "isolate_node").with("node", node.0)
            }
            Action::NodeQuarantined { node } => {
                Value::obj().with("action", "node_quarantined").with("node", node.0)
            }
            Action::SpareRetained { node } => {
                Value::obj().with("action", "spare_retained").with("node", node.0)
            }
            Action::SpareReleased { node } => {
                Value::obj().with("action", "spare_released").with("node", node.0)
            }
            Action::ApplyPlan { plan, reason } => Value::obj()
                .with("action", "apply_plan")
                .with("reason", reason.name())
                .with("plan", plan_to_value(plan)),
            Action::ScheduleReplan { after_s } => {
                Value::obj().with("action", "schedule_replan").with("after_s", *after_s)
            }
            Action::AlertOps { message } => {
                Value::obj().with("action", "alert_ops").with("message", message.as_str())
            }
        }
    }

    /// Strict decode: unknown action tags and plan reasons are rejected.
    pub fn from_value(v: &Value) -> Result<Action, ProtoError> {
        match get_str(v, "action")? {
            "instruct_reattempt" => {
                Ok(Action::InstructReattempt { node: get_node(v)?, task: get_task(v)? })
            }
            "instruct_restart" => {
                Ok(Action::InstructRestart { node: get_node(v)?, task: get_task(v)? })
            }
            "isolate_node" => Ok(Action::IsolateNode { node: get_node(v)? }),
            "node_quarantined" => Ok(Action::NodeQuarantined { node: get_node(v)? }),
            "spare_retained" => Ok(Action::SpareRetained { node: get_node(v)? }),
            "spare_released" => Ok(Action::SpareReleased { node: get_node(v)? }),
            "apply_plan" => {
                let reason_name = get_str(v, "reason")?;
                let reason = PlanReason::from_name(reason_name).ok_or_else(|| {
                    ProtoError::new(format!("unknown plan reason {reason_name:?}"))
                })?;
                Ok(Action::ApplyPlan { plan: plan_from_value(v.req("plan")?)?, reason })
            }
            "schedule_replan" => Ok(Action::ScheduleReplan { after_s: get_f64(v, "after_s")? }),
            "alert_ops" => Ok(Action::AlertOps { message: get_str(v, "message")?.to_string() }),
            other => Err(ProtoError::new(format!("unknown action type {other:?}"))),
        }
    }
}

// ---------------------------------------------------------------------------
// DecisionLog
// ---------------------------------------------------------------------------

/// One recorded decision: when the event was delivered, the event, and the
/// actions decided. The timestamp is part of the record because since wire
/// v3 some decisions are time-fed: the fleet's EWMA MTBF estimator (which
/// tightens the cost ledger's horizon) and the correlated-burst batch
/// window both read the delivery clock, so replays must feed the exact
/// recorded `at_s` to reproduce decisions bit-identically.
#[derive(Debug, Clone, PartialEq)]
pub struct LogEntry {
    /// Commit sequence number (wire v7): dense from 0, assigned by
    /// [`DecisionLog::record`]. This is the replication cursor — a standby
    /// acks by `seq`, and a decoded log must be gapless in it.
    pub seq: u64,
    /// Delivery timestamp, seconds on the recording driver's clock
    /// (simulated time in the environment model, wall clock in the live
    /// driver; `0.0` for clockless unit-test sessions).
    pub at_s: f64,
    pub event: CoordEvent,
    pub actions: Vec<Action>,
}

impl LogEntry {
    /// Encode one committed entry — the same shape `DecisionLog::to_json`
    /// nests under `"entries"` and the control plane ships as a
    /// replication frame body.
    pub fn to_value(&self) -> Value {
        Value::obj()
            .with("seq", self.seq)
            .with("at", self.at_s)
            .with("event", self.event.to_value())
            .with("actions", Value::Arr(self.actions.iter().map(Action::to_value).collect()))
    }

    /// Strict decode of one entry: missing `seq`, an unknown event/action
    /// variant, or a malformed field is an error, never a skip.
    pub fn from_value(v: &Value) -> Result<LogEntry, ProtoError> {
        let seq = v
            .req("seq")?
            .as_u64()
            .ok_or_else(|| ProtoError::new("field \"seq\" is not an unsigned integer"))?;
        let at_s = get_f64(v, "at")?;
        let event = CoordEvent::from_value(v.req("event")?)?;
        let actions = v
            .req("actions")?
            .as_arr()
            .ok_or_else(|| ProtoError::new("field \"actions\" is not an array"))?
            .iter()
            .map(Action::from_value)
            .collect::<Result<Vec<Action>, ProtoError>>()?;
        Ok(LogEntry { seq, at_s, event, actions })
    }
}

/// The ordered record of every decision a coordinator (or a simulated
/// policy) made in one session. This is simultaneously:
///
/// * the audit log tests assert on ([`crate::coordinator::Coordinator::log`]);
/// * the simulation decision record
///   ([`crate::simulator::SimResult::decision_log`]);
/// * a serializable incident artifact ([`DecisionLog::to_bytes`] /
///   [`DecisionLog::from_bytes`]) that [`DecisionLog::replay`]s
///   deterministically through a fresh coordinator.
#[derive(Debug, Clone, PartialEq, Default)]
pub struct DecisionLog {
    pub entries: Vec<LogEntry>,
}

/// Replay stopped: the coordinator's live decision differed from the
/// recorded one at `step`.
#[derive(Debug, Clone, PartialEq)]
pub struct ReplayDivergence {
    pub step: usize,
    pub event: CoordEvent,
    pub expected: Vec<Action>,
    pub got: Vec<Action>,
}

impl fmt::Display for ReplayDivergence {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(
            f,
            "replay diverged at step {} ({:?}): expected {:?}, got {:?}",
            self.step, self.event, self.expected, self.got
        )
    }
}

impl std::error::Error for ReplayDivergence {}

impl DecisionLog {
    pub fn new() -> DecisionLog {
        DecisionLog::default()
    }

    /// Append one decision with its delivery timestamp. The entry's
    /// [`LogEntry::seq`] is assigned here (dense from 0), so two recorders
    /// fed the same event stream produce byte-identical logs.
    pub fn record(&mut self, at_s: f64, event: CoordEvent, actions: Vec<Action>) {
        let seq = self.entries.len() as u64;
        self.entries.push(LogEntry { seq, at_s, event, actions });
    }

    /// The sequence number the next recorded entry will get — the
    /// replication layer's "committed up to" cursor.
    pub fn next_seq(&self) -> u64 {
        self.entries.len() as u64
    }

    pub fn len(&self) -> usize {
        self.entries.len()
    }

    pub fn is_empty(&self) -> bool {
        self.entries.is_empty()
    }

    pub fn iter(&self) -> std::slice::Iter<'_, LogEntry> {
        self.entries.iter()
    }

    /// All actions in decision order, flattened.
    pub fn actions(&self) -> impl Iterator<Item = &Action> {
        self.entries.iter().flat_map(|e| e.actions.iter())
    }

    /// Events in delivery order.
    pub fn events(&self) -> impl Iterator<Item = &CoordEvent> {
        self.entries.iter().map(|e| &e.event)
    }

    /// Encode with the format version (see the module docs).
    pub fn to_json(&self) -> Value {
        let entries: Vec<Value> = self.entries.iter().map(LogEntry::to_value).collect();
        Value::obj().with("version", DECISION_LOG_VERSION).with("entries", Value::Arr(entries))
    }

    /// Strict decode: wrong version, any unknown variant, or a seq gap /
    /// reorder (wire v7: entry `i` must carry `seq == i`) is an error.
    pub fn from_json(v: &Value) -> Result<DecisionLog, ProtoError> {
        let version = v
            .req("version")?
            .as_u64()
            .ok_or_else(|| ProtoError::new("field \"version\" is not an unsigned integer"))?;
        if version != DECISION_LOG_VERSION {
            return Err(ProtoError::new(format!(
                "unsupported decision-log version {version} (reader speaks {DECISION_LOG_VERSION})"
            )));
        }
        let entries = v
            .req("entries")?
            .as_arr()
            .ok_or_else(|| ProtoError::new("field \"entries\" is not an array"))?;
        let mut log = DecisionLog::new();
        for (i, entry) in entries.iter().enumerate() {
            let entry = LogEntry::from_value(entry)
                .map_err(|e| ProtoError::new(format!("entry {i}: {}", e.msg)))?;
            if entry.seq != i as u64 {
                return Err(ProtoError::new(format!(
                    "entry {i}: seq {} breaks the gapless sequence (expected {i})",
                    entry.seq
                )));
            }
            log.entries.push(entry);
        }
        Ok(log)
    }

    /// Wire encoding (compact JSON, UTF-8 bytes).
    pub fn to_bytes(&self) -> Vec<u8> {
        self.to_json().encode().into_bytes()
    }

    pub fn from_bytes(bytes: &[u8]) -> Result<DecisionLog, ProtoError> {
        let text = std::str::from_utf8(bytes)
            .map_err(|_| ProtoError::new("decision log is not valid UTF-8"))?;
        DecisionLog::from_json(&Value::parse(text)?)
    }

    /// Replay the recorded event stream through `coord`, asserting the
    /// identical action sequence at every step. Each event is delivered at
    /// its recorded [`LogEntry::at_s`], so time-fed decisions (the fleet's
    /// MTBF estimator, the burst-batch window) reproduce exactly.
    ///
    /// `coord` must be constructed with the same initial state (config,
    /// worker pool, initially-registered tasks) the recording session
    /// started from. Tasks that arrived mid-session (Fig. 7 trigger ⑥) are
    /// admitted through `admit`, which maps a [`TaskId`] to its planner
    /// inputs just before the corresponding `TaskLaunched` event — mirroring
    /// how the live driver and the environment model register tasks.
    ///
    /// Returns the number of replayed steps, or the first divergence.
    pub fn replay(
        &self,
        coord: &mut crate::coordinator::Coordinator,
        mut admit: impl FnMut(TaskId) -> Option<crate::planner::PlanTask>,
    ) -> Result<usize, ReplayDivergence> {
        for (step, entry) in self.entries.iter().enumerate() {
            if let CoordEvent::TaskLaunched { task } = entry.event {
                if coord.task_assignment(task).is_none() {
                    if let Some(pt) = admit(task) {
                        coord.add_task(pt);
                    }
                }
            }
            let got = coord.handle_at(entry.event.clone(), entry.at_s);
            if got != entry.actions {
                return Err(ReplayDivergence {
                    step,
                    event: entry.event.clone(),
                    expected: entry.actions.clone(),
                    got,
                });
            }
        }
        Ok(self.entries.len())
    }
}

impl<'a> IntoIterator for &'a DecisionLog {
    type Item = &'a LogEntry;
    type IntoIter = std::slice::Iter<'a, LogEntry>;
    fn into_iter(self) -> Self::IntoIter {
        self.entries.iter()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ids_display_and_convert() {
        assert_eq!(TaskId(3).to_string(), "3");
        assert_eq!(NodeId::from(7), NodeId(7));
        assert_eq!(WorkerCount(16).0, 16);
        assert!(TaskId(1) < TaskId(2));
    }

    #[test]
    fn plan_reason_names_round_trip() {
        for r in PlanReason::all() {
            assert_eq!(PlanReason::from_name(r.name()), Some(r));
            // the wire tag is not the display label (protocol hygiene)
            assert_ne!(r.name(), r.as_str());
            assert!(r
                .name()
                .chars()
                .all(|c| c.is_ascii_lowercase() || c == '_' || c.is_ascii_digit()));
        }
        assert_eq!(PlanReason::from_name("cosmic ray"), None);
        assert_eq!(PlanReason::from_name("task launched"), None, "display label is not a wire tag");
    }

    #[test]
    fn event_value_round_trip_via_text() {
        let ev = CoordEvent::ErrorReport {
            node: NodeId(3),
            task: TaskId(1),
            kind: ErrorKind::EccError,
        };
        let text = ev.to_value().encode();
        let back = CoordEvent::from_value(&Value::parse(&text).unwrap()).unwrap();
        assert_eq!(ev, back);
    }

    #[test]
    fn fleet_variants_round_trip() {
        let ev = CoordEvent::NodeRepaired { node: NodeId(11) };
        let back = CoordEvent::from_value(&Value::parse(&ev.to_value().encode()).unwrap()).unwrap();
        assert_eq!(ev, back);
        for a in [
            Action::NodeQuarantined { node: NodeId(3) },
            Action::SpareRetained { node: NodeId(0) },
            Action::SpareReleased { node: NodeId(u32::MAX) },
        ] {
            let back = Action::from_value(&Value::parse(&a.to_value().encode()).unwrap()).unwrap();
            assert_eq!(a, back);
        }
    }

    #[test]
    fn cost_ledger_variants_round_trip() {
        let ev = CoordEvent::ReplanDue;
        let back = CoordEvent::from_value(&Value::parse(&ev.to_value().encode()).unwrap()).unwrap();
        assert_eq!(ev, back);
        let a = Action::ScheduleReplan { after_s: 900.0 };
        let back = Action::from_value(&Value::parse(&a.to_value().encode()).unwrap()).unwrap();
        assert_eq!(a, back);
    }

    #[test]
    fn batch_events_round_trip() {
        let ev = CoordEvent::Batch(vec![
            CoordEvent::NodeLost { node: NodeId(3) },
            CoordEvent::ErrorReport {
                node: NodeId(4),
                task: TaskId(1),
                kind: ErrorKind::EccError,
            },
            CoordEvent::NodeJoined { node: NodeId(9) },
        ]);
        let back = CoordEvent::from_value(&Value::parse(&ev.to_value().encode()).unwrap()).unwrap();
        assert_eq!(ev, back);
        // the empty batch is legal (a no-op decision) and round-trips too
        let empty = CoordEvent::Batch(vec![]);
        let back =
            CoordEvent::from_value(&Value::parse(&empty.to_value().encode()).unwrap()).unwrap();
        assert_eq!(empty, back);
        // a corrupt member poisons the whole batch — strict, never skipped
        let v = Value::obj().with(
            "events",
            Value::Arr(vec![Value::obj().with("event", "warp_core_breach")]),
        );
        assert!(CoordEvent::from_value(&v.with("event", "batch")).is_err());
    }

    #[test]
    fn state_residency_round_trips() {
        for source in [
            StateSource::DpReplica,
            StateSource::InMemoryCheckpoint,
            StateSource::LocalDiskCheckpoint,
            StateSource::RemoteCheckpoint,
        ] {
            let ev = CoordEvent::StateResidency { task: TaskId(2), source, restore_s: 0.75 };
            let back =
                CoordEvent::from_value(&Value::parse(&ev.to_value().encode()).unwrap()).unwrap();
            assert_eq!(ev, back);
        }
        // unknown source is rejected, never defaulted
        let v = Value::obj()
            .with("event", "state_residency")
            .with("task", 2u32)
            .with("source", "tape_vault")
            .with("restore_s", 1.0);
        assert!(CoordEvent::from_value(&v).is_err());
    }

    #[test]
    fn health_variants_round_trip() {
        let ev = CoordEvent::StepTiming { node: NodeId(5), task: TaskId(1), duration_s: 47.25 };
        let back = CoordEvent::from_value(&Value::parse(&ev.to_value().encode()).unwrap()).unwrap();
        assert_eq!(ev, back);
        for kind in DegradationKind::all() {
            let ev = CoordEvent::NodeDegraded {
                node: NodeId(12),
                task: TaskId(0),
                kind,
                slow_frac: 0.375,
            };
            let back =
                CoordEvent::from_value(&Value::parse(&ev.to_value().encode()).unwrap()).unwrap();
            assert_eq!(ev, back);
        }
        // unknown degradation kind is rejected, never defaulted
        let v = Value::obj()
            .with("event", "node_degraded")
            .with("node", 12u32)
            .with("task", 0u32)
            .with("kind", "quantum_jitter")
            .with("slow_frac", 0.5);
        assert!(CoordEvent::from_value(&v).is_err());
    }

    #[test]
    fn unknown_variants_rejected() {
        let v = Value::obj().with("event", "warp_core_breach").with("node", 1u32);
        assert!(CoordEvent::from_value(&v).is_err());
        let v = Value::obj().with("action", "self_destruct");
        assert!(Action::from_value(&v).is_err());
        let v = Value::obj()
            .with("event", "error_report")
            .with("node", 1u32)
            .with("task", 0u32)
            .with("kind", "gamma_burst");
        assert!(CoordEvent::from_value(&v).is_err());
    }

    #[test]
    fn version_mismatch_rejected() {
        let mut log = DecisionLog::new();
        log.record(0.0, CoordEvent::NodeLost { node: NodeId(0) }, vec![]);
        let mut v = log.to_json();
        v.set("version", DECISION_LOG_VERSION + 1);
        let err = DecisionLog::from_json(&v).unwrap_err();
        assert!(err.msg.contains("version"), "{err}");
    }

    #[test]
    fn empty_log_round_trips() {
        let log = DecisionLog::new();
        assert!(log.is_empty());
        let back = DecisionLog::from_bytes(&log.to_bytes()).unwrap();
        assert_eq!(log, back);
    }
}
