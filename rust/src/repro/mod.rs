//! Experiment harness: regenerates every table and figure of the paper's
//! evaluation (§7) as terminal tables/series. `unicron repro <exp>` is the
//! CLI entry; each experiment is an entry in the typed [`EXPERIMENTS`]
//! registry (id, description, runner) that the CLI, tests, and docs all
//! enumerate — one source of truth. Each runner returns the rendered text
//! so tests can assert on the rows. DESIGN.md §6 maps experiments to
//! modules.

use std::fmt::Write as _;

use crate::config::{table3_case, ClusterSpec, ModelSpec, TaskSpec, UnicronConfig};
use crate::failure::{ErrorKind, TerminationStats, Trace, TraceConfig};
use crate::fleet::FleetModel;
use crate::metrics::{Figure, Table};
use crate::perfmodel::{best_config, throughput_table};
use crate::planner::{baselines, solve, PlanTask};
use crate::proto::{Action, CoordEvent, NodeId, PlanReason, TaskId};
use crate::simulator::{compare_policies, PolicyKind, PolicyParams, SimResult, Simulator};
use crate::telemetry::Timeline;
use crate::util::{fmt_duration, fmt_si};

/// One reproducible experiment: a stable id, a one-line description, and a
/// seeded runner producing the rendered table/figure text.
#[derive(Clone, Copy)]
pub struct Experiment {
    pub id: &'static str,
    pub description: &'static str,
    pub run: fn(u64) -> String,
}

/// The experiment registry, in paper order — the single source of truth the
/// CLI (`unicron repro list`), the dispatch in [`run`], and the tests all
/// enumerate.
pub const EXPERIMENTS: &[Experiment] = &[
    Experiment {
        id: "table1",
        description: "detection methods and severity levels (Table 1)",
        run: |_| table1(),
    },
    Experiment {
        id: "fig1",
        description: "distribution of task termination statistics (Fig. 1)",
        run: |_| fig1(),
    },
    Experiment {
        id: "fig2",
        description: "manual failure-recovery timeline on Megatron (Fig. 2)",
        run: |_| fig2(),
    },
    Experiment {
        id: "fig3a",
        description: "healthy throughput per system, GPT-3 7B on 64 GPUs (Fig. 3a)",
        run: |_| fig3a(),
    },
    Experiment {
        id: "fig3b",
        description: "FLOP/s reduction under ~10 node faults in 7 days (Fig. 3b)",
        run: fig3b,
    },
    Experiment {
        id: "fig4",
        description: "achieved FLOP/s ratio and aggregate vs GPU count (Fig. 4)",
        run: |_| fig4(),
    },
    Experiment {
        id: "fig6",
        description: "iteration-time consistency and stall thresholds (Fig. 6)",
        run: fig6,
    },
    Experiment {
        id: "fig7-churn",
        description: "task churn: Fig. 7 trigger \u{2464}\u{2465} arrivals/departures per policy",
        run: fig7_churn,
    },
    Experiment {
        id: "table2-model",
        description: "failure detection time model (Table 2; live half in the detection bench)",
        run: |_| table2_model(),
    },
    Experiment {
        id: "fig9",
        description: "transition time after a SEV1 failure vs cluster size (Fig. 9)",
        run: fig9,
    },
    Experiment {
        id: "fig10a",
        description: "single-task training throughput, Unicron vs Megatron (Fig. 10a)",
        run: |_| fig10a(),
    },
    Experiment {
        id: "fig10b",
        description: "achieved FLOP/s ratio by model size on 64 GPUs (Fig. 10b)",
        run: |_| fig10b(),
    },
    Experiment {
        id: "fig10c",
        description: "multi-task WAF vs allocation baselines, Table 3 cases (Fig. 10c)",
        run: |_| fig10c(),
    },
    Experiment {
        id: "fleet-lemon",
        description: "lemon quarantine on/off goodput on a recurrent-lemon trace (fleet)",
        run: fleet_lemon,
    },
    Experiment {
        id: "placement-frag",
        description: "fragmented cluster: min-churn placement vs topology-blind goodput",
        run: placement_frag,
    },
    Experiment {
        id: "warm-peer",
        description: "warm peer-replica failover: store-aware recovery vs formula-priced (state tier)",
        run: warm_peer,
    },
    Experiment {
        id: "sev1-timeline",
        description: "incident narratives reconstructed from a recorded DecisionLog (telemetry)",
        run: sev1_timeline,
    },
    Experiment {
        id: "straggler-evict",
        description: "in-band straggler detection: detect-and-evict vs oblivious goodput (health)",
        run: straggler_evict,
    },
    Experiment {
        id: "fig11a",
        description: "training efficiency under failure trace-a (Fig. 11)",
        run: |seed| fig11(TraceConfig::trace_a(), seed),
    },
    Experiment {
        id: "fig11b",
        description: "training efficiency under failure trace-b (Fig. 11)",
        run: |seed| fig11(TraceConfig::trace_b(), seed),
    },
];

/// Look up an experiment by id.
pub fn find(id: &str) -> Option<&'static Experiment> {
    EXPERIMENTS.iter().find(|e| e.id == id)
}

/// Dispatch by experiment id through the registry. The unknown-id error
/// lists every registered experiment (the CLI surfaces it and exits
/// non-zero).
pub fn run(exp: &str, seed: u64) -> Result<String, String> {
    match find(exp) {
        Some(e) => Ok((e.run)(seed)),
        None => {
            let known: Vec<&str> = EXPERIMENTS.iter().map(|e| e.id).collect();
            Err(format!("unknown experiment {exp:?}; known: {}", known.join(", ")))
        }
    }
}

/// Table 1: detection methods and severity levels.
pub fn table1() -> String {
    let mut t = Table::new(&["Detection method", "Error status", "Severity"]);
    for &k in ErrorKind::all() {
        t.row(&[
            format!("{:?}", k.detector()),
            format!("{k:?}"),
            format!("{:?}", k.severity()).to_uppercase(),
        ]);
    }
    format!("Table 1 — detection methods and severity levels\n{}", t.render())
}

/// Fig. 1: distribution of task termination statistics.
pub fn fig1() -> String {
    let stats = TerminationStats::published();
    let mut t = Table::new(&["resource percentile", "abnormal-termination rate"]);
    for (bucket, rate) in &stats.buckets {
        t.row(&[bucket.to_string(), format!("{:.1}%", rate * 100.0)]);
    }
    format!(
        "Fig. 1 — task termination statistics (top-5%: {:.1}%)\n{}",
        stats.top5_rate() * 100.0,
        t.render()
    )
}

/// Fig. 2: the manual-recovery timeline Unicron eliminates.
pub fn fig2() -> String {
    let phases: &[(&str, f64)] = &[
        ("system hang until NCCL timeout", 30.0 * 60.0),
        ("task resubmission wait", 9.0 * 60.0),
        ("environment + CUDA setup", 14.0 * 60.0),
        ("recompute lost progress", 15.0 * 60.0),
    ];
    let total: f64 = phases.iter().map(|p| p.1).sum();
    let mut t = Table::new(&["phase", "duration"]);
    for (name, d) in phases {
        t.row(&[name.to_string(), fmt_duration(*d)]);
    }
    t.row(&["TOTAL (transient-fault downtime)".into(), fmt_duration(total)]);
    format!("Fig. 2 — manual failure recovery on Megatron (transient fault)\n{}", t.render())
}

/// Fig. 3a: healthy throughput of each system (GPT-3 7B, 64 GPUs).
pub fn fig3a() -> String {
    let cluster = ClusterSpec::default();
    let cfg = UnicronConfig::default();
    let model = ModelSpec::gpt3("gpt3-7b").unwrap();
    let est = best_config(&model, &cluster, 64).expect("7B fits on 64 GPUs");
    let mut t = Table::new(&["system", "samples/s", "vs Megatron"]);
    for kind in PolicyKind::all() {
        let p = PolicyParams::for_kind(kind, &cfg);
        let sps = est.samples_per_s * p.efficiency;
        t.row(&[kind.name().into(), format!("{sps:.1}"), format!("{:.2}×", p.efficiency)]);
    }
    format!(
        "Fig. 3a — throughput w/o failures (GPT-3 7B, 64 GPUs; best config {:?}, {:.0}% of peak)\n{}",
        est.config,
        est.flops_ratio * 100.0,
        t.render()
    )
}

/// Fig. 3b: FLOP/s reduction under ~10 node faults in 7 days (64 GPUs).
pub fn fig3b(seed: u64) -> String {
    let cluster = ClusterSpec { n_nodes: 8, ..Default::default() }; // 64 GPUs
    let cfg = UnicronConfig::default();
    let specs = vec![TaskSpec::new(0u32, "gpt3-7b", 1.0, 8)];
    let tc = TraceConfig {
        name: "fig3b".into(),
        duration_s: 7.0 * 86400.0,
        n_nodes: 8,
        expect_sev1: 10.0,
        expect_other: 0.0,
        repair_min_s: 0.25 * 86400.0,
        repair_max_s: 1.0 * 86400.0,
    };
    let trace = Trace::generate(tc, seed);
    // theoretical reduction: GPU-hours unavailable / total GPU-hours
    let tl = trace.availability_timeline(cluster.gpus_per_node);
    let mut lost = 0.0;
    for w in tl.windows(2) {
        lost += (64.0 - w[0].1 as f64) * (w[1].0 - w[0].0);
    }
    let theo = lost / (64.0 * trace.config.duration_s);
    let mut t = Table::new(&["system", "FLOP/s reduction", "vs theoretical"]);
    t.row(&["theoretical (hardware loss)".into(), format!("{:.1}%", theo * 100.0), "1.0×".into()]);
    for r in compare_policies(&cluster, &cfg, &specs, &trace) {
        t.row(&[
            r.policy.name().into(),
            format!("{:.1}%", r.reduction() * 100.0),
            format!("{:.1}×", r.reduction() / theo.max(1e-9)),
        ]);
    }
    format!(
        "Fig. 3b — FLOP/s reduction from failures (7B, 64 GPUs, 7 days, {} SEV1)\n{}",
        trace.count_by_severity(crate::failure::Severity::Sev1),
        t.render()
    )
}

/// Fig. 4: achieved FLOP/s ratio + aggregate vs GPU count, per model size.
pub fn fig4() -> String {
    let cluster = ClusterSpec::default();
    let mut out = String::new();
    let _ = writeln!(out, "Fig. 4 — achieved FLOP/s ratio and aggregate FLOP/s (Megatron model)");
    let mut t = Table::new(&["model", "GPUs", "config (tp,pp,dp,mbs)", "ratio", "aggregate"]);
    for name in ModelSpec::zoo() {
        let model = ModelSpec::gpt3(name).unwrap();
        for x in [8u32, 16, 24, 32, 40, 48, 56, 64, 96, 128] {
            match best_config(&model, &cluster, x) {
                Some(e) => t.row(&[
                    name.to_string(),
                    x.to_string(),
                    format!(
                        "({},{},{},{})",
                        e.config.tp, e.config.pp, e.config.dp, e.config.mbs
                    ),
                    format!("{:.1}%", e.flops_ratio * 100.0),
                    format!("{}FLOP/s", fmt_si(e.achieved_flops)),
                ]),
                None => t.row(&[
                    name.to_string(),
                    x.to_string(),
                    "infeasible (memory)".into(),
                    "-".into(),
                    "-".into(),
                ]),
            }
        }
    }
    out.push_str(&t.render());
    // highlight the non-monotonicity the paper calls out
    let m7 = ModelSpec::gpt3("gpt3-7b").unwrap();
    let tab = throughput_table(&m7, &cluster, 64);
    for x in 9..=64usize {
        if tab[x] < tab[x - 1] && tab[x - 1] > 0.0 {
            let _ = writeln!(
                out,
                "note: non-monotonic point for 7B: {} GPUs achieve {}FLOP/s vs {}FLOP/s at {} \
                 (awkward factorization / memory wall)",
                x,
                fmt_si(tab[x]),
                fmt_si(tab[x - 1]),
                x - 1
            );
            break;
        }
    }
    out
}

/// Fig. 6: iteration-time consistency + the 1.1× / 3× thresholds.
pub fn fig6(seed: u64) -> String {
    use crate::detect::{StatMonitor, StatStatus};
    use crate::rng::{Rand, Xoshiro256};
    let mut rng = Xoshiro256::seed_from_u64(seed);
    let mut mon = StatMonitor::paper_defaults();
    let base = 45.0; // seconds per iteration (GPT-3 175B-ish on 256 GPUs)
    let mut fig = Figure::new("Fig. 6 — completion time per iteration", "iteration", "seconds");
    for i in 0..60 {
        let jitter = 1.0 + 0.02 * rng.normal();
        let d = base * jitter;
        mon.record(d);
        fig.series_mut("normal").push(i as f64, d);
    }
    // a switch goes down: iterations slow ~1.6× but training persists
    for i in 60..70 {
        let d = base * (1.6 + 0.05 * rng.normal());
        fig.series_mut("degraded").push(i as f64, d);
        mon.record(d);
    }
    let avg = mon.average().unwrap();
    let mut out = fig.ascii_chart(72, 12);
    let _ = writeln!(out, "average D_iter: {avg:.1}s");
    let _ = writeln!(out, "warn  (1.1×): {:.1}s", 1.1 * avg);
    let _ = writeln!(out, "fail  (3.0×): {:.1}s  (grey line — declare failure)", 3.0 * avg);
    let _ = writeln!(
        out,
        "status at 1.2×avg: {:?}; at 3.5×avg: {:?}",
        mon.check(1.2 * avg),
        mon.check(3.5 * avg)
    );
    debug_assert_eq!(mon.check(3.5 * avg), StatStatus::Failed);
    out
}

/// Table 2 (model view): detection times per method — the same
/// [`crate::cost`] constants the ledger prices `detection_penalty` with.
/// The measured-over-TCP version is `cargo bench --bench detection`.
pub fn table2_model() -> String {
    use crate::cost::{detection_latency_s, DETECT_STATISTICAL_S};
    let mut t = Table::new(&["case", "method", "Unicron", "w/o Unicron"]);
    t.row(&[
        "1".into(),
        "Node health monitoring".into(),
        format!("~{:.1}s (lease TTL)", detection_latency_s(ErrorKind::LostConnection)),
        "~5.7s".into(),
    ]);
    t.row(&[
        "2".into(),
        "Process supervision".into(),
        format!("~{:.1}s (poll)", detection_latency_s(ErrorKind::ExitedAbnormally)),
        "D_timeout (30m)".into(),
    ]);
    t.row(&[
        "3".into(),
        "Exception propagation".into(),
        format!("~{:.1}s (immediate)", detection_latency_s(ErrorKind::CudaError)),
        "D_timeout (30m)".into(),
    ]);
    t.row(&[
        "4".into(),
        "Online statistical monitoring".into(),
        format!("3×D_iter = {}", fmt_duration(DETECT_STATISTICAL_S)),
        "D_timeout (30m)".into(),
    ]);
    format!("Table 2 — failure detection time (model; run the detection bench for live numbers)\n{}", t.render())
}

/// Fig. 9: transition time under a SEV1 failure vs cluster size.
pub fn fig9(seed: u64) -> String {
    let cfg = UnicronConfig::default();
    let mut t = Table::new(&["GPUs", "Unicron", "Bamboo", "Oobleck", "Varuna", "Megatron"]);
    for nodes in [2u32, 4, 8] {
        let gpus = nodes * 8;
        let cluster = ClusterSpec { n_nodes: nodes, ..Default::default() };
        let specs = vec![TaskSpec::new(0u32, "gpt3-7b", 1.0, 8)];
        let tc = TraceConfig {
            name: "fig9".into(),
            duration_s: 4.0 * 3600.0,
            n_nodes: nodes,
            expect_sev1: 1.0,
            expect_other: 0.0,
            repair_min_s: 3600.0,
            repair_max_s: 7200.0,
        };
        // force exactly one SEV1 by regenerating until the trace has one
        let mut trace = Trace::generate(tc.clone(), seed);
        let mut s = seed;
        while trace.count_by_severity(crate::failure::Severity::Sev1) == 0 {
            s += 1;
            trace = Trace::generate(tc.clone(), s);
        }
        let mut row = vec![gpus.to_string()];
        for kind in [
            PolicyKind::Unicron,
            PolicyKind::Bamboo,
            PolicyKind::Oobleck,
            PolicyKind::Varuna,
            PolicyKind::Megatron,
        ] {
            let r = Simulator::builder()
                .cluster(cluster.clone())
                .config(cfg.clone())
                .policy(kind)
                .tasks(&specs)
                .build()
                .run(&trace);
            match r.transitions.first() {
                Some(&(_, d)) => row.push(fmt_duration(d)),
                None => row.push("-".into()),
            }
        }
        t.row(&row);
    }
    format!(
        "Fig. 9 — transition time after a SEV1 failure (GPT-3 7B; detection included)\n{}\n\
         (Megatron time excludes its wait for a spare node, matching the paper's footnote;\n  \
         its recompute-from-checkpoint dominates.)\n",
        t.render()
    )
}

/// Fig. 10a: single-task training throughput, Unicron vs Megatron.
pub fn fig10a() -> String {
    let cluster = ClusterSpec::default();
    let model = ModelSpec::gpt3("gpt3-7b").unwrap();
    let mut t = Table::new(&["GPUs", "Megatron samples/s", "Unicron samples/s", "overhead"]);
    for x in [8u32, 16, 32, 64, 128] {
        if let Some(e) = best_config(&model, &cluster, x) {
            // Unicron inherits Megatron's execution path: no overhead (§7.4)
            t.row(&[
                x.to_string(),
                format!("{:.1}", e.samples_per_s),
                format!("{:.1}", e.samples_per_s),
                "0.0%".into(),
            ]);
        }
    }
    format!("Fig. 10a — training throughput, GPT-3 7B (Unicron on par with Megatron)\n{}", t.render())
}

/// Fig. 10b: achieved FLOP/s ratio by model size on 64 GPUs.
pub fn fig10b() -> String {
    let cluster = ClusterSpec::default();
    let mut t = Table::new(&["model", "Megatron ratio", "Unicron ratio"]);
    for name in ModelSpec::zoo() {
        let model = ModelSpec::gpt3(name).unwrap();
        match best_config(&model, &cluster, 64) {
            Some(e) => {
                let r = format!("{:.1}%", e.flops_ratio * 100.0);
                t.row(&[name.into(), r.clone(), r]);
            }
            None => t.row(&[name.into(), "OOM @64".into(), "OOM @64".into()]),
        }
    }
    format!("Fig. 10b — achieved FLOP/s ratio on 64 GPUs\n{}", t.render())
}

/// Fig. 10c: multi-task WAF for Table 3 cases vs allocation baselines.
pub fn fig10c() -> String {
    let cluster = ClusterSpec::default();
    let cost = crate::cost::CostModel::from_config(&UnicronConfig::default());
    let n = cluster.total_gpus();
    let mut t = Table::new(&["case", "Unicron", "equally", "weighted", "sized"]);
    for case in 1..=5u32 {
        let specs = table3_case(case);
        let tasks: Vec<PlanTask> =
            specs.iter().map(|s| PlanTask::from_spec(s, &cluster, n)).collect();
        let sizes: Vec<f64> =
            specs.iter().map(|s| ModelSpec::gpt3(&s.model).unwrap().n_params).collect();
        let waf_of = |alloc: &[u32]| -> f64 {
            tasks.iter().zip(alloc).map(|(t, &x)| t.waf(x)).sum()
        };
        let uni = solve(&tasks, n, &cost).total_waf;
        let eq = waf_of(&baselines::equally(&tasks, n));
        let we = waf_of(&baselines::weighted(&tasks, n));
        let si = waf_of(&baselines::sized(&tasks, n, &sizes));
        t.row(&[
            case.to_string(),
            format!("{}FLOP/s", fmt_si(uni)),
            format!("{}FLOP/s ({:.2}×)", fmt_si(eq), uni / eq.max(1.0)),
            format!("{}FLOP/s ({:.2}×)", fmt_si(we), uni / we.max(1.0)),
            format!("{}FLOP/s ({:.2}×)", fmt_si(si), uni / si.max(1.0)),
        ]);
    }
    format!("Fig. 10c — cluster WAF across Table 3 cases (128 GPUs; ratios = Unicron/baseline)\n{}", t.render())
}

/// Sum one breakdown column over every committed plan in a decision log —
/// the CLI's ledger view (the CostBreakdown rides every `ApplyPlan`).
fn breakdown_total(r: &SimResult, term: fn(&crate::cost::CostBreakdown) -> f64) -> f64 {
    r.decision_log
        .actions()
        .filter_map(|a| match a {
            Action::ApplyPlan { plan, .. } => Some(term(&plan.breakdown)),
            _ => None,
        })
        .sum()
}

/// Render the ledger columns (Σ over committed plans) for a set of runs —
/// surfaces the wire-v4 `CostBreakdown` in the repro tables.
fn ledger_table(rows: &[(&str, &SimResult)]) -> String {
    let mut t = Table::new(&[
        "system",
        "plans",
        "Σ running reward",
        "Σ transition pen.",
        "Σ detection pen.",
        "Σ degradation pen.",
        "Σ spare value",
    ]);
    for (label, r) in rows {
        let plans =
            r.decision_log.actions().filter(|a| matches!(a, Action::ApplyPlan { .. })).count();
        t.row(&[
            label.to_string(),
            plans.to_string(),
            format!("{}FLOP·s", fmt_si(breakdown_total(r, |b| b.running_reward))),
            format!("{}FLOP·s", fmt_si(breakdown_total(r, |b| b.transition_penalty))),
            format!("{}FLOP·s", fmt_si(breakdown_total(r, |b| b.detection_penalty))),
            format!("{}FLOP·s", fmt_si(breakdown_total(r, |b| b.degradation_penalty))),
            format!("{}FLOP·s", fmt_si(breakdown_total(r, |b| b.spare_value))),
        ]);
    }
    t.render()
}

/// Fig. 11: overall training efficiency under a failure trace.
pub fn fig11(tc: TraceConfig, seed: u64) -> String {
    let cluster = ClusterSpec::default();
    let cfg = UnicronConfig::default();
    let specs = table3_case(5); // §7.5 uses Case #5
    let trace = Trace::generate(tc.clone(), seed);
    let results = compare_policies(&cluster, &cfg, &specs, &trace);
    let uni = results.iter().find(|r| r.policy == PolicyKind::Unicron).unwrap().accumulated_waf;

    let mut out = format!(
        "Fig. 11 ({}) — {} SEV1 + {} other failures over {}\n",
        tc.name,
        trace.count_by_severity(crate::failure::Severity::Sev1),
        trace.events.len() - trace.count_by_severity(crate::failure::Severity::Sev1),
        fmt_duration(tc.duration_s),
    );
    let mut fig = Figure::new(
        &format!("WAF over time ({})", tc.name),
        "hours",
        "weighted PFLOP/s",
    );
    let mut t = Table::new(&["system", "mean WAF", "accumulated WAF", "Unicron advantage"]);
    for r in &results {
        t.row(&[
            r.policy.name().into(),
            format!("{}FLOP/s", fmt_si(r.mean_waf())),
            format!("{}FLOP·s", fmt_si(r.accumulated_waf)),
            format!("{:.1}×", uni / r.accumulated_waf.max(1.0)),
        ]);
        // subsample the series for the ascii chart
        let s = fig.series_mut(r.policy.name());
        let step = (r.waf_series.len() / 120).max(1);
        for (i, &(tt, w)) in r.waf_series.iter().enumerate() {
            if i % step == 0 {
                s.push(tt / 3600.0, w / 1e15);
            }
        }
    }
    out.push_str(&t.render());
    out.push_str(&fig.ascii_chart(100, 16));
    // the cost-ledger view of the same runs: Unicron's plans price their
    // transitions and detection windows; the baselines optimize nothing
    // (all-zero breakdowns)
    let rows: Vec<(&str, &SimResult)> =
        results.iter().map(|r| (r.policy.name(), r)).collect();
    out.push_str("\ncost ledger (Σ over committed plans):\n");
    out.push_str(&ledger_table(&rows));
    out
}

/// Fig. 7 triggers ⑤⑥: task churn (mid-trace arrivals and departures) on
/// the Table 3 case-5 cluster, per recovery policy. Counts are read off the
/// recorded [`crate::proto::DecisionLog`]: every launch/finish the policy
/// saw and every replan it answered with.
pub fn fig7_churn(seed: u64) -> String {
    let cluster = ClusterSpec::default();
    let cfg = UnicronConfig::default();
    let specs = table3_case(5);
    // two late arrivals in the first half, two departures in the second
    let trace = Trace::generate(TraceConfig::trace_a(), seed).with_task_churn(6, 2, 2, seed);
    let mut t = Table::new(&["system", "launches", "finishes", "churn replans", "mean WAF"]);
    for kind in PolicyKind::all() {
        let r = Simulator::builder()
            .cluster(cluster.clone())
            .config(cfg.clone())
            .policy(kind)
            .tasks(&specs)
            .build()
            .run(&trace);
        let launches = r
            .decision_log
            .events()
            .filter(|e| matches!(e, CoordEvent::TaskLaunched { .. }))
            .count();
        let finishes = r
            .decision_log
            .events()
            .filter(|e| matches!(e, CoordEvent::TaskFinished { .. }))
            .count();
        let churn_replans = r
            .decision_log
            .iter()
            .filter(|en| {
                matches!(
                    en.event,
                    CoordEvent::TaskLaunched { .. } | CoordEvent::TaskFinished { .. }
                ) && en.actions.iter().any(|a| {
                    matches!(
                        a,
                        crate::proto::Action::ApplyPlan {
                            reason: PlanReason::TaskLaunched | PlanReason::TaskFinished,
                            ..
                        }
                    )
                })
            })
            .count();
        t.row(&[
            kind.name().into(),
            launches.to_string(),
            finishes.to_string(),
            churn_replans.to_string(),
            format!("{}FLOP/s", fmt_si(r.mean_waf())),
        ]);
    }
    format!(
        "Fig. 7 ⑤⑥ — task churn (6 tasks, 2 late arrivals, 2 departures, trace-a seed {seed})\n{}",
        t.render()
    )
}

/// The recurrent-lemon trace and its two Unicron runs (quarantine on/off).
/// Split out so tests can pin the acceptance property — quarantine-on
/// goodput ≥ quarantine-off — without re-parsing the rendered table.
pub fn fleet_lemon_runs(seed: u64) -> (Trace, SimResult, SimResult) {
    let cluster = ClusterSpec::default();
    let specs = table3_case(5);
    let tc = TraceConfig {
        name: "fleet-lemon".into(),
        duration_s: 6.0 * 3600.0,
        n_nodes: cluster.n_nodes,
        expect_sev1: 0.0,
        expect_other: 0.0,
        repair_min_s: 0.25 * 86400.0,
        repair_max_s: 86400.0,
    };
    // One lemon node failing at the process level every 30 s — each failure
    // alone is SEV2-trivial (restart in place), but the recurrence starves
    // the owning task, the pattern Meta's reliability study found dominating
    // lost goodput. The period deliberately exceeds the ~17 s restart
    // recovery so every restart *succeeds* before the next failure: the
    // §4.2 escalation ladder resets each cycle and never reaches SEV1 —
    // only the fleet's recurrence memory can end the loop.
    let trace = Trace::generate(tc, seed).with_recurrent_lemon(
        NodeId(5),
        ErrorKind::CudaError,
        600.0,
        30.0,
        f64::INFINITY,
    );
    let run_with = |quarantine: bool| {
        let cfg = UnicronConfig { lemon_quarantine: quarantine, ..UnicronConfig::default() };
        Simulator::builder()
            .cluster(cluster.clone())
            .config(cfg)
            .policy(PolicyKind::Unicron)
            .tasks(&specs)
            .build()
            .run(&trace)
    };
    let on = run_with(true);
    let off = run_with(false);
    (trace, on, off)
}

/// Fleet economics: goodput with lemon quarantine on vs off on a
/// recurrent-lemon trace, plus the fleet's offline per-node health report
/// (lemon score, EWMA MTBF estimate, failure domain).
pub fn fleet_lemon(seed: u64) -> String {
    let (trace, on, off) = fleet_lemon_runs(seed);
    fleet_lemon_render(&trace, &on, &off)
}

/// Render the `fleet-lemon` report from already-computed runs (so tests
/// that need both the raw runs and the rendered text pay for the two
/// simulations once).
pub fn fleet_lemon_render(trace: &Trace, on: &SimResult, off: &SimResult) -> String {
    let cfg = UnicronConfig::default();

    let count =
        |r: &SimResult, f: fn(&Action) -> bool| r.decision_log.actions().filter(|&a| f(a)).count();
    let mut t =
        Table::new(&["lemon quarantine", "accumulated WAF", "mean WAF", "quarantines", "restarts"]);
    for (label, r) in [("on", on), ("off", off)] {
        t.row(&[
            label.into(),
            format!("{}FLOP·s", fmt_si(r.accumulated_waf)),
            format!("{}FLOP/s", fmt_si(r.mean_waf())),
            count(r, |a| matches!(a, Action::NodeQuarantined { .. })).to_string(),
            count(r, |a| matches!(a, Action::InstructRestart { .. })).to_string(),
        ]);
    }
    let mut out = format!(
        "fleet-lemon — node 5 fails every 30s from t=600s ({} failures over {})\n{}",
        trace.events.len(),
        fmt_duration(trace.config.duration_s),
        t.render()
    );
    let _ = writeln!(
        out,
        "quarantine advantage: {:.3}× accumulated WAF",
        on.accumulated_waf / off.accumulated_waf.max(1.0)
    );

    // the fleet's offline view of the same trace
    let fleet = FleetModel::ingest_trace(trace, &cfg);
    let mut h = Table::new(&["node", "domain", "failures", "EWMA MTBF", "lemon score", "lemon?"]);
    for (&node, health) in fleet.nodes() {
        h.row(&[
            node.to_string(),
            health.domain.to_string(),
            health.failures.to_string(),
            health.mtbf_estimate_s().map_or("-".into(), fmt_duration),
            format!("{:.2}", fleet.lemon_score(node)),
            if fleet.is_lemon(node) { "LEMON".into() } else { "ok".into() },
        ]);
    }
    let _ = writeln!(out, "\nfleet health history (offline trace ingest):\n{}", h.render());
    out
}

/// `sev1-timeline` — the observability loop closed end to end: run the
/// Unicron policy on a SEV1-heavy trace, then reconstruct the incident
/// narratives (failure → detection latency → replan economics → recovery)
/// from the recorded [`DecisionLog`](crate::coordinator::DecisionLog)
/// *alone*, exactly as `unicron obs --log` would. A timeline that fails to
/// render (non-reconciling cost terms, malformed spans) panics, so both
/// `every_experiment_runs` and the CI repro smoke catch telemetry drift.
pub fn sev1_timeline(seed: u64) -> String {
    let cluster = ClusterSpec::default();
    let cfg = UnicronConfig::default();
    let specs = table3_case(5);
    let tc = TraceConfig {
        name: "sev1-timeline".into(),
        duration_s: 7.0 * 86400.0,
        n_nodes: cluster.n_nodes,
        expect_sev1: 4.0,
        expect_other: 6.0,
        repair_min_s: 0.5 * 86400.0,
        repair_max_s: 2.0 * 86400.0,
    };
    let trace = Trace::generate(tc.clone(), seed);
    let r = Simulator::builder()
        .cluster(cluster)
        .config(cfg)
        .policy(PolicyKind::Unicron)
        .tasks(&specs)
        .build()
        .run(&trace);
    let timeline = Timeline::from_log(&r.decision_log);
    let rendered = timeline
        .render()
        .unwrap_or_else(|e| panic!("sev1-timeline: recorded log failed to render: {e}"));
    let incidents = timeline.incidents().count();
    format!(
        "sev1-timeline — {} incident{} reconstructed from {} recorded decisions over {}\n{}",
        incidents,
        if incidents == 1 { "" } else { "s" },
        r.decision_log.len(),
        fmt_duration(tc.duration_s),
        rendered
    )
}

/// The fragmented-cluster trace and its two Unicron runs: min-churn
/// placement on vs the topology-blind reference. Split out so tests can pin
/// the acceptance property — placement-aware goodput ≥ topology-blind —
/// without re-parsing the rendered table.
pub fn placement_frag_runs(seed: u64) -> (Trace, SimResult, SimResult) {
    let cluster = ClusterSpec::default();
    let specs = table3_case(5);
    let cfg = UnicronConfig::default();
    // moderate background noise + three full fragmentation waves: every
    // domain loses a node per wave (fast repairs), so a topology-blind
    // assignment reshuffles the whole cluster wave after wave while the
    // min-churn solver moves only the replacements
    let tc = TraceConfig {
        name: "placement-frag".into(),
        duration_s: 14.0 * 86400.0,
        n_nodes: cluster.n_nodes,
        expect_sev1: 2.0,
        expect_other: 8.0,
        repair_min_s: 0.5 * 86400.0,
        repair_max_s: 2.0 * 86400.0,
    };
    let trace =
        Trace::generate(tc, seed).with_fragmented_cluster(cfg.nodes_per_domain, 3, seed);
    let run_with = |min_churn: bool| {
        let cfg = UnicronConfig { placement_min_churn: min_churn, ..UnicronConfig::default() };
        Simulator::builder()
            .cluster(cluster.clone())
            .config(cfg)
            .policy(PolicyKind::Unicron)
            .tasks(&specs)
            .build()
            .run(&trace)
    };
    let churn = run_with(true);
    let blind = run_with(false);
    (trace, churn, blind)
}

/// Per-run placement churn, read off the committed layouts of a decision
/// log: how many nodes were *gained* across all replans (state pulled onto
/// a node that did not already serve the task), the ledger-priced
/// migration seconds those gains cost ([`TaskMoves::migration_s`] with each
/// task's §6.3 profile), and the final cluster map.
///
/// [`TaskMoves::migration_s`]: crate::placement::TaskMoves::migration_s
pub fn layout_churn(
    r: &SimResult,
    profiles: &std::collections::BTreeMap<TaskId, crate::cost::TransitionProfile>,
    cost: &crate::cost::CostModel,
) -> (usize, f64, crate::placement::Layout) {
    let mut prev = crate::placement::Layout::default();
    let mut gained = 0usize;
    let mut priced_s = 0.0;
    for a in r.decision_log.actions() {
        if let Action::ApplyPlan { plan, .. } = a {
            for m in plan.layout.diff(&prev) {
                gained += m.gained.len();
                if let Some(profile) = profiles.get(&m.task) {
                    priced_s += m.migration_s(profile, cost, false);
                }
            }
            prev = plan.layout.clone();
        }
    }
    (gained, priced_s, prev)
}

/// The §6.3 transition profiles of the `placement-frag` task set, keyed by
/// task id — the pricing `layout_churn` feeds [`crate::placement::TaskMoves`].
fn placement_frag_profiles() -> std::collections::BTreeMap<TaskId, crate::cost::TransitionProfile> {
    let cluster = ClusterSpec::default();
    let n = cluster.total_gpus();
    table3_case(5)
        .iter()
        .map(|spec| (spec.id, PlanTask::from_spec(spec, &cluster, n).profile))
        .collect()
}

/// Placement under fragmentation: min-churn vs topology-blind layouts on
/// the same trace — goodput, nodes moved, priced migration, and final rack
/// spread, plus the ledger columns of both runs.
pub fn placement_frag(seed: u64) -> String {
    let (trace, churn, blind) = placement_frag_runs(seed);
    let nodes_per_domain = UnicronConfig::default().nodes_per_domain;
    let cost = crate::cost::CostModel::from_config(&UnicronConfig::default());
    let profiles = placement_frag_profiles();

    let mut t = Table::new(&[
        "placement",
        "accumulated WAF",
        "mean WAF",
        "nodes moved",
        "Σ priced migration",
        "final domains/task",
    ]);
    for (label, r) in [("min-churn", &churn), ("topology-blind", &blind)] {
        let (gained, priced_s, last) = layout_churn(r, &profiles, &cost);
        let spreads: Vec<usize> =
            last.iter().map(|(task, _)| last.domain_spread(task, nodes_per_domain)).collect();
        let mean_spread = if spreads.is_empty() {
            0.0
        } else {
            spreads.iter().sum::<usize>() as f64 / spreads.len() as f64
        };
        t.row(&[
            label.into(),
            format!("{}FLOP·s", fmt_si(r.accumulated_waf)),
            format!("{}FLOP/s", fmt_si(r.mean_waf())),
            gained.to_string(),
            fmt_duration(priced_s),
            format!("{mean_spread:.2}"),
        ]);
    }
    let mut out = format!(
        "placement-frag — {} failures over {} ({} fragmentation waves across {} domains)\n{}",
        trace.events.len(),
        fmt_duration(trace.config.duration_s),
        3,
        ClusterSpec::default().n_nodes / nodes_per_domain,
        t.render()
    );
    let _ = writeln!(
        out,
        "consolidation advantage: {:.3}× accumulated WAF",
        churn.accumulated_waf / blind.accumulated_waf.max(1.0)
    );
    out.push_str("\ncost ledger (Σ over committed plans):\n");
    out.push_str(&ledger_table(&[("min-churn", &churn), ("topology-blind", &blind)]));
    out
}

/// The warm-peer trace and its two Unicron runs: store-aware recovery on
/// (checkpoints execute against the snapshot store, SEV1 failovers restore
/// from the nearest resident tier) vs off (the closed-form §6.3 transition
/// model). Split out so tests can pin the acceptance properties — every
/// store restore sub-second, store-aware goodput ≥ formula-priced — without
/// re-parsing the rendered table.
///
/// Scenario: one GPT-3 7B task on the 16×8 cluster, a quiet trace, and one
/// injected SEV1 (node 0, t = 2.5 h) after four checkpoint ticks — the
/// peer-replica in-memory snapshot is warm, so the failover is a ~13 GB
/// shard pull over the training interconnect, not a minutes-class rebuild.
pub fn warm_peer_runs(seed: u64) -> (Trace, SimResult, SimResult) {
    let cluster = ClusterSpec::default();
    let specs = vec![TaskSpec::new(0u32, "gpt3-7b", 1.0, 8).with_max_workers(64)];
    let tc = TraceConfig {
        name: "warm-peer".into(),
        duration_s: 6.0 * 3600.0,
        n_nodes: cluster.n_nodes,
        expect_sev1: 0.0,
        expect_other: 0.0,
        repair_min_s: 86400.0,
        repair_max_s: 86400.0,
    };
    let trace = Trace::generate(tc, seed).with_injected_failure(
        NodeId(0),
        2.5 * 3600.0,
        ErrorKind::LostConnection,
    );
    let run_with = |store_aware: bool| {
        let cfg = UnicronConfig { store_aware_recovery: store_aware, ..UnicronConfig::default() };
        Simulator::builder()
            .cluster(cluster.clone())
            .config(cfg)
            .policy(PolicyKind::Unicron)
            .tasks(&specs)
            .build()
            .run(&trace)
    };
    let on = run_with(true);
    let off = run_with(false);
    (trace, on, off)
}

/// Render the `warm-peer` report from already-computed runs.
pub fn warm_peer_render(trace: &Trace, on: &SimResult, off: &SimResult) -> String {
    let mut t = Table::new(&[
        "recovery",
        "accumulated WAF",
        "mean WAF",
        "store restores",
        "restore time",
        "SEV1 transition",
    ]);
    for (label, r) in [("store-aware", on), ("formula-priced", off)] {
        let restore = r
            .store_restores
            .first()
            .map_or("-".into(), |&(_, d)| format!("{d:.3}s"));
        let trans = r
            .transitions
            .first()
            .map_or("-".into(), |&(_, d)| fmt_duration(d));
        t.row(&[
            label.into(),
            format!("{}FLOP·s", fmt_si(r.accumulated_waf)),
            format!("{}FLOP/s", fmt_si(r.mean_waf())),
            r.store_restores.len().to_string(),
            restore,
            trans,
        ]);
    }
    let mut out = format!(
        "warm-peer — one injected SEV1 (node 0, t=2.5h) over {}, GPT-3 7B, 128 GPUs\n{}",
        fmt_duration(trace.config.duration_s),
        t.render()
    );
    let _ = writeln!(
        out,
        "warm-peer advantage: {:.4}× accumulated WAF",
        on.accumulated_waf / off.accumulated_waf.max(1.0)
    );
    if let Some(rep) = &on.store_report {
        let _ = writeln!(
            out,
            "state tier: dedup ratio {:.1}×, restore hits {}, misses {}",
            rep.get("dedup_ratio").and_then(crate::ser::Value::as_f64).unwrap_or(1.0),
            rep.get("hits").and_then(crate::ser::Value::as_u64).unwrap_or(0),
            rep.get("misses").and_then(crate::ser::Value::as_u64).unwrap_or(0),
        );
    }
    out
}

/// The state tier under failover: store-aware recovery on vs off on the
/// injected-SEV1 trace — goodput, the executed restore, and the dedup the
/// delta checkpoints achieved.
pub fn warm_peer(seed: u64) -> String {
    let (trace, on, off) = warm_peer_runs(seed);
    warm_peer_render(&trace, &on, &off)
}

/// The straggler trace and its two Unicron runs: in-band degradation
/// detection on (per-step timing streams feed [`crate::health`], the
/// verdict is priced through the cost ledger, the straggler is evicted)
/// vs off (degradation-oblivious — the slow node drags its cohort for the
/// whole five-hour window). Split out so tests can pin the acceptance
/// property — detect-and-evict goodput ≥ oblivious — without re-parsing
/// the rendered table.
pub fn straggler_evict_runs(seed: u64) -> (Trace, SimResult, SimResult) {
    let cluster = ClusterSpec::default();
    let specs = table3_case(5);
    let tc = TraceConfig {
        name: "straggler-evict".into(),
        duration_s: 6.0 * 3600.0,
        n_nodes: cluster.n_nodes,
        expect_sev1: 0.0,
        expect_other: 0.0,
        repair_min_s: 0.25 * 86400.0,
        repair_max_s: 86400.0,
    };
    // Node 3 starts running ~70% slow at t≈1.1h and stays degraded for five
    // hours. No hard failure ever fires — the gray-failure gap: heartbeats
    // stay green while the slowest data-parallel worker gates its whole
    // cohort. Only the in-band step-timing stream can see it.
    let trace =
        Trace::generate(tc, seed).with_straggler_onset(NodeId(3), 4000.0, 0.7, 18000.0);
    let run_with = |detect: bool| {
        let cfg =
            UnicronConfig { degradation_detection: detect, ..UnicronConfig::default() };
        Simulator::builder()
            .cluster(cluster.clone())
            .config(cfg)
            .policy(PolicyKind::Unicron)
            .tasks(&specs)
            .build()
            .run(&trace)
    };
    let on = run_with(true);
    let off = run_with(false);
    (trace, on, off)
}

/// Render the `straggler-evict` report from already-computed runs.
pub fn straggler_evict_render(trace: &Trace, on: &SimResult, off: &SimResult) -> String {
    let count =
        |r: &SimResult, f: fn(&Action) -> bool| r.decision_log.actions().filter(|&a| f(a)).count();
    let steps = |r: &SimResult| {
        r.decision_log
            .events()
            .filter(|e| matches!(e, CoordEvent::StepTiming { .. }))
            .count()
    };
    let mut t = Table::new(&[
        "degradation detection",
        "accumulated WAF",
        "mean WAF",
        "step reports",
        "evictions",
        "alerts",
    ]);
    for (label, r) in [("detect-and-evict", on), ("oblivious", off)] {
        t.row(&[
            label.into(),
            format!("{}FLOP·s", fmt_si(r.accumulated_waf)),
            format!("{}FLOP/s", fmt_si(r.mean_waf())),
            steps(r).to_string(),
            count(r, |a| matches!(a, Action::IsolateNode { .. })).to_string(),
            count(r, |a| matches!(a, Action::AlertOps { .. })).to_string(),
        ]);
    }
    let mut out = format!(
        "straggler-evict — node 3 runs 70% slow from t=1.1h for 5h ({} hard failures over {})\n{}",
        trace.events.len(),
        fmt_duration(trace.config.duration_s),
        t.render()
    );
    let _ = writeln!(
        out,
        "detection advantage: {:.3}× accumulated WAF",
        on.accumulated_waf / off.accumulated_waf.max(1.0)
    );
    out.push_str("\ncost ledger (Σ over committed plans):\n");
    out.push_str(&ledger_table(&[("detect-and-evict", on), ("oblivious", off)]));
    out
}

/// In-band health observation: detect-and-evict vs degradation-oblivious
/// goodput on the gray straggler trace, with the ledger columns of both.
pub fn straggler_evict(seed: u64) -> String {
    let (trace, on, off) = straggler_evict_runs(seed);
    straggler_evict_render(&trace, &on, &off)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn warm_peer_failover_is_sub_second_and_store_pricing_pays() {
        // the ISSUE acceptance properties: with a resident peer-replica
        // snapshot the SEV1 failover restore completes in under a second of
        // simulated time, and store-aware pricing never loses goodput to
        // the closed-form prior
        let (trace, on, off) = warm_peer_runs(42);
        assert!(!on.store_restores.is_empty(), "the injected SEV1 must restore from the store");
        for &(_, d) in &on.store_restores {
            assert!(d < 1.0, "warm-peer restore must be sub-second: {d}s");
        }
        assert!(off.store_restores.is_empty(), "formula-priced run never touches the store");
        assert!(
            on.accumulated_waf >= off.accumulated_waf,
            "store-aware {} must be >= formula-priced {}",
            on.accumulated_waf,
            off.accumulated_waf
        );
        // residency surfaced to the coordinator as wire-v6 events
        assert!(
            on.decision_log.events().any(|e| matches!(e, CoordEvent::StateResidency { .. })),
            "peer loss must report residency"
        );
        let out = warm_peer_render(&trace, &on, &off);
        assert!(out.contains("warm-peer advantage"));
        assert!(out.contains("store-aware") && out.contains("formula-priced"));
        assert!(out.contains("dedup ratio"));
    }

    #[test]
    fn placement_frag_min_churn_beats_topology_blind() {
        // the acceptance property: consolidation goodput ≥ topology-blind
        // on the fragmented-cluster trace, with strictly fewer nodes moved
        // and strictly less ledger-priced migration
        let (_, churn, blind) = placement_frag_runs(42);
        assert!(
            churn.accumulated_waf >= blind.accumulated_waf,
            "min-churn {} must be >= topology-blind {}",
            churn.accumulated_waf,
            blind.accumulated_waf
        );
        let cost = crate::cost::CostModel::from_config(&UnicronConfig::default());
        let profiles = placement_frag_profiles();
        let (moved_churn, priced_churn, _) = layout_churn(&churn, &profiles, &cost);
        let (moved_blind, priced_blind, _) = layout_churn(&blind, &profiles, &cost);
        assert!(
            moved_churn < moved_blind,
            "min-churn must move fewer nodes: {moved_churn} vs {moved_blind}"
        );
        assert!(
            priced_churn < priced_blind,
            "min-churn must price less migration: {priced_churn} vs {priced_blind}"
        );
        let out = placement_frag(42);
        assert!(out.contains("consolidation advantage"));
        assert!(out.contains("min-churn") && out.contains("topology-blind"));
    }

    #[test]
    fn fig11_surfaces_the_ledger_columns() {
        let out = fig11(TraceConfig::trace_a(), 42);
        assert!(out.contains("cost ledger"), "breakdown columns must be rendered:\n{out}");
        assert!(out.contains("Σ transition pen."));
        assert!(out.contains("Σ detection pen."));
    }

    #[test]
    fn sev1_timeline_renders_an_incident_narrative() {
        let out = sev1_timeline(42);
        assert!(out.starts_with("sev1-timeline —"), "header missing:\n{out}");
        assert!(out.contains("incident timeline —"), "rendered timeline missing:\n{out}");
        assert!(out.contains("recent events:"), "event tail missing:\n{out}");
    }

    #[test]
    fn every_experiment_runs() {
        for exp in EXPERIMENTS {
            let out = run(exp.id, 42).unwrap_or_else(|e| panic!("{}: {e}", exp.id));
            assert!(!out.is_empty(), "{} produced no output", exp.id);
            assert!(!exp.description.is_empty(), "{} has no description", exp.id);
        }
    }

    #[test]
    fn unknown_experiment_error_lists_the_registry() {
        let err = run("fig99", 0).unwrap_err();
        for exp in EXPERIMENTS {
            assert!(err.contains(exp.id), "error must list {}: {err}", exp.id);
        }
        assert!(find("fig99").is_none());
        assert!(find("fig7-churn").is_some());
    }

    #[test]
    fn fig7_churn_counts_lifecycle_decisions() {
        let out = fig7_churn(13);
        assert!(out.contains("Unicron"));
        assert!(out.contains("Megatron"));
        // Unicron row: bootstrap + two arrivals = 3 launches, 2 finishes
        let row = out.lines().find(|l| l.contains("Unicron")).unwrap();
        let cols: Vec<&str> = row.split('|').map(str::trim).collect();
        assert_eq!(cols[2], "3", "launches column: {row}");
        assert_eq!(cols[3], "2", "finishes column: {row}");
    }

    #[test]
    fn fleet_lemon_quarantine_on_beats_off() {
        // the acceptance property: fencing the lemon must pay for the lost
        // capacity on the recurrent-lemon trace
        let (trace, on, off) = fleet_lemon_runs(42);
        assert!(
            on.accumulated_waf >= off.accumulated_waf,
            "quarantine-on {} must be >= quarantine-off {}",
            on.accumulated_waf,
            off.accumulated_waf
        );
        let q = |r: &SimResult| {
            r.decision_log
                .actions()
                .filter(|a| matches!(a, Action::NodeQuarantined { .. }))
                .count()
        };
        assert_eq!(q(&on), 1);
        assert_eq!(q(&off), 0);
        let out = fleet_lemon_render(&trace, &on, &off);
        assert!(out.contains("LEMON"), "the health report must flag node 5:\n{out}");
        assert!(out.contains("quarantine advantage"));
    }

    #[test]
    fn straggler_evict_detection_beats_oblivious() {
        // the acceptance property: pricing the gray straggler through the
        // ledger and evicting it must beat tolerating it for five hours
        let (trace, on, off) = straggler_evict_runs(42);
        assert!(
            on.accumulated_waf > off.accumulated_waf,
            "detect-and-evict {} must beat oblivious {}",
            on.accumulated_waf,
            off.accumulated_waf
        );
        // the eviction is a ledger decision: the committed plan reconciles
        // with a positive degradation penalty
        assert!(
            on.decision_log.actions().any(|a| matches!(
                a,
                Action::ApplyPlan { plan, .. } if plan.breakdown.degradation_penalty > 0.0
            )),
            "eviction replan must carry the degradation term"
        );
        assert!(
            on.decision_log
                .actions()
                .any(|a| matches!(a, Action::IsolateNode { node } if *node == NodeId(3))),
            "the straggler must be evicted"
        );
        // the oblivious run never sees a verdict, so it never isolates
        assert!(
            !off.decision_log.actions().any(|a| matches!(a, Action::IsolateNode { .. })),
            "degradation-oblivious run must not evict"
        );
        let out = straggler_evict_render(&trace, &on, &off);
        assert!(out.contains("detection advantage"));
        assert!(out.contains("detect-and-evict") && out.contains("oblivious"));
        assert!(out.contains("Σ degradation pen."));
    }

    #[test]
    fn fig1_contains_headline_rate() {
        assert!(fig1().contains("43.4%"));
    }

    #[test]
    fn fig2_totals_68_minutes() {
        assert!(fig2().contains("1h08m00s"));
    }

    #[test]
    fn fig3a_orders_systems() {
        let out = fig3a();
        let pos = |s: &str| out.find(s).unwrap();
        assert!(pos("Unicron") < pos("Oobleck"));
        assert!(out.contains("1.00×"));
        assert!(out.contains("0.28×"), "Oobleck efficiency row: {out}");
    }

    #[test]
    fn fig4_reports_infeasible_and_feasible() {
        let out = fig4();
        assert!(out.contains("infeasible"));
        assert!(out.contains("%"));
        assert!(out.contains("non-monotonic"), "should flag the Fig.4 dip");
    }

    #[test]
    fn fig10c_unicron_never_loses() {
        let out = fig10c();
        // every ratio printed is >= 1.0 (Unicron plan dominates)
        for cap in out.match_indices('(').map(|(i, _)| &out[i + 1..]) {
            if let Some(x) = cap.split('×').next() {
                if let Ok(v) = x.parse::<f64>() {
                    assert!(v >= 0.999, "ratio {v} < 1 in {out}");
                }
            }
        }
    }

    #[test]
    fn fig11a_headline_band() {
        let out = fig11(TraceConfig::trace_a(), 42);
        assert!(out.contains("Unicron"));
        assert!(out.contains("Megatron"));
        // the Megatron advantage row should be ~1.1-1.6×
        let idx = out.find("Megatron").unwrap();
        let row = &out[idx..out[idx..].find('\n').unwrap() + idx];
        let adv: f64 = row.rsplit('|').nth(1).unwrap().trim().trim_end_matches('×').parse().unwrap();
        assert!((1.05..1.7).contains(&adv), "trace-a advantage {adv} from row {row:?}");
    }
}
