//! Deterministic random numbers + the distributions the failure models need.
//!
//! No `rand` crate in the vendored registry, so this implements
//! xoshiro256++ on top of `rand_core::RngCore` plus the samplers used across
//! the repo: uniform, normal (Box–Muller), exponential and Poisson — the
//! latter two drive the paper's failure traces (§7.5: Poisson arrivals,
//! uniform 1–7-day repairs).

use rand_core::RngCore;

/// xoshiro256++ 1.0 (Blackman & Vigna). Fast, 256-bit state, passes BigCrush.
#[derive(Debug, Clone)]
pub struct Xoshiro256 {
    s: [u64; 4],
}

impl Xoshiro256 {
    /// Seed via SplitMix64 so that any u64 (including 0) gives a good state.
    pub fn seed_from_u64(seed: u64) -> Self {
        let mut sm = seed;
        let mut next = || {
            sm = sm.wrapping_add(0x9E37_79B9_7F4A_7C15);
            let mut z = sm;
            z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
            z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
            z ^ (z >> 31)
        };
        Self { s: [next(), next(), next(), next()] }
    }

    /// Independent child stream (for per-node / per-task generators).
    pub fn fork(&mut self, stream: u64) -> Self {
        Self::seed_from_u64(self.next_u64() ^ stream.wrapping_mul(0x9E37_79B9_7F4A_7C15))
    }
}

impl RngCore for Xoshiro256 {
    fn next_u32(&mut self) -> u32 {
        (self.next_u64() >> 32) as u32
    }

    fn next_u64(&mut self) -> u64 {
        let s = &mut self.s;
        let result = s[0].wrapping_add(s[3]).rotate_left(23).wrapping_add(s[0]);
        let t = s[1] << 17;
        s[2] ^= s[0];
        s[3] ^= s[1];
        s[1] ^= s[2];
        s[0] ^= s[3];
        s[2] ^= t;
        s[3] = s[3].rotate_left(45);
        result
    }

    fn fill_bytes(&mut self, dest: &mut [u8]) {
        for chunk in dest.chunks_mut(8) {
            let v = self.next_u64().to_le_bytes();
            chunk.copy_from_slice(&v[..chunk.len()]);
        }
    }

    fn try_fill_bytes(&mut self, dest: &mut [u8]) -> Result<(), rand_core::Error> {
        self.fill_bytes(dest);
        Ok(())
    }
}

/// Convenience samplers over any `RngCore`.
pub trait Rand: RngCore {
    /// Uniform in `[0, 1)`.
    fn f64(&mut self) -> f64 {
        // 53 random mantissa bits.
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform in `[lo, hi)`.
    fn uniform(&mut self, lo: f64, hi: f64) -> f64 {
        lo + (hi - lo) * self.f64()
    }

    /// Uniform integer in `[0, n)` (Lemire's method, bias-free).
    fn below(&mut self, n: u64) -> u64 {
        assert!(n > 0);
        let mut x = self.next_u64();
        let mut m = (x as u128) * (n as u128);
        let mut lo = m as u64;
        if lo < n {
            let t = n.wrapping_neg() % n;
            while lo < t {
                x = self.next_u64();
                m = (x as u128) * (n as u128);
                lo = m as u64;
            }
        }
        (m >> 64) as u64
    }

    /// Uniform integer in `[lo, hi]` inclusive.
    fn range_inclusive(&mut self, lo: u64, hi: u64) -> u64 {
        assert!(hi >= lo);
        lo + self.below(hi - lo + 1)
    }

    /// Standard normal via Box–Muller.
    fn normal(&mut self) -> f64 {
        let u1 = (1.0 - self.f64()).max(f64::MIN_POSITIVE); // (0,1]
        let u2 = self.f64();
        (-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos()
    }

    /// Exponential with rate `lambda` (mean `1/lambda`): inter-arrival times
    /// of a Poisson failure process.
    fn exponential(&mut self, lambda: f64) -> f64 {
        assert!(lambda > 0.0);
        -(1.0 - self.f64()).ln() / lambda
    }

    /// Poisson count with mean `lambda`. Knuth for small lambda, normal
    /// approximation above 64 (adequate for trace generation).
    fn poisson(&mut self, lambda: f64) -> u64 {
        assert!(lambda >= 0.0);
        if lambda == 0.0 {
            return 0;
        }
        if lambda > 64.0 {
            let x = lambda + lambda.sqrt() * self.normal();
            return x.max(0.0).round() as u64;
        }
        let l = (-lambda).exp();
        let mut k = 0u64;
        let mut p = 1.0;
        loop {
            p *= self.f64();
            if p <= l {
                return k;
            }
            k += 1;
        }
    }

    /// Fisher–Yates shuffle.
    fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i as u64 + 1) as usize;
            xs.swap(i, j);
        }
    }

    /// Pick a uniformly random element.
    fn choose<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        &xs[self.below(xs.len() as u64) as usize]
    }
}

impl<R: RngCore + ?Sized> Rand for R {}

#[cfg(test)]
mod tests {
    use super::*;

    fn rng() -> Xoshiro256 {
        Xoshiro256::seed_from_u64(42)
    }

    #[test]
    fn deterministic_for_same_seed() {
        let mut a = rng();
        let mut b = rng();
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn forked_streams_differ() {
        let mut a = rng();
        let mut c = a.fork(1);
        let mut d = a.fork(2);
        assert_ne!(c.next_u64(), d.next_u64());
    }

    #[test]
    fn f64_in_unit_interval() {
        let mut r = rng();
        for _ in 0..10_000 {
            let x = r.f64();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn below_is_in_range_and_roughly_uniform() {
        let mut r = rng();
        let mut counts = [0usize; 10];
        for _ in 0..100_000 {
            counts[r.below(10) as usize] += 1;
        }
        for &c in &counts {
            assert!((8_000..12_000).contains(&c), "bucket count {c}");
        }
    }

    #[test]
    fn normal_moments() {
        let mut r = rng();
        let n = 200_000;
        let (mut sum, mut sq) = (0.0, 0.0);
        for _ in 0..n {
            let x = r.normal();
            sum += x;
            sq += x * x;
        }
        let mean = sum / n as f64;
        let var = sq / n as f64 - mean * mean;
        assert!(mean.abs() < 0.02, "mean {mean}");
        assert!((var - 1.0).abs() < 0.03, "var {var}");
    }

    #[test]
    fn exponential_mean() {
        let mut r = rng();
        let n = 100_000;
        let lambda = 0.5;
        let mean: f64 = (0..n).map(|_| r.exponential(lambda)).sum::<f64>() / n as f64;
        assert!((mean - 2.0).abs() < 0.05, "mean {mean}");
    }

    #[test]
    fn poisson_mean_small_and_large_lambda() {
        let mut r = rng();
        for &lambda in &[0.5, 4.0, 100.0] {
            let n = 50_000;
            let mean: f64 = (0..n).map(|_| r.poisson(lambda) as f64).sum::<f64>() / n as f64;
            assert!((mean - lambda).abs() < lambda.max(1.0) * 0.05, "lambda {lambda} mean {mean}");
        }
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = rng();
        let mut xs: Vec<u32> = (0..100).collect();
        r.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
    }

    #[test]
    fn range_inclusive_hits_bounds() {
        let mut r = rng();
        let mut saw_lo = false;
        let mut saw_hi = false;
        for _ in 0..10_000 {
            match r.range_inclusive(3, 5) {
                3 => saw_lo = true,
                5 => saw_hi = true,
                4 => {}
                other => panic!("out of range: {other}"),
            }
        }
        assert!(saw_lo && saw_hi);
    }
}
