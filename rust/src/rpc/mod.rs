//! Framed-JSON RPC over TCP: the wire substrate for agent↔coordinator and
//! the kvstore protocol (no tokio in the vendored registry — blocking I/O,
//! one thread per connection, which is fine at workload-manager scale:
//! one connection per *node*, not per request).
//!
//! Frame format: `u32` little-endian payload length, then that many bytes of
//! UTF-8 JSON. Max frame 64 MiB (guards against corrupt length prefixes).

use anyhow::{anyhow, bail, Result};
use std::io::{Read, Write};
use std::net::{TcpListener, TcpStream, ToSocketAddrs};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::thread::JoinHandle;
use std::time::Duration;

use crate::ser::Value;

const MAX_FRAME: u32 = 64 * 1024 * 1024;

/// Write one JSON frame.
pub fn send_msg(stream: &mut TcpStream, msg: &Value) -> Result<()> {
    let body = msg.encode();
    let len = body.len() as u32;
    if len > MAX_FRAME {
        bail!("frame too large: {len} bytes");
    }
    stream.write_all(&len.to_le_bytes())?;
    stream.write_all(body.as_bytes())?;
    stream.flush()?;
    Ok(())
}

/// Read one JSON frame (blocking; respects the stream's read timeout).
pub fn recv_msg(stream: &mut TcpStream) -> Result<Value> {
    let mut len_buf = [0u8; 4];
    stream.read_exact(&mut len_buf)?;
    let len = u32::from_le_bytes(len_buf);
    if len > MAX_FRAME {
        bail!("frame too large: {len} bytes");
    }
    let mut body = vec![0u8; len as usize];
    stream.read_exact(&mut body)?;
    let text = String::from_utf8(body)?;
    Value::parse(&text).map_err(|e| anyhow!("bad frame: {e}"))
}

/// Request helper: adds a `method` tag.
pub fn request(method: &str) -> Value {
    Value::obj().with("method", method)
}

/// Response helpers.
pub fn ok_response() -> Value {
    Value::obj().with("ok", true)
}

pub fn err_response(msg: &str) -> Value {
    Value::obj().with("ok", false).with("error", msg)
}

/// True if a response frame signals success.
pub fn is_ok(v: &Value) -> bool {
    v.get("ok").and_then(Value::as_bool).unwrap_or(false)
}

/// A blocking RPC server: one handler thread per connection.
pub struct Server {
    pub addr: std::net::SocketAddr,
    stop: Arc<AtomicBool>,
    accept_thread: Option<JoinHandle<()>>,
}

impl Server {
    /// Start serving on `addr` (use port 0 for an ephemeral port). The
    /// handler is invoked per request frame; its return value is the
    /// response frame. A handler may take over the connection for streaming
    /// by returning `None` from `on_connect`-style logic — here we keep the
    /// simple request/response discipline and let kvstore watches run on a
    /// dedicated subscription connection.
    pub fn serve<F>(addr: impl ToSocketAddrs, handler: F) -> Result<Server>
    where
        F: Fn(Value, &mut TcpStream) -> Option<Value> + Send + Sync + 'static,
    {
        let listener = TcpListener::bind(addr)?;
        let local = listener.local_addr()?;
        listener.set_nonblocking(true)?;
        let stop = Arc::new(AtomicBool::new(false));
        let stop2 = stop.clone();
        let handler = Arc::new(handler);
        let accept_thread = std::thread::Builder::new()
            .name("rpc-accept".into())
            .spawn(move || {
                while !stop2.load(Ordering::Relaxed) {
                    match listener.accept() {
                        Ok((mut stream, _peer)) => {
                            let h = handler.clone();
                            let stop3 = stop2.clone();
                            let _ = std::thread::Builder::new().name("rpc-conn".into()).spawn(
                                move || {
                                    stream.set_nodelay(true).ok();
                                    // periodic timeout so the thread notices shutdown
                                    stream
                                        .set_read_timeout(Some(Duration::from_millis(200)))
                                        .ok();
                                    loop {
                                        if stop3.load(Ordering::Relaxed) {
                                            return;
                                        }
                                        match recv_msg(&mut stream) {
                                            Ok(req) => {
                                                if let Some(resp) = h(req, &mut stream) {
                                                    if send_msg(&mut stream, &resp).is_err() {
                                                        return;
                                                    }
                                                }
                                            }
                                            Err(e) => {
                                                // timeout => retry; disconnect => exit
                                                if let Some(ioe) =
                                                    e.downcast_ref::<std::io::Error>()
                                                {
                                                    if matches!(
                                                        ioe.kind(),
                                                        std::io::ErrorKind::WouldBlock
                                                            | std::io::ErrorKind::TimedOut
                                                    ) {
                                                        continue;
                                                    }
                                                }
                                                return;
                                            }
                                        }
                                    }
                                },
                            );
                        }
                        Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                            std::thread::sleep(Duration::from_millis(5));
                        }
                        Err(_) => return,
                    }
                }
            })?;
        Ok(Server { addr: local, stop, accept_thread: Some(accept_thread) })
    }

    pub fn shutdown(&mut self) {
        self.stop.store(true, Ordering::Relaxed);
        if let Some(h) = self.accept_thread.take() {
            let _ = h.join();
        }
    }
}

impl Drop for Server {
    fn drop(&mut self) {
        self.shutdown();
    }
}

/// Blocking RPC client with one persistent connection.
pub struct Client {
    stream: TcpStream,
}

impl Client {
    pub fn connect(addr: impl ToSocketAddrs) -> Result<Client> {
        let stream = TcpStream::connect(addr)?;
        stream.set_nodelay(true)?;
        Ok(Client { stream })
    }

    pub fn connect_timeout(addr: &std::net::SocketAddr, timeout: Duration) -> Result<Client> {
        let stream = TcpStream::connect_timeout(addr, timeout)?;
        stream.set_nodelay(true)?;
        Ok(Client { stream })
    }

    /// One request/response round trip.
    pub fn call(&mut self, req: &Value) -> Result<Value> {
        send_msg(&mut self.stream, req)?;
        recv_msg(&mut self.stream)
    }

    /// Read the next pushed frame (subscription streams).
    pub fn next_push(&mut self) -> Result<Value> {
        recv_msg(&mut self.stream)
    }

    pub fn set_read_timeout(&mut self, t: Option<Duration>) -> Result<()> {
        self.stream.set_read_timeout(t)?;
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_echo() {
        let mut server = Server::serve("127.0.0.1:0", |req, _s| {
            Some(ok_response().with("echo", req.get("msg").cloned().unwrap_or(Value::Null)))
        })
        .unwrap();
        let mut c = Client::connect(server.addr).unwrap();
        let resp = c.call(&request("echo").with("msg", "hello")).unwrap();
        assert!(is_ok(&resp));
        assert_eq!(resp.get("echo").unwrap().as_str(), Some("hello"));
        server.shutdown();
    }

    #[test]
    fn multiple_clients_and_requests() {
        let server = Server::serve("127.0.0.1:0", |req, _s| {
            let x = req.get("x").and_then(Value::as_f64).unwrap_or(0.0);
            Some(ok_response().with("y", x * 2.0))
        })
        .unwrap();
        let mut handles = Vec::new();
        for i in 0..4 {
            let addr = server.addr;
            handles.push(std::thread::spawn(move || {
                let mut c = Client::connect(addr).unwrap();
                for j in 0..10 {
                    let v = (i * 10 + j) as f64;
                    let resp = c.call(&request("double").with("x", v)).unwrap();
                    assert_eq!(resp.get("y").unwrap().as_f64(), Some(v * 2.0));
                }
            }));
        }
        for h in handles {
            h.join().unwrap();
        }
    }

    #[test]
    fn error_response_shape() {
        let e = err_response("boom");
        assert!(!is_ok(&e));
        assert_eq!(e.get("error").unwrap().as_str(), Some("boom"));
    }

    #[test]
    fn oversize_frame_rejected() {
        // construct a client-side check: sending is refused before the wire
        let huge = "x".repeat((MAX_FRAME + 1) as usize);
        let v = Value::obj().with("data", huge.as_str());
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        let _accept = std::thread::spawn(move || {
            let _ = listener.accept();
        });
        let mut stream = TcpStream::connect(addr).unwrap();
        assert!(send_msg(&mut stream, &v).is_err());
    }
}
