//! Framed-JSON RPC over TCP: the wire substrate for agent↔coordinator and
//! the kvstore protocol (no tokio in the vendored registry — blocking I/O,
//! one thread per connection, which is fine at workload-manager scale:
//! one connection per *node*, not per request).
//!
//! Frame format: `u32` little-endian payload length, then that many bytes of
//! UTF-8 JSON. Max frame 64 MiB (guards against corrupt length prefixes).

use anyhow::{anyhow, Result};
use std::fmt;
use std::io::{Read, Write};
use std::net::{TcpListener, TcpStream, ToSocketAddrs};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::thread::JoinHandle;
use std::time::Duration;

use crate::ser::Value;

/// Maximum frame body length — guards against corrupt length prefixes on
/// receive and runaway payloads on send.
pub const MAX_FRAME: u32 = 64 * 1024 * 1024;

/// How many consecutive zero-progress read timeouts mid-frame we tolerate
/// before declaring the frame [`FrameError::Truncated`]. With the server's
/// 200 ms poll timeout this bounds a stalled peer to ~30 s instead of
/// holding the connection thread forever.
const MAX_MIDFRAME_STALLS: u32 = 150;

/// Typed frame-codec failure: the two ways a length-prefixed frame can be
/// structurally bad on the wire. Transport failures (reset, refused, poll
/// timeouts between frames) stay `std::io::Error`; a `FrameError` always
/// means the connection is desynced and must be dropped. Retrieve from an
/// [`anyhow::Error`] with `e.downcast_ref::<FrameError>()`.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum FrameError {
    /// The length prefix (or outgoing body) exceeds [`MAX_FRAME`].
    Oversized { len: u64, max: u32 },
    /// The peer closed (or stalled) mid-frame: `got` of `want` bytes read.
    Truncated { got: usize, want: usize },
}

impl fmt::Display for FrameError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            FrameError::Oversized { len, max } => {
                write!(f, "frame oversized: {len} bytes (max {max})")
            }
            FrameError::Truncated { got, want } => {
                write!(f, "frame truncated: got {got} of {want} bytes")
            }
        }
    }
}

impl std::error::Error for FrameError {}

/// Write one JSON frame. An oversized body is refused before any bytes hit
/// the wire ([`FrameError::Oversized`]).
pub fn send_msg(stream: &mut TcpStream, msg: &Value) -> Result<()> {
    let body = msg.encode();
    if body.len() as u64 > MAX_FRAME as u64 {
        return Err(FrameError::Oversized { len: body.len() as u64, max: MAX_FRAME }.into());
    }
    let len = body.len() as u32;
    stream.write_all(&len.to_le_bytes())?;
    stream.write_all(body.as_bytes())?;
    stream.flush()?;
    Ok(())
}

/// Fill `buf`, counting progress. A read timeout with **zero** bytes read
/// so far is surfaced as the underlying `io::Error` only when `idle_ok`
/// (the between-frames poll position); once any byte of a frame has
/// arrived, timeouts keep waiting (bounded by [`MAX_MIDFRAME_STALLS`]) and
/// EOF or a stall bound becomes a typed [`FrameError::Truncated`] — never
/// a silent partial read.
fn read_exact_counted(stream: &mut TcpStream, buf: &mut [u8], idle_ok: bool) -> Result<usize> {
    let mut got = 0usize;
    let mut stalls = 0u32;
    while got < buf.len() {
        match stream.read(&mut buf[got..]) {
            Ok(0) => return Err(FrameError::Truncated { got, want: buf.len() }.into()),
            Ok(n) => {
                got += n;
                stalls = 0;
            }
            Err(e) if e.kind() == std::io::ErrorKind::Interrupted => {}
            Err(e)
                if matches!(
                    e.kind(),
                    std::io::ErrorKind::WouldBlock | std::io::ErrorKind::TimedOut
                ) =>
            {
                if got == 0 && idle_ok {
                    return Err(e.into());
                }
                stalls += 1;
                if stalls >= MAX_MIDFRAME_STALLS {
                    return Err(FrameError::Truncated { got, want: buf.len() }.into());
                }
            }
            Err(e) => return Err(e.into()),
        }
    }
    Ok(got)
}

/// Read one JSON frame (blocking; respects the stream's read timeout).
/// Structural failures — a length prefix beyond [`MAX_FRAME`], a peer that
/// closes or stalls mid-frame — come back as typed [`FrameError`]s; an idle
/// poll timeout before any byte arrives stays an `io::Error` so server
/// loops can keep polling.
pub fn recv_msg(stream: &mut TcpStream) -> Result<Value> {
    let mut len_buf = [0u8; 4];
    read_exact_counted(stream, &mut len_buf, true)?;
    let len = u32::from_le_bytes(len_buf);
    if len > MAX_FRAME {
        return Err(FrameError::Oversized { len: len as u64, max: MAX_FRAME }.into());
    }
    let mut body = vec![0u8; len as usize];
    read_exact_counted(stream, &mut body, false)?;
    let text = String::from_utf8(body)?;
    Value::parse(&text).map_err(|e| anyhow!("bad frame: {e}"))
}

/// Request helper: adds a `method` tag.
pub fn request(method: &str) -> Value {
    Value::obj().with("method", method)
}

/// Response helpers.
pub fn ok_response() -> Value {
    Value::obj().with("ok", true)
}

pub fn err_response(msg: &str) -> Value {
    Value::obj().with("ok", false).with("error", msg)
}

/// True if a response frame signals success.
pub fn is_ok(v: &Value) -> bool {
    v.get("ok").and_then(Value::as_bool).unwrap_or(false)
}

/// A blocking RPC server: one handler thread per connection.
pub struct Server {
    pub addr: std::net::SocketAddr,
    stop: Arc<AtomicBool>,
    accept_thread: Option<JoinHandle<()>>,
}

impl Server {
    /// Start serving on `addr` (use port 0 for an ephemeral port). The
    /// handler is invoked per request frame; its return value is the
    /// response frame. A handler may take over the connection for streaming
    /// by returning `None` from `on_connect`-style logic — here we keep the
    /// simple request/response discipline and let kvstore watches run on a
    /// dedicated subscription connection.
    pub fn serve<F>(addr: impl ToSocketAddrs, handler: F) -> Result<Server>
    where
        F: Fn(Value, &mut TcpStream) -> Option<Value> + Send + Sync + 'static,
    {
        let listener = TcpListener::bind(addr)?;
        let local = listener.local_addr()?;
        listener.set_nonblocking(true)?;
        let stop = Arc::new(AtomicBool::new(false));
        let stop2 = stop.clone();
        let handler = Arc::new(handler);
        let accept_thread = std::thread::Builder::new()
            .name("rpc-accept".into())
            .spawn(move || {
                while !stop2.load(Ordering::Relaxed) {
                    match listener.accept() {
                        Ok((mut stream, _peer)) => {
                            let h = handler.clone();
                            let stop3 = stop2.clone();
                            let _ = std::thread::Builder::new().name("rpc-conn".into()).spawn(
                                move || {
                                    stream.set_nodelay(true).ok();
                                    // periodic timeout so the thread notices shutdown
                                    stream
                                        .set_read_timeout(Some(Duration::from_millis(200)))
                                        .ok();
                                    loop {
                                        if stop3.load(Ordering::Relaxed) {
                                            return;
                                        }
                                        match recv_msg(&mut stream) {
                                            Ok(req) => {
                                                if let Some(resp) = h(req, &mut stream) {
                                                    if send_msg(&mut stream, &resp).is_err() {
                                                        return;
                                                    }
                                                }
                                            }
                                            Err(e) => {
                                                // timeout => retry; disconnect => exit
                                                if let Some(ioe) =
                                                    e.downcast_ref::<std::io::Error>()
                                                {
                                                    if matches!(
                                                        ioe.kind(),
                                                        std::io::ErrorKind::WouldBlock
                                                            | std::io::ErrorKind::TimedOut
                                                    ) {
                                                        continue;
                                                    }
                                                }
                                                return;
                                            }
                                        }
                                    }
                                },
                            );
                        }
                        Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                            std::thread::sleep(Duration::from_millis(5));
                        }
                        Err(_) => return,
                    }
                }
            })?;
        Ok(Server { addr: local, stop, accept_thread: Some(accept_thread) })
    }

    pub fn shutdown(&mut self) {
        self.stop.store(true, Ordering::Relaxed);
        if let Some(h) = self.accept_thread.take() {
            let _ = h.join();
        }
    }
}

impl Drop for Server {
    fn drop(&mut self) {
        self.shutdown();
    }
}

/// Blocking RPC client with one persistent connection.
pub struct Client {
    stream: TcpStream,
}

impl Client {
    pub fn connect(addr: impl ToSocketAddrs) -> Result<Client> {
        let stream = TcpStream::connect(addr)?;
        stream.set_nodelay(true)?;
        Ok(Client { stream })
    }

    pub fn connect_timeout(addr: &std::net::SocketAddr, timeout: Duration) -> Result<Client> {
        let stream = TcpStream::connect_timeout(addr, timeout)?;
        stream.set_nodelay(true)?;
        Ok(Client { stream })
    }

    /// One request/response round trip.
    pub fn call(&mut self, req: &Value) -> Result<Value> {
        send_msg(&mut self.stream, req)?;
        recv_msg(&mut self.stream)
    }

    /// Read the next pushed frame (subscription streams).
    pub fn next_push(&mut self) -> Result<Value> {
        recv_msg(&mut self.stream)
    }

    /// One-way frame with no response read (subscription acks).
    pub fn send(&mut self, msg: &Value) -> Result<()> {
        send_msg(&mut self.stream, msg)
    }

    pub fn set_read_timeout(&mut self, t: Option<Duration>) -> Result<()> {
        self.stream.set_read_timeout(t)?;
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_echo() {
        let mut server = Server::serve("127.0.0.1:0", |req, _s| {
            Some(ok_response().with("echo", req.get("msg").cloned().unwrap_or(Value::Null)))
        })
        .unwrap();
        let mut c = Client::connect(server.addr).unwrap();
        let resp = c.call(&request("echo").with("msg", "hello")).unwrap();
        assert!(is_ok(&resp));
        assert_eq!(resp.get("echo").unwrap().as_str(), Some("hello"));
        server.shutdown();
    }

    #[test]
    fn multiple_clients_and_requests() {
        let server = Server::serve("127.0.0.1:0", |req, _s| {
            let x = req.get("x").and_then(Value::as_f64).unwrap_or(0.0);
            Some(ok_response().with("y", x * 2.0))
        })
        .unwrap();
        let mut handles = Vec::new();
        for i in 0..4 {
            let addr = server.addr;
            handles.push(std::thread::spawn(move || {
                let mut c = Client::connect(addr).unwrap();
                for j in 0..10 {
                    let v = (i * 10 + j) as f64;
                    let resp = c.call(&request("double").with("x", v)).unwrap();
                    assert_eq!(resp.get("y").unwrap().as_f64(), Some(v * 2.0));
                }
            }));
        }
        for h in handles {
            h.join().unwrap();
        }
    }

    #[test]
    fn error_response_shape() {
        let e = err_response("boom");
        assert!(!is_ok(&e));
        assert_eq!(e.get("error").unwrap().as_str(), Some("boom"));
    }

    #[test]
    fn oversize_frame_rejected() {
        // construct a client-side check: sending is refused before the wire
        let huge = "x".repeat((MAX_FRAME + 1) as usize);
        let v = Value::obj().with("data", huge.as_str());
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        let _accept = std::thread::spawn(move || {
            let _ = listener.accept();
        });
        let mut stream = TcpStream::connect(addr).unwrap();
        let err = send_msg(&mut stream, &v).unwrap_err();
        match err.downcast_ref::<FrameError>() {
            Some(FrameError::Oversized { len, max }) => {
                assert!(*len > MAX_FRAME as u64);
                assert_eq!(*max, MAX_FRAME);
            }
            other => panic!("expected typed Oversized, got {other:?} ({err})"),
        }
    }

    /// A loopback (client, server-side) stream pair for codec tests.
    fn stream_pair() -> (TcpStream, TcpStream) {
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        let client = TcpStream::connect(addr).unwrap();
        let (server, _) = listener.accept().unwrap();
        (client, server)
    }

    #[test]
    fn oversized_length_prefix_is_typed_error() {
        let (mut client, mut server) = stream_pair();
        // a corrupt length prefix claiming a frame beyond MAX_FRAME: the
        // receiver must refuse it *before* allocating or reading the body
        let bad_len = MAX_FRAME + 1;
        client.write_all(&bad_len.to_le_bytes()).unwrap();
        client.flush().unwrap();
        let err = recv_msg(&mut server).unwrap_err();
        match err.downcast_ref::<FrameError>() {
            Some(FrameError::Oversized { len, max }) => {
                assert_eq!(*len, bad_len as u64);
                assert_eq!(*max, MAX_FRAME);
            }
            other => panic!("expected typed Oversized, got {other:?} ({err})"),
        }
    }

    #[test]
    fn truncated_frame_is_typed_error() {
        let (mut client, mut server) = stream_pair();
        // announce a 100-byte body, deliver 10, then close the connection
        client.write_all(&100u32.to_le_bytes()).unwrap();
        client.write_all(&[b'x'; 10]).unwrap();
        client.flush().unwrap();
        drop(client);
        let err = recv_msg(&mut server).unwrap_err();
        match err.downcast_ref::<FrameError>() {
            Some(FrameError::Truncated { got, want }) => {
                assert_eq!(*got, 10);
                assert_eq!(*want, 100);
            }
            other => panic!("expected typed Truncated, got {other:?} ({err})"),
        }
    }

    #[test]
    fn truncated_length_prefix_is_typed_error() {
        let (mut client, mut server) = stream_pair();
        // even the 4-byte header is covered: 2 bytes then EOF
        client.write_all(&[1u8, 0]).unwrap();
        client.flush().unwrap();
        drop(client);
        let err = recv_msg(&mut server).unwrap_err();
        assert_eq!(
            err.downcast_ref::<FrameError>(),
            Some(&FrameError::Truncated { got: 2, want: 4 })
        );
    }

    #[test]
    fn idle_poll_timeout_stays_io_error() {
        // between frames, a read timeout is the server loop's poll tick —
        // it must stay an io::Error (retry), not a typed FrameError (drop)
        let (_client, mut server) = stream_pair();
        server.set_read_timeout(Some(Duration::from_millis(20))).unwrap();
        let err = recv_msg(&mut server).unwrap_err();
        assert!(err.downcast_ref::<FrameError>().is_none());
        let ioe = err.downcast_ref::<std::io::Error>().expect("io error");
        assert!(matches!(
            ioe.kind(),
            std::io::ErrorKind::WouldBlock | std::io::ErrorKind::TimedOut
        ));
    }
}
