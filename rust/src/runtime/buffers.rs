//! Host-side tensor math for the L3 hot path: gradient accumulation and the
//! data-parallel all-reduce (paper Eq. 6) are done here, in Rust, so the
//! coordinator can split a global batch across DP workers and merge partial
//! results even when a worker dies mid-iteration (Eq. 7).

/// `dst += src`, elementwise over a tensor list.
pub fn add_assign(dst: &mut [Vec<f32>], src: &[Vec<f32>]) {
    assert_eq!(dst.len(), src.len(), "tensor-list arity mismatch");
    for (d, s) in dst.iter_mut().zip(src) {
        assert_eq!(d.len(), s.len(), "tensor length mismatch");
        for (x, y) in d.iter_mut().zip(s) {
            *x += *y;
        }
    }
}

/// `dst *= k`, elementwise over a tensor list.
pub fn scale(dst: &mut [Vec<f32>], k: f32) {
    for d in dst.iter_mut() {
        for x in d.iter_mut() {
            *x *= k;
        }
    }
}

/// Sum-reduce the gradient sets of all DP ranks into one (ranks may be empty
/// when workers died; at least one contribution is required), then divide by
/// `total_micro_batches` to recover the mean over the global batch.
///
/// This mirrors Eq. 6: `grad = (1/B) Σ_i Σ_j grad_{i,j}` where each rank's
/// contribution is already a *sum* over its micro-batches.
pub fn allreduce_sum(mut ranks: Vec<Vec<Vec<f32>>>, total_micro_batches: usize) -> Vec<Vec<f32>> {
    assert!(!ranks.is_empty(), "allreduce over zero contributions");
    assert!(total_micro_batches > 0);
    let mut acc = ranks.remove(0);
    for r in ranks {
        add_assign(&mut acc, &r);
    }
    scale(&mut acc, 1.0 / total_micro_batches as f32);
    acc
}

/// Global L2 norm across a tensor list (diagnostics / grad-norm logging).
pub fn l2_norm(xs: &[Vec<f32>]) -> f64 {
    xs.iter().flat_map(|t| t.iter()).map(|&x| (x as f64) * (x as f64)).sum::<f64>().sqrt()
}

/// Allocate a zeroed gradient accumulator shaped like `like`.
pub fn zeros_like(like: &[Vec<f32>]) -> Vec<Vec<f32>> {
    like.iter().map(|t| vec![0.0; t.len()]).collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn add_assign_elementwise() {
        let mut a = vec![vec![1.0, 2.0], vec![3.0]];
        add_assign(&mut a, &[vec![10.0, 20.0], vec![30.0]]);
        assert_eq!(a, vec![vec![11.0, 22.0], vec![33.0]]);
    }

    #[test]
    #[should_panic(expected = "arity")]
    fn add_assign_rejects_arity_mismatch() {
        let mut a = vec![vec![1.0]];
        add_assign(&mut a, &[vec![1.0], vec![2.0]]);
    }

    #[test]
    fn allreduce_means_over_microbatches() {
        // two ranks, each the sum of 2 micro-batches; 4 total micro-batches
        let r1 = vec![vec![4.0, 8.0]];
        let r2 = vec![vec![0.0, 4.0]];
        let out = allreduce_sum(vec![r1, r2], 4);
        assert_eq!(out, vec![vec![1.0, 3.0]]);
    }

    #[test]
    fn allreduce_single_rank() {
        let out = allreduce_sum(vec![vec![vec![2.0, 4.0]]], 2);
        assert_eq!(out, vec![vec![1.0, 2.0]]);
    }

    #[test]
    fn l2_norm_and_zeros() {
        let xs = vec![vec![3.0, 0.0], vec![4.0]];
        assert!((l2_norm(&xs) - 5.0).abs() < 1e-12);
        let z = zeros_like(&xs);
        assert_eq!(z, vec![vec![0.0, 0.0], vec![0.0]]);
        assert_eq!(l2_norm(&z), 0.0);
    }
}
