//! Artifact manifest: the contract between `aot.py` and the Rust runtime.
//!
//! `manifest.json` pins the parameter tensor order (JAX dict-flatten order),
//! shapes, initializer specs, the micro-batch token shape, and the model
//! hyper-parameters — everything Rust needs to construct literals, initialize
//! state, and budget memory without ever importing Python.

use anyhow::{anyhow, bail, Context, Result};
use std::fs;
use std::path::Path;

use crate::ser::Value;

/// Initializer of one tensor (`init` column of the manifest).
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum InitKind {
    Zeros,
    Ones,
    Normal(f32),
}

impl InitKind {
    pub fn parse(s: &str) -> Result<InitKind> {
        if s == "zeros" {
            Ok(InitKind::Zeros)
        } else if s == "ones" {
            Ok(InitKind::Ones)
        } else if let Some(std) = s.strip_prefix("normal:") {
            Ok(InitKind::Normal(std.parse().with_context(|| format!("bad init {s:?}"))?))
        } else {
            bail!("unknown init kind {s:?}")
        }
    }
}

/// One parameter tensor.
#[derive(Debug, Clone, PartialEq)]
pub struct ParamSpec {
    pub name: String,
    pub shape: Vec<usize>,
    pub elems: usize,
    pub init: InitKind,
    pub decay: bool,
}

/// Parsed `manifest.json`.
#[derive(Debug, Clone)]
pub struct Manifest {
    pub name: String,
    pub vocab: usize,
    pub d_model: usize,
    pub n_layers: usize,
    pub n_heads: usize,
    pub seq_len: usize,
    pub micro_batch: usize,
    pub n_params: u64,
    pub flops_per_token: f64,
    pub params: Vec<ParamSpec>,
    /// `(micro_batch, seq_len + 1)`.
    pub tokens_shape: Vec<usize>,
}

impl Manifest {
    pub fn load(path: impl AsRef<Path>) -> Result<Manifest> {
        let text = fs::read_to_string(path.as_ref())
            .with_context(|| format!("reading {}", path.as_ref().display()))?;
        Self::parse(&text)
    }

    pub fn parse(text: &str) -> Result<Manifest> {
        let v = Value::parse(text).map_err(|e| anyhow!("manifest: {e}"))?;
        let cfg = v.req("config").map_err(|e| anyhow!("{e}"))?;
        let num = |k: &str| -> Result<f64> {
            cfg.req(k)
                .map_err(|e| anyhow!("{e}"))?
                .as_f64()
                .ok_or_else(|| anyhow!("config.{k} not a number"))
        };

        let mut params = Vec::new();
        for p in v
            .req("params")
            .map_err(|e| anyhow!("{e}"))?
            .as_arr()
            .ok_or_else(|| anyhow!("params not an array"))?
        {
            let name = p
                .req("name")
                .map_err(|e| anyhow!("{e}"))?
                .as_str()
                .ok_or_else(|| anyhow!("param name not a string"))?
                .to_string();
            let shape: Vec<usize> = p
                .req("shape")
                .map_err(|e| anyhow!("{e}"))?
                .as_arr()
                .ok_or_else(|| anyhow!("shape not an array"))?
                .iter()
                .map(|d| d.as_usize().ok_or_else(|| anyhow!("bad dim in {name}")))
                .collect::<Result<_>>()?;
            let elems: usize = shape.iter().product::<usize>().max(1);
            let init = InitKind::parse(
                p.req("init").map_err(|e| anyhow!("{e}"))?.as_str().unwrap_or_default(),
            )?;
            let decay = p.get("decay").and_then(Value::as_bool).unwrap_or(false);
            params.push(ParamSpec { name, shape, elems, init, decay });
        }
        if params.is_empty() {
            bail!("manifest has no params");
        }
        // order must match JAX dict-flatten (sorted by name)
        for w in params.windows(2) {
            if w[0].name >= w[1].name {
                bail!("manifest params not sorted: {} >= {}", w[0].name, w[1].name);
            }
        }

        let ms = v.req("micro_step").map_err(|e| anyhow!("{e}"))?;
        let tokens_shape: Vec<usize> = ms
            .req("tokens_shape")
            .map_err(|e| anyhow!("{e}"))?
            .as_arr()
            .ok_or_else(|| anyhow!("tokens_shape not an array"))?
            .iter()
            .map(|d| d.as_usize().ok_or_else(|| anyhow!("bad tokens dim")))
            .collect::<Result<_>>()?;

        let man = Manifest {
            name: cfg.req("name").map_err(|e| anyhow!("{e}"))?.as_str().unwrap_or_default().into(),
            vocab: num("vocab")? as usize,
            d_model: num("d_model")? as usize,
            n_layers: num("n_layers")? as usize,
            n_heads: num("n_heads")? as usize,
            seq_len: num("seq_len")? as usize,
            micro_batch: num("micro_batch")? as usize,
            n_params: num("n_params")? as u64,
            flops_per_token: num("flops_per_token")?,
            params,
            tokens_shape,
        };
        let total: u64 = man.params.iter().map(|p| p.elems as u64).sum();
        if total != man.n_params {
            bail!("manifest n_params {} != sum of tensor elems {total}", man.n_params);
        }
        if man.tokens_shape != vec![man.micro_batch, man.seq_len + 1] {
            bail!("tokens_shape {:?} inconsistent with config", man.tokens_shape);
        }
        Ok(man)
    }

    /// Tokens per micro-batch (training positions, i.e. seq_len per row).
    pub fn tokens_per_micro_batch(&self) -> usize {
        self.micro_batch * self.seq_len
    }

    /// Estimated training FLOPs of one micro-step.
    pub fn flops_per_micro_step(&self) -> f64 {
        self.flops_per_token * self.tokens_per_micro_batch() as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample() -> String {
        r#"{
          "format_version": 1,
          "config": {"name":"t","vocab":16,"d_model":4,"n_layers":1,"n_heads":1,
                     "seq_len":8,"micro_batch":2,"n_params":20,"flops_per_token":120.0,
                     "beta1":0.9,"beta2":0.95,"eps":1e-8,"weight_decay":0.1},
          "params": [
            {"name":"a_w","shape":[4,4],"init":"normal:0.02","decay":true,"elems":16},
            {"name":"b_b","shape":[4],"init":"zeros","decay":false,"elems":4}
          ],
          "micro_step": {"inputs":["param:a_w","param:b_b","tokens"],
                          "outputs":["loss","grad:a_w","grad:b_b"],
                          "tokens_shape":[2,9],"tokens_dtype":"s32"},
          "apply_update": {"inputs":[],"outputs":[]}
        }"#
        .to_string()
    }

    #[test]
    fn parses_sample() {
        let m = Manifest::parse(&sample()).unwrap();
        assert_eq!(m.name, "t");
        assert_eq!(m.params.len(), 2);
        assert_eq!(m.params[0].init, InitKind::Normal(0.02));
        assert_eq!(m.params[1].init, InitKind::Zeros);
        assert!(m.params[0].decay && !m.params[1].decay);
        assert_eq!(m.tokens_shape, vec![2, 9]);
        assert_eq!(m.tokens_per_micro_batch(), 16);
        assert!((m.flops_per_micro_step() - 120.0 * 16.0).abs() < 1e-9);
    }

    #[test]
    fn rejects_unsorted_params() {
        let bad = sample().replace("a_w", "z_w");
        assert!(Manifest::parse(&bad).is_err());
    }

    #[test]
    fn rejects_param_count_mismatch() {
        let bad = sample().replace("\"n_params\":20", "\"n_params\":21");
        assert!(Manifest::parse(&bad).is_err());
    }

    #[test]
    fn rejects_inconsistent_tokens_shape() {
        let bad = sample().replace("[2,9]", "[2,8]");
        assert!(Manifest::parse(&bad).is_err());
    }

    #[test]
    fn init_kind_parsing() {
        assert_eq!(InitKind::parse("zeros").unwrap(), InitKind::Zeros);
        assert_eq!(InitKind::parse("ones").unwrap(), InitKind::Ones);
        assert_eq!(InitKind::parse("normal:0.5").unwrap(), InitKind::Normal(0.5));
        assert!(InitKind::parse("uniform").is_err());
        assert!(InitKind::parse("normal:x").is_err());
    }

    #[test]
    fn loads_real_artifact_if_present() {
        let path = concat!(env!("CARGO_MANIFEST_DIR"), "/artifacts/tiny/manifest.json");
        if std::path::Path::new(path).exists() {
            let m = Manifest::load(path).unwrap();
            assert_eq!(m.name, "tiny");
            assert_eq!(m.n_params, 118_528);
            assert_eq!(m.params.len(), 4 + 12 * m.n_layers);
        }
    }
}
