//! PJRT runtime: load the AOT'd HLO-text artifacts and run them on CPU.
//!
//! This is the L2↔L3 bridge: `make artifacts` (Python, build time) writes
//! `artifacts/<model>/{micro_step,apply_update}.hlo.txt` + `manifest.json`;
//! this module loads them with the `xla` crate
//! (`PjRtClient::cpu` → `HloModuleProto::from_text_file` → `compile` →
//! `execute`) and exposes typed entry points over plain `Vec<f32>` tensors.
//!
//! XLA handles are *not* `Send` (raw C pointers), so each DP worker thread
//! owns its own [`ModelRuntime`]; training state crosses threads as
//! [`TrainState`] (plain vectors), which is also what the checkpointing and
//! state-migration paths serialize.

pub mod buffers;
pub mod manifest;

pub use buffers::{add_assign, allreduce_sum, l2_norm, scale};
pub use manifest::{InitKind, Manifest, ParamSpec};

use anyhow::{anyhow, bail, Context, Result};
use std::path::{Path, PathBuf};

use crate::rng::{Rand, Xoshiro256};

/// Training state for one model replica: flat f32 tensors in manifest order.
#[derive(Debug, Clone, PartialEq)]
pub struct TrainState {
    pub params: Vec<Vec<f32>>,
    pub m: Vec<Vec<f32>>,
    pub v: Vec<Vec<f32>>,
    /// 1-based optimizer step (the next `apply_update` uses `step + 1`).
    pub step: u64,
}

impl TrainState {
    /// Total bytes of all tensors (params + optimizer state).
    pub fn size_bytes(&self) -> u64 {
        let count = |xs: &Vec<Vec<f32>>| xs.iter().map(|t| t.len() as u64 * 4).sum::<u64>();
        count(&self.params) + count(&self.m) + count(&self.v)
    }
}

/// Result of one micro-batch step.
pub struct MicroStepOut {
    pub loss: f32,
    pub grads: Vec<Vec<f32>>,
}

/// A loaded model: PJRT client + compiled executables + manifest.
pub struct ModelRuntime {
    pub manifest: Manifest,
    client: xla::PjRtClient,
    micro_step: xla::PjRtLoadedExecutable,
    apply_update: xla::PjRtLoadedExecutable,
    pub artifact_dir: PathBuf,
}

impl ModelRuntime {
    /// Load and compile the artifacts in `dir` (e.g. `artifacts/tiny`).
    pub fn load(dir: impl AsRef<Path>) -> Result<ModelRuntime> {
        // Silence TF/XLA INFO chatter (client created/destroyed) unless the
        // user asked for it.
        if std::env::var_os("TF_CPP_MIN_LOG_LEVEL").is_none() {
            std::env::set_var("TF_CPP_MIN_LOG_LEVEL", "2");
        }
        let dir = dir.as_ref().to_path_buf();
        let manifest = Manifest::load(dir.join("manifest.json"))
            .with_context(|| format!("loading manifest from {}", dir.display()))?;
        let client = xla::PjRtClient::cpu().map_err(wrap_xla)?;
        let micro_step = compile(&client, &dir.join("micro_step.hlo.txt"))?;
        let apply_update = compile(&client, &dir.join("apply_update.hlo.txt"))?;
        Ok(ModelRuntime { manifest, client, micro_step, apply_update, artifact_dir: dir })
    }

    /// Materialize the initial [`TrainState`] from the manifest's init table.
    /// Deterministic in `seed` — every DP replica must call this with the
    /// same seed to start bit-identical.
    pub fn init_state(&self, seed: u64) -> TrainState {
        let mut rng = Xoshiro256::seed_from_u64(seed);
        let mut params = Vec::with_capacity(self.manifest.params.len());
        for p in &self.manifest.params {
            // Per-tensor forked stream => adding/removing tensors elsewhere
            // does not shift this tensor's values.
            let mut trng = rng.fork(hash64(&p.name));
            let data: Vec<f32> = match p.init {
                InitKind::Zeros => vec![0.0; p.elems],
                InitKind::Ones => vec![1.0; p.elems],
                InitKind::Normal(std) => {
                    (0..p.elems).map(|_| (trng.normal() * std as f64) as f32).collect()
                }
            };
            params.push(data);
        }
        let zeros: Vec<Vec<f32>> = self.manifest.params.iter().map(|p| vec![0.0; p.elems]).collect();
        TrainState { params, m: zeros.clone(), v: zeros, step: 0 }
    }

    /// Forward+backward for one micro-batch: `(params, tokens) -> (loss, grads)`.
    ///
    /// `tokens` is row-major `(micro_batch, seq_len + 1)` int32.
    pub fn micro_step(&self, params: &[Vec<f32>], tokens: &[i32]) -> Result<MicroStepOut> {
        let man = &self.manifest;
        if params.len() != man.params.len() {
            bail!("micro_step: got {} param tensors, manifest has {}", params.len(), man.params.len());
        }
        let want_tokens: usize = man.tokens_shape.iter().product();
        if tokens.len() != want_tokens {
            bail!("micro_step: got {} tokens, expected {:?}", tokens.len(), man.tokens_shape);
        }
        let mut args = Vec::with_capacity(params.len() + 1);
        for (spec, data) in man.params.iter().zip(params) {
            args.push(f32_literal(&spec.shape, data)?);
        }
        args.push(i32_literal(&man.tokens_shape, tokens)?);

        let mut outs = run_tuple(&self.micro_step, &args)?;
        if outs.len() != man.params.len() + 1 {
            bail!("micro_step returned {} outputs, expected {}", outs.len(), man.params.len() + 1);
        }
        let loss: f32 = outs.remove(0).to_vec::<f32>().map_err(wrap_xla)?[0];
        let grads = outs
            .into_iter()
            .map(|l| l.to_vec::<f32>().map_err(wrap_xla))
            .collect::<Result<Vec<_>>>()?;
        Ok(MicroStepOut { loss, grads })
    }

    /// AdamW update in place: consumes averaged grads, advances `state.step`.
    pub fn apply_update(&self, state: &mut TrainState, grads: &[Vec<f32>], lr: f32) -> Result<()> {
        let man = &self.manifest;
        let n = man.params.len();
        if grads.len() != n {
            bail!("apply_update: got {} grad tensors, expected {n}", grads.len());
        }
        let step = (state.step + 1) as f32;
        let mut args = Vec::with_capacity(4 * n + 2);
        for (spec, data) in man.params.iter().zip(&state.params) {
            args.push(f32_literal(&spec.shape, data)?);
        }
        for (spec, data) in man.params.iter().zip(&state.m) {
            args.push(f32_literal(&spec.shape, data)?);
        }
        for (spec, data) in man.params.iter().zip(&state.v) {
            args.push(f32_literal(&spec.shape, data)?);
        }
        for (spec, data) in man.params.iter().zip(grads) {
            args.push(f32_literal(&spec.shape, data)?);
        }
        args.push(xla::Literal::scalar(step));
        args.push(xla::Literal::scalar(lr));

        let outs = run_tuple(&self.apply_update, &args)?;
        if outs.len() != 3 * n {
            bail!("apply_update returned {} outputs, expected {}", outs.len(), 3 * n);
        }
        for (i, lit) in outs.into_iter().enumerate() {
            let data = lit.to_vec::<f32>().map_err(wrap_xla)?;
            let (which, idx) = (i / n, i % n);
            match which {
                0 => state.params[idx] = data,
                1 => state.m[idx] = data,
                _ => state.v[idx] = data,
            }
        }
        state.step += 1;
        Ok(())
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }
}

fn compile(client: &xla::PjRtClient, path: &Path) -> Result<xla::PjRtLoadedExecutable> {
    let proto = xla::HloModuleProto::from_text_file(path)
        .map_err(wrap_xla)
        .with_context(|| format!("parsing HLO text {}", path.display()))?;
    let comp = xla::XlaComputation::from_proto(&proto);
    client.compile(&comp).map_err(wrap_xla).with_context(|| format!("compiling {}", path.display()))
}

/// Execute and unpack the 1-tuple-of-N-results convention produced by
/// `return_tuple=True` in aot.py.
fn run_tuple(exe: &xla::PjRtLoadedExecutable, args: &[xla::Literal]) -> Result<Vec<xla::Literal>> {
    let result = exe.execute::<xla::Literal>(args).map_err(wrap_xla)?;
    let buffer = result
        .first()
        .and_then(|per_device| per_device.first())
        .ok_or_else(|| anyhow!("executable produced no output buffers"))?;
    let mut tuple = buffer.to_literal_sync().map_err(wrap_xla)?;
    tuple.decompose_tuple().map_err(wrap_xla)
}

fn f32_literal(shape: &[usize], data: &[f32]) -> Result<xla::Literal> {
    let want: usize = shape.iter().product();
    if data.len() != want {
        bail!("tensor has {} elems, shape {:?} wants {want}", data.len(), shape);
    }
    let bytes = unsafe { std::slice::from_raw_parts(data.as_ptr() as *const u8, data.len() * 4) };
    xla::Literal::create_from_shape_and_untyped_data(xla::ElementType::F32, shape, bytes)
        .map_err(wrap_xla)
}

fn i32_literal(shape: &[usize], data: &[i32]) -> Result<xla::Literal> {
    let want: usize = shape.iter().product();
    if data.len() != want {
        bail!("tokens have {} elems, shape {:?} wants {want}", data.len(), shape);
    }
    let bytes = unsafe { std::slice::from_raw_parts(data.as_ptr() as *const u8, data.len() * 4) };
    xla::Literal::create_from_shape_and_untyped_data(xla::ElementType::S32, shape, bytes)
        .map_err(wrap_xla)
}

fn wrap_xla(e: xla::Error) -> anyhow::Error {
    anyhow!("xla: {e}")
}

fn hash64(s: &str) -> u64 {
    let mut h = 0xcbf29ce484222325u64;
    for b in s.bytes() {
        h ^= b as u64;
        h = h.wrapping_mul(0x100000001b3);
    }
    h
}

#[cfg(test)]
mod tests {
    use super::*;

    // Full PJRT round-trip tests live in rust/tests/runtime_exactness.rs
    // (they need `make artifacts`). Here: the pure-host pieces.

    #[test]
    fn hash64_distinct() {
        assert_ne!(hash64("tok_emb"), hash64("pos_emb"));
        assert_eq!(hash64("x"), hash64("x"));
    }

    #[test]
    fn train_state_size() {
        let s = TrainState {
            params: vec![vec![0.0; 10], vec![0.0; 6]],
            m: vec![vec![0.0; 10], vec![0.0; 6]],
            v: vec![vec![0.0; 10], vec![0.0; 6]],
            step: 0,
        };
        assert_eq!(s.size_bytes(), 3 * 16 * 4);
    }

    #[test]
    fn literal_shape_mismatch_rejected() {
        assert!(f32_literal(&[2, 2], &[0.0; 3]).is_err());
        assert!(i32_literal(&[4], &[0; 3]).is_err());
    }
}
