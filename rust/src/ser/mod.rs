//! JSON substrate: a self-contained value model, parser and encoder.
//!
//! The vendored registry has no `serde` facade, so the repo carries its own
//! JSON layer. It is used for artifact manifests (written by `aot.py`), the
//! RPC wire format, config files, and metrics dumps. The parser is a strict
//! recursive-descent RFC 8259 implementation with a depth limit; the encoder
//! round-trips every value the parser accepts.

use std::collections::BTreeMap;
use std::fmt;

/// A JSON document. Objects use `BTreeMap` so encoding is deterministic.
#[derive(Debug, Clone, PartialEq)]
pub enum Value {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Value>),
    Obj(BTreeMap<String, Value>),
}

/// Parse or access error with byte offset (parse) or path context (access).
#[derive(Debug, Clone, PartialEq)]
pub struct JsonError {
    pub msg: String,
    pub offset: usize,
}

impl fmt::Display for JsonError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "json error at byte {}: {}", self.offset, self.msg)
    }
}

impl std::error::Error for JsonError {}

const MAX_DEPTH: usize = 128;

impl Value {
    // -- constructors ------------------------------------------------------

    pub fn obj() -> Value {
        Value::Obj(BTreeMap::new())
    }

    /// Builder-style insert; panics on non-objects (programmer error).
    pub fn with(mut self, key: &str, v: impl Into<Value>) -> Value {
        match &mut self {
            Value::Obj(m) => {
                m.insert(key.to_string(), v.into());
            }
            _ => panic!("Value::with on non-object"),
        }
        self
    }

    pub fn set(&mut self, key: &str, v: impl Into<Value>) {
        match self {
            Value::Obj(m) => {
                m.insert(key.to_string(), v.into());
            }
            _ => panic!("Value::set on non-object"),
        }
    }

    // -- accessors ---------------------------------------------------------

    pub fn get(&self, key: &str) -> Option<&Value> {
        match self {
            Value::Obj(m) => m.get(key),
            _ => None,
        }
    }

    /// `get` that reports the missing key — for manifest/config loading.
    pub fn req(&self, key: &str) -> Result<&Value, JsonError> {
        self.get(key).ok_or_else(|| JsonError { msg: format!("missing key {key:?}"), offset: 0 })
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Value::Num(x) => Some(*x),
            _ => None,
        }
    }

    pub fn as_u64(&self) -> Option<u64> {
        self.as_f64().and_then(|x| if x >= 0.0 && x.fract() == 0.0 { Some(x as u64) } else { None })
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_u64().map(|x| x as usize)
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Value::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Value::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Value]> {
        match self {
            Value::Arr(v) => Some(v),
            _ => None,
        }
    }

    pub fn as_obj(&self) -> Option<&BTreeMap<String, Value>> {
        match self {
            Value::Obj(m) => Some(m),
            _ => None,
        }
    }

    // -- encode ------------------------------------------------------------

    /// Compact encoding (wire format).
    pub fn encode(&self) -> String {
        let mut out = String::with_capacity(64);
        self.write(&mut out);
        out
    }

    fn write(&self, out: &mut String) {
        match self {
            Value::Null => out.push_str("null"),
            Value::Bool(true) => out.push_str("true"),
            Value::Bool(false) => out.push_str("false"),
            Value::Num(x) => write_num(*x, out),
            Value::Str(s) => write_str(s, out),
            Value::Arr(items) => {
                out.push('[');
                for (i, v) in items.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    v.write(out);
                }
                out.push(']');
            }
            Value::Obj(map) => {
                out.push('{');
                for (i, (k, v)) in map.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    write_str(k, out);
                    out.push(':');
                    v.write(out);
                }
                out.push('}');
            }
        }
    }

    // -- parse -------------------------------------------------------------

    pub fn parse(input: &str) -> Result<Value, JsonError> {
        let mut p = Parser { bytes: input.as_bytes(), pos: 0 };
        p.skip_ws();
        let v = p.value(0)?;
        p.skip_ws();
        if p.pos != p.bytes.len() {
            return Err(p.err("trailing characters"));
        }
        Ok(v)
    }
}

fn write_num(x: f64, out: &mut String) {
    if x.fract() == 0.0 && x.abs() < 9e15 {
        out.push_str(&format!("{}", x as i64));
    } else if x.is_finite() {
        out.push_str(&format!("{x}"));
    } else {
        out.push_str("null"); // JSON has no inf/nan
    }
}

fn write_str(s: &str, out: &mut String) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out.push('"');
}

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> JsonError {
        JsonError { msg: msg.to_string(), offset: self.pos }
    }

    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.pos += 1;
        }
    }

    fn expect(&mut self, b: u8) -> Result<(), JsonError> {
        if self.peek() == Some(b) {
            self.pos += 1;
            Ok(())
        } else {
            Err(self.err(&format!("expected {:?}", b as char)))
        }
    }

    fn value(&mut self, depth: usize) -> Result<Value, JsonError> {
        if depth > MAX_DEPTH {
            return Err(self.err("nesting too deep"));
        }
        self.skip_ws();
        match self.peek() {
            Some(b'{') => self.object(depth),
            Some(b'[') => self.array(depth),
            Some(b'"') => Ok(Value::Str(self.string()?)),
            Some(b't') => self.literal("true", Value::Bool(true)),
            Some(b'f') => self.literal("false", Value::Bool(false)),
            Some(b'n') => self.literal("null", Value::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            Some(_) => Err(self.err("unexpected character")),
            None => Err(self.err("unexpected end of input")),
        }
    }

    fn literal(&mut self, word: &str, v: Value) -> Result<Value, JsonError> {
        if self.bytes[self.pos..].starts_with(word.as_bytes()) {
            self.pos += word.len();
            Ok(v)
        } else {
            Err(self.err(&format!("invalid literal (expected {word})")))
        }
    }

    fn number(&mut self) -> Result<Value, JsonError> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
            self.pos += 1;
        }
        if self.peek() == Some(b'.') {
            self.pos += 1;
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        if matches!(self.peek(), Some(b'e' | b'E')) {
            self.pos += 1;
            if matches!(self.peek(), Some(b'+' | b'-')) {
                self.pos += 1;
            }
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        let text = std::str::from_utf8(&self.bytes[start..self.pos]).unwrap();
        text.parse::<f64>().map(Value::Num).map_err(|_| self.err("invalid number"))
    }

    fn string(&mut self) -> Result<String, JsonError> {
        self.expect(b'"')?;
        let mut out = String::new();
        loop {
            match self.peek() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => {
                    self.pos += 1;
                    return Ok(out);
                }
                Some(b'\\') => {
                    self.pos += 1;
                    match self.peek() {
                        Some(b'"') => out.push('"'),
                        Some(b'\\') => out.push('\\'),
                        Some(b'/') => out.push('/'),
                        Some(b'b') => out.push('\u{8}'),
                        Some(b'f') => out.push('\u{c}'),
                        Some(b'n') => out.push('\n'),
                        Some(b'r') => out.push('\r'),
                        Some(b't') => out.push('\t'),
                        Some(b'u') => {
                            self.pos += 1;
                            let cp = self.hex4()?;
                            // surrogate pair?
                            let c = if (0xD800..0xDC00).contains(&cp) {
                                self.expect(b'\\')?;
                                self.expect(b'u')?;
                                let lo = self.hex4()?;
                                if !(0xDC00..0xE000).contains(&lo) {
                                    return Err(self.err("invalid low surrogate"));
                                }
                                let c = 0x10000 + ((cp - 0xD800) << 10) + (lo - 0xDC00);
                                char::from_u32(c).ok_or_else(|| self.err("invalid codepoint"))?
                            } else {
                                char::from_u32(cp).ok_or_else(|| self.err("invalid codepoint"))?
                            };
                            out.push(c);
                            continue; // hex4 advanced pos already
                        }
                        _ => return Err(self.err("invalid escape")),
                    }
                    self.pos += 1;
                }
                Some(_) => {
                    // copy one utf-8 char
                    let rest = std::str::from_utf8(&self.bytes[self.pos..])
                        .map_err(|_| self.err("invalid utf-8"))?;
                    let c = rest.chars().next().unwrap();
                    if (c as u32) < 0x20 {
                        return Err(self.err("control character in string"));
                    }
                    out.push(c);
                    self.pos += c.len_utf8();
                }
            }
        }
    }

    fn hex4(&mut self) -> Result<u32, JsonError> {
        if self.pos + 4 > self.bytes.len() {
            return Err(self.err("truncated \\u escape"));
        }
        let hex = std::str::from_utf8(&self.bytes[self.pos..self.pos + 4])
            .map_err(|_| self.err("invalid \\u escape"))?;
        let cp = u32::from_str_radix(hex, 16).map_err(|_| self.err("invalid \\u escape"))?;
        self.pos += 4;
        Ok(cp)
    }

    fn array(&mut self, depth: usize) -> Result<Value, JsonError> {
        self.expect(b'[')?;
        let mut items = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Value::Arr(items));
        }
        loop {
            items.push(self.value(depth + 1)?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => {
                    self.pos += 1;
                }
                Some(b']') => {
                    self.pos += 1;
                    return Ok(Value::Arr(items));
                }
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }

    fn object(&mut self, depth: usize) -> Result<Value, JsonError> {
        self.expect(b'{')?;
        let mut map = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Value::Obj(map));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            let val = self.value(depth + 1)?;
            map.insert(key, val);
            self.skip_ws();
            match self.peek() {
                Some(b',') => {
                    self.pos += 1;
                }
                Some(b'}') => {
                    self.pos += 1;
                    return Ok(Value::Obj(map));
                }
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }
}

// ----------------------------------------------------------------------
// From impls for ergonomic construction
// ----------------------------------------------------------------------

impl From<f64> for Value {
    fn from(x: f64) -> Self {
        Value::Num(x)
    }
}
impl From<u64> for Value {
    fn from(x: u64) -> Self {
        Value::Num(x as f64)
    }
}
impl From<usize> for Value {
    fn from(x: usize) -> Self {
        Value::Num(x as f64)
    }
}
impl From<i64> for Value {
    fn from(x: i64) -> Self {
        Value::Num(x as f64)
    }
}
impl From<u32> for Value {
    fn from(x: u32) -> Self {
        Value::Num(x as f64)
    }
}
impl From<bool> for Value {
    fn from(x: bool) -> Self {
        Value::Bool(x)
    }
}
impl From<&str> for Value {
    fn from(x: &str) -> Self {
        Value::Str(x.to_string())
    }
}
impl From<String> for Value {
    fn from(x: String) -> Self {
        Value::Str(x)
    }
}
impl<T: Into<Value>> From<Vec<T>> for Value {
    fn from(xs: Vec<T>) -> Self {
        Value::Arr(xs.into_iter().map(Into::into).collect())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_scalars() {
        assert_eq!(Value::parse("null").unwrap(), Value::Null);
        assert_eq!(Value::parse("true").unwrap(), Value::Bool(true));
        assert_eq!(Value::parse("  -12.5e2 ").unwrap(), Value::Num(-1250.0));
        assert_eq!(Value::parse(r#""hi\nthere""#).unwrap(), Value::Str("hi\nthere".into()));
    }

    #[test]
    fn parse_nested() {
        let v = Value::parse(r#"{"a": [1, 2, {"b": null}], "c": "x"}"#).unwrap();
        assert_eq!(v.get("a").unwrap().as_arr().unwrap().len(), 3);
        assert_eq!(v.get("c").unwrap().as_str(), Some("x"));
    }

    #[test]
    fn parse_unicode_escapes() {
        assert_eq!(Value::parse(r#""é""#).unwrap(), Value::Str("é".into()));
        // surrogate pair: U+1F600
        assert_eq!(Value::parse(r#""😀""#).unwrap(), Value::Str("😀".into()));
    }

    #[test]
    fn rejects_garbage() {
        for bad in ["", "{", "[1,]", "{\"a\":}", "tru", "1.2.3", "\"\\q\"", "[1] x", "\"abc"] {
            assert!(Value::parse(bad).is_err(), "should reject {bad:?}");
        }
    }

    #[test]
    fn rejects_deep_nesting() {
        let s = "[".repeat(200) + &"]".repeat(200);
        assert!(Value::parse(&s).is_err());
    }

    #[test]
    fn roundtrip() {
        let src = r#"{"arr":[1,2.5,true,null,"s\"x"],"n":-3,"o":{"k":"v"}}"#;
        let v = Value::parse(src).unwrap();
        let enc = v.encode();
        assert_eq!(Value::parse(&enc).unwrap(), v);
    }

    #[test]
    fn integers_encode_without_decimal_point() {
        assert_eq!(Value::Num(42.0).encode(), "42");
        assert_eq!(Value::Num(0.5).encode(), "0.5");
    }

    #[test]
    fn builder_and_accessors() {
        let v = Value::obj().with("x", 3u64).with("s", "hi").with("b", true);
        assert_eq!(v.get("x").unwrap().as_u64(), Some(3));
        assert_eq!(v.get("s").unwrap().as_str(), Some("hi"));
        assert_eq!(v.get("b").unwrap().as_bool(), Some(true));
        assert!(v.req("missing").is_err());
        assert_eq!(Value::Num(1.5).as_u64(), None);
    }

    #[test]
    fn parses_real_manifest() {
        // shape of the file aot.py writes
        let src = r#"{"format_version":1,"config":{"name":"tiny","n_params":118528},
                      "params":[{"name":"lnf_b","shape":[64],"init":"zeros","decay":false}]}"#;
        let v = Value::parse(src).unwrap();
        assert_eq!(v.req("config").unwrap().req("n_params").unwrap().as_u64(), Some(118528));
    }
}
