//! Discrete-event *environment model* — the §7.5 evaluation substrate.
//!
//! This module no longer makes recovery decisions. It models the cluster
//! environment around a [`RecoveryPolicy`]:
//!
//! 1. trace events ([`crate::failure::Trace`]) are translated into the
//!    production [`CoordEvent`] vocabulary (SEV1 node drains become
//!    `ErrorReport`/`NodeLost`, completed repairs `NodeRepaired`, task
//!    churn `TaskLaunched`/`TaskFinished`);
//! 2. the policy decides — for [`PolicyKind::Unicron`] that policy *is* the
//!    production [`crate::coordinator::Coordinator`] state machine, so the
//!    simulated decision path is byte-for-byte the deployed one; the §7
//!    baselines (Megatron/Oobleck/Varuna/Bamboo) implement the same trait
//!    in [`policies`];
//! 3. the returned [`Action`]s are executed against simulated time from the
//!    shared [`crate::engine::EventQueue`], with policy-specific timing
//!    ([`PolicyParams`]): detection latency, transition duration per moved
//!    GPU, restart/recompute cost. The fleet actions are environment
//!    effects too: `SpareRetained` re-admits a repaired node,
//!    `SpareReleased` and `NodeQuarantined` retire it for good.
//!
//! Every `(event, actions)` pair is recorded in [`SimResult::decision_log`];
//! `rust/tests/sim_unification.rs` replays that log through a standalone
//! [`crate::coordinator::Coordinator`] and asserts identical actions — the
//! guarantee that Fig. 9 / Fig. 11 numbers exercise real coordinator code.
//!
//! Outputs: WAF time series + accumulated WAF (Fig. 11), FLOP/s-reduction
//! summaries (Fig. 3b), transition-time views (Fig. 9 cross-check). Runs are
//! bit-deterministic per `(trace, policy)`; `rust/tests/sim_determinism.rs`
//! keeps a recorded-seed regression corpus.

pub mod policies;

pub use policies::{
    build as build_policy, BaselinePolicy, PolicyKind, PolicyParams, RecoveryPolicy, UnicronPolicy,
};

use crate::config::{ClusterSpec, ModelSpec, TaskSpec, UnicronConfig};
use crate::engine::EventQueue;
use crate::failure::{LifecycleKind, Severity, Trace};
use crate::health::DegradationKind;
use crate::placement::{Layout, TaskMoves};
use crate::planner::{Plan, PlanTask};
use crate::proto::{Action, CoordEvent, DecisionLog, NodeId, TaskId, WorkerCount};
use crate::store::{ChunkId, Manifest, SnapshotStore, Tier};
use crate::transition::resolve_source;

/// Chunk granularity for *synthetic* simulated snapshots (the environment
/// never materializes state bytes; 64 MiB keeps manifests of a 100+ GB
/// optimizer state at a few thousand ids).
const SIM_CHUNK_BYTES: u64 = 64 << 20;

/// Nominal healthy per-step duration for in-band timing reports, seconds —
/// the baseline the coordinator's streaming estimators learn. A node
/// degraded by `slow_frac` reports `SIM_STEP_S / (1 - slow_frac)` instead.
const SIM_STEP_S: f64 = 45.0;

/// Per-task environment state (what is physically running, not what the
/// policy has decided — decisions live in the policy).
#[derive(Debug, Clone)]
struct SimTask {
    spec: TaskSpec,
    /// Megatron-level `T(t,x)` table (FLOP/s) indexed by worker count.
    throughput: Vec<f64>,
    /// Workers (GPUs) the task is currently running with.
    workers: u32,
    /// Workers the task will run with once its pending recovery completes.
    pending_workers: u32,
    /// If `Some(t)`, the task produces zero WAF until simulated time `t`.
    down_until: Option<f64>,
    /// Recovery generation: stale `RecoveryDone` events are ignored.
    epoch: u64,
    /// False before a task's Arrival and after its Departure (Fig. 7 ⑤⑥).
    active: bool,
}

impl SimTask {
    /// Instantaneous WAF under `eff` policy efficiency.
    fn waf(&self, now: f64, eff: f64) -> f64 {
        if !self.active {
            return 0.0;
        }
        if let Some(t) = self.down_until {
            if now < t {
                return 0.0;
            }
        }
        if self.workers < self.spec.min_workers {
            return 0.0;
        }
        let t = self.throughput.get(self.workers as usize).copied().unwrap_or(0.0);
        self.spec.weight * eff * t
    }
}

/// Environment events on the engine queue.
#[derive(Debug, Clone, PartialEq)]
enum EnvEvent {
    /// index into `trace.events`
    Failure(usize),
    /// index into `trace.lifecycle`
    Lifecycle(usize),
    Repair { node: NodeId },
    RecoveryDone { task: usize, workers: u32, epoch: u64 },
    /// Deferred outcome report back to the policy (restart completed).
    PolicyResult { result: CoordEvent },
    /// A policy-requested [`Action::ScheduleReplan`] timer: deliver
    /// [`CoordEvent::ReplanDue`] so a deferred burst replan can commit.
    ReplanTimer,
    /// Periodic checkpoint: every active task writes a (synthetic, delta)
    /// snapshot into the [`SnapshotStore`]. Only scheduled under
    /// `store_aware_recovery`; reschedules itself each firing.
    CheckpointTick,
    /// index into `trace.degradations`: the episode begins.
    DegradationStart(usize),
    /// A degradation episode's natural end — the node recovers on its own
    /// (if the policy never evicted it).
    DegradationEnd { node: NodeId },
    /// In-band step-timing report for a watched node (index into
    /// `trace.degradations`). Scheduled only around degradation episodes,
    /// so degradation-free traces carry zero extra events.
    StepReport { di: usize },
}

/// Execution context for a batch of policy actions: what triggered them and
/// therefore which timing applies.
#[derive(Debug, Clone, Copy, Default)]
struct Ctx {
    /// Severity of the triggering failure (None for joins/lifecycle).
    severity: Option<Severity>,
    /// Task *index* the failure hit (transition-penalty + Fig. 9 recording).
    affected: Option<usize>,
    /// Bootstrap: apply assignments instantly with no downtime (t = 0).
    instant: bool,
}

impl Ctx {
    fn bootstrap() -> Ctx {
        Ctx { instant: true, ..Default::default() }
    }
    fn failure(severity: Severity, affected: Option<usize>) -> Ctx {
        Ctx { severity: Some(severity), affected, ..Default::default() }
    }
    fn quiet() -> Ctx {
        Ctx::default() // joins, task churn, result notifications: no detection delay
    }
}

/// Result of one simulation run.
#[derive(Debug, Clone)]
pub struct SimResult {
    pub policy: PolicyKind,
    /// Piecewise-constant total-WAF series: (seconds, FLOP/s).
    pub waf_series: Vec<(f64, f64)>,
    /// ∫ WAF dt over the whole trace (FLOP·s of weighted useful work).
    pub accumulated_waf: f64,
    /// WAF of the failure-free cluster (constant), for reduction ratios.
    pub healthy_waf: f64,
    pub duration_s: f64,
    /// SEV1 transitions performed: (time, seconds the transition took).
    pub transitions: Vec<(f64, f64)>,
    /// Every (event, actions) decision the policy made, in delivery order —
    /// for the Unicron policy this is exactly the coordinator's audit log,
    /// and it serializes/replays via [`crate::proto::DecisionLog`].
    pub decision_log: DecisionLog,
    /// `AlertOps` pages raised (SEV1 isolations).
    pub alerts: usize,
    /// Replans the policy served from its precomputed §5.2 table (Unicron:
    /// the coordinator's `lookup_hits`; baselines: 0).
    pub plan_lookup_hits: u64,
    /// Replans the policy solved live.
    pub plan_solve_calls: u64,
    /// SEV1 restores executed against the snapshot store instead of the
    /// closed-form transition model: (time, restore seconds). Empty unless
    /// `store_aware_recovery` is on.
    pub store_restores: Vec<(f64, f64)>,
    /// Final [`SnapshotStore::report`] (occupancy, dedup ratio, hit/miss),
    /// `None` unless `store_aware_recovery` is on.
    pub store_report: Option<crate::ser::Value>,
}

impl SimResult {
    /// Fraction of the ideal (failure-free) weighted work that was lost —
    /// Fig. 3b's y-axis.
    pub fn reduction(&self) -> f64 {
        let ideal = self.healthy_waf * self.duration_s;
        if ideal <= 0.0 {
            return 0.0;
        }
        1.0 - self.accumulated_waf / ideal
    }

    /// Mean WAF over the run.
    pub fn mean_waf(&self) -> f64 {
        self.accumulated_waf / self.duration_s
    }
}

/// The environment model. Owns physical cluster state (which nodes are up,
/// what each task is running with) and the engine event queue; defers every
/// recovery decision to the [`RecoveryPolicy`].
pub struct Simulator {
    cluster: ClusterSpec,
    policy: Box<dyn RecoveryPolicy>,
    /// Cached copy of the policy's timing constants.
    params: PolicyParams,
    tasks: Vec<SimTask>,
    /// Planner inputs per task (handed to the policy at init/admission).
    plan_inputs: Vec<PlanTask>,
    /// node -> down/isolated?
    node_down: Vec<bool>,
    /// node -> permanently out of the fleet (quarantined lemon or released
    /// spare): repairs are ignored and the node never carries work again.
    retired: Vec<bool>,
    /// The executed cluster map — the last layout-carrying plan's
    /// [`Layout`]. Empty until a policy publishes concrete layouts (the
    /// Unicron coordinator, wire v4); once non-empty, failure attribution
    /// reads it — a domain burst hits exactly the co-located tasks the
    /// layout says it hits — instead of the legacy contiguous convention
    /// the topology-blind baselines still use.
    layout: Layout,
    available: u32,
    now: f64,
    queue: EventQueue<EnvEvent>,
    /// Repair delay for nodes isolated by policy escalation (not by a trace
    /// SEV1, which carries its own repair time).
    default_repair_s: f64,
    series: Vec<(f64, f64)>,
    accumulated: f64,
    last_waf: f64,
    last_t: f64,
    transitions: Vec<(f64, f64)>,
    decision_log: DecisionLog,
    alerts: usize,
    /// The state tier (DESIGN.md §13). Always constructed (priors from the
    /// cluster spec), but written/consulted only under `store_aware`.
    store: SnapshotStore,
    /// `cfg.store_aware_recovery`: execute checkpoints/evictions/restores
    /// against the store and let failover timing reflect residency.
    store_aware: bool,
    /// Checkpoint cadence (`cfg.ckpt_interval_s`).
    ckpt_interval_s: f64,
    /// Fraction of a task's chunks that change between ticks
    /// (`cfg.store_delta_fraction`).
    store_delta_fraction: f64,
    /// Optimizer+model state bytes per task ([`ModelSpec`]-derived).
    state_bytes: Vec<u64>,
    /// Per-task synthetic chunk content versions: a tick bumps a rotating
    /// dirty window, every unchanged chunk re-addresses identically.
    chunk_version: Vec<Vec<u64>>,
    /// Checkpoint ticks taken (every 4th also persists to remote).
    ckpt_ticks: u64,
    /// Last `(source, restore_s)` reported per task via
    /// [`CoordEvent::StateResidency`] — only changes are re-emitted.
    last_residency: Vec<Option<(crate::transition::StateSource, f64)>>,
    store_restores: Vec<(f64, f64)>,
    /// Currently-degraded nodes → `slow_frac`. While a node is here (and
    /// up), its owner task's WAF is dragged by `1 - slow_frac` — the
    /// slowest data-parallel worker gates the whole cohort. Empty unless
    /// the trace schedules degradations.
    degraded: std::collections::BTreeMap<NodeId, f64>,
    /// In-band step-report cadence (`cfg.step_report_period_s`).
    step_period_s: f64,
    /// Healthy reports emitted before an episode so the coordinator's
    /// estimators have a warm baseline (`cfg.degradation_min_samples + 2`).
    health_warm_samples: u32,
}

/// Staged construction of a [`Simulator`] — replaces the old positional
/// `Simulator::new(cluster, cfg, kind, specs)` / `Simulator::with_policy`
/// (DESIGN.md §7). Defaults: default cluster and config, the Unicron
/// policy, no tasks.
pub struct SimulatorBuilder {
    cluster: ClusterSpec,
    cfg: UnicronConfig,
    kind: PolicyKind,
    policy: Option<Box<dyn RecoveryPolicy>>,
    specs: Vec<TaskSpec>,
}

impl SimulatorBuilder {
    pub fn cluster(mut self, cluster: ClusterSpec) -> Self {
        self.cluster = cluster;
        self
    }

    pub fn config(mut self, cfg: UnicronConfig) -> Self {
        self.cfg = cfg;
        self
    }

    /// Use one of the five stock policies (builds it from the config).
    pub fn policy(mut self, kind: PolicyKind) -> Self {
        self.kind = kind;
        self.policy = None;
        self
    }

    /// Use a custom [`RecoveryPolicy`] implementation (it carries its own
    /// config; the environment needs none).
    pub fn policy_impl(mut self, policy: Box<dyn RecoveryPolicy>) -> Self {
        self.policy = Some(policy);
        self
    }

    /// Task specs, in ascending-id order (the assignment-vector contract).
    pub fn tasks(mut self, specs: &[TaskSpec]) -> Self {
        self.specs.extend(specs.iter().cloned());
        self
    }

    pub fn build(self) -> Simulator {
        let SimulatorBuilder { cluster, cfg, kind, policy, specs } = self;
        debug_assert!(
            specs.windows(2).all(|w| w[0].id < w[1].id),
            "task specs must be in ascending-id order"
        );
        let policy = policy
            .unwrap_or_else(|| policies::build(kind, &cfg, WorkerCount(cluster.gpus_per_node)));
        let n = cluster.total_gpus();
        let plan_inputs: Vec<PlanTask> =
            specs.iter().map(|spec| PlanTask::from_spec(spec, &cluster, n)).collect();
        // Optimizer+model state per task: params × 16 B (fp16 weights +
        // fp32 master + Adam moments); unknown models get a nominal 1 GiB.
        let state_bytes: Vec<u64> = specs
            .iter()
            .map(|spec| {
                ModelSpec::gpt3(&spec.model)
                    .map(|m| (m.n_params * crate::cost::STATE_BYTES_PER_PARAM) as u64)
                    .unwrap_or(1 << 30)
            })
            .collect();
        let chunk_version: Vec<Vec<u64>> = state_bytes
            .iter()
            .map(|&b| vec![0u64; b.div_ceil(SIM_CHUNK_BYTES) as usize])
            .collect();
        let tasks = plan_inputs
            .iter()
            .map(|pt| SimTask {
                spec: pt.spec.clone(),
                throughput: pt.throughput.clone(),
                workers: 0,
                pending_workers: 0,
                down_until: None,
                epoch: 0,
                active: true,
            })
            .collect();
        let params = policy.params().clone();
        let n_tasks = tasks.len();
        Simulator {
            node_down: vec![false; cluster.n_nodes as usize],
            retired: vec![false; cluster.n_nodes as usize],
            layout: Layout::default(),
            available: n,
            store: SnapshotStore::new(&cluster),
            store_aware: cfg.store_aware_recovery,
            ckpt_interval_s: cfg.ckpt_interval_s,
            store_delta_fraction: cfg.store_delta_fraction,
            state_bytes,
            chunk_version,
            ckpt_ticks: 0,
            last_residency: vec![None; n_tasks],
            store_restores: Vec::new(),
            degraded: std::collections::BTreeMap::new(),
            step_period_s: cfg.step_report_period_s,
            health_warm_samples: cfg.degradation_min_samples + 2,
            cluster,
            policy,
            params,
            tasks,
            plan_inputs,
            now: 0.0,
            queue: EventQueue::new(),
            default_repair_s: 86400.0,
            series: Vec::new(),
            accumulated: 0.0,
            last_waf: 0.0,
            last_t: 0.0,
            transitions: Vec::new(),
            decision_log: DecisionLog::new(),
            alerts: 0,
        }
    }
}

impl Simulator {
    /// Start building an environment model.
    pub fn builder() -> SimulatorBuilder {
        SimulatorBuilder {
            cluster: ClusterSpec::default(),
            cfg: UnicronConfig::default(),
            kind: PolicyKind::Unicron,
            policy: None,
            specs: Vec::new(),
        }
    }

    fn total_waf(&self) -> f64 {
        if self.degraded.is_empty() {
            return self.tasks.iter().map(|t| t.waf(self.now, self.params.efficiency)).sum();
        }
        // a degraded (but up) node gates its whole task: the cohort runs at
        // the slowest worker's pace until the episode ends or the policy
        // evicts the node
        let mut waf: Vec<f64> =
            self.tasks.iter().map(|t| t.waf(self.now, self.params.efficiency)).collect();
        for (&node, &slow) in &self.degraded {
            if self.node_down[node.0 as usize] {
                continue;
            }
            if let Some(ti) = self.owner_of(node) {
                waf[ti] *= 1.0 - slow;
            }
        }
        waf.iter().sum()
    }

    fn record(&mut self) {
        // integrate the previous segment, then note the new level
        self.accumulated += self.last_waf * (self.now - self.last_t);
        self.last_t = self.now;
        self.last_waf = self.total_waf();
        self.series.push((self.now, self.last_waf));
    }

    /// Which task owns `node`. When the policy publishes concrete layouts
    /// (wire v4 Unicron), this IS the coordinator's own cluster map — the
    /// environment and the policy can never disagree about which task a
    /// node's failure hits. Topology-blind baselines fall back to the
    /// legacy convention: active tasks take nodes in id order,
    /// `ceil(workers/gpn)` nodes each, over the healthy nodes. Returns a
    /// task *index*.
    fn owner_of(&self, node: NodeId) -> Option<usize> {
        if !self.layout.is_empty() {
            return self
                .layout
                .owner_of(node)
                .and_then(|task| self.index_of(task))
                .filter(|&ti| self.tasks[ti].active);
        }
        let healthy: Vec<u32> =
            (0..self.cluster.n_nodes).filter(|&n| !self.node_down[n as usize]).collect();
        let gpn = self.cluster.gpus_per_node;
        let mut cursor = 0usize;
        for ti in self.active_indices() {
            let t = &self.tasks[ti];
            let nodes_needed = ((t.workers + gpn - 1) / gpn) as usize;
            for k in 0..nodes_needed {
                if healthy.get(cursor + k) == Some(&node.0) {
                    return Some(ti);
                }
            }
            cursor += nodes_needed;
        }
        None
    }

    /// Indices of active tasks in ascending-id order — the order every
    /// `ApplyPlan.assignment` vector uses.
    fn active_indices(&self) -> Vec<usize> {
        let mut idx: Vec<usize> = (0..self.tasks.len()).filter(|&i| self.tasks[i].active).collect();
        idx.sort_by_key(|&i| self.tasks[i].spec.id);
        idx
    }

    fn index_of(&self, task_id: TaskId) -> Option<usize> {
        self.tasks.iter().position(|t| t.spec.id == task_id)
    }

    /// Feed one event to the policy at the current simulated time; log and
    /// return its decisions.
    fn decide(&mut self, ev: CoordEvent) -> Vec<Action> {
        let actions = self.policy.on_event(ev.clone(), self.now);
        self.decision_log.record(self.now, ev, actions.clone());
        actions
    }

    /// Execute policy actions under `ctx` timing.
    fn execute(&mut self, actions: &[Action], ctx: &Ctx) {
        for a in actions {
            match a {
                Action::ApplyPlan { plan, .. } => self.apply_plan(plan, ctx),
                Action::InstructReattempt { node, task } => {
                    self.instruct_recovery(*task, *node, true, ctx)
                }
                Action::InstructRestart { node, task } => {
                    self.instruct_recovery(*task, *node, false, ctx)
                }
                Action::IsolateNode { node } => self.isolate(*node),
                Action::NodeQuarantined { node } => self.retire(*node),
                Action::SpareRetained { node } => self.readmit(*node),
                Action::SpareReleased { node } => self.release(*node),
                Action::ScheduleReplan { after_s } => {
                    self.queue.schedule(self.now + after_s, EnvEvent::ReplanTimer)
                }
                Action::AlertOps { .. } => self.alerts += 1,
            }
        }
    }

    /// Permanently fence a lemon: the node goes (or stays) down, and no
    /// pending or future repair returns it.
    fn retire(&mut self, node: NodeId) {
        let idx = node.0 as usize;
        if idx >= self.retired.len() || self.retired[idx] {
            return;
        }
        self.retired[idx] = true;
        if !self.node_down[idx] {
            self.node_down[idx] = true;
            self.available = self.available.saturating_sub(self.cluster.gpus_per_node);
        }
    }

    /// A repaired node the policy retained rejoins the pool.
    fn readmit(&mut self, node: NodeId) {
        let idx = node.0 as usize;
        if idx >= self.node_down.len() || self.retired[idx] || !self.node_down[idx] {
            return;
        }
        self.node_down[idx] = false;
        self.available =
            (self.available + self.cluster.gpus_per_node).min(self.cluster.total_gpus());
    }

    /// A repaired node the policy released: healthy, but returned to the
    /// provider — out of the fleet for good.
    fn release(&mut self, node: NodeId) {
        let idx = node.0 as usize;
        if idx < self.retired.len() {
            self.retired[idx] = true;
        }
    }

    /// Peer host for a task's node-local snapshot tiers: the lowest-id
    /// healthy node *outside* the task's own layout (so losing a training
    /// node does not take the replica with it), falling back to the lowest
    /// healthy node when the task spans the whole fleet.
    fn checkpoint_peer(&self, ti: usize) -> Option<NodeId> {
        let task = self.tasks[ti].spec.id;
        let own = self.layout.nodes_of(task);
        let mut fallback = None;
        for n in (0..self.cluster.n_nodes).map(NodeId) {
            if self.node_down[n.0 as usize] || self.retired[n.0 as usize] {
                continue;
            }
            if fallback.is_none() {
                fallback = Some(n);
            }
            if !own.contains(&n) {
                return Some(n);
            }
        }
        fallback
    }

    /// One checkpoint cadence firing: every running task writes a synthetic
    /// delta snapshot. A rotating `store_delta_fraction` window of chunks
    /// bumps its content version; everything else re-addresses identically
    /// and deduplicates — the FFTrainer-style near-zero steady-state cost.
    /// Peer-memory and local-disk copies land on the checkpoint peer; every
    /// 4th tick also persists to remote (the always-survives baseline).
    fn on_checkpoint_tick(&mut self) {
        self.ckpt_ticks += 1;
        let step = self.ckpt_ticks;
        for ti in self.active_indices() {
            if self.tasks[ti].workers == 0 {
                continue;
            }
            let task = self.tasks[ti].spec.id;
            let n = self.chunk_version[ti].len();
            if n == 0 {
                continue;
            }
            let dirty = (((n as f64) * self.store_delta_fraction).ceil() as usize).clamp(1, n);
            let start = ((step - 1) as usize).wrapping_mul(dirty) % n;
            for k in 0..dirty {
                self.chunk_version[ti][(start + k) % n] += 1;
            }
            let chunks: Vec<ChunkId> = self.chunk_version[ti]
                .iter()
                .enumerate()
                .map(|(i, &v)| ChunkId::synthetic(task, i as u64, v))
                .collect();
            let manifest = Manifest {
                task,
                step,
                total_bytes: self.state_bytes[ti],
                chunk_bytes: SIM_CHUNK_BYTES,
                chunks,
            };
            let peer = self.checkpoint_peer(ti);
            self.store.put_manifest(Tier::PeerMemory, peer, &manifest);
            self.store.put_manifest(Tier::LocalDisk, peer, &manifest);
            if step % 4 == 0 {
                self.store.put_manifest(Tier::Remote, None, &manifest);
            }
        }
    }

    /// Bytes a replacement node must pull to rejoin `ti` at `workers`
    /// workers: the per-node shard of the task's state.
    fn shard_bytes(&self, ti: usize, workers: u32) -> u64 {
        let gpn = self.cluster.gpus_per_node as u64;
        (self.state_bytes[ti].saturating_mul(gpn) / (workers.max(1) as u64))
            .min(self.state_bytes[ti])
    }

    /// Report residency changes to the policy (wire v6): after the store's
    /// contents moved (peer loss), any task whose nearest resident tier or
    /// restore estimate changed gets a [`CoordEvent::StateResidency`]
    /// *before* the failure event, so the SEV1 replan prices the true
    /// restore path (the coordinator invalidates and rebuilds its table).
    fn emit_residency_updates(&mut self) {
        if !self.store_aware {
            return;
        }
        for ti in self.active_indices() {
            let task = self.tasks[ti].spec.id;
            let shard = self.shard_bytes(ti, self.tasks[ti].workers);
            let source = resolve_source(false, &self.store, task);
            let restore_s = match self.store.restore_estimate_s(task, shard) {
                Some((_, est)) => est,
                // nothing resident anywhere: price the always-there remote
                // persistent baseline from its tier stats
                None => self.store.tier_stats(Tier::Remote).time_s(shard),
            };
            if self.last_residency[ti] == Some((source, restore_s)) {
                continue;
            }
            self.last_residency[ti] = Some((source, restore_s));
            let actions = self.decide(CoordEvent::StateResidency { task, source, restore_s });
            self.execute(&actions, &Ctx::quiet());
        }
    }

    /// Reconfigure the cluster to `plan`. Each task whose worker count
    /// changes (or that hosts the fault, or that must pull state onto newly
    /// gained nodes) goes down for detection + a transition proportional to
    /// the GPUs it moves, then resumes at the new size — the Fig. 9 cost
    /// model.
    ///
    /// With a layout-carrying plan (wire v4) the moved-GPU count is a real
    /// migration fact: workers on *gained* nodes must receive state, workers
    /// that stay in place pay nothing — so a min-churn layout transitions
    /// strictly cheaper than a topology-blind reshuffle of the same counts
    /// (the `placement-frag` experiment pins this).
    fn apply_plan(&mut self, plan: &Plan, ctx: &Ctx) {
        let active = self.active_indices();
        debug_assert_eq!(active.len(), plan.assignment.len(), "policy assignment order contract");
        let detect = match ctx.severity {
            Some(sev) if !ctx.instant => self.params.detect_s(sev),
            _ => 0.0,
        };
        let gpn = self.cluster.gpus_per_node;
        // Execute the concrete node assignment: diff the new map against
        // the executed one (the placement layer's own move accounting),
        // then install it.
        let mut moves: Vec<Option<TaskMoves>> = vec![None; self.tasks.len()];
        if !plan.layout.is_empty() {
            for m in plan.layout.diff(&self.layout) {
                if let Some(ti) = self.index_of(m.task) {
                    moves[ti] = Some(m);
                }
            }
            self.layout = plan.layout.clone();
        }
        for (k, &ti) in active.iter().enumerate() {
            let new_w = plan.assignment.get(k).copied().unwrap_or(0);
            let old_w = self.tasks[ti].workers;
            let affected = ctx.affected == Some(ti);
            // workers that must receive migrated state: the overflow that
            // does not fit on the task's kept nodes (TaskMoves::gained_gpus)
            let gained_gpus =
                moves[ti].as_ref().map_or(0, |m| m.gained_gpus(gpn, new_w));
            if new_w == old_w && !affected && gained_gpus == 0 {
                continue;
            }
            if ctx.instant {
                let t = &mut self.tasks[ti];
                t.workers = new_w;
                t.pending_workers = new_w;
                t.down_until = None;
                continue;
            }
            // layout plans move exactly the gained workers; legacy plans
            // approximate with the count delta. The faulted task pays at
            // least a node's worth of migration either way.
            let base_moved =
                if plan.layout.is_empty() { old_w.abs_diff(new_w) } else { gained_gpus };
            let moved = base_moved.max(if affected { gpn } else { 0 });
            let mut trans = self.params.sev1_transition_s(moved);
            // Store-aware failover: when the faulted task has a resident
            // snapshot, the transition is the actual restore from its
            // nearest tier — latency plus the replacement node's shard over
            // tier bandwidth — not the closed-form migration model. The
            // executed transfer feeds the tier's measured-bandwidth EWMA.
            if self.store_aware && affected {
                let task = self.tasks[ti].spec.id;
                let shard = self.shard_bytes(ti, new_w);
                if let Some((tier, restore_s)) = self.store.restore(task, shard) {
                    trans = restore_s;
                    self.store.observe_transfer(tier, shard, restore_s);
                    self.store_restores.push((self.now, restore_s));
                }
            }
            let until = self.now + detect + trans;
            let t = &mut self.tasks[ti];
            t.down_until = Some(until);
            t.pending_workers = new_w;
            t.epoch += 1;
            let epoch = t.epoch;
            self.queue.schedule(until, EnvEvent::RecoveryDone { task: ti, workers: new_w, epoch });
            if affected {
                self.transitions.push((self.now, detect + trans));
            }
        }
    }

    /// Execute an in-place reattempt/restart instruction: the task is down
    /// for detection + restart + recompute, then resumes at its pending
    /// size, and the outcome is reported back to the policy.
    fn instruct_recovery(&mut self, task_id: TaskId, node: NodeId, reattempt: bool, ctx: &Ctx) {
        let Some(ti) = self.index_of(task_id) else { return };
        let sev = ctx.severity.unwrap_or(Severity::Sev2);
        let dt = self.params.detect_s(sev) + self.params.restart_recovery_s();
        let until = self.now + dt;
        let t = &mut self.tasks[ti];
        // A failure mid-recovery restarts the recovery (the new process dies
        // during setup/recompute) — this compounds under trace-b's rates.
        // Resume at whichever size the task was headed for.
        let w = t.pending_workers.max(t.workers);
        t.down_until = Some(until);
        t.epoch += 1;
        let epoch = t.epoch;
        self.queue.schedule(until, EnvEvent::RecoveryDone { task: ti, workers: w, epoch });
        let result = if reattempt {
            CoordEvent::ReattemptResult { node, task: task_id, ok: true }
        } else {
            CoordEvent::RestartResult { node, task: task_id, ok: true }
        };
        self.queue.schedule(until, EnvEvent::PolicyResult { result });
    }

    /// Fence a node. Idempotent: trace SEV1s pre-mark the node (hardware is
    /// down whatever the policy says), so the policy's `IsolateNode` is a
    /// no-op then; a policy-escalated isolation (failed restart chain) marks
    /// it here and schedules a repair at the environment's default delay.
    fn isolate(&mut self, node: NodeId) {
        let idx = node.0 as usize;
        if idx >= self.node_down.len() || self.node_down[idx] {
            return;
        }
        self.node_down[idx] = true;
        self.available = self.available.saturating_sub(self.cluster.gpus_per_node);
        self.queue.schedule(self.now + self.default_repair_s, EnvEvent::Repair { node });
    }

    /// Run the trace to completion.
    pub fn run(mut self, trace: &Trace) -> SimResult {
        self.default_repair_s = 0.5 * (trace.config.repair_min_s + trace.config.repair_max_s);
        let active = trace.initially_active(self.tasks.len());
        for (t, &a) in self.tasks.iter_mut().zip(&active) {
            t.active = a;
        }
        self.policy.init(&self.plan_inputs, &active, WorkerCount(self.available));

        for (i, e) in trace.events.iter().enumerate() {
            self.queue.schedule(e.at_s, EnvEvent::Failure(i));
        }
        for (i, l) in trace.lifecycle.iter().enumerate() {
            self.queue.schedule(l.at_s, EnvEvent::Lifecycle(i));
        }
        if self.store_aware && self.ckpt_interval_s > 0.0 {
            self.queue.schedule(self.ckpt_interval_s, EnvEvent::CheckpointTick);
        }
        for (i, d) in trace.degradations.iter().enumerate() {
            self.queue.schedule(d.at_s, EnvEvent::DegradationStart(i));
            if d.kind != DegradationKind::ChurnRisk {
                self.queue
                    .schedule(d.at_s + d.duration_s, EnvEvent::DegradationEnd { node: d.node });
                // in-band step reports: a healthy warm-up run-in so the
                // coordinator's estimators have a baseline, then reports
                // through the episode at the configured cadence
                let period = self.step_period_s.max(1.0);
                let warm = self.health_warm_samples as f64 * period;
                let mut t = (d.at_s - warm).max(0.0);
                while t < d.at_s + d.duration_s {
                    self.queue.schedule(t, EnvEvent::StepReport { di: i });
                    t += period;
                }
            }
        }

        // Bootstrap: the initial assignment is itself a policy decision (a
        // TaskLaunched replan), applied instantly — §7.5 starts every policy
        // from the same healthy plan.
        if let Some(&first) = self.active_indices().first() {
            let ev = CoordEvent::TaskLaunched { task: self.tasks[first].spec.id };
            let actions = self.decide(ev);
            self.execute(&actions, &Ctx::bootstrap());
        }
        self.record(); // t=0 healthy level
        let healthy_waf = self.last_waf;

        while let Some((at, ev)) = self.queue.pop() {
            if at > trace.config.duration_s {
                break;
            }
            self.now = at;
            match ev {
                EnvEvent::Failure(i) => {
                    // Batched dispatch: further SEV1 trace failures due at
                    // the bit-identical instant (total_cmp equality) drain
                    // into one CoordEvent::Batch — the whole burst costs one
                    // decide/replan cycle. Independent trace failures never
                    // collide bitwise (exponential inter-arrivals), so this
                    // path only fires for deliberately correlated bursts.
                    if trace.events[i].severity() == Severity::Sev1 {
                        let mut burst = vec![i];
                        while let Some(j) = self.pop_simultaneous_sev1(trace, at) {
                            burst.push(j);
                        }
                        if burst.len() > 1 {
                            self.on_trace_failure_burst(trace, &burst);
                        } else {
                            self.on_trace_failure(trace, i);
                        }
                    } else {
                        self.on_trace_failure(trace, i);
                    }
                }
                EnvEvent::Lifecycle(i) => self.on_lifecycle(trace, i),
                EnvEvent::Repair { node } => self.on_repair(node),
                EnvEvent::RecoveryDone { task, workers, epoch } => {
                    let t = &mut self.tasks[task];
                    if t.epoch == epoch && t.active {
                        t.workers = workers;
                        t.pending_workers = workers;
                        t.down_until = None;
                    }
                }
                EnvEvent::PolicyResult { result } => {
                    let actions = self.decide(result);
                    // success reports ask for nothing, but execute whatever
                    // the policy returns (defensive: escalations)
                    self.execute(&actions, &Ctx::quiet());
                }
                EnvEvent::ReplanTimer => {
                    // The batch window elapsed: the policy either commits
                    // the consolidated burst replan now or reports nothing
                    // (an earlier replan already settled it). The flush is
                    // SEV1 recovery work — it pays the standard detection
                    // latency once (deferred members never charged it) plus
                    // the per-GPU migration of whatever actually moves.
                    let actions = self.decide(CoordEvent::ReplanDue);
                    self.execute(&actions, &Ctx::failure(Severity::Sev1, None));
                }
                EnvEvent::CheckpointTick => {
                    self.on_checkpoint_tick();
                    self.queue.schedule(self.now + self.ckpt_interval_s, EnvEvent::CheckpointTick);
                }
                EnvEvent::DegradationStart(i) => self.on_degradation_start(trace, i),
                EnvEvent::DegradationEnd { node } => {
                    self.degraded.remove(&node);
                }
                EnvEvent::StepReport { di } => self.on_step_report(trace, di),
            }
            self.record();
        }
        self.now = trace.config.duration_s;
        self.record();

        let (plan_lookup_hits, plan_solve_calls) = self.policy.plan_stats();
        SimResult {
            policy: self.params.kind,
            waf_series: self.series,
            accumulated_waf: self.accumulated,
            healthy_waf,
            duration_s: trace.config.duration_s,
            transitions: self.transitions,
            decision_log: self.decision_log,
            alerts: self.alerts,
            plan_lookup_hits,
            plan_solve_calls,
            store_restores: self.store_restores,
            store_report: if self.store_aware { Some(self.store.report()) } else { None },
        }
    }

    /// Translate one trace failure into the coordinator vocabulary and run
    /// the decide → execute cycle.
    fn on_trace_failure(&mut self, trace: &Trace, idx: usize) {
        let ev = &trace.events[idx];
        let node = ev.node;
        if self.node_down[node.0 as usize] {
            return; // node already out; failure has no additional effect
        }
        match ev.severity() {
            Severity::Sev1 => {
                let affected = self.owner_of(node);
                // hardware state changes regardless of any policy decision
                self.node_down[node.0 as usize] = true;
                self.available = self.available.saturating_sub(self.cluster.gpus_per_node);
                self.queue.schedule(self.now + ev.repair_after_s, EnvEvent::Repair { node });
                if self.store_aware {
                    // the node's peer-memory replicas and local disk die
                    // with it; residency falls down the ladder, and the
                    // policy hears about it before the failure itself
                    self.store.drop_peer(node);
                    self.emit_residency_updates();
                }
                let coord_ev = match affected {
                    Some(ti) => CoordEvent::ErrorReport {
                        node,
                        task: self.tasks[ti].spec.id,
                        kind: ev.kind,
                    },
                    None => CoordEvent::NodeLost { node },
                };
                let actions = self.decide(coord_ev);
                self.execute(&actions, &Ctx::failure(Severity::Sev1, affected));
                // Burst batching: the policy deferred the replan
                // (ScheduleReplan, no ApplyPlan). The hardware is gone
                // regardless — the affected task limps on minus the lost
                // node (§6.2 partial-iteration reuse keeps it training)
                // until the consolidated replan commits.
                let deferred = actions
                    .iter()
                    .any(|a| matches!(a, Action::ScheduleReplan { .. }))
                    && !actions.iter().any(|a| matches!(a, Action::ApplyPlan { .. }));
                if deferred {
                    if let Some(ti) = affected {
                        let gpn = self.cluster.gpus_per_node;
                        let t = &mut self.tasks[ti];
                        t.workers = t.workers.saturating_sub(gpn);
                        t.pending_workers = t.pending_workers.saturating_sub(gpn);
                    }
                }
            }
            sev => {
                // SEV2/SEV3: process-level; hits whatever task owns the node
                let Some(ti) = self.owner_of(node) else { return };
                if self.tasks[ti].pending_workers == 0 {
                    return; // stalled anyway; nothing more to lose
                }
                let coord_ev =
                    CoordEvent::ErrorReport { node, task: self.tasks[ti].spec.id, kind: ev.kind };
                let actions = self.decide(coord_ev);
                self.execute(&actions, &Ctx::failure(sev, Some(ti)));
            }
        }
    }

    /// Pop the next queued event only if it is another SEV1 trace failure
    /// due at the bit-identical instant `at` — the drain step of batched
    /// dispatch. Anything else (later time, other event kind, SEV2/SEV3)
    /// stays queued and takes the one-event-at-a-time path.
    fn pop_simultaneous_sev1(&mut self, trace: &Trace, at: f64) -> Option<usize> {
        let j = match self.queue.peek() {
            Some((t, &EnvEvent::Failure(j)))
                if t.total_cmp(&at) == std::cmp::Ordering::Equal
                    && trace.events[j].severity() == Severity::Sev1 =>
            {
                j
            }
            _ => return None,
        };
        self.queue.pop();
        Some(j)
    }

    /// N SEV1 trace failures at the bit-identical instant, ONE
    /// decide/execute cycle: hardware effects land per node, every affected
    /// task is pre-shrunk by its lost capacity (it limps on via §6.2
    /// partial-iteration reuse — the same semantics the deferred
    /// burst-batch path established), and the policy sees a single
    /// [`CoordEvent::Batch`] that commits one consolidated plan for the
    /// merged loss.
    fn on_trace_failure_burst(&mut self, trace: &Trace, idxs: &[usize]) {
        let gpn = self.cluster.gpus_per_node;
        let mut members = Vec::new();
        for &idx in idxs {
            let ev = &trace.events[idx];
            let node = ev.node;
            if self.node_down[node.0 as usize] {
                continue; // already out; no additional effect
            }
            let affected = self.owner_of(node);
            self.node_down[node.0 as usize] = true;
            self.available = self.available.saturating_sub(gpn);
            self.queue.schedule(self.now + ev.repair_after_s, EnvEvent::Repair { node });
            if self.store_aware {
                self.store.drop_peer(node);
            }
            if let Some(ti) = affected {
                // the consolidated plan prices the merged post-burst state,
                // so the shrink lands up front, not via the deferred path
                let t = &mut self.tasks[ti];
                t.workers = t.workers.saturating_sub(gpn);
                t.pending_workers = t.pending_workers.saturating_sub(gpn);
            }
            members.push(match affected {
                Some(ti) => CoordEvent::ErrorReport {
                    node,
                    task: self.tasks[ti].spec.id,
                    kind: ev.kind,
                },
                None => CoordEvent::NodeLost { node },
            });
        }
        if members.is_empty() {
            return; // every node in the burst was already down
        }
        self.emit_residency_updates();
        let actions = self.decide(CoordEvent::Batch(members));
        self.execute(&actions, &Ctx::failure(Severity::Sev1, None));
    }

    /// A degradation episode begins. Measured slowdowns (straggler, gray
    /// bandwidth) start dragging the owner task's WAF and are *not*
    /// reported to the policy directly — the policy only ever sees the
    /// in-band [`CoordEvent::StepTiming`] stream, exactly like production.
    /// [`DegradationKind::ChurnRisk`] advisories are the opposite: there is
    /// nothing to measure (the provider pushed a warning), so the verdict
    /// itself is forwarded as [`CoordEvent::NodeDegraded`].
    fn on_degradation_start(&mut self, trace: &Trace, idx: usize) {
        let d = &trace.degradations[idx];
        let ni = d.node.0 as usize;
        if ni >= self.node_down.len() || self.node_down[ni] || self.retired[ni] {
            return; // a dead node cannot degrade
        }
        if d.kind == DegradationKind::ChurnRisk {
            let Some(ti) = self.owner_of(d.node) else { return };
            let ev = CoordEvent::NodeDegraded {
                node: d.node,
                task: self.tasks[ti].spec.id,
                kind: d.kind,
                slow_frac: d.slow_frac,
            };
            let actions = self.decide(ev);
            self.execute(&actions, &Ctx::quiet());
        } else {
            self.degraded.insert(d.node, d.slow_frac.clamp(0.0, 0.999));
        }
    }

    /// One in-band step-timing report: the watched node tells the policy how
    /// long its last step took. Healthy nodes report [`SIM_STEP_S`];
    /// degraded ones report the stretched duration. If the policy reacts
    /// (detection verdict crossed the ledger's break-even), the eviction
    /// executes with SEV1 recovery mechanics — the node is policy-fenced,
    /// its task replans without it, and the WAF drag ends.
    fn on_step_report(&mut self, trace: &Trace, di: usize) {
        let node = trace.degradations[di].node;
        let ni = node.0 as usize;
        if ni >= self.node_down.len() || self.node_down[ni] || self.retired[ni] {
            return; // fenced or dead nodes run no steps
        }
        let Some(ti) = self.owner_of(node) else { return };
        let t = &self.tasks[ti];
        if !t.active || t.workers == 0 || t.down_until.is_some_and(|u| self.now < u) {
            return; // no steps while the task is down or gone
        }
        let duration_s = match self.degraded.get(&node) {
            Some(&slow) => SIM_STEP_S / (1.0 - slow),
            None => SIM_STEP_S,
        };
        let ev = CoordEvent::StepTiming { node, task: self.tasks[ti].spec.id, duration_s };
        let actions = self.decide(ev);
        if !actions.is_empty() {
            self.execute(&actions, &Ctx::failure(Severity::Sev1, Some(ti)));
            self.degraded.remove(&node);
        }
    }

    /// Repair completed. The environment no longer re-admits a node on
    /// its own: it reports [`CoordEvent::NodeRepaired`] and executes
    /// whatever the policy decides — rejoin (`SpareRetained`), return to
    /// the provider (`SpareReleased`), or fence for good
    /// (`NodeQuarantined`). A policy that answers with none of these leaves
    /// the node out of service.
    fn on_repair(&mut self, node: NodeId) {
        let idx = node.0 as usize;
        if self.retired[idx] || !self.node_down[idx] {
            return;
        }
        let actions = self.decide(CoordEvent::NodeRepaired { node });
        self.execute(&actions, &Ctx::quiet());
    }

    /// Fig. 7 triggers ⑤⑥: task departure/arrival mid-trace.
    fn on_lifecycle(&mut self, trace: &Trace, idx: usize) {
        let l = &trace.lifecycle[idx];
        let Some(ti) = self.index_of(l.task) else { return };
        match l.kind {
            LifecycleKind::Arrival => {
                if self.tasks[ti].active {
                    return;
                }
                self.tasks[ti].active = true;
                self.policy.admit_task(self.plan_inputs[ti].clone());
                let actions = self.decide(CoordEvent::TaskLaunched { task: l.task });
                self.execute(&actions, &Ctx::quiet());
            }
            LifecycleKind::Departure => {
                if !self.tasks[ti].active {
                    return;
                }
                let t = &mut self.tasks[ti];
                t.active = false;
                t.workers = 0;
                t.pending_workers = 0;
                t.down_until = None;
                t.epoch += 1; // orphan any in-flight recovery
                let actions = self.decide(CoordEvent::TaskFinished { task: l.task });
                self.execute(&actions, &Ctx::quiet());
            }
        }
    }
}

/// Convenience: run one trace under every policy.
pub fn compare_policies(
    cluster: &ClusterSpec,
    cfg: &UnicronConfig,
    specs: &[TaskSpec],
    trace: &Trace,
) -> Vec<SimResult> {
    PolicyKind::all()
        .iter()
        .map(|&k| {
            Simulator::builder()
                .cluster(cluster.clone())
                .config(cfg.clone())
                .policy(k)
                .tasks(specs)
                .build()
                .run(trace)
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::table3_case;
    use crate::failure::TraceConfig;

    fn setup() -> (ClusterSpec, UnicronConfig, Vec<TaskSpec>) {
        (ClusterSpec::default(), UnicronConfig::default(), table3_case(5))
    }

    fn run(kind: PolicyKind, trace: &Trace) -> SimResult {
        let (cluster, cfg, specs) = setup();
        Simulator::builder()
            .cluster(cluster)
            .config(cfg)
            .policy(kind)
            .tasks(&specs)
            .build()
            .run(trace)
    }

    #[test]
    fn healthy_cluster_efficiencies_ordered() {
        // with an empty trace the accumulated WAF ratio equals the efficiency
        let mut tc = TraceConfig::trace_a();
        tc.expect_sev1 = 0.0;
        tc.expect_other = 0.0;
        let trace = Trace::generate(tc, 1);
        let uni = run(PolicyKind::Unicron, &trace);
        let meg = run(PolicyKind::Megatron, &trace);
        let oob = run(PolicyKind::Oobleck, &trace);
        assert!((uni.accumulated_waf - meg.accumulated_waf).abs() < 1e-6 * meg.accumulated_waf);
        assert!(meg.accumulated_waf > 2.0 * oob.accumulated_waf);
        assert!(uni.reduction().abs() < 1e-9);
    }

    #[test]
    fn deterministic_given_trace() {
        let trace = Trace::generate(TraceConfig::trace_a(), 11);
        let a = run(PolicyKind::Unicron, &trace);
        let b = run(PolicyKind::Unicron, &trace);
        assert_eq!(a.accumulated_waf, b.accumulated_waf);
        assert_eq!(a.waf_series, b.waf_series);
        assert_eq!(a.decision_log, b.decision_log);
    }

    #[test]
    fn failures_reduce_waf() {
        let trace = Trace::generate(TraceConfig::trace_a(), 5);
        let r = run(PolicyKind::Unicron, &trace);
        assert!(r.reduction() > 0.0, "SEV1s must cost something");
        assert!(r.reduction() < 0.5, "Unicron should keep most of the work: {}", r.reduction());
    }

    #[test]
    fn unicron_beats_megatron_on_trace_a_by_fig11_margin() {
        let trace = Trace::generate(TraceConfig::trace_a(), 42);
        let uni = run(PolicyKind::Unicron, &trace);
        let meg = run(PolicyKind::Megatron, &trace);
        let ratio = uni.accumulated_waf / meg.accumulated_waf;
        // paper: 1.2× on trace-a; accept a band around it
        assert!((1.05..1.6).contains(&ratio), "ratio {ratio}");
    }

    #[test]
    fn unicron_margin_grows_on_trace_b() {
        let ta = Trace::generate(TraceConfig::trace_a(), 42);
        let tb = Trace::generate(TraceConfig::trace_b(), 42);
        let ratio_a = run(PolicyKind::Unicron, &ta).accumulated_waf
            / run(PolicyKind::Megatron, &ta).accumulated_waf;
        let ratio_b = run(PolicyKind::Unicron, &tb).accumulated_waf
            / run(PolicyKind::Megatron, &tb).accumulated_waf;
        assert!(ratio_b > ratio_a, "trace-b {ratio_b} should exceed trace-a {ratio_a}");
        assert!((1.3..3.0).contains(&ratio_b), "trace-b ratio {ratio_b}");
    }

    #[test]
    fn unicron_dominates_resilient_baselines() {
        let trace = Trace::generate(TraceConfig::trace_a(), 7);
        let uni = run(PolicyKind::Unicron, &trace);
        for k in [PolicyKind::Oobleck, PolicyKind::Varuna, PolicyKind::Bamboo] {
            let r = run(k, &trace);
            let ratio = uni.accumulated_waf / r.accumulated_waf;
            assert!((2.0..8.0).contains(&ratio), "{k:?} ratio {ratio}");
        }
    }

    #[test]
    fn series_is_time_ordered_and_nonnegative() {
        let trace = Trace::generate(TraceConfig::trace_b(), 3);
        let r = run(PolicyKind::Varuna, &trace);
        let mut prev = 0.0;
        for &(t, w) in &r.waf_series {
            assert!(t >= prev);
            assert!(w >= 0.0);
            prev = t;
        }
        assert!(r.accumulated_waf > 0.0);
    }

    #[test]
    fn transitions_recorded_for_sev1() {
        let trace = Trace::generate(TraceConfig::trace_a(), 9);
        let sev1s = trace.count_by_severity(Severity::Sev1);
        let r = run(PolicyKind::Unicron, &trace);
        assert!(!r.transitions.is_empty());
        assert!(r.transitions.len() <= sev1s + 2);
        for &(_, d) in &r.transitions {
            assert!(d > 0.0 && d < 600.0, "unicron transition {d}s");
        }
    }

    #[test]
    fn compare_policies_preserves_paper_ordering_on_trace_a() {
        let (cluster, cfg, specs) = setup();
        let trace = Trace::generate(TraceConfig::trace_a(), 42);
        let results = compare_policies(&cluster, &cfg, &specs, &trace);
        let acc =
            |k: PolicyKind| results.iter().find(|r| r.policy == k).unwrap().accumulated_waf;
        let uni = acc(PolicyKind::Unicron);
        for k in
            [PolicyKind::Megatron, PolicyKind::Oobleck, PolicyKind::Varuna, PolicyKind::Bamboo]
        {
            assert!(uni > acc(k), "Unicron must accumulate the most WAF (vs {k:?})");
        }
        // Fig. 11 trace-a baseline ordering: Megatron > Oobleck > Bamboo > Varuna
        assert!(acc(PolicyKind::Megatron) > acc(PolicyKind::Oobleck));
        assert!(acc(PolicyKind::Oobleck) > acc(PolicyKind::Bamboo));
        assert!(acc(PolicyKind::Bamboo) > acc(PolicyKind::Varuna));
    }

    #[test]
    fn unicron_decisions_flow_through_coordinator_actions() {
        // No inline SEV1/SEV2/SEV3 branching for Unicron anymore: every
        // effect the environment applies is justified by a logged
        // coordinator action.
        let trace = Trace::generate(TraceConfig::trace_a(), 42);
        let r = run(PolicyKind::Unicron, &trace);
        assert!(!r.decision_log.is_empty());
        let isolations =
            r.decision_log.actions().filter(|a| matches!(a, Action::IsolateNode { .. })).count();
        assert_eq!(isolations, r.alerts, "every isolation pages ops");
        assert!(
            r.decision_log.actions().any(|x| matches!(
                x,
                Action::ApplyPlan { reason: crate::proto::PlanReason::Sev1Failure, .. }
            )),
            "SEV1 replans must come from the coordinator"
        );
        // bootstrap decision is the first log entry
        assert!(matches!(r.decision_log.entries[0].event, CoordEvent::TaskLaunched { .. }));
    }

    #[test]
    fn repairs_are_policy_decisions_for_every_policy() {
        // The environment never re-admits a node on its own: every repair
        // surfaces as NodeRepaired and capacity returns only through an
        // executed SpareRetained.
        let trace = Trace::generate(TraceConfig::trace_a(), 42);
        for kind in PolicyKind::all() {
            let r = run(kind, &trace);
            let repairs = r
                .decision_log
                .events()
                .filter(|e| matches!(e, CoordEvent::NodeRepaired { .. }))
                .count();
            let retained = r
                .decision_log
                .actions()
                .filter(|a| matches!(a, Action::SpareRetained { .. }))
                .count();
            assert!(repairs > 0, "{kind:?}: trace-a repairs must surface");
            assert_eq!(repairs, retained, "{kind:?}: stock traces always retain");
            assert!(
                !r.decision_log.events().any(|e| matches!(e, CoordEvent::NodeJoined { .. })),
                "{kind:?}: simulated repairs are NodeRepaired, not NodeJoined"
            );
        }
    }

    #[test]
    fn simulated_sev1_replans_hit_the_precomputed_table() {
        // ROADMAP SEV1 hot-path item: inside the simulator too, replans are
        // table commits, not per-event solves.
        let trace = Trace::generate(TraceConfig::trace_a(), 42);
        let r = run(PolicyKind::Unicron, &trace);
        assert!(r.plan_lookup_hits > 0, "SEV1/repair replans must be table hits");
        assert!(
            r.plan_lookup_hits >= r.plan_solve_calls,
            "the table path must dominate: {} hits vs {} solves",
            r.plan_lookup_hits,
            r.plan_solve_calls
        );
        let meg = run(PolicyKind::Megatron, &trace);
        assert_eq!((meg.plan_lookup_hits, meg.plan_solve_calls), (0, 0), "baselines have no table");
    }

    #[test]
    fn recurrent_lemon_is_quarantined_and_quarantine_pays() {
        let (cluster, cfg, specs) = setup();
        let tc = TraceConfig {
            name: "lemon".into(),
            duration_s: 6.0 * 3600.0,
            n_nodes: cluster.n_nodes,
            expect_sev1: 0.0,
            expect_other: 0.0,
            repair_min_s: 0.25 * 86400.0,
            repair_max_s: 86400.0,
        };
        // period > restart recovery (~17 s): every restart succeeds, the
        // escalation ladder resets, and only the fleet's recurrence memory
        // can fence the node
        let trace = Trace::generate(tc, 1).with_recurrent_lemon(
            crate::proto::NodeId(5),
            crate::failure::ErrorKind::CudaError,
            600.0,
            30.0,
            f64::INFINITY,
        );
        let mut off_cfg = cfg.clone();
        off_cfg.lemon_quarantine = false;
        let run_with = |c: &UnicronConfig| {
            Simulator::builder()
                .cluster(cluster.clone())
                .config(c.clone())
                .policy(PolicyKind::Unicron)
                .tasks(&specs)
                .build()
                .run(&trace)
        };
        let on = run_with(&cfg);
        let off = run_with(&off_cfg);
        let quarantines = |r: &SimResult| {
            r.decision_log
                .actions()
                .filter(|a| matches!(a, Action::NodeQuarantined { .. }))
                .count()
        };
        assert_eq!(quarantines(&on), 1, "the lemon is fenced exactly once");
        assert_eq!(quarantines(&off), 0);
        assert!(
            on.accumulated_waf >= off.accumulated_waf,
            "fencing the lemon must not lose goodput: on {} vs off {}",
            on.accumulated_waf,
            off.accumulated_waf
        );
    }

    #[test]
    fn store_aware_recovery_is_gated_and_executes_restores() {
        let (cluster, cfg, specs) = setup();
        // gate off (the default): no ticks, no restores, no report — the
        // pinned ratio bands and the determinism corpus never see the store
        let off = run(PolicyKind::Unicron, &Trace::generate(TraceConfig::trace_a(), 42));
        assert!(off.store_restores.is_empty());
        assert!(off.store_report.is_none());
        // gate on: a quiet 6 h window with one injected SEV1 after four
        // checkpoint ticks — the failover restores from the store, and the
        // synthetic 1%-delta checkpoints deduplicate heavily
        let mut on_cfg = cfg.clone();
        on_cfg.store_aware_recovery = true;
        let tc = TraceConfig {
            name: "store-gate".into(),
            duration_s: 6.0 * 3600.0,
            n_nodes: cluster.n_nodes,
            expect_sev1: 0.0,
            expect_other: 0.0,
            repair_min_s: 86400.0,
            repair_max_s: 86400.0,
        };
        let trace = Trace::generate(tc, 1).with_injected_failure(
            crate::proto::NodeId(0),
            2.5 * 3600.0,
            crate::failure::ErrorKind::LostConnection,
        );
        let r = Simulator::builder()
            .cluster(cluster)
            .config(on_cfg)
            .policy(PolicyKind::Unicron)
            .tasks(&specs)
            .build()
            .run(&trace);
        assert_eq!(r.store_restores.len(), 1, "the injected SEV1 restores from the store");
        let (at, d) = r.store_restores[0];
        assert!((at - 2.5 * 3600.0).abs() < 1e-6 && d > 0.0 && d.is_finite());
        let rep = r.store_report.expect("store report");
        let dedup = rep.get("dedup_ratio").and_then(crate::ser::Value::as_f64).unwrap();
        assert!(dedup > 3.0, "1%-delta checkpoints must dedup heavily: {dedup}");
        // residency reports reached the decision log (wire v6)
        assert!(
            r.decision_log.events().any(|e| matches!(e, CoordEvent::StateResidency { .. })),
            "peer loss must surface residency changes"
        );
    }

    #[test]
    fn straggler_is_detected_in_band_and_eviction_beats_tolerating() {
        let (cluster, cfg, specs) = setup();
        let tc = TraceConfig {
            name: "straggler".into(),
            duration_s: 6.0 * 3600.0,
            n_nodes: cluster.n_nodes,
            expect_sev1: 0.0,
            expect_other: 0.0,
            repair_min_s: 86400.0,
            repair_max_s: 86400.0,
        };
        let trace = Trace::generate(tc, 1).with_straggler_onset(
            crate::proto::NodeId(3),
            4000.0,
            0.7,
            18000.0,
        );
        let mut off_cfg = cfg.clone();
        off_cfg.degradation_detection = false;
        let run_with = |c: &UnicronConfig| {
            Simulator::builder()
                .cluster(cluster.clone())
                .config(c.clone())
                .policy(PolicyKind::Unicron)
                .tasks(&specs)
                .build()
                .run(&trace)
        };
        let on = run_with(&cfg);
        let off = run_with(&off_cfg);
        // the policy only ever saw the in-band timing stream
        assert!(on.decision_log.events().any(|e| matches!(e, CoordEvent::StepTiming { .. })));
        assert!(
            !on.decision_log.events().any(|e| matches!(e, CoordEvent::NodeDegraded { .. })),
            "measured slowdowns are detected, not announced"
        );
        // detection-on evicts the straggler and pages ops about it
        let evicted = on
            .decision_log
            .iter()
            .any(|en| {
                matches!(en.event, CoordEvent::StepTiming { .. })
                    && en.actions.iter().any(
                        |a| matches!(a, Action::IsolateNode { node: crate::proto::NodeId(3) }),
                    )
            });
        assert!(evicted, "the sustained straggler must be evicted");
        assert!(on.alerts >= 1);
        // detection-off drags the whole cohort for the full episode
        assert!(
            !off.decision_log.actions().any(|a| matches!(a, Action::IsolateNode { .. })),
            "oblivious run must not evict"
        );
        assert!(
            on.accumulated_waf > off.accumulated_waf,
            "detect-and-evict must beat tolerating: on {} vs off {}",
            on.accumulated_waf,
            off.accumulated_waf
        );
        // deterministic — the corpus contract extends to degradations
        let again = run_with(&cfg);
        assert_eq!(on.decision_log, again.decision_log);
        assert_eq!(on.accumulated_waf, again.accumulated_waf);
    }

    #[test]
    fn mild_gray_bandwidth_is_tolerated_but_costs_goodput() {
        let (cluster, cfg, specs) = setup();
        let tc = TraceConfig {
            name: "gray".into(),
            duration_s: 6.0 * 3600.0,
            n_nodes: cluster.n_nodes,
            expect_sev1: 0.0,
            expect_other: 0.0,
            repair_min_s: 86400.0,
            repair_max_s: 86400.0,
        };
        let quiet = Trace::generate(tc.clone(), 1);
        let gray = Trace::generate(tc, 1).with_gray_bandwidth(
            crate::proto::NodeId(2),
            5000.0,
            0.10,
            8000.0,
        );
        let run_with = |t: &Trace| {
            Simulator::builder()
                .cluster(cluster.clone())
                .config(cfg.clone())
                .policy(PolicyKind::Unicron)
                .tasks(&specs)
                .build()
                .run(t)
        };
        let healthy = run_with(&quiet);
        let r = run_with(&gray);
        // a 10% slowdown sits below the ledger's break-even: no eviction,
        // but the drag is real while the episode lasts
        assert!(!r.decision_log.actions().any(|a| matches!(a, Action::IsolateNode { .. })));
        assert!(
            r.accumulated_waf < healthy.accumulated_waf,
            "gray episode must cost goodput: {} vs {}",
            r.accumulated_waf,
            healthy.accumulated_waf
        );
        // and it ends on its own — the final WAF level is back to healthy
        assert_eq!(r.waf_series.last().unwrap().1, healthy.waf_series.last().unwrap().1);
    }

    #[test]
    fn churn_advisories_flow_as_node_degraded_verdicts() {
        let (cluster, cfg, specs) = setup();
        let tc = TraceConfig {
            name: "churn".into(),
            duration_s: 6.0 * 3600.0,
            n_nodes: cluster.n_nodes,
            expect_sev1: 0.0,
            expect_other: 0.0,
            repair_min_s: 3600.0,
            repair_max_s: 7200.0,
        };
        let trace = Trace::generate(tc, 1).with_spot_churn(3, 120.0, 9);
        let r = Simulator::builder()
            .cluster(cluster)
            .config(cfg)
            .policy(PolicyKind::Unicron)
            .tasks(&specs)
            .build()
            .run(&trace);
        assert!(
            r.decision_log.events().any(|e| matches!(
                e,
                CoordEvent::NodeDegraded { kind: DegradationKind::ChurnRisk, .. }
            )),
            "churn advisories must reach the policy as typed verdicts"
        );
        // and the preemptions themselves still land as SEV1s
        assert!(r
            .decision_log
            .events()
            .any(|e| matches!(e, CoordEvent::ErrorReport { .. } | CoordEvent::NodeLost { .. })));
    }

    #[test]
    fn degradation_free_traces_emit_no_timing_events() {
        let trace = Trace::generate(TraceConfig::trace_a(), 42);
        let r = run(PolicyKind::Unicron, &trace);
        assert!(!r.decision_log.events().any(|e| matches!(
            e,
            CoordEvent::StepTiming { .. } | CoordEvent::NodeDegraded { .. }
        )));
    }

    #[test]
    fn task_churn_is_simulated_end_to_end() {
        let (cluster, cfg, specs) = setup();
        let trace = Trace::generate(TraceConfig::trace_a(), 13).with_task_churn(6, 2, 2, 13);
        let r = Simulator::builder()
            .cluster(cluster)
            .config(cfg)
            .policy(PolicyKind::Unicron)
            .tasks(&specs)
            .build()
            .run(&trace);
        let launches = r
            .decision_log
            .events()
            .filter(|e| matches!(e, CoordEvent::TaskLaunched { .. }))
            .count();
        let finishes = r
            .decision_log
            .events()
            .filter(|e| matches!(e, CoordEvent::TaskFinished { .. }))
            .count();
        assert_eq!(launches, 3, "bootstrap + two arrivals");
        assert_eq!(finishes, 2, "two departures");
        assert!(r.accumulated_waf > 0.0);
        // arriving work raises cluster WAF over the pre-arrival level at
        // some point (the late tasks actually get scheduled)
        let healthy0 = r.waf_series[0].1;
        let peak = r.waf_series.iter().map(|&(_, w)| w).fold(0.0, f64::max);
        assert!(peak > healthy0, "late arrivals must add WAF: {peak} vs {healthy0}");
    }

    #[test]
    fn departures_release_capacity_to_survivors() {
        let (cluster, cfg, specs) = setup();
        let mut tc = TraceConfig::trace_a();
        tc.expect_sev1 = 0.0;
        tc.expect_other = 0.0;
        // no failures: three tasks leave halfway; survivors replan upward
        let trace = Trace::generate(tc, 3).with_task_churn(6, 0, 3, 3);
        let r = Simulator::builder()
            .cluster(cluster)
            .config(cfg)
            .policy(PolicyKind::Unicron)
            .tasks(&specs)
            .build()
            .run(&trace);
        let first = r.waf_series.first().unwrap().1;
        let last = r.waf_series.last().unwrap().1;
        assert!(last > 0.0, "survivors keep training");
        assert!(last < first, "fewer tasks -> less total weighted work");
        // the replans grew at least one surviving task beyond its t=0 share
        let grew = r.decision_log.iter().any(|en| {
            matches!(en.event, CoordEvent::TaskFinished { .. })
                && en.actions.iter().any(|x| matches!(x, Action::ApplyPlan { .. }))
        });
        assert!(grew, "task finish must trigger a coordinator replan");
    }
}
